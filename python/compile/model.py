"""Layer-2: the QAT transformer (tiny-LLaMA) in pure JAX.

This is the build-time model definition that `aot.py` lowers to HLO text
for the Rust coordinator. It implements:

  * a LLaMA-style decoder (RMSNorm, RoPE, SwiGLU, causal attention),
  * QAT linear layers for every quantization method the paper compares
    (sherry34 / absmean / absmedian / twn / binary / lsq / seq / dlt),
    with the Straight-Through Estimator and the Arenas annealing residual
    synapse  Y = X·Tα + λ_t·X·W  (paper Eq. 7),
  * cross-entropy loss and an Adam train step,
  * forward/eval graphs whose sherry34 path calls the Layer-1 Pallas
    kernels (quantize34 / ternary_matmul).

STE wiring (no custom_vjp needed):

    deq = dequant(stop_gradient(W), aux)        # aux stays differentiable
    Q   = deq + (W - stop_gradient(W))          # identity gradient to W
    Y   = X @ Q + λ_t * (X @ W)                 # Arenas residual

which yields exactly the paper's gradients:  ∂L/∂W ≈ (1+λ)·Xᵀ∂L/∂Y
(Eq. 2 plus the residual term) and ∂L/∂X = ∂L/∂Y·(Tα + λW)ᵀ (Eq. 8).

Params are a flat ordered dict (name → array); the same ordering is used
for the PJRT ABI and written into the artifact manifest by `aot.py`.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels import quantize34 as pallas_quantize34
from .kernels import ternary_matmul as pallas_ternary_matmul
from .kernels import ref

Params = Dict[str, jnp.ndarray]

# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters.

    Dimensions are chosen as multiples of 128 so Pallas COL_TILE tiling and
    the paper's group size both divide evenly.
    """

    vocab_size: int = 256
    d_model: int = 128
    n_layers: int = 2
    n_heads: int = 4
    d_ff: int = 384
    seq_len: int = 64

    # QAT settings
    method: str = "sherry34"  # quantizer registry key
    granularity: str = "per_channel"  # per_tensor | per_channel | per_group
    group_size: int = 128
    use_arenas: bool = True  # when False λ_t is forced to 0
    # Use the Pallas kernels on the (non-differentiated) quantize path of
    # the *forward* graph. The train graph keeps plain-jnp quantize for
    # compact HLO; both are tested equal.
    pallas_forward: bool = True

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


# Named configs used by the Rust side (keep in sync with rust/src/config).
CONFIGS: Dict[str, ModelConfig] = {
    "nano": ModelConfig(vocab_size=256, d_model=128, n_layers=2, n_heads=4, d_ff=384, seq_len=64),
    "micro": ModelConfig(vocab_size=512, d_model=256, n_layers=4, n_heads=4, d_ff=768, seq_len=128),
    "e2e": ModelConfig(vocab_size=1024, d_model=384, n_layers=6, n_heads=6, d_ff=1152, seq_len=128),
}


# ---------------------------------------------------------------------------
# Quantizer registry: method -> dequant(stopped_w, aux) -> (d_in, d_out)
# ---------------------------------------------------------------------------


def _granular(w: jnp.ndarray, cfg: ModelConfig, tern_fn, scale_fn):
    """Apply a (ternarize, scale) pair at the configured granularity.

    tern_fn: w -> T.  scale_fn: (w, t) -> per-channel scales for its input.
    Granularity reshapes rows into groups so that each (group, channel)
    cell gets its own scale; per-tensor collapses everything into one
    column.
    """
    d_in, d_out = w.shape
    t = tern_fn(w)
    if cfg.granularity == "per_channel":
        alpha = scale_fn(w, t)  # (d_out,)
        return t * alpha[None, :]
    if cfg.granularity == "per_tensor":
        alpha = scale_fn(w.reshape(-1, 1), t.reshape(-1, 1))  # (1,)
        return t * alpha[0]
    if cfg.granularity == "per_group":
        g = cfg.group_size
        assert d_in % g == 0, "group_size must divide d_in"
        wg = w.reshape(d_in // g, g, d_out)
        tg = t.reshape(d_in // g, g, d_out)
        # vmap the per-channel scale over groups.
        alpha = jax.vmap(scale_fn)(wg, tg)  # (d_in/g, d_out)
        return (tg * alpha[:, None, :]).reshape(d_in, d_out)
    raise ValueError(f"unknown granularity {cfg.granularity}")


def _deq_sherry34(w, aux, cfg: ModelConfig):
    return _granular(w, cfg, ref.sherry34_ternary, ref.sherry34_scale)


def _deq_sherry34_pallas(w, aux, cfg: ModelConfig):
    # Pallas path (forward graphs only): per-channel granularity.
    if cfg.granularity == "per_channel" and w.shape[1] % 128 == 0:
        t, alpha = pallas_quantize34(w)
        return t * alpha[None, :]
    return _deq_sherry34(w, aux, cfg)


def _mk_threshold_deq(tern_of):
    def deq(w, aux, cfg: ModelConfig):
        def tern(wx):
            return ref._threshold_ternary(wx, tern_of(wx))

        return _granular(w, cfg, tern, ref._masked_absmean_scale)

    return deq


def _deq_binary(w, aux, cfg: ModelConfig):
    def tern(wx):
        return jnp.where(wx >= 0, 1.0, -1.0)

    def scale(wx, tx):
        return jnp.mean(jnp.abs(wx), axis=0)

    return _granular(w, cfg, tern, scale)


def _deq_lsq(w, aux, cfg: ModelConfig):
    """LSQ-style: learnable per-channel step `aux`; round(clamp(w/s)) · s.

    The gradient to `aux` flows naturally because only `w` is stopped.
    """
    s = jnp.maximum(jnp.abs(aux), 1e-6)
    t = jnp.clip(jnp.round(w / s[None, :]), -1.0, 1.0)
    return jax.lax.stop_gradient(t) * s[None, :]


def _deq_seq(w, aux, cfg: ModelConfig):
    """SEQ (ParetoQ-style, paper Eq. 20): zero state re-assigned to α·b."""
    abs_mean = jnp.mean(jnp.abs(w), axis=0)
    t = ref._threshold_ternary(w, abs_mean / 2.0)
    alpha = ref._masked_absmean_scale(w, t)
    deq = t * alpha[None, :]
    zero_fill = (alpha * aux)[None, :] * (t == 0)
    return deq + zero_fill


def _deq_dlt(w, aux, cfg: ModelConfig):
    """DLT (TernaryLLM-style, paper Eq. 19): additive learnable bias."""
    t, alpha = ref.absmean_quantize(w)
    return t * alpha[None, :] + aux[None, :] / jnp.sqrt(w.shape[0]).astype(w.dtype)


def _deq_tequila(w, aux, cfg: ModelConfig):
    """Tequila-style trap-mitigated ternary: absmean thresholds with a
    magnitude-compensated scale (survivor absmean, slightly sharpened
    threshold 0.4·E|w| per the TequilaLLM recipe)."""

    def tern(wx):
        return ref._threshold_ternary(wx, 0.4 * jnp.mean(jnp.abs(wx), axis=0))

    return _granular(w, cfg, tern, ref._masked_absmean_scale)


def _deq_bf16(w, aux, cfg: ModelConfig):
    """Identity 'quantizer': the full-precision reference rows of
    Tables 1-2. With STE wiring, q = w exactly."""
    return w


QUANTIZERS: Dict[str, Callable] = {
    "bf16": _deq_bf16,
    "sherry34": _deq_sherry34,
    "absmean": _mk_threshold_deq(lambda w: jnp.mean(jnp.abs(w), axis=0) / 2.0),
    "absmedian": _mk_threshold_deq(lambda w: jnp.median(jnp.abs(w), axis=0) / 2.0),
    "twn": _mk_threshold_deq(lambda w: 0.7 * jnp.mean(jnp.abs(w), axis=0)),
    "binary": _deq_binary,
    "lsq": _deq_lsq,
    "seq": _deq_seq,
    "dlt": _deq_dlt,
    "tequila": _deq_tequila,
}

# Methods whose `aux` parameter is trained.
LEARNABLE_AUX = {"lsq", "seq", "dlt"}


# ---------------------------------------------------------------------------
# QAT linear
# ---------------------------------------------------------------------------


def qat_linear(x, w, aux, lam, cfg: ModelConfig, *, forward_only: bool = False):
    """Quantization-aware linear with STE + Arenas residual (Eq. 7).

    forward_only=True builds the inference graph: pure quantized matmul
    with λ ignored (post-training, λ has annealed to 0) and the Pallas
    kernels on the sherry34 path.
    """
    deq_fn = QUANTIZERS[cfg.method]
    if forward_only:
        if cfg.method == "sherry34" and cfg.pallas_forward and cfg.granularity == "per_channel" and w.shape[1] % 128 == 0:
            t, alpha = pallas_quantize34(w)
            return pallas_ternary_matmul(x, t, alpha)
        deq = deq_fn(jax.lax.stop_gradient(w), aux, cfg)
        return x @ deq

    w_stop = jax.lax.stop_gradient(w)
    deq = deq_fn(w_stop, aux, cfg)
    q = deq + (w - w_stop)  # STE
    y = x @ q
    if cfg.use_arenas:
        y = y + lam * (x @ w)
    return y


# ---------------------------------------------------------------------------
# Model definition
# ---------------------------------------------------------------------------


def _linear_names(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, int]]]:
    d, f = cfg.d_model, cfg.d_ff
    names = []
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        names += [
            (p + "wq", (d, d)),
            (p + "wk", (d, d)),
            (p + "wv", (d, d)),
            (p + "wo", (d, d)),
            (p + "w_gate", (d, f)),
            (p + "w_up", (d, f)),
            (p + "w_down", (f, d)),
        ]
    return names


def param_spec(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) list — the PJRT ABI. Keep deterministic!"""
    spec: List[Tuple[str, Tuple[int, ...]]] = [("embed", (cfg.vocab_size, cfg.d_model))]
    for i in range(cfg.n_layers):
        spec.append((f"layer{i}.norm_attn", (cfg.d_model,)))
        spec.append((f"layer{i}.norm_mlp", (cfg.d_model,)))
    for name, shape in _linear_names(cfg):
        spec.append((name, shape))
        spec.append((name + ".aux", (shape[1],)))
    spec.append(("norm_out", (cfg.d_model,)))
    spec.append(("lm_head", (cfg.d_model, cfg.vocab_size)))
    return spec


def init_params(key, cfg: ModelConfig) -> Params:
    params: Params = {}
    for name, shape in param_spec(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(".aux"):
            if cfg.method == "lsq":
                params[name] = jnp.full(shape, 0.05, jnp.float32)
            else:
                params[name] = jnp.zeros(shape, jnp.float32)
        elif name.endswith(("norm_attn", "norm_mlp", "norm_out")):
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            fan_in = shape[0]
            params[name] = jax.random.normal(sub, shape, jnp.float32) * (fan_in**-0.5)
    return params


def rmsnorm(x, g, eps: float = 1e-5):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps) * g


def rope(x, positions):
    """Rotary position embedding over the last dim (pairs)."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs[None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _attention(q, k, v, causal: bool = True):
    # q,k,v: (B, T, H, Dh)
    scale = q.shape[-1] ** -0.5
    att = jnp.einsum("bthd,bshd->bhts", q, k) * scale
    if causal:
        t, s = att.shape[-2], att.shape[-1]
        mask = jnp.tril(jnp.ones((t, s), bool), k=s - t)
        att = jnp.where(mask[None, None], att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    return jnp.einsum("bhts,bshd->bthd", att, v)


def forward(params: Params, tokens, lam, cfg: ModelConfig, *, forward_only: bool = False):
    """Logits for a (B, T) int32 token batch."""
    b, t = tokens.shape
    h = params["embed"][tokens]  # (B, T, D)
    pos = jnp.arange(t)[None, :].repeat(b, axis=0)

    def lin(name, x2d):
        w = params[name]
        aux = params[name + ".aux"]
        return qat_linear(x2d, w, aux, lam, cfg, forward_only=forward_only)

    for i in range(cfg.n_layers):
        p = f"layer{i}."
        xin = rmsnorm(h, params[p + "norm_attn"])
        x2 = xin.reshape(b * t, cfg.d_model)
        q = lin(p + "wq", x2).reshape(b, t, cfg.n_heads, cfg.head_dim)
        k = lin(p + "wk", x2).reshape(b, t, cfg.n_heads, cfg.head_dim)
        v = lin(p + "wv", x2).reshape(b, t, cfg.n_heads, cfg.head_dim)
        q, k = rope(q, pos[..., None]), rope(k, pos[..., None])
        att = _attention(q, k, v).reshape(b * t, cfg.d_model)
        h = h + lin(p + "wo", att).reshape(b, t, cfg.d_model)

        xin = rmsnorm(h, params[p + "norm_mlp"])
        x2 = xin.reshape(b * t, cfg.d_model)
        gate = jax.nn.silu(lin(p + "w_gate", x2))
        up = lin(p + "w_up", x2)
        down = lin(p + "w_down", gate * up)
        h = h + down.reshape(b, t, cfg.d_model)

    h = rmsnorm(h, params["norm_out"])
    return h.reshape(b * t, cfg.d_model) @ params["lm_head"]


def loss_fn(params: Params, batch, lam, cfg: ModelConfig):
    """Next-token cross entropy. batch: (B, T+1) int32."""
    tokens, targets = batch[:, :-1], batch[:, 1:]
    logits = forward(params, tokens, lam, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    tgt = targets.reshape(-1)
    nll = -jnp.take_along_axis(logp, tgt[:, None], axis=-1)[:, 0]
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# Adam train step (flat-ordered ABI for PJRT)
# ---------------------------------------------------------------------------

ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.95, 1e-8


def _trainable(name: str, cfg: ModelConfig) -> bool:
    if name.endswith(".aux"):
        return cfg.method in LEARNABLE_AUX
    return True


def train_step(params: Params, m: Params, v: Params, batch, step, lam, lr, cfg: ModelConfig):
    """One Adam step. Returns (loss, params', m', v')."""
    loss, grads = jax.value_and_grad(loss_fn)(params, batch, lam, cfg)
    step_f = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - ADAM_B1**step_f
    bc2 = 1.0 - ADAM_B2**step_f
    new_p, new_m, new_v = {}, {}, {}
    for name in params:
        g = grads[name]
        if not _trainable(name, cfg):
            g = jnp.zeros_like(g)
        nm = ADAM_B1 * m[name] + (1.0 - ADAM_B1) * g
        nv = ADAM_B2 * v[name] + (1.0 - ADAM_B2) * (g * g)
        upd = (nm / bc1) / (jnp.sqrt(nv / bc2) + ADAM_EPS)
        new_p[name] = params[name] - lr * upd
        new_m[name] = nm
        new_v[name] = nv
    return loss, new_p, new_m, new_v


# ---------------------------------------------------------------------------
# Flat ABI helpers for aot.py
# ---------------------------------------------------------------------------


def flatten(params: Params, cfg: ModelConfig):
    return [params[name] for name, _ in param_spec(cfg)]


def unflatten(flat, cfg: ModelConfig) -> Params:
    return {name: arr for (name, _), arr in zip(param_spec(cfg), flat)}


def make_train_step_fn(cfg: ModelConfig):
    """(flat_params..., flat_m..., flat_v..., batch, step, lam, lr) -> tuple."""
    n = len(param_spec(cfg))

    def fn(*args):
        flat_p, flat_m, flat_v = args[:n], args[n : 2 * n], args[2 * n : 3 * n]
        batch, step, lam, lr = args[3 * n :]
        p, m, v = unflatten(flat_p, cfg), unflatten(flat_m, cfg), unflatten(flat_v, cfg)
        loss, p2, m2, v2 = train_step(p, m, v, batch, step, lam, lr, cfg)
        return tuple([loss] + flatten(p2, cfg) + flatten(m2, cfg) + flatten(v2, cfg))

    return fn


def make_forward_fn(cfg: ModelConfig, forward_only: bool = True):
    """(flat_params..., tokens) -> (logits,). λ fixed at 0 (post-anneal)."""
    n = len(param_spec(cfg))

    def fn(*args):
        flat_p, tokens = args[:n], args[n]
        p = unflatten(flat_p, cfg)
        logits = forward(p, tokens, jnp.float32(0.0), cfg, forward_only=forward_only)
        return (logits,)

    return fn


def make_loss_fn(cfg: ModelConfig):
    """(flat_params..., batch, lam) -> (loss,). For eval perplexity."""
    n = len(param_spec(cfg))

    def fn(*args):
        flat_p, batch, lam = args[:n], args[n], args[n + 1]
        p = unflatten(flat_p, cfg)
        return (loss_fn(p, batch, lam, cfg),)

    return fn
