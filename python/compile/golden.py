"""Golden-vector exporter: cross-language ground truth for the Rust side.

Writes ``artifacts/golden/*.bin`` files in a trivial binary format

    [u32 rows LE][u32 cols LE][f32 data row-major LE]

so ``rust/src/quant`` and ``rust/src/engine`` can be tested bit-for-bit
against the jnp oracles without any PRNG coordination. Vectors (α, y) are
stored as 1×n matrices.

Invoked from ``aot.py`` (part of ``make artifacts``).
"""

from __future__ import annotations

import os
import struct

import numpy as np
import jax.numpy as jnp

from .kernels import ref
from . import model as M

SEED = 20260710
D_IN, D_OUT, D_T = 256, 128, 16


def write_mat(path: str, a: np.ndarray):
    a = np.asarray(a, dtype=np.float32)
    if a.ndim == 1:
        a = a[None, :]
    assert a.ndim == 2
    with open(path, "wb") as f:
        f.write(struct.pack("<II", a.shape[0], a.shape[1]))
        f.write(a.astype("<f4").tobytes())


def export_golden(out_dir: str):
    gdir = os.path.join(out_dir, "golden")
    os.makedirs(gdir, exist_ok=True)
    rng = np.random.default_rng(SEED)
    w = rng.normal(size=(D_IN, D_OUT)).astype(np.float32)
    x = rng.normal(size=(D_T, D_IN)).astype(np.float32)
    wj = jnp.asarray(w)
    write_mat(os.path.join(gdir, "w.bin"), w)
    write_mat(os.path.join(gdir, "x.bin"), x)

    # Per-channel quantizers: T and alpha for each method.
    cases = {
        "sherry34": ref.sherry34_quantize,
        "absmean": ref.absmean_quantize,
        "absmedian": ref.absmedian_quantize,
        "twn": ref.twn_quantize,
        "binary": ref.binary_quantize,
    }
    for name, fn in cases.items():
        t, a = fn(wj)
        write_mat(os.path.join(gdir, f"{name}.t.bin"), np.asarray(t))
        write_mat(os.path.join(gdir, f"{name}.alpha.bin"), np.asarray(a))

    # Sherry at all three granularities: full dequant matrix.
    for gran in ("per_tensor", "per_channel", "per_group"):
        cfg = M.ModelConfig(**{**M.CONFIGS["nano"].__dict__, "granularity": gran, "group_size": 128})
        deq = M._deq_sherry34(wj, None, cfg)
        write_mat(os.path.join(gdir, f"sherry34_{gran}.deq.bin"), np.asarray(deq))

    # Matmul ground truth for the LUT engine: y = x @ (T∘α), sherry per-channel.
    t, a = ref.sherry34_quantize(wj)
    y = ref.ternary_matmul(jnp.asarray(x), t, a)
    write_mat(os.path.join(gdir, "sherry34.y.bin"), np.asarray(y))

    # Arenas forward ground truth at λ = 0.37.
    ya = ref.arenas_matmul(jnp.asarray(x), t, a, wj, 0.37)
    write_mat(os.path.join(gdir, "sherry34.arenas_y.bin"), np.asarray(ya))

    # Effective-rank scalars for the Rust SVD/ER implementation.
    g1 = rng.normal(size=(64, 48)).astype(np.float32)
    g2 = (np.outer(rng.normal(size=64), rng.normal(size=48)) + 0.01 * rng.normal(size=(64, 48))).astype(np.float32)
    write_mat(os.path.join(gdir, "er_g1.bin"), g1)
    write_mat(os.path.join(gdir, "er_g2.bin"), g2)
    ers = np.array(
        [float(ref.effective_rank(jnp.asarray(g1))), float(ref.effective_rank(jnp.asarray(g2)))],
        dtype=np.float32,
    )
    write_mat(os.path.join(gdir, "er_expected.bin"), ers)
    print(f"  wrote golden vectors to {gdir}")
