"""AOT exporter: lower the Layer-2 graphs to HLO *text* artifacts.

HLO text (NOT ``lowered.compile().serialize()``) is the interchange format:
the ``xla`` crate's xla_extension 0.5.1 rejects jax≥0.5 serialized protos
(64-bit instruction ids); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Outputs, under ``artifacts/``:

  {cfg}_{method}_{gran}.train.hlo.txt   Adam train step (fwd+bwd, STE+Arenas)
  {cfg}_{method}_{gran}.loss.hlo.txt    eval loss (perplexity)
  {cfg}_{method}_{gran}.fwd.hlo.txt     inference logits (Pallas sherry path)
  kernel_quantize34.hlo.txt             standalone L1 kernel round-trip test
  kernel_ternary_matmul.hlo.txt         standalone L1 kernel round-trip test
  {cfg}.params.tsv                      ordered param ABI (name, shape)
  manifest.tsv                          artifact index for the Rust runtime

Batch sizes are fixed per config (PJRT executables are shape-specialized);
the Rust coordinator reads them from the manifest.

Run as ``python -m compile.aot --out ../artifacts`` from ``python/``.
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import quantize34, ternary_matmul

# (config name, batch size) pairs: train batch is (B, T+1) int32.
BATCH = {"nano": 16, "micro": 8, "e2e": 8}

ALL_METHODS = [
    "bf16",
    "sherry34",
    "absmean",
    "absmedian",
    "twn",
    "binary",
    "lsq",
    "seq",
    "dlt",
    "tequila",
]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def export_model_artifacts(cfg_name: str, method: str, granularity: str, out_dir: str, kinds):
    cfg = M.CONFIGS[cfg_name]
    cfg = M.ModelConfig(**{**cfg.__dict__, "method": method, "granularity": granularity})
    b = BATCH[cfg_name]
    pspecs = [_spec(s) for _, s in M.param_spec(cfg)]
    stem = f"{cfg_name}_{method}_{granularity}"
    rows = []

    if "train" in kinds:
        fn = M.make_train_step_fn(cfg)
        args = (
            pspecs
            + pspecs
            + pspecs
            + [
                _spec((b, cfg.seq_len + 1), jnp.int32),
                _spec((), jnp.int32),
                _spec((), jnp.float32),
                _spec((), jnp.float32),
            ]
        )
        path = f"{stem}.train.hlo.txt"
        _write(out_dir, path, to_hlo_text(jax.jit(fn, keep_unused=True).lower(*args)))
        rows.append((stem, "train", cfg_name, method, granularity, path, str(len(pspecs)), str(b)))

    if "loss" in kinds:
        fn = M.make_loss_fn(cfg)
        args = pspecs + [_spec((b, cfg.seq_len + 1), jnp.int32), _spec((), jnp.float32)]
        path = f"{stem}.loss.hlo.txt"
        _write(out_dir, path, to_hlo_text(jax.jit(fn, keep_unused=True).lower(*args)))
        rows.append((stem, "loss", cfg_name, method, granularity, path, str(len(pspecs)), str(b)))

    if "fwd" in kinds:
        fn = M.make_forward_fn(cfg)
        args = pspecs + [_spec((b, cfg.seq_len), jnp.int32)]
        path = f"{stem}.fwd.hlo.txt"
        _write(out_dir, path, to_hlo_text(jax.jit(fn, keep_unused=True).lower(*args)))
        rows.append((stem, "fwd", cfg_name, method, granularity, path, str(len(pspecs)), str(b)))

    return rows


def export_param_spec(cfg_name: str, out_dir: str):
    cfg = M.CONFIGS[cfg_name]
    lines = [f"{name}\t{','.join(map(str, shape))}" for name, shape in M.param_spec(cfg)]
    _write(out_dir, f"{cfg_name}.params.tsv", "\n".join(lines) + "\n")


def export_kernel_artifacts(out_dir: str):
    """Standalone Pallas kernels for Rust runtime integration tests."""
    w = _spec((512, 256))
    path = "kernel_quantize34.hlo.txt"
    _write(out_dir, path, to_hlo_text(jax.jit(lambda w: tuple(quantize34(w))).lower(w)))
    x, t, a = _spec((16, 512)), _spec((512, 256)), _spec((256,))
    path2 = "kernel_ternary_matmul.hlo.txt"
    _write(
        out_dir,
        path2,
        to_hlo_text(jax.jit(lambda x, t, a: (ternary_matmul(x, t, a),)).lower(x, t, a)),
    )
    return [
        ("kernel_quantize34", "kernel", "-", "-", "-", path, "1", "-"),
        ("kernel_ternary_matmul", "kernel", "-", "-", "-", path2, "3", "-"),
    ]


def _write(out_dir: str, rel: str, text: str):
    p = os.path.join(out_dir, rel)
    with open(p, "w") as f:
        f.write(text)
    print(f"  wrote {rel} ({len(text) // 1024} KiB)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--fast", action="store_true", help="nano sherry34+absmean only (CI)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    from .golden import export_golden

    export_golden(args.out)

    rows = []
    rows += export_kernel_artifacts(args.out)

    if args.fast:
        plan = [("nano", ["sherry34", "absmean"], "per_channel", ("train", "loss", "fwd"))]
        cfgs = ["nano"]
    else:
        plan = [
            ("nano", ALL_METHODS, "per_channel", ("train", "loss", "fwd")),
            ("nano", ["sherry34"], "per_tensor", ("train", "loss")),
            ("nano", ["sherry34"], "per_group", ("train", "loss")),
            ("micro", ["sherry34", "absmean"], "per_channel", ("train", "loss", "fwd")),
            ("e2e", ["sherry34"], "per_channel", ("train", "loss", "fwd")),
        ]
        cfgs = ["nano", "micro", "e2e"]

    for cfg_name in cfgs:
        export_param_spec(cfg_name, args.out)

    for cfg_name, methods, gran, kinds in plan:
        for method in methods:
            print(f"[aot] {cfg_name}/{method}/{gran} {kinds}")
            rows += export_model_artifacts(cfg_name, method, gran, args.out, kinds)

    header = "stem\tkind\tconfig\tmethod\tgranularity\tpath\tn_params\tbatch\n"
    with open(os.path.join(args.out, "manifest.tsv"), "w") as f:
        f.write(header + "\n".join("\t".join(r) for r in rows) + "\n")
    print(f"[aot] manifest: {len(rows)} artifacts")


if __name__ == "__main__":
    main()
