"""Pallas kernel: ternary matmul Y = X · (T ∘ α) (paper Eq. 2, inference path).

Tiling (DESIGN.md §6, L1):

  grid = (d_t / ROW_TILE, d_out / COL_TILE, d_in / K_TILE)

with an f32 accumulator tile revisited across the k axis — the classic
MXU-shaped schedule. K_TILE is a multiple of 4·128 so 3:4 sparse blocks
never straddle a VMEM tile, and the α scaling is applied once on the final
k step. On a real TPU, T would be streamed at 1.25 bits and widened to
bf16 in VMEM; under interpret=True both operands are f32 but the HBM↔VMEM
schedule expressed by the BlockSpecs is identical.

VMEM budget per program (defaults, f32): X tile 8×512×4B = 16 KB, T tile
512×128×4B = 256 KB (real TPU: 1.25-bit packed ≈ 10 KB), acc 8×128×4B =
4 KB — far under the 16 MB VMEM ceiling, leaving room for 4-deep double
buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_TILE = 8
COL_TILE = 128
K_TILE = 512


def _ternary_matmul_kernel(x_ref, t_ref, alpha_ref, o_ref):
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # The matmul itself: on TPU this hits the MXU with T widened to the
    # activation dtype; ternary values make it an add/sub tree on LUT
    # hardware, but the dataflow (and numerics) are this exact product.
    o_ref[...] += x_ref[...] @ t_ref[...]

    @pl.when(k == nk - 1)
    def _scale():
        o_ref[...] *= alpha_ref[...][None, :]


def _pick(tile: int, dim: int) -> int:
    """Largest divisor of ``dim`` that is ≤ tile (shapes in tests vary)."""
    t = min(tile, dim)
    while dim % t != 0:
        t -= 1
    return t


@functools.partial(jax.jit, static_argnames=())
def ternary_matmul(x: jnp.ndarray, t: jnp.ndarray, alpha: jnp.ndarray):
    """Y = X·(T∘α) as a tiled Pallas matmul.

    Args:
      x: (d_t, d_in) activations.
      t: (d_in, d_out) ternary weights in {-1,0,+1} (stored as x.dtype).
      alpha: (d_out,) per-channel scales.
    """
    d_t, d_in = x.shape
    d_in2, d_out = t.shape
    assert d_in == d_in2
    rt, ct, kt = _pick(ROW_TILE, d_t), _pick(COL_TILE, d_out), _pick(K_TILE, d_in)
    grid = (d_t // rt, d_out // ct, d_in // kt)
    return pl.pallas_call(
        _ternary_matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rt, kt), lambda i, j, k: (i, k)),
            pl.BlockSpec((kt, ct), lambda i, j, k: (k, j)),
            pl.BlockSpec((ct,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((rt, ct), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((d_t, d_out), x.dtype),
        interpret=True,
    )(x, t, alpha)
