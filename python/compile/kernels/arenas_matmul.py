"""Pallas kernel: fused Arenas training forward Y = X·Tα + λ_t·X·W (Eq. 7).

During QAT both the ternary product and the full-precision residual read
the *same* X tile, so fusing them halves activation traffic — on TPU the
X tile is loaded into VMEM once and feeds two MXU passes (T widened, W
native). λ_t enters as a scalar in SMEM, prefetched per program.

Same grid/tiling as ``ternary_matmul``; the scale-and-residual epilogue
runs on the last k step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ternary_matmul import _pick, COL_TILE, K_TILE, ROW_TILE


def _arenas_kernel(lam_ref, x_ref, t_ref, alpha_ref, w_ref, tern_ref, res_ref):
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        tern_ref[...] = jnp.zeros_like(tern_ref)
        res_ref[...] = jnp.zeros_like(res_ref)

    x = x_ref[...]
    tern_ref[...] += x @ t_ref[...]
    res_ref[...] += x @ w_ref[...]

    @pl.when(k == nk - 1)
    def _epilogue():
        lam = lam_ref[0]
        tern_ref[...] = tern_ref[...] * alpha_ref[...][None, :] + lam * res_ref[...]


@functools.partial(jax.jit, static_argnames=())
def arenas_matmul(x, t, alpha, w, lam):
    """Fused Y = X·Tα + λ·X·W.

    Args:
      x: (d_t, d_in); t, w: (d_in, d_out); alpha: (d_out,); lam: scalar.

    Returns:
      (d_t, d_out) output. The residual accumulator is an internal
      second output discarded here (Pallas needs it materialized to
      revisit across k steps).
    """
    d_t, d_in = x.shape
    _, d_out = t.shape
    rt, ct, kt = _pick(ROW_TILE, d_t), _pick(COL_TILE, d_out), _pick(K_TILE, d_in)
    grid = (d_t // rt, d_out // ct, d_in // kt)
    lam_arr = jnp.asarray(lam, x.dtype).reshape(1)
    out, _res = pl.pallas_call(
        _arenas_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i, j, k: (0,)),
            pl.BlockSpec((rt, kt), lambda i, j, k: (i, k)),
            pl.BlockSpec((kt, ct), lambda i, j, k: (k, j)),
            pl.BlockSpec((ct,), lambda i, j, k: (j,)),
            pl.BlockSpec((kt, ct), lambda i, j, k: (k, j)),
        ],
        out_specs=[
            pl.BlockSpec((rt, ct), lambda i, j, k: (i, j)),
            pl.BlockSpec((rt, ct), lambda i, j, k: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d_t, d_out), x.dtype),
            jax.ShapeDtypeStruct((d_t, d_out), x.dtype),
        ],
        interpret=True,
    )(lam_arr, x, t, alpha, w)
    return out
