"""Pure-jnp reference oracles for the Sherry kernels and all baseline
ternary quantizers.

Everything here is the *correctness ground truth*: the Pallas kernels in
this package and the Rust implementations in ``rust/src/quant`` are both
tested against these functions (the Rust side via golden vectors exported
by ``python/tests/test_golden.py``).

Shapes follow the paper's convention: ``W`` is ``(d_in, d_out)``, ``X`` is
``(d_t, d_in)``, quantization is per output channel (column) unless a
granularity is specified.
"""

from __future__ import annotations

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Sherry 3:4 sparse ternary quantization (paper Eq. 3-5, App. D)
# ---------------------------------------------------------------------------


def sherry34_ternary(w: jnp.ndarray) -> jnp.ndarray:
    """Optimal 3:4 sparse ternary assignment T* (paper Eq. 4).

    For every contiguous block of four weights along axis 0, the element
    with the smallest |w| is pruned to 0 and the remaining three take
    sign(w). Ties are broken toward the *lowest index*, matching the Rust
    implementation (stable argmin).
    """
    d_in, d_out = w.shape
    assert d_in % 4 == 0, "d_in must be a multiple of the block size 4"
    blocks = jnp.abs(w).reshape(d_in // 4, 4, d_out)
    # Stable argmin over the block dimension.
    prune = jnp.argmin(blocks, axis=1)  # (d_in/4, d_out)
    lane = jnp.arange(4)[None, :, None]
    keep = lane != prune[:, None, :]
    t = jnp.sign(w).reshape(d_in // 4, 4, d_out) * keep
    return t.reshape(d_in, d_out)


def sherry34_scale(w: jnp.ndarray, t: jnp.ndarray) -> jnp.ndarray:
    """Optimal per-channel scale α* (paper Eq. 5).

    α_j = (4 / (3·d_in)) · Σ_{i∈S_j} |W_ij| — i.e. the mean |w| over the
    3·d_in/4 surviving (non-pruned) entries of column j.
    """
    d_in = w.shape[0]
    active = (t != 0).astype(w.dtype)
    return (4.0 / (3.0 * d_in)) * jnp.sum(jnp.abs(w) * active, axis=0)


def sherry34_quantize(w: jnp.ndarray):
    """Full Sherry quantizer: returns (T, α) with T 3:4-sparse ternary."""
    t = sherry34_ternary(w)
    alpha = sherry34_scale(w, t)
    return t, alpha


def sherry34_dequant(t: jnp.ndarray, alpha: jnp.ndarray) -> jnp.ndarray:
    """Dequantized weights Tα (element-wise column scaling)."""
    return t * alpha[None, :]


# ---------------------------------------------------------------------------
# Ternary matmul + Arenas forward (paper Eq. 2, Eq. 7)
# ---------------------------------------------------------------------------


def ternary_matmul(x: jnp.ndarray, t: jnp.ndarray, alpha: jnp.ndarray) -> jnp.ndarray:
    """Y = X · (T ∘ α): the multiplication-free inference matmul."""
    return (x @ t) * alpha[None, :]


def arenas_matmul(
    x: jnp.ndarray,
    t: jnp.ndarray,
    alpha: jnp.ndarray,
    w: jnp.ndarray,
    lam,
) -> jnp.ndarray:
    """Arenas training forward Y = X·Tα + λ_t·X·W (paper Eq. 7)."""
    return ternary_matmul(x, t, alpha) + lam * (x @ w)


# ---------------------------------------------------------------------------
# Baseline ternary quantizers (paper §2.1, App. E)
# ---------------------------------------------------------------------------


def _threshold_ternary(w: jnp.ndarray, delta: jnp.ndarray) -> jnp.ndarray:
    """General thresholded ternarization (paper Eq. 1): ±1 outside ±Δ_j."""
    return jnp.where(w > delta[None, :], 1.0, jnp.where(w < -delta[None, :], -1.0, 0.0))


def _masked_absmean_scale(w: jnp.ndarray, t: jnp.ndarray) -> jnp.ndarray:
    """α_j = mean |w| over active entries (paper Eq. 18); 0 if none."""
    active = (t != 0).astype(w.dtype)
    n = jnp.sum(active, axis=0)
    s = jnp.sum(jnp.abs(w) * active, axis=0)
    return jnp.where(n > 0, s / jnp.maximum(n, 1.0), 0.0)


def absmean_quantize(w: jnp.ndarray):
    """BitNet-style AbsMean (paper Eq. 15): Δ_j = α̅_j/2, α̅_j = mean|W_:,j|."""
    abs_mean = jnp.mean(jnp.abs(w), axis=0)
    t = _threshold_ternary(w, abs_mean / 2.0)
    return t, _masked_absmean_scale(w, t)


def absmedian_quantize(w: jnp.ndarray):
    """AbsMedian variant: Δ_j = median(|W_:,j|)/2."""
    abs_med = jnp.median(jnp.abs(w), axis=0)
    t = _threshold_ternary(w, abs_med / 2.0)
    return t, _masked_absmean_scale(w, t)


def twn_quantize(w: jnp.ndarray):
    """Ternary Weight Networks (paper Eq. 17): Δ*_j ≈ 0.7·E|W_:,j|."""
    t = _threshold_ternary(w, 0.7 * jnp.mean(jnp.abs(w), axis=0))
    return t, _masked_absmean_scale(w, t)


def binary_quantize(w: jnp.ndarray):
    """1-bit sign quantization with absmean scale (Fig. 6 ablation arm)."""
    t = jnp.where(w >= 0, 1.0, -1.0)
    return t, jnp.mean(jnp.abs(w), axis=0)


# ---------------------------------------------------------------------------
# Arenas λ_t schedules (paper Eq. 23-25, Fig. 7)
# ---------------------------------------------------------------------------


def lambda_linear(p):
    return 1.0 - p


def lambda_cosine(p):
    return 0.5 * (1.0 + jnp.cos(jnp.pi * p))


def lambda_exponential(p):
    return jnp.exp(-5.0 * p)


def lambda_with_warmup(base, p, warmup: float = 0.1):
    """Ramp 0→1 over the first ``warmup`` fraction, then decay on the
    re-normalized remaining progress."""
    ramp = p / warmup
    rest = (p - warmup) / (1.0 - warmup)
    return jnp.where(p < warmup, ramp, base(jnp.clip(rest, 0.0, 1.0)))


# ---------------------------------------------------------------------------
# Effective rank (paper Eq. 21-22, App. F)
# ---------------------------------------------------------------------------


def effective_rank(g: jnp.ndarray, eps: float = 1e-12) -> jnp.ndarray:
    """ER(G) = exp(H(p)), p = σ/Σσ over the singular values of G."""
    s = jnp.linalg.svd(g, compute_uv=False)
    p = s / jnp.maximum(jnp.sum(s), eps)
    h = -jnp.sum(jnp.where(p > eps, p * jnp.log(p), 0.0))
    return jnp.exp(h)
