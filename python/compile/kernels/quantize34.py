"""Pallas kernel: Sherry 3:4 sparse-absmean block quantizer (paper Eq. 4-5).

The kernel tiles the weight matrix along the output-channel axis so each
program instance quantizes a full column stripe: the per-channel scale α_j
is a reduction over the whole column, so d_in is kept inside one block and
only d_out is gridded. For the LLaMA layer shapes the column stripe easily
fits VMEM (d_in ≤ 8192 → ≤ 4 MB per 128-channel stripe at f32).

TPU adaptation (DESIGN.md §Hardware-Adaptation): blocks of 4 never straddle
a tile because the tile covers all of d_in; the inner prune/sign selection
is pure VPU element-wise work; no MXU involvement.

interpret=True everywhere — real-TPU lowering would emit a Mosaic
custom-call the CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Output-channel tile. 128 matches the TPU lane width; it is also the
# paper's quantization group size, so per-group granularity reuses the
# same tiling.
COL_TILE = 128


def _quantize34_kernel(w_ref, t_ref, alpha_ref):
    """One column stripe: T* per Eq. 4, α* per Eq. 5."""
    w = w_ref[...]  # (d_in, COL_TILE)
    d_in = w.shape[0]
    aw = jnp.abs(w)
    blocks = aw.reshape(d_in // 4, 4, w.shape[1])
    # Stable argmin across the 4-lane axis → the pruned position.
    prune = jnp.argmin(blocks, axis=1)
    lane = jax.lax.broadcasted_iota(jnp.int32, blocks.shape, 1)
    keep = lane != prune[:, None, :]
    sign = jnp.where(w >= 0, 1.0, -1.0).reshape(blocks.shape)
    # sign(0) should be 0 for exact zeros so T stays ternary-faithful;
    # jnp.sign handles that, but we need the tie-break of argmin to zero
    # the *pruned* slot, so apply keep-mask to the sign grid.
    sign = jnp.where(w.reshape(blocks.shape) == 0.0, 0.0, sign)
    t = jnp.where(keep, sign, 0.0)
    t = t.reshape(d_in, w.shape[1])
    t_ref[...] = t
    # α_j = 4/(3 d_in) Σ_{active} |w| (Eq. 5).
    alpha_ref[...] = (4.0 / (3.0 * d_in)) * jnp.sum(aw * jnp.abs(t), axis=0)


@functools.partial(jax.jit, static_argnames=())
def quantize34(w: jnp.ndarray):
    """Sherry 3:4 quantizer as a Pallas call.

    Args:
      w: (d_in, d_out) float weights; d_in % 4 == 0, d_out % COL_TILE == 0.

    Returns:
      (t, alpha): t is (d_in, d_out) in {-1,0,+1} (as w.dtype), alpha is
      (d_out,) per-channel scales.
    """
    d_in, d_out = w.shape
    assert d_in % 4 == 0, "d_in must be a multiple of 4"
    assert d_out % COL_TILE == 0, f"d_out must be a multiple of {COL_TILE}"
    grid = (d_out // COL_TILE,)
    return pl.pallas_call(
        _quantize34_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((d_in, COL_TILE), lambda j: (0, j))],
        out_specs=[
            pl.BlockSpec((d_in, COL_TILE), lambda j: (0, j)),
            pl.BlockSpec((COL_TILE,), lambda j: (j,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d_in, d_out), w.dtype),
            jax.ShapeDtypeStruct((d_out,), w.dtype),
        ],
        interpret=True,
    )(w)
