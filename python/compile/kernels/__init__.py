"""Layer-1 Pallas kernels for Sherry (build-time only; interpret=True).

Public surface:
  quantize34       — 3:4 sparse-absmean ternary quantizer (Eq. 4-5)
  ternary_matmul   — Y = X·(T∘α) inference matmul (Eq. 2)
  arenas_matmul    — fused Y = X·Tα + λ·X·W training forward (Eq. 7)
  ref              — pure-jnp oracles for all of the above + baselines
"""

from .quantize34 import quantize34
from .ternary_matmul import ternary_matmul
from .arenas_matmul import arenas_matmul
from . import ref

__all__ = ["quantize34", "ternary_matmul", "arenas_matmul", "ref"]
