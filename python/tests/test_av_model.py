"""Behavioral model of the fixed-point a.V accumulation walk.

Replays `rust/src/simd/mod.rs::av_i8_rows_scalar` (the scalar ground
truth) and `rust/src/simd/walk.rs::av_i8_rows` (the generic channel-
chunked vector walk monomorphized by the AVX2/NEON leaves) in numpy,
and asserts **exact** i32 equality — the same hard-parity contract
`rust/tests/simd_parity.rs` enforces on the real code (DESIGN.md §4/§5).

Why this works as a model: the walk vectorizes across *head channels*,
so an i32 "register" lane is exactly the scalar accumulator for one
output channel, and integer adds/multiplies are associative — lane
width (W=4 models NEON, W=8 models AVX2) can only change which channels
share a register, never any value. Channels past the last full chunk
fall through to the scalar replay, mirroring `walk::av_i8_rows`'s tail.

Also validated here, mirroring
`engine/model.rs::integer_v_pass_stays_within_design_bound_elementwise`:
the post-softmax weight quantization rule (`s_a = max/127`,
`a_hat = round(a/s_a) in [0,127]`) and the DESIGN.md §4 element-wise
error bound `|Delta out[c]| <= 1/2 * s_a * s_v * sum_r |v_hat_r[c]|`.

numpy-only (no jax/hypothesis): runnable as a plain script in toolchain-
less environments, and pytest-collectible in CI.
"""

import numpy as np

F = np.float32


# ---------------------------------------------------------------------------
# Scalar ground truth and the channel-chunked vector walk
# ---------------------------------------------------------------------------


def av_scalar(weights, v, d, col0, hd, rows):
    """`simd::av_i8_rows_scalar`: out[c] = sum_r w_r * v[r*d + col0 + c],
    exact i32, zero-weight rows skipped, rows == 0 still zeroes out."""
    out = np.zeros(hd, np.int64)  # i64 here only to catch i32 overflow
    for r in range(rows):
        w = int(weights[r])
        if w == 0:
            continue
        row = v[r * d + col0 : r * d + col0 + hd].astype(np.int64)
        out += w * row
    assert np.all(np.abs(out) <= np.iinfo(np.int32).max), "i32 overflow"
    return out.astype(np.int32)


def av_walk(W, weights, v, d, col0, hd, rows):
    """`walk::av_i8_rows::<L>`: W-channel i32 register chunks accumulated
    over rows (zero-weight rows skipped on the vector path too), scalar
    tail for `hd % W` channels at `col0 + c0`."""
    out = np.full(hd, np.iinfo(np.int32).min, np.int32)  # istore overwrites
    c0 = 0
    while c0 + W <= hd:
        acc = np.zeros(W, np.int32)  # L::izero
        for r in range(rows):
            w = np.int32(weights[r])
            if w == 0:
                continue
            lanes = v[r * d + col0 + c0 : r * d + col0 + c0 + W]
            acc = acc + w * lanes.astype(np.int32)  # L::imac: widen, mul, add
        out[c0 : c0 + W] = acc  # L::istore
        c0 += W
    if c0 < hd:
        out[c0:] = av_scalar(weights, v, d, col0 + c0, hd - c0, rows)
    return out


def i8_pattern(n, seed):
    rng = np.random.default_rng(seed)
    return rng.integers(-128, 128, size=n).astype(np.int8)


def u8_weights(rows, seed):
    rng = np.random.default_rng(seed)
    w = rng.integers(0, 128, size=rows).astype(np.uint8)
    if rows >= 3:
        w[0], w[1], w[2] = 0, 127, 1  # skip path + both extremes
    return w


def test_av_walk_matches_scalar_every_width_and_geometry():
    # Head widths straddle every chunk boundary of both lane widths,
    # including sub-vector widths and one-off tails; rows include the
    # empty page (must still zero the output) and a partial page.
    for hd in [1, 2, 3, 4, 5, 7, 8, 9, 12, 15, 16, 17, 19, 32, 33]:
        nh = 2
        d = nh * hd
        for rows in [0, 1, 3, 9]:
            v = i8_pattern(rows * d, 100 + hd)
            w = u8_weights(rows, 200 + hd + rows)
            for h in range(nh):
                want = av_scalar(w, v, d, h * hd, hd, rows)
                for W in (4, 8):  # NEON, AVX2
                    got = av_walk(W, w, v, d, h * hd, hd, rows)
                    assert np.array_equal(got, want), (
                        f"hd={hd} rows={rows} h={h} W={W}: {got} vs {want}"
                    )
                if rows == 0:
                    assert np.all(want == 0), "rows=0 must zero the output"


def test_av_walk_random_geometry_sweep():
    rng = np.random.default_rng(7)
    for _ in range(64):
        hd = int(rng.integers(1, 38))
        nh = int(rng.integers(1, 5))
        rows = int(rng.integers(0, 22))
        d = nh * hd
        v = i8_pattern(rows * d, rng.integers(1 << 30))
        w = u8_weights(rows, rng.integers(1 << 30))
        h = int(rng.integers(nh))
        want = av_scalar(w, v, d, h * hd, hd, rows)
        for W in (4, 8):
            got = av_walk(W, w, v, d, h * hd, hd, rows)
            assert np.array_equal(got, want), f"hd={hd} rows={rows} W={W}"


def test_weight_quantization_stays_within_design_bound():
    # The attention pass-3 rule (`engine/model.rs::attention_blocked`):
    # per (query, page, head) softmax weights quantize with s_a = max/127,
    # a_hat = round(a/s_a) clamped to [0, 127]; the fused output
    # (sum a_hat * v_hat) * s_a * s_v must stay within
    # 1/2 * s_a * s_v * sum|v_hat| of the dequant path per channel.
    # Per-page bookkeeping: the 1/2*s_a*s_v factors differ per page, so
    # the bound accumulates page by page, exactly as the Rust test does.
    rng = np.random.default_rng(53)
    hd, pages, page_size = 19, 3, 4
    for trial in range(32):
        reference = np.zeros(hd, np.float64)
        fused = np.zeros(hd, np.float64)
        bound = np.zeros(hd, np.float64)
        for _ in range(pages):
            rows = int(rng.integers(1, page_size + 1))
            logits = rng.normal(size=rows)
            a = np.exp(logits - logits.max())
            a = (a / a.sum()).astype(F)
            v_hat = i8_pattern(rows * hd, rng.integers(1 << 30))
            s_v = F(abs(rng.normal()) / 127.0 + 1e-4)
            s_a = F(F(a.max()) / F(127.0))
            a_hat = np.clip(np.round(a / s_a), 0.0, 127.0).astype(np.uint8)
            acc = av_scalar(a_hat, v_hat, hd, 0, hd, rows)
            fused += acc.astype(np.float64) * float(s_a) * float(s_v)
            abs_v = np.zeros(hd, np.float64)
            for r in range(rows):
                row = v_hat[r * hd : (r + 1) * hd].astype(np.float64)
                reference += float(a[r]) * row * float(s_v)
                abs_v += np.abs(row)
            bound += 0.5 * float(s_a) * float(s_v) * abs_v
        err = np.abs(fused - reference)
        assert np.all(err <= bound + 1e-6), (
            f"trial {trial}: err {err.max()} > bound {bound[err.argmax()]}"
        )
        assert np.any(np.abs(fused) > 0), "degenerate all-zero fixture"


def test_i16_accumulation_would_overflow():
    # Teeth for the i32-lane requirement: the extremes the kernel admits
    # (w = 127, v = -128, several rows) overflow an i16 accumulator
    # immediately — any implementation that pairs i8 products into i16
    # (e.g. AVX2 `vpmaddubsw`) would saturate and diverge from scalar.
    rows, hd = 3, 4
    w = np.full(rows, 127, np.uint8)
    v = np.full(rows * hd, -128, np.int8)
    want = av_scalar(w, v, hd, 0, hd, rows)
    assert np.all(want == 127 * -128 * rows)
    assert want.min() < np.iinfo(np.int16).min, (
        "fixture no longer exceeds i16 — teeth test is vacuous"
    )
    i16 = np.clip(want, np.iinfo(np.int16).min, np.iinfo(np.int16).max)
    assert not np.array_equal(i16, want)


def test_misindexed_stride_is_caught():
    # Sanity: the parity assertions have teeth against layout bugs — a
    # walk reading with the wrong row stride must differ from scalar for
    # this fixture (distinct bytes per channel).
    hd, nh, rows = 8, 2, 5
    d = nh * hd
    v = i8_pattern(rows * d, 3)
    w = u8_weights(rows, 4)
    w[:] = np.maximum(w, 1)  # no skipped rows: every row must be read
    want = av_scalar(w, v, d, hd, hd, rows)
    wrong = av_scalar(w, v, d + 1, hd, hd, rows - 1)  # stride off-by-one
    assert not np.array_equal(wrong, want), (
        "stride bug was invisible — the fixture cannot catch misindexing"
    )


if __name__ == "__main__":
    fns = [v for k, v in sorted(globals().items()) if k.startswith("test_")]
    for fn in fns:
        fn()
        print(f"ok {fn.__name__}")
    print(f"{len(fns)} behavioral checks passed")
