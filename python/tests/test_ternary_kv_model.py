"""Behavioral model of the 1.25-bit ternary KV subsystem (PR 7).

Replays, in numpy, the Rust pieces that make `TernaryStore` + the
LUT-routed attention score pass correct, and asserts the same contracts
the Rust tests assert (`rust/src/cache/ternary.rs`,
`rust/src/engine/lut.rs`, `rust/src/simd/walk.rs`,
`rust/tests/{paged_kv,simd_parity}.rs`, DESIGN.md §4):

1. the streaming b1.58 absmean quantizer (`quant::absmean`): stable
   argmin 3:4 drop, `sign(0) = +1`, scale-independent codes, running
   absmean == batch absmean;
2. the pack34 codec (`pack::pack34`): 16 canonical patterns × mirror
   bit, exhaustive encode/decode round-trip over every 3:4 block;
3. the K page model: packed bytes + per-(page, head) scale are a pure
   function of the row sequence (frozen-byte determinism; no
   requantization cascade), dequant == codes × final running scale;
4. the per-query 32-entry q·k LUTs: integer-valued entries (exact in
   f32), mirror half an exact negation, the LUT row walk equal to
   decode-then-dot *bit-for-bit*, and the W-lane vector walk (W=4
   models NEON, W=8 AVX2 `gather_at`) bit-identical to the scalar walk
   across batch/tail shapes;
5. the DESIGN.md §4 error bounds: the fused score vs the dequantized-K
   reference stays within the query-rounding bound (and a constructed
   worst case saturates most of it), and vs the *exact* f32 K within
   the dropped-mass + scale-spread + rounding bound.

numpy-only (no jax/hypothesis): runnable as a plain script in
toolchain-less environments, and pytest-collectible in CI.
"""

import numpy as np

F = np.float32


def bits(a):
    return np.asarray(a, dtype=F).view(np.uint32)


def assert_bits_eq(got, want, what):
    got, want = np.asarray(got, F), np.asarray(want, F)
    assert got.shape == want.shape, f"{what}: shape {got.shape} vs {want.shape}"
    if not np.array_equal(bits(got), bits(want)):
        i = int(np.flatnonzero(bits(got).ravel() != bits(want).ravel())[0])
        raise AssertionError(f"{what}[{i}]: {got.ravel()[i]!r} vs {want.ravel()[i]!r}")


# ---------------------------------------------------------------------------
# quant::absmean — streaming 3:4 sparsifier + running absmean scale
# ---------------------------------------------------------------------------


def sparsify34_codes(x):
    """`sparsify34_codes`: per 4-block drop the smallest-|x| lane
    (strictly-less scan => lowest index wins ties), sign(x) elsewhere
    with sign(0) = +1."""
    x = np.asarray(x, F)
    assert x.size % 4 == 0
    codes = np.zeros(x.size, np.int8)
    for b0 in range(0, x.size, 4):
        xb = x[b0 : b0 + 4]
        drop = 0
        for lane in range(1, 4):
            if abs(xb[lane]) < abs(xb[drop]):
                drop = lane
        for lane in range(4):
            if lane == drop:
                codes[b0 + lane] = 0
            else:
                codes[b0 + lane] = -1 if xb[lane] < 0.0 else 1
    return codes


def kept_abs_sum(x, codes):
    """f32 left-fold of |x| over kept lanes, matching the Rust iterator
    sum's association order."""
    t = F(0.0)
    for v, c in zip(np.asarray(x, F), codes):
        if c != 0:
            t = F(t + F(abs(v)))
    return t


def absmean_scale(sum_abs, count):
    return F(0.0) if count == 0 else F(F(sum_abs) / F(count))


def test_codes_drop_argmin_stable_and_sign_zero_positive():
    assert list(sparsify34_codes([3.0, -1.0, 0.5, -2.0])) == [1, -1, 0, -1]
    # A strictly-smallest |x| is dropped wherever it sits.
    assert list(sparsify34_codes([1.0, 0.0, -1.0, 2.0])) == [1, 0, -1, 1]
    # |x| tie between lanes 0 and 1 -> lane 0 dropped (lowest index); the
    # kept exact-zero lane codes +1 so the block still holds one zero.
    assert list(sparsify34_codes([0.0, 0.0, -1.0, 2.0])) == [0, 1, -1, 1]
    rng = np.random.default_rng(3)
    for _ in range(50):
        c = sparsify34_codes(rng.normal(size=32).astype(F))
        for b0 in range(0, 32, 4):
            blk = c[b0 : b0 + 4]
            assert np.count_nonzero(blk == 0) == 1, blk


def test_running_absmean_is_a_pure_fold_equal_to_batch():
    rng = np.random.default_rng(5)
    rows = [rng.normal(size=16).astype(F) for _ in range(6)]
    s, n = F(0.0), 0
    kept = []
    for r in rows:
        c = sparsify34_codes(r)
        s = F(s + kept_abs_sum(r, c))
        n += 12  # 3/4 of 16
        kept.extend(abs(v) for v, cc in zip(r, c) if cc != 0)
    assert abs(absmean_scale(s, n) - np.mean(kept, dtype=np.float64)) < 1e-5
    assert absmean_scale(0.0, 0) == 0.0


# ---------------------------------------------------------------------------
# pack::pack34 — canonical patterns, encode/decode
# ---------------------------------------------------------------------------


def build_patterns():
    out = np.zeros((16, 4), np.int8)
    for z in range(4):
        for sb in range(2):
            for sc in range(2):
                idx = z * 4 + (sb << 1 | sc)
                active = 0
                for lane in range(4):
                    if lane == z:
                        continue
                    if active == 0:
                        out[idx, lane] = 1
                    elif active == 1:
                        out[idx, lane] = -1 if sb else 1
                    else:
                        out[idx, lane] = -1 if sc else 1
                    active += 1
    return out


PATTERNS = build_patterns()


def encode_block(block):
    zeros = [i for i, v in enumerate(block) if v == 0]
    assert len(zeros) == 1, "pack34 requires exactly one zero per block"
    z = zeros[0]
    active = [v for v in block if v != 0]
    mirror = active[0] == -1
    m = -1 if mirror else 1
    sb = int(active[1] * m == -1)
    sc = int(active[2] * m == -1)
    return z * 4 + (sb << 1 | sc), mirror


def decode_block(idx, mirror):
    p = PATTERNS[idx].copy()
    return -p if mirror else p


def test_pack34_roundtrip_every_34_block():
    # All 32 legal 3:4 blocks: 4 zero positions x 8 sign patterns.
    seen = set()
    for z in range(4):
        for signs in range(8):
            blk = np.zeros(4, np.int8)
            s, lanes = signs, [l for l in range(4) if l != z]
            for i, lane in enumerate(lanes):
                blk[lane] = -1 if (s >> (2 - i)) & 1 else 1
            idx, mirror = encode_block(blk)
            assert 0 <= idx < 16
            assert np.array_equal(decode_block(idx, mirror), blk), blk
            seen.add((idx, mirror))
    assert len(seen) == 32, "every (idx, mirror) state must be reachable"


# ---------------------------------------------------------------------------
# TernaryStore K page model — packed planes + running per-head scale
# ---------------------------------------------------------------------------


class KPageModel:
    """One (layer, page) of `TernaryStore`'s K plane at nano-like shape:
    per-(slot, head) nibble/sign lanes, one running absmean scale per
    head. Mirrors write_row / dequant_k_into."""

    def __init__(self, page_size, n_heads, hd):
        assert hd % 4 == 0
        self.ps, self.nh, self.hd = page_size, n_heads, hd
        self.nb = hd // 4
        self.idx = np.zeros((page_size, n_heads, self.nb), np.uint8)
        self.mirror = np.zeros((page_size, n_heads, self.nb), np.uint8)
        self.sum_abs = np.zeros(n_heads, F)
        self.count = np.zeros(n_heads, np.uint32)

    def write_row(self, slot, k_row):
        codes = sparsify34_codes(k_row)
        for h in range(self.nh):
            c0 = h * self.hd
            self.sum_abs[h] = F(
                self.sum_abs[h] + kept_abs_sum(k_row[c0 : c0 + self.hd], codes[c0 : c0 + self.hd])
            )
            self.count[h] += 3 * self.hd // 4
            for b in range(self.nb):
                i, m = encode_block(codes[c0 + 4 * b : c0 + 4 * b + 4])
                self.idx[slot, h, b] = i
                self.mirror[slot, h, b] = m

    def scale(self, h):
        return absmean_scale(self.sum_abs[h], self.count[h])

    def dequant(self, rows):
        out = np.zeros((rows, self.nh * self.hd), F)
        for r in range(rows):
            for h in range(self.nh):
                s = self.scale(h)
                for b in range(self.nb):
                    pat = decode_block(self.idx[r, h, b], self.mirror[r, h, b])
                    out[r, h * self.hd + 4 * b : h * self.hd + 4 * b + 4] = pat.astype(F) * s
        return out

    def packed_bytes(self):
        """The frozen artifact: packed planes + materialized scales."""
        scales = np.array([self.scale(h) for h in range(self.nh)], F)
        return self.idx.tobytes() + self.mirror.tobytes() + scales.tobytes()


def test_frozen_page_bytes_are_a_pure_function_of_the_rows():
    # Two pages fed the identical row sequence — one of them inside a
    # "busy server" with other pages interleaved — must freeze to
    # byte-identical artifacts. This is what makes ternary prefix
    # sharing serving-order invariant.
    rng = np.random.default_rng(11)
    rows = [rng.normal(size=4 * 8).astype(F) for _ in range(4)]
    a = KPageModel(4, 2, 16)
    b = KPageModel(4, 2, 16)
    noise = KPageModel(4, 2, 16)
    for s, r in enumerate(rows):
        a.write_row(s, r)
        noise.write_row(s, rng.normal(size=32).astype(F))  # unrelated traffic
        b.write_row(s, r)
    assert a.packed_bytes() == b.packed_bytes()
    assert a.packed_bytes() != noise.packed_bytes()


def test_dequant_is_codes_times_final_scale_no_requantization():
    # Codes never move after their write; only the scale (a pure fold)
    # evolves. So every row dequantizes to its own codes x the final
    # scale — there is no int8-style requantization cascade to model.
    rng = np.random.default_rng(13)
    pg = KPageModel(4, 2, 16)
    rows = [rng.normal(size=32).astype(F) * (10.0**i) for i in range(4)]
    snap_codes = []
    for s, r in enumerate(rows):
        pg.write_row(s, r)
        snap_codes.append(sparsify34_codes(r))
        # Earlier rows' packed bytes are untouched by later writes.
        for t in range(s + 1):
            c = snap_codes[t]
            for h in range(2):
                for b in range(pg.nb):
                    pat = decode_block(pg.idx[t, h, b], pg.mirror[t, h, b])
                    assert np.array_equal(pat, c[h * 16 + 4 * b : h * 16 + 4 * b + 4])
    dq = pg.dequant(4)
    for t, c in enumerate(snap_codes):
        for h in range(2):
            want = c[h * 16 : (h + 1) * 16].astype(F) * pg.scale(h)
            assert_bits_eq(dq[t, h * 16 : (h + 1) * 16], want, f"slot {t} head {h}")


# ---------------------------------------------------------------------------
# engine::lut — per-query 32-entry q·k LUTs + row walks
# ---------------------------------------------------------------------------


def quantize_query(q_row, n_heads, hd):
    """`model::quantize_query`: symmetric round-to-nearest int8 per head,
    scale = absmax/127 (zero head keeps scale 0 / zero codes)."""
    q_row = np.asarray(q_row, F)
    codes = np.zeros(n_heads * hd, np.int32)
    scales = np.zeros(n_heads, F)
    for h in range(n_heads):
        seg = q_row[h * hd : (h + 1) * hd]
        absmax = F(np.max(np.abs(seg), initial=0.0))
        if absmax == 0.0:
            continue
        s = F(absmax / F(127.0))
        scales[h] = s
        codes[h * hd : (h + 1) * hd] = np.clip(
            np.round(seg.astype(np.float64) / s), -127, 127
        ).astype(np.int32)
    return codes, scales


def build_qk_luts34(q_codes, hd, n_heads):
    """`lut::build_qk_luts34`: luts[(h*nb+b)*32 + mirror*16 + idx] =
    sum_lane decode(idx, mirror)[lane] * q[h*hd + 4b + lane], exact in
    f32 (integer-valued, |.| <= 3*127 << 2^24)."""
    nb = hd // 4
    luts = np.zeros(n_heads * nb * 32, F)
    for h in range(n_heads):
        for b in range(nb):
            q = q_codes[h * hd + 4 * b : h * hd + 4 * b + 4]
            base = (h * nb + b) * 32
            for idx in range(16):
                s = int(np.dot(PATTERNS[idx].astype(np.int64), q))
                luts[base + idx] = F(s)
                luts[base + 16 + idx] = F(-float(s))
    return luts


def qk_lut34_rows_scalar(page, h, luts, rows):
    """`lut::qk_lut34_rows`: per row, left-fold of one gathered entry per
    block — raw integer sums, scales applied by the caller."""
    nb = page.nb
    out = np.zeros(rows, F)
    for r in range(rows):
        acc = F(0.0)
        for b in range(nb):
            off = (h * nb + b) * 32 + int(page.mirror[r, h, b]) * 16 + int(page.idx[r, h, b])
            acc = F(acc + luts[off])
        out[r] = acc
    return out


def qk_lut34_rows_walk(W, page, h, luts, rows):
    """`walk::qk_lut34_rows::<L>`: W-row chunks, per-block `gather_at`
    (per-lane offsets into the head's LUT base), scalar row tail."""
    nb = page.nb
    out = np.zeros(rows, F)
    r0 = 0
    base = h * nb * 32
    while r0 + W <= rows:
        acc = np.zeros(W, F)
        for b in range(nb):
            off = np.array(
                [
                    b * 32 + int(page.mirror[r0 + i, h, b]) * 16 + int(page.idx[r0 + i, h, b])
                    for i in range(W)
                ]
            )
            acc = acc + luts[base + off]  # L::add(acc, L::gather_at(base, off))
        out[r0 : r0 + W] = acc
        r0 += W
    if r0 < rows:
        out[r0:] = qk_lut34_rows_scalar_from(page, h, luts, r0, rows)
    return out


def qk_lut34_rows_scalar_from(page, h, luts, r0, rows):
    nb = page.nb
    out = np.zeros(rows - r0, F)
    for i, r in enumerate(range(r0, rows)):
        acc = F(0.0)
        for b in range(nb):
            off = (h * nb + b) * 32 + int(page.mirror[r, h, b]) * 16 + int(page.idx[r, h, b])
            acc = F(acc + luts[off])
        out[i] = acc
    return out


def filled_page(rng, ps, nh, hd):
    pg = KPageModel(ps, nh, hd)
    krows = [rng.normal(size=nh * hd).astype(F) for _ in range(ps)]
    for s, r in enumerate(krows):
        pg.write_row(s, r)
    return pg, krows


def test_luts_are_integer_exact_with_mirror_negation():
    rng = np.random.default_rng(17)
    nh, hd = 2, 16
    q_codes, _ = quantize_query(rng.normal(size=nh * hd).astype(F), nh, hd)
    luts = build_qk_luts34(q_codes, hd, nh)
    assert np.array_equal(luts, np.round(luts)), "entries must sit on the integer lattice"
    assert np.max(np.abs(luts)) <= 3 * 127
    half = luts.reshape(-1, 32)
    assert np.array_equal(half[:, 16:], -half[:, :16]), "mirror half = exact negation"


def test_lut_walk_equals_decode_then_dot_bitwise():
    # Integer lattice => f32 accumulation is exact in any order, so the
    # LUT walk must equal the decode-then-integer-dot reference exactly,
    # not approximately — the Rust side asserts the same.
    rng = np.random.default_rng(19)
    nh, hd, ps = 2, 16, 5
    pg, _ = filled_page(rng, ps, nh, hd)
    q_codes, _ = quantize_query(rng.normal(size=nh * hd).astype(F), nh, hd)
    luts = build_qk_luts34(q_codes, hd, nh)
    for h in range(nh):
        got = qk_lut34_rows_scalar(pg, h, luts, ps)
        for r in range(ps):
            kdec = np.concatenate(
                [decode_block(pg.idx[r, h, b], pg.mirror[r, h, b]) for b in range(pg.nb)]
            ).astype(np.int64)
            want = int(np.dot(kdec, q_codes[h * hd : (h + 1) * hd]))
            assert got[r] == F(want), f"h={h} r={r}: {got[r]} vs {want}"


def test_qk_walk_parity_scalar_vs_lanes_every_tail():
    rng = np.random.default_rng(23)
    nh, hd = 3, 24
    for rows in [0, 1, 2, 3, 4, 5, 7, 8, 9, 13, 16, 17]:
        pg, _ = filled_page(rng, max(rows, 1), nh, hd)
        q_codes, _ = quantize_query(rng.normal(size=nh * hd).astype(F), nh, hd)
        luts = build_qk_luts34(q_codes, hd, nh)
        for h in range(nh):
            want = qk_lut34_rows_scalar(pg, h, luts, rows)
            for W in (4, 8):
                got = qk_lut34_rows_walk(W, pg, h, luts, rows)
                assert_bits_eq(got, want, f"qk rows={rows} h={h} W={W}")


# ---------------------------------------------------------------------------
# DESIGN.md §4 — fused-score error bounds
# ---------------------------------------------------------------------------


def fused_scores(pg, q_row, nh, hd, rows):
    """The KBlock::Ternary arm: quantize q once, LUT-walk raw sums, then
    one multiply by q_scale[h] * k_scale[h] (softmax 1/sqrt(hd) omitted —
    it scales both sides of every bound identically)."""
    q_codes, q_scales = quantize_query(q_row, nh, hd)
    luts = build_qk_luts34(q_codes, hd, nh)
    out = np.zeros((nh, rows), F)
    for h in range(nh):
        raw = qk_lut34_rows_scalar(pg, h, luts, rows)
        out[h] = raw * F(q_scales[h] * pg.scale(h))
    return out, q_scales


def test_bound1_fused_vs_dequantized_k():
    # Bound 1: |fused - q_f32 . k_dequant| <= (3/4) hd * (s_q/2) * s_k —
    # only query rounding separates them; K contributes the same
    # codes x scale to both sides.
    rng = np.random.default_rng(29)
    nh, hd, ps = 2, 32, 6
    pg, _ = filled_page(rng, ps, nh, hd)
    q_row = rng.normal(size=nh * hd).astype(F)
    fused, q_scales = fused_scores(pg, q_row, nh, hd, ps)
    dq = pg.dequant(ps)
    for h in range(nh):
        s_k = pg.scale(h)
        bound = 0.75 * hd * 0.5 * float(q_scales[h]) * float(s_k)
        for r in range(ps):
            ref = float(
                np.dot(
                    q_row[h * hd : (h + 1) * hd].astype(np.float64),
                    dq[r, h * hd : (h + 1) * hd].astype(np.float64),
                )
            )
            err = abs(float(fused[h, r]) - ref)
            assert err <= bound + 1e-4, f"h={h} r={r}: {err} > {bound}"


def test_bound1_worst_case_nearly_saturates():
    # Constructed adversary: every query channel sits 0.47 of a quantum
    # above its code (decisively rounding down, so every channel's error
    # is +0.47 s_q — exactly half a quantum would hit round-half-to-even
    # and the errors would cancel pairwise) and every kept k lane is
    # +s_k, so each of the (3/4) hd surviving lanes pushes the same way.
    # Measured error = 0.94x Bound 1, proving the bound is tight up to
    # the rounding-breaking offset.
    nh, hd = 1, 32
    pg = KPageModel(1, nh, hd)
    k_row = np.tile([1.0, 1.0, 1.0, 1e-6], hd // 4).astype(F)  # drop lane 3
    pg.write_row(0, k_row)
    s_k = float(pg.scale(0))
    assert abs(s_k - 1.0) < 1e-5
    # Codes 0..95 scaled so absmax/127 = s_q, then shifted 0.47 quanta.
    # Keep signs positive so every error pushes the same way.
    s_q = 1.0 / 127.0
    q_row = ((np.arange(hd) % 96 + 0.47) * s_q).astype(F)
    q_row[-1] = F(127.0 * s_q)  # pin absmax so the scale is exactly s_q
    fused, q_scales = fused_scores(pg, q_row, nh, hd, 1)
    assert abs(float(q_scales[0]) - s_q) < 1e-9
    dq = pg.dequant(1)
    ref = float(np.dot(q_row.astype(np.float64), dq[0].astype(np.float64)))
    err = abs(float(fused[0, 0]) - ref)
    bound = 0.75 * hd * 0.5 * s_q * s_k
    assert err <= bound + 1e-6
    assert err >= 0.9 * bound, f"worst case should nearly saturate: {err} vs {bound}"


def test_bound2_fused_vs_exact_f32_k():
    # Bound 2 (vs the exact f32 K row): dropped mass + kept magnitude
    # spread + query rounding:
    #   sum_dropped |q_c||k_c| + sum_kept |q_c| ||k_c| - s_k|
    #     + (s_q/2) s_k (3/4) hd.
    rng = np.random.default_rng(31)
    nh, hd, ps = 2, 32, 6
    krows = [rng.normal(size=nh * hd).astype(F) for _ in range(ps)]
    pg = KPageModel(ps, nh, hd)
    for s, r in enumerate(krows):
        pg.write_row(s, r)
    q_row = rng.normal(size=nh * hd).astype(F)
    fused, q_scales = fused_scores(pg, q_row, nh, hd, ps)
    for h in range(nh):
        s_k = float(pg.scale(h))
        for r in range(ps):
            k = krows[r][h * hd : (h + 1) * hd].astype(np.float64)
            q = q_row[h * hd : (h + 1) * hd].astype(np.float64)
            codes = sparsify34_codes(krows[r])[h * hd : (h + 1) * hd]
            exact = float(np.dot(q, k))
            dropped = float(np.sum(np.abs(q[codes == 0]) * np.abs(k[codes == 0])))
            spread = float(np.sum(np.abs(q[codes != 0]) * np.abs(np.abs(k[codes != 0]) - s_k)))
            bound = dropped + spread + 0.5 * float(q_scales[h]) * s_k * 0.75 * hd
            err = abs(float(fused[h, r]) - exact)
            assert err <= bound + 1e-4, f"h={h} r={r}: {err} > {bound}"


if __name__ == "__main__":
    fns = [v for k, v in sorted(globals().items()) if k.startswith("test_")]
    for fn in fns:
        fn()
        print(f"ok {fn.__name__}")
    print(f"{len(fns)} behavioral checks passed")
