"""Behavioral model of the Rust two-class priority batcher.

Replays `rust/src/coordinator/batcher.rs` — the continuous batcher
behind the SLO scheduler (DESIGN.md §10) — in plain python and asserts
the scheduling laws the Rust unit and property tests pin:

* strict priority with FIFO order inside a class: the Interactive queue
  drains head-first, then Batch; a blocked head blocks the whole wave
  (no lower class ever backfills past it),
* aging: a Batch entry that has waited past the threshold moves to the
  Interactive queue's tail, relative order among promotees preserved,
  and its *intrinsic* class never changes,
* preemption parking: a preempted active sequence returns to the front
  of its class queue with its generated count carried, so re-admission
  resumes the allowance instead of restarting it,
* accounting: `reserved` always equals the active set's worst-case
  token sum (prompt + full allowance), and the max_active / token
  budget / page caps hold at every step (modulo the documented
  lone-oversized token exception),
* liveness: every submitted request eventually completes under any
  interleaving of submit / admit / advance / retire / preempt with a
  sane page supply.

numpy-only (no jax/hypothesis): runnable as a plain script in
toolchain-less environments, and pytest-collectible in CI.
"""

import math

import numpy as np

INTERACTIVE = 0  # Priority::Interactive.index()
BATCH = 1  # Priority::Batch.index()


class Request:
    """Mirror of coordinator::Request (the scheduling-relevant fields)."""

    def __init__(self, rid, prompt_len, max_new, priority=INTERACTIVE, arrival=0.0):
        self.id = rid
        self.prompt_len = prompt_len
        self.max_new = max_new
        self.priority = priority
        self.arrival = arrival

    @property
    def need(self):
        return self.prompt_len + self.max_new


class Waiting:
    def __init__(self, req, generated, enqueued_at):
        self.req = req
        self.generated = generated
        self.enqueued_at = enqueued_at


class BatcherModel:
    """Mirror of batcher.rs::Batcher."""

    def __init__(self, max_active, token_budget, aging_threshold_s=5.0):
        self.max_active = max_active
        self.token_budget = token_budget
        self.aging_threshold_s = aging_threshold_s
        self.queues = [[], []]  # [interactive, batch], index == priority
        self.active = []  # list of [Request, generated]
        self.reserved = 0
        self.admissions = 0
        self.aged_promotions = 0

    def submit(self, req):
        at = req.arrival if math.isfinite(req.arrival) else 0.0
        self.queues[req.priority].append(Waiting(req, 0, at))

    def waiting_len(self):
        return sum(len(q) for q in self.queues)

    def head_priority(self):
        """Intrinsic class of the next admission candidate (not queue
        residence — an aged-up Batch head still reports BATCH)."""
        for q in self.queues:
            if q:
                return q[0].req.priority
        return None

    def _age(self, now):
        if not math.isfinite(self.aging_threshold_s):
            return
        kept, promoted = [], []
        for w in self.queues[BATCH]:
            if now - w.enqueued_at >= self.aging_threshold_s:
                promoted.append(w)
            else:
                kept.append(w)
        self.queues[BATCH] = kept
        self.queues[INTERACTIVE].extend(promoted)  # tail, order preserved
        self.aged_promotions += len(promoted)

    def admit_pages(self, free_pages, page_cost, now):
        self._age(now)
        admitted = 0
        for q in self.queues:
            while q:
                if len(self.active) >= self.max_active:
                    return admitted
                head = q[0]
                # A blocked head blocks the whole wave. Token budget has
                # the lone-oversized exception; pages do not (the server
                # sizes the arena to ≥ one worst-case sequence).
                if self.reserved + head.req.need > self.token_budget and self.active:
                    return admitted
                if page_cost(head.req) > free_pages:
                    return admitted
                q.pop(0)
                self.reserved += head.req.need
                free_pages -= page_cost(head.req)
                self.active.append([head.req, head.generated])
                self.admissions += 1
                admitted += 1
        return admitted

    def admit(self):
        return self.admit_pages(float("inf"), lambda r: 0, 0.0)

    def preempt(self, i, now):
        """swap_remove + park at the *front* of the intrinsic class queue,
        generated count carried."""
        req, generated = self.active[i]
        self.active[i] = self.active[-1]
        self.active.pop()
        self.reserved -= req.need
        self.queues[req.priority].insert(0, Waiting(req, generated, now))

    def advance(self, i):
        self.active[i][1] += 1
        return self.active[i][1] >= self.active[i][0].max_new

    def retire(self, finished):
        out = []
        for i in reversed(finished):
            req, generated = self.active[i]
            self.active[i] = self.active[-1]
            self.active.pop()
            self.reserved -= req.need
            out.append((req, generated))
        out.reverse()
        return out

    def is_idle(self):
        return self.waiting_len() == 0 and not self.active


def test_strict_priority_with_fifo_within_class():
    b = BatcherModel(max_active=3, token_budget=1000)
    b.submit(Request(1, 4, 4, BATCH))
    b.submit(Request(2, 4, 4, INTERACTIVE))
    b.submit(Request(3, 4, 4, INTERACTIVE))
    b.submit(Request(4, 4, 4, BATCH))
    assert b.admit() == 3
    # Interactive arrivals (FIFO among themselves) beat the older Batch.
    assert [a[0].id for a in b.active] == [2, 3, 1]
    assert b.waiting_len() == 1


def test_blocked_head_is_never_backfilled():
    b = BatcherModel(max_active=4, token_budget=20)
    b.submit(Request(1, 8, 4, INTERACTIVE))  # 12 — admitted
    b.submit(Request(2, 8, 4, INTERACTIVE))  # 12 — blocks the wave
    b.submit(Request(3, 1, 1, BATCH))  # 2 — would fit, must wait anyway
    assert b.admit() == 1
    assert [a[0].id for a in b.active] == [1]
    assert b.waiting_len() == 2


def test_lone_oversized_request_still_admits():
    # Larger than the whole budget: admitted when alone rather than
    # deadlocking the queue (tokens are a soft cap, unlike pages).
    b = BatcherModel(max_active=4, token_budget=10)
    b.submit(Request(1, 50, 10))
    assert b.admit() == 1


def test_page_cap_has_no_oversized_exception():
    # Pages are physical memory: a head needing more than the supply
    # blocks even when the active set is empty.
    b = BatcherModel(max_active=4, token_budget=10_000)
    b.submit(Request(1, 16, 16))
    cost = lambda r: (r.need + 3) // 4
    assert b.admit_pages(7, cost, 0.0) == 0
    assert b.admit_pages(8, cost, 0.0) == 1


def test_aging_promotes_to_interactive_tail_and_keeps_intrinsic_class():
    b = BatcherModel(max_active=1, token_budget=1000, aging_threshold_s=2.0)
    b.submit(Request(1, 4, 4, BATCH, arrival=0.0))
    b.submit(Request(2, 4, 4, INTERACTIVE))
    # Below the threshold: strict priority holds.
    assert b.admit_pages(float("inf"), lambda r: 0, 1.0) == 1
    assert b.active[0][0].id == 2
    assert b.aged_promotions == 0
    b.retire([0])
    # Past the threshold: promoted even in a page-blocked wave.
    assert b.admit_pages(0, lambda r: 1, 3.0) == 0
    assert b.aged_promotions == 1
    assert len(b.queues[INTERACTIVE]) == 1
    # A newer Interactive arrival ranks behind the promotee, and the
    # promotee's intrinsic class is still BATCH at the head.
    b.submit(Request(3, 4, 4, INTERACTIVE))
    assert b.head_priority() == BATCH
    assert b.admit_pages(float("inf"), lambda r: 0, 3.0) == 1
    assert b.active[0][0].id == 1
    assert b.active[0][0].priority == BATCH


def test_aging_preserves_relative_order_among_promotees():
    b = BatcherModel(max_active=0, token_budget=1000, aging_threshold_s=1.0)
    for rid in (1, 2, 3):
        b.submit(Request(rid, 4, 4, BATCH, arrival=0.0))
    b.admit_pages(float("inf"), lambda r: 0, 5.0)  # max_active 0: only ages
    assert [w.req.id for w in b.queues[INTERACTIVE]] == [1, 2, 3]
    assert b.aged_promotions == 3


def test_infinite_threshold_disables_aging():
    b = BatcherModel(max_active=1, token_budget=1000, aging_threshold_s=float("inf"))
    b.submit(Request(1, 4, 4, BATCH))
    b.submit(Request(2, 4, 4, INTERACTIVE))
    assert b.admit_pages(float("inf"), lambda r: 0, 1e12) == 1
    assert b.active[0][0].id == 2
    assert b.aged_promotions == 0


def test_preempt_parks_at_front_and_resumes_allowance():
    b = BatcherModel(max_active=2, token_budget=1000)
    for rid in (1, 2, 3):
        b.submit(Request(rid, 4, 6, BATCH))
    assert b.admit() == 2
    assert not b.advance(0)  # id 1: generated 1 of 6
    reserved = b.reserved
    b.preempt(0, 1.0)
    assert b.reserved == reserved - 10
    # Parked at the front: re-admission picks id 1 before id 3.
    assert b.admit() == 1
    assert b.active[1][0].id == 1
    assert b.active[1][1] == 1, "generated count survives parking"
    # Remaining allowance resumes: 5 more tokens finish it.
    for k in range(5):
        assert b.advance(1) == (k == 4)


def test_non_finite_arrival_is_clamped_for_aging():
    b = BatcherModel(max_active=0, token_budget=1000, aging_threshold_s=1.0)
    b.submit(Request(1, 4, 4, BATCH, arrival=float("nan")))
    b.admit_pages(float("inf"), lambda r: 0, 2.0)  # nan would poison waited
    assert b.aged_promotions == 1


def test_random_interleavings_hold_every_invariant():
    """Mirror of the Rust prop test: random submit / admit_pages /
    advance / retire / preempt interleavings, checking the FIFO-head
    law, the accounting law, the caps, and liveness."""
    rng = np.random.default_rng(7)
    for _ in range(80):
        n = int(rng.integers(1, 25))
        reqs = [
            Request(
                rid,
                int(rng.integers(1, 21)),
                int(rng.integers(1, 11)),
                BATCH if rng.integers(0, 2) else INTERACTIVE,
            )
            for rid in range(n)
        ]
        max_active = int(rng.integers(1, 7))
        budget = int(rng.integers(10, 121))
        b = BatcherModel(max_active, budget, aging_threshold_s=float("inf"))
        page_cost = lambda r: (r.need + 3) // 4
        expect = [[], []]  # per-class expected FIFO order of waiting ids
        next_submit = 0
        completed = 0
        steps = 0
        while completed < n:
            steps += 1
            assert steps < 20_000, "livelock"
            pages = int(rng.integers(0, 41))
            knob = int(rng.integers(0, 10))
            if next_submit < n and knob % 3 != 0:
                r = reqs[next_submit]
                b.submit(r)
                expect[r.priority].append(r.id)
                next_submit += 1
            before = len(b.active)
            b.admit_pages(pages, page_cost, 0.0)
            for req, _ in b.active[before:]:
                q = req.priority
                assert expect[q] and expect[q][0] == req.id, (
                    f"class {q} admitted {req.id}, head {expect[q][:1]}"
                )
                expect[q].pop(0)
                assert not (q == BATCH and expect[INTERACTIVE]), (
                    f"batch {req.id} admitted past waiting interactive head"
                )
            total = sum(req.need for req, _ in b.active)
            assert b.reserved == total, f"reserved {b.reserved} != {total}"
            assert len(b.active) <= max_active
            if len(b.active) > 1:
                assert total <= budget, f"budget exceeded: {total} > {budget}"
            if len(b.active) > 1 and knob == 9:
                i = knob % len(b.active)
                victim = b.active[i][0]
                b.preempt(i, 0.0)
                expect[victim.priority].insert(0, victim.id)
            finished = [i for i in range(len(b.active)) if b.advance(i)]
            completed += len(b.retire(finished))
        assert b.is_idle(), "requests left behind"


if __name__ == "__main__":
    fns = [v for k, v in sorted(globals().items()) if k.startswith("test_")]
    for fn in fns:
        fn()
        print(f"ok {fn.__name__}")
    print(f"{len(fns)} behavioral checks passed")
