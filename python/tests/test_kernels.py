"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes/dtypes/seeds; assert_allclose against ref.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import quantize34, ternary_matmul, arenas_matmul, ref

jax.config.update("jax_platform_name", "cpu")


def _rand_w(seed, d_in, d_out, dtype=np.float32):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(d_in, d_out)).astype(dtype))


# ---------------------------------------------------------------------------
# quantize34
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    blocks=st.integers(1, 64),
    cols=st.integers(1, 4),
)
def test_quantize34_matches_ref(seed, blocks, cols):
    w = _rand_w(seed, 4 * blocks, 128 * cols)
    t, a = quantize34(w)
    t_ref, a_ref = ref.sherry34_quantize(w)
    np.testing.assert_array_equal(np.asarray(t), np.asarray(t_ref))
    np.testing.assert_allclose(np.asarray(a), np.asarray(a_ref), rtol=1e-5, atol=1e-7)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_quantize34_is_34_sparse(seed):
    """Every 4-block has exactly one zero and three ±1 (paper Eq. 3)."""
    w = _rand_w(seed, 256, 128)
    t, _ = quantize34(w)
    t = np.asarray(t).reshape(64, 4, 128)
    nnz = (t != 0).sum(axis=1)
    assert (nnz == 3).all()
    assert np.isin(t, [-1.0, 0.0, 1.0]).all()


def test_quantize34_prunes_min_abs():
    """The pruned lane is the min-|w| lane (Eq. 4)."""
    w = _rand_w(7, 512, 128)
    t, _ = quantize34(w)
    t = np.asarray(t).reshape(-1, 4, 128)
    aw = np.abs(np.asarray(w)).reshape(-1, 4, 128)
    pruned = np.argmin(np.where(t == 0, 0.0, 1.0), axis=1)  # lane of the zero
    assert (pruned == np.argmin(aw, axis=1)).all()


def test_quantize34_alpha_formula():
    """α_j = 4/(3 d_in) Σ_active |w| (Eq. 5)."""
    w = _rand_w(3, 64, 128)
    t, a = quantize34(w)
    t_np, w_np = np.asarray(t), np.asarray(w)
    expect = (4.0 / (3.0 * 64)) * (np.abs(w_np) * (t_np != 0)).sum(axis=0)
    np.testing.assert_allclose(np.asarray(a), expect, rtol=1e-5)


def test_quantize34_optimality_bruteforce():
    """No other 3:4 sign assignment has lower per-block correlation loss
    (App. D): the greedy choice maximizes Σ w_i t_i per block."""
    rng = np.random.default_rng(11)
    w = jnp.asarray(rng.normal(size=(8, 128)).astype(np.float32))
    t, _ = quantize34(w)
    w_np, t_np = np.asarray(w), np.asarray(t)
    # enumerate all 32 valid block patterns
    pats = []
    for zero in range(4):
        for bits in range(8):
            p = []
            k = 0
            for lane in range(4):
                if lane == zero:
                    p.append(0.0)
                else:
                    p.append(1.0 if (bits >> k) & 1 else -1.0)
                    k += 1
            pats.append(p)
    pats = np.array(pats)  # (32, 4)
    for j in range(w_np.shape[1]):
        for b in range(2):
            blk = w_np[4 * b : 4 * b + 4, j]
            ours = (blk * t_np[4 * b : 4 * b + 4, j]).sum()
            best = (pats * blk[None, :]).sum(axis=1).max()
            assert ours >= best - 1e-6


# ---------------------------------------------------------------------------
# ternary_matmul
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    dt=st.sampled_from([1, 3, 8, 16, 33]),
    din=st.sampled_from([4, 64, 512, 520]),
    dout=st.sampled_from([1, 16, 128, 256]),
)
def test_ternary_matmul_matches_ref(seed, dt, din, dout):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(dt, din)).astype(np.float32))
    t = jnp.asarray(rng.integers(-1, 2, size=(din, dout)).astype(np.float32))
    a = jnp.asarray(np.abs(rng.normal(size=(dout,))).astype(np.float32))
    y = ternary_matmul(x, t, a)
    y_ref = ref.ternary_matmul(x, t, a)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-4, atol=1e-4)


def test_ternary_matmul_zero_alpha_zeroes_output():
    x = _rand_w(0, 8, 64).T  # (64, 8) -> transpose to (8, 64)? keep simple:
    x = _rand_w(0, 8, 64)
    t = jnp.ones((64, 128), jnp.float32)
    a = jnp.zeros((128,), jnp.float32)
    y = ternary_matmul(x, t, a)
    assert np.abs(np.asarray(y)).max() == 0.0


# ---------------------------------------------------------------------------
# arenas_matmul
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    lam=st.floats(0.0, 1.0),
)
def test_arenas_matmul_matches_ref(seed, lam):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(8, 256)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(256, 128)).astype(np.float32))
    t, a = ref.sherry34_quantize(w)
    y = arenas_matmul(x, t, a, w, lam)
    y_ref = ref.arenas_matmul(x, t, a, w, lam)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-4, atol=1e-4)


def test_arenas_lambda_zero_equals_ternary():
    """λ=0 must reduce to the pure ternary product (zero-overhead claim)."""
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(8, 128)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(128, 128)).astype(np.float32))
    t, a = ref.sherry34_quantize(w)
    y0 = arenas_matmul(x, t, a, w, 0.0)
    yt = ternary_matmul(x, t, a)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(yt), rtol=1e-5, atol=1e-5)


def test_arenas_lambda_one_adds_full_residual():
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.normal(size=(4, 128)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(128, 128)).astype(np.float32))
    t, a = ref.sherry34_quantize(w)
    y1 = arenas_matmul(x, t, a, w, 1.0)
    expect = np.asarray(ternary_matmul(x, t, a)) + np.asarray(x) @ np.asarray(w)
    np.testing.assert_allclose(np.asarray(y1), expect, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# baseline quantizer oracles: internal consistency
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "quant",
    [ref.absmean_quantize, ref.absmedian_quantize, ref.twn_quantize],
)
def test_threshold_quantizers_are_ternary(quant):
    w = _rand_w(9, 128, 64)
    t, a = quant(w)
    t_np = np.asarray(t)
    assert np.isin(t_np, [-1.0, 0.0, 1.0]).all()
    assert (np.asarray(a) >= 0).all()


def test_sherry_reconstruction_beats_naive_over_blocks():
    """Sanity: Sparse-AbsMean reconstruction error ≤ pruning a random lane."""
    rng = np.random.default_rng(13)
    w = jnp.asarray(rng.normal(size=(256, 64)).astype(np.float32))
    t, a = ref.sherry34_quantize(w)
    err_opt = float(jnp.sum((w - ref.sherry34_dequant(t, a)) ** 2))
    # prune lane 0 of each block instead
    t_bad = np.sign(np.asarray(w))
    t_bad.reshape(-1, 4, 64)[:, 0, :] = 0
    t_bad = jnp.asarray(t_bad)
    a_bad = ref.sherry34_scale(w, t_bad)
    err_bad = float(jnp.sum((w - ref.sherry34_dequant(t_bad, a_bad)) ** 2))
    assert err_opt <= err_bad + 1e-4


# ---------------------------------------------------------------------------
# λ schedules + effective rank oracles
# ---------------------------------------------------------------------------


def test_lambda_schedules_boundaries():
    for fn in (ref.lambda_linear, ref.lambda_cosine, ref.lambda_exponential):
        assert float(fn(jnp.float32(0.0))) == pytest.approx(1.0, abs=1e-2)
        assert float(fn(jnp.float32(1.0))) == pytest.approx(0.0, abs=1e-2)


def test_lambda_warmup_starts_at_zero():
    f = lambda p: ref.lambda_with_warmup(ref.lambda_cosine, p, 0.1)
    assert float(f(jnp.float32(0.0))) == pytest.approx(0.0, abs=1e-6)
    assert float(f(jnp.float32(0.1))) == pytest.approx(1.0, abs=1e-5)
    assert float(f(jnp.float32(1.0))) == pytest.approx(0.0, abs=1e-5)


def test_effective_rank_identity():
    """ER of the identity = full rank; ER of rank-1 = 1 (Eq. 22 bounds)."""
    assert float(ref.effective_rank(jnp.eye(32))) == pytest.approx(32.0, rel=1e-3)
    r1 = jnp.outer(jnp.arange(1.0, 9.0), jnp.arange(1.0, 17.0))
    assert float(ref.effective_rank(r1)) == pytest.approx(1.0, abs=1e-3)
