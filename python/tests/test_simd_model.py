"""Behavioral model of the Rust SIMD dispatch layer's lane/tail semantics.

Replays `rust/src/simd/walk.rs` (the generic vector tile walks) and the
arch dots (`simd/avx2.rs`, `simd/neon.rs`) in numpy, against a replay of
the scalar ground-truth kernels (`rust/src/engine/lut.rs`), and asserts
**bit-for-bit** f32 equality — the same hard-parity contract
`rust/tests/simd_parity.rs` enforces on the real code (DESIGN.md §5).

Why this works as a model: the vector walks chunk the *batch* dimension,
so a "register" is just the same f32 value per lane that scalar row `i`
holds, and numpy elementwise float32 ops are per-lane IEEE-754 single
ops — exactly what the AVX2/NEON lanes compute. Lane width is a
parameter here (W=4 models NEON, W=8 models AVX2), and rows past the
last full chunk fall through to the scalar replay, mirroring
`walk::gemm_*`'s tail handling.

numpy-only (no jax/hypothesis): runnable as a plain script in toolchain-
less environments, and pytest-collectible in CI.
"""

import numpy as np

F = np.float32

TL2_LUT_STRIDE = 32
TILE_SB = 16  # pack34: sign bytes per cache tile = 128 blocks


def bits(a):
    return np.asarray(a, dtype=F).view(np.uint32)


def assert_bits_eq(got, want, what):
    got, want = np.asarray(got, F), np.asarray(want, F)
    assert got.shape == want.shape, f"{what}: shape {got.shape} vs {want.shape}"
    if not np.array_equal(bits(got), bits(want)):
        i = int(np.flatnonzero(bits(got).ravel() != bits(want).ravel())[0])
        raise AssertionError(
            f"{what}[{i}]: {got.ravel()[i]!r} vs {want.ravel()[i]!r}"
        )


# ---------------------------------------------------------------------------
# i8×i8 dot — scalar fold vs the two vector widening/fold shapes
# ---------------------------------------------------------------------------


def dot_scalar(a, b):
    """Left-fold i32 sum (`simd::dot_i8_scalar`)."""
    t = np.int32(0)
    for x, y in zip(a, b):
        t = np.int32(t + np.int32(x) * np.int32(y))
    return int(t)


def dot_avx2_model(a, b):
    """`avx2::dot_i8`: 16 i8/iter → i16 lanes → `vpmaddwd` pairs → 8×i32
    accumulator, horizontal sum, scalar tail."""
    n = len(a)
    acc = np.zeros(8, np.int32)
    i = 0
    while i + 16 <= n:
        wa = a[i : i + 16].astype(np.int32)  # vpmovsxbw widening
        wb = b[i : i + 16].astype(np.int32)
        prod = wa * wb  # each fits i16? no — but vpmaddwd sums pairs in i32
        madd = prod[0::2] + prod[1::2]  # 8 i32 lanes
        acc = acc + madd.astype(np.int32)
        i += 16
    total = np.int32(acc.sum(dtype=np.int32))
    while i < n:
        total = np.int32(total + np.int32(a[i]) * np.int32(b[i]))
        i += 1
    return int(total)


def dot_neon_model(a, b):
    """`neon::dot_i8`: 16 i8/iter → two `smull` i16 halves → `sadalp`
    pairwise-accumulate into 4×i32, `vaddvq` horizontal sum, scalar tail."""
    n = len(a)
    acc = np.zeros(4, np.int32)
    i = 0
    while i + 16 <= n:
        prod = a[i : i + 16].astype(np.int16) * b[i : i + 16].astype(np.int16)
        lo, hi = prod[:8].astype(np.int32), prod[8:].astype(np.int32)
        acc = acc + (lo[0::2] + lo[1::2])  # sadalp(acc, lo)
        acc = acc + (hi[0::2] + hi[1::2])  # sadalp(acc, hi)
        i += 16
    total = np.int32(acc.sum(dtype=np.int32))
    while i < n:
        total = np.int32(total + np.int32(a[i]) * np.int32(b[i]))
        i += 1
    return int(total)


def test_dot_models_match_scalar_on_every_tail_shape():
    rng = np.random.default_rng(7)
    for n in [0, 1, 2, 3, 7, 8, 15, 16, 17, 31, 32, 33, 63, 64, 100, 128, 257]:
        a = rng.integers(-128, 128, n).astype(np.int8)
        b = rng.integers(-128, 128, n).astype(np.int8)
        if n >= 2:  # pin the extremes into every shape
            a[0], b[0] = -128, -128
            a[1], b[1] = 127, -128
        want = dot_scalar(a, b)
        assert dot_avx2_model(a, b) == want, f"avx2 n={n}"
        assert dot_neon_model(a, b) == want, f"neon n={n}"


def test_dot_extreme_saturation_candidates():
    # All-(-128)² is the max-magnitude i16 product; vpmaddwd pair sums
    # (2·16384) and sadalp pair sums must be formed in i32, not i16 —
    # the model would catch an i16-accumulate mistake here.
    a = np.full(96, -128, np.int8)
    want = dot_scalar(a, a)
    assert want == 96 * 16384
    assert dot_avx2_model(a, a) == want
    assert dot_neon_model(a, a) == want


# ---------------------------------------------------------------------------
# Fixtures: random packed planes (kernel semantics need planes + LUTs,
# not a faithful quantizer)
# ---------------------------------------------------------------------------


def pack34_planes(rng, d_in, d_out):
    nb = d_in // 4
    idx = rng.integers(0, 16, (d_out, nb))  # 4-bit pattern index
    sign = rng.integers(0, 2, (d_out, nb))  # mirror bit
    alpha = rng.normal(size=d_out).astype(F)
    return idx, sign, alpha


def pack34_luts(rng, d_in, batch, stride=None):
    nb = d_in // 4
    stride = stride or nb * 16
    luts = rng.normal(size=(batch, stride)).astype(F)
    return luts, stride


def tl2_planes(rng, d_in, d_out):
    ng = -(-d_in // 3)
    codes = rng.integers(0, 27, (d_out, ng))  # valid 5-bit codes < 27
    alpha = rng.normal(size=d_out).astype(F)
    return codes, alpha


def tl2_luts(rng, d_in, batch):
    ng = -(-d_in // 3)
    stride = ng * TL2_LUT_STRIDE
    luts = rng.normal(size=(batch, stride)).astype(F)
    # The builder zeroes padding entries 27..32 per group; model that so
    # a walk gathering a padding lane would be caught by the zero read.
    for g in range(ng):
        luts[:, g * TL2_LUT_STRIDE + 27 : (g + 1) * TL2_LUT_STRIDE] = 0.0
    return luts, stride


def i2s_planes(rng, d_in, d_out):
    mult = rng.integers(-1, 2, (d_out, d_in)).astype(F)  # ternary decode
    alpha = rng.normal(size=d_out).astype(F)
    return mult, alpha


# ---------------------------------------------------------------------------
# Scalar replays (`engine::lut`, statement for statement)
# ---------------------------------------------------------------------------


def scalar_pack34(idx, sign, alpha, luts, stride, batch, j0, j1):
    nb = idx.shape[1]
    w = j1 - j0
    full = nb // 8
    out = np.zeros((batch, w), F)
    sb0 = 0
    while sb0 < full:  # cache tiles of TILE_SB sign bytes
        sb1 = min(sb0 + TILE_SB, full)
        for jj, j in enumerate(range(j0, j1)):
            acc0 = np.zeros(batch, F)
            acc1 = np.zeros(batch, F)
            for sb in range(sb0, sb1):
                for k in range(4):
                    b0 = sb * 8 + 2 * k
                    o0 = b0 * 16 + idx[j, b0]
                    o1 = (b0 + 1) * 16 + idx[j, b0 + 1]
                    v0 = np.where(sign[j, b0], -luts[:, o0], luts[:, o0])
                    v1 = np.where(sign[j, b0 + 1], -luts[:, o1], luts[:, o1])
                    acc0 = acc0 + v0.astype(F)  # two interleaved accumulators
                    acc1 = acc1 + v1.astype(F)
            out[:, jj] = out[:, jj] + (acc0 + acc1)
        sb0 = sb1
    for jj, j in enumerate(range(j0, j1)):  # tail blocks + α
        a = out[:, jj]
        for b in range(full * 8, nb):
            v = luts[:, b * 16 + idx[j, b]]
            a = a + np.where(sign[j, b], -v, v).astype(F)
        out[:, jj] = a * alpha[j]
    return out


def scalar_tl2(codes, alpha, luts, stride, batch, j0, j1):
    ng = codes.shape[1]
    w = j1 - j0
    out = np.zeros((batch, w), F)
    for jj, j in enumerate(range(j0, j1)):
        acc = np.zeros(batch, F)
        for g in range(ng):
            acc = acc + luts[:, g * TL2_LUT_STRIDE + codes[j, g]]
        out[:, jj] = acc * alpha[j]
    return out


def scalar_i2s(mult, alpha, xs, batch, j0, j1):
    d_in = mult.shape[1]
    w = j1 - j0
    pairs = (d_in // 4) // 2
    out = np.zeros((batch, w), F)
    for jj, j in enumerate(range(j0, j1)):
        acc0 = np.zeros(batch, F)
        acc1 = np.zeros(batch, F)
        for bp in range(pairs):
            xo = bp * 8
            # left-to-right chain: ((m0x0 + m1x1) + m2x2) + m3x3
            t0 = mult[j, xo] * xs[:, xo]
            t0 = (t0 + mult[j, xo + 1] * xs[:, xo + 1]).astype(F)
            t0 = (t0 + mult[j, xo + 2] * xs[:, xo + 2]).astype(F)
            t0 = (t0 + mult[j, xo + 3] * xs[:, xo + 3]).astype(F)
            t1 = mult[j, xo + 4] * xs[:, xo + 4]
            t1 = (t1 + mult[j, xo + 5] * xs[:, xo + 5]).astype(F)
            t1 = (t1 + mult[j, xo + 6] * xs[:, xo + 6]).astype(F)
            t1 = (t1 + mult[j, xo + 7] * xs[:, xo + 7]).astype(F)
            acc0 = acc0 + t0
            acc1 = acc1 + t1
        for i in range(pairs * 8, d_in):  # element tail into acc0 only
            acc0 = acc0 + (mult[j, i] * xs[:, i]).astype(F)
        out[:, jj] = (acc0 + acc1) * alpha[j]
    return out


# ---------------------------------------------------------------------------
# Vector walk replays (`simd::walk`): W-row chunks + scalar row tail.
# A lane vector is a shape-(W,) float32 array; elementwise numpy ops are
# the per-lane IEEE single ops the intrinsics perform.
# ---------------------------------------------------------------------------


def walk_pack34(W, idx, sign, alpha, luts, stride, batch, j0, j1):
    w = j1 - j0
    out = np.zeros((batch, w), F)
    r0 = 0
    while r0 + W <= batch:
        rows = luts[r0 : r0 + W]
        chunk = out[r0 : r0 + W]
        nb = idx.shape[1]
        full = nb // 8
        sb0 = 0
        while sb0 < full:
            sb1 = min(sb0 + TILE_SB, full)
            for jj, j in enumerate(range(j0, j1)):
                acc0 = np.zeros(W, F)  # L::zero()
                acc1 = np.zeros(W, F)
                for sb in range(sb0, sb1):
                    for k in range(4):
                        b0 = sb * 8 + 2 * k
                        o0 = b0 * 16 + idx[j, b0]
                        o1 = (b0 + 1) * 16 + idx[j, b0 + 1]
                        g0 = rows[:, o0]  # L::gather(base, stride, o0)
                        g1 = rows[:, o1]
                        if sign[j, b0]:  # L::xor_sign
                            g0 = -g0
                        if sign[j, b0 + 1]:
                            g1 = -g1
                        acc0 = acc0 + g0  # L::add
                        acc1 = acc1 + g1
                # store + the same two scalar adds per lane
                chunk[:, jj] = chunk[:, jj] + (acc0 + acc1)
            sb0 = sb1
        for jj, j in enumerate(range(j0, j1)):  # exact scalar tail replica
            a = chunk[:, jj]
            for b in range(full * 8, nb):
                v = rows[:, b * 16 + idx[j, b]]
                a = a + np.where(sign[j, b], -v, v).astype(F)
            chunk[:, jj] = a * alpha[j]
        r0 += W
    if r0 < batch:  # row tail → scalar kernel on the sliced region
        out[r0:] = scalar_pack34(idx, sign, alpha, luts[r0:], stride, batch - r0, j0, j1)
    return out


def walk_tl2(W, codes, alpha, luts, stride, batch, j0, j1):
    w = j1 - j0
    ng = codes.shape[1]
    out = np.zeros((batch, w), F)
    r0 = 0
    while r0 + W <= batch:
        rows = luts[r0 : r0 + W]
        for jj, j in enumerate(range(j0, j1)):
            acc = np.zeros(W, F)
            for g in range(ng):  # code extracted once, shared across lanes
                acc = acc + rows[:, g * TL2_LUT_STRIDE + codes[j, g]]
            out[r0 : r0 + W, jj] = acc * alpha[j]
        r0 += W
    if r0 < batch:
        out[r0:] = scalar_tl2(codes, alpha, luts[r0:], stride, batch - r0, j0, j1)
    return out


def walk_i2s(W, mult, alpha, xs, batch, j0, j1):
    d_in = mult.shape[1]
    w = j1 - j0
    pairs = (d_in // 4) // 2
    out = np.zeros((batch, w), F)
    r0 = 0
    while r0 + W <= batch:
        rows = xs[r0 : r0 + W]
        for jj, j in enumerate(range(j0, j1)):
            acc0 = np.zeros(W, F)
            acc1 = np.zeros(W, F)
            for bp in range(pairs):
                xo = bp * 8
                # splat(m)·gather(x) in the same nested-add chain as walk.rs
                t0 = (F(mult[j, xo]) * rows[:, xo]).astype(F)
                t0 = (t0 + F(mult[j, xo + 1]) * rows[:, xo + 1]).astype(F)
                t0 = (t0 + F(mult[j, xo + 2]) * rows[:, xo + 2]).astype(F)
                t0 = (t0 + F(mult[j, xo + 3]) * rows[:, xo + 3]).astype(F)
                t1 = (F(mult[j, xo + 4]) * rows[:, xo + 4]).astype(F)
                t1 = (t1 + F(mult[j, xo + 5]) * rows[:, xo + 5]).astype(F)
                t1 = (t1 + F(mult[j, xo + 6]) * rows[:, xo + 6]).astype(F)
                t1 = (t1 + F(mult[j, xo + 7]) * rows[:, xo + 7]).astype(F)
                acc0 = acc0 + t0
                acc1 = acc1 + t1
            for i in range(pairs * 8, d_in):
                acc0 = acc0 + (F(mult[j, i]) * rows[:, i]).astype(F)
            out[r0 : r0 + W, jj] = (acc0 + acc1) * alpha[j]
        r0 += W
    if r0 < batch:
        out[r0:] = scalar_i2s(mult, alpha, xs[r0:], batch - r0, j0, j1)
    return out


# ---------------------------------------------------------------------------
# Parity sweeps: both lane widths × odd tails × batch shapes × windows
# ---------------------------------------------------------------------------

BATCHES = [1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 16, 17]


def test_pack34_walk_parity():
    rng = np.random.default_rng(11)
    # nb ∈ {1, 5, 8, 9, 33}: sub-sign-byte, odd, exact, one-off, >TILE_SB·2
    for d_in in [4, 20, 32, 36, 132]:
        idx, sign, alpha = pack34_planes(rng, d_in, 7)
        for batch in BATCHES:
            luts, stride = pack34_luts(rng, d_in, batch)
            want = scalar_pack34(idx, sign, alpha, luts, stride, batch, 0, 7)
            for W in (4, 8):
                got = walk_pack34(W, idx, sign, alpha, luts, stride, batch, 0, 7)
                assert_bits_eq(got, want, f"pack34 d_in={d_in} b={batch} W={W}")


def test_tl2_walk_parity():
    rng = np.random.default_rng(13)
    for d_in in [3, 5, 7, 96, 97, 98]:  # every d_in % 3 residue
        codes, alpha = tl2_planes(rng, d_in, 5)
        for batch in BATCHES:
            luts, stride = tl2_luts(rng, d_in, batch)
            want = scalar_tl2(codes, alpha, luts, stride, batch, 0, 5)
            for W in (4, 8):
                got = walk_tl2(W, codes, alpha, luts, stride, batch, 0, 5)
                assert_bits_eq(got, want, f"tl2 d_in={d_in} b={batch} W={W}")


def test_i2s_walk_parity():
    rng = np.random.default_rng(17)
    for d_in in [4, 7, 8, 9, 11, 100, 101]:  # every d_in % 4 residue ± pair tails
        mult, alpha = i2s_planes(rng, d_in, 6)
        for batch in BATCHES:
            xs = rng.normal(size=(batch, d_in)).astype(F)
            want = scalar_i2s(mult, alpha, xs, batch, 0, 6)
            for W in (4, 8):
                got = walk_i2s(W, mult, alpha, xs, batch, 0, 6)
                assert_bits_eq(got, want, f"i2s d_in={d_in} b={batch} W={W}")


def test_column_window_parity():
    rng = np.random.default_rng(19)
    d_in, d_out, batch = 32, 11, 9
    idx, sign, alpha = pack34_planes(rng, d_in, d_out)
    luts, stride = pack34_luts(rng, d_in, batch)
    codes, alpha_t = tl2_planes(rng, d_in, d_out)
    luts_t, stride_t = tl2_luts(rng, d_in, batch)
    mult, alpha_i = i2s_planes(rng, d_in, d_out)
    xs = rng.normal(size=(batch, d_in)).astype(F)
    for j0, j1 in [(0, 11), (0, 1), (3, 8), (10, 11), (5, 5)]:
        for W in (4, 8):
            assert_bits_eq(
                walk_pack34(W, idx, sign, alpha, luts, stride, batch, j0, j1),
                scalar_pack34(idx, sign, alpha, luts, stride, batch, j0, j1),
                f"pack34 window [{j0},{j1}) W={W}",
            )
            assert_bits_eq(
                walk_tl2(W, codes, alpha_t, luts_t, stride_t, batch, j0, j1),
                scalar_tl2(codes, alpha_t, luts_t, stride_t, batch, j0, j1),
                f"tl2 window [{j0},{j1}) W={W}",
            )
            assert_bits_eq(
                walk_i2s(W, mult, alpha_i, xs, batch, j0, j1),
                scalar_i2s(mult, alpha_i, xs, batch, j0, j1),
                f"i2s window [{j0},{j1}) W={W}",
            )


def test_reassociation_would_be_caught():
    # Sanity check that bitwise assertions have teeth: summing a LUT walk
    # in a different association order must NOT be bit-identical for some
    # fixture (f32 addition is not associative). If this ever passes for
    # all fixtures, the harness itself is broken.
    rng = np.random.default_rng(23)
    d_in, d_out, batch = 96, 5, 8
    codes, alpha = tl2_planes(rng, d_in, d_out)
    luts, stride = tl2_luts(rng, d_in, batch)
    want = scalar_tl2(codes, alpha, luts, stride, batch, 0, d_out)
    ng = codes.shape[1]
    reassoc = np.zeros((batch, d_out), F)
    for jj in range(d_out):
        # pairwise tree-sum instead of scalar's left fold
        terms = np.stack(
            [luts[:, g * TL2_LUT_STRIDE + codes[jj, g]] for g in range(ng)]
        )
        while terms.shape[0] > 1:
            if terms.shape[0] % 2:
                terms = np.concatenate([terms, np.zeros((1, batch), F)])
            terms = (terms[0::2] + terms[1::2]).astype(F)
        reassoc[:, jj] = terms[0] * alpha[jj]
    assert not np.array_equal(bits(reassoc), bits(want)), (
        "tree-sum was bit-identical to the left fold — the parity "
        "assertions would not detect reassociation"
    )


if __name__ == "__main__":
    fns = [v for k, v in sorted(globals().items()) if k.startswith("test_")]
    for fn in fns:
        fn()
        print(f"ok {fn.__name__}")
    print(f"{len(fns)} behavioral checks passed")
