"""L2 correctness: QAT model shapes, STE gradients, Arenas dynamics."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

CFG = M.CONFIGS["nano"]


def _params(seed=0, **over):
    cfg = M.ModelConfig(**{**CFG.__dict__, **over})
    return M.init_params(jax.random.PRNGKey(seed), cfg), cfg


def _batch(cfg, b=2, seed=1):
    return jax.random.randint(jax.random.PRNGKey(seed), (b, cfg.seq_len + 1), 0, cfg.vocab_size)


# ---------------------------------------------------------------------------
# shapes and ABI
# ---------------------------------------------------------------------------


def test_param_spec_matches_init():
    params, cfg = _params()
    spec = M.param_spec(cfg)
    assert list(params.keys()) == [n for n, _ in spec]
    for name, shape in spec:
        assert params[name].shape == shape, name


def test_flatten_roundtrip():
    params, cfg = _params()
    flat = M.flatten(params, cfg)
    back = M.unflatten(flat, cfg)
    assert set(back) == set(params)
    for k in params:
        assert (back[k] == params[k]).all()


@pytest.mark.parametrize("method", list(M.QUANTIZERS))
def test_forward_shapes_all_methods(method):
    params, cfg = _params(method=method)
    tokens = _batch(cfg)[:, :-1]
    logits = M.forward(params, tokens, jnp.float32(0.5), cfg)
    assert logits.shape == (tokens.shape[0] * cfg.seq_len, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("gran", ["per_tensor", "per_channel", "per_group"])
def test_forward_granularities(gran):
    params, cfg = _params(granularity=gran)
    loss = M.loss_fn(params, _batch(cfg), jnp.float32(0.3), cfg)
    assert np.isfinite(float(loss))


# ---------------------------------------------------------------------------
# STE and Arenas gradient structure
# ---------------------------------------------------------------------------


def test_ste_gradient_matches_paper_eq2():
    """For a single qat_linear, ∂L/∂W = (1+λ)·Xᵀ∂L/∂Y under STE+Arenas."""
    cfg = M.ModelConfig(**{**CFG.__dict__, "method": "sherry34"})
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(8, 128)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(128, 128)).astype(np.float32))
    aux = jnp.zeros((128,), jnp.float32)
    lam = jnp.float32(0.25)

    def scalar_loss(w_):
        y = M.qat_linear(x, w_, aux, lam, cfg)
        return jnp.sum(y * y)

    g = jax.grad(scalar_loss)(w)
    y = M.qat_linear(x, w, aux, lam, cfg)
    dy = 2.0 * y
    expect = (1.0 + float(lam)) * (np.asarray(x).T @ np.asarray(dy))
    np.testing.assert_allclose(np.asarray(g), expect, rtol=1e-4, atol=1e-3)


def test_arenas_input_gradient_matches_paper_eq8():
    """∂L/∂X = ∂L/∂Y (Tα + λW)ᵀ."""
    cfg = M.ModelConfig(**{**CFG.__dict__, "method": "sherry34"})
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(4, 128)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(128, 128)).astype(np.float32))
    aux = jnp.zeros((128,), jnp.float32)
    lam = jnp.float32(0.5)

    def scalar_loss(x_):
        y = M.qat_linear(x_, w, aux, lam, cfg)
        return jnp.sum(y * y)

    g = jax.grad(scalar_loss)(x)
    y = M.qat_linear(x, w, aux, lam, cfg)
    dy = 2.0 * np.asarray(y)
    t, a = ref.sherry34_quantize(w)
    deq = np.asarray(ref.sherry34_dequant(t, a))
    expect = dy @ (deq + float(lam) * np.asarray(w)).T
    np.testing.assert_allclose(np.asarray(g), expect, rtol=1e-4, atol=1e-3)


def test_lambda_zero_kills_residual():
    """λ=0 ⇒ output equals the pure quantized product (zero overhead)."""
    cfg = M.ModelConfig(**{**CFG.__dict__, "method": "sherry34"})
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(4, 128)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(128, 128)).astype(np.float32))
    aux = jnp.zeros((128,), jnp.float32)
    y0 = M.qat_linear(x, w, aux, jnp.float32(0.0), cfg)
    t, a = ref.sherry34_quantize(w)
    expect = ref.ternary_matmul(x, t, a)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(expect), rtol=1e-4, atol=1e-4)


def test_aux_gradient_only_for_learnable_methods():
    b = _batch(CFG)
    for method in ["sherry34", "lsq"]:
        params, cfg = _params(method=method)
        g = jax.grad(M.loss_fn)(params, b, jnp.float32(0.2), cfg)
        aux_g = np.abs(np.asarray(g["layer0.wq.aux"])).sum()
        if method == "lsq":
            assert aux_g > 0.0
        else:
            assert aux_g == 0.0


# ---------------------------------------------------------------------------
# training dynamics
# ---------------------------------------------------------------------------


def test_train_step_decreases_loss():
    params, cfg = _params()
    b = _batch(cfg, b=4)
    m = {k: jnp.zeros_like(v) for k, v in params.items()}
    v = {k: jnp.zeros_like(x) for k, x in params.items()}
    losses = []
    p = params
    for s in range(8):
        l, p, m, v = M.train_step(p, m, v, b, jnp.int32(s), jnp.float32(0.5), jnp.float32(1e-3), cfg)
        losses.append(float(l))
    assert losses[-1] < losses[0]


def test_train_step_respects_frozen_aux():
    params, cfg = _params(method="absmean")
    b = _batch(cfg)
    m = {k: jnp.zeros_like(v) for k, v in params.items()}
    v = {k: jnp.zeros_like(x) for k, x in params.items()}
    _, p2, _, _ = M.train_step(params, m, v, b, jnp.int32(0), jnp.float32(0.5), jnp.float32(1e-3), cfg)
    assert (np.asarray(p2["layer0.wq.aux"]) == np.asarray(params["layer0.wq.aux"])).all()


def test_forward_only_pallas_close_to_jnp():
    """Inference graph (Pallas quantize+matmul) ≈ STE graph at λ=0."""
    params, cfg = _params(method="sherry34")
    tokens = _batch(cfg)[:, :-1]
    lp = M.forward(params, tokens, jnp.float32(0.0), cfg, forward_only=True)
    lj = M.forward(params, tokens, jnp.float32(0.0), cfg, forward_only=False)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(lj), rtol=2e-3, atol=2e-3)
