"""Behavioral model of the Rust observability histogram math.

Replays `rust/src/obs/hist.rs` — the bounded log-linear (HDR-style)
histogram that replaced the serving metrics' unbounded latency
reservoirs — in plain python/numpy and asserts the properties the Rust
unit tests pin:

* bucket indices are total-ordered and every value lands inside its
  bucket's half-open range,
* values below one octave of sub-buckets (< 32 ns) are exact,
* the bucket midpoint's relative error is ≤ 1/64 ≈ 1.56% (inside the
  ~2% bound DESIGN.md §9 documents),
* storage is fixed at N_BUCKETS counts regardless of sample count,
* percentiles recovered from the histogram match exact nearest-rank
  percentiles of the raw sample within the documented 2% error on a
  heavy-tailed (lognormal) latency distribution.

numpy-only (no jax/hypothesis): runnable as a plain script in toolchain-
less environments, and pytest-collectible in CI.
"""

import math

import numpy as np

SUB_BITS = 5
SUB = 1 << SUB_BITS  # 32 linear sub-buckets per power-of-two octave
N_BUCKETS = SUB * (64 - SUB_BITS + 1)  # 1920


def bucket_index(v):
    """Mirror of hist.rs::bucket_index over u64 nanosecond values."""
    assert 0 <= v < (1 << 64)
    if v < SUB:
        return v
    h = v.bit_length() - 1  # floor(log2 v) == 63 - leading_zeros
    octave = h - SUB_BITS + 1
    sub = (v >> (h - SUB_BITS)) & (SUB - 1)
    return octave * SUB + sub


def bucket_bounds(index):
    """Mirror of hist.rs::bucket_bounds: (lowest value, width)."""
    if index < SUB:
        return index, 1
    octave = index // SUB
    sub = index % SUB
    width = 1 << (octave - 1)
    return (SUB + sub) << (octave - 1), width


def representative(index):
    lo, width = bucket_bounds(index)
    return lo + width // 2


class LogHistModel:
    """Mirror of hist.rs::LogHistogram (counts + exact min/max/count)."""

    def __init__(self):
        self.counts = np.zeros(N_BUCKETS, dtype=np.uint64)
        self.count = 0
        self.vmin = None
        self.vmax = None

    def record(self, nanos):
        self.counts[bucket_index(nanos)] += 1
        self.vmin = nanos if self.vmin is None else min(self.vmin, nanos)
        self.vmax = nanos if self.vmax is None else max(self.vmax, nanos)
        self.count += 1

    def percentile(self, p):
        """Nearest-rank bucket walk, midpoint clamped into [min, max] —
        the exact algorithm `percentile_secs` runs (in nanos here)."""
        if self.count == 0:
            return 0
        target = max(1, math.ceil((p / 100.0) * self.count))
        seen = 0
        for i, c in enumerate(self.counts):
            seen += int(c)
            if seen >= target:
                return min(max(representative(i), self.vmin), self.vmax)
        return self.vmax


def test_small_values_are_exact():
    for v in range(SUB):
        assert bucket_index(v) == v
        assert representative(v) == v


def test_bucket_index_is_monotonic_and_contains_value():
    # Probe every octave boundary (where the index math could go wrong)
    # plus mid-bucket offsets — same probe set as the Rust unit test.
    vals = {0, (1 << 64) - 1}
    for shift in range(64):
        p = 1 << shift
        for near in (-1, 0, 1, 17):
            v = p + near
            if 0 <= v < (1 << 64):
                vals.add(v)
    prev = -1
    for v in sorted(vals):
        i = bucket_index(v)
        assert 0 <= i < N_BUCKETS, f"v={v} i={i}"
        assert i >= prev, f"index must be monotone in the value (v={v})"
        lo, width = bucket_bounds(i)
        assert lo <= v < lo + max(width, 1), f"v={v} outside [{lo}, {lo}+{width})"
        prev = i


def test_midpoint_relative_error_is_within_one_64th():
    rng = np.random.default_rng(7)
    # Log-uniform probes across ~12 decades plus fixed edge cases.
    probes = [33, 100, 1_000, 123_456, 10_000_000_000, ((1 << 64) - 1) // 3]
    probes += [int(v) for v in np.exp(rng.uniform(np.log(32), np.log(2**62), 2000))]
    for v in probes:
        rep = representative(bucket_index(v))
        err = abs(rep - v) / v
        assert err <= 1 / 64 + 1e-12, f"v={v} rep={rep} err={err}"


def test_storage_is_fixed():
    h = LogHistModel()
    for i in range(50_000):
        h.record(1 + i * 31)
    assert h.counts.shape == (N_BUCKETS,), "bucket storage never grows"
    assert h.count == 50_000


def test_single_value_percentiles_are_exact():
    h = LogHistModel()
    h.record(125_000_000)  # 0.125 s
    for p in (0.0, 50.0, 99.0, 100.0):
        assert h.percentile(p) == 125_000_000, "clamp to [min,max] makes this exact"


def test_percentiles_recover_exact_nearest_rank_within_2pct():
    # Heavy-tailed latencies: lognormal ns samples over ~4 decades, the
    # shape real serving latency/TTFT/ITL distributions take.
    rng = np.random.default_rng(42)
    xs = np.asarray(np.exp(rng.normal(np.log(5e6), 1.2, 20_000)), dtype=np.uint64)
    xs = np.maximum(xs, 1)
    h = LogHistModel()
    for v in xs:
        h.record(int(v))
    xs_sorted = np.sort(xs)
    for p in (50.0, 90.0, 99.0, 99.9):
        rank = max(1, math.ceil((p / 100.0) * len(xs_sorted))) - 1
        exact = int(xs_sorted[rank])
        got = h.percentile(p)
        err = abs(got - exact) / exact
        assert err <= 0.02, f"p{p}: got {got}, exact {exact}, err {err:.4f}"
    assert h.vmin == int(xs_sorted[0])
    assert h.vmax == int(xs_sorted[-1])


def test_adjacent_buckets_tile_the_line_with_no_gaps():
    # Walking bucket bounds from 0 must tile u64 contiguously: each
    # bucket starts exactly where the previous one ended, so no value can
    # fall between buckets (the "bounded memory, no lost samples" claim).
    pos = 0
    for i in range(N_BUCKETS):
        lo, width = bucket_bounds(i)
        assert lo == pos, f"bucket {i} starts at {lo}, expected {pos}"
        pos += width
        if pos >= (1 << 64):
            break
    assert pos >= (1 << 64), "buckets must cover the full u64 range"


if __name__ == "__main__":
    fns = [v for k, v in sorted(globals().items()) if k.startswith("test_")]
    for fn in fns:
        fn()
        print(f"ok {fn.__name__}")
    print(f"{len(fns)} behavioral checks passed")
