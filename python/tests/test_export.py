"""Export-path sanity: HLO text generation and golden vectors."""

import os
import struct

import numpy as np
import jax
import jax.numpy as jnp

from compile import model as M
from compile.aot import to_hlo_text, _spec
from compile.golden import export_golden, write_mat

jax.config.update("jax_platform_name", "cpu")


def test_hlo_text_roundtrips_through_parser(tmp_path):
    """Lowered HLO text must contain an ENTRY and parameter decls that the
    xla text parser (rust side) can consume."""
    cfg = M.CONFIGS["nano"]
    fn = M.make_loss_fn(cfg)
    pspecs = [_spec(s) for _, s in M.param_spec(cfg)]
    args = pspecs + [_spec((2, cfg.seq_len + 1), jnp.int32), _spec((), jnp.float32)]
    text = to_hlo_text(jax.jit(fn, keep_unused=True).lower(*args))
    assert "ENTRY" in text
    assert "parameter(0)" in text  # ENTRY params kept via keep_unused=True
    # one parameter per input
    n_inputs = len(args)
    assert f"parameter({n_inputs - 1})" in text
    assert f"parameter({n_inputs})" not in text


def test_write_mat_format(tmp_path):
    p = tmp_path / "m.bin"
    a = np.arange(6, dtype=np.float32).reshape(2, 3)
    write_mat(str(p), a)
    raw = p.read_bytes()
    r, c = struct.unpack("<II", raw[:8])
    assert (r, c) == (2, 3)
    back = np.frombuffer(raw[8:], dtype="<f4").reshape(2, 3)
    np.testing.assert_array_equal(back, a)


def test_export_golden_writes_all(tmp_path):
    export_golden(str(tmp_path))
    gdir = tmp_path / "golden"
    expected = [
        "w.bin",
        "x.bin",
        "sherry34.t.bin",
        "sherry34.alpha.bin",
        "absmean.t.bin",
        "absmedian.t.bin",
        "twn.t.bin",
        "binary.t.bin",
        "sherry34_per_tensor.deq.bin",
        "sherry34_per_channel.deq.bin",
        "sherry34_per_group.deq.bin",
        "sherry34.y.bin",
        "sherry34.arenas_y.bin",
        "er_expected.bin",
    ]
    for name in expected:
        assert (gdir / name).exists(), name


def test_golden_sherry_t_is_34_sparse(tmp_path):
    export_golden(str(tmp_path))
    raw = (tmp_path / "golden" / "sherry34.t.bin").read_bytes()
    r, c = struct.unpack("<II", raw[:8])
    t = np.frombuffer(raw[8:], dtype="<f4").reshape(r, c)
    nnz = (t.reshape(r // 4, 4, c) != 0).sum(axis=1)
    assert (nnz == 3).all()
