//! Packing walkthrough: the paper's §3.1/App. A format, bit by bit.
//!
//! Walks one real weight block through the whole offline phase — 3:4
//! quantization, canonicalization, 4-bit index + 1 sign bit encoding —
//! then shows the online phase: the 16-entry activation LUT a single
//! `vpshufb`-class instruction can search, and why the competing formats
//! pay (2-bit wastage, or TL2's byte-straddling codes).
//!
//! Run: `cargo run --release --example packing_walkthrough`

use sherry::engine::lut::build_luts34;
use sherry::pack::pack34::{decode_block, encode_block, PATTERNS};
use sherry::pack::{Packed34, PackedTl2};
use sherry::quant::{quantize, Granularity, Method};
use sherry::tensor::Mat;
use sherry::util::Pcg64;

fn main() {
    let mut rng = Pcg64::seeded(7);

    println!("== offline phase: one block ==");
    let w_block = [0.42f32, -0.03, -0.88, 0.17];
    println!("weights     : {w_block:?}");
    let wm = Mat::from_vec(4, 1, w_block.to_vec());
    let q = quantize(&wm, Method::Sherry34, Granularity::PerChannel);
    let block: Vec<i8> = q.t_col(0);
    println!("ternarized  : {block:?}   (min-|w| lane pruned, others sign(w); Eq. 4)");
    let (idx, mirror) = encode_block(&block);
    println!("encoded     : index {idx:#06b} ({idx}), sign bit {}", mirror as u8);
    println!("canonical   : {:?} (first non-zero forced +1; mirror bit restores)", PATTERNS[idx as usize]);
    assert_eq!(decode_block(idx, mirror)[..], block[..]);
    println!("→ 5 bits for 4 weights = 1.25 bits/weight\n");

    println!("== the 16 canonical patterns (= the vpshufb LUT index space) ==");
    for (i, p) in PATTERNS.iter().enumerate() {
        println!("  idx {i:>2} ({i:04b}): {p:?}");
    }
    println!("  ×2 mirror states = 32 = C(4,3)·2³: saturates 5 bits exactly (§3.1 point 3)\n");

    println!("== online phase: the activation LUT ==");
    let x = [1.0f32, 2.0, 4.0, 8.0];
    let mut luts = vec![0.0f32; 16];
    build_luts34(&x, &mut luts);
    println!("activations  : {x:?}");
    println!("16-entry LUT : {luts:?}");
    println!("lookup       : lut[{idx}] = {}, sign {} → partial sum {}", luts[idx as usize], mirror as u8, if mirror { -luts[idx as usize] } else { luts[idx as usize] });
    // verify against the direct dot product
    let direct: f32 = w_block
        .iter()
        .zip(&block)
        .map(|(_, &t)| 0.0 * t as f32)
        .sum::<f32>()
        + block.iter().zip(&x).map(|(&t, &xi)| t as f32 * xi).sum::<f32>();
    let looked_up = if mirror { -luts[idx as usize] } else { luts[idx as usize] };
    assert!((direct - looked_up).abs() < 1e-6);
    println!("matches Σ t·x = {direct} — multiplication-free (Fig. 9)\n");

    println!("== why the baselines pay ==");
    let w = Mat::randn(&mut rng, 960, 8, 1.0); // divisible by 3 and 4
    let qs = quantize(&w, Method::Sherry34, Granularity::PerChannel);
    let qd = quantize(&w, Method::AbsMean, Granularity::PerChannel);
    let p34 = Packed34::from_ternary(&qs);
    let tl2 = PackedTl2::from_ternary(&qd);
    println!(
        "sherry  : idx nibbles + sign bitplane, all byte-aligned; {} bytes/channel",
        p34.idx_bytes_per_ch + p34.sign_bytes_per_ch
    );
    // Show TL2's straddling: which groups cross a byte boundary?
    let straddling = (0..tl2.n_groups())
        .filter(|g| {
            let bit = g * 5;
            bit / 8 != (bit + 4) / 8
        })
        .count();
    println!(
        "tl2     : {}/{} 5-bit codes straddle a byte boundary → every decode is a 16-bit load+shift (Fig. 2 middle)",
        straddling,
        tl2.n_groups()
    );
    println!("i2_s    : byte-aligned but 2.0 bits/w — {:.0}% larger than sherry's 1.25", (2.0 / 1.25 - 1.0) * 100.0);
    println!("\npacking_walkthrough OK");
}
