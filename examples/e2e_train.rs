//! End-to-end driver (DESIGN.md §6): proves every layer composes.
//!
//! 1. Loads the AOT train-step for the `e2e` config (6-layer, d=384
//!    LLaMA-style QAT transformer with Sherry 3:4 + Arenas) and trains it
//!    for a few hundred steps on the synthetic corpus via PJRT, logging
//!    the loss curve while Layer-3 anneals λ_t.
//! 2. Exports the trained latents as a checkpoint.
//! 3. PTQ-projects them, packs to 1.25-bit, and serves the model on the
//!    native LUT engine — reporting accuracy, perplexity, tokens/s and
//!    model bytes against the BF16 / I2_S / TL2 baselines.
//!
//! Run: `cargo run --release --example e2e_train -- [steps]`
//! (default 250; results recorded in EXPERIMENTS.md)

use std::time::Instant;

use sherry::engine::{KvCache, NativeConfig, Scratch, TernaryModel};
use sherry::eval;
use sherry::pack::Format;
use sherry::quant::Schedule;
use sherry::runtime::Runtime;
use sherry::train::{checkpoint, TrainConfig, Trainer};

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(250);
    let artifacts = sherry::artifacts_dir();
    anyhow::ensure!(
        artifacts.join("manifest.tsv").exists(),
        "artifacts missing — run `make artifacts` first"
    );

    // --- 1. QAT training through PJRT -----------------------------------
    let cfg = TrainConfig {
        config: "e2e".into(),
        method: "sherry34".into(),
        granularity: "per_channel".into(),
        steps,
        lr: 1e-3,
        schedule: Schedule::CosineWarmup,
        seed: 0,
        er_layer: "layer0.wq".into(),
        er_every: (steps / 8).max(1),
    };
    let mut rt = Runtime::cpu(&artifacts)?;
    let mut trainer = Trainer::new(&mut rt, &cfg)?;
    println!("[e2e] training e2e/sherry34 for {steps} steps (Arenas cosine-warmup)...");
    let t0 = Instant::now();
    let outcome = trainer.run(&cfg)?;
    let train_s = t0.elapsed().as_secs_f64();
    println!("[e2e] loss curve:");
    for (i, l) in outcome.losses.iter().enumerate() {
        if i % (steps / 20).max(1) == 0 || i + 1 == steps {
            println!("  step {i:>5}  loss {l:.4}");
        }
    }
    println!("[e2e] gradient effective-rank trace (layer0.wq):");
    for (s, er) in &outcome.er_trace {
        println!("  step {s:>5}  ER {er:.1}");
    }
    let eval_loss = trainer.eval_loss(&cfg, &outcome.params, 4)?;
    println!(
        "[e2e] trained in {train_s:.0}s ({:.2} s/step) | final train loss {:.4} | heldout loss {:.4} (ppl {:.1}) | final λ {:.4}",
        train_s / steps as f64,
        outcome.losses.last().unwrap(),
        eval_loss,
        eval_loss.exp(),
        outcome.final_lambda,
    );

    // --- 2. checkpoint ----------------------------------------------------
    let ckpt = artifacts.join("checkpoints/e2e_sherry.ckpt");
    checkpoint::save(&ckpt, &outcome.params)?;
    println!("[e2e] checkpoint → {}", ckpt.display());

    // --- 3. native serving: accuracy + efficiency across formats ----------
    let native = NativeConfig::named("e2e").unwrap();
    println!("\n[e2e] synthetic-benchmark accuracy (PTQ sherry34, LUT-served):");
    let row = eval::evaluate_ptq(
        "SherryLLM-e2e",
        native,
        &outcome.params,
        sherry::quant::Method::Sherry34,
        sherry::quant::Granularity::PerChannel,
        25,
        0,
    );
    println!("{}", eval::render_table("e2e evaluation", &[row]));

    println!("[e2e] token-generation efficiency across formats (Table 4 shape):");
    println!("{:<8} {:>10} {:>12} {:>12}", "format", "size MB", "tok/s", "vs bf16");
    let mut bf16_tps = 0.0f64;
    for format in [Format::Dense, Format::I2S, Format::Tl2, Format::Sherry] {
        let model = TernaryModel::build(native, &outcome.params, format);
        let mut cache = KvCache::new(&native);
        let mut scratch = Scratch::default();
        // warmup + timed generation
        model.generate(&[1, 2, 3, 4], 16, &mut cache, &mut scratch);
        let n_tok = 96usize;
        let t0 = Instant::now();
        let out = model.generate(&[1, 2, 3, 4], n_tok, &mut cache, &mut scratch);
        let dt = t0.elapsed().as_secs_f64();
        let tps = out.len() as f64 / dt;
        if format == Format::Dense {
            bf16_tps = tps;
        }
        println!(
            "{:<8} {:>10.2} {:>12.1} {:>11.2}x",
            format.name(),
            model.bytes() as f64 / 1e6,
            tps,
            tps / bf16_tps
        );
    }
    println!("\ne2e_train OK");
    Ok(())
}
