//! Quickstart: the Sherry pipeline on one weight matrix.
//!
//! 1. Quantize a float matrix with the 3:4 Sparse-AbsMean quantizer
//!    (paper Eq. 4-5) and compare reconstruction error against baselines.
//! 2. Pack it into the 1.25-bit format (4-bit index + 1 sign bit per
//!    4-weight block) next to TL2 (1.67-bit) and I2_S (2-bit).
//! 3. Run the multiplication-free LUT GEMV and verify it matches the
//!    dense product exactly.
//!
//! Run: `cargo run --release --example quickstart`

use sherry::engine::lut;
use sherry::pack::{Format, Packed34, PackedI2S, PackedTl2};
use sherry::quant::{quantize, reconstruction_error, Granularity, Method};
use sherry::tensor::Mat;
use sherry::util::Pcg64;

fn main() {
    let (d_in, d_out) = (1024, 256);
    let mut rng = Pcg64::seeded(42);
    let w = Mat::randn(&mut rng, d_in, d_out, 0.05);

    println!("== 1. Quantization (d_in={d_in}, d_out={d_out}) ==");
    println!("{:<12} {:>12} {:>10} {:>10}", "method", "L2 error", "sparsity", "bits/w");
    let mut sherry_q = None;
    for m in [Method::Sherry34, Method::AbsMean, Method::AbsMedian, Method::Twn, Method::Binary] {
        let q = quantize(&w, m, Granularity::PerChannel);
        println!(
            "{:<12} {:>12.4} {:>9.1}% {:>10.2}",
            m.name(),
            reconstruction_error(&w, &q),
            q.sparsity() * 100.0,
            m.bits_per_weight()
        );
        if m == Method::Sherry34 {
            assert!(q.is_34_sparse(), "3:4 constraint (Eq. 3) violated");
            sherry_q = Some(q);
        }
    }
    let q = sherry_q.unwrap();

    println!("\n== 2. Packing ==");
    let p34 = Packed34::from_ternary(&q);
    let n = (d_in * d_out) as f32;
    println!(
        "sherry 1.25-bit: {} weight bytes ({:.3} bits/weight; {} idx + {} sign bytes/channel)",
        p34.weight_bytes(),
        p34.weight_bytes() as f32 * 8.0 / n,
        p34.idx_bytes_per_ch,
        p34.sign_bytes_per_ch,
    );
    let qd = quantize(&w, Method::AbsMean, Granularity::PerChannel);
    for (f, bytes) in [
        (Format::Tl2, PackedTl2::from_ternary(&qd).weight_bytes()),
        (Format::I2S, PackedI2S::from_ternary(&qd).weight_bytes()),
    ] {
        println!(
            "{:<6} {:>5.2}-bit: {} weight bytes ({:.3} bits/weight)",
            f.name(),
            f.bits_per_weight(),
            bytes,
            bytes as f32 * 8.0 / n
        );
    }
    // round-trip check
    for j in [0usize, 17, d_out - 1] {
        assert_eq!(p34.decode_channel(j), q.t_col(j), "pack34 round-trip");
    }

    println!("\n== 3. LUT GEMV (Fig. 9 engine) ==");
    let x = rng.normal_vec(d_in);
    let mut luts = vec![0.0f32; (d_in / 4) * 16];
    let mut y = vec![0.0f32; d_out];
    lut::gemv_pack34(&p34, &x, &mut luts, &mut y);
    // dense reference
    let deq = q.dequant().transpose();
    let mut y_ref = vec![0.0f32; d_out];
    sherry::tensor::gemv_f32(&deq.data, d_out, d_in, &x, &mut y_ref);
    let max_err = y
        .iter()
        .zip(&y_ref)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("LUT vs dense max |Δ| = {max_err:.2e} (pure adds + one α multiply per channel)");
    assert!(max_err < 1e-3, "LUT engine must match dense");
    println!("\nquickstart OK");
}
