//! Serving demo: the Layer-3 coordinator under a bursty request trace.
//!
//! Spins up the native Sherry 1.25-bit engine behind the continuous
//! batcher + paged KV cache (block allocator + radix prefix sharing),
//! replays a Poisson trace with a shared system prompt, and prints
//! routing + latency + prefix-hit metrics per format — the
//! edge-deployment scenario the paper's introduction motivates.
//!
//! Run: `cargo run --release --example serve_demo`

use sherry::coordinator::{serve_trace, BatcherConfig, ServerConfig, TraceSpec};
use sherry::engine::{random_weights, NativeConfig, TernaryModel};
use sherry::pack::Format;
use sherry::train::checkpoint;

fn main() -> anyhow::Result<()> {
    let cfg = NativeConfig::named("micro").unwrap();
    // Use the e2e-trained checkpoint when present, else random weights.
    let ckpt = sherry::artifacts_dir().join("checkpoints/micro_sherry.ckpt");
    let weights = if ckpt.exists() {
        println!("[serve_demo] using checkpoint {}", ckpt.display());
        checkpoint::load(&ckpt)?
    } else {
        println!("[serve_demo] no checkpoint; random weights");
        random_weights(&cfg, 7)
    };

    let trace = TraceSpec {
        n_requests: 24,
        mean_interarrival_s: 0.005,
        prompt_len: 12,
        // First 8 prompt tokens are a shared system prompt: later
        // requests reuse its frozen KV pages instead of re-prefilling.
        shared_prefix_len: 8,
        max_new_tokens: 32,
        seed: 3,
        // A third of the traffic rides the Batch class: it yields to
        // Interactive arrivals (and is preempted under page pressure).
        batch_fraction: 0.33,
        ..Default::default()
    };
    let server_cfg = ServerConfig {
        batcher: BatcherConfig { max_active: 6, token_budget: 6 * (12 + 32), ..Default::default() },
        kv_capacity: 6,
        page_size: 8,
        workers: 6,
        ..Default::default()
    };

    println!(
        "[serve_demo] trace: {} requests, {} prompt + {} gen tokens, Poisson {:.0}ms\n",
        trace.n_requests,
        trace.prompt_len,
        trace.max_new_tokens,
        trace.mean_interarrival_s * 1e3
    );
    println!(
        "{:<8} {:>9} {:>12} {:>10} {:>10} {:>9}",
        "format", "size MB", "tok/s", "p50 lat", "p99 lat", "kv-hit%"
    );
    for format in [Format::Dense, Format::I2S, Format::Tl2, Format::Sherry] {
        let model = TernaryModel::build(cfg, &weights, format);
        let (completions, metrics) = serve_trace(&model, server_cfg, trace);
        assert_eq!(completions.len(), trace.n_requests, "all requests must finish");
        println!(
            "{:<8} {:>9.2} {:>12.1} {:>9.3}s {:>9.3}s {:>8.0}%",
            format.name(),
            model.bytes() as f64 / 1e6,
            metrics.throughput_tps(),
            metrics.latency_p50(),
            metrics.latency_p99(),
            100.0 * metrics.prefix_hit_rate(),
        );
    }
    println!("\nserve_demo OK");
    Ok(())
}
