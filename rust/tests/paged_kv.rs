//! Paged KV-cache subsystem: cross-layer guarantees.
//!
//! 1. Bit-for-bit token parity between paged and contiguous KV on mixed
//!    (ragged, continuously batched) traces, prefix sharing on and off.
//! 2. Allocator property tests: no double-free, refcounts return to zero
//!    after a full trace, copy-on-write never mutates a shared page.

use sherry::cache::{BlockAllocator, BlockTable, KvBatch, KvDtype, Plane, PrefixIndex};
use sherry::coordinator::{
    serve_trace, BatcherConfig, Request, Server, ServerConfig, TraceSpec,
};
use sherry::engine::{random_weights, KvCache, NativeConfig, Scratch, TernaryModel};
use sherry::pack::Format;
use sherry::util::{prop, Pcg64};

fn nano_model(seed: u64, format: Format) -> TernaryModel {
    let cfg = NativeConfig::named("nano").unwrap();
    TernaryModel::build(cfg, &random_weights(&cfg, seed), format)
}

/// Decode the same ragged multi-sequence trace through (a) contiguous
/// per-sequence caches and (b) block tables over a paged arena, asserting
/// exact logits equality at every step. Exercises pages straddling
/// positions (page_size 4 < prompt lengths) and sequences at different
/// offsets in one fused call.
#[test]
fn paged_and_contiguous_decode_are_bit_for_bit_identical() {
    let cfg = NativeConfig::named("nano").unwrap();
    let prompts: [&[u32]; 3] = [&[1, 2, 3, 4, 5, 6, 7], &[9, 8], &[5, 5, 5, 5, 5]];
    let decode_steps = 6usize;
    for format in [Format::Sherry, Format::I2S] {
        let model = nano_model(3, format);
        let mut scratch = Scratch::default();

        // (a) contiguous, via the public forward_batch wrapper.
        let mut caches: Vec<KvCache> = prompts.iter().map(|_| KvCache::new(&cfg)).collect();
        // (b) paged: one shared arena, page_size 4.
        let mut alloc = BlockAllocator::new(&cfg, 32, 4);
        let mut tables: Vec<BlockTable> = prompts.iter().map(|_| BlockTable::new(4)).collect();

        let mut last_contig: Vec<Vec<f32>> = vec![Vec::new(); prompts.len()];
        let mut last_paged: Vec<Vec<f32>> = vec![Vec::new(); prompts.len()];
        let max_len = prompts.iter().map(|p| p.len()).max().unwrap() + decode_steps;
        for step in 0..max_len {
            // Ragged plan: sequence i feeds prompt[step] while it lasts,
            // then replays its own greedy continuation.
            let sel: Vec<usize> = (0..prompts.len())
                .filter(|&i| step < prompts[i].len() + decode_steps)
                .collect();
            let toks: Vec<u32> = sel
                .iter()
                .map(|&i| {
                    if step < prompts[i].len() {
                        prompts[i][step]
                    } else {
                        // greedy continuation from the contiguous run
                        // (paged run must reproduce it exactly anyway)
                        sherry::engine::argmax(&last_contig[i]) as u32
                    }
                })
                .collect();

            let contig_logits = {
                let mut refs: Vec<&mut KvCache> = Vec::new();
                let mut rest: &mut [KvCache] = &mut caches;
                let mut taken = 0usize;
                for &i in &sel {
                    let (_, tail) = rest.split_at_mut(i - taken);
                    let (head, tail) = tail.split_at_mut(1);
                    refs.push(&mut head[0]);
                    rest = tail;
                    taken = i + 1;
                }
                model.forward_batch(&toks, &mut refs, &mut scratch, None)
            };
            let paged_logits = {
                let mut refs: Vec<&mut BlockTable> = Vec::new();
                let mut rest: &mut [BlockTable] = &mut tables;
                let mut taken = 0usize;
                for &i in &sel {
                    let (_, tail) = rest.split_at_mut(i - taken);
                    let (head, tail) = tail.split_at_mut(1);
                    refs.push(&mut head[0]);
                    rest = tail;
                    taken = i + 1;
                }
                let mut kvb = KvBatch::Paged { alloc: &mut alloc, tables: &mut refs };
                model.forward_kv(&toks, &mut kvb, &mut scratch, None)
            };
            for (row, &i) in sel.iter().enumerate() {
                assert_eq!(
                    contig_logits.row(row),
                    paged_logits.row(row),
                    "{format:?} seq {i} step {step}: paged logits diverged"
                );
                last_contig[i] = contig_logits.row(row).to_vec();
                last_paged[i] = paged_logits.row(row).to_vec();
            }
        }
        for (a, b) in last_contig.iter().zip(&last_paged) {
            assert_eq!(a, b);
        }
        for t in &mut tables {
            t.release_all(&mut alloc);
        }
        assert_eq!(alloc.used_pages(), 0, "all pages returned");
    }
}

/// Serve a mixed trace (short + long + context-capped requests, shared
/// system prompt) with prefix sharing on and off: tokens must be
/// identical to each other and to the single-stream contiguous baseline,
/// and every sequence-held page reference must be returned.
#[test]
fn mixed_trace_token_parity_sharing_on_and_off() {
    let m = nano_model(17, Format::Sherry);
    let spec = TraceSpec {
        n_requests: 10,
        mean_interarrival_s: 0.003,
        prompt_len: 20,
        shared_prefix_len: 12,
        max_new_tokens: 8,
        seed: 29,
        ..Default::default()
    };
    let base = ServerConfig {
        batcher: BatcherConfig { max_active: 5, token_budget: 100_000, ..Default::default() },
        kv_capacity: 4,
        page_size: 4,
        ..Default::default()
    };
    let on = ServerConfig { prefix_sharing: true, ..base };
    let off = ServerConfig { prefix_sharing: false, ..base };
    let (mut c_on, m_on) = serve_trace(&m, on, spec);
    let (mut c_off, m_off) = serve_trace(&m, off, spec);
    assert_eq!(c_on.len(), spec.n_requests);
    assert_eq!(c_off.len(), spec.n_requests);
    c_on.sort_by_key(|c| c.id);
    c_off.sort_by_key(|c| c.id);

    let reqs = spec.generate(m.cfg.vocab_size);
    let mut scratch = Scratch::default();
    for ((req, a), b) in reqs.iter().zip(&c_on).zip(&c_off) {
        assert_eq!(a.tokens, b.tokens, "sharing changed tokens for request {}", req.id);
        let mut cache = KvCache::new(&m.cfg);
        let expect = m.generate(&req.prompt, req.max_new_tokens, &mut cache, &mut scratch);
        assert_eq!(expect, a.tokens, "request {} diverged from contiguous baseline", req.id);
    }
    // Refcount hygiene: after the trace only index-frozen pages remain.
    assert_eq!(m_on.kv_pages_end_in_use, m_on.kv_pages_index);
    assert_eq!(m_off.kv_pages_end_in_use, 0);
    assert_eq!(m_off.kv_pages_index, 0);
}

/// Allocator model check: random interleavings of alloc / retain /
/// release against a reference refcount model. No double-free is
/// observable (release panics are asserted separately), the free count
/// always matches the model, and draining every handle returns the
/// arena to fully free.
#[test]
fn prop_allocator_refcounts_match_model() {
    let cfg = NativeConfig::named("nano").unwrap();
    prop::check(
        "allocator refcount model",
        40,
        |rng| {
            let n_pages = prop::gens::usize_in(rng, 1, 12);
            let ops: Vec<u8> = (0..prop::gens::usize_in(rng, 5, 120))
                .map(|_| rng.below(3) as u8)
                .collect();
            (n_pages, ops, rng.next_u64())
        },
        |&(n_pages, ref ops, seed)| {
            let mut rng = Pcg64::seeded(seed);
            let mut alloc = BlockAllocator::new(&cfg, n_pages, 2);
            // Model: multiset of live handles (page → refs we hold).
            let mut held: Vec<u32> = Vec::new(); // one entry per handle
            for &op in ops {
                match op {
                    0 => {
                        // alloc
                        if let Some(p) = alloc.alloc() {
                            held.push(p);
                        } else if alloc.free_pages() != 0 {
                            return Err("alloc failed with free pages".into());
                        }
                    }
                    1 => {
                        // retain a random held page
                        if !held.is_empty() {
                            let p = held[rng.below(held.len() as u64) as usize];
                            alloc.retain(p);
                            held.push(p);
                        }
                    }
                    _ => {
                        // release a random handle
                        if !held.is_empty() {
                            let i = rng.below(held.len() as u64) as usize;
                            let p = held.swap_remove(i);
                            alloc.release(p);
                        }
                    }
                }
                // Invariant: every held page is live with the right count.
                for &p in &held {
                    let want = held.iter().filter(|&&q| q == p).count() as u32;
                    if alloc.ref_count(p) != want {
                        return Err(format!(
                            "page {p}: refcount {} != model {want}",
                            alloc.ref_count(p)
                        ));
                    }
                }
                let live: std::collections::BTreeSet<u32> = held.iter().copied().collect();
                if alloc.used_pages() != live.len() {
                    return Err(format!(
                        "used {} != live {}",
                        alloc.used_pages(),
                        live.len()
                    ));
                }
            }
            // Drain: every refcount must return to zero.
            while let Some(p) = held.pop() {
                alloc.release(p);
            }
            if alloc.used_pages() != 0 || alloc.free_pages() != n_pages {
                return Err("arena not fully free after draining all handles".into());
            }
            Ok(())
        },
    );
}

/// CoW property: under random prompt pairs sharing random prefixes, the
/// diverging sequence never mutates a page the index (or donor) still
/// references — the frozen page's bytes are bit-identical before and
/// after the second sequence writes through its table.
#[test]
fn prop_cow_never_mutates_shared_pages() {
    let cfg = NativeConfig::named("nano").unwrap();
    let d = cfg.d_model;
    prop::check(
        "CoW preserves frozen pages",
        25,
        |rng| {
            let ps = prop::gens::usize_in(rng, 2, 6);
            let prompt_len = prop::gens::usize_in(rng, ps + 1, 4 * ps);
            let appends = prop::gens::usize_in(rng, 1, 2 * ps);
            (ps, prompt_len, appends, rng.next_u64())
        },
        |&(ps, prompt_len, appends, seed)| {
            let mut rng = Pcg64::seeded(seed);
            let mut alloc = BlockAllocator::new(&cfg, 64, ps);
            let mut index = PrefixIndex::new(ps);
            let prompt: Vec<u32> = (0..prompt_len).map(|_| rng.below(50) as u32).collect();

            // Donor: prefill `prompt_len` positions with marked rows.
            let mut donor = BlockTable::new(ps);
            for pos in 0..prompt_len {
                donor.prepare_append(&mut alloc);
                let (page, slot) = donor.slot_for(pos);
                let row = vec![pos as f32 + 1.0; d];
                for li in 0..cfg.n_layers {
                    alloc.write_row(li, page, slot, &row, &row);
                }
                donor.advance();
            }
            index.register(&prompt, &donor, &mut alloc);

            // Recipient shares the longest usable prefix.
            let cap = prompt_len - 1;
            let (pages, matched) = index.probe_pages(&prompt, cap);
            if matched == 0 {
                // prompt shorter than one page: nothing frozen; fine.
                donor.release_all(&mut alloc);
                index.clear(&mut alloc);
                return Ok(());
            }
            for &p in &pages {
                alloc.retain(p);
            }
            let frozen: Vec<u32> = pages.clone();
            let mut scratch = Vec::new();
            let snapshot: Vec<Vec<f32>> = frozen
                .iter()
                .map(|&p| alloc.read_block(Plane::K, 0, p, ps, &mut scratch).to_vec())
                .collect();

            let mut recip = BlockTable::from_shared(ps, pages, matched);
            for i in 0..appends {
                let pos = matched + i;
                recip.prepare_append(&mut alloc);
                let (page, slot) = recip.slot_for(pos);
                let row = vec![-(pos as f32) - 100.0; d];
                for li in 0..cfg.n_layers {
                    alloc.write_row(li, page, slot, &row, &row);
                }
                recip.advance();
            }
            // Every frozen page is byte-identical to its snapshot.
            for (&p, snap) in frozen.iter().zip(&snapshot) {
                if alloc.read_block(Plane::K, 0, p, ps, &mut scratch) != snap.as_slice() {
                    return Err(format!("shared page {p} was mutated (ps={ps})"));
                }
            }
            // And the recipient still reads the shared prefix correctly.
            for pos in 0..matched {
                let (page, slot) = recip.slot_for(pos);
                let blk = alloc.read_block(Plane::K, 0, page, slot + 1, &mut scratch);
                if blk[slot * d] != pos as f32 + 1.0 {
                    return Err(format!("recipient lost shared row {pos}"));
                }
            }
            recip.release_all(&mut alloc);
            donor.release_all(&mut alloc);
            index.clear(&mut alloc);
            if alloc.used_pages() != 0 {
                return Err("refcounts did not return to zero".into());
            }
            Ok(())
        },
    );
}

/// Int8 KV pages against the f32 baseline: decode the same ragged
/// multi-sequence trace (token stream fixed by the f32 greedy run)
/// through f32 and int8 paged arenas and assert the logits stay within
/// the documented error bound at every step. The bound (DESIGN.md §4):
/// per-element dequantization error is ≤ (page_size + 1)/2 quanta of the
/// per-page per-head scale (≲ 1% of the head's absmax at page_size 4),
/// which propagates to a small relative logit error —
/// asserted here as `|Δ| ≤ 0.25 + 0.1·|logit|`, loose enough to be
/// seed-stable and tight enough to catch a broken scale path (a wrong
/// scale is a >100% error).
#[test]
fn int8_kv_logit_error_bounded_vs_f32() {
    let cfg = NativeConfig::named("nano").unwrap();
    let model = nano_model(7, Format::Sherry);
    let mut scratch = Scratch::default();
    let prompts: [&[u32]; 3] = [&[1, 2, 3, 4, 5, 6, 7], &[9, 8], &[5, 5, 5, 5, 5]];
    let decode_steps = 8usize;

    let mut f32_alloc = BlockAllocator::new_with(&cfg, 32, 4, KvDtype::F32);
    let mut i8_alloc = BlockAllocator::new_with(&cfg, 32, 4, KvDtype::Int8);
    let mut f32_tables: Vec<BlockTable> = prompts.iter().map(|_| BlockTable::new(4)).collect();
    let mut i8_tables: Vec<BlockTable> = prompts.iter().map(|_| BlockTable::new(4)).collect();

    let mut last_f32: Vec<Vec<f32>> = vec![Vec::new(); prompts.len()];
    let mut max_err = 0.0f32;
    let max_len = prompts.iter().map(|p| p.len()).max().unwrap() + decode_steps;
    for step in 0..max_len {
        let sel: Vec<usize> = (0..prompts.len())
            .filter(|&i| step < prompts[i].len() + decode_steps)
            .collect();
        // Both runs feed the f32 run's greedy continuation so the two
        // KV histories stay token-identical and only storage differs.
        let toks: Vec<u32> = sel
            .iter()
            .map(|&i| {
                if step < prompts[i].len() {
                    prompts[i][step]
                } else {
                    sherry::engine::argmax(&last_f32[i]) as u32
                }
            })
            .collect();
        let run = |alloc: &mut BlockAllocator,
                   tables: &mut Vec<BlockTable>,
                   scratch: &mut Scratch| {
            let mut refs: Vec<&mut BlockTable> = Vec::new();
            let mut rest: &mut [BlockTable] = tables;
            let mut taken = 0usize;
            for &i in &sel {
                let (_, tail) = rest.split_at_mut(i - taken);
                let (head, tail) = tail.split_at_mut(1);
                refs.push(&mut head[0]);
                rest = tail;
                taken = i + 1;
            }
            let mut kvb = KvBatch::Paged { alloc, tables: &mut refs };
            model.forward_kv(&toks, &mut kvb, scratch, None)
        };
        let lf = run(&mut f32_alloc, &mut f32_tables, &mut scratch);
        let lq = run(&mut i8_alloc, &mut i8_tables, &mut scratch);
        for (row, &i) in sel.iter().enumerate() {
            for (a, b) in lq.row(row).iter().zip(lf.row(row)) {
                let err = (a - b).abs();
                max_err = max_err.max(err);
                assert!(
                    err <= 0.25 + 0.1 * b.abs(),
                    "seq {i} step {step}: int8 logit {a} vs f32 {b} (err {err})"
                );
            }
            last_f32[i] = lf.row(row).to_vec();
        }
    }
    println!("int8-vs-f32 max logit error over the trace: {max_err}");
    for (t, alloc) in [(&mut f32_tables, &mut f32_alloc), (&mut i8_tables, &mut i8_alloc)] {
        for table in t.iter_mut() {
            table.release_all(alloc);
        }
        assert_eq!(alloc.used_pages(), 0);
    }
}

/// End-to-end token tolerance across the three KV dtypes: decode the
/// same teacher-forced trace through f32, int8, and ternary paged
/// arenas and assert the quantized greedy choice matches f32 wherever
/// f32 is not itself ambiguous at the dtype's documented logit
/// tolerance. Argmax can only flip when the f32 top-2 margin is within
/// twice the elementwise logit error, so gating on
/// `margin > 2·tol(dtype)` makes token equality a consequence of the §4
/// bounds rather than a seed lottery: int8 uses the bound asserted
/// above (`0.25 + 0.1·|logit|`); ternary uses a deliberately generous
/// envelope (`1.0 + 0.5·|logit|`) — 3:4 sparsification is lossy, but a
/// broken scale, LUT walk, or fixed-point a·V path is a >100% error and
/// flips large-margin tokens immediately.
#[test]
fn quantized_decode_tokens_match_f32_within_documented_tolerance() {
    fn top2(row: &[f32]) -> (usize, f32, f32) {
        let (mut bi, mut b1, mut b2) = (0usize, f32::NEG_INFINITY, f32::NEG_INFINITY);
        for (i, &x) in row.iter().enumerate() {
            if x > b1 {
                b2 = b1;
                b1 = x;
                bi = i;
            } else if x > b2 {
                b2 = x;
            }
        }
        (bi, b1, b1 - b2)
    }

    let cfg = NativeConfig::named("nano").unwrap();
    let model = nano_model(7, Format::Sherry);
    let mut scratch = Scratch::default();
    let prompts: [&[u32]; 3] = [&[1, 2, 3, 4, 5, 6, 7], &[9, 8], &[5, 5, 5, 5, 5]];
    let decode_steps = 8usize;

    let mut allocs = [
        BlockAllocator::new_with(&cfg, 32, 4, KvDtype::F32),
        BlockAllocator::new_with(&cfg, 32, 4, KvDtype::Int8),
        BlockAllocator::new_with(&cfg, 32, 4, KvDtype::Ternary),
    ];
    let mut tables: Vec<Vec<BlockTable>> = (0..allocs.len())
        .map(|_| prompts.iter().map(|_| BlockTable::new(4)).collect())
        .collect();

    let mut last_f32: Vec<Vec<f32>> = vec![Vec::new(); prompts.len()];
    let (mut gated_i8, mut gated_t) = (0u32, 0u32);
    let (mut steps, mut agree_i8, mut agree_t) = (0u32, 0u32, 0u32);
    let max_len = prompts.iter().map(|p| p.len()).max().unwrap() + decode_steps;
    for step in 0..max_len {
        let sel: Vec<usize> = (0..prompts.len())
            .filter(|&i| step < prompts[i].len() + decode_steps)
            .collect();
        // All three runs feed the f32 run's greedy continuation so the
        // KV histories stay token-identical and only storage differs.
        let toks: Vec<u32> = sel
            .iter()
            .map(|&i| {
                if step < prompts[i].len() {
                    prompts[i][step]
                } else {
                    sherry::engine::argmax(&last_f32[i]) as u32
                }
            })
            .collect();
        let mut logits = Vec::with_capacity(allocs.len());
        for (alloc, tabs) in allocs.iter_mut().zip(tables.iter_mut()) {
            let mut refs: Vec<&mut BlockTable> = Vec::new();
            let mut rest: &mut [BlockTable] = tabs;
            let mut taken = 0usize;
            for &i in &sel {
                let (_, tail) = rest.split_at_mut(i - taken);
                let (head, tail) = tail.split_at_mut(1);
                refs.push(&mut head[0]);
                rest = tail;
                taken = i + 1;
            }
            let mut kvb = KvBatch::Paged { alloc, tables: &mut refs };
            logits.push(model.forward_kv(&toks, &mut kvb, &mut scratch, None));
        }
        let (lf, li8, lt) = (&logits[0], &logits[1], &logits[2]);
        for (row, &i) in sel.iter().enumerate() {
            let (f_tok, f_top, margin) = top2(lf.row(row));
            let i8_tok = sherry::engine::argmax(li8.row(row));
            let t_tok = sherry::engine::argmax(lt.row(row));
            steps += 1;
            agree_i8 += (i8_tok == f_tok) as u32;
            agree_t += (t_tok == f_tok) as u32;
            if margin > 2.0 * (0.25 + 0.1 * f_top.abs()) {
                gated_i8 += 1;
                assert_eq!(
                    i8_tok, f_tok,
                    "seq {i} step {step}: int8 flipped a gated token (margin {margin})"
                );
            }
            if margin > 2.0 * (1.0 + 0.5 * f_top.abs()) {
                gated_t += 1;
                assert_eq!(
                    t_tok, f_tok,
                    "seq {i} step {step}: ternary flipped a gated token (margin {margin})"
                );
            }
            last_f32[i] = lf.row(row).to_vec();
        }
    }
    println!(
        "token agreement vs f32 over {steps} steps: int8 {agree_i8} (gated {gated_i8}), \
         ternary {agree_t} (gated {gated_t})"
    );
    assert!(gated_i8 > 0, "tolerance gate never engaged — test is vacuous");
    for (alloc, tabs) in allocs.iter_mut().zip(tables.iter_mut()) {
        for table in tabs.iter_mut() {
            table.release_all(alloc);
        }
        assert_eq!(alloc.used_pages(), 0);
    }
}

/// F32Store through the page-blocked attention path must be bit-for-bit
/// identical to the contiguous engine baseline — the storage trait and
/// the blocked walk are memory-system changes, never numeric ones.
/// (The ragged mixed-trace version of this guarantee is
/// `paged_and_contiguous_decode_are_bit_for_bit_identical` above; this
/// one pins the explicit `new_with(F32)` constructor.)
#[test]
fn f32_store_decode_is_bit_for_bit_with_contiguous() {
    let cfg = NativeConfig::named("nano").unwrap();
    let model = nano_model(13, Format::I2S);
    let mut scratch = Scratch::default();
    let prompt: [u32; 5] = [3, 1, 4, 1, 5];

    let mut cache = KvCache::new(&cfg);
    let mut alloc = BlockAllocator::new_with(&cfg, 16, 4, KvDtype::F32);
    let mut table = BlockTable::new(4);
    let mut last_c = Vec::new();
    let mut last_p = Vec::new();
    for step in 0..prompt.len() + 6 {
        let tok = if step < prompt.len() {
            prompt[step]
        } else {
            sherry::engine::argmax(&last_c) as u32
        };
        last_c = model.forward_one(tok, &mut cache, &mut scratch);
        let mut tables = [&mut table];
        let mut kvb = KvBatch::Paged { alloc: &mut alloc, tables: &mut tables };
        last_p = model.forward_kv(&[tok], &mut kvb, &mut scratch, None).data;
        assert_eq!(last_c, last_p, "step {step}");
    }
    assert_eq!(last_c, last_p);
    table.release_all(&mut alloc);
}

/// Quantize→dequantize round-trip property for per-page-per-head scales
/// through the public arena API: random page sizes, random row batches
/// (including magnitude ramps that force requantization), every element
/// within the provable `(rows + 1)/2`-quanta bound of the final per-head
/// scale, and the page's dequantized bytes unchanged by further *reads*.
#[test]
fn prop_int8_roundtrip_bounded_by_page_head_scale() {
    let cfg = NativeConfig::named("nano").unwrap();
    let d = cfg.d_model;
    let hd = cfg.head_dim();
    prop::check(
        "int8 page round-trip",
        40,
        |rng| {
            let ps = prop::gens::usize_in(rng, 1, 8);
            let rows = prop::gens::usize_in(rng, 1, ps);
            let ramp = rng.below(2) == 1; // magnitude ramp → forced rescales
            (ps, rows, ramp, rng.next_u64())
        },
        |&(ps, rows, ramp, seed)| {
            let mut rng = Pcg64::seeded(seed);
            let mut alloc = BlockAllocator::new_with(&cfg, 2, ps, KvDtype::Int8);
            let p = alloc.alloc().unwrap();
            let mut written: Vec<Vec<f32>> = Vec::new();
            for s in 0..rows {
                let mut row = rng.normal_vec(d);
                if ramp {
                    for x in &mut row {
                        *x *= 10f32.powi(s as i32);
                    }
                }
                alloc.write_row(0, p, s, &row, &row);
                written.push(row);
            }
            let mut scratch = Vec::new();
            let blk = alloc.read_block(Plane::K, 0, p, rows, &mut scratch).to_vec();
            let blk2 = alloc.read_block(Plane::K, 0, p, rows, &mut scratch).to_vec();
            if blk != blk2 {
                return Err("block reads must be pure".into());
            }
            for h in 0..cfg.n_heads {
                // Final scale = absmax over the written rows' head lane / 127.
                let absmax = written
                    .iter()
                    .flat_map(|r| r[h * hd..(h + 1) * hd].iter())
                    .fold(0.0f32, |m, &x| m.max(x.abs()));
                let quantum = absmax / 127.0;
                let bound = (rows + 1) as f32 / 2.0 * quantum;
                for (s, row) in written.iter().enumerate() {
                    for c in h * hd..(h + 1) * hd {
                        let err = (blk[s * d + c] - row[c]).abs();
                        if err > bound + 1e-6 {
                            return Err(format!(
                                "ps={ps} rows={rows} ramp={ramp} slot {s} ch {c}: \
                                 err {err} > bound {bound} (quantum {quantum})"
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// Serving-order invariance for int8 prefix sharing (the acceptance
/// regression): the same shared-prefix request set served in two
/// different arrival orders must produce identical completions per
/// request id, *with sharing engaged in both orders*.
///
/// Why this is the hard case: whichever request arrives first becomes
/// the donor whose quantization trajectory freezes into the prefix
/// index. Whole-page sharing with registration-frozen scales makes a
/// frozen page's bytes a deterministic function of its chunk's tokens —
/// identical no matter which request wrote it — so donor/recipient
/// roles must not be observable in the tokens. (Partial-page sharing
/// would break this: a prefix of a donor page is quantized at a scale
/// grown by the donor's later rows; that is exactly what `PagedKv`
/// forbids for quantized pools.)
#[test]
fn int8_prefix_sharing_is_serving_order_invariant() {
    let m = nano_model(37, Format::Sherry);
    let shared: Vec<u32> = (40..48).collect(); // two full pages at page_size 4
    let mk = |id: u64, tail: &[u32]| Request {
        id,
        prompt: shared.iter().copied().chain(tail.iter().copied()).collect(),
        max_new_tokens: 6,
        ..Default::default()
    };
    let reqs =
        [mk(0, &[1, 2, 3]), mk(1, &[7, 8, 9]), mk(2, &[1, 9, 2]), mk(3, &[5])];
    // max_active 1 strictly serializes: arrival order IS serving order,
    // so the two runs exercise different donor/recipient assignments.
    let cfg = ServerConfig {
        batcher: BatcherConfig { max_active: 1, token_budget: 100_000, ..Default::default() },
        page_size: 4,
        kv_dtype: KvDtype::Int8,
        prefix_sharing: true,
        ..Default::default()
    };
    let order_a: Vec<Request> = reqs
        .iter()
        .enumerate()
        .map(|(i, r)| Request { arrival: i as f64 * 1e-4, ..r.clone() })
        .collect();
    let order_b: Vec<Request> = reqs
        .iter()
        .rev()
        .enumerate()
        .map(|(i, r)| Request { arrival: i as f64 * 1e-4, ..r.clone() })
        .collect();
    let (mut c_a, m_a) = Server::new(&m, cfg).run(order_a);
    let (mut c_b, m_b) = Server::new(&m, cfg).run(order_b);
    assert_eq!(c_a.len(), reqs.len());
    assert_eq!(c_b.len(), reqs.len());
    c_a.sort_by_key(|c| c.id);
    c_b.sort_by_key(|c| c.id);
    for (a, b) in c_a.iter().zip(&c_b) {
        assert_eq!(a.id, b.id);
        assert_eq!(
            a.tokens, b.tokens,
            "request {}: completion depends on serving order",
            a.id
        );
    }
    // The invariance must not be vacuous: both orders reused the shared
    // prefix (8 tokens, page-aligned) for every non-first request.
    assert_eq!(m_a.prefix_hit_tokens, 3 * 8, "order A must share the frozen prefix");
    assert_eq!(m_b.prefix_hit_tokens, 3 * 8, "order B must share the frozen prefix");
    assert_eq!(m_a.int8_dot_fraction(), 1.0);
}

/// Ternary K page round-trip property through the public arena API:
/// random page sizes, row batches, and magnitude ramps. Every read-back
/// K element must equal its scale-independent 3:4 code (recomputed with
/// the pure quantizer, [`sparsify34_codes`]) times the final per-head
/// running absmean — *exactly*, because pack34 codes are immutable once
/// written and the scale is materialized from the same `(Σ|x|, count)`
/// fold the reference replays in write order. Unlike int8 absmax pages
/// there is no requantization cascade, so this is bit-equality, not a
/// quanta bound.
#[test]
fn prop_ternary_k_roundtrip_is_codes_times_running_absmean() {
    use sherry::quant::absmean::{absmean_scale, kept_abs_sum, sparsify34_codes};
    let cfg = NativeConfig::named("nano").unwrap();
    let d = cfg.d_model;
    let hd = cfg.head_dim();
    prop::check(
        "ternary K page round-trip",
        40,
        |rng| {
            let ps = prop::gens::usize_in(rng, 1, 8);
            let rows = prop::gens::usize_in(rng, 1, ps);
            let ramp = rng.below(2) == 1; // magnitude ramp → moving absmean
            (ps, rows, ramp, rng.next_u64())
        },
        |&(ps, rows, ramp, seed)| {
            let mut rng = Pcg64::seeded(seed);
            let mut alloc = BlockAllocator::new_with(&cfg, 2, ps, KvDtype::Ternary);
            let p = alloc.alloc().unwrap();
            let mut written: Vec<Vec<f32>> = Vec::new();
            for s in 0..rows {
                let mut row = rng.normal_vec(d);
                if ramp {
                    for x in &mut row {
                        *x *= 10f32.powi(s as i32);
                    }
                }
                alloc.write_row(0, p, s, &row, &row);
                written.push(row);
            }
            let mut scratch = Vec::new();
            let blk = alloc.read_block(Plane::K, 0, p, rows, &mut scratch).to_vec();
            let blk2 = alloc.read_block(Plane::K, 0, p, rows, &mut scratch).to_vec();
            if blk != blk2 {
                return Err("block reads must be pure".into());
            }
            // Replay the running absmean fold and compare elementwise.
            let mut codes = vec![0i8; d];
            let all_codes: Vec<Vec<i8>> = written
                .iter()
                .map(|row| {
                    sparsify34_codes(row, &mut codes);
                    codes.clone()
                })
                .collect();
            for h in 0..cfg.n_heads {
                let (mut sum, mut n) = (0.0f32, 0u32);
                for (row, c) in written.iter().zip(&all_codes) {
                    sum += kept_abs_sum(&row[h * hd..(h + 1) * hd], &c[h * hd..(h + 1) * hd]);
                    n += (3 * hd / 4) as u32;
                }
                let s_h = absmean_scale(sum, n);
                for (r, c) in all_codes.iter().enumerate() {
                    for col in h * hd..(h + 1) * hd {
                        let want = c[col] as f32 * s_h;
                        if blk[r * d + col] != want {
                            return Err(format!(
                                "ps={ps} rows={rows} ramp={ramp} slot {r} ch {col}: \
                                 {} != code {} × scale {s_h}",
                                blk[r * d + col],
                                c[col]
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// Serving-order invariance for ternary prefix sharing — the same
/// acceptance regression as the int8 variant above, at 1.25 bits. The
/// absmean accumulator makes this *stricter* than int8: a frozen page's
/// scale is the running absmean of exactly its own rows, so whole-page
/// sharing with registration-frozen state is what keeps donor identity
/// unobservable. Sharing must also leave tokens identical to a
/// sharing-off run, and every paged q·k row must take the LUT walk.
#[test]
fn ternary_prefix_sharing_is_serving_order_invariant() {
    let m = nano_model(37, Format::Sherry);
    let shared: Vec<u32> = (40..48).collect(); // two full pages at page_size 4
    let mk = |id: u64, tail: &[u32]| Request {
        id,
        prompt: shared.iter().copied().chain(tail.iter().copied()).collect(),
        max_new_tokens: 6,
        ..Default::default()
    };
    let reqs =
        [mk(0, &[1, 2, 3]), mk(1, &[7, 8, 9]), mk(2, &[1, 9, 2]), mk(3, &[5])];
    // max_active 1 strictly serializes: arrival order IS serving order.
    let cfg = ServerConfig {
        batcher: BatcherConfig { max_active: 1, token_budget: 100_000, ..Default::default() },
        page_size: 4,
        kv_dtype: KvDtype::Ternary,
        prefix_sharing: true,
        ..Default::default()
    };
    let order_a: Vec<Request> = reqs
        .iter()
        .enumerate()
        .map(|(i, r)| Request { arrival: i as f64 * 1e-4, ..r.clone() })
        .collect();
    let order_b: Vec<Request> = reqs
        .iter()
        .rev()
        .enumerate()
        .map(|(i, r)| Request { arrival: i as f64 * 1e-4, ..r.clone() })
        .collect();
    let (mut c_a, m_a) = Server::new(&m, cfg).run(order_a.clone());
    let (mut c_b, m_b) = Server::new(&m, cfg).run(order_b);
    let off = ServerConfig { prefix_sharing: false, ..cfg };
    let (mut c_off, m_off) = Server::new(&m, off).run(order_a);
    assert_eq!(c_a.len(), reqs.len());
    assert_eq!(c_b.len(), reqs.len());
    c_a.sort_by_key(|c| c.id);
    c_b.sort_by_key(|c| c.id);
    c_off.sort_by_key(|c| c.id);
    for ((a, b), o) in c_a.iter().zip(&c_b).zip(&c_off) {
        assert_eq!(a.id, b.id);
        assert_eq!(
            a.tokens, b.tokens,
            "request {}: completion depends on serving order",
            a.id
        );
        assert_eq!(
            a.tokens, o.tokens,
            "request {}: sharing changed ternary tokens",
            a.id
        );
    }
    // Non-vacuous: both orders shared the full 8-token frozen prefix for
    // every non-first request, and the score pass was all LUT walks.
    assert_eq!(m_a.prefix_hit_tokens, 3 * 8, "order A must share the frozen prefix");
    assert_eq!(m_b.prefix_hit_tokens, 3 * 8, "order B must share the frozen prefix");
    assert_eq!(m_off.prefix_hit_tokens, 0);
    assert_eq!(m_a.ternary_dot_fraction(), 1.0);
    assert_eq!(m_a.int8_dot_fraction(), 0.0);
}

/// Freeze/thaw + CoW at the arena layer for ternary pages: a frozen
/// donor page is byte-immutable across a recipient's copy-on-write
/// divergence, the private copy dequantizes identically over the shared
/// rows at copy time, and — the quantizer-state claim — appending to the
/// copy continues the donor's absmean trajectory, bit-identical to a
/// straight-line table that wrote the same rows on a fresh page.
/// Releasing the last reference thaws: the recycled page comes back
/// unfrozen with a cleared accumulator.
#[test]
fn ternary_cow_and_freeze_thaw_carry_quantizer_state() {
    let cfg = NativeConfig::named("nano").unwrap();
    let d = cfg.d_model;
    let mut alloc = BlockAllocator::new_with(&cfg, 4, 4, KvDtype::Ternary);
    let mut rng = Pcg64::seeded(53);

    // Donor fills 3 of 4 slots of one page, then the page freezes (the
    // registration protocol's effect, driven here through the allocator).
    let rows: Vec<Vec<f32>> = (0..3).map(|_| rng.normal_vec(d)).collect();
    let mut donor = BlockTable::new(4);
    for (pos, row) in rows.iter().enumerate() {
        donor.prepare_append(&mut alloc);
        let (page, slot) = donor.slot_for(pos);
        for li in 0..cfg.n_layers {
            alloc.write_row(li, page, slot, row, row);
        }
        donor.advance();
    }
    let shared = donor.pages()[0];
    alloc.freeze_page(shared);
    assert!(alloc.store().is_frozen(shared));
    let mut scratch = Vec::new();
    let k_snap = alloc.read_block(Plane::K, 0, shared, 3, &mut scratch).to_vec();
    let v_snap = alloc.read_block(Plane::V, 0, shared, 3, &mut scratch).to_vec();

    // Recipient shares the partially-filled page; appending position 3
    // diverges inside it → CoW onto a private copy.
    alloc.retain(shared);
    let mut recip = BlockTable::from_shared(4, vec![shared], 3);
    recip.prepare_append(&mut alloc);
    let (copy, slot) = recip.slot_for(3);
    assert_ne!(copy, shared, "divergence must land on a private copy");
    assert_eq!(
        alloc.read_block(Plane::K, 0, copy, 3, &mut scratch),
        &k_snap[..],
        "CoW copy must dequantize identically over the shared K rows"
    );
    assert_eq!(alloc.read_block(Plane::V, 0, copy, 3, &mut scratch), &v_snap[..]);

    // Divergent append through the copy; the frozen donor page is
    // untouched even though the copy's running absmean moves on.
    let tail = rng.normal_vec(d);
    for li in 0..cfg.n_layers {
        alloc.write_row(li, copy, slot, &tail, &tail);
    }
    recip.advance();
    assert_eq!(
        alloc.read_block(Plane::K, 0, shared, 3, &mut scratch),
        &k_snap[..],
        "frozen donor K bytes mutated by a CoW append"
    );
    assert_eq!(alloc.read_block(Plane::V, 0, shared, 3, &mut scratch), &v_snap[..]);

    // Trajectory: CoW + append ≡ writing all four rows straight onto a
    // fresh page — only possible because copy_rows carried the
    // (Σ|x|, count) accumulator, not just bytes and scales.
    let mut control = BlockTable::new(4);
    for (pos, row) in rows.iter().chain(std::iter::once(&tail)).enumerate() {
        control.prepare_append(&mut alloc);
        let (page, slot) = control.slot_for(pos);
        for li in 0..cfg.n_layers {
            alloc.write_row(li, page, slot, row, row);
        }
        control.advance();
    }
    let cp = control.pages()[0];
    for plane in [Plane::K, Plane::V] {
        let mut s2 = Vec::new();
        assert_eq!(
            alloc.read_block(plane, 0, copy, 4, &mut scratch).to_vec(),
            alloc.read_block(plane, 0, cp, 4, &mut s2),
            "CoW trajectory diverged from straight-line writes ({plane:?})"
        );
    }

    // Thaw: dropping the last reference recycles the page unfrozen and
    // with a cleared accumulator — the next lease may write it again.
    donor.release_all(&mut alloc);
    recip.release_all(&mut alloc);
    control.release_all(&mut alloc);
    assert_eq!(alloc.used_pages(), 0);
    let fresh = alloc.alloc().unwrap();
    assert!(!alloc.store().is_frozen(fresh), "recycled page must thaw");
    let row = rng.normal_vec(d);
    alloc.write_row(0, fresh, 0, &row, &row); // would panic if still frozen
    alloc.release(fresh);
}

/// Full-trace refcount hygiene at the serving layer: after heavy mixed
/// traffic (staggered arrivals, shared prefixes, context-capped
/// requests) every sequence reference is returned — only the prefix
/// index holds pages, and block utilization stays within the arena.
#[test]
fn serve_trace_returns_all_page_references() {
    let m = nano_model(23, Format::I2S);
    let cfg = ServerConfig {
        batcher: BatcherConfig { max_active: 6, token_budget: 100_000, ..Default::default() },
        kv_capacity: 3,
        page_size: 4,
        ..Default::default()
    };
    let spec = TraceSpec {
        n_requests: 12,
        mean_interarrival_s: 0.001,
        prompt_len: 9,
        shared_prefix_len: 5,
        max_new_tokens: 70, // exceeds nano's 64-token context → capped
        seed: 31,
        ..Default::default()
    };
    let (completions, metrics) = serve_trace(&m, cfg, spec);
    assert_eq!(completions.len(), 12);
    assert_eq!(metrics.kv_pages_end_in_use, metrics.kv_pages_index);
    assert!(metrics.kv_pages_peak <= metrics.kv_pages_total);
    assert!(metrics.block_utilization() <= 1.0);
    assert_eq!(metrics.context_limit_finishes, 12, "all requests hit the context cap");
}
