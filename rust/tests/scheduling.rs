//! SLO-aware scheduling: the serving-invariant suite.
//!
//! The scheduling contract (DESIGN.md §10): a request's tokens are a
//! function of the request alone — chunked prefill, priority classes,
//! and preemption may reorder *when* work runs, never *what* it
//! produces. These tests pin that contract end-to-end across all three
//! KV storage dtypes, plus the graceful-degradation edges (oversized
//! requests, non-finite arrivals) and the scheduling metrics surface.

use sherry::cache::KvDtype;
use sherry::coordinator::{
    serve_trace, BatcherConfig, Completion, FinishReason, Preemption, Priority, Request,
    Server, ServerConfig, TraceSpec,
};
use sherry::engine::{random_weights, NativeConfig, TernaryModel};
use sherry::pack::Format;

fn nano_model(seed: u64) -> TernaryModel {
    let cfg = NativeConfig::named("nano").unwrap();
    TernaryModel::build(cfg, &random_weights(&cfg, seed), Format::Sherry)
}

fn by_id(mut completions: Vec<Completion>) -> Vec<Completion> {
    completions.sort_by_key(|c| c.id);
    completions
}

/// A page-tight configuration (2 f32 cache-equivalents, small pages,
/// more admission slots than pages) so chunking and preemption actually
/// engage instead of idling behind a roomy arena.
fn tight_cfg(dtype: KvDtype, chunk: usize, preemption: Preemption) -> ServerConfig {
    ServerConfig {
        batcher: BatcherConfig { max_active: 4, token_budget: 100_000, ..Default::default() },
        kv_capacity: 2,
        page_size: 4,
        kv_dtype: dtype,
        prefill_chunk_tokens: chunk,
        preemption,
        workers: 2,
        ..Default::default()
    }
}

/// A mixed-priority bursty trace: multi-chunk prompts, arrivals close
/// enough that waves overlap and queues form.
fn mixed_trace(batch_fraction: f64) -> TraceSpec {
    TraceSpec {
        n_requests: 12,
        mean_interarrival_s: 0.0005,
        prompt_len: 18,
        shared_prefix_len: 0,
        max_new_tokens: 12,
        seed: 11,
        batch_fraction,
        ..Default::default()
    }
}

fn assert_same_tokens(a: &[Completion], b: &[Completion], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: request count");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.id, y.id, "{what}: id alignment");
        assert_eq!(x.tokens, y.tokens, "{what}: tokens of request {}", x.id);
        assert_eq!(x.finish, y.finish, "{what}: finish of request {}", x.id);
    }
}

/// The acceptance matrix: for one KV dtype, serve the same seeded trace
/// under every scheduling policy combination and require per-request
/// token identity with the monolithic / never-preempt baseline.
fn scheduling_policies_are_token_invariant(dtype: KvDtype) {
    let m = nano_model(5);
    let spec = mixed_trace(0.5);
    let (base, base_m) = serve_trace(&m, tight_cfg(dtype, 0, Preemption::Never), spec);
    let base = by_id(base);
    assert_eq!(base.len(), spec.n_requests, "{dtype:?}: baseline must serve everything");
    assert_eq!(base_m.prefill_chunk_tokens, 0);
    for (label, chunk, policy) in [
        ("chunked", 4usize, Preemption::Never),
        ("fine-chunked", 2, Preemption::Never),
        ("monolithic+preempt", 0, Preemption::Always),
        ("chunked+preempt", 4, Preemption::Always),
    ] {
        let (got, gm) = serve_trace(&m, tight_cfg(dtype, chunk, policy), spec);
        assert_same_tokens(&base, &by_id(got), &format!("{dtype:?}/{label}"));
        if chunk != 0 {
            // A chunked prompt (18 tokens) needs multiple (seq, round)
            // chunks; monolithic feeds each prompt inside one round.
            assert!(
                gm.prefill_chunks > base_m.prefill_chunks,
                "{dtype:?}/{label}: chunking must split prefill \
                 ({} vs monolithic {})",
                gm.prefill_chunks,
                base_m.prefill_chunks
            );
        }
    }
    // Sharing off is the same contract with the prefix index out of the
    // restore path: re-prefill rebuilds everything from scratch.
    let mut off = tight_cfg(dtype, 4, Preemption::Always);
    off.prefix_sharing = false;
    let mut off_base = tight_cfg(dtype, 0, Preemption::Never);
    off_base.prefix_sharing = false;
    let (want, _) = serve_trace(&m, off_base, spec);
    let (got, _) = serve_trace(&m, off, spec);
    assert_same_tokens(&by_id(want), &by_id(got), &format!("{dtype:?}/sharing-off"));
}

#[test]
fn scheduling_policies_are_token_invariant_f32() {
    scheduling_policies_are_token_invariant(KvDtype::F32);
}

#[test]
fn scheduling_policies_are_token_invariant_int8() {
    scheduling_policies_are_token_invariant(KvDtype::Int8);
}

#[test]
fn scheduling_policies_are_token_invariant_ternary() {
    scheduling_policies_are_token_invariant(KvDtype::Ternary);
}

/// Chunked prefill's round-level shape: one sequence with an 18-token
/// prompt and a 2-token chunk must spread its prefill over ≥ 9 rounds,
/// never feeding more than the chunk in any one round — visible through
/// the flight recorder's per-round `prefill_tokens`.
#[test]
fn chunk_budget_bounds_prefill_tokens_per_round() {
    let m = nano_model(5);
    let spec = TraceSpec { n_requests: 1, prompt_len: 18, max_new_tokens: 4, seed: 2, ..Default::default() };
    let (completions, metrics) = serve_trace(&m, tight_cfg(KvDtype::F32, 2, Preemption::Never), spec);
    assert_eq!(completions.len(), 1);
    assert_eq!(completions[0].tokens.len(), 4);
    let records = metrics.flight.records();
    let fed: u32 = records.iter().map(|r| r.prefill_tokens).sum();
    assert_eq!(fed, 18, "whole prompt fed through chunks");
    assert!(
        records.iter().all(|r| r.prefill_tokens <= 2),
        "no round may exceed the 2-token chunk: {records:?}"
    );
    assert_eq!(metrics.prefill_chunks, 9, "ceil(18 / 2) chunks");
    // 9 chunked-prefill rounds (the first token emits off the last
    // prompt feed, inside round 9) + 3 pure decode rounds.
    assert_eq!(metrics.decode_rounds, 12);
    // Monolithic: the same prompt is one chunk inside one round.
    let (_, mono) = serve_trace(&m, tight_cfg(KvDtype::F32, 0, Preemption::Never), spec);
    assert_eq!(mono.prefill_chunks, 1);
    assert_eq!(mono.decode_rounds, 4);
    assert_eq!(mono.flight.records().iter().map(|r| r.prefill_tokens).max(), Some(18));
}

/// Forced preemption end-to-end: one admission slot, a pile of Batch
/// work submitted at t=0, and an Interactive request arriving while the
/// Batch backlog decodes. `Preemption::Always` must park a Batch victim
/// for the Interactive arrival, restore it later (restored tokens > 0),
/// and the per-class histograms must attribute every retirement — all
/// with tokens identical to the never-preempt run.
#[test]
fn forced_preemption_restores_token_identical_sequences() {
    let m = nano_model(5);
    let mk_trace = || -> Vec<Request> {
        let mut reqs: Vec<Request> = (0..8)
            .map(|i| Request {
                id: i,
                prompt: vec![(3 + i) as u32 % 16, 7, 11, 2],
                max_new_tokens: 48,
                arrival: 0.0,
                priority: Priority::Batch,
                ..Default::default()
            })
            .collect();
        // Arrives after the Batch backlog is decoding (the backlog is
        // ≳ 384 engine rounds — orders of magnitude past 0.5 ms).
        reqs.push(Request {
            id: 8,
            prompt: vec![1, 2, 3, 4],
            max_new_tokens: 8,
            arrival: 0.0005,
            priority: Priority::Interactive,
            ..Default::default()
        });
        reqs
    };
    let cfg = |preemption| ServerConfig {
        batcher: BatcherConfig { max_active: 1, token_budget: 100_000, ..Default::default() },
        kv_capacity: 1,
        page_size: 4,
        preemption,
        workers: 2,
        ..Default::default()
    };
    let (never, _) = Server::new(&m, cfg(Preemption::Never)).run(mk_trace());
    let (always, metrics) = Server::new(&m, cfg(Preemption::Always)).run(mk_trace());
    assert_same_tokens(&by_id(never), &by_id(always), "preempt-vs-never");
    assert!(metrics.preemptions >= 1, "the Interactive arrival must preempt");
    assert!(metrics.restored_tokens > 0, "a restore re-prefills at least one token");
    assert_eq!(metrics.preemption_policy, "always");
    let it = Priority::Interactive.index();
    let bt = Priority::Batch.index();
    assert_eq!(metrics.ttft_class[it].count(), 1, "one Interactive retirement");
    assert_eq!(metrics.ttft_class[bt].count(), 8, "eight Batch retirements");
    assert!(metrics.itl_class[bt].count() > 0, "Batch sequences emit multiple tokens");
    assert_eq!(
        metrics.ttft_class[it].count() + metrics.ttft_class[bt].count(),
        metrics.ttft_hist.count(),
        "per-class TTFT histograms partition the aggregate"
    );
}

/// Satellite regression (trace sort): non-finite arrivals used to panic
/// the serve loop's `partial_cmp().unwrap()` — and a NaN that merely
/// sorted last would livelock intake. They now mean "arrives
/// immediately" and the run completes with finite latencies.
#[test]
fn non_finite_arrivals_complete_with_finite_latencies() {
    let m = nano_model(5);
    let trace = vec![
        Request { id: 0, prompt: vec![1, 2, 3], max_new_tokens: 3, arrival: f64::NAN, ..Default::default() },
        Request { id: 1, prompt: vec![4, 5, 6], max_new_tokens: 3, arrival: 0.001, ..Default::default() },
        Request { id: 2, prompt: vec![7, 8, 9], max_new_tokens: 3, arrival: f64::INFINITY, ..Default::default() },
        Request { id: 3, prompt: vec![2, 4, 6], max_new_tokens: 3, arrival: f64::NEG_INFINITY, ..Default::default() },
    ];
    let (completions, metrics) = Server::new(&m, ServerConfig::default()).run(trace);
    assert_eq!(completions.len(), 4, "no panic, no livelock");
    assert_eq!(metrics.requests_done, 4);
    for c in &completions {
        assert_eq!(c.tokens.len(), 3);
        assert!(c.latency.is_finite() && c.latency >= 0.0, "request {}: {}", c.id, c.latency);
        assert!(c.ttft.is_finite() && c.ttft >= 0.0);
    }
}

/// Satellite regression (oversized requests): a request whose worst-case
/// span exceeds the context limit — or whose page need would exceed a
/// minimal arena — must finish gracefully via `ContextLimit` (possibly
/// with zero tokens for an over-long prompt), never deadlock admission.
/// The arena contract backing this: `PagedKv::new` raises the page count
/// to at least one worst-case (context-limit-capped) sequence.
#[test]
fn oversized_requests_finish_gracefully_on_a_minimal_arena() {
    let m = nano_model(5);
    let seq_cap = m.cfg.seq_len; // nano: 64
    let cfg = ServerConfig {
        batcher: BatcherConfig { max_active: 2, token_budget: 100_000, ..Default::default() },
        kv_capacity: 1, // minimal byte budget: the arena is exactly one worst case
        page_size: 16,
        workers: 2,
        ..Default::default()
    };
    let trace = vec![
        // Generation allowance far past the context limit.
        Request { id: 0, prompt: vec![1, 2, 3, 4], max_new_tokens: 10 * seq_cap, ..Default::default() },
        // Prompt alone past the context limit: truncated prefill, zero tokens.
        Request { id: 1, prompt: vec![7; seq_cap + 9], max_new_tokens: 4, arrival: 0.0002, ..Default::default() },
        // A normal request sharing the queue with the oversized ones.
        Request { id: 2, prompt: vec![5, 6], max_new_tokens: 4, arrival: 0.0004, ..Default::default() },
    ];
    let (completions, metrics) = Server::new(&m, cfg).run(trace);
    let completions = by_id(completions);
    assert_eq!(completions.len(), 3, "oversized requests must not deadlock the queue");
    assert_eq!(completions[0].finish, FinishReason::ContextLimit);
    assert_eq!(completions[0].tokens.len(), seq_cap - 4, "decoded up to the context limit");
    assert_eq!(completions[1].finish, FinishReason::ContextLimit);
    assert!(completions[1].tokens.is_empty(), "over-long prompt produces no tokens");
    assert_eq!(completions[2].finish, FinishReason::Length);
    assert_eq!(completions[2].tokens.len(), 4);
    assert_eq!(metrics.context_limit_finishes, 2);
    assert_eq!(metrics.zero_token_finishes, 1);
    assert_eq!(metrics.kv_pages_end_in_use, metrics.kv_pages_index, "all pages returned");
}

/// Deadline accounting is observational: an unmeetable deadline counts
/// every completion as a miss, a generous one counts none, and the
/// tokens are identical either way.
#[test]
fn deadline_misses_count_without_changing_tokens() {
    let m = nano_model(5);
    let spec = |deadline_s: f64| TraceSpec {
        n_requests: 5,
        prompt_len: 6,
        max_new_tokens: 6,
        seed: 4,
        deadline_s,
        ..Default::default()
    };
    let (tight, tm) = serve_trace(&m, ServerConfig::default(), spec(1e-12));
    let (loose, lm) = serve_trace(&m, ServerConfig::default(), spec(1e9));
    let (none, nm) = serve_trace(&m, ServerConfig::default(), spec(0.0));
    assert_eq!(tm.deadline_misses, 5, "1 ps deadline: every completion misses");
    assert_eq!(lm.deadline_misses, 0);
    assert_eq!(nm.deadline_misses, 0, "0.0 disables deadlines entirely");
    assert_same_tokens(&by_id(tight), &by_id(loose), "deadline knob");
    assert_same_tokens(&by_id(loose), &by_id(none), "deadline off");
}

/// The priority mix surfaces in the per-class histograms and the trace
/// generator's legacy stream stays intact: `batch_fraction == 0` draws
/// the exact pre-priority RNG sequence, so the same seed with and
/// without the field yields identical prompts and arrivals.
#[test]
fn per_class_histograms_partition_retirements() {
    let m = nano_model(5);
    let spec = mixed_trace(0.5);
    let reqs = spec.generate(m.cfg.vocab_size);
    let n_batch = reqs.iter().filter(|r| r.priority == Priority::Batch).count() as u64;
    assert!(n_batch > 0 && n_batch < spec.n_requests as u64, "seed 11 mixes both classes");
    let (completions, metrics) =
        serve_trace(&m, tight_cfg(KvDtype::F32, 4, Preemption::UnderPressure), spec);
    assert_eq!(completions.len(), spec.n_requests);
    let it = Priority::Interactive.index();
    let bt = Priority::Batch.index();
    assert_eq!(metrics.ttft_class[bt].count(), n_batch);
    assert_eq!(metrics.ttft_class[it].count(), spec.n_requests as u64 - n_batch);
    // Legacy stream: zero batch fraction reproduces the same prompts.
    let legacy = TraceSpec { batch_fraction: 0.0, ..spec }.generate(m.cfg.vocab_size);
    for (a, b) in reqs.iter().zip(&legacy) {
        assert_eq!(a.prompt, b.prompt, "prompt stream must not shift");
        assert_eq!(a.arrival, b.arrival, "arrival stream must not shift");
    }
    assert!(legacy.iter().all(|r| r.priority == Priority::Interactive));
}
