//! Cross-module integration tests: artifacts → PJRT → native engine →
//! coordinator. Tests that need `make artifacts` skip gracefully when the
//! artifacts are absent.

use sherry::engine::{KvCache, NativeConfig, Scratch, TernaryModel};
use sherry::eval;
use sherry::pack::Format;
use sherry::quant::{Granularity, Method, Schedule};
use sherry::runtime::{literal_f32, literal_i32, to_vec_f32, ParamSpec, Runtime};
use sherry::train::{checkpoint, corpus::Corpus, TrainConfig, Trainer};

fn runtime() -> Option<Runtime> {
    let dir = sherry::artifacts_dir();
    if !dir.join("manifest.tsv").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    match Runtime::cpu(&dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            // Default builds ship the stub PJRT backend (`pjrt` feature
            // off); treat it like missing artifacts and skip.
            eprintln!("skipping: {e}");
            None
        }
    }
}

/// L2-vs-L3 parity: the AOT `fwd` graph (Pallas quantize + ternary
/// matmul) and the native Rust engine must produce near-identical logits
/// for the same latent weights — the strongest whole-stack consistency
/// check in the repo.
#[test]
fn pjrt_forward_matches_native_engine() {
    let Some(mut rt) = runtime() else { return };
    let manifest = rt.manifest().unwrap();
    let entry = manifest.find("nano", "sherry34", "per_channel", "fwd").unwrap().clone();
    let spec = ParamSpec::load(&rt.artifacts_dir().join("nano.params.tsv")).unwrap();

    // Train a few steps so weights are non-degenerate.
    let cfg = TrainConfig { steps: 6, ..Default::default() };
    let mut trainer = Trainer::new(&mut rt, &cfg).unwrap();
    let outcome = trainer.run(&cfg).unwrap();

    // PJRT logits.
    let b = entry.batch.unwrap();
    let native_cfg = NativeConfig::named("nano").unwrap();
    let t = native_cfg.seq_len;
    let mut corpus = Corpus::new(native_cfg.vocab_size, 99);
    let tokens = corpus.batch_i32(b, t);
    let mut inputs = Vec::new();
    for (name, shape) in &spec.entries {
        inputs.push(literal_f32(&outcome.params[name].data, shape).unwrap());
    }
    inputs.push(literal_i32(&tokens, &[b, t]).unwrap());
    let out = rt.run(&entry.path, &inputs).unwrap();
    let logits_pjrt = to_vec_f32(&out[0]).unwrap(); // (b*t, vocab)

    // Native engine logits for sequence 0 (teacher-forced decode).
    let model = TernaryModel::build_ptq(
        native_cfg,
        &outcome.params,
        Method::Sherry34,
        Granularity::PerChannel,
    );
    let mut cache = KvCache::new(&native_cfg);
    let mut scratch = Scratch::default();
    let v = native_cfg.vocab_size;
    let mut max_rel = 0.0f32;
    for pos in 0..t {
        let logits = model.forward_one(tokens[pos] as u32, &mut cache, &mut scratch);
        // pjrt row for (seq 0, pos) — batch-major flattening
        let row = &logits_pjrt[pos * v..(pos + 1) * v];
        for (a, b) in logits.iter().zip(row) {
            let rel = (a - b).abs() / (1.0 + b.abs());
            max_rel = max_rel.max(rel);
        }
    }
    assert!(max_rel < 5e-2, "PJRT vs native max rel diff {max_rel}");
}

/// Full pipeline: train → checkpoint → reload → serve through the
/// coordinator → sane completions.
#[test]
fn train_checkpoint_serve_roundtrip() {
    let Some(mut rt) = runtime() else { return };
    let cfg = TrainConfig { steps: 8, ..Default::default() };
    let mut trainer = Trainer::new(&mut rt, &cfg).unwrap();
    let outcome = trainer.run(&cfg).unwrap();

    let dir = std::env::temp_dir().join("sherry_integration");
    let ckpt = dir.join("nano.ckpt");
    checkpoint::save(&ckpt, &outcome.params).unwrap();
    let params = checkpoint::load(&ckpt).unwrap();
    assert_eq!(params.len(), outcome.params.len());

    let native_cfg = NativeConfig::named("nano").unwrap();
    let model = TernaryModel::build(native_cfg, &params, Format::Sherry);
    let (completions, metrics) = sherry::coordinator::serve_trace(
        &model,
        sherry::coordinator::ServerConfig::default(),
        sherry::coordinator::TraceSpec {
            n_requests: 4,
            mean_interarrival_s: 0.0,
            prompt_len: 4,
            shared_prefix_len: 0,
            max_new_tokens: 6,
            seed: 0,
            ..Default::default()
        },
    );
    assert_eq!(completions.len(), 4);
    assert_eq!(metrics.tokens_generated, 4 * 6);
}

/// Arenas training sanity at short horizon: λ anneals to zero, training
/// converges, and the held-out gap vs naive 3:4 stays bounded. (The
/// paper's *improvement* from Arenas is a long-horizon effect — at tens
/// of steps the residual path takes optimization budget before it
/// anneals away; see EXPERIMENTS.md §Fig 3/6. This test pins the
/// zero-overhead contract, not the long-run win.)
#[test]
fn arenas_short_horizon_contract() {
    let Some(mut rt) = runtime() else { return };
    let steps = 40;
    let mut losses = Vec::new();
    for schedule in [Schedule::Off, Schedule::CosineWarmup] {
        let cfg = TrainConfig { steps, schedule, seed: 3, ..Default::default() };
        let mut trainer = Trainer::new(&mut rt, &cfg).unwrap();
        let outcome = trainer.run(&cfg).unwrap();
        if schedule == Schedule::CosineWarmup {
            assert!(outcome.final_lambda < 1e-3, "λ must anneal to ~0");
        }
        assert!(outcome.losses.iter().all(|l| l.is_finite()));
        assert!(outcome.losses.last().unwrap() < &outcome.losses[0]);
        let l = trainer.eval_loss(&cfg, &outcome.params, 3).unwrap();
        losses.push(l);
    }
    // Short-horizon gap stays bounded (both directions).
    assert!(
        (losses[1] - losses[0]).abs() < 1.0,
        "arenas {} vs naive {}",
        losses[1],
        losses[0]
    );
}

/// Artifact-free whole-stack check: random weights → every packing format
/// → batched continuous-batching decode rounds through the unified
/// `TernaryKernel` path → all requests complete with the exact tokens a
/// single-stream decode produces. This is the coordinator-level batched
/// vs single parity contract and needs no PJRT/artifacts.
#[test]
fn batched_coordinator_serves_all_formats_without_artifacts() {
    let native_cfg = NativeConfig::named("nano").unwrap();
    let weights = sherry::engine::random_weights(&native_cfg, 42);
    let spec = sherry::coordinator::TraceSpec {
        n_requests: 5,
        mean_interarrival_s: 0.0,
        prompt_len: 4,
        shared_prefix_len: 0,
        max_new_tokens: 5,
        seed: 3,
        ..Default::default()
    };
    for format in Format::ALL {
        let model = TernaryModel::build(native_cfg, &weights, format);
        let reqs = spec.generate(native_cfg.vocab_size);
        let (mut completions, metrics) = sherry::coordinator::serve_trace(
            &model,
            sherry::coordinator::ServerConfig::default(),
            spec,
        );
        assert_eq!(completions.len(), 5, "{format:?}");
        assert_eq!(metrics.tokens_generated, 5 * 5, "{format:?}");
        completions.sort_by_key(|c| c.id);
        let mut scratch = Scratch::default();
        for (req, comp) in reqs.iter().zip(&completions) {
            let mut cache = KvCache::new(&native_cfg);
            let expect = model.generate(&req.prompt, req.max_new_tokens, &mut cache, &mut scratch);
            assert_eq!(expect, comp.tokens, "{format:?} request {}", req.id);
        }
    }
}

/// Eval harness discriminates: a trained model beats an untrained one.
#[test]
fn training_improves_task_accuracy() {
    let Some(mut rt) = runtime() else { return };
    let native_cfg = NativeConfig::named("nano").unwrap();
    let cfg = TrainConfig { steps: 60, ..Default::default() };
    let mut trainer = Trainer::new(&mut rt, &cfg).unwrap();
    let trained = trainer.run(&cfg).unwrap();

    let row_trained = eval::evaluate_ptq(
        "trained",
        native_cfg,
        &trained.params,
        Method::Sherry34,
        Granularity::PerChannel,
        20,
        0,
    );
    let untrained = sherry::engine::random_weights(&native_cfg, 5);
    let row_rand = eval::evaluate_ptq(
        "untrained",
        native_cfg,
        &untrained,
        Method::Sherry34,
        Granularity::PerChannel,
        20,
        0,
    );
    assert!(
        row_trained.perplexity < row_rand.perplexity * 0.8,
        "trained ppl {} vs untrained {}",
        row_trained.perplexity,
        row_rand.perplexity
    );
}
