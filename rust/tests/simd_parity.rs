//! Scalar-vs-SIMD bit-for-bit parity suite for the four dispatched hot
//! loops (satellite of the kernel-dispatch PR; DESIGN.md §5):
//!
//! 1. the i8×i8 attention dot (`simd::dot_i8_with`),
//! 2. the LUT-GEMM tile walks for all three pack formats
//!    (`simd::gemm_{pack34,tl2}_preluts_with`, `simd::gemm_i2s_with`),
//! 3. the ternary-KV q·k LUT walk over packed pack34 K pages
//!    (`simd::qk_lut34_rows_with`), and
//! 4. the fixed-point a·V accumulation over raw int8 V page bytes
//!    (`simd::av_i8_rows_with`).
//!
//! Equality is **hard** (`f32::to_bits`), never a tolerance: the vector
//! walks chunk the *batch* (row) dimension so each lane replays the
//! scalar kernel's operand order exactly, and the i8 dot accumulates in
//! i32 where addition is associative. Every test iterates all `Isa` variants
//! — available ones exercise the real vector leaf, unavailable ones
//! exercise the silent scalar degrade — plus a forced-`Isa::Scalar`
//! control pinned against the raw `engine::lut` kernels. Nothing here
//! calls `simd::select`, so the suite never pins the process-global ISA
//! and stays order-independent with other tests.

use sherry::cache::{F32Store, Int8Store, PageStore, Plane, TernaryStore};
use sherry::engine::{lut, NativeConfig};
use sherry::pack::{Packed34, PackedI2S, PackedTl2};
use sherry::quant::{absmean_quantize, sherry34_quantize, Granularity};
use sherry::simd::{self, Isa};
use sherry::tensor::Mat;
use sherry::util::{prop, Pcg64};

/// Assert two f32 buffers are bitwise identical (NaN-safe, -0.0 ≠ 0.0).
fn assert_bits_eq(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(
            g.to_bits(),
            w.to_bits(),
            "{what}[{i}]: {g:?} ({:#010x}) vs {w:?} ({:#010x})",
            g.to_bits(),
            w.to_bits()
        );
    }
}

/// Deterministic i8 fill that covers the full range, including ±127 and
/// -128 (so any widening/saturating trick in a vector path would show).
fn i8_pattern(n: usize, salt: u64) -> Vec<i8> {
    let mut rng = Pcg64::seeded(salt);
    let mut v: Vec<i8> = (0..n).map(|_| (rng.next_u64() & 0xff) as u8 as i8).collect();
    if n >= 3 {
        v[0] = i8::MIN;
        v[1] = i8::MAX;
        v[2] = -127;
    }
    v
}

// ---------------------------------------------------------------------------
// i8×i8 dot
// ---------------------------------------------------------------------------

#[test]
fn dot_parity_every_isa_every_tail_length() {
    // Lengths straddle every chunk boundary of both vector widths (AVX2
    // eats 16 i8 at a time, NEON 16): empty, sub-chunk, exact multiples,
    // one-off tails, and a head-dim-like odd size.
    for n in [0usize, 1, 2, 3, 7, 8, 15, 16, 17, 31, 32, 33, 63, 64, 100, 128, 257] {
        let a = i8_pattern(n, 11 + n as u64);
        let b = i8_pattern(n, 97 + n as u64);
        let want = simd::dot_i8_scalar(&a, &b);
        for isa in Isa::ALL {
            assert_eq!(
                simd::dot_i8_with(isa, &a, &b),
                want,
                "n={n} isa={} (available={})",
                isa.name(),
                isa.available()
            );
        }
    }
}

#[test]
fn dot_parity_extreme_values_and_mismatched_lengths() {
    // All-(-128)² rows: the largest magnitude any i16 product path sees.
    let a = vec![i8::MIN; 96];
    let b = vec![i8::MIN; 96];
    let want = 96 * 16_384;
    for isa in Isa::ALL {
        assert_eq!(simd::dot_i8_with(isa, &a, &b), want, "{}", isa.name());
    }
    // Mismatched lengths follow the scalar zip contract: min(len) terms.
    let long = i8_pattern(40, 5);
    let short = i8_pattern(25, 6);
    let want = simd::dot_i8_scalar(&long, &short);
    for isa in Isa::ALL {
        assert_eq!(simd::dot_i8_with(isa, &long, &short), want, "{}", isa.name());
        assert_eq!(simd::dot_i8_with(isa, &short, &long), want, "{}", isa.name());
    }
}

#[test]
fn prop_dot_parity_random_lengths() {
    prop::check(
        "dot_i8 simd == scalar",
        64,
        |rng| (prop::gens::usize_in(rng, 0, 300), rng.next_u64()),
        |&(n, seed)| {
            let a = i8_pattern(n, seed);
            let b = i8_pattern(n, seed ^ 0x9e37_79b9);
            let want = simd::dot_i8_scalar(&a, &b);
            for isa in Isa::ALL {
                let got = simd::dot_i8_with(isa, &a, &b);
                if got != want {
                    return Err(format!("n={n} isa={}: {got} vs {want}", isa.name()));
                }
            }
            Ok(())
        },
    );
}

/// The dot exactly as attention uses it: per-head slices of raw int8 page
/// bytes from an `Int8Store`, including a *partial* page (3 of 4 slots
/// written) and an *empty* prefix (0 rows).
#[test]
fn dot_parity_on_partial_and_empty_pages() {
    let cfg = NativeConfig::named("nano").unwrap();
    let d = cfg.d_model;
    let hd = cfg.head_dim();
    let mut st = Int8Store::new(&cfg, 2, 4);
    st.reset_page(0);
    let mut rng = Pcg64::seeded(31);
    for s in 0..3 {
        let row = rng.normal_vec(d);
        st.write_row(0, 0, s, &row, &row);
    }
    let q = i8_pattern(d, 77);
    for rows in [0usize, 1, 3] {
        let (data, scales) = st.block_i8(Plane::K, 0, 0, rows).expect("int8-native view");
        assert_eq!(data.len(), rows * d);
        assert_eq!(scales.len(), cfg.n_heads);
        for r in 0..rows {
            for h in 0..cfg.n_heads {
                let kh = &data[r * d + h * hd..r * d + (h + 1) * hd];
                let qh = &q[h * hd..(h + 1) * hd];
                let want = simd::dot_i8_scalar(qh, kh);
                for isa in Isa::ALL {
                    assert_eq!(
                        simd::dot_i8_with(isa, qh, kh),
                        want,
                        "rows={rows} r={r} h={h} isa={}",
                        isa.name()
                    );
                }
            }
        }
    }
    // rows = 0 yields an empty dot on every path.
    for isa in Isa::ALL {
        assert_eq!(simd::dot_i8_with(isa, &[], &[]), 0, "{}", isa.name());
    }
    // Control: the f32 store has no int8 view — attention would take the
    // dequant path and never reach the dispatched dot.
    let f = F32Store::new(&cfg, 1, 4);
    assert!(f.block_i8(Plane::K, 0, 0, 1).is_none());
}

// ---------------------------------------------------------------------------
// LUT-GEMM walks — shared fixture plumbing
// ---------------------------------------------------------------------------

struct Packs {
    p34: Packed34,
    tl2: PackedTl2,
    i2s: PackedI2S,
}

/// Quantize one random weight matrix per family. `d_in` must be a
/// multiple of 4 (pack34's layout contract); tl2/i2s take it as-is.
fn packs(rng: &mut Pcg64, d_in: usize, d_out: usize) -> Packs {
    let w = Mat::randn(rng, d_in, d_out, 1.0);
    let qs = sherry34_quantize(&w, Granularity::PerChannel);
    let qd = absmean_quantize(&w, Granularity::PerChannel);
    Packs {
        p34: Packed34::from_ternary(&qs),
        tl2: PackedTl2::from_ternary(&qd),
        i2s: PackedI2S::from_ternary(&qd),
    }
}

/// Per-row pack34 LUTs for a `batch × d_in` activation block.
fn luts34(xs: &[f32], d_in: usize, batch: usize) -> (Vec<f32>, usize) {
    let stride = (d_in / 4) * 16;
    let mut luts = vec![0.0f32; batch * stride];
    for bi in 0..batch {
        lut::build_luts34(&xs[bi * d_in..(bi + 1) * d_in], &mut luts[bi * stride..(bi + 1) * stride]);
    }
    (luts, stride)
}

/// Per-row TL2 LUTs for a `batch × d_in` activation block.
fn luts_tl2(xs: &[f32], d_in: usize, batch: usize) -> (Vec<f32>, usize) {
    let stride = d_in.div_ceil(3) * lut::TL2_LUT_STRIDE;
    let mut luts = vec![0.0f32; batch * stride];
    for bi in 0..batch {
        lut::build_luts_tl2(&xs[bi * d_in..(bi + 1) * d_in], &mut luts[bi * stride..(bi + 1) * stride]);
    }
    (luts, stride)
}

/// Run every ISA (and the scalar control) over one (shape, batch, window)
/// case for all three formats, asserting bit equality against the raw
/// scalar kernels.
fn check_gemm_case(
    packs: &Packs,
    xs: &[f32],
    d_in: usize,
    batch: usize,
    j0: usize,
    j1: usize,
) -> Result<(), String> {
    let w = j1 - j0;
    let (l34, s34) = luts34(xs, d_in, batch);
    let (ltl2, stl2) = luts_tl2(xs, d_in, batch);

    let mut want34 = vec![0.0f32; batch * w];
    let mut want_tl2 = vec![0.0f32; batch * w];
    let mut want_i2s = vec![0.0f32; batch * w];
    lut::gemm_pack34_preluts(&packs.p34, &l34, s34, batch, j0, j1, &mut want34);
    lut::gemm_tl2_preluts(&packs.tl2, &ltl2, stl2, batch, j0, j1, &mut want_tl2);
    lut::gemm_i2s(&packs.i2s, xs, batch, j0, j1, &mut want_i2s);

    for isa in Isa::ALL {
        let tag = format!(
            "d_in={d_in} batch={batch} j0={j0} j1={j1} isa={} (available={})",
            isa.name(),
            isa.available()
        );
        let mut got = vec![f32::NAN; batch * w];
        simd::gemm_pack34_preluts_with(isa, &packs.p34, &l34, s34, batch, j0, j1, &mut got);
        for (i, (g, w)) in got.iter().zip(&want34).enumerate() {
            if g.to_bits() != w.to_bits() {
                return Err(format!("pack34 {tag} [{i}]: {g:?} vs {w:?}"));
            }
        }
        let mut got = vec![f32::NAN; batch * w];
        simd::gemm_tl2_preluts_with(isa, &packs.tl2, &ltl2, stl2, batch, j0, j1, &mut got);
        for (i, (g, w)) in got.iter().zip(&want_tl2).enumerate() {
            if g.to_bits() != w.to_bits() {
                return Err(format!("tl2 {tag} [{i}]: {g:?} vs {w:?}"));
            }
        }
        let mut got = vec![f32::NAN; batch * w];
        simd::gemm_i2s_with(isa, &packs.i2s, xs, batch, j0, j1, &mut got);
        for (i, (g, w)) in got.iter().zip(&want_i2s).enumerate() {
            if g.to_bits() != w.to_bits() {
                return Err(format!("i2s {tag} [{i}]: {g:?} vs {w:?}"));
            }
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// LUT-GEMM walks — exhaustive deterministic cases
// ---------------------------------------------------------------------------

/// Batches 1..=10 straddle both lane widths (NEON chunks 4 rows, AVX2 8)
/// plus their one-off tails; 16 and 17 hit multi-chunk and
/// multi-chunk-plus-tail.
const BATCHES: [usize; 12] = [1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 16, 17];

#[test]
fn gemm_parity_across_batches_and_formats() {
    // d_in = 64: pack34's sb-tile loop runs full 16-block tiles; d_out
    // deliberately not a "nice" width.
    let mut rng = Pcg64::seeded(101);
    let (d_in, d_out) = (64usize, 13usize);
    let packs = packs(&mut rng, d_in, d_out);
    for batch in BATCHES {
        let xs = rng.normal_vec(batch * d_in);
        check_gemm_case(&packs, &xs, d_in, batch, 0, d_out).unwrap();
    }
}

#[test]
fn gemm_parity_odd_tail_d_in() {
    // Shapes chosen so every format's *element* tail path runs:
    //   pack34: nb = d_in/4 not a multiple of 8 → partial sb tile;
    //   tl2:    d_in % 3 ∈ {1, 2} → padded final group;
    //   i2s:    d_in % 4 ∈ {1, 2, 3} → partial final byte.
    // pack34 requires d_in % 4 == 0, so tl2/i2s odd tails get their own
    // fixtures below.
    let mut rng = Pcg64::seeded(202);
    for d_in in [4usize, 12, 20, 36, 100] {
        let d_out = 7;
        let packs = packs(&mut rng, d_in, d_out);
        for batch in [1usize, 4, 5, 8, 9] {
            let xs = rng.normal_vec(batch * d_in);
            check_gemm_case(&packs, &xs, d_in, batch, 0, d_out).unwrap();
        }
    }
    // tl2 / i2s only (d_in not a multiple of 4): drive their dispatched
    // walks directly over every residue class.
    for d_in in [3usize, 5, 7, 9, 97, 98] {
        let d_out = 5;
        let w = Mat::randn(&mut rng, d_in, d_out, 1.0);
        let qd = absmean_quantize(&w, Granularity::PerChannel);
        let tl2 = PackedTl2::from_ternary(&qd);
        let i2s = PackedI2S::from_ternary(&qd);
        for batch in [1usize, 3, 4, 5, 8, 9] {
            let xs = rng.normal_vec(batch * d_in);
            let (ltl2, stl2) = luts_tl2(&xs, d_in, batch);
            let mut want = vec![0.0f32; batch * d_out];
            lut::gemm_tl2_preluts(&tl2, &ltl2, stl2, batch, 0, d_out, &mut want);
            let mut want_i = vec![0.0f32; batch * d_out];
            lut::gemm_i2s(&i2s, &xs, batch, 0, d_out, &mut want_i);
            for isa in Isa::ALL {
                let mut got = vec![f32::NAN; batch * d_out];
                simd::gemm_tl2_preluts_with(isa, &tl2, &ltl2, stl2, batch, 0, d_out, &mut got);
                assert_bits_eq(&got, &want, &format!("tl2 d_in={d_in} b={batch} {}", isa.name()));
                let mut got = vec![f32::NAN; batch * d_out];
                simd::gemm_i2s_with(isa, &i2s, &xs, batch, 0, d_out, &mut got);
                assert_bits_eq(&got, &want_i, &format!("i2s d_in={d_in} b={batch} {}", isa.name()));
            }
        }
    }
}

#[test]
fn gemm_parity_on_column_windows() {
    // The engine tiles output columns (gemm_tile), so dispatched walks
    // must honor partial [j0, j1) windows, including single-column and
    // empty windows.
    let mut rng = Pcg64::seeded(303);
    let (d_in, d_out) = (32usize, 11usize);
    let packs = packs(&mut rng, d_in, d_out);
    let xs = rng.normal_vec(9 * d_in);
    for (j0, j1) in [(0usize, 11usize), (0, 1), (3, 8), (10, 11), (5, 5)] {
        check_gemm_case(&packs, &xs, d_in, 9, j0, j1).unwrap();
    }
}

#[test]
fn prop_gemm_parity_random_shapes() {
    prop::check(
        "gemm walks simd == scalar (all formats)",
        40,
        |rng| {
            let d_in = 4 * prop::gens::usize_in(rng, 1, 40);
            let d_out = prop::gens::usize_in(rng, 1, 24);
            let batch = prop::gens::usize_in(rng, 1, 18);
            (d_in, d_out, batch, rng.next_u64())
        },
        |&(d_in, d_out, batch, seed)| {
            let mut rng = Pcg64::seeded(seed);
            let packs = packs(&mut rng, d_in, d_out);
            let xs = rng.normal_vec(batch * d_in);
            // Random sub-window half the time.
            let (j0, j1) = if seed % 2 == 0 {
                (0, d_out)
            } else {
                let j0 = (seed as usize / 2) % d_out;
                (j0, j0 + 1 + (seed as usize / 7) % (d_out - j0))
            };
            check_gemm_case(&packs, &xs, d_in, batch, j0, j1)
        },
    );
}

// ---------------------------------------------------------------------------
// Ternary-KV q·k LUT walk
// ---------------------------------------------------------------------------

/// The ternary attention score walk exactly as the engine drives it:
/// packed idx/sign planes come from a real `TernaryStore` page via
/// `block_ternary`, the per-query 32-entry LUTs from
/// `lut::build_qk_luts34` over full-range i8 query codes, and every ISA
/// runs every row count — empty, sub-chunk, exact vector-width
/// multiples, one-off tails, and the full odd-sized page.
#[test]
fn qk_lut34_parity_on_store_pages_every_isa_and_row_count() {
    let cfg = NativeConfig::named("nano").unwrap();
    let (d, hd, nh) = (cfg.d_model, cfg.head_dim(), cfg.n_heads);
    let nb = hd / 4;
    let ps = 17; // odd: straddles both vector widths' chunk + tail
    let mut st = TernaryStore::new(&cfg, 1, ps);
    st.reset_page(0);
    let mut rng = Pcg64::seeded(55);
    for s in 0..ps {
        let row = rng.normal_vec(d);
        st.write_row(0, 0, s, &row, &row);
    }
    let q_codes = i8_pattern(nh * hd, 7);
    let mut luts = vec![0.0f32; nh * nb * 32];
    lut::build_qk_luts34(&q_codes, hd, nh, &mut luts);
    for rows in [0usize, 1, 2, 3, 7, 8, 9, 13, 16, 17] {
        let tb = st.block_ternary(0, 0, rows).expect("ternary-native view");
        for h in 0..nh {
            let mut want = vec![f32::NAN; rows];
            lut::qk_lut34_rows(
                tb.idx, tb.sign, tb.idx_bh, tb.sign_bh, nb, h, nh, &luts, rows, &mut want,
            );
            for isa in Isa::ALL {
                let mut got = vec![f32::NAN; rows];
                simd::qk_lut34_rows_with(
                    isa, tb.idx, tb.sign, tb.idx_bh, tb.sign_bh, nb, h, nh, &luts, rows, &mut got,
                );
                assert_bits_eq(
                    &got,
                    &want,
                    &format!("qk rows={rows} h={h} isa={} (available={})", isa.name(), isa.available()),
                );
            }
        }
    }
}

/// Random plane geometry for the dispatched q·k walk: every nibble value
/// is a valid pack34 code, so raw random bytes are legal planes, and the
/// LUT entries are arbitrary floats — per-row accumulation order is
/// identical in every lane, so bit parity must hold even off the integer
/// lattice `build_qk_luts34` produces.
#[test]
fn prop_qk_lut34_parity_random_geometry() {
    prop::check(
        "qk_lut34 walk simd == scalar",
        40,
        |rng| {
            let nb = prop::gens::usize_in(rng, 1, 12);
            let n_heads = prop::gens::usize_in(rng, 1, 5);
            let rows = prop::gens::usize_in(rng, 0, 33);
            (nb, n_heads, rows, rng.next_u64())
        },
        |&(nb, n_heads, rows, seed)| {
            let mut rng = Pcg64::seeded(seed);
            let idx_bh = nb.div_ceil(2);
            let sign_bh = nb.div_ceil(8);
            let idx: Vec<u8> =
                (0..rows * n_heads * idx_bh).map(|_| rng.next_u64() as u8).collect();
            let sign: Vec<u8> =
                (0..rows * n_heads * sign_bh).map(|_| rng.next_u64() as u8).collect();
            let luts = rng.normal_vec(n_heads * nb * 32);
            for h in 0..n_heads {
                let mut want = vec![f32::NAN; rows];
                lut::qk_lut34_rows(
                    &idx, &sign, idx_bh, sign_bh, nb, h, n_heads, &luts, rows, &mut want,
                );
                for isa in Isa::ALL {
                    let mut got = vec![f32::NAN; rows];
                    simd::qk_lut34_rows_with(
                        isa, &idx, &sign, idx_bh, sign_bh, nb, h, n_heads, &luts, rows, &mut got,
                    );
                    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                        if g.to_bits() != w.to_bits() {
                            return Err(format!(
                                "nb={nb} nh={n_heads} rows={rows} h={h} isa={} [{i}]: {g:?} vs {w:?}",
                                isa.name()
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Fixed-point a·V accumulation walk
// ---------------------------------------------------------------------------

/// Deterministic u8 weight fill in the kernel's `[0, 127]` contract,
/// pinning the zero-weight skip path and both extremes.
fn u8_weights(n: usize, salt: u64) -> Vec<u8> {
    let mut rng = Pcg64::seeded(salt);
    let mut w: Vec<u8> = (0..n).map(|_| (rng.next_u64() % 128) as u8).collect();
    if n >= 3 {
        w[0] = 0; // the skip path
        w[1] = 127;
        w[2] = 1;
    }
    w
}

/// The integer a·V walk exactly as attention drives it: raw int8 V page
/// bytes from real stores (int8 and ternary share the V plane machinery),
/// including partial pages and the empty prefix. i32 accumulation is
/// exact, so parity is hard equality on every ISA.
#[test]
fn av_i8_parity_on_store_pages_every_isa_and_row_count() {
    let cfg = NativeConfig::named("nano").unwrap();
    let (d, hd, nh) = (cfg.d_model, cfg.head_dim(), cfg.n_heads);
    let mut rng = Pcg64::seeded(61);
    let mut i8st = Int8Store::new(&cfg, 2, 4);
    i8st.reset_page(0);
    for s in 0..3 {
        let row = rng.normal_vec(d);
        i8st.write_row(0, 0, s, &row, &row);
    }
    let ps = 17; // odd: straddles both vector widths' row geometry
    let mut tst = TernaryStore::new(&cfg, 1, ps);
    tst.reset_page(0);
    for s in 0..ps {
        let row = rng.normal_vec(d);
        tst.write_row(0, 0, s, &row, &row);
    }
    let stores: [(&dyn PageStore, &[usize]); 2] = [
        (&i8st, &[0usize, 1, 3][..]),
        (&tst, &[0usize, 1, 2, 3, 7, 8, 9, 13, 16, 17][..]),
    ];
    for (st, row_counts) in stores {
        for &rows in row_counts {
            let (data, scales) = st.block_i8(Plane::V, 0, 0, rows).expect("int8 V view");
            assert_eq!(data.len(), rows * d);
            assert_eq!(scales.len(), nh);
            let weights = u8_weights(rows, 83 + rows as u64);
            for h in 0..nh {
                let mut want = vec![0i32; hd];
                simd::av_i8_rows_scalar(&weights, data, d, h * hd, hd, rows, &mut want);
                for isa in Isa::ALL {
                    let mut got = vec![i32::MIN; hd];
                    simd::av_i8_rows_with(isa, &weights, data, d, h * hd, hd, rows, &mut got);
                    assert_eq!(
                        got,
                        want,
                        "rows={rows} h={h} isa={} (available={})",
                        isa.name(),
                        isa.available()
                    );
                }
            }
        }
    }
    // Control: the f32 store has no int8 view — attention keeps its f32
    // V arm and never reaches the dispatched walk.
    let f = F32Store::new(&cfg, 1, 4);
    assert!(f.block_i8(Plane::V, 0, 0, 1).is_none());
}

/// Head widths straddle every channel-chunk boundary of both vector
/// widths (AVX2: 8 i32 lanes, NEON: 4), plus one-off tails and widths
/// below one vector — the walk's scalar channel tail must engage on
/// every one of them.
#[test]
fn av_i8_parity_odd_and_tail_head_dims() {
    for hd in [1usize, 2, 3, 4, 5, 7, 8, 9, 12, 15, 16, 17, 19, 32, 33] {
        let nh = 2;
        let d = nh * hd;
        let rows = 9;
        let v = i8_pattern(rows * d, 1000 + hd as u64);
        let weights = u8_weights(rows, 2000 + hd as u64);
        for h in 0..nh {
            let mut want = vec![0i32; hd];
            simd::av_i8_rows_scalar(&weights, &v, d, h * hd, hd, rows, &mut want);
            for isa in Isa::ALL {
                let mut got = vec![i32::MIN; hd];
                simd::av_i8_rows_with(isa, &weights, &v, d, h * hd, hd, rows, &mut got);
                assert_eq!(got, want, "hd={hd} h={h} isa={}", isa.name());
            }
        }
    }
}

#[test]
fn prop_av_i8_parity_random_geometry() {
    prop::check(
        "av_i8 walk simd == scalar",
        48,
        |rng| {
            let hd = prop::gens::usize_in(rng, 1, 37);
            let n_heads = prop::gens::usize_in(rng, 1, 4);
            let rows = prop::gens::usize_in(rng, 0, 21);
            (hd, n_heads, rows, rng.next_u64())
        },
        |&(hd, n_heads, rows, seed)| {
            let d = n_heads * hd;
            let v = i8_pattern(rows * d, seed);
            let weights = u8_weights(rows, seed ^ 0x1234_5678);
            for h in 0..n_heads {
                let mut want = vec![0i32; hd];
                simd::av_i8_rows_scalar(&weights, &v, d, h * hd, hd, rows, &mut want);
                for isa in Isa::ALL {
                    let mut got = vec![i32::MIN; hd];
                    simd::av_i8_rows_with(isa, &weights, &v, d, h * hd, hd, rows, &mut got);
                    if got != want {
                        return Err(format!(
                            "hd={hd} nh={n_heads} rows={rows} h={h} isa={}",
                            isa.name()
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Dispatching through `Isa::Scalar` must be the *identical* code path as
/// calling `engine::lut` directly — outputs are compared bit-for-bit
/// above, but this control also pins the zero-batch edge and proves the
/// `_with` wrappers add no observable behavior of their own.
#[test]
fn forced_scalar_control_matches_direct_lut_calls() {
    let mut rng = Pcg64::seeded(404);
    let (d_in, d_out) = (24usize, 6usize);
    let packs = packs(&mut rng, d_in, d_out);
    for batch in [0usize, 1, 5] {
        let xs = rng.normal_vec(batch * d_in);
        check_gemm_case(&packs, &xs, d_in, batch, 0, d_out).unwrap();
    }
    // The process-global selection (whatever this test binary pinned —
    // SHERRY_KERNEL_ISA in the CI matrix) agrees with itself and is one
    // of the variants the loops above already proved bit-exact.
    let active = simd::active();
    assert!(active.available());
    assert!(Isa::ALL.contains(&active));
}
