//! Fig 1: packing strategies in (bits/weight, relative speed) space —
//! the scatter the paper opens with.
//!
//! Run: `cargo bench --bench fig1_packing`

use sherry::engine::{QuantLinear, Scratch};
use sherry::pack::Format;
use sherry::tensor::Mat;
use sherry::util::{bench::bench, Pcg64};

fn main() {
    let (d_in, d_out) = (4096usize, 4096usize);
    let mut rng = Pcg64::seeded(1);
    let w = Mat::randn(&mut rng, d_in, d_out, 0.02);
    let x = rng.normal_vec(d_in);

    println!("\n### Fig 1 — packing strategies: bits vs speed ({d_in}x{d_out} GEMV)\n");
    println!("| strategy | bits/weight | GEMV ms | Mweights/s | speed vs 2-bit |");
    println!("|---|---|---|---|---|");
    let mut results = Vec::new();
    for format in [Format::Dense, Format::I2S, Format::Tl2, Format::Sherry] {
        let lin = QuantLinear::from_float(&w, format);
        let mut y = vec![0.0f32; d_out];
        let mut scratch = Scratch::default();
        let m = bench(format.name(), 2, 9, || {
            lin.forward(&x, &mut y, &mut scratch);
            std::hint::black_box(&y);
        });
        results.push((format, m.median_s));
    }
    let i2s_t = results.iter().find(|(f, _)| *f == Format::I2S).unwrap().1;
    for (format, t) in &results {
        println!(
            "| {} | {:.2} | {:.3} | {:.1} | {:.2}x |",
            format.name(),
            format.bits_per_weight(),
            t * 1e3,
            (d_in * d_out) as f64 / t / 1e6,
            i2s_t / t
        );
    }
    let sherry_t = results.iter().find(|(f, _)| *f == Format::Sherry).unwrap().1;
    let tl2_t = results.iter().find(|(f, _)| *f == Format::Tl2).unwrap().1;
    println!(
        "\nshape check — sherry faster than tl2: {} (paper Fig 1: 1.25-bit sits above-left of both baselines)",
        if sherry_t < tl2_t { "YES" } else { "NO" }
    );
}
