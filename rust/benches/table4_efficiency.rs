//! Table 4: inference speed (t/s) and model size (MB) per format, at the
//! paper's 0.7B and 3B layer shapes.
//!
//! Method: the decode hot path is 7 GEMVs per layer; we measure each
//! unique layer shape once per format (weights are too large to hold
//! n_layers copies in RAM at the 3B scale) and extrapolate per-token time
//! as Σ layer-GEMV × n_layers + LM-head GEMV — the standard per-layer
//! roofline extrapolation, documented in EXPERIMENTS.md. Sizes are exact
//! byte counts of the packed planes + scales + bf16 embed/head.
//!
//! Run: `cargo bench --bench table4_efficiency` (FAST=1 env for CI sizes)

use sherry::engine::{NativeConfig, QuantLinear, Scratch};
use sherry::pack::Format;
use sherry::quant::{quantize, Granularity, Method};
use sherry::tensor::Mat;
use sherry::util::{bench::bench, Pcg64};

struct Shape {
    name: &'static str,
    cfg: NativeConfig,
}

fn main() {
    let fast = std::env::var("FAST").is_ok();
    let shapes = if fast {
        vec![Shape { name: "0.2B-ish (micro×)", cfg: NativeConfig::named("micro").unwrap() }]
    } else {
        vec![
            Shape { name: "0.7B", cfg: NativeConfig::named("bench700m").unwrap() },
            Shape { name: "3B", cfg: NativeConfig::named("bench3b").unwrap() },
        ]
    };

    println!("\n### Table 4 — inference efficiency (this CPU; paper: i7-14700HX)\n");
    println!("| Scale | Method | Bits | Speed (t/s) ↑ | Size (MB) ↓ |");
    println!("|---|---|---|---|---|");

    for shape in &shapes {
        let cfg = &shape.cfg;
        let d = cfg.d_model;
        let layer_shapes = [(d, d, 4usize), (d, cfg.d_ff, 3usize)];
        // bf16 row first for the ratio.
        let mut rows: Vec<(String, f32, f64, f64)> = Vec::new();
        for format in [Format::Dense, Format::I2S, Format::Tl2, Format::Sherry] {
            let mut per_tok = 0.0f64;
            let mut lin_bytes = 0usize;
            for &(d_in, d_out, count) in &layer_shapes {
                let mut rng = Pcg64::seeded(7);
                let w = Mat::randn(&mut rng, d_in, d_out, 0.02);
                let lin = QuantLinear::from_float(&w, format);
                let x = rng.normal_vec(d_in);
                let mut y = vec![0.0f32; d_out];
                let mut scratch = Scratch::default();
                let m = bench(format.name(), 2, 9, || {
                    lin.forward(&x, &mut y, &mut scratch);
                    std::hint::black_box(&y);
                });
                per_tok += m.median_s * (count * cfg.n_layers) as f64;
                lin_bytes += lin.bytes() * count * cfg.n_layers;
                // also time the down-projection direction for the (d, ff)
                // shape (w_down is ff→d): reuse transposed shape
                if d_out == cfg.d_ff {
                    let wt = Mat::randn(&mut rng, d_out, d_in, 0.02);
                    let lin2 = QuantLinear::from_float(&wt, format);
                    let x2 = rng.normal_vec(d_out);
                    let mut y2 = vec![0.0f32; d_in];
                    let m2 = bench("down", 2, 9, || {
                        lin2.forward(&x2, &mut y2, &mut scratch);
                        std::hint::black_box(&y2);
                    });
                    per_tok += m2.median_s * cfg.n_layers as f64;
                    lin_bytes += lin2.bytes() * cfg.n_layers;
                }
            }
            // LM head (dense in all variants) + embeddings: bf16 bytes.
            let head_bytes = cfg.d_model * cfg.vocab_size * 2 * 2;
            // head GEMV time at f32 (same for all formats) — measure once.
            let mut rng = Pcg64::seeded(9);
            let wh = Mat::randn(&mut rng, cfg.d_model, cfg.vocab_size, 0.02);
            let head = QuantLinear::from_float(&wh, Format::Dense);
            let xh = rng.normal_vec(cfg.d_model);
            let mut yh = vec![0.0f32; cfg.vocab_size];
            let mut scratch = Scratch::default();
            let mh = bench("head", 1, 5, || {
                head.forward(&xh, &mut yh, &mut scratch);
                std::hint::black_box(&yh);
            });
            per_tok += mh.median_s;
            let total_bytes = lin_bytes + head_bytes;
            rows.push((
                format.name().to_string(),
                format.bits_per_weight(),
                1.0 / per_tok,
                total_bytes as f64 / 1e6,
            ));
        }
        for (name, bits, tps, mb) in &rows {
            println!("| {} | {} | {:.2} | {:.2} | {:.2} |", shape.name, name, bits, tps, mb);
        }
        // shape checks vs paper Table 4
        let get = |n: &str| rows.iter().find(|r| r.0 == n).unwrap();
        let (sherry, tl2, i2s, bf16) = (get("sherry"), get("tl2"), get("i2_s"), get("bf16"));
        println!(
            "| {} | — | — | sherry/tl2 = {:.2}x (paper 1.18x@3B), sherry/i2s = {:.2}x (paper 1.09-1.12x), sherry/bf16 = {:.1}x | sherry saves {:.0}% vs tl2 (paper ~16%) |",
            shape.name,
            sherry.2 / tl2.2,
            sherry.2 / i2s.2,
            sherry.2 / bf16.2,
            (1.0 - sherry.3 / tl2.3) * 100.0
        );
    }
    println!("\n(LUT GEMV timings; per-token = Σ layer GEMVs × n_layers + head — see EXPERIMENTS.md)");
}
