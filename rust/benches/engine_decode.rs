//! End-to-end decode throughput on full (small) models per format —
//! validates that the Table 4 per-layer extrapolation matches a real
//! decode loop where everything (attention, norms, sampling) is included.
//!
//! Run: `cargo bench --bench engine_decode`

use sherry::engine::{random_weights, KvCache, NativeConfig, Scratch, TernaryModel};
use sherry::pack::Format;
use sherry::util::bench::bench;

fn main() {
    println!("\n### End-to-end decode throughput (full model, KV cache)\n");
    println!("| config | format | tok/s | model MB |");
    println!("|---|---|---|---|");
    for cfg_name in ["nano", "micro"] {
        let cfg = NativeConfig::named(cfg_name).unwrap();
        let weights = random_weights(&cfg, 5);
        for format in [Format::Dense, Format::I2S, Format::Tl2, Format::Sherry] {
            let model = TernaryModel::build(cfg, &weights, format);
            let mut cache = KvCache::new(&cfg);
            let mut scratch = Scratch::default();
            let n_gen = 32usize;
            let m = bench(format.name(), 1, 7, || {
                let out = model.generate(&[1, 2, 3], n_gen, &mut cache, &mut scratch);
                std::hint::black_box(&out);
            });
            println!(
                "| {} | {} | {:.1} | {:.2} |",
                cfg_name,
                format.name(),
                (n_gen + 3) as f64 / m.median_s,
                model.bytes() as f64 / 1e6
            );
        }
    }
    println!("\n(nano/micro fit in cache: compute-bound regime. Paper-scale memory-bound numbers: table4_efficiency.)");
}
