//! Kernel microbench: per-format LUT GEMV across layer widths — the §Perf
//! workhorse (EXPERIMENTS.md §Perf before/after numbers come from here) —
//! plus the batched LUT-GEMM sweep over B ∈ {1, 4, 16, 64} that tracks
//! the continuous-batching win (written to `BENCH_batched_gemm.json`),
//! plus the scalar-vs-vector kernel sweep (`BENCH_simd_kernels.json`)
//! comparing the runtime-dispatched SIMD walks against forced scalar.
//! Every JSON record carries the ISA the measurement dispatched through.
//!
//! Run: `cargo bench --bench gemv_kernels`

use sherry::engine::lut::{self, TL2_LUT_STRIDE};
use sherry::engine::{Scratch, TernaryKernel};
use sherry::pack::{Packed34, PackedI2S, PackedTl2};
use sherry::quant::{quantize, Granularity, Method};
use sherry::simd::{self, Isa};
use sherry::tensor::{gemv_f32, Mat};
use sherry::util::{bench::bench, Pcg64, ThreadPool};

fn main() {
    println!("[bench] kernel isa: {}", simd::active().name());
    gemv_table();
    batched_gemm_sweep();
    simd_kernel_sweep();
}

fn gemv_table() {
    println!("\n### GEMV kernel microbenchmarks (median, warm cache)\n");
    println!("| d_in x d_out | kernel | µs | Gweights/s |");
    println!("|---|---|---|---|");
    for &(d_in, d_out) in &[(1024usize, 1024usize), (3200, 3200), (3200, 8640)] {
        let mut rng = Pcg64::seeded(3);
        let w = Mat::randn(&mut rng, d_in, d_out, 0.02);
        let x = rng.normal_vec(d_in);
        let n = (d_in * d_out) as f64;

        let qs = quantize(&w, Method::Sherry34, Granularity::PerChannel);
        let qd = quantize(&w, Method::AbsMean, Granularity::PerChannel);

        // dense f32
        let wt = w.transpose();
        let mut y = vec![0.0f32; d_out];
        let m = bench("dense", 2, 9, || {
            gemv_f32(&wt.data, d_out, d_in, &x, &mut y);
            std::hint::black_box(&y);
        });
        print_row(d_in, d_out, "dense f32", m.median_s, n);

        // sherry LUT
        let p34 = Packed34::from_ternary(&qs);
        let mut luts = vec![0.0f32; (d_in / 4) * 16];
        let m = bench("sherry", 2, 9, || {
            lut::gemv_pack34(&p34, &x, &mut luts, &mut y);
            std::hint::black_box(&y);
        });
        print_row(d_in, d_out, "sherry lut16", m.median_s, n);
        // lut build alone (amortization accounting)
        let m = bench("sherry-lut-build", 2, 9, || {
            lut::build_luts34(&x, &mut luts);
            std::hint::black_box(&luts);
        });
        print_row(d_in, d_out, "  (lut build)", m.median_s, n);

        // tl2
        let ptl2 = PackedTl2::from_ternary(&qd);
        let mut luts2 = vec![0.0f32; d_in.div_ceil(3) * TL2_LUT_STRIDE];
        let m = bench("tl2", 2, 9, || {
            lut::gemv_tl2(&ptl2, &x, &mut luts2, &mut y);
            std::hint::black_box(&y);
        });
        print_row(d_in, d_out, "tl2 lut27", m.median_s, n);

        // i2s
        let pi2s = PackedI2S::from_ternary(&qd);
        let m = bench("i2s", 2, 9, || {
            lut::gemv_i2s(&pi2s, &x, &mut y);
            std::hint::black_box(&y);
        });
        print_row(d_in, d_out, "i2_s decode", m.median_s, n);
    }
}

fn print_row(d_in: usize, d_out: usize, name: &str, t: f64, n: f64) {
    println!("| {d_in}x{d_out} | {name} | {:.1} | {:.2} |", t * 1e6, n / t / 1e9);
}

/// Batched LUT-GEMM sweep: one fused `gemm_nt` over B rows vs B
/// independent `gemv` calls, per packed format. Emits
/// `BENCH_batched_gemm.json` so the perf trajectory captures the
/// batching win over time.
fn batched_gemm_sweep() {
    let (d_in, d_out) = (3200usize, 3200usize);
    let batches = [1usize, 4, 16, 64];
    let pool = ThreadPool::new(ThreadPool::default_size());
    let mut rng = Pcg64::seeded(11);
    let w = Mat::randn(&mut rng, d_in, d_out, 0.02);
    let qs = quantize(&w, Method::Sherry34, Granularity::PerChannel);
    let qd = quantize(&w, Method::AbsMean, Granularity::PerChannel);
    let kernels: Vec<(&str, Box<dyn TernaryKernel>)> = vec![
        ("sherry", Box::new(Packed34::from_ternary(&qs))),
        ("tl2", Box::new(PackedTl2::from_ternary(&qd))),
        ("i2_s", Box::new(PackedI2S::from_ternary(&qd))),
    ];

    let isa = simd::active().name();
    println!("\n### Batched LUT-GEMM ({d_in}x{d_out}, {} workers, isa {isa})\n", pool.size());
    println!("| kernel | B | fused µs/tok | B×gemv µs/tok | speedup | Gweights/s |");
    println!("|---|---|---|---|---|---|");
    let n = (d_in * d_out) as f64;
    let mut records = Vec::new();
    for (name, k) in &kernels {
        for &b in &batches {
            let xs = rng.normal_vec(b * d_in);
            let mut ys = vec![0.0f32; b * d_out];
            let mut scratch = Scratch::default();
            let fused = bench(name, 1, 7, || {
                k.gemm_nt(&xs, &mut ys, b, &mut scratch, Some(&pool));
                std::hint::black_box(&ys);
            });
            let singles = bench(name, 1, 7, || {
                for bi in 0..b {
                    let (x, y) =
                        (&xs[bi * d_in..(bi + 1) * d_in], &mut ys[bi * d_out..(bi + 1) * d_out]);
                    k.gemv(x, y, &mut scratch);
                }
                std::hint::black_box(&ys);
            });
            let fused_tok = fused.median_s / b as f64;
            let single_tok = singles.median_s / b as f64;
            println!(
                "| {name} | {b} | {:.1} | {:.1} | {:.2}x | {:.2} |",
                fused_tok * 1e6,
                single_tok * 1e6,
                single_tok / fused_tok,
                n / fused_tok / 1e9,
            );
            records.push(format!(
                "    {{\"kernel\": \"{name}\", \"isa\": \"{isa}\", \"batch\": {b}, \
                 \"d_in\": {d_in}, \"d_out\": {d_out}, \
                 \"fused_us_per_tok\": {:.3}, \"gemv_us_per_tok\": {:.3}, \"speedup\": {:.4}, \
                 \"gweights_per_s\": {:.4}}}",
                fused_tok * 1e6,
                single_tok * 1e6,
                single_tok / fused_tok,
                n / fused_tok / 1e9,
            ));
        }
    }
    let json = format!("{{\n  \"bench\": \"batched_gemm\",\n  \"records\": [\n{}\n  ]\n}}\n", records.join(",\n"));
    let path = "BENCH_batched_gemm.json";
    match std::fs::write(path, &json) {
        Ok(()) => println!("\n[bench] wrote {path}"),
        Err(e) => eprintln!("[bench] could not write {path}: {e}"),
    }
}

/// Scalar vs vector, same work: the three LUT-GEMM walks through
/// `simd::gemm_*_with` at forced-scalar and at the auto-detected ISA,
/// plus the i8×i8 attention dot, over B ∈ {1, 4, 16, 64}. Emits
/// `BENCH_simd_kernels.json` — the scalar-vs-vector baseline the
/// dispatch layer is accountable to. On a scalar-only host both arms run
/// the same code and the speedup column reads ~1.0.
fn simd_kernel_sweep() {
    let (d_in, d_out) = (3200usize, 3200usize);
    let batches = [1usize, 4, 16, 64];
    let vec_isa = Isa::detect();
    let mut rng = Pcg64::seeded(17);
    let w = Mat::randn(&mut rng, d_in, d_out, 0.02);
    let qs = quantize(&w, Method::Sherry34, Granularity::PerChannel);
    let qd = quantize(&w, Method::AbsMean, Granularity::PerChannel);
    let p34 = Packed34::from_ternary(&qs);
    let ptl2 = PackedTl2::from_ternary(&qd);
    let pi2s = PackedI2S::from_ternary(&qd);
    let stride34 = (d_in / 4) * 16;
    let stride_tl2 = d_in.div_ceil(3) * TL2_LUT_STRIDE;

    println!("\n### SIMD kernel sweep ({d_in}x{d_out}, scalar vs {})\n", vec_isa.name());
    println!("| kernel | B | scalar µs/tok | {} µs/tok | speedup |", vec_isa.name());
    println!("|---|---|---|---|---|");
    let mut records = Vec::new();
    let mut push = |kernel: &str, b: usize, scalar_s: f64, vec_s: f64| {
        let (sc_tok, v_tok) = (scalar_s / b as f64, vec_s / b as f64);
        println!(
            "| {kernel} | {b} | {:.1} | {:.1} | {:.2}x |",
            sc_tok * 1e6,
            v_tok * 1e6,
            sc_tok / v_tok
        );
        records.push(format!(
            "    {{\"kernel\": \"{kernel}\", \"isa\": \"{}\", \"batch\": {b}, \
             \"scalar_us_per_tok\": {:.3}, \"vector_us_per_tok\": {:.3}, \"speedup\": {:.4}}}",
            vec_isa.name(),
            sc_tok * 1e6,
            v_tok * 1e6,
            sc_tok / v_tok,
        ));
    };
    for &b in &batches {
        let xs = rng.normal_vec(b * d_in);
        let mut ys = vec![0.0f32; b * d_out];

        let mut luts = vec![0.0f32; b * stride34];
        for bi in 0..b {
            lut::build_luts34(&xs[bi * d_in..(bi + 1) * d_in], &mut luts[bi * stride34..(bi + 1) * stride34]);
        }
        let sc = bench("p34-scalar", 1, 7, || {
            simd::gemm_pack34_preluts_with(Isa::Scalar, &p34, &luts, stride34, b, 0, d_out, &mut ys);
            std::hint::black_box(&ys);
        });
        let vc = bench("p34-vec", 1, 7, || {
            simd::gemm_pack34_preluts_with(vec_isa, &p34, &luts, stride34, b, 0, d_out, &mut ys);
            std::hint::black_box(&ys);
        });
        push("sherry", b, sc.median_s, vc.median_s);

        let mut luts = vec![0.0f32; b * stride_tl2];
        for bi in 0..b {
            lut::build_luts_tl2(&xs[bi * d_in..(bi + 1) * d_in], &mut luts[bi * stride_tl2..(bi + 1) * stride_tl2]);
        }
        let sc = bench("tl2-scalar", 1, 7, || {
            simd::gemm_tl2_preluts_with(Isa::Scalar, &ptl2, &luts, stride_tl2, b, 0, d_out, &mut ys);
            std::hint::black_box(&ys);
        });
        let vc = bench("tl2-vec", 1, 7, || {
            simd::gemm_tl2_preluts_with(vec_isa, &ptl2, &luts, stride_tl2, b, 0, d_out, &mut ys);
            std::hint::black_box(&ys);
        });
        push("tl2", b, sc.median_s, vc.median_s);

        let sc = bench("i2s-scalar", 1, 7, || {
            simd::gemm_i2s_with(Isa::Scalar, &pi2s, &xs, b, 0, d_out, &mut ys);
            std::hint::black_box(&ys);
        });
        let vc = bench("i2s-vec", 1, 7, || {
            simd::gemm_i2s_with(vec_isa, &pi2s, &xs, b, 0, d_out, &mut ys);
            std::hint::black_box(&ys);
        });
        push("i2_s", b, sc.median_s, vc.median_s);
    }
    // The attention-side i8×i8 dot (per-row granularity, hd=100 as in
    // bench3b heads), amortized over a simulated 4096-row score pass.
    let hd = 100usize;
    let rows = 4096usize;
    let qc: Vec<i8> = (0..hd).map(|i| ((i * 37 + 11) % 255) as i8).collect();
    let kc: Vec<i8> = (0..rows * hd).map(|i| ((i * 91 + 3) % 255) as i8).collect();
    let mut acc = 0i64;
    let sc = bench("dot-scalar", 1, 7, || {
        for r in 0..rows {
            acc += simd::dot_i8_with(Isa::Scalar, &qc, &kc[r * hd..(r + 1) * hd]) as i64;
        }
        std::hint::black_box(acc);
    });
    let vc = bench("dot-vec", 1, 7, || {
        for r in 0..rows {
            acc += simd::dot_i8_with(vec_isa, &qc, &kc[r * hd..(r + 1) * hd]) as i64;
        }
        std::hint::black_box(acc);
    });
    push("dot_i8", rows, sc.median_s, vc.median_s);

    let json = format!("{{\n  \"bench\": \"simd_kernels\",\n  \"records\": [\n{}\n  ]\n}}\n", records.join(",\n"));
    let path = "BENCH_simd_kernels.json";
    match std::fs::write(path, &json) {
        Ok(()) => println!("\n[bench] wrote {path}"),
        Err(e) => eprintln!("[bench] could not write {path}: {e}"),
    }
}
