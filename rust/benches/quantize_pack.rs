//! Offline-phase cost (App. A "Offline Packing Phase"): quantize + pack
//! throughput per format — one-time conversion cost for a model.
//!
//! Run: `cargo bench --bench quantize_pack`

use sherry::pack::{Packed34, PackedI2S, PackedTl2};
use sherry::quant::{quantize, Granularity, Method};
use sherry::tensor::Mat;
use sherry::util::{bench::bench, Pcg64};

fn main() {
    let (d_in, d_out) = (2048usize, 2048usize);
    let mut rng = Pcg64::seeded(4);
    let w = Mat::randn(&mut rng, d_in, d_out, 0.02);
    let n = (d_in * d_out) as f64;

    println!("\n### Offline phase: quantize + pack throughput ({d_in}x{d_out})\n");
    println!("| stage | ms | Mweights/s |");
    println!("|---|---|---|");

    let m = bench("q-sherry", 1, 5, || {
        std::hint::black_box(quantize(&w, Method::Sherry34, Granularity::PerChannel));
    });
    println!("| quantize sherry34 (Eq. 4-5) | {:.1} | {:.1} |", m.median_s * 1e3, n / m.median_s / 1e6);

    let m = bench("q-absmean", 1, 5, || {
        std::hint::black_box(quantize(&w, Method::AbsMean, Granularity::PerChannel));
    });
    println!("| quantize absmean (Eq. 15) | {:.1} | {:.1} |", m.median_s * 1e3, n / m.median_s / 1e6);

    let qs = quantize(&w, Method::Sherry34, Granularity::PerChannel);
    let qd = quantize(&w, Method::AbsMean, Granularity::PerChannel);

    let m = bench("pack34", 1, 5, || {
        std::hint::black_box(Packed34::from_ternary(&qs));
    });
    println!("| pack 1.25-bit (idx+sign planes) | {:.1} | {:.1} |", m.median_s * 1e3, n / m.median_s / 1e6);

    let m = bench("tl2", 1, 5, || {
        std::hint::black_box(PackedTl2::from_ternary(&qd));
    });
    println!("| pack tl2 1.67-bit (bitstream) | {:.1} | {:.1} |", m.median_s * 1e3, n / m.median_s / 1e6);

    let m = bench("i2s", 1, 5, || {
        std::hint::black_box(PackedI2S::from_ternary(&qd));
    });
    println!("| pack i2_s 2-bit | {:.1} | {:.1} |", m.median_s * 1e3, n / m.median_s / 1e6);
}
