//! Serving-loop throughput: coordinator overhead on top of the engine
//! (batching, paged KV leasing, prefix sharing, scheduling). L3 must not
//! be the bottleneck — DESIGN.md §8.
//!
//! Three tables:
//! 1. Serving vs raw single-stream engine (coordinator overhead).
//! 2. Paged-vs-contiguous × shared-prefix sweep: page_size = seq_len is
//!    the degenerate whole-cache (contiguous-equivalent) configuration,
//!    page_size = 16 the paged one; traces with and without a common
//!    system prompt. Emitted to `BENCH_serve_paged.json` so the perf
//!    trajectory captures throughput, admitted concurrency and
//!    prefix-hit rate over time.
//! 3. KV-dtype sweep: f32-vs-int8 × contiguous-vs-paged at one fixed
//!    byte budget — tokens/s, peak KV bytes, bytes/token and dequant
//!    overhead. Emitted to `BENCH_kv_quant.json`.
//! 4. Int8-native attention sweep: int8 shared-prefix serving ×
//!    prefix-sharing × tile-cache on/off vs the f32 sharing baseline —
//!    tokens/s, int8 q·k dot fraction, tile-cache hit rate, prefix hit
//!    rate and dequant overhead. Emitted to `BENCH_int8_attn.json`.
//! 5. Ternary-KV sweep: f32 vs int8 vs 1.25-bit ternary × shared-prefix
//!    on/off at one fixed byte budget — tokens/s, per-dtype K/V
//!    bytes-per-token breakdown, q·k routing fractions (int8 dot vs
//!    ternary LUT walk) and dequant overhead. Emitted to
//!    `BENCH_kv_ternary.json`.
//! 6. Integer a·V sweep: fixed-point V accumulation on/off × {int8,
//!    ternary} pools — tokens/s, int8 a·V rows, residual dequant and
//!    tile traffic. Off is the dequant-per-block legacy path; on (the
//!    default) keeps the whole decode round in integer arithmetic.
//!    Emitted to `BENCH_int8_vpass.json`.
//! 7. SLO serving sweep: chunked-vs-monolithic prefill × priority mix ×
//!    preemption policy under page pressure — per-class p50/p99 TTFT and
//!    inter-token latency, preemption/restore counters. The forced-
//!    preemption leg must actually preempt (asserted). Emitted to
//!    `BENCH_slo_serving.json`.
//!
//! Every record carries its sweep knobs plus the headline figures
//! (tok/s, TTFT p50, inter-token p50/p99) at top level, and the run's
//! complete `Metrics::snapshot()` tree under `"metrics"` — phase
//! breakdown, bounded-histogram percentiles, KV gauges and the flight
//! ring all land in the bench JSON without hand-formatted duplication.
//!
//! Run: `cargo bench --bench serve_throughput`

use sherry::cache::KvDtype;
use sherry::coordinator::{
    serve_trace, BatcherConfig, Metrics, Preemption, Priority, Server, ServerConfig, TraceSpec,
};
use sherry::engine::{random_weights, KvCache, NativeConfig, Scratch, TernaryModel};
use sherry::obs::json::Json;
use sherry::pack::Format;

/// One sweep record: the cell's knobs, the headline latency/throughput
/// figures, and the full metrics snapshot.
fn bench_record(knobs: Json, m: &Metrics) -> Json {
    knobs
        .field("tok_per_s", m.throughput_tps())
        .field("ttft_p50_s", m.ttft_p50())
        .field("itl_p50_s", m.itl_p50())
        .field("itl_p99_s", m.itl_p99())
        .field("metrics", m.snapshot())
}

fn write_bench(path: &str, bench: &str, records: Vec<Json>) {
    let doc = Json::obj().field("bench", bench).field("records", Json::Arr(records));
    match std::fs::write(path, doc.render_pretty()) {
        Ok(()) => println!("[bench] wrote {path}"),
        Err(e) => eprintln!("[bench] could not write {path}: {e}"),
    }
}

fn main() {
    let cfg = NativeConfig::named("nano").unwrap();
    let weights = random_weights(&cfg, 5);
    let model = TernaryModel::build(cfg, &weights, Format::Sherry);

    // raw engine baseline: single-stream decode
    let mut cache = KvCache::new(&cfg);
    let mut scratch = Scratch::default();
    let n = 48usize;
    let t0 = std::time::Instant::now();
    for _ in 0..5 {
        model.generate(&[1, 2, 3], n, &mut cache, &mut scratch);
    }
    let single = 5.0 * (n as f64) / t0.elapsed().as_secs_f64();

    println!("\n### Serving throughput vs raw engine (nano, sherry format)\n");
    println!("| setup | tok/s | vs single-stream | itl p50/p99 |");
    println!("|---|---|---|---|");
    println!("| raw engine single-stream | {single:.1} | 1.00x | - |");

    for (label, active, workers) in [("serve 1-way", 1usize, 1usize), ("serve 4-way", 4, 4), ("serve 8-way", 8, 8)] {
        let server_cfg = ServerConfig {
            batcher: BatcherConfig { max_active: active, token_budget: 100_000, ..Default::default() },
            kv_capacity: active,
            workers,
            ..Default::default()
        };
        let trace = TraceSpec {
            n_requests: 16,
            mean_interarrival_s: 0.0,
            prompt_len: 3,
            shared_prefix_len: 0,
            max_new_tokens: 24,
            seed: 1,
            ..Default::default()
        };
        let (_c, m) = serve_trace(&model, server_cfg, trace);
        println!(
            "| {label} | {:.1} | {:.2}x | {:.4}/{:.4}s |",
            m.throughput_tps(),
            m.throughput_tps() / single,
            m.itl_p50(),
            m.itl_p99(),
        );
    }
    println!("\n(>1x at 4/8-way = batching scales; 1-way ratio shows pure coordinator overhead)");

    paged_sweep(&model, single);
    kv_quant_sweep(&model);
    int8_attn_sweep(&model);
    ternary_kv_sweep(&model);
    int8_vpass_sweep(&model);
    slo_serving_sweep(&model);
}

/// Paged vs contiguous-equivalent KV at a fixed byte budget, with and
/// without a shared system prompt. `page_size = seq_len` makes every
/// sequence reserve one whole cache — the seed's whole-cache pool as a
/// degenerate configuration of the same subsystem — so the comparison
/// isolates paging granularity and prefix reuse.
fn paged_sweep(model: &TernaryModel, single: f64) {
    let seq_len = model.cfg.seq_len;
    // 4 whole-cache equivalents of KV memory, 16 admission slots: the
    // contiguous configuration is capacity-bound at 4-way, the paged one
    // admits by actual page need.
    let kv_capacity = 4usize;
    let trace = |shared: usize| TraceSpec {
        n_requests: 24,
        mean_interarrival_s: 0.0005,
        prompt_len: 18,
        shared_prefix_len: shared,
        max_new_tokens: 16,
        seed: 12,
        ..Default::default()
    };

    println!(
        "\n### Paged vs contiguous KV at fixed byte budget ({kv_capacity} cache-equivalents)\n"
    );
    println!(
        "| kv layout | shared prefix | tok/s | vs single | peak active | hit-rate | block util |"
    );
    println!("|---|---|---|---|---|---|---|");
    let mut records = Vec::new();
    for (layout, page_size, sharing) in [
        ("contiguous", seq_len, false),
        ("paged", 16usize, false),
        ("paged+prefix", 16usize, true),
    ] {
        for shared_len in [0usize, 12] {
            let server_cfg = ServerConfig {
                batcher: BatcherConfig { max_active: 16, token_budget: 100_000, ..Default::default() },
                kv_capacity,
                page_size,
                prefix_sharing: sharing,
                workers: 8,
                ..Default::default()
            };
            let spec = trace(shared_len);
            let (completions, m) = serve_trace(model, server_cfg, spec);
            assert_eq!(completions.len(), spec.n_requests, "sweep must serve everything");
            println!(
                "| {layout} | {shared_len} | {:.1} | {:.2}x | {} | {:.0}% | {:.0}% |",
                m.throughput_tps(),
                m.throughput_tps() / single,
                m.peak_active,
                100.0 * m.prefix_hit_rate(),
                100.0 * m.block_utilization(),
            );
            let knobs = Json::obj()
                .field("layout", layout)
                .field("page_size", page_size)
                .field("prefix_sharing", sharing)
                .field("shared_prefix_len", shared_len);
            records.push(bench_record(knobs, &m));
        }
    }
    println!(
        "\n(paged admits more than the contiguous {kv_capacity}-way cap at the same KV bytes; \
         +prefix skips shared-span prefill)"
    );
    write_bench("BENCH_serve_paged.json", "serve_paged", records);
}

/// f32-vs-int8 KV × contiguous-vs-paged layout at one fixed byte budget
/// (2 f32 whole-cache equivalents). Int8 pages hold the same bytes in
/// ~4× the positions, so the paged+int8 cell admits the most sequences;
/// the dequant-overhead column prices what that costs on the decode
/// path.
fn kv_quant_sweep(model: &TernaryModel) {
    let seq_len = model.cfg.seq_len;
    let kv_capacity = 2usize;
    let spec = TraceSpec {
        n_requests: 24,
        mean_interarrival_s: 0.0005,
        prompt_len: 18,
        shared_prefix_len: 0,
        max_new_tokens: 16,
        seed: 12,
        ..Default::default()
    };

    println!(
        "\n### KV dtype × layout at fixed byte budget ({kv_capacity} f32 cache-equivalents)\n"
    );
    println!(
        "| layout | kv dtype | tok/s | peak active | peak KV MiB | B/token | dequant cpu-s/wall-s |"
    );
    println!("|---|---|---|---|---|---|---|");
    let mut records = Vec::new();
    for (layout, page_size) in [("contiguous", seq_len), ("paged", 16usize)] {
        for dtype in [KvDtype::F32, KvDtype::Int8] {
            let server_cfg = ServerConfig {
                batcher: BatcherConfig { max_active: 16, token_budget: 100_000, ..Default::default() },
                kv_capacity,
                page_size,
                kv_dtype: dtype,
                prefix_sharing: false,
                workers: 8,
                ..Default::default()
            };
            let (completions, m) = serve_trace(model, server_cfg, spec);
            assert_eq!(completions.len(), spec.n_requests, "sweep must serve everything");
            // Peak resident KV bytes = high-water pages × bytes/page.
            let peak_bytes = if m.kv_pages_total == 0 {
                0
            } else {
                m.kv_pages_peak * (m.kv_bytes / m.kv_pages_total)
            };
            println!(
                "| {layout} | {} | {:.1} | {} | {:.3} | {} | {:.3} |",
                dtype.name(),
                m.throughput_tps(),
                m.peak_active,
                peak_bytes as f64 / (1024.0 * 1024.0),
                m.kv_bytes_per_token,
                m.dequant_overhead(),
            );
            let knobs = Json::obj()
                .field("layout", layout)
                .field("page_size", page_size)
                .field("kv_dtype", dtype.name())
                .field("peak_kv_bytes", peak_bytes);
            records.push(bench_record(knobs, &m));
        }
    }
    println!(
        "\n(int8 halves B/token and multiplies admissible pages at the same budget; \
         dequant overhead is the price, amortized per page block)"
    );
    write_bench("BENCH_kv_quant.json", "kv_quant", records);
}

/// Int8-native attention on a shared-system-prompt trace: the score pass
/// runs i32 q·k dots over raw page bytes (no K dequant), and the V pass
/// serves registration-frozen prefix pages from the tile-cache LRU.
/// Sweeps int8 × prefix-sharing × tile-cache against the f32 sharing
/// baseline at the same byte budget; tokens are invariant across every
/// cell's sharing/cache knobs by construction (asserted in tests), so
/// the sweep isolates the speed/footprint trade.
fn int8_attn_sweep(model: &TernaryModel) {
    let kv_capacity = 4usize;
    let spec = TraceSpec {
        n_requests: 24,
        mean_interarrival_s: 0.0005,
        prompt_len: 18,
        shared_prefix_len: 12,
        max_new_tokens: 16,
        seed: 12,
        ..Default::default()
    };

    println!("\n### Int8-native attention × prefix sharing × tile cache (shared prompt)\n");
    println!(
        "| kv dtype | sharing | tile cache | tok/s | int8 q·k | tile hits | prefix hit-rate | dequant cpu-s/wall-s |"
    );
    println!("|---|---|---|---|---|---|---|---|");
    let mut records = Vec::new();
    for (dtype, sharing, tiles) in [
        (KvDtype::F32, true, 0usize),
        (KvDtype::Int8, false, 0),
        (KvDtype::Int8, true, 0),
        (KvDtype::Int8, true, 64),
    ] {
        let server_cfg = ServerConfig {
            batcher: BatcherConfig { max_active: 16, token_budget: 100_000, ..Default::default() },
            kv_capacity,
            page_size: 4,
            kv_dtype: dtype,
            prefix_sharing: sharing,
            tile_cache_tiles: tiles,
            workers: 8,
            ..Default::default()
        };
        let (completions, m) = serve_trace(model, server_cfg, spec);
        assert_eq!(completions.len(), spec.n_requests, "sweep must serve everything");
        println!(
            "| {} | {} | {} | {:.1} | {:.0}% | {:.0}% | {:.0}% | {:.3} |",
            dtype.name(),
            sharing,
            tiles,
            m.throughput_tps(),
            100.0 * m.int8_dot_fraction(),
            100.0 * m.tile_cache_hit_rate(),
            100.0 * m.prefix_hit_rate(),
            m.dequant_overhead(),
        );
        let knobs = Json::obj()
            .field("kv_dtype", dtype.name())
            .field("prefix_sharing", sharing)
            .field("tile_cache_tiles", tiles);
        records.push(bench_record(knobs, &m));
    }
    println!(
        "\n(int8 rows dot natively — dequant now prices only the V pass; \
         the tile cache amortizes shared-prefix V tiles across sequences)"
    );
    write_bench("BENCH_int8_attn.json", "int8_attn", records);
}

/// All three KV dtypes head-to-head at one fixed byte budget (2 f32
/// whole-cache equivalents), with and without a shared system prompt.
/// Ternary packs K at 1.25 bits/channel (V stays int8), so the same
/// budget buys the most pages; the score pass routes per storage dtype —
/// i32 dots for int8, per-query LUT walks for ternary — and the K/V
/// bytes-per-token breakdown shows exactly where the footprint went.
fn ternary_kv_sweep(model: &TernaryModel) {
    let kv_capacity = 2usize;
    let trace = |shared: usize| TraceSpec {
        n_requests: 24,
        mean_interarrival_s: 0.0005,
        prompt_len: 18,
        shared_prefix_len: shared,
        max_new_tokens: 16,
        seed: 12,
        ..Default::default()
    };

    println!(
        "\n### KV dtype sweep incl. 1.25-bit ternary ({kv_capacity} f32 cache-equivalents)\n"
    );
    println!(
        "| kv dtype | shared prefix | tok/s | peak active | B/token (K+V) | int8 q·k | ternary q·k | prefix hit-rate | dequant cpu-s/wall-s |"
    );
    println!("|---|---|---|---|---|---|---|---|---|");
    let mut records = Vec::new();
    for dtype in KvDtype::ALL {
        for shared_len in [0usize, 12] {
            let server_cfg = ServerConfig {
                batcher: BatcherConfig { max_active: 16, token_budget: 100_000, ..Default::default() },
                kv_capacity,
                page_size: 4,
                kv_dtype: dtype,
                prefix_sharing: shared_len > 0,
                workers: 8,
                ..Default::default()
            };
            let spec = trace(shared_len);
            let (completions, m) = serve_trace(model, server_cfg, spec);
            assert_eq!(completions.len(), spec.n_requests, "sweep must serve everything");
            println!(
                "| {} | {shared_len} | {:.1} | {} | {} ({}+{}) | {:.0}% | {:.0}% | {:.0}% | {:.3} |",
                dtype.name(),
                m.throughput_tps(),
                m.peak_active,
                m.kv_bytes_per_token,
                m.kv_bytes_per_token_k,
                m.kv_bytes_per_token_v,
                100.0 * m.int8_dot_fraction(),
                100.0 * m.ternary_dot_fraction(),
                100.0 * m.prefix_hit_rate(),
                m.dequant_overhead(),
            );
            let knobs = Json::obj()
                .field("kv_dtype", dtype.name())
                .field("shared_prefix_len", shared_len);
            records.push(bench_record(knobs, &m));
        }
    }
    println!(
        "\n(ternary K is 1.25 bits/channel — the budget buys the most pages; \
         its q·k rows never dequantize K, they walk per-query LUTs over packed codes)"
    );
    write_bench("BENCH_kv_ternary.json", "kv_ternary", records);
}

/// The integer-a·V knob isolated: the same shared-prefix trace through
/// int8 and ternary pools with the fixed-point a·V pass on (default)
/// and off (legacy dequant-per-block V). On, a decode round touches no
/// f32 K or V page bytes — `av_rows_int8` meters every V row and the
/// residual dequant gauge stays 0; off, the V pass dequantizes into
/// scratch/tiles and the dequant and tile columns price it.
fn int8_vpass_sweep(model: &TernaryModel) {
    let kv_capacity = 2usize;
    let spec = TraceSpec {
        n_requests: 24,
        mean_interarrival_s: 0.0005,
        prompt_len: 18,
        shared_prefix_len: 12,
        max_new_tokens: 16,
        seed: 12,
        ..Default::default()
    };

    println!("\n### Integer a·V accumulation on/off × quantized KV dtype (shared prompt)\n");
    println!(
        "| kv dtype | integer a·V | tok/s | int8 a·V rows | tile hits | dequant cpu-s/wall-s |"
    );
    println!("|---|---|---|---|---|---|");
    let mut records = Vec::new();
    for dtype in [KvDtype::Int8, KvDtype::Ternary] {
        for integer_av in [true, false] {
            let server_cfg = ServerConfig {
                batcher: BatcherConfig { max_active: 16, token_budget: 100_000, ..Default::default() },
                kv_capacity,
                page_size: 4,
                kv_dtype: dtype,
                prefix_sharing: true,
                integer_av,
                workers: 8,
                ..Default::default()
            };
            let (completions, m) = serve_trace(model, server_cfg, spec);
            assert_eq!(completions.len(), spec.n_requests, "sweep must serve everything");
            println!(
                "| {} | {integer_av} | {:.1} | {} | {} | {:.3} |",
                dtype.name(),
                m.throughput_tps(),
                m.kv_av_rows_int8,
                m.kv_tile_hits,
                m.dequant_overhead(),
            );
            let knobs = Json::obj()
                .field("kv_dtype", dtype.name())
                .field("integer_av", integer_av);
            records.push(bench_record(knobs, &m));
        }
    }
    println!(
        "\n(on = softmax weights quantize to u8 fixed point and a·V accumulates in i32 over raw \
         int8 V bytes — zero hot-path dequant; off = the legacy f32 V walk with tile/scratch fills)"
    );
    write_bench("BENCH_int8_vpass.json", "int8_vpass", records);
}

/// SLO scheduling head-to-head: monolithic vs chunked prefill ×
/// Interactive/Batch mix × preemption policy on a page-tight arena.
/// Tokens per request are invariant across every cell by the scheduling
/// contract (pinned in `tests/scheduling.rs`); the sweep prices what
/// each policy does to the per-class tail — chunking bounds the decode
/// stall a new prompt injects, preemption moves the Batch class out of
/// an Interactive arrival's way at a restore-prefill cost.
fn slo_serving_sweep(model: &TernaryModel) {
    let kv_capacity = 2usize;
    let page_size = 4usize;
    let trace = |batch_fraction: f64| TraceSpec {
        n_requests: 24,
        mean_interarrival_s: 0.0005,
        prompt_len: 18,
        shared_prefix_len: 0,
        max_new_tokens: 16,
        seed: 12,
        batch_fraction,
        ..Default::default()
    };

    println!("\n### SLO scheduling: chunked prefill × priority mix × preemption\n");
    println!(
        "| prefill | preemption | batch mix | tok/s | int ttft p50/p99 | int itl p50/p99 | bat ttft p50/p99 | preempts | restored tok |"
    );
    println!("|---|---|---|---|---|---|---|---|---|");
    let mut records = Vec::new();
    for (label, chunk, policy) in [
        ("monolithic", 0usize, Preemption::Never),
        ("chunked", page_size, Preemption::Never),
        ("chunked+preempt", page_size, Preemption::Always),
    ] {
        for batch_fraction in [0.0f64, 0.5] {
            let server_cfg = ServerConfig {
                batcher: BatcherConfig { max_active: 4, token_budget: 100_000, ..Default::default() },
                kv_capacity,
                page_size,
                prefill_chunk_tokens: chunk,
                preemption: policy,
                workers: 4,
                ..Default::default()
            };
            let spec = trace(batch_fraction);
            let (completions, m) = serve_trace(model, server_cfg, spec);
            assert_eq!(completions.len(), spec.n_requests, "sweep must serve everything");
            let it = Priority::Interactive.index();
            let bt = Priority::Batch.index();
            println!(
                "| {label} | {} | {batch_fraction} | {:.1} | {:.3}/{:.3}s | {:.4}/{:.4}s | {:.3}/{:.3}s | {} | {} |",
                policy.name(),
                m.throughput_tps(),
                m.ttft_class[it].p50(),
                m.ttft_class[it].p99(),
                m.itl_class[it].p50(),
                m.itl_class[it].p99(),
                m.ttft_class[bt].p50(),
                m.ttft_class[bt].p99(),
                m.preemptions,
                m.restored_tokens,
            );
            let knobs = Json::obj()
                .field("prefill_chunk_tokens", chunk)
                .field("preemption", policy.name())
                .field("batch_fraction", batch_fraction)
                .field("ttft_p50_interactive_s", m.ttft_class[it].p50())
                .field("ttft_p99_interactive_s", m.ttft_class[it].p99())
                .field("itl_p50_interactive_s", m.itl_class[it].p50())
                .field("itl_p99_interactive_s", m.itl_class[it].p99())
                .field("ttft_p50_batch_s", m.ttft_class[bt].p50())
                .field("ttft_p99_batch_s", m.ttft_class[bt].p99())
                .field("itl_p50_batch_s", m.itl_class[bt].p50())
                .field("itl_p99_batch_s", m.itl_class[bt].p99())
                .field("preemptions", m.preemptions)
                .field("restored_tokens", m.restored_tokens);
            records.push(bench_record(knobs, &m));
        }
    }
    // Dedicated pressure leg. The matrix cells above share one Poisson
    // trace, so whether an Interactive arrival actually catches a Batch
    // request mid-decode depends on host speed. Here the backlog is
    // shaped by hand — every Batch request arrives at t=0 with a long
    // token allowance, Interactive requests land while that backlog is
    // still decoding — so preemption fires on any host.
    let server_cfg = ServerConfig {
        batcher: BatcherConfig { max_active: 4, token_budget: 100_000, ..Default::default() },
        kv_capacity,
        page_size,
        prefill_chunk_tokens: page_size,
        preemption: Preemption::Always,
        workers: 4,
        ..Default::default()
    };
    let mut reqs = trace(0.5).generate(model.cfg.vocab_size);
    for r in &mut reqs {
        match r.priority {
            Priority::Batch => {
                r.arrival = 0.0;
                r.max_new_tokens = 40;
            }
            // The Batch backlog above is hundreds of engine rounds; the
            // first Interactive arrival lands ~0.5 ms in, far before the
            // backlog can drain on any host.
            Priority::Interactive => r.arrival = 0.0005 + 0.0005 * r.id as f64,
        }
    }
    let n = reqs.len();
    let (completions, m) = Server::new(model, server_cfg).run(reqs);
    assert_eq!(completions.len(), n, "pressure leg must serve everything");
    assert!(m.preemptions > 0, "pressure leg must preempt");
    let it = Priority::Interactive.index();
    let bt = Priority::Batch.index();
    println!(
        "| pressure (batch backlog) | always | 0.5 | {:.1} | {:.3}/{:.3}s | {:.4}/{:.4}s | {:.3}/{:.3}s | {} | {} |",
        m.throughput_tps(),
        m.ttft_class[it].p50(),
        m.ttft_class[it].p99(),
        m.itl_class[it].p50(),
        m.itl_class[it].p99(),
        m.ttft_class[bt].p50(),
        m.ttft_class[bt].p99(),
        m.preemptions,
        m.restored_tokens,
    );
    let knobs = Json::obj()
        .field("leg", "pressure")
        .field("prefill_chunk_tokens", page_size)
        .field("preemption", Preemption::Always.name())
        .field("batch_fraction", 0.5)
        .field("ttft_p50_interactive_s", m.ttft_class[it].p50())
        .field("ttft_p99_interactive_s", m.ttft_class[it].p99())
        .field("itl_p50_interactive_s", m.itl_class[it].p50())
        .field("itl_p99_interactive_s", m.itl_class[it].p99())
        .field("ttft_p50_batch_s", m.ttft_class[bt].p50())
        .field("ttft_p99_batch_s", m.ttft_class[bt].p99())
        .field("itl_p50_batch_s", m.itl_class[bt].p50())
        .field("itl_p99_batch_s", m.itl_class[bt].p99())
        .field("preemptions", m.preemptions)
        .field("restored_tokens", m.restored_tokens);
    records.push(bench_record(knobs, &m));
    println!(
        "\n(matrix cells share seeds and completions — the scheduling contract; the pressure \
         leg shapes a batch backlog by hand so the preempt counters are live on any host)"
    );
    write_bench("BENCH_slo_serving.json", "slo_serving", records);
}
