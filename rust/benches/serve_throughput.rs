//! Serving-loop throughput: coordinator overhead on top of the engine
//! (batching, KV pool, scheduling). L3 must not be the bottleneck —
//! DESIGN.md §6.
//!
//! Run: `cargo bench --bench serve_throughput`

use sherry::coordinator::{serve_trace, BatcherConfig, ServerConfig, TraceSpec};
use sherry::engine::{random_weights, KvCache, NativeConfig, Scratch, TernaryModel};
use sherry::pack::Format;

fn main() {
    let cfg = NativeConfig::named("nano").unwrap();
    let weights = random_weights(&cfg, 5);
    let model = TernaryModel::build(cfg, &weights, Format::Sherry);

    // raw engine baseline: single-stream decode
    let mut cache = KvCache::new(&cfg);
    let mut scratch = Scratch::default();
    let n = 48usize;
    let t0 = std::time::Instant::now();
    for _ in 0..5 {
        model.generate(&[1, 2, 3], n, &mut cache, &mut scratch);
    }
    let single = 5.0 * (n as f64) / t0.elapsed().as_secs_f64();

    println!("\n### Serving throughput vs raw engine (nano, sherry format)\n");
    println!("| setup | tok/s | vs single-stream |");
    println!("|---|---|---|");
    println!("| raw engine single-stream | {single:.1} | 1.00x |");

    for (label, active, workers) in [("serve 1-way", 1usize, 1usize), ("serve 4-way", 4, 4), ("serve 8-way", 8, 8)] {
        let server_cfg = ServerConfig {
            batcher: BatcherConfig { max_active: active, token_budget: 100_000 },
            kv_capacity: active,
            workers,
        };
        let trace = TraceSpec {
            n_requests: 16,
            mean_interarrival_s: 0.0,
            prompt_len: 3,
            max_new_tokens: 24,
            seed: 1,
        };
        let (_c, m) = serve_trace(&model, server_cfg, trace);
        println!("| {label} | {:.1} | {:.2}x |", m.throughput_tps(), m.throughput_tps() / single);
    }
    println!("\n(>1x at 4/8-way = batching scales; 1-way ratio shows pure coordinator overhead)");
}
