//! Artifact manifest + parameter ABI parsing (`manifest.tsv`,
//! `{cfg}.params.tsv` — written by `python/compile/aot.py`).

use anyhow::{Context, Result};
use std::path::Path;

/// One artifact row from `manifest.tsv`.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub stem: String,
    pub kind: String,
    pub config: String,
    pub method: String,
    pub granularity: String,
    pub path: String,
    pub n_params: usize,
    pub batch: Option<usize>,
}

/// Parsed artifact manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub entries: Vec<ArtifactEntry>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read manifest {}", path.display()))?;
        let mut entries = Vec::new();
        for (i, line) in text.lines().enumerate() {
            if i == 0 || line.trim().is_empty() {
                continue; // header
            }
            let cols: Vec<&str> = line.split('\t').collect();
            anyhow::ensure!(cols.len() >= 8, "manifest line {i} malformed: {line}");
            entries.push(ArtifactEntry {
                stem: cols[0].to_string(),
                kind: cols[1].to_string(),
                config: cols[2].to_string(),
                method: cols[3].to_string(),
                granularity: cols[4].to_string(),
                path: cols[5].to_string(),
                n_params: cols[6].parse().unwrap_or(0),
                batch: cols[7].parse().ok(),
            });
        }
        Ok(Self { entries })
    }

    /// Find an artifact by (config, method, granularity, kind).
    pub fn find(&self, config: &str, method: &str, granularity: &str, kind: &str) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| {
            e.config == config && e.method == method && e.granularity == granularity && e.kind == kind
        })
    }
}

/// Ordered parameter ABI from `{cfg}.params.tsv`: (name, shape).
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub entries: Vec<(String, Vec<usize>)>,
}

impl ParamSpec {
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read param spec {}", path.display()))?;
        let mut entries = Vec::new();
        for line in text.lines() {
            if line.trim().is_empty() {
                continue;
            }
            let (name, shape_s) = line.split_once('\t').context("param line malformed")?;
            let shape: Vec<usize> = shape_s
                .split(',')
                .map(|d| d.parse().context("bad dim"))
                .collect::<Result<_>>()?;
            entries.push((name.to_string(), shape));
        }
        Ok(Self { entries })
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Total scalar count across all params.
    pub fn total_elems(&self) -> usize {
        self.entries.iter().map(|(_, s)| s.iter().product::<usize>()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_text() {
        let dir = std::env::temp_dir().join("sherry_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("manifest.tsv");
        std::fs::write(
            &p,
            "stem\tkind\tconfig\tmethod\tgranularity\tpath\tn_params\tbatch\n\
             nano_x_y\ttrain\tnano\tx\ty\tnano_x_y.train.hlo.txt\t35\t16\n\
             kern\tkernel\t-\t-\t-\tk.hlo.txt\t1\t-\n",
        )
        .unwrap();
        let m = Manifest::load(&p).unwrap();
        assert_eq!(m.entries.len(), 2);
        assert_eq!(m.entries[0].n_params, 35);
        assert_eq!(m.entries[0].batch, Some(16));
        assert_eq!(m.entries[1].batch, None);
        assert!(m.find("nano", "x", "y", "train").is_some());
        assert!(m.find("nano", "x", "y", "fwd").is_none());
    }

    #[test]
    fn parses_param_spec() {
        let dir = std::env::temp_dir().join("sherry_pspec_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("nano.params.tsv");
        std::fs::write(&p, "embed\t256,128\nlayer0.norm_attn\t128\n").unwrap();
        let s = ParamSpec::load(&p).unwrap();
        assert_eq!(s.len(), 2);
        assert_eq!(s.entries[0].1, vec![256, 128]);
        assert_eq!(s.total_elems(), 256 * 128 + 128);
    }

    #[test]
    fn real_param_spec_if_built() {
        let p = crate::test_artifacts_dir().join("nano.params.tsv");
        if !p.exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let s = ParamSpec::load(&p).unwrap();
        assert_eq!(s.entries[0].0, "embed");
        assert_eq!(s.len(), 35);
    }
}
