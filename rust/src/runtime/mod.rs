//! PJRT runtime: loads the HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`): the
//! crate's xla_extension 0.5.1 rejects jax≥0.5 serialized protos
//! (64-bit instruction ids); the text parser reassigns ids. See
//! `/opt/xla-example/README.md` and DESIGN.md.
//!
//! The XLA closure is an out-of-tree vendored dependency, so the real
//! backend is gated behind the `pjrt` cargo feature. Without it this
//! module compiles as an API-identical stub whose constructor returns an
//! error; every artifact-dependent caller already skips gracefully when
//! the runtime (or the artifacts) are unavailable.

mod manifest;

pub use manifest::{ArtifactEntry, Manifest, ParamSpec};

use anyhow::Result;
use std::path::{Path, PathBuf};

#[cfg(feature = "pjrt")]
use anyhow::Context;
#[cfg(feature = "pjrt")]
use std::collections::HashMap;
#[cfg(feature = "pjrt")]
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

// ---------------------------------------------------------------------------
// Real backend (feature = "pjrt")
// ---------------------------------------------------------------------------

/// A PJRT client plus a compile cache of loaded artifacts.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    client: PjRtClient,
    dir: PathBuf,
    cache: HashMap<String, PjRtLoadedExecutable>,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// CPU-backed runtime rooted at the artifacts directory.
    pub fn cpu(artifacts_dir: &Path) -> Result<Self> {
        let client = PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Self { client, dir: artifacts_dir.to_path_buf(), cache: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.dir
    }

    /// Load + compile an HLO-text artifact (cached by relative path).
    pub fn load(&mut self, rel_path: &str) -> Result<&PjRtLoadedExecutable> {
        if !self.cache.contains_key(rel_path) {
            let full = self.dir.join(rel_path);
            let proto = HloModuleProto::from_text_file(
                full.to_str().context("non-utf8 artifact path")?,
            )
            .with_context(|| format!("parse HLO text {}", full.display()))?;
            let comp = XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compile {}", full.display()))?;
            self.cache.insert(rel_path.to_string(), exe);
        }
        Ok(&self.cache[rel_path])
    }

    /// Execute a loaded artifact on literals; returns the flattened tuple
    /// elements (aot.py lowers with `return_tuple=True`).
    pub fn run(&mut self, rel_path: &str, inputs: &[Literal]) -> Result<Vec<Literal>> {
        let exe = self.load(rel_path)?;
        let mut result = exe.execute::<Literal>(inputs)?[0][0].to_literal_sync()?;
        Ok(result.decompose_tuple()?)
    }

    /// Read the artifact manifest.
    pub fn manifest(&self) -> Result<Manifest> {
        Manifest::load(&self.dir.join("manifest.tsv"))
    }
}

/// Build an f32 literal of `shape` from a slice.
#[cfg(feature = "pjrt")]
pub fn literal_f32(data: &[f32], shape: &[usize]) -> Result<Literal> {
    let n: usize = shape.iter().product();
    anyhow::ensure!(n == data.len(), "shape/product mismatch");
    let flat = Literal::vec1(data);
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(flat.reshape(&dims)?)
}

/// Build an i32 literal of `shape` from a slice.
#[cfg(feature = "pjrt")]
pub fn literal_i32(data: &[i32], shape: &[usize]) -> Result<Literal> {
    let n: usize = shape.iter().product();
    anyhow::ensure!(n == data.len(), "shape/product mismatch");
    let flat = Literal::vec1(data);
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(flat.reshape(&dims)?)
}

/// Scalar literals.
#[cfg(feature = "pjrt")]
pub fn scalar_f32(x: f32) -> Literal {
    Literal::scalar(x)
}

#[cfg(feature = "pjrt")]
pub fn scalar_i32(x: i32) -> Literal {
    Literal::scalar(x)
}

/// Extract an f32 vector from a literal.
#[cfg(feature = "pjrt")]
pub fn to_vec_f32(lit: &Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

// ---------------------------------------------------------------------------
// Stub backend (default build; no XLA closure available)
// ---------------------------------------------------------------------------

/// Opaque stand-in for an XLA literal in stub builds.
#[cfg(not(feature = "pjrt"))]
#[derive(Clone, Debug, Default)]
pub struct Literal;

/// Stub runtime: carries the artifacts directory so path plumbing still
/// works, but construction fails with a clear message.
#[cfg(not(feature = "pjrt"))]
pub struct Runtime {
    dir: PathBuf,
}

#[cfg(not(feature = "pjrt"))]
const STUB_MSG: &str =
    "PJRT backend unavailable: build with `--features pjrt` (requires the vendored xla crate)";

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    /// Always errors in stub builds; callers treat it like missing
    /// artifacts and skip.
    pub fn cpu(artifacts_dir: &Path) -> Result<Self> {
        let _ = artifacts_dir;
        Err(anyhow::anyhow!("{STUB_MSG}"))
    }

    pub fn platform(&self) -> String {
        "stub".to_string()
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.dir
    }

    pub fn load(&mut self, rel_path: &str) -> Result<&Literal> {
        let _ = rel_path;
        Err(anyhow::anyhow!("{STUB_MSG}"))
    }

    pub fn run(&mut self, rel_path: &str, inputs: &[Literal]) -> Result<Vec<Literal>> {
        let _ = (rel_path, inputs);
        Err(anyhow::anyhow!("{STUB_MSG}"))
    }

    pub fn manifest(&self) -> Result<Manifest> {
        Manifest::load(&self.dir.join("manifest.tsv"))
    }
}

#[cfg(not(feature = "pjrt"))]
pub fn literal_f32(data: &[f32], shape: &[usize]) -> Result<Literal> {
    let n: usize = shape.iter().product();
    anyhow::ensure!(n == data.len(), "shape/product mismatch");
    Ok(Literal)
}

#[cfg(not(feature = "pjrt"))]
pub fn literal_i32(data: &[i32], shape: &[usize]) -> Result<Literal> {
    let n: usize = shape.iter().product();
    anyhow::ensure!(n == data.len(), "shape/product mismatch");
    Ok(Literal)
}

#[cfg(not(feature = "pjrt"))]
pub fn scalar_f32(_x: f32) -> Literal {
    Literal
}

#[cfg(not(feature = "pjrt"))]
pub fn scalar_i32(_x: i32) -> Literal {
    Literal
}

#[cfg(not(feature = "pjrt"))]
pub fn to_vec_f32(_lit: &Literal) -> Result<Vec<f32>> {
    Err(anyhow::anyhow!("{STUB_MSG}"))
}

#[cfg(all(test, feature = "pjrt"))]
mod tests {
    use super::*;

    fn runtime() -> Option<Runtime> {
        let dir = crate::test_artifacts_dir();
        if !dir.join("manifest.tsv").exists() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return None;
        }
        Some(Runtime::cpu(&dir).unwrap())
    }

    #[test]
    fn kernel_quantize34_roundtrip() {
        // The standalone Pallas quantize34 kernel, AOT-lowered, must match
        // the native Rust quantizer on the same input.
        let Some(mut rt) = runtime() else { return };
        let mut rng = crate::util::Pcg64::seeded(0);
        let w = crate::tensor::Mat::randn(&mut rng, 512, 256, 1.0);
        let lit = literal_f32(&w.data, &[512, 256]).unwrap();
        let out = rt.run("kernel_quantize34.hlo.txt", &[lit]).unwrap();
        assert_eq!(out.len(), 2);
        let t = to_vec_f32(&out[0]).unwrap();
        let alpha = to_vec_f32(&out[1]).unwrap();
        let q = crate::quant::sherry34_quantize(&w, crate::quant::Granularity::PerChannel);
        for (i, (&pj, &rs)) in t.iter().zip(q.t.iter()).enumerate() {
            assert_eq!(pj, rs as f32, "T mismatch at {i}");
        }
        for (j, (&pj, &rs)) in alpha.iter().zip(q.alpha.iter()).enumerate() {
            assert!((pj - rs).abs() < 1e-5, "alpha mismatch at {j}");
        }
    }

    #[test]
    fn kernel_ternary_matmul_matches_native_lut() {
        let Some(mut rt) = runtime() else { return };
        let mut rng = crate::util::Pcg64::seeded(1);
        let w = crate::tensor::Mat::randn(&mut rng, 512, 256, 1.0);
        let q = crate::quant::sherry34_quantize(&w, crate::quant::Granularity::PerChannel);
        let x: Vec<f32> = rng.normal_vec(16 * 512);
        let t_f32: Vec<f32> = q.t.iter().map(|&v| v as f32).collect();
        let out = rt
            .run(
                "kernel_ternary_matmul.hlo.txt",
                &[
                    literal_f32(&x, &[16, 512]).unwrap(),
                    literal_f32(&t_f32, &[512, 256]).unwrap(),
                    literal_f32(&q.alpha, &[256]).unwrap(),
                ],
            )
            .unwrap();
        let y_pjrt = to_vec_f32(&out[0]).unwrap();
        // native LUT engine on the same rows
        let p = crate::pack::Packed34::from_ternary(&q);
        let mut luts = vec![0.0; (512 / 4) * 16];
        let mut y = vec![0.0; 256];
        for r in 0..16 {
            crate::engine::lut::gemv_pack34(&p, &x[r * 512..(r + 1) * 512], &mut luts, &mut y);
            for j in 0..256 {
                let pj = y_pjrt[r * 256 + j];
                assert!(
                    (pj - y[j]).abs() < 1e-3 * (1.0 + pj.abs()),
                    "row {r} col {j}: pjrt {pj} vs native {}",
                    y[j]
                );
            }
        }
    }

    #[test]
    fn manifest_lists_artifacts() {
        let Some(rt) = runtime() else { return };
        let m = rt.manifest().unwrap();
        assert!(m.entries.len() >= 8);
        assert!(m.find("nano", "sherry34", "per_channel", "train").is_some());
    }
}

#[cfg(all(test, not(feature = "pjrt")))]
mod stub_tests {
    use super::*;

    #[test]
    fn stub_runtime_errors_clearly() {
        let err = Runtime::cpu(Path::new("/tmp")).err().unwrap();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }

    #[test]
    fn stub_literals_still_shape_check() {
        assert!(literal_f32(&[1.0, 2.0], &[2]).is_ok());
        assert!(literal_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(literal_i32(&[1, 2, 3, 4], &[2, 2]).is_ok());
    }
}
