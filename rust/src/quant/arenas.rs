//! Arenas λ_t annealing schedules (paper §3.2, Eq. 23-25, Fig. 7).
//!
//! λ_t gates the residual synapse Y = X·Tα + λ_t·X·W. All schedules decay
//! 1 → 0 over training progress p ∈ [0, 1]; warmup variants ramp 0 → 1
//! over the first `warmup` fraction first (Fig. 8 shows warmup helps every
//! decay shape).

/// Annealing schedule for the residual-synapse gate.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Schedule {
    /// λ = 0 always — Arenas disabled (the "naive" ablation arm).
    Off,
    /// λ = 1 − p (Eq. 23).
    Linear,
    /// λ = ½(1 + cos πp) (Eq. 24).
    Cosine,
    /// λ = exp(−5p) (Eq. 25).
    Exponential,
    LinearWarmup,
    /// The paper's default (§4.1).
    CosineWarmup,
    ExponentialWarmup,
}

/// Warmup fraction used by the *Warmup variants.
pub const WARMUP_FRAC: f32 = 0.1;

impl Schedule {
    pub const ALL: [Schedule; 7] = [
        Schedule::Off,
        Schedule::Linear,
        Schedule::Cosine,
        Schedule::Exponential,
        Schedule::LinearWarmup,
        Schedule::CosineWarmup,
        Schedule::ExponentialWarmup,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Schedule::Off => "off",
            Schedule::Linear => "linear",
            Schedule::Cosine => "cosine",
            Schedule::Exponential => "exponential",
            Schedule::LinearWarmup => "linear_warmup",
            Schedule::CosineWarmup => "cosine_warmup",
            Schedule::ExponentialWarmup => "exponential_warmup",
        }
    }

    pub fn parse(s: &str) -> Option<Schedule> {
        Schedule::ALL.iter().copied().find(|x| x.name() == s)
    }
}

fn base(s: Schedule, p: f32) -> f32 {
    match s {
        Schedule::Off => 0.0,
        Schedule::Linear | Schedule::LinearWarmup => 1.0 - p,
        Schedule::Cosine | Schedule::CosineWarmup => 0.5 * (1.0 + (std::f32::consts::PI * p).cos()),
        Schedule::Exponential | Schedule::ExponentialWarmup => (-5.0 * p).exp(),
    }
}

/// λ_t at training progress `p` ∈ [0, 1] (clamped).
pub fn lambda_at(schedule: Schedule, p: f32) -> f32 {
    let p = p.clamp(0.0, 1.0);
    match schedule {
        Schedule::Off => 0.0,
        Schedule::Linear | Schedule::Cosine | Schedule::Exponential => base(schedule, p),
        Schedule::LinearWarmup | Schedule::CosineWarmup | Schedule::ExponentialWarmup => {
            if p < WARMUP_FRAC {
                p / WARMUP_FRAC
            } else {
                let rest = (p - WARMUP_FRAC) / (1.0 - WARMUP_FRAC);
                base(schedule, rest.clamp(0.0, 1.0))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn non_warmup_start_at_one_end_near_zero() {
        for s in [Schedule::Linear, Schedule::Cosine, Schedule::Exponential] {
            assert!((lambda_at(s, 0.0) - 1.0).abs() < 1e-6, "{s:?}");
            assert!(lambda_at(s, 1.0) < 0.01, "{s:?}");
        }
    }

    #[test]
    fn warmup_ramps_from_zero() {
        for s in [Schedule::LinearWarmup, Schedule::CosineWarmup, Schedule::ExponentialWarmup] {
            assert_eq!(lambda_at(s, 0.0), 0.0, "{s:?}");
            assert!((lambda_at(s, WARMUP_FRAC) - 1.0).abs() < 1e-5, "{s:?}");
            assert!(lambda_at(s, 1.0) < 0.01, "{s:?}");
        }
    }

    #[test]
    fn monotone_decay_after_warmup() {
        for s in Schedule::ALL {
            let mut prev = f32::INFINITY;
            for k in 0..=40 {
                let p = WARMUP_FRAC + (1.0 - WARMUP_FRAC) * k as f32 / 40.0;
                let l = lambda_at(s, p);
                assert!(l <= prev + 1e-6, "{s:?} not monotone at p={p}");
                prev = l;
            }
        }
    }

    #[test]
    fn off_is_identically_zero() {
        for k in 0..=10 {
            assert_eq!(lambda_at(Schedule::Off, k as f32 / 10.0), 0.0);
        }
    }

    #[test]
    fn cosine_midpoint_is_half() {
        assert!((lambda_at(Schedule::Cosine, 0.5) - 0.5).abs() < 1e-6);
    }

    #[test]
    fn clamps_out_of_range_progress() {
        assert_eq!(lambda_at(Schedule::Linear, -1.0), 1.0);
        assert_eq!(lambda_at(Schedule::Linear, 2.0), 0.0);
    }

    #[test]
    fn parse_roundtrip() {
        for s in Schedule::ALL {
            assert_eq!(Schedule::parse(s.name()), Some(s));
        }
    }
}
