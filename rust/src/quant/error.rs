//! Quantization-error analytics: the numbers a practitioner checks before
//! deploying a quantized model (per-layer reconstruction error, SNR,
//! angular distortion, sparsity), and the output-error propagation bound
//! used to sanity-check the Eq. 3 objective against actual activations.

use super::{quantize, Granularity, Method, Ternary};
use crate::tensor::{matmul, Mat};

/// Error report for one weight matrix under one quantizer.
#[derive(Clone, Debug)]
pub struct ErrorReport {
    pub method: Method,
    pub granularity: Granularity,
    /// ‖W − Tα‖²_F (the paper's Eq. 3 objective value).
    pub l2_error: f32,
    /// Relative error ‖W − Tα‖_F / ‖W‖_F.
    pub rel_error: f32,
    /// Quantization SNR in dB: 20·log10(‖W‖/‖W−Tα‖).
    pub snr_db: f32,
    /// Mean per-column cosine similarity between W and Tα columns.
    pub cos_sim: f32,
    /// Fraction of zero entries in T.
    pub sparsity: f32,
}

/// Analyze `w` under `method`/`granularity`.
pub fn analyze(w: &Mat, method: Method, granularity: Granularity) -> ErrorReport {
    let q = quantize(w, method, granularity);
    analyze_quantized(w, &q, method)
}

/// Analyze a pre-quantized pair.
pub fn analyze_quantized(w: &Mat, q: &Ternary, method: Method) -> ErrorReport {
    let deq = q.dequant();
    let err = w.sq_err(&deq);
    let wn = w.frob();
    let en = err.sqrt();
    let mut cos_total = 0.0f64;
    let mut cols = 0usize;
    for j in 0..w.cols {
        let (mut dot, mut na, mut nb) = (0.0f64, 0.0f64, 0.0f64);
        for i in 0..w.rows {
            let a = w.at(i, j) as f64;
            let b = deq.at(i, j) as f64;
            dot += a * b;
            na += a * a;
            nb += b * b;
        }
        if na > 0.0 && nb > 0.0 {
            cos_total += dot / (na.sqrt() * nb.sqrt());
            cols += 1;
        }
    }
    ErrorReport {
        method,
        granularity: q.granularity,
        l2_error: err,
        rel_error: if wn > 0.0 { en / wn } else { 0.0 },
        snr_db: if en > 0.0 { 20.0 * (wn / en).log10() } else { f32::INFINITY },
        cos_sim: if cols > 0 { (cos_total / cols as f64) as f32 } else { 0.0 },
        sparsity: q.sparsity(),
    }
}

/// Measured output error ‖X(W − Tα)‖_F / ‖XW‖_F on a probe batch —
/// the quantity the weight-space objective (Eq. 3) is a proxy for.
pub fn output_error(w: &Mat, q: &Ternary, x: &Mat) -> f32 {
    let y_full = matmul(x, w);
    let y_quant = matmul(x, &q.dequant());
    let num = y_full.sq_err(&y_quant).sqrt();
    let den = y_full.frob();
    if den > 0.0 {
        num / den
    } else {
        0.0
    }
}

/// Render reports as the `sherry inspect` table.
pub fn render_reports(title: &str, reports: &[ErrorReport]) -> String {
    let mut s = format!("### {title}\n\n");
    s.push_str("| method | gran | rel err | SNR dB | cos sim | sparsity |\n|---|---|---|---|---|---|\n");
    for r in reports {
        s.push_str(&format!(
            "| {} | {:?} | {:.4} | {:.1} | {:.4} | {:.1}% |\n",
            r.method.name(),
            r.granularity,
            r.rel_error,
            r.snr_db,
            r.cos_sim,
            r.sparsity * 100.0
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn w(seed: u64) -> Mat {
        let mut rng = Pcg64::seeded(seed);
        Mat::randn(&mut rng, 256, 64, 1.0)
    }

    #[test]
    fn snr_consistent_with_rel_error() {
        let r = analyze(&w(0), Method::Sherry34, Granularity::PerChannel);
        let expect = -20.0 * r.rel_error.log10();
        assert!((r.snr_db - expect).abs() < 0.1);
        assert!(r.rel_error > 0.0 && r.rel_error < 1.0);
    }

    #[test]
    fn sherry_sparsity_exactly_quarter() {
        let r = analyze(&w(1), Method::Sherry34, Granularity::PerChannel);
        assert!((r.sparsity - 0.25).abs() < 1e-6);
    }

    #[test]
    fn cos_sim_high_for_all_ternary_methods() {
        for m in [Method::Sherry34, Method::AbsMean, Method::Twn] {
            let r = analyze(&w(2), m, Granularity::PerChannel);
            assert!(r.cos_sim > 0.7, "{m:?} cos {:.3}", r.cos_sim);
        }
    }

    #[test]
    fn output_error_tracks_weight_error() {
        // Lower weight-space error ⇒ lower output error on Gaussian probes
        // (the Eq. 3 proxy argument).
        let wm = w(3);
        let mut rng = Pcg64::seeded(9);
        let x = Mat::randn(&mut rng, 32, 256, 1.0);
        let q_good = quantize(&wm, Method::Sherry34, Granularity::PerGroup { group_size: 64 });
        let q_bad = quantize(&wm, Method::Binary, Granularity::PerTensor);
        let e_good = output_error(&wm, &q_good, &x);
        let e_bad = output_error(&wm, &q_bad, &x);
        assert!(e_good < e_bad, "{e_good} vs {e_bad}");
    }

    #[test]
    fn render_contains_all_rows() {
        let reports: Vec<ErrorReport> = [Method::Sherry34, Method::AbsMean]
            .iter()
            .map(|&m| analyze(&w(4), m, Granularity::PerChannel))
            .collect();
        let s = render_reports("t", &reports);
        assert!(s.contains("sherry34") && s.contains("absmean"));
    }
}
