//! Ternary quantization core: the Sherry 3:4 sparse quantizer, every
//! baseline the paper compares against (§2.1, App. E), quantization
//! granularities (Table 3), and the Arenas λ_t schedules (Fig. 7).
//!
//! Convention (matches `python/compile/kernels/ref.py`): weight matrices
//! are `(d_in, d_out)` row-major; quantization is per *output channel*
//! (column) at the default granularity.

pub mod absmean;
mod arenas;
mod baselines;
pub mod error;
mod sherry;

pub use arenas::{lambda_at, Schedule};
pub use baselines::*;
pub use sherry::{sherry34_quantize, sherry34_ternary};

use crate::tensor::Mat;

/// Quantization granularity (paper Table 3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Granularity {
    PerTensor,
    PerChannel,
    /// Groups of `group_size` consecutive input rows share a scale.
    PerGroup { group_size: usize },
}

impl Granularity {
    pub fn parse(s: &str, group_size: usize) -> Option<Self> {
        match s {
            "per_tensor" => Some(Self::PerTensor),
            "per_channel" => Some(Self::PerChannel),
            "per_group" => Some(Self::PerGroup { group_size }),
            _ => None,
        }
    }
}

/// A quantized weight matrix: ternary assignment + scales.
///
/// `t` is `(d_in, d_out)` row-major with entries in {-1, 0, +1}.
/// `alpha` layout depends on granularity:
/// * PerTensor — 1 entry;
/// * PerChannel — `d_out` entries;
/// * PerGroup — `(d_in / g) × d_out` row-major.
#[derive(Clone, Debug)]
pub struct Ternary {
    pub d_in: usize,
    pub d_out: usize,
    pub t: Vec<i8>,
    pub alpha: Vec<f32>,
    pub granularity: Granularity,
}

impl Ternary {
    /// Scale applied to element (i, j).
    #[inline]
    pub fn scale_at(&self, i: usize, j: usize) -> f32 {
        match self.granularity {
            Granularity::PerTensor => self.alpha[0],
            Granularity::PerChannel => self.alpha[j],
            Granularity::PerGroup { group_size } => self.alpha[(i / group_size) * self.d_out + j],
        }
    }

    /// Dense dequantized matrix Tα.
    pub fn dequant(&self) -> Mat {
        let mut m = Mat::zeros(self.d_in, self.d_out);
        for i in 0..self.d_in {
            for j in 0..self.d_out {
                let t = self.t[i * self.d_out + j];
                if t != 0 {
                    *m.at_mut(i, j) = t as f32 * self.scale_at(i, j);
                }
            }
        }
        m
    }

    /// Ternary value at (i, j).
    #[inline]
    pub fn t_at(&self, i: usize, j: usize) -> i8 {
        self.t[i * self.d_out + j]
    }

    /// Column `j` of T (one output channel) — what the packers consume.
    pub fn t_col(&self, j: usize) -> Vec<i8> {
        (0..self.d_in).map(|i| self.t_at(i, j)).collect()
    }

    /// Fraction of zero entries.
    pub fn sparsity(&self) -> f32 {
        self.t.iter().filter(|&&x| x == 0).count() as f32 / self.t.len() as f32
    }

    /// Does every contiguous 4-block of every column hold exactly one zero?
    /// (the 3:4 constraint, paper Eq. 3)
    pub fn is_34_sparse(&self) -> bool {
        if self.d_in % 4 != 0 {
            return false;
        }
        for j in 0..self.d_out {
            for b in 0..self.d_in / 4 {
                let zeros = (0..4).filter(|&k| self.t_at(b * 4 + k, j) == 0).count();
                if zeros != 1 {
                    return false;
                }
            }
        }
        true
    }
}

/// Quantization method registry (paper Tables 1-2 rows).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    Sherry34,
    AbsMean,
    AbsMedian,
    Twn,
    Binary,
    Lsq,
    Seq,
    Dlt,
    Tequila,
}

impl Method {
    pub const ALL: [Method; 9] = [
        Method::Sherry34,
        Method::AbsMean,
        Method::AbsMedian,
        Method::Twn,
        Method::Binary,
        Method::Lsq,
        Method::Seq,
        Method::Dlt,
        Method::Tequila,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Method::Sherry34 => "sherry34",
            Method::AbsMean => "absmean",
            Method::AbsMedian => "absmedian",
            Method::Twn => "twn",
            Method::Binary => "binary",
            Method::Lsq => "lsq",
            Method::Seq => "seq",
            Method::Dlt => "dlt",
            Method::Tequila => "tequila",
        }
    }

    pub fn parse(s: &str) -> Option<Method> {
        Method::ALL.iter().copied().find(|m| m.name() == s)
    }

    /// Effective stored bits per weight under each method's best packing
    /// (paper Fig. 1 / Tables 1-2 "Bit-width" column).
    pub fn bits_per_weight(&self) -> f32 {
        match self {
            Method::Sherry34 => 1.25, // 4 weights in 5 bits (this paper)
            Method::Binary => 1.0,
            _ => 5.0 / 3.0, // 1.67-bit TL2 packing for dense ternary
        }
    }
}

/// Quantize `w` with `method` at `granularity` (PTQ path; the QAT path
/// lives in the AOT-lowered JAX graphs).
pub fn quantize(w: &Mat, method: Method, granularity: Granularity) -> Ternary {
    match method {
        Method::Sherry34 => sherry::sherry34_quantize(w, granularity),
        Method::AbsMean => baselines::absmean_quantize(w, granularity),
        Method::AbsMedian => baselines::absmedian_quantize(w, granularity),
        Method::Twn => baselines::twn_quantize(w, granularity),
        Method::Binary => baselines::binary_quantize(w, granularity),
        Method::Lsq => baselines::lsq_quantize(w, granularity),
        Method::Seq => baselines::seq_quantize(w, granularity),
        Method::Dlt => baselines::dlt_quantize(w, granularity),
        Method::Tequila => baselines::tequila_quantize(w, granularity),
    }
}

/// L2 reconstruction error ‖W − Tα‖² (the paper's Eq. 3 objective).
pub fn reconstruction_error(w: &Mat, q: &Ternary) -> f32 {
    w.sq_err(&q.dequant())
}

/// Shared helper: masked absmean scale per column over the active set
/// (paper Eq. 18). Returns 0 for all-pruned columns.
pub(crate) fn masked_absmean_col(w: &Mat, t: &[i8], j: usize, row_range: std::ops::Range<usize>) -> f32 {
    let mut sum = 0.0f32;
    let mut n = 0u32;
    for i in row_range {
        if t[i * w.cols + j] != 0 {
            sum += w.at(i, j).abs();
            n += 1;
        }
    }
    if n == 0 {
        0.0
    } else {
        sum / n as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn w(seed: u64, d_in: usize, d_out: usize) -> Mat {
        let mut rng = Pcg64::seeded(seed);
        Mat::randn(&mut rng, d_in, d_out, 1.0)
    }

    #[test]
    fn every_method_produces_valid_ternary() {
        let w = w(1, 64, 32);
        for m in Method::ALL {
            let q = quantize(&w, m, Granularity::PerChannel);
            assert_eq!(q.t.len(), 64 * 32);
            assert!(q.t.iter().all(|&x| (-1..=1).contains(&x)), "{m:?}");
            assert!(q.alpha.iter().all(|a| a.is_finite() && *a >= 0.0), "{m:?}");
        }
    }

    #[test]
    fn sherry_is_34_sparse_baselines_are_not_forced() {
        let w = w(2, 128, 16);
        let q = quantize(&w, Method::Sherry34, Granularity::PerChannel);
        assert!(q.is_34_sparse());
        assert!((q.sparsity() - 0.25).abs() < 1e-6);
        let qb = quantize(&w, Method::Binary, Granularity::PerChannel);
        assert_eq!(qb.sparsity(), 0.0);
    }

    #[test]
    fn granularity_alpha_lengths() {
        let w = w(3, 256, 8);
        let qt = quantize(&w, Method::Sherry34, Granularity::PerTensor);
        assert_eq!(qt.alpha.len(), 1);
        let qc = quantize(&w, Method::Sherry34, Granularity::PerChannel);
        assert_eq!(qc.alpha.len(), 8);
        let qg = quantize(&w, Method::Sherry34, Granularity::PerGroup { group_size: 128 });
        assert_eq!(qg.alpha.len(), 2 * 8);
    }

    #[test]
    fn finer_granularity_never_hurts_reconstruction() {
        // More scales = strictly more expressive fit (Table 3 rationale).
        let w = w(4, 256, 16);
        let e_t = reconstruction_error(&w, &quantize(&w, Method::Sherry34, Granularity::PerTensor));
        let e_c = reconstruction_error(&w, &quantize(&w, Method::Sherry34, Granularity::PerChannel));
        let e_g = reconstruction_error(
            &w,
            &quantize(&w, Method::Sherry34, Granularity::PerGroup { group_size: 64 }),
        );
        assert!(e_c <= e_t * 1.001, "per-channel {e_c} vs per-tensor {e_t}");
        assert!(e_g <= e_c * 1.001, "per-group {e_g} vs per-channel {e_c}");
    }

    #[test]
    fn method_parse_roundtrip() {
        for m in Method::ALL {
            assert_eq!(Method::parse(m.name()), Some(m));
        }
        assert_eq!(Method::parse("nope"), None);
    }

    #[test]
    fn bits_per_weight_ordering() {
        assert!(Method::Sherry34.bits_per_weight() < Method::AbsMean.bits_per_weight());
        assert_eq!(Method::Sherry34.bits_per_weight(), 1.25);
    }
}
