//! The Sherry 3:4 Sparse-AbsMean quantizer (paper §3.1, Eq. 3-5, App. D).
//!
//! Per contiguous block of four input weights: prune the smallest-|w|
//! element (stable argmin — ties go to the lowest index, matching the jnp
//! oracle), assign sign(w) to the rest, then scale by the mean |w| of the
//! surviving entries at the requested granularity.

use super::{Granularity, Ternary};
use crate::tensor::Mat;

/// Optimal 3:4 ternary assignment T* (Eq. 4). `w` is (d_in, d_out);
/// d_in must be a multiple of 4.
pub fn sherry34_ternary(w: &Mat) -> Vec<i8> {
    assert_eq!(w.rows % 4, 0, "d_in must be a multiple of the block size 4");
    let (d_in, d_out) = (w.rows, w.cols);
    let mut t = vec![0i8; d_in * d_out];
    for j in 0..d_out {
        for b in (0..d_in).step_by(4) {
            // Stable argmin of |w| over the block.
            let mut min_i = b;
            let mut min_v = w.at(b, j).abs();
            for i in b + 1..b + 4 {
                let v = w.at(i, j).abs();
                if v < min_v {
                    min_v = v;
                    min_i = i;
                }
            }
            for i in b..b + 4 {
                if i != min_i {
                    let v = w.at(i, j);
                    // sign(0) = 0 stays ternary-faithful for exact zeros.
                    t[i * d_out + j] = if v > 0.0 {
                        1
                    } else if v < 0.0 {
                        -1
                    } else {
                        0
                    };
                }
            }
        }
    }
    t
}

/// Full Sherry quantizer at a granularity. Scales are the mean |w| over
/// *active* entries of each scale cell — for per-channel this equals the
/// paper's Eq. 5 closed form 4/(3·d_in)·Σ_active|w| because exactly 3/4 of
/// entries are active.
pub fn sherry34_quantize(w: &Mat, granularity: Granularity) -> Ternary {
    let t = sherry34_ternary(w);
    let (d_in, d_out) = (w.rows, w.cols);
    let alpha = match granularity {
        Granularity::PerChannel => (0..d_out)
            .map(|j| super::masked_absmean_col(w, &t, j, 0..d_in))
            .collect(),
        Granularity::PerTensor => {
            let mut sum = 0.0f32;
            let mut n = 0u64;
            for i in 0..d_in {
                for j in 0..d_out {
                    if t[i * d_out + j] != 0 {
                        sum += w.at(i, j).abs();
                        n += 1;
                    }
                }
            }
            vec![if n == 0 { 0.0 } else { sum / n as f32 }]
        }
        Granularity::PerGroup { group_size } => {
            assert_eq!(d_in % group_size, 0, "group_size must divide d_in");
            assert_eq!(group_size % 4, 0, "group_size must be a multiple of 4");
            let mut alpha = Vec::with_capacity((d_in / group_size) * d_out);
            for g in 0..d_in / group_size {
                for j in 0..d_out {
                    alpha.push(super::masked_absmean_col(
                        w,
                        &t,
                        j,
                        g * group_size..(g + 1) * group_size,
                    ));
                }
            }
            alpha
        }
    };
    Ternary { d_in, d_out, t, alpha, granularity }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::reconstruction_error;
    use crate::util::{prop, Pcg64};

    fn randw(seed: u64, d_in: usize, d_out: usize) -> Mat {
        let mut rng = Pcg64::seeded(seed);
        Mat::randn(&mut rng, d_in, d_out, 1.0)
    }

    #[test]
    fn eq5_closed_form_per_channel() {
        // α_j == 4/(3 d_in) Σ_active |w| (Eq. 5).
        let w = randw(0, 64, 8);
        let q = sherry34_quantize(&w, Granularity::PerChannel);
        for j in 0..8 {
            let mut s = 0.0;
            for i in 0..64 {
                if q.t_at(i, j) != 0 {
                    s += w.at(i, j).abs();
                }
            }
            let expect = 4.0 / (3.0 * 64.0) * s;
            assert!((q.alpha[j] - expect).abs() < 1e-5);
        }
    }

    #[test]
    fn prunes_exactly_min_abs() {
        let w = randw(1, 128, 4);
        let q = sherry34_quantize(&w, Granularity::PerChannel);
        for j in 0..4 {
            for b in (0..128).step_by(4) {
                let zero_lane = (0..4).find(|&k| q.t_at(b + k, j) == 0).unwrap();
                let min_lane = (0..4)
                    .min_by(|&a, &bb| {
                        w.at(b + a, j)
                            .abs()
                            .partial_cmp(&w.at(b + bb, j).abs())
                            .unwrap()
                    })
                    .unwrap();
                assert_eq!(zero_lane, min_lane);
            }
        }
    }

    #[test]
    fn signs_match_weights() {
        let w = randw(2, 64, 4);
        let q = sherry34_quantize(&w, Granularity::PerChannel);
        for j in 0..4 {
            for i in 0..64 {
                let t = q.t_at(i, j);
                if t != 0 {
                    assert_eq!(t as f32, w.at(i, j).signum());
                }
            }
        }
    }

    #[test]
    fn prop_optimality_vs_bruteforce() {
        // App. D: no other 3:4 sign pattern achieves higher block
        // correlation Σ w·t (equivalently lower L2 at optimal α).
        let mut patterns: Vec<[i8; 4]> = Vec::new();
        for zero in 0..4usize {
            for bits in 0..8u32 {
                let mut p = [0i8; 4];
                let mut k = 0;
                for lane in 0..4 {
                    if lane != zero {
                        p[lane] = if (bits >> k) & 1 == 1 { 1 } else { -1 };
                        k += 1;
                    }
                }
                patterns.push(p);
            }
        }
        prop::check(
            "sherry34 block optimality",
            200,
            |rng| {
                let v: Vec<f32> = rng.normal_vec(4);
                v
            },
            |blk| {
                let w = Mat::from_vec(4, 1, blk.clone());
                let t = sherry34_ternary(&w);
                let ours: f32 = (0..4).map(|i| blk[i] * t[i] as f32).sum();
                let best = patterns
                    .iter()
                    .map(|p| (0..4).map(|i| blk[i] * p[i] as f32).sum::<f32>())
                    .fold(f32::NEG_INFINITY, f32::max);
                if ours >= best - 1e-6 {
                    Ok(())
                } else {
                    Err(format!("greedy {ours} < brute-force {best}"))
                }
            },
        );
    }

    #[test]
    fn prop_sherry_error_leq_random_34_assignment() {
        prop::check(
            "sherry beats random 3:4 masks",
            50,
            |rng| {
                let w: Vec<f32> = rng.normal_vec(32);
                let seed = rng.next_u64();
                (w, seed)
            },
            |(wdata, seed)| {
                let w = Mat::from_vec(32, 1, wdata.clone());
                let q = sherry34_quantize(&w, Granularity::PerChannel);
                let e_opt = reconstruction_error(&w, &q);
                let mut rng = Pcg64::seeded(*seed);
                let t_rand = prop::gens::sparse34_vec(&mut rng, 32);
                // optimal alpha for that mask
                let s: f32 = (0..32)
                    .filter(|&i| t_rand[i] != 0)
                    .map(|i| wdata[i].abs())
                    .sum();
                let alpha = s / 24.0;
                let q_rand = Ternary {
                    d_in: 32,
                    d_out: 1,
                    t: t_rand,
                    alpha: vec![alpha],
                    granularity: Granularity::PerChannel,
                };
                // random mask signs may not match w; fix signs to sign(w)
                // to make it the strongest adversary
                let mut q_rand = q_rand;
                for i in 0..32 {
                    if q_rand.t[i] != 0 {
                        q_rand.t[i] = if wdata[i] >= 0.0 { 1 } else { -1 };
                    }
                }
                let e_rand = reconstruction_error(&w, &q_rand);
                if e_opt <= e_rand + 1e-4 {
                    Ok(())
                } else {
                    Err(format!("opt {e_opt} > rand {e_rand}"))
                }
            },
        );
    }

    #[test]
    fn matches_python_golden() {
        let dir = crate::test_artifacts_dir().join("golden");
        if !dir.join("w.bin").exists() {
            eprintln!("skipping: goldens not built");
            return;
        }
        let (r, c, wd) = crate::util::binio::read_mat(&dir.join("w.bin")).unwrap();
        let w = Mat::from_vec(r, c, wd);
        let q = sherry34_quantize(&w, Granularity::PerChannel);
        let (_, _, t_g) = crate::util::binio::read_mat(&dir.join("sherry34.t.bin")).unwrap();
        let (_, _, a_g) = crate::util::binio::read_mat(&dir.join("sherry34.alpha.bin")).unwrap();
        for (i, (&ours, &gold)) in q.t.iter().zip(t_g.iter()).enumerate() {
            assert_eq!(ours as f32, gold, "T mismatch at flat index {i}");
        }
        for (j, (&ours, &gold)) in q.alpha.iter().zip(a_g.iter()).enumerate() {
            assert!((ours - gold).abs() < 1e-5, "alpha mismatch at {j}: {ours} vs {gold}");
        }
        // Granularity goldens: compare dequant matrices.
        for (gran, g) in [
            ("per_tensor", Granularity::PerTensor),
            ("per_channel", Granularity::PerChannel),
            ("per_group", Granularity::PerGroup { group_size: 128 }),
        ] {
            let (_, _, deq_g) = crate::util::binio::read_mat(
                &dir.join(format!("sherry34_{gran}.deq.bin")),
            )
            .unwrap();
            let deq = sherry34_quantize(&w, g).dequant();
            for (i, (&ours, &gold)) in deq.data.iter().zip(deq_g.iter()).enumerate() {
                assert!(
                    (ours - gold).abs() < 1e-5,
                    "{gran} deq mismatch at {i}: {ours} vs {gold}"
                );
            }
        }
    }
}
