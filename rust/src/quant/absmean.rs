//! BitNet-b1.58-style absmean ternarization of *live* rows, 3:4-sparse.
//!
//! The weight quantizer (`sherry34_quantize`) solves a least-squares
//! fit over a whole frozen matrix; KV rows arrive one at a time and
//! must quantize deterministically in write order with no second pass.
//! This module is that streaming variant, shared by
//! [`crate::cache::TernaryStore`] and the tests that model it:
//!
//! * **Codes are scale-independent.** Per 4-channel block the
//!   smallest-|x| lane is dropped (stable argmin — ties take the lowest
//!   index) and the three kept lanes store `sign(x)`, with
//!   `sign(0) = +1`. No code decision reads the scale, so — unlike
//!   int8 absmax — later rows can never force a requantization of
//!   already-written bytes, and every block holds *exactly* one zero
//!   (the `pack34` codec's precondition) by construction.
//! * **The scale is a running absmean** over the kept lanes
//!   (`sum |x| / count`, the b1.58 rule restricted to the active set —
//!   the same masked absmean the paper's Eq. 18 uses per column). It is
//!   a pure fold over rows in write order, so a full page's scale is a
//!   deterministic function of its rows.

/// Ternarize one row slice into 3:4-sparse codes: per 4-channel block,
/// zero the smallest-|x| lane (stable argmin), `sign(x)` elsewhere with
/// `sign(0) = +1`. `x.len()` must be a multiple of 4; `codes` is
/// overwritten elementwise.
pub fn sparsify34_codes(x: &[f32], codes: &mut [i8]) {
    assert_eq!(x.len() % 4, 0, "3:4 blocks need a multiple of 4 channels");
    assert_eq!(codes.len(), x.len());
    for (xb, cb) in x.chunks_exact(4).zip(codes.chunks_exact_mut(4)) {
        let mut drop = 0usize;
        for lane in 1..4 {
            // Strictly-less keeps the argmin stable (lowest index wins
            // ties), so codes are a pure function of the row bytes.
            if xb[lane].abs() < xb[drop].abs() {
                drop = lane;
            }
        }
        for lane in 0..4 {
            cb[lane] = if lane == drop {
                0
            } else if xb[lane] < 0.0 {
                -1
            } else {
                1
            };
        }
    }
}

/// Sum of |x| over the kept (non-zero-coded) lanes — the increment the
/// running absmean accumulator takes for this row. The kept count is
/// always `3/4 · x.len()`.
pub fn kept_abs_sum(x: &[f32], codes: &[i8]) -> f32 {
    debug_assert_eq!(x.len(), codes.len());
    x.iter()
        .zip(codes)
        .filter(|&(_, &c)| c != 0)
        .map(|(v, _)| v.abs())
        .sum()
}

/// The absmean scale for an accumulated `(sum_abs, count)` state;
/// 0 while nothing has been written (an unwritten slot is never read).
#[inline]
pub fn absmean_scale(sum_abs: f32, count: u32) -> f32 {
    if count == 0 {
        0.0
    } else {
        sum_abs / count as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_drop_exactly_the_argmin_lane() {
        let x = [3.0, -1.0, 0.5, -2.0, -4.0, 4.0, 0.25, 1.0];
        let mut c = [9i8; 8];
        sparsify34_codes(&x, &mut c);
        assert_eq!(c, [1, -1, 0, -1, -1, 1, 0, 1]);
    }

    #[test]
    fn ties_take_the_lowest_index_and_zero_signs_positive() {
        // |x| ties across lanes 0 and 1 → lane 0 dropped; the kept
        // exact-zero lane codes +1 so the block still has one zero.
        let x = [0.0, 0.0, -1.0, 2.0];
        let mut c = [0i8; 4];
        sparsify34_codes(&x, &mut c);
        assert_eq!(c, [0, 1, -1, 1]);
    }

    #[test]
    fn every_block_has_exactly_one_zero() {
        let x: Vec<f32> = (0..64).map(|i| ((i * 37 % 13) as f32 - 6.0) * 0.3).collect();
        let mut c = vec![0i8; 64];
        sparsify34_codes(&x, &mut c);
        for b in c.chunks_exact(4) {
            assert_eq!(b.iter().filter(|&&v| v == 0).count(), 1, "{b:?}");
        }
    }

    #[test]
    fn running_absmean_matches_batch_recompute() {
        let rows = [[1.0f32, -2.0, 0.1, 4.0], [0.5, 0.5, 0.5, -8.0]];
        let mut sum = 0.0f32;
        let mut n = 0u32;
        let mut kept_all = Vec::new();
        for r in &rows {
            let mut c = [0i8; 4];
            sparsify34_codes(r, &mut c);
            sum += kept_abs_sum(r, &c);
            n += 3;
            kept_all.extend(r.iter().zip(&c).filter(|&(_, &cc)| cc != 0).map(|(v, _)| v.abs()));
        }
        let batch = kept_all.iter().sum::<f32>() / kept_all.len() as f32;
        assert!((absmean_scale(sum, n) - batch).abs() < 1e-6);
        assert_eq!(absmean_scale(0.0, 0), 0.0);
    }
}
