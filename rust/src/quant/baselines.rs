//! Baseline ternary quantizers (paper §2.1, App. E): the comparison rows
//! of Tables 1-2. All are implemented as PTQ projections of a weight
//! matrix; their QAT counterparts live in the AOT-lowered JAX graphs.
//!
//! * AbsMean / AbsMedian — BitNet/Spectra-style thresholding (Eq. 15);
//! * TWN — Gaussian-motivated Δ* ≈ 0.7·E|w| (Eq. 17);
//! * Binary — 1-bit sign quantization (Fig. 6 ablation arm);
//! * LSQ / SEQ / DLT — the learnable methods, projected with one
//!   closed-form fitting pass (their learnable parameters are trained in
//!   the L2 graphs; here we use their calibration initializations);
//! * Tequila — trap-mitigated thresholding (sharpened 0.4·E|w| threshold).

use super::{Granularity, Ternary};
use crate::tensor::Mat;

/// Generic thresholded ternarization (paper Eq. 1) + masked-absmean scale,
/// with the threshold recomputed per granularity cell.
fn threshold_quantize(
    w: &Mat,
    granularity: Granularity,
    delta_of: impl Fn(&[f32]) -> f32,
) -> Ternary {
    let (d_in, d_out) = (w.rows, w.cols);
    let mut t = vec![0i8; d_in * d_out];
    let mut alpha = Vec::new();

    let cell = |rows: std::ops::Range<usize>, j: usize, t: &mut Vec<i8>| -> f32 {
        let vals: Vec<f32> = rows.clone().map(|i| w.at(i, j).abs()).collect();
        let delta = delta_of(&vals);
        let mut sum = 0.0f32;
        let mut n = 0u32;
        for i in rows {
            let v = w.at(i, j);
            let ti = if v > delta {
                1
            } else if v < -delta {
                -1
            } else {
                0
            };
            t[i * d_out + j] = ti;
            if ti != 0 {
                sum += v.abs();
                n += 1;
            }
        }
        if n == 0 {
            0.0
        } else {
            sum / n as f32
        }
    };

    match granularity {
        Granularity::PerChannel => {
            for j in 0..d_out {
                let a = cell(0..d_in, j, &mut t);
                alpha.push(a);
            }
        }
        Granularity::PerTensor => {
            // One threshold from the whole tensor, one scale.
            let all: Vec<f32> = w.data.iter().map(|x| x.abs()).collect();
            let delta = delta_of(&all);
            let mut sum = 0.0f32;
            let mut n = 0u32;
            for i in 0..d_in {
                for j in 0..d_out {
                    let v = w.at(i, j);
                    let ti = if v > delta {
                        1
                    } else if v < -delta {
                        -1
                    } else {
                        0
                    };
                    t[i * d_out + j] = ti;
                    if ti != 0 {
                        sum += v.abs();
                        n += 1;
                    }
                }
            }
            alpha.push(if n == 0 { 0.0 } else { sum / n as f32 });
        }
        Granularity::PerGroup { group_size } => {
            assert_eq!(d_in % group_size, 0);
            for g in 0..d_in / group_size {
                for j in 0..d_out {
                    let a = cell(g * group_size..(g + 1) * group_size, j, &mut t);
                    alpha.push(a);
                }
            }
        }
    }
    Ternary { d_in, d_out, t, alpha, granularity }
}

fn mean(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f32>() / xs.len() as f32
    }
}

fn median(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = s.len();
    if n % 2 == 1 {
        s[n / 2]
    } else {
        0.5 * (s[n / 2 - 1] + s[n / 2])
    }
}

/// BitNet-style AbsMean (Eq. 15): Δ = E|w| / 2.
pub fn absmean_quantize(w: &Mat, g: Granularity) -> Ternary {
    threshold_quantize(w, g, |abs| mean(abs) / 2.0)
}

/// AbsMedian (Spectra-style): Δ = median(|w|) / 2.
pub fn absmedian_quantize(w: &Mat, g: Granularity) -> Ternary {
    threshold_quantize(w, g, |abs| median(abs) / 2.0)
}

/// TWN (Eq. 17): Δ* ≈ 0.7·E|w|.
pub fn twn_quantize(w: &Mat, g: Granularity) -> Ternary {
    threshold_quantize(w, g, |abs| 0.7 * mean(abs))
}

/// Tequila-style: sharpened threshold 0.4·E|w| keeps more weights active,
/// mitigating the trapped-zero region.
pub fn tequila_quantize(w: &Mat, g: Granularity) -> Ternary {
    threshold_quantize(w, g, |abs| 0.4 * mean(abs))
}

/// 1-bit sign quantization with absmean scale (no zeros).
pub fn binary_quantize(w: &Mat, g: Granularity) -> Ternary {
    // threshold −ε: everything non-negative → +1, negatives → −1.
    threshold_quantize(w, g, |_| -1.0e-30)
}

/// LSQ-style calibration: grid-search the step size s per scale cell to
/// minimize ‖w − s·clip(round(w/s))‖² (the QAT version learns s; this is
/// its standard projection-based init).
pub fn lsq_quantize(w: &Mat, g: Granularity) -> Ternary {
    // Reuse threshold machinery: for ternary, round(clip(w/s)) == |w| > s/2.
    // Grid-search multiplier m in Δ = m·E|w|.
    let mut best: Option<(f32, Ternary)> = None;
    for m in [0.3f32, 0.5, 0.7, 0.9, 1.1] {
        let q = threshold_quantize(w, g, move |abs| m * mean(abs));
        let err = super::reconstruction_error(w, &q);
        if best.as_ref().map(|(e, _)| err < *e).unwrap_or(true) {
            best = Some((err, q));
        }
    }
    best.unwrap().1
}

/// SEQ-style (Eq. 20): absmean ternarization; the zero-state offset b is a
/// trained parameter in the L2 graph — the PTQ projection uses b = 0, so
/// this coincides with absmean here (documented difference).
pub fn seq_quantize(w: &Mat, g: Granularity) -> Ternary {
    absmean_quantize(w, g)
}

/// DLT-style (Eq. 19): absmean ternary + learnable dequant bias; the bias
/// is trained at L2, PTQ projection uses bias = 0.
pub fn dlt_quantize(w: &Mat, g: Granularity) -> Ternary {
    absmean_quantize(w, g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::reconstruction_error;
    use crate::util::Pcg64;

    fn randw(seed: u64, d_in: usize, d_out: usize) -> Mat {
        let mut rng = Pcg64::seeded(seed);
        Mat::randn(&mut rng, d_in, d_out, 1.0)
    }

    #[test]
    fn absmean_threshold_semantics() {
        let w = randw(0, 128, 4);
        let q = absmean_quantize(&w, Granularity::PerChannel);
        for j in 0..4 {
            let am: f32 = (0..128).map(|i| w.at(i, j).abs()).sum::<f32>() / 128.0;
            for i in 0..128 {
                let v = w.at(i, j);
                let expect = if v > am / 2.0 {
                    1
                } else if v < -am / 2.0 {
                    -1
                } else {
                    0
                };
                assert_eq!(q.t_at(i, j), expect);
            }
        }
    }

    #[test]
    fn twn_sparser_than_absmean() {
        // 0.7·E|w| > 0.5·E|w| ⇒ TWN prunes strictly more (Gaussian w).
        let w = randw(1, 512, 8);
        let s_twn = twn_quantize(&w, Granularity::PerChannel).sparsity();
        let s_am = absmean_quantize(&w, Granularity::PerChannel).sparsity();
        assert!(s_twn > s_am, "twn {s_twn} vs absmean {s_am}");
    }

    #[test]
    fn tequila_denser_than_absmean() {
        let w = randw(2, 512, 8);
        let s_tq = tequila_quantize(&w, Granularity::PerChannel).sparsity();
        let s_am = absmean_quantize(&w, Granularity::PerChannel).sparsity();
        assert!(s_tq < s_am, "tequila {s_tq} vs absmean {s_am}");
    }

    #[test]
    fn binary_has_no_zeros_for_nonzero_w() {
        let w = randw(3, 64, 8);
        let q = binary_quantize(&w, Granularity::PerChannel);
        assert_eq!(q.sparsity(), 0.0);
    }

    #[test]
    fn lsq_beats_or_ties_absmean_reconstruction() {
        // LSQ grid search includes the absmean multiplier 0.5.
        let w = randw(4, 256, 8);
        let e_lsq = reconstruction_error(&w, &lsq_quantize(&w, Granularity::PerChannel));
        let e_am = reconstruction_error(&w, &absmean_quantize(&w, Granularity::PerChannel));
        assert!(e_lsq <= e_am + 1e-4);
    }

    #[test]
    fn golden_absmean_absmedian_twn_binary() {
        let dir = crate::test_artifacts_dir().join("golden");
        if !dir.join("w.bin").exists() {
            eprintln!("skipping: goldens not built");
            return;
        }
        let (r, c, wd) = crate::util::binio::read_mat(&dir.join("w.bin")).unwrap();
        let w = Mat::from_vec(r, c, wd);
        for (name, f) in [
            ("absmean", absmean_quantize as fn(&Mat, Granularity) -> Ternary),
            ("absmedian", absmedian_quantize),
            ("twn", twn_quantize),
            ("binary", binary_quantize),
        ] {
            let q = f(&w, Granularity::PerChannel);
            let (_, _, t_g) =
                crate::util::binio::read_mat(&dir.join(format!("{name}.t.bin"))).unwrap();
            let (_, _, a_g) =
                crate::util::binio::read_mat(&dir.join(format!("{name}.alpha.bin"))).unwrap();
            for (i, (&ours, &gold)) in q.t.iter().zip(t_g.iter()).enumerate() {
                assert_eq!(ours as f32, gold, "{name} T mismatch at {i}");
            }
            for (j, (&ours, &gold)) in q.alpha.iter().zip(a_g.iter()).enumerate() {
                assert!((ours - gold).abs() < 1e-5, "{name} alpha mismatch at {j}");
            }
        }
    }

    #[test]
    fn per_tensor_single_alpha() {
        let w = randw(5, 64, 8);
        for f in [absmean_quantize, twn_quantize, binary_quantize] {
            assert_eq!(f(&w, Granularity::PerTensor).alpha.len(), 1);
        }
    }
}
