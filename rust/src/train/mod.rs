//! QAT training driver: Layer-3 owns the training loop, λ_t annealing and
//! logging; the fwd+bwd+Adam step itself is the AOT-compiled Layer-2
//! graph executed via PJRT.
//!
//! Per step the driver (1) samples a synthetic batch, (2) computes λ_t
//! from the Arenas schedule at the current progress, (3) invokes the
//! train-step executable with the flat parameter ABI, and (4) reads back
//! loss and updated (params, m, v). Gradients for the Fig. 4 Effective
//! Rank diagnostics are recovered exactly from the Adam first-moment
//! outputs: g_t = (m_t − β₁·m_{t−1}) / (1 − β₁).

pub mod checkpoint;
pub mod corpus;

use anyhow::{Context, Result};
use std::collections::BTreeMap;

use crate::quant::{lambda_at, Schedule};
use crate::runtime::{literal_f32, literal_i32, scalar_f32, scalar_i32, to_vec_f32, ParamSpec, Runtime};
use crate::tensor::Mat;
use crate::util::Pcg64;
use corpus::Corpus;

/// Adam β₁ — must match `python/compile/model.py::ADAM_B1`.
pub const ADAM_B1: f32 = 0.9;

/// Training configuration for one QAT run.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Model config name ("nano" | "micro" | "e2e").
    pub config: String,
    /// Quantization method (artifact must exist).
    pub method: String,
    /// Granularity name.
    pub granularity: String,
    pub steps: usize,
    pub lr: f32,
    pub schedule: Schedule,
    pub seed: u64,
    /// Compute gradient ER for this layer every `er_every` steps (0 = off).
    pub er_layer: String,
    pub er_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            config: "nano".into(),
            method: "sherry34".into(),
            granularity: "per_channel".into(),
            steps: 200,
            lr: 1e-3,
            schedule: Schedule::CosineWarmup,
            seed: 0,
            er_layer: "layer0.wq".into(),
            er_every: 0,
        }
    }
}

/// Result of a training run.
pub struct TrainOutcome {
    /// Loss at every step.
    pub losses: Vec<f32>,
    /// (step, effective-rank of ∂L/∂W for `er_layer`) samples.
    pub er_trace: Vec<(usize, f32)>,
    /// Final latent float parameters, keyed by ABI names.
    pub params: BTreeMap<String, Mat>,
    /// λ_t at the final step (should be ≈0 for annealing schedules).
    pub final_lambda: f32,
}

/// Model dims needed to shape batches (mirrors the Python CONFIGS).
pub fn config_dims(config: &str) -> Option<(usize, usize)> {
    // (vocab, seq_len)
    match config {
        "nano" => Some((256, 64)),
        "micro" => Some((512, 128)),
        "e2e" => Some((1024, 128)),
        _ => None,
    }
}

/// The QAT driver.
pub struct Trainer<'rt> {
    rt: &'rt mut Runtime,
    spec: ParamSpec,
    artifact: String,
    batch: usize,
    vocab: usize,
    seq_len: usize,
}

impl<'rt> Trainer<'rt> {
    /// Resolve artifacts for `(config, method, granularity)`.
    pub fn new(rt: &'rt mut Runtime, cfg: &TrainConfig) -> Result<Self> {
        let manifest = rt.manifest()?;
        let entry = manifest
            .find(&cfg.config, &cfg.method, &cfg.granularity, "train")
            .with_context(|| {
                format!(
                    "no train artifact for {}/{}/{} — re-run `make artifacts`",
                    cfg.config, cfg.method, cfg.granularity
                )
            })?
            .clone();
        let spec = ParamSpec::load(&rt.artifacts_dir().join(format!("{}.params.tsv", cfg.config)))?;
        let (vocab, seq_len) = config_dims(&cfg.config).context("unknown config")?;
        Ok(Self {
            rt,
            spec,
            artifact: entry.path.clone(),
            batch: entry.batch.context("train artifact lacks batch size")?,
            vocab,
            seq_len,
        })
    }

    /// Initialize latent params the same way as the Python side: N(0,
    /// fan_in^-1/2) for matrices, ones for norms, method-specific aux.
    pub fn init_params(&self, seed: u64, method: &str) -> Vec<Vec<f32>> {
        let mut rng = Pcg64::new(seed, 99);
        self.spec
            .entries
            .iter()
            .map(|(name, shape)| {
                let n: usize = shape.iter().product();
                if name.ends_with(".aux") {
                    let fill = if method == "lsq" { 0.05 } else { 0.0 };
                    vec![fill; n]
                } else if name.contains("norm") {
                    vec![1.0; n]
                } else {
                    let scale = (shape[0] as f32).powf(-0.5);
                    (0..n).map(|_| rng.normal() * scale).collect()
                }
            })
            .collect()
    }

    /// Run the full QAT loop.
    pub fn run(&mut self, cfg: &TrainConfig) -> Result<TrainOutcome> {
        let n = self.spec.len();
        let mut params = self.init_params(cfg.seed, &cfg.method);
        let mut m: Vec<Vec<f32>> = params.iter().map(|p| vec![0.0; p.len()]).collect();
        let mut v: Vec<Vec<f32>> = params.iter().map(|p| vec![0.0; p.len()]).collect();
        let mut corpus = Corpus::new(self.vocab, cfg.seed.wrapping_add(1));
        let mut losses = Vec::with_capacity(cfg.steps);
        let mut er_trace = Vec::new();
        let er_idx = self
            .spec
            .entries
            .iter()
            .position(|(name, _)| name == &cfg.er_layer);
        let mut final_lambda = 0.0;

        for step in 0..cfg.steps {
            let progress = if cfg.steps > 1 {
                step as f32 / (cfg.steps - 1) as f32
            } else {
                1.0
            };
            let lam = lambda_at(cfg.schedule, progress);
            final_lambda = lam;
            let batch = corpus.batch_i32(self.batch, self.seq_len + 1);

            let mut inputs = Vec::with_capacity(3 * n + 4);
            for (vals, (_, shape)) in params.iter().zip(&self.spec.entries) {
                inputs.push(literal_f32(vals, shape)?);
            }
            for (vals, (_, shape)) in m.iter().zip(&self.spec.entries) {
                inputs.push(literal_f32(vals, shape)?);
            }
            for (vals, (_, shape)) in v.iter().zip(&self.spec.entries) {
                inputs.push(literal_f32(vals, shape)?);
            }
            inputs.push(literal_i32(&batch, &[self.batch, self.seq_len + 1])?);
            inputs.push(scalar_i32(step as i32));
            inputs.push(scalar_f32(lam));
            inputs.push(scalar_f32(cfg.lr));

            let outputs = self.rt.run(&self.artifact, &inputs)?;
            anyhow::ensure!(outputs.len() == 1 + 3 * n, "train step output arity");
            let loss = to_vec_f32(&outputs[0])?[0];
            losses.push(loss);

            // ER diagnostic: recover g from m before overwriting state.
            if cfg.er_every > 0 && step % cfg.er_every == 0 {
                if let Some(idx) = er_idx {
                    let m_new = to_vec_f32(&outputs[1 + n + idx])?;
                    let m_old = &m[idx];
                    let shape = &self.spec.entries[idx].1;
                    let g: Vec<f32> = m_new
                        .iter()
                        .zip(m_old)
                        .map(|(mn, mo)| (mn - ADAM_B1 * mo) / (1.0 - ADAM_B1))
                        .collect();
                    let gm = Mat::from_vec(shape[0], shape[1], g);
                    er_trace.push((step, crate::linalg::effective_rank(&gm)));
                }
            }

            for i in 0..n {
                params[i] = to_vec_f32(&outputs[1 + i])?;
                m[i] = to_vec_f32(&outputs[1 + n + i])?;
                v[i] = to_vec_f32(&outputs[1 + 2 * n + i])?;
            }
        }

        let mut out_params = BTreeMap::new();
        for ((name, shape), vals) in self.spec.entries.iter().zip(params) {
            let (r, c) = match shape.len() {
                2 => (shape[0], shape[1]),
                1 => (1, shape[0]),
                _ => (1, vals.len()),
            };
            out_params.insert(name.clone(), Mat::from_vec(r, c, vals));
        }
        Ok(TrainOutcome { losses, er_trace, params: out_params, final_lambda })
    }

    /// Mean eval loss of `params` on `n_batches` held-out batches via the
    /// loss artifact (λ forced to 0: inference-time behaviour).
    pub fn eval_loss(
        &mut self,
        cfg: &TrainConfig,
        params: &BTreeMap<String, Mat>,
        n_batches: usize,
    ) -> Result<f32> {
        let manifest = self.rt.manifest()?;
        let entry = manifest
            .find(&cfg.config, &cfg.method, &cfg.granularity, "loss")
            .context("no loss artifact")?
            .clone();
        let mut corpus = Corpus::new(self.vocab, 0xEEE); // held-out stream
        let mut total = 0.0f32;
        for _ in 0..n_batches {
            let batch = corpus.batch_i32(self.batch, self.seq_len + 1);
            let mut inputs = Vec::with_capacity(self.spec.len() + 2);
            for (name, shape) in &self.spec.entries {
                let mat = params.get(name).with_context(|| format!("missing param {name}"))?;
                inputs.push(literal_f32(&mat.data, shape)?);
            }
            inputs.push(literal_i32(&batch, &[self.batch, self.seq_len + 1])?);
            inputs.push(scalar_f32(0.0));
            let out = self.rt.run(&entry.path, &inputs)?;
            total += to_vec_f32(&out[0])?[0];
        }
        Ok(total / n_batches as f32)
    }
}

/// Convenience: run a full QAT training + eval, returning
/// (losses, eval_loss, outcome).
pub fn train_and_eval(
    rt: &mut Runtime,
    cfg: &TrainConfig,
    eval_batches: usize,
) -> Result<(TrainOutcome, f32)> {
    let mut trainer = Trainer::new(rt, cfg)?;
    let outcome = trainer.run(cfg)?;
    let eval = trainer.eval_loss(cfg, &outcome.params, eval_batches)?;
    Ok((outcome, eval))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Option<Runtime> {
        let dir = crate::test_artifacts_dir();
        if !dir.join("manifest.tsv").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        match Runtime::cpu(&dir) {
            Ok(rt) => Some(rt),
            Err(e) => {
                eprintln!("skipping: {e}");
                None
            }
        }
    }

    #[test]
    fn short_qat_run_decreases_loss() {
        let Some(mut rt) = runtime() else { return };
        let cfg = TrainConfig { steps: 12, er_every: 4, ..Default::default() };
        let mut t = Trainer::new(&mut rt, &cfg).unwrap();
        let out = t.run(&cfg).unwrap();
        assert_eq!(out.losses.len(), 12);
        assert!(out.losses.iter().all(|l| l.is_finite()));
        assert!(
            out.losses[11] < out.losses[0],
            "loss did not decrease: {:?}",
            out.losses
        );
        assert!(!out.er_trace.is_empty());
        assert!(out.final_lambda < 0.01, "λ must anneal to ~0");
    }

    #[test]
    fn eval_loss_runs() {
        let Some(mut rt) = runtime() else { return };
        let cfg = TrainConfig { steps: 6, ..Default::default() };
        let (out, eval) = train_and_eval(&mut rt, &cfg, 2).unwrap();
        assert!(eval.is_finite());
        assert!(eval > 0.0);
        assert_eq!(out.params.len(), 35);
    }

    #[test]
    fn init_params_match_spec_shapes() {
        let Some(mut rt) = runtime() else { return };
        let cfg = TrainConfig::default();
        let t = Trainer::new(&mut rt, &cfg).unwrap();
        let p = t.init_params(0, "sherry34");
        assert_eq!(p.len(), t.spec.len());
        for (vals, (_, shape)) in p.iter().zip(&t.spec.entries) {
            assert_eq!(vals.len(), shape.iter().product::<usize>());
        }
    }
}
