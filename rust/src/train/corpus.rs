//! Synthetic training corpus: a Zipf-marginal Markov language.
//!
//! Stands in for the paper's 10B UltraFineWeb tokens (see DESIGN.md
//! substitutions). The process has genuine sequential structure a small
//! LM can learn — per-token preferred successors plus periodic motifs —
//! so quantization methods separate by how much of that structure they
//! retain, which is all Tables 1-2 measure relatively.

use crate::util::Pcg64;

/// Synthetic corpus sampler.
pub struct Corpus {
    vocab: usize,
    rng: Pcg64,
    /// Zipf weights for the unconditional mixture component.
    zipf: Vec<f32>,
    /// Deterministic preferred successor per token.
    succ: Vec<u32>,
    /// Second preferred successor (bimodal transitions).
    succ2: Vec<u32>,
}

impl Corpus {
    /// Corpus over `vocab` tokens, seeded (held-out split uses a
    /// different seed stream, same process).
    pub fn new(vocab: usize, seed: u64) -> Self {
        let mut rng = Pcg64::new(seed, 17);
        let zipf: Vec<f32> = (0..vocab).map(|i| 1.0 / (i as f32 + 1.0)).collect();
        // Structured successor maps: affine permutations of the vocab so
        // transitions are learnable but non-trivial.
        let a = 5u64; // gcd(5, vocab) == 1 for our power-of-two vocabs
        let succ = (0..vocab).map(|i| ((a * i as u64 + 3) % vocab as u64) as u32).collect();
        let succ2 = (0..vocab).map(|i| ((a * i as u64 + 7 * vocab as u64 / 16) % vocab as u64) as u32).collect();
        let _ = rng.next_u64();
        Self { vocab, rng, zipf, succ, succ2 }
    }

    /// Sample one sequence of `len` tokens.
    pub fn sequence(&mut self, len: usize) -> Vec<u32> {
        let mut out = Vec::with_capacity(len);
        let mut prev = self.rng.categorical(&self.zipf) as u32;
        out.push(prev);
        while out.len() < len {
            let r = self.rng.next_f32();
            let next = if r < 0.55 {
                self.succ[prev as usize]
            } else if r < 0.8 {
                self.succ2[prev as usize]
            } else {
                self.rng.categorical(&self.zipf) as u32
            };
            out.push(next);
            prev = next;
        }
        out
    }

    /// Batch of `b` sequences of `len` tokens, flattened row-major, as the
    /// i32 the train/loss artifacts expect.
    pub fn batch_i32(&mut self, b: usize, len: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(b * len);
        for _ in 0..b {
            out.extend(self.sequence(len).into_iter().map(|t| t as i32));
        }
        out
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// The two structured successors of `t` (used by eval task builders).
    pub fn successors(&self, t: u32) -> (u32, u32) {
        (self.succ[t as usize], self.succ2[t as usize])
    }

    /// Entropy rate upper bound of the mixture (nats) — a sanity anchor
    /// for achievable loss.
    pub fn entropy_bound(&self) -> f32 {
        // H ≤ H(mixture indicator) + 0.2·H(zipf); rough but useful.
        let z: f32 = self.zipf.iter().sum();
        let h_zipf: f32 = -self
            .zipf
            .iter()
            .map(|w| {
                let p = w / z;
                p * p.ln()
            })
            .sum::<f32>();
        let h_mix = -(0.55f32 * 0.55f32.ln() + 0.25 * 0.25f32.ln() + 0.2 * 0.2f32.ln());
        h_mix + 0.2 * h_zipf
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequences_in_vocab() {
        let mut c = Corpus::new(256, 0);
        let s = c.sequence(512);
        assert_eq!(s.len(), 512);
        assert!(s.iter().all(|&t| t < 256));
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Corpus::new(256, 7);
        let mut b = Corpus::new(256, 7);
        assert_eq!(a.sequence(100), b.sequence(100));
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Corpus::new(256, 1);
        let mut b = Corpus::new(256, 2);
        assert_ne!(a.sequence(100), b.sequence(100));
    }

    #[test]
    fn has_learnable_structure() {
        // Majority of transitions follow the two preferred successors.
        let mut c = Corpus::new(256, 3);
        let s = c.sequence(20_000);
        let mut hits = 0usize;
        for w in s.windows(2) {
            let (s1, s2) = c.successors(w[0]);
            if w[1] == s1 || w[1] == s2 {
                hits += 1;
            }
        }
        let frac = hits as f32 / (s.len() - 1) as f32;
        assert!(frac > 0.7, "structured fraction {frac}");
    }

    #[test]
    fn batch_shape() {
        let mut c = Corpus::new(256, 4);
        let b = c.batch_i32(4, 65);
        assert_eq!(b.len(), 4 * 65);
        assert!(b.iter().all(|&t| (0..256).contains(&t)));
    }
}
