//! Checkpoint format: named f32 matrices in one file.
//!
//! `[u32 n LE]` then per entry: `[u16 name_len][name utf8][u32 rows]
//! [u32 cols][f32 data LE]`. Written by the QAT driver, consumed by the
//! native engine (`TernaryModel::build`) and the eval harness.

use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use crate::tensor::Mat;

/// Save named matrices (deterministic order: BTreeMap iteration).
pub fn save(path: &Path, weights: &BTreeMap<String, Mat>) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?;
    f.write_all(&(weights.len() as u32).to_le_bytes())?;
    for (name, m) in weights {
        let nb = name.as_bytes();
        f.write_all(&(nb.len() as u16).to_le_bytes())?;
        f.write_all(nb)?;
        f.write_all(&(m.rows as u32).to_le_bytes())?;
        f.write_all(&(m.cols as u32).to_le_bytes())?;
        let mut buf = Vec::with_capacity(m.data.len() * 4);
        for &x in &m.data {
            buf.extend_from_slice(&x.to_le_bytes());
        }
        f.write_all(&buf)?;
    }
    Ok(())
}

/// Load a checkpoint written by [`save`].
pub fn load(path: &Path) -> Result<BTreeMap<String, Mat>> {
    let mut f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut u32b = [0u8; 4];
    let mut u16b = [0u8; 2];
    f.read_exact(&mut u32b)?;
    let n = u32::from_le_bytes(u32b) as usize;
    let mut out = BTreeMap::new();
    for _ in 0..n {
        f.read_exact(&mut u16b)?;
        let name_len = u16::from_le_bytes(u16b) as usize;
        let mut name = vec![0u8; name_len];
        f.read_exact(&mut name)?;
        let name = String::from_utf8(name).context("bad checkpoint name")?;
        f.read_exact(&mut u32b)?;
        let rows = u32::from_le_bytes(u32b) as usize;
        f.read_exact(&mut u32b)?;
        let cols = u32::from_le_bytes(u32b) as usize;
        let mut buf = vec![0u8; rows * cols * 4];
        f.read_exact(&mut buf)?;
        let data = buf
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        out.insert(name, Mat::from_vec(rows, cols, data));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    #[test]
    fn roundtrip() {
        let mut rng = Pcg64::seeded(0);
        let mut w = BTreeMap::new();
        w.insert("embed".to_string(), Mat::randn(&mut rng, 8, 4, 1.0));
        w.insert("layer0.wq".to_string(), Mat::randn(&mut rng, 4, 4, 1.0));
        let dir = std::env::temp_dir().join("sherry_ckpt_test");
        let p = dir.join("a.ckpt");
        save(&p, &w).unwrap();
        let back = load(&p).unwrap();
        assert_eq!(back.len(), 2);
        for (k, m) in &w {
            assert_eq!(&back[k], m);
        }
    }

    #[test]
    fn missing_file_errors() {
        assert!(load(Path::new("/nonexistent/x.ckpt")).is_err());
    }
}
