//! I2_S: the 2-bit baseline packing (BitNet.cpp / T-MAC; paper Fig. 2
//! left). One ternary weight per 2 bits (00=−1, 01=0, 10=+1), four to a
//! byte. Byte-aligned and SIMD-regular but wastes 0.415 bits/weight vs the
//! ternary entropy bound — the "bit wastage" arm of the trade-off.

use crate::quant::{Granularity, Ternary};

/// Packed 2-bit weight matrix.
#[derive(Clone, Debug)]
pub struct PackedI2S {
    pub d_in: usize,
    pub d_out: usize,
    /// 4 weights per byte, channel-major.
    pub bytes: Vec<u8>,
    pub bytes_per_ch: usize,
    pub alpha: Vec<f32>,
}

#[inline]
fn enc(t: i8) -> u8 {
    (t + 1) as u8 // 0, 1, 2
}

#[inline]
fn dec(c: u8) -> i8 {
    (c & 0x3) as i8 - 1
}

impl PackedI2S {
    pub fn from_ternary(q: &Ternary) -> Self {
        assert!(
            matches!(q.granularity, Granularity::PerChannel | Granularity::PerTensor),
            "engine packing uses per-channel scales"
        );
        let bytes_per_ch = q.d_in.div_ceil(4);
        let mut bytes = vec![0u8; bytes_per_ch * q.d_out];
        for j in 0..q.d_out {
            for i in 0..q.d_in {
                let code = enc(q.t_at(i, j));
                bytes[j * bytes_per_ch + i / 4] |= code << ((i % 4) * 2);
            }
        }
        let alpha = match q.granularity {
            Granularity::PerChannel => q.alpha.clone(),
            Granularity::PerTensor => vec![q.alpha[0]; q.d_out],
            _ => unreachable!(),
        };
        Self { d_in: q.d_in, d_out: q.d_out, bytes, bytes_per_ch, alpha }
    }

    /// Borrow channel `j`'s packed bytes.
    #[inline]
    pub fn channel(&self, j: usize) -> &[u8] {
        &self.bytes[j * self.bytes_per_ch..(j + 1) * self.bytes_per_ch]
    }

    /// Total bytes of the packed planes.
    pub fn weight_bytes(&self) -> usize {
        self.bytes.len()
    }

    /// Decode channel `j` back to a ternary column (round-trip testing).
    pub fn decode_channel(&self, j: usize) -> Vec<i8> {
        (0..self.d_in)
            .map(|i| dec(self.channel(j)[i / 4] >> ((i % 4) * 2)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{absmean_quantize, Granularity};
    use crate::tensor::Mat;
    use crate::util::{prop, Pcg64};

    #[test]
    fn enc_dec_all_states() {
        for t in -1i8..=1 {
            assert_eq!(dec(enc(t)), t);
        }
    }

    #[test]
    fn prop_matrix_roundtrip() {
        prop::check(
            "i2s matrix roundtrip",
            30,
            |rng| {
                let d_in = prop::gens::usize_in(rng, 1, 100);
                let d_out = prop::gens::usize_in(rng, 1, 8);
                let seed = rng.next_u64();
                (d_in, d_out, seed)
            },
            |&(d_in, d_out, seed)| {
                let mut rng = Pcg64::seeded(seed);
                let w = Mat::randn(&mut rng, d_in, d_out, 1.0);
                let q = absmean_quantize(&w, Granularity::PerChannel);
                let p = PackedI2S::from_ternary(&q);
                for j in 0..d_out {
                    if p.decode_channel(j) != q.t_col(j) {
                        return Err(format!("channel {j} mismatch"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn two_bits_per_weight() {
        let mut rng = Pcg64::seeded(0);
        let w = Mat::randn(&mut rng, 256, 4, 1.0);
        let q = absmean_quantize(&w, Granularity::PerChannel);
        let p = PackedI2S::from_ternary(&q);
        assert_eq!(p.weight_bytes() * 8, 2 * 256 * 4);
    }
}
