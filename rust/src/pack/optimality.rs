//! Mechanized version of the paper's App. C argument: among all N:M
//! formats, 3:4 is the unique one satisfying every hardware constraint of
//! a LUT-based ternary engine.
//!
//! Constraints (App. C.1):
//! 1. **SIMD alignment** — M ∈ {2, 4, 8} (power of two);
//! 2. **LUT capacity** — index bits B−1 ≤ 4 (single 16-byte `vpshufb`);
//! 3. **Sparsity threshold** — density N/M strictly above 0.5: the paper
//!    notes 2:4 "resides exactly on the 50% threshold where performance
//!    begins to destabilize" (Zhu et al. 2016), so the boundary itself is
//!    excluded;
//! 4. **Efficiency** — bits/weight B/M strictly below the 1.67-bit
//!    state of the art.
//!
//! `enumerate_nm_formats` scores every candidate; the tests assert the
//! paper's Table-of-candidates reasoning and that 3:4 uniquely survives.

/// One candidate N:M block format for a LUT engine.
#[derive(Clone, Debug, PartialEq)]
pub struct NmFormat {
    pub n: usize,
    pub m: usize,
    /// Total bits per block: 1 sign bit + index bits.
    pub bits_per_block: u32,
    pub bits_per_weight: f32,
    /// Distinct block states: C(M,N)·2^N.
    pub states: u64,
    /// Index states after mirror-symmetry folding: states / 2.
    pub index_states: u64,
    pub simd_aligned: bool,
    pub fits_16_entry_lut: bool,
    pub density_safe: bool,
    pub efficient: bool,
}

impl NmFormat {
    /// All four App. C constraints hold.
    pub fn feasible(&self) -> bool {
        self.simd_aligned && self.fits_16_entry_lut && self.density_safe && self.efficient
    }
}

fn binom(m: u64, n: u64) -> u64 {
    let mut r = 1u64;
    for k in 0..n {
        r = r * (m - k) / (k + 1);
    }
    r
}

/// Enumerate every N:M candidate with M ≤ `max_m` and 1 ≤ N < M.
pub fn enumerate_nm_formats(max_m: usize) -> Vec<NmFormat> {
    let mut out = Vec::new();
    for m in 2..=max_m {
        for n in 1..m {
            let states = binom(m as u64, n as u64) * (1u64 << n);
            // Mirror symmetry folds sign: index space = states / 2, plus
            // 1 explicit sign bit.
            let index_states = states / 2;
            let index_bits = (64 - (index_states.max(1) - 1).leading_zeros()).max(1);
            let bits_per_block = index_bits + 1;
            let bits_per_weight = bits_per_block as f32 / m as f32;
            out.push(NmFormat {
                n,
                m,
                bits_per_block,
                bits_per_weight,
                states,
                index_states,
                simd_aligned: m.is_power_of_two(),
                fits_16_entry_lut: index_bits <= 4,
                density_safe: (n as f32 / m as f32) > 0.5,
                efficient: bits_per_weight < 5.0 / 3.0 - 1e-6,
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn find(formats: &[NmFormat], n: usize, m: usize) -> &NmFormat {
        formats.iter().find(|f| f.n == n && f.m == m).unwrap()
    }

    #[test]
    fn sherry_34_is_uniquely_feasible() {
        let formats = enumerate_nm_formats(8);
        let feasible: Vec<_> = formats.iter().filter(|f| f.feasible()).collect();
        assert_eq!(feasible.len(), 1, "{feasible:?}");
        assert_eq!((feasible[0].n, feasible[0].m), (3, 4));
    }

    #[test]
    fn sherry_saturates_the_index_space() {
        // 3:4: C(4,3)·2³ = 32 states → 16 index states = 2⁴ exactly
        // (paper: "maximum bit-state utilization without bit wastage").
        let f = enumerate_nm_formats(4);
        let s = find(&f, 3, 4);
        assert_eq!(s.states, 32);
        assert_eq!(s.index_states, 16);
        assert_eq!(s.bits_per_block, 5);
        assert_eq!(s.bits_per_weight, 1.25);
    }

    #[test]
    fn two_four_wastes_states_and_sits_on_the_edge() {
        // App. C.2: 2:4 yields C(4,2)·2¹ = 12 index states (< 16, waste)
        // and density exactly 0.5 — the destabilization threshold.
        let f = enumerate_nm_formats(4);
        let s = find(&f, 2, 4);
        assert_eq!(s.states, 24);
        assert_eq!(s.index_states, 12);
        assert!(s.index_states < 16);
        assert_eq!(s.n as f32 / s.m as f32, 0.5);
        assert!(!s.density_safe);
    }

    #[test]
    fn one_two_fails_density() {
        // App. C.2 rejects M=2. In our accounting 1:2 packs into 2 bits
        // (1 index + 1 sign) — storage-efficient but at 50% density, on
        // the destabilization boundary, hence infeasible.
        let f = enumerate_nm_formats(4);
        let s = find(&f, 1, 2);
        assert!(!s.density_safe);
        assert!(!s.feasible());
    }

    #[test]
    fn m8_formats_blow_the_lut_budget() {
        // App. C.2: dense-enough M=8 formats need > 4 index bits.
        let f = enumerate_nm_formats(8);
        for n in 5..8 {
            let s = find(&f, n, 8);
            assert!(!s.fits_16_entry_lut, "{n}:8 should exceed the 16-entry LUT");
        }
    }

    #[test]
    fn non_power_of_two_m_rejected() {
        let f = enumerate_nm_formats(6);
        for s in f.iter().filter(|s| !s.m.is_power_of_two()) {
            assert!(!s.simd_aligned);
            assert!(!s.feasible());
        }
    }
}
