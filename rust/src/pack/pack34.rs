//! Sherry's 1.25-bit packing (paper §3.1 point (3), App. A).
//!
//! A 3:4-sparse ternary block has 4 zero positions × 2³ sign patterns =
//! 32 states. Mirror symmetry (negating all signs) halves that to 16
//! canonical patterns — exactly saturating a 4-bit index and the 16-entry
//! LUT a single `vpshufb` can search — plus 1 sign bit: 5 bits per 4
//! weights = **1.25 bits/weight**.
//!
//! Canonical form: the *first non-zero lane* of a canonical pattern is +1;
//! the sign bit records whether the stored block is the mirrored
//! (all-negated) variant.
//!
//! Index encoding: `idx = z·4 + (s_b << 1 | s_c)` where `z` is the zero
//! lane and `s_b`,`s_c` are the signs (1 = −1) of the second and third
//! non-zero lanes after canonicalization.
//!
//! Layout: two planes per channel, both power-of-two aligned —
//! * index plane: one nibble per block, two blocks per byte;
//! * sign plane: one bit per block, eight blocks per byte.
//!
//! No code crosses a byte boundary, which is the property the 1.67-bit
//! format lacks.

use crate::quant::{Granularity, Ternary};

/// All 16 canonical block patterns, precomputed: `PATTERNS[idx][lane]`.
pub const PATTERNS: [[i8; 4]; 16] = build_patterns();

const fn build_patterns() -> [[i8; 4]; 16] {
    let mut out = [[0i8; 4]; 16];
    let mut z = 0;
    while z < 4 {
        let mut sb = 0;
        while sb < 2 {
            let mut sc = 0;
            while sc < 2 {
                let idx = z * 4 + (sb << 1 | sc);
                let mut pat = [0i8; 4];
                // active lanes in increasing order; first gets +1
                let mut lane = 0;
                let mut active = 0;
                while lane < 4 {
                    if lane != z {
                        pat[lane] = match active {
                            0 => 1,
                            1 => {
                                if sb == 1 {
                                    -1
                                } else {
                                    1
                                }
                            }
                            _ => {
                                if sc == 1 {
                                    -1
                                } else {
                                    1
                                }
                            }
                        };
                        active += 1;
                    }
                    lane += 1;
                }
                out[idx] = pat;
                sc += 1;
            }
            sb += 1;
        }
        z += 1;
    }
    out
}

/// Encode one 3:4 block → (index, mirror). Panics if not 3:4.
pub fn encode_block(block: &[i8]) -> (u8, bool) {
    assert_eq!(block.len(), 4);
    let z = block
        .iter()
        .position(|&x| x == 0)
        .expect("pack34 requires exactly one zero per block");
    assert_eq!(
        block.iter().filter(|&&x| x == 0).count(),
        1,
        "pack34 requires exactly one zero per block"
    );
    let active: Vec<i8> = block.iter().copied().filter(|&x| x != 0).collect();
    let mirror = active[0] == -1;
    let m = if mirror { -1 } else { 1 };
    let sb = (active[1] * m == -1) as u8;
    let sc = (active[2] * m == -1) as u8;
    ((z as u8) * 4 + (sb << 1 | sc), mirror)
}

/// Decode (index, mirror) → block of 4 ternary values.
pub fn decode_block(idx: u8, mirror: bool) -> [i8; 4] {
    let mut p = PATTERNS[idx as usize];
    if mirror {
        for v in &mut p {
            *v = -*v;
        }
    }
    p
}

/// Packed 1.25-bit weight matrix (channel-major planes, per-channel α).
#[derive(Clone, Debug)]
pub struct Packed34 {
    pub d_in: usize,
    pub d_out: usize,
    /// Nibble-packed pattern indices: `idx_bytes_per_ch` bytes per channel.
    pub idx: Vec<u8>,
    /// Bit-packed mirror signs: `sign_bytes_per_ch` bytes per channel.
    pub signs: Vec<u8>,
    /// Per-channel scales.
    pub alpha: Vec<f32>,
    pub idx_bytes_per_ch: usize,
    pub sign_bytes_per_ch: usize,
}

impl Packed34 {
    /// Blocks per channel.
    #[inline]
    pub fn n_blocks(&self) -> usize {
        self.d_in / 4
    }

    /// Pack a 3:4-sparse [`Ternary`] (per-channel granularity).
    pub fn from_ternary(q: &Ternary) -> Self {
        assert_eq!(q.d_in % 4, 0, "d_in must be a multiple of 4");
        assert!(
            matches!(q.granularity, Granularity::PerChannel | Granularity::PerTensor),
            "engine packing uses per-channel scales"
        );
        assert!(q.is_34_sparse(), "pack34 requires 3:4 sparsity");
        let nb = q.d_in / 4;
        let idx_bpc = nb.div_ceil(2);
        let sign_bpc = nb.div_ceil(8);
        let mut idx = vec![0u8; idx_bpc * q.d_out];
        let mut signs = vec![0u8; sign_bpc * q.d_out];
        let mut col = vec![0i8; q.d_in];
        for j in 0..q.d_out {
            for i in 0..q.d_in {
                col[i] = q.t_at(i, j);
            }
            for b in 0..nb {
                let (code, mirror) = encode_block(&col[b * 4..b * 4 + 4]);
                let ib = j * idx_bpc + b / 2;
                if b % 2 == 0 {
                    idx[ib] |= code;
                } else {
                    idx[ib] |= code << 4;
                }
                if mirror {
                    signs[j * sign_bpc + b / 8] |= 1 << (b % 8);
                }
            }
        }
        let alpha = match q.granularity {
            Granularity::PerChannel => q.alpha.clone(),
            Granularity::PerTensor => vec![q.alpha[0]; q.d_out],
            _ => unreachable!(),
        };
        Self { d_in: q.d_in, d_out: q.d_out, idx, signs, alpha, idx_bytes_per_ch: idx_bpc, sign_bytes_per_ch: sign_bpc }
    }

    /// Index nibble of block `b` in channel `j`.
    #[inline]
    pub fn idx_at(&self, j: usize, b: usize) -> u8 {
        let byte = self.idx[j * self.idx_bytes_per_ch + b / 2];
        if b % 2 == 0 {
            byte & 0x0F
        } else {
            byte >> 4
        }
    }

    /// Mirror bit of block `b` in channel `j`.
    #[inline]
    pub fn sign_at(&self, j: usize, b: usize) -> bool {
        (self.signs[j * self.sign_bytes_per_ch + b / 8] >> (b % 8)) & 1 == 1
    }

    /// Borrow channel `j`'s index plane.
    #[inline]
    pub fn idx_plane(&self, j: usize) -> &[u8] {
        &self.idx[j * self.idx_bytes_per_ch..(j + 1) * self.idx_bytes_per_ch]
    }

    /// Borrow channel `j`'s sign plane.
    #[inline]
    pub fn sign_plane(&self, j: usize) -> &[u8] {
        &self.signs[j * self.sign_bytes_per_ch..(j + 1) * self.sign_bytes_per_ch]
    }

    /// Total bytes of the weight planes (size accounting for Table 4).
    pub fn weight_bytes(&self) -> usize {
        self.idx.len() + self.signs.len()
    }

    /// Decode channel `j` back to a ternary column (round-trip testing).
    pub fn decode_channel(&self, j: usize) -> Vec<i8> {
        let mut out = Vec::with_capacity(self.d_in);
        for b in 0..self.n_blocks() {
            out.extend_from_slice(&decode_block(self.idx_at(j, b), self.sign_at(j, b)));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{sherry34_quantize, Granularity};
    use crate::tensor::Mat;
    use crate::util::{prop, Pcg64};

    #[test]
    fn patterns_are_all_distinct_and_canonical() {
        for (i, p) in PATTERNS.iter().enumerate() {
            // exactly one zero
            assert_eq!(p.iter().filter(|&&x| x == 0).count(), 1, "pattern {i}");
            // first non-zero is +1 (canonical)
            let first = p.iter().find(|&&x| x != 0).unwrap();
            assert_eq!(*first, 1, "pattern {i}");
            for (k, q) in PATTERNS.iter().enumerate() {
                if i != k {
                    assert_ne!(p, q, "patterns {i} and {k} collide");
                }
            }
        }
    }

    #[test]
    fn the_32_states_saturate_5_bits() {
        // 16 patterns × 2 mirrors = 32 distinct blocks = C(4,3)·2³ (paper
        // §3.1 point (3)).
        let mut seen = std::collections::HashSet::new();
        for idx in 0..16u8 {
            for mirror in [false, true] {
                seen.insert(decode_block(idx, mirror));
            }
        }
        assert_eq!(seen.len(), 32);
    }

    #[test]
    fn exhaustive_roundtrip_over_all_81_ternary_blocks() {
        // Every one of the 3⁴ = 81 ternary 4-blocks: the 32 with exactly
        // one zero (C(4,1)·2³) must round-trip through (index, mirror) —
        // mirror bit included on both sides of the trip — and every other
        // block must be rejected by the encoder (the 3:4 structural
        // contract, paper Eq. 3).
        let mut valid = 0usize;
        let mut mirrored = 0usize;
        for code in 0..81usize {
            let mut c = code;
            let mut blk = [0i8; 4];
            for lane in &mut blk {
                *lane = (c % 3) as i8 - 1;
                c /= 3;
            }
            let zeros = blk.iter().filter(|&&x| x == 0).count();
            if zeros == 1 {
                let (idx, mirror) = encode_block(&blk);
                assert!(idx < 16, "{blk:?} -> index {idx} out of range");
                assert_eq!(decode_block(idx, mirror), blk, "{blk:?} failed roundtrip");
                valid += 1;
                mirrored += mirror as usize;
            } else {
                let r = std::panic::catch_unwind(|| encode_block(&blk));
                assert!(r.is_err(), "{blk:?} (zeros={zeros}) must be rejected");
            }
        }
        assert_eq!(valid, 32, "exactly C(4,1)·2³ valid 3:4 blocks");
        assert_eq!(mirrored, 16, "mirror symmetry halves the states");
    }

    #[test]
    fn prop_block_roundtrip() {
        prop::check(
            "pack34 block roundtrip",
            500,
            |rng| prop::gens::sparse34_vec(rng, 4),
            |blk| {
                let (idx, mirror) = encode_block(blk);
                if idx >= 16 {
                    return Err(format!("index {idx} out of range"));
                }
                let back = decode_block(idx, mirror);
                if back[..] == blk[..] {
                    Ok(())
                } else {
                    Err(format!("{blk:?} -> ({idx},{mirror}) -> {back:?}"))
                }
            },
        );
    }

    #[test]
    fn prop_matrix_roundtrip() {
        prop::check(
            "pack34 matrix roundtrip",
            30,
            |rng| {
                let blocks = prop::gens::usize_in(rng, 1, 32);
                let d_out = prop::gens::usize_in(rng, 1, 16);
                let seed = rng.next_u64();
                (blocks * 4, d_out, seed)
            },
            |&(d_in, d_out, seed)| {
                let mut rng = Pcg64::seeded(seed);
                let w = Mat::randn(&mut rng, d_in, d_out, 1.0);
                let q = sherry34_quantize(&w, Granularity::PerChannel);
                let p = Packed34::from_ternary(&q);
                for j in 0..d_out {
                    if p.decode_channel(j) != q.t_col(j) {
                        return Err(format!("channel {j} mismatch"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn exact_bit_budget() {
        let mut rng = Pcg64::seeded(0);
        let w = Mat::randn(&mut rng, 256, 8, 1.0);
        let q = sherry34_quantize(&w, Granularity::PerChannel);
        let p = Packed34::from_ternary(&q);
        // 64 blocks/channel: 32 idx bytes + 8 sign bytes = 40 bytes = 320
        // bits for 256 weights → 1.25 bits/weight exactly.
        assert_eq!(p.weight_bytes(), 8 * (32 + 8));
        let bits_per_w = p.weight_bytes() as f32 * 8.0 / (256.0 * 8.0);
        assert_eq!(bits_per_w, 1.25);
    }

    #[test]
    #[should_panic(expected = "3:4")]
    fn rejects_dense_ternary() {
        let mut rng = Pcg64::seeded(1);
        let w = Mat::randn(&mut rng, 64, 4, 1.0);
        let q = crate::quant::absmean_quantize(&w, Granularity::PerChannel);
        let _ = Packed34::from_ternary(&q);
    }

    #[test]
    fn mirror_symmetry_negates() {
        for idx in 0..16u8 {
            let a = decode_block(idx, false);
            let b = decode_block(idx, true);
            for lane in 0..4 {
                assert_eq!(a[lane], -b[lane]);
            }
        }
    }
}
