//! Weight packing formats (paper Fig. 2, App. A): the storage half of the
//! bit-width / speed trade-off Sherry resolves.
//!
//! * [`pack34`] — **Sherry's 1.25-bit format**: every 3:4-sparse block of
//!   four weights becomes a 4-bit pattern index + 1 sign bit, stored in
//!   two separate planes (nibble-aligned indices, bit-packed signs) so the
//!   LUT engine loads power-of-two aligned words with zero bit-shuffling.
//! * [`tl2`] — the 1.67-bit baseline (BitNet.cpp TL2): 3 dense ternary
//!   weights → one 5-bit code in a *misaligned bitstream*; decoding
//!   straddles byte boundaries, which is exactly the overhead the paper
//!   blames for TL2 losing to 2-bit packing.
//! * [`i2s`] — the 2.0-bit baseline (BitNet.cpp I2_S): one weight per
//!   2 bits, four to a byte, decode-and-add.
//!
//! All packers consume per-output-channel ternary columns from
//! [`crate::quant::Ternary`] and store channels contiguously (the GEMV
//! iteration order). This module owns only the *storage*: the kernels
//! that multiply packed matrices — and the single dispatch surface over
//! them — live in `engine::kernel` behind the `TernaryKernel` trait
//! (which each packed type implements). The old `PackedMatrix` object
//! trait and `pack()` boxing factory were folded into it.

mod i2s;
mod optimality;
pub mod pack34;
mod tl2;

pub use i2s::PackedI2S;
pub use optimality::{enumerate_nm_formats, NmFormat};
pub use pack34::Packed34;
pub use tl2::PackedTl2;

/// Storage format tag (Table 4 rows).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Format {
    /// f32 dense (stands in for the BF16 row; see DESIGN.md substitutions).
    Dense,
    /// 2-bit I2_S.
    I2S,
    /// 1.67-bit TL2.
    Tl2,
    /// 1.25-bit Sherry 3:4.
    Sherry,
}

impl Format {
    pub const ALL: [Format; 4] = [Format::Dense, Format::I2S, Format::Tl2, Format::Sherry];

    pub fn name(&self) -> &'static str {
        match self {
            Format::Dense => "bf16",
            Format::I2S => "i2_s",
            Format::Tl2 => "tl2",
            Format::Sherry => "sherry",
        }
    }

    /// Nominal stored bits per weight (weight planes only, excluding the
    /// per-channel scales, matching the paper's accounting).
    pub fn bits_per_weight(&self) -> f32 {
        match self {
            Format::Dense => 16.0,
            Format::I2S => 2.0,
            Format::Tl2 => 5.0 / 3.0,
            Format::Sherry => 1.25,
        }
    }
}

/// Bytes for the per-channel scale vector (f32), shared across formats.
pub fn scale_bytes(d_out: usize) -> usize {
    d_out * 4
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{quantize, Granularity, Method};
    use crate::tensor::Mat;
    use crate::util::Pcg64;

    #[test]
    fn bits_ordering_matches_paper_fig1() {
        assert!(Format::Sherry.bits_per_weight() < Format::Tl2.bits_per_weight());
        assert!(Format::Tl2.bits_per_weight() < Format::I2S.bits_per_weight());
        assert!(Format::I2S.bits_per_weight() < Format::Dense.bits_per_weight());
    }

    #[test]
    fn packed_sizes_match_nominal_bits() {
        let mut rng = Pcg64::seeded(0);
        let d_in = 3072usize; // divisible by 4 and 3
        let d_out = 64usize;
        let w = Mat::randn(&mut rng, d_in, d_out, 1.0);
        let qs = quantize(&w, Method::Sherry34, Granularity::PerChannel);
        let qd = quantize(&w, Method::AbsMean, Granularity::PerChannel);

        let p34 = Packed34::from_ternary(&qs);
        let ptl2 = PackedTl2::from_ternary(&qd);
        let pi2s = PackedI2S::from_ternary(&qd);

        let n = (d_in * d_out) as f32;
        let b34 = p34.weight_bytes() as f32 * 8.0 / n;
        let btl2 = ptl2.weight_bytes() as f32 * 8.0 / n;
        let bi2s = pi2s.weight_bytes() as f32 * 8.0 / n;
        assert!((b34 - 1.25).abs() < 0.01, "sherry {b34} bits/w");
        assert!((btl2 - 1.6667).abs() < 0.02, "tl2 {btl2} bits/w");
        assert!((bi2s - 2.0).abs() < 0.01, "i2s {bi2s} bits/w");
    }

    #[test]
    fn size_savings_vs_tl2_is_25_percent() {
        // The paper's headline: 1.25 / 1.67 = 0.75 → 25% bit savings.
        let saving = 1.0 - Format::Sherry.bits_per_weight() / Format::Tl2.bits_per_weight();
        assert!((saving - 0.25).abs() < 1e-6);
    }
}
