//! TL2: the 1.67-bit baseline packing (BitNet.cpp; paper Fig. 2 middle).
//!
//! Three dense ternary weights → one 5-bit code (3³ = 27 ≤ 32 states),
//! codes written back-to-back in a **misaligned bitstream**: codes
//! regularly straddle byte boundaries, so every decode needs a 16-bit load
//! + shift + mask. This is the "SIMD-unfriendly 3-way pattern" whose
//! shuffling overhead the paper measures against.
//!
//! Code: `c = (t0+1)·9 + (t1+1)·3 + (t2+1)` ∈ [0, 27). Channels whose
//! d_in is not a multiple of 3 are zero-padded.

use crate::quant::{Granularity, Ternary};

/// Packed 1.67-bit weight matrix.
#[derive(Clone, Debug)]
pub struct PackedTl2 {
    pub d_in: usize,
    pub d_out: usize,
    /// 5-bit codes, bit-packed contiguously per channel.
    pub bits: Vec<u8>,
    pub bytes_per_ch: usize,
    pub alpha: Vec<f32>,
}

/// Encode one 3-weight group.
#[inline]
pub fn encode_group(t: &[i8]) -> u8 {
    debug_assert!(t.len() == 3);
    ((t[0] + 1) as u8) * 9 + ((t[1] + 1) as u8) * 3 + (t[2] + 1) as u8
}

/// Decode a 5-bit code back to 3 ternary weights.
#[inline]
pub fn decode_group(c: u8) -> [i8; 3] {
    [(c / 9) as i8 - 1, ((c / 3) % 3) as i8 - 1, (c % 3) as i8 - 1]
}

impl PackedTl2 {
    /// Groups per channel (d_in padded up to a multiple of 3).
    #[inline]
    pub fn n_groups(&self) -> usize {
        self.d_in.div_ceil(3)
    }

    pub fn from_ternary(q: &Ternary) -> Self {
        assert!(
            matches!(q.granularity, Granularity::PerChannel | Granularity::PerTensor),
            "engine packing uses per-channel scales"
        );
        let ng = q.d_in.div_ceil(3);
        let bytes_per_ch = (ng * 5).div_ceil(8);
        let mut bits = vec![0u8; bytes_per_ch * q.d_out];
        for j in 0..q.d_out {
            let base = j * bytes_per_ch;
            for g in 0..ng {
                let mut grp = [0i8; 3];
                for k in 0..3 {
                    let i = g * 3 + k;
                    if i < q.d_in {
                        grp[k] = q.t_at(i, j);
                    }
                }
                let code = encode_group(&grp) as u16;
                let bit_off = g * 5;
                let byte = base + bit_off / 8;
                let shift = bit_off % 8;
                // May straddle a byte boundary — the TL2 misalignment.
                bits[byte] |= (code << shift) as u8;
                if shift > 3 {
                    bits[byte + 1] |= (code >> (8 - shift)) as u8;
                }
            }
        }
        let alpha = match q.granularity {
            Granularity::PerChannel => q.alpha.clone(),
            Granularity::PerTensor => vec![q.alpha[0]; q.d_out],
            _ => unreachable!(),
        };
        Self { d_in: q.d_in, d_out: q.d_out, bits, bytes_per_ch, alpha }
    }

    /// Extract the 5-bit code of group `g` in channel `j` (16-bit load +
    /// shift + mask — the decode cost the paper attributes to TL2).
    #[inline]
    pub fn code_at(&self, j: usize, g: usize) -> u8 {
        let base = j * self.bytes_per_ch;
        let bit_off = g * 5;
        let byte = base + bit_off / 8;
        let lo = self.bits[byte] as u16;
        let hi = if byte + 1 < (j + 1) * self.bytes_per_ch {
            self.bits[byte + 1] as u16
        } else {
            0
        };
        (((hi << 8) | lo) >> (bit_off % 8)) as u8 & 0x1F
    }

    /// Borrow channel `j`'s bitstream.
    #[inline]
    pub fn stream(&self, j: usize) -> &[u8] {
        &self.bits[j * self.bytes_per_ch..(j + 1) * self.bytes_per_ch]
    }

    /// Total bytes of the packed bitstreams.
    pub fn weight_bytes(&self) -> usize {
        self.bits.len()
    }

    /// Decode channel `j` back to a ternary column (round-trip testing).
    pub fn decode_channel(&self, j: usize) -> Vec<i8> {
        let mut out = Vec::with_capacity(self.d_in);
        for g in 0..self.n_groups() {
            let grp = decode_group(self.code_at(j, g));
            for (k, &v) in grp.iter().enumerate() {
                if g * 3 + k < self.d_in {
                    out.push(v);
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{absmean_quantize, Granularity};
    use crate::tensor::Mat;
    use crate::util::{prop, Pcg64};

    #[test]
    fn group_roundtrip_all_27() {
        for a in -1i8..=1 {
            for b in -1i8..=1 {
                for c in -1i8..=1 {
                    let code = encode_group(&[a, b, c]);
                    assert!(code < 27);
                    assert_eq!(decode_group(code), [a, b, c]);
                }
            }
        }
    }

    #[test]
    fn prop_matrix_roundtrip() {
        prop::check(
            "tl2 matrix roundtrip",
            30,
            |rng| {
                let d_in = prop::gens::usize_in(rng, 1, 100);
                let d_out = prop::gens::usize_in(rng, 1, 8);
                let seed = rng.next_u64();
                (d_in, d_out, seed)
            },
            |&(d_in, d_out, seed)| {
                let mut rng = Pcg64::seeded(seed);
                let w = Mat::randn(&mut rng, d_in, d_out, 1.0);
                let q = absmean_quantize(&w, Granularity::PerChannel);
                let p = PackedTl2::from_ternary(&q);
                for j in 0..d_out {
                    if p.decode_channel(j) != q.t_col(j) {
                        return Err(format!("channel {j} mismatch (d_in={d_in})"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn bit_density_is_5_over_3() {
        let mut rng = Pcg64::seeded(0);
        let w = Mat::randn(&mut rng, 3 * 160, 4, 1.0); // 160 groups/channel
        let q = absmean_quantize(&w, Granularity::PerChannel);
        let p = PackedTl2::from_ternary(&q);
        let bits_per_w = p.weight_bytes() as f32 * 8.0 / (3.0 * 160.0 * 4.0);
        assert!((bits_per_w - 5.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn codes_straddle_byte_boundaries() {
        // Group 1 occupies bits 5..10 — proof the stream is misaligned.
        let mut rng = Pcg64::seeded(1);
        let w = Mat::randn(&mut rng, 9, 1, 1.0);
        let q = absmean_quantize(&w, Granularity::PerChannel);
        let p = PackedTl2::from_ternary(&q);
        // read back group 1 and check against direct decode
        assert_eq!(decode_group(p.code_at(0, 1))[..], q.t_col(0)[3..6]);
    }
}
