//! # Sherry — hardware-efficient 1.25-bit ternary quantization
//!
//! Reproduction of *"Sherry: Hardware-Efficient 1.25-Bit Ternary
//! Quantization via Fine-grained Sparsification"* (ACL 2026) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the edge-serving coordinator: the native
//!   LUT inference engine with the paper's 5-bit 3:4 packing (plus TL2 and
//!   I2_S baselines), request routing/batching, paged KV-cache management
//!   with radix prefix sharing (`cache`), the QAT training driver, and
//!   the full evaluation harness.
//! * **Layer 2** — the QAT transformer in JAX (`python/compile/model.py`),
//!   AOT-lowered to HLO text artifacts loaded here via PJRT.
//! * **Layer 1** — Pallas kernels (`python/compile/kernels/`) for the
//!   quantize/matmul hot spots, checked against pure-jnp oracles.
//!
//! ## Module map
//!
//! | Module | Role |
//! |---|---|
//! | [`quant`] | Ternarization methods (Sherry 3:4 + baselines), λ schedules, error metrics |
//! | [`pack`] | Weight storage formats: Sherry 1.25-bit, TL2, I2_S byte planes |
//! | [`engine`] | `TernaryKernel` LUT-GEMM dispatch, quantized linears, the native transformer |
//! | [`cache`] | Paged KV arena: `PageStore` dtypes, block tables, radix prefix sharing |
//! | [`coordinator`] | Continuous batching, paged-KV leasing, sampling, serving metrics |
//! | [`obs`] | Phase/kernel tracing, log-linear histograms, JSON/Prometheus export |
//! | [`train`] / [`runtime`] | QAT driver over the AOT PJRT train-step (stubbed without `pjrt`) |
//! | [`simd`] | Runtime-dispatched AVX2/NEON/scalar kernel capability layer |
//! | [`eval`] / [`exp`] | Task harness and paper table/figure drivers |
//! | [`tensor`] / [`linalg`] / [`util`] | Mat/ops, thread pool, PCG RNG, property testing, bench clock |
//! | [`cli`] | Offline `clap` stand-in for the `sherry` binary |
//!
//! See DESIGN.md (repository root) for the complete system inventory —
//! including the `TernaryKernel` trait, the batched LUT-GEMM tiling
//! scheme, and the paged-KV/int8-attention design (§4) — and
//! `rust/README.md` for the build/run/bench quickstart and the metrics
//! glossary.

// The kernel/packing code deliberately uses explicit index loops: the
// iteration order IS the numeric contract (bit-for-bit batched/single
// parity) and mirrors the paper's plane-walk pseudocode. Keep clippy's
// iterator-style suggestions out of `-D warnings` CI for these idioms.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]

pub mod cache;
pub mod cli;
pub mod coordinator;
pub mod engine;
pub mod eval;
pub mod exp;
pub mod linalg;
pub mod obs;
pub mod pack;
pub mod quant;
pub mod runtime;
pub mod simd;
pub mod tensor;
pub mod train;
pub mod util;

use std::path::PathBuf;

/// Locate the repository's `artifacts/` directory (env override:
/// `SHERRY_ARTIFACTS`). Used by the runtime, tests and examples.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("SHERRY_ARTIFACTS") {
        return PathBuf::from(p);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Test helper: same as [`artifacts_dir`] (kept separate so tests read as
/// explicitly artifact-dependent and can skip when not built).
pub fn test_artifacts_dir() -> PathBuf {
    artifacts_dir()
}
