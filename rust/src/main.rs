//! `sherry` — the Layer-3 coordinator CLI.
//!
//! Subcommands:
//!   train      QAT training via the AOT PJRT train-step (+ checkpoint)
//!   eval       evaluate a checkpoint (or PTQ random init) on the tasks
//!   serve      trace-driven serving demo on the native LUT engine
//!   generate   one-shot generation from a checkpoint
//!   exp        regenerate a paper table/figure (table1..3, fig3..11, appc)
//!   pack-info  packing format inventory + App. C feasibility table

use anyhow::{bail, Context, Result};

use sherry::cli::{App, Command, Parsed};
use sherry::coordinator::{
    serve_trace, BatcherConfig, Preemption, SamplerConfig, ServerConfig, TraceSpec,
};
use sherry::engine::{random_weights, NativeConfig, TernaryModel};
use sherry::pack::{enumerate_nm_formats, Format};
use sherry::quant::Schedule;
use sherry::runtime::Runtime;
use sherry::train::{checkpoint, train_and_eval, TrainConfig};

fn app() -> App {
    App::new("sherry", "1.25-bit ternary quantization (ACL 2026 reproduction)")
        .command(
            Command::new("train", "QAT training via PJRT train-step artifacts")
                .flag("config", "model config (nano|micro|e2e)", Some("nano"))
                .flag("method", "quantizer (sherry34|absmean|...|bf16)", Some("sherry34"))
                .flag("granularity", "per_tensor|per_channel|per_group", Some("per_channel"))
                .flag("steps", "training steps", Some("200"))
                .flag("lr", "learning rate", Some("0.001"))
                .flag("schedule", "arenas λ schedule", Some("cosine_warmup"))
                .flag("seed", "rng seed", Some("0"))
                .flag("out", "checkpoint output path", None),
        )
        .command(
            Command::new("eval", "evaluate a checkpoint on the synthetic benchmark suite")
                .flag("config", "model config", Some("nano"))
                .flag("ckpt", "checkpoint path (omit = random init)", None)
                .flag("method", "PTQ method", Some("sherry34"))
                .flag("questions", "questions per task", Some("40"))
                .flag("seed", "rng seed", Some("0")),
        )
        .command(
            Command::new("serve", "trace-driven serving on the native LUT engine")
                .flag("config", "model config", Some("nano"))
                .flag("ckpt", "checkpoint path (omit = random init)", None)
                .flag("format", "bf16|i2_s|tl2|sherry", Some("sherry"))
                .flag("requests", "number of requests", Some("16"))
                .flag("interarrival", "mean inter-arrival seconds", Some("0.01"))
                .flag("prompt", "prompt length", Some("8"))
                .flag("shared-prefix", "shared system-prompt tokens per prompt", Some("0"))
                .flag("tokens", "max new tokens per request", Some("24"))
                .flag("active", "max concurrent sequences", Some("8"))
                .flag("page-size", "KV page size (positions)", Some("16"))
                .flag("prefill-chunk", "prefill chunk tokens (page = page size, 0 = monolithic)", Some("page"))
                .flag("preemption", "preemption policy (never|pressure|always)", Some("pressure"))
                .flag("aging-threshold", "seconds before Batch requests age up (0 = off)", Some("5"))
                .flag("batch-fraction", "fraction of trace requests in the Batch class", Some("0"))
                .flag("deadline", "per-request deadline seconds (0 = none)", Some("0"))
                .flag("kv-dtype", "KV page storage dtype (f32|int8|ternary)", Some("f32"))
                .flag("prefix-sharing", "reuse frozen prefix KV pages (0|1)", Some("1"))
                .flag("tile-cache", "frozen-tile LRU tiles, residual path (0 = off)", Some("16"))
                .flag("integer-av", "fixed-point a·V over raw int8 V bytes (0|1)", Some("1"))
                .flag("temperature", "sampling temperature (0 = greedy)", Some("0"))
                .flag("top-k", "sample from top-k logits (0 = full vocab)", Some("0"))
                .flag("top-p", "nucleus sampling mass (1 = off)", Some("1"))
                .flag("rep-penalty", "repetition penalty (1 = off)", Some("1"))
                .flag("kernel-isa", "kernel ISA (auto|scalar|avx2|neon)", Some("auto"))
                .flag("trace", "tracing depth (off|phases|kernels)", Some("phases"))
                .flag("metrics-json", "write the metrics snapshot JSON here", None)
                .flag("metrics-prom", "write a Prometheus text exposition here", None),
        )
        .command(
            Command::new("generate", "greedy generation from a checkpoint")
                .flag("config", "model config", Some("nano"))
                .flag("ckpt", "checkpoint path (omit = random init)", None)
                .flag("format", "bf16|i2_s|tl2|sherry", Some("sherry"))
                .flag("prompt", "comma-separated token ids", Some("1,2,3"))
                .flag("tokens", "tokens to generate", Some("32"))
                .flag("kernel-isa", "kernel ISA (auto|scalar|avx2|neon)", Some("auto")),
        )
        .command(
            Command::new("exp", "regenerate a paper table/figure")
                .flag("id", "table1|table2|table3|fig3|fig4|fig6|fig7|fig8|fig10|appc", None)
                .flag("steps", "QAT steps per arm", Some("150"))
                .flag("questions", "questions per task", Some("40"))
                .flag("seeds", "seeds (table3)", Some("3"))
                .flag("seed", "base seed", Some("0")),
        )
        .command(
            Command::new("inspect", "per-layer quantization error report for a checkpoint")
                .flag("config", "model config", Some("nano"))
                .flag("ckpt", "checkpoint path (omit = random init)", None)
                .flag("layer", "layer name substring filter", Some("layer0"))
                .flag("granularity", "per_tensor|per_channel|per_group", Some("per_channel")),
        )
        .command(Command::new("pack-info", "packing formats + App. C feasibility"))
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let (command, args) = match app().parse(&argv)? {
        Parsed::Help(h) => {
            println!("{h}");
            return Ok(());
        }
        Parsed::Run { command, args } => (command, args),
    };

    match command.as_str() {
        "train" => {
            let cfg = TrainConfig {
                config: args.str_or("config", "nano"),
                method: args.str_or("method", "sherry34"),
                granularity: args.str_or("granularity", "per_channel"),
                steps: args.usize_or("steps", 200),
                lr: args.f64_or("lr", 1e-3) as f32,
                schedule: Schedule::parse(&args.str_or("schedule", "cosine_warmup"))
                    .context("unknown schedule")?,
                seed: args.u64_or("seed", 0),
                er_layer: "layer0.wq".into(),
                er_every: 0,
            };
            let mut rt = Runtime::cpu(&sherry::artifacts_dir())?;
            println!(
                "[train] {}/{}/{} steps={} schedule={:?}",
                cfg.config, cfg.method, cfg.granularity, cfg.steps, cfg.schedule
            );
            let t0 = std::time::Instant::now();
            let (outcome, eval_loss) = train_and_eval(&mut rt, &cfg, 4)?;
            for (i, l) in outcome.losses.iter().enumerate() {
                if i % 10 == 0 || i + 1 == outcome.losses.len() {
                    println!("  step {i:>5}  loss {l:.4}");
                }
            }
            println!(
                "[train] done in {:.1}s | final train loss {:.4} | heldout loss {:.4} | ppl {:.2} | final λ {:.4}",
                t0.elapsed().as_secs_f64(),
                outcome.losses.last().unwrap(),
                eval_loss,
                eval_loss.exp(),
                outcome.final_lambda
            );
            if let Some(out) = args.get("out") {
                checkpoint::save(std::path::Path::new(out), &outcome.params)?;
                println!("[train] checkpoint → {out}");
            }
        }
        "eval" => {
            let cfg_name = args.str_or("config", "nano");
            let native = NativeConfig::named(&cfg_name).context("unknown config")?;
            let params = match args.get("ckpt") {
                Some(p) => checkpoint::load(std::path::Path::new(p))?,
                None => random_weights(&native, args.u64_or("seed", 0)),
            };
            let method = sherry::quant::Method::parse(&args.str_or("method", "sherry34"))
                .context("unknown method")?;
            let row = sherry::eval::evaluate_ptq(
                method.name(),
                native,
                &params,
                method,
                sherry::quant::Granularity::PerChannel,
                args.usize_or("questions", 40),
                args.u64_or("seed", 0),
            );
            println!("{}", sherry::eval::render_table("Evaluation", &[row]));
        }
        "serve" => {
            let cfg_name = args.str_or("config", "nano");
            let native = NativeConfig::named(&cfg_name).context("unknown config")?;
            let params = match args.get("ckpt") {
                Some(p) => checkpoint::load(std::path::Path::new(p))?,
                None => random_weights(&native, 0),
            };
            let format = parse_format(&args.str_or("format", "sherry"))?;
            let isa = select_kernel_isa(&args.str_or("kernel-isa", "auto"))?;
            let trace_name = args.str_or("trace", "phases");
            let trace = sherry::obs::TraceLevel::parse(&trace_name)
                .with_context(|| format!("unknown trace level '{trace_name}' (off|phases|kernels)"))?;
            // Pin the process level before the first forward pass so
            // kernel spans in the hot loops see it.
            sherry::obs::set_trace_level(trace);
            let model = TernaryModel::build(native, &params, format);
            println!(
                "[serve] {} model, format {} ({:.2} MB), kernel isa {}, trace {}",
                cfg_name,
                format.name(),
                model.bytes() as f64 / 1e6,
                isa.name(),
                trace.name()
            );
            let active = args.usize_or("active", 8);
            let kv_dtype = match sherry::cache::KvDtype::from_name(&args.str_or("kv-dtype", "f32"))
            {
                Ok(d) => d,
                Err(e) => bail!("{e}"),
            };
            let page_size = args.usize_or("page-size", 16);
            let chunk_arg = args.str_or("prefill-chunk", "page");
            let prefill_chunk_tokens = if chunk_arg == "page" {
                page_size
            } else {
                chunk_arg.parse().with_context(|| {
                    format!("bad --prefill-chunk '{chunk_arg}' (page | token count | 0)")
                })?
            };
            let preemption_name = args.str_or("preemption", "pressure");
            let preemption = Preemption::parse(&preemption_name).with_context(|| {
                format!("unknown preemption policy '{preemption_name}' (never|pressure|always)")
            })?;
            let aging = args.f64_or("aging-threshold", 5.0);
            let server_cfg = ServerConfig {
                batcher: BatcherConfig {
                    max_active: active,
                    aging_threshold_s: if aging > 0.0 { aging } else { f64::INFINITY },
                    ..Default::default()
                },
                kv_capacity: active,
                page_size,
                prefill_chunk_tokens,
                preemption,
                kv_dtype,
                prefix_sharing: args.usize_or("prefix-sharing", 1) != 0,
                tile_cache_tiles: args
                    .usize_or("tile-cache", sherry::cache::DEFAULT_TILE_CACHE_TILES),
                integer_av: args.usize_or("integer-av", 1) != 0,
                sampler: SamplerConfig {
                    temperature: args.f64_or("temperature", 0.0) as f32,
                    top_k: args.usize_or("top-k", 0),
                    top_p: args.f64_or("top-p", 1.0) as f32,
                    repetition_penalty: args.f64_or("rep-penalty", 1.0) as f32,
                    ..Default::default()
                },
                trace,
                ..Default::default()
            };
            let trace_spec = TraceSpec {
                n_requests: args.usize_or("requests", 16),
                mean_interarrival_s: args.f64_or("interarrival", 0.01),
                prompt_len: args.usize_or("prompt", 8),
                shared_prefix_len: args.usize_or("shared-prefix", 0),
                max_new_tokens: args.usize_or("tokens", 24),
                seed: 0,
                batch_fraction: args.f64_or("batch-fraction", 0.0),
                deadline_s: args.f64_or("deadline", 0.0),
            };
            let (_completions, metrics) = serve_trace(&model, server_cfg, trace_spec);
            println!("{}", metrics.report());
            if let Some(path) = args.get("metrics-json") {
                std::fs::write(path, metrics.snapshot().render_pretty())
                    .with_context(|| format!("writing metrics snapshot to {path}"))?;
                println!("[serve] metrics snapshot → {path}");
            }
            if let Some(path) = args.get("metrics-prom") {
                std::fs::write(path, metrics.render_prometheus())
                    .with_context(|| format!("writing Prometheus exposition to {path}"))?;
                println!("[serve] Prometheus exposition → {path}");
            }
        }
        "generate" => {
            let cfg_name = args.str_or("config", "nano");
            let native = NativeConfig::named(&cfg_name).context("unknown config")?;
            let params = match args.get("ckpt") {
                Some(p) => checkpoint::load(std::path::Path::new(p))?,
                None => random_weights(&native, 0),
            };
            let format = parse_format(&args.str_or("format", "sherry"))?;
            let isa = select_kernel_isa(&args.str_or("kernel-isa", "auto"))?;
            let model = TernaryModel::build(native, &params, format);
            let prompt: Vec<u32> = args
                .str_or("prompt", "1,2,3")
                .split(',')
                .map(|s| s.trim().parse().context("bad token id"))
                .collect::<Result<_>>()?;
            let mut cache = sherry::engine::KvCache::new(&native);
            let mut scratch = sherry::engine::Scratch::default();
            let t0 = std::time::Instant::now();
            let out = model.generate(&prompt, args.usize_or("tokens", 32), &mut cache, &mut scratch);
            let dt = t0.elapsed().as_secs_f64();
            println!("prompt: {prompt:?}");
            println!("output: {out:?}");
            println!(
                "[generate] {} tokens in {:.3}s → {:.1} tok/s ({}, {})",
                out.len(),
                dt,
                out.len() as f64 / dt,
                format.name(),
                isa.name()
            );
        }
        "exp" => {
            let id = args
                .get("id")
                .map(str::to_string)
                .or_else(|| args.positional().first().cloned())
                .context("exp needs --id (or positional id)")?;
            let steps = args.usize_or("steps", 150);
            let n_q = args.usize_or("questions", 40);
            let seed = args.u64_or("seed", 0);
            run_exp(&id, steps, n_q, args.u64_or("seeds", 3), seed)?;
        }
        "inspect" => {
            let cfg_name = args.str_or("config", "nano");
            let native = NativeConfig::named(&cfg_name).context("unknown config")?;
            let params = match args.get("ckpt") {
                Some(p) => checkpoint::load(std::path::Path::new(p))?,
                None => random_weights(&native, 0),
            };
            let filter = args.str_or("layer", "layer0");
            let gran = sherry::quant::Granularity::parse(&args.str_or("granularity", "per_channel"), 128)
                .context("bad granularity")?;
            for (name, w) in &params {
                let is_linear = name.contains("layer") && !name.contains("norm") && !name.ends_with(".aux");
                if !is_linear || !name.contains(&filter) {
                    continue;
                }
                let reports: Vec<_> = sherry::quant::Method::ALL
                    .iter()
                    .map(|&m| sherry::quant::error::analyze(w, m, gran))
                    .collect();
                println!(
                    "{}",
                    sherry::quant::error::render_reports(
                        &format!("{name} ({}x{})", w.rows, w.cols),
                        &reports
                    )
                );
            }
        }
        "pack-info" => {
            println!("Packing formats (Table 4 / Fig 1 axes):");
            for f in Format::ALL {
                println!("  {:<8} {:>5.2} bits/weight", f.name(), f.bits_per_weight());
            }
            println!("\nApp. C — N:M feasibility for LUT-based ternary engines:");
            println!(
                "{:<6} {:>6} {:>7} {:>7} {:>6} {:>5} {:>5} {:>6} {:>9}",
                "N:M", "states", "idx", "bits/w", "simd", "lut", "dens", "eff", "feasible"
            );
            for f in enumerate_nm_formats(8) {
                println!(
                    "{:<6} {:>6} {:>7} {:>7.3} {:>6} {:>5} {:>5} {:>6} {:>9}",
                    format!("{}:{}", f.n, f.m),
                    f.states,
                    f.index_states,
                    f.bits_per_weight,
                    f.simd_aligned,
                    f.fits_16_entry_lut,
                    f.density_safe,
                    f.efficient,
                    if f.feasible() { "YES ←" } else { "-" }
                );
            }
        }
        other => bail!("unhandled command {other}"),
    }
    Ok(())
}

/// Pin the process kernel ISA from `--kernel-isa` (must run before the
/// first forward pass, which would otherwise auto-detect).
fn select_kernel_isa(name: &str) -> Result<sherry::simd::Isa> {
    match sherry::simd::select(name) {
        Ok(isa) => Ok(isa),
        Err(e) => bail!("{e}"),
    }
}

fn parse_format(s: &str) -> Result<Format> {
    Format::ALL
        .iter()
        .copied()
        .find(|f| f.name() == s)
        .with_context(|| format!("unknown format '{s}' (bf16|i2_s|tl2|sherry)"))
}

fn run_exp(id: &str, steps: usize, n_q: usize, seeds: u64, seed: u64) -> Result<()> {
    use sherry::exp;
    if id == "fig7" {
        exp::fig7()?;
        return Ok(());
    }
    if id == "appc" {
        let mut s = String::from("### App. C — N:M feasibility\n\n");
        for f in enumerate_nm_formats(8) {
            s.push_str(&format!(
                "{}:{} states={} idx={} bits/w={:.3} feasible={}\n",
                f.n, f.m, f.states, f.index_states, f.bits_per_weight, f.feasible()
            ));
        }
        exp::emit("appc_nm_feasibility.md", &s)?;
        return Ok(());
    }
    let mut rt = Runtime::cpu(&sherry::artifacts_dir())?;
    match id {
        "table1" => drop(exp::table1(&mut rt, steps, n_q, seed)?),
        "table2" => drop(exp::table2(&mut rt, steps, n_q, seed)?),
        "table3" => drop(exp::table3(&mut rt, steps, n_q, seeds)?),
        "fig3" => drop(exp::fig3(&mut rt, steps, seed)?),
        "fig4" => drop(exp::fig4(&mut rt, steps, seed)?),
        "fig6" => drop(exp::fig6(&mut rt, steps, n_q, seed)?),
        "fig8" => drop(exp::fig8(&mut rt, steps, n_q, seed)?),
        "fig10" | "fig11" => drop(exp::fig10_11(&mut rt, steps, seed)?),
        other => bail!("unknown experiment '{other}'"),
    }
    Ok(())
}
