//! Declarative CLI parser substrate (clap is unavailable offline).
//!
//! Supports subcommands, `--flag value`, `--flag=value`, boolean switches,
//! typed accessors with defaults, and auto-generated `--help`.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// One registered flag.
#[derive(Clone, Debug)]
pub struct FlagSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_switch: bool,
}

/// Parsed arguments for one (sub)command.
#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn switch(&self, name: &str) -> bool {
        matches!(self.get(name), Some("true"))
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

/// A subcommand: name, summary, flags.
pub struct Command {
    pub name: &'static str,
    pub summary: &'static str,
    pub flags: Vec<FlagSpec>,
}

impl Command {
    pub fn new(name: &'static str, summary: &'static str) -> Self {
        Self { name, summary, flags: Vec::new() }
    }

    /// Register `--name <value>` with an optional default.
    pub fn flag(mut self, name: &'static str, help: &'static str, default: Option<&'static str>) -> Self {
        self.flags.push(FlagSpec { name, help, default, is_switch: false });
        self
    }

    /// Register a boolean `--name` switch.
    pub fn switch(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(FlagSpec { name, help, default: None, is_switch: true });
        self
    }

    fn parse(&self, argv: &[String]) -> Result<Args> {
        let mut args = Args::default();
        for spec in &self.flags {
            if let Some(d) = spec.default {
                args.values.insert(spec.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(stripped) = tok.strip_prefix("--") {
                let (name, inline_val) = match stripped.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (stripped, None),
                };
                let Some(spec) = self.flags.iter().find(|f| f.name == name) else {
                    bail!("unknown flag --{name} for '{}'; see --help", self.name);
                };
                let value = if spec.is_switch {
                    inline_val.unwrap_or_else(|| "true".to_string())
                } else if let Some(v) = inline_val {
                    v
                } else {
                    i += 1;
                    if i >= argv.len() {
                        bail!("flag --{name} expects a value");
                    }
                    argv[i].clone()
                };
                args.values.insert(name.to_string(), value);
            } else {
                args.positional.push(tok.clone());
            }
            i += 1;
        }
        Ok(args)
    }

    fn usage(&self) -> String {
        let mut s = format!("sherry {} — {}\n\nFlags:\n", self.name, self.summary);
        for f in &self.flags {
            let d = match (f.is_switch, f.default) {
                (true, _) => " (switch)".to_string(),
                (false, Some(d)) => format!(" (default: {d})"),
                (false, None) => String::new(),
            };
            s.push_str(&format!("  --{:<18} {}{}\n", f.name, f.help, d));
        }
        s
    }
}

/// Top-level application: a set of subcommands.
pub struct App {
    pub name: &'static str,
    pub about: &'static str,
    pub commands: Vec<Command>,
}

/// Parse result: which subcommand and its args, or a help string to print.
pub enum Parsed {
    Run { command: String, args: Args },
    Help(String),
}

impl App {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self { name, about, commands: Vec::new() }
    }

    pub fn command(mut self, c: Command) -> Self {
        self.commands.push(c);
        self
    }

    fn top_usage(&self) -> String {
        let mut s = format!("{} — {}\n\nCommands:\n", self.name, self.about);
        for c in &self.commands {
            s.push_str(&format!("  {:<14} {}\n", c.name, c.summary));
        }
        s.push_str("\nUse `sherry <command> --help` for flags.\n");
        s
    }

    /// Parse argv (without the binary name).
    pub fn parse(&self, argv: &[String]) -> Result<Parsed> {
        if argv.is_empty() || argv[0] == "--help" || argv[0] == "-h" || argv[0] == "help" {
            return Ok(Parsed::Help(self.top_usage()));
        }
        let name = &argv[0];
        let Some(cmd) = self.commands.iter().find(|c| c.name == *name) else {
            bail!("unknown command '{name}'\n\n{}", self.top_usage());
        };
        if argv.iter().any(|a| a == "--help" || a == "-h") {
            return Ok(Parsed::Help(cmd.usage()));
        }
        let args = cmd.parse(&argv[1..])?;
        Ok(Parsed::Run { command: name.clone(), args })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn app() -> App {
        App::new("sherry", "test").command(
            Command::new("train", "train a model")
                .flag("steps", "number of steps", Some("100"))
                .flag("method", "quant method", Some("sherry34"))
                .switch("verbose", "log more"),
        )
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_defaults() {
        let Parsed::Run { args, .. } = app().parse(&sv(&["train"])).unwrap() else {
            panic!()
        };
        assert_eq!(args.usize_or("steps", 0), 100);
        assert_eq!(args.str_or("method", ""), "sherry34");
        assert!(!args.switch("verbose"));
    }

    #[test]
    fn parses_values_and_switches() {
        let Parsed::Run { args, .. } =
            app().parse(&sv(&["train", "--steps", "5", "--verbose", "--method=twn"])).unwrap()
        else {
            panic!()
        };
        assert_eq!(args.usize_or("steps", 0), 5);
        assert!(args.switch("verbose"));
        assert_eq!(args.str_or("method", ""), "twn");
    }

    #[test]
    fn unknown_flag_errors() {
        assert!(app().parse(&sv(&["train", "--nope", "1"])).is_err());
    }

    #[test]
    fn help_paths() {
        assert!(matches!(app().parse(&sv(&[])).unwrap(), Parsed::Help(_)));
        assert!(matches!(app().parse(&sv(&["train", "--help"])).unwrap(), Parsed::Help(_)));
    }

    #[test]
    fn unknown_command_errors() {
        assert!(app().parse(&sv(&["nope"])).is_err());
    }

    #[test]
    fn positional_args_collected() {
        let Parsed::Run { args, .. } = app().parse(&sv(&["train", "foo", "bar"])).unwrap() else {
            panic!()
        };
        assert_eq!(args.positional(), &["foo".to_string(), "bar".to_string()]);
    }
}
