//! Dense float kernels: the BF16/f32 baseline GEMV (Table 4's "BF16" row),
//! matmul for the eval path, and the transformer nonlinearities used by the
//! native inference model.

use super::Mat;

/// y = W·x with W (rows × cols) row-major, x (cols), y (rows).
///
/// This is the dense baseline the LUT engines are benchmarked against
/// (Table 4 "BF16" row runs this at f32 — see DESIGN.md substitutions).
/// Unrolled by 4 over the row to let LLVM autovectorize.
pub fn gemv_f32(w: &[f32], rows: usize, cols: usize, x: &[f32], y: &mut [f32]) {
    assert_eq!(w.len(), rows * cols);
    assert_eq!(x.len(), cols);
    assert_eq!(y.len(), rows);
    for r in 0..rows {
        let row = &w[r * cols..(r + 1) * cols];
        let mut acc0 = 0.0f32;
        let mut acc1 = 0.0f32;
        let mut acc2 = 0.0f32;
        let mut acc3 = 0.0f32;
        let chunks = cols / 4;
        for c in 0..chunks {
            let i = c * 4;
            acc0 += row[i] * x[i];
            acc1 += row[i + 1] * x[i + 1];
            acc2 += row[i + 2] * x[i + 2];
            acc3 += row[i + 3] * x[i + 3];
        }
        let mut acc = acc0 + acc1 + acc2 + acc3;
        for i in chunks * 4..cols {
            acc += row[i] * x[i];
        }
        y[r] = acc;
    }
}

/// C = A·B (naive blocked; used by eval, not the serving hot path).
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows, "inner dims");
    let mut c = Mat::zeros(a.rows, b.cols);
    // i-k-j loop order: streams B rows, accumulates into C rows.
    for i in 0..a.rows {
        for k in 0..a.cols {
            let aik = a.at(i, k);
            if aik == 0.0 {
                continue;
            }
            let brow = b.row(k);
            let crow = c.row_mut(i);
            for j in 0..b.cols {
                crow[j] += aik * brow[j];
            }
        }
    }
    c
}

/// In-place softmax over a slice.
pub fn softmax_inplace(x: &mut [f32]) {
    let m = x.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut z = 0.0;
    for v in x.iter_mut() {
        *v = (*v - m).exp();
        z += *v;
    }
    let inv = 1.0 / z;
    for v in x.iter_mut() {
        *v *= inv;
    }
}

/// In-place RMSNorm with gain `g` (LLaMA-style, eps 1e-5).
pub fn rmsnorm_inplace(x: &mut [f32], g: &[f32]) {
    assert_eq!(x.len(), g.len());
    let ms = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let inv = 1.0 / (ms + 1e-5).sqrt();
    for (v, gi) in x.iter_mut().zip(g) {
        *v *= inv * gi;
    }
}

/// In-place rotary position embedding over head_dim pairs (matches
/// `python/compile/model.py::rope`: first/second half pairing).
pub fn rope_inplace(x: &mut [f32], pos: usize) {
    let d = x.len();
    let half = d / 2;
    for i in 0..half {
        let freq = (-(10000.0f32.ln()) * i as f32 / half as f32).exp();
        let ang = pos as f32 * freq;
        let (sin, cos) = ang.sin_cos();
        let (a, b) = (x[i], x[i + half]);
        x[i] = a * cos - b * sin;
        x[i + half] = a * sin + b * cos;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    #[test]
    fn gemv_matches_matmul() {
        let mut rng = Pcg64::seeded(4);
        let w = Mat::randn(&mut rng, 13, 29, 1.0);
        let x: Vec<f32> = rng.normal_vec(29);
        let mut y = vec![0.0; 13];
        gemv_f32(&w.data, 13, 29, &x, &mut y);
        let xm = Mat::from_vec(29, 1, x);
        let expect = matmul(&w, &xm);
        for r in 0..13 {
            assert!((y[r] - expect.at(r, 0)).abs() < 1e-4);
        }
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Pcg64::seeded(5);
        let a = Mat::randn(&mut rng, 4, 4, 1.0);
        let mut eye = Mat::zeros(4, 4);
        for i in 0..4 {
            *eye.at_mut(i, i) = 1.0;
        }
        assert!(matmul(&a, &eye).max_abs_diff(&a) < 1e-6);
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut x = vec![1.0f32, 2.0, 3.0, 4.0];
        softmax_inplace(&mut x);
        assert!((x.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(x[3] > x[2] && x[2] > x[1]);
    }

    #[test]
    fn softmax_handles_large_values() {
        let mut x = vec![1000.0f32, 1000.0];
        softmax_inplace(&mut x);
        assert!((x[0] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn rmsnorm_unit_output_scale() {
        let mut x = vec![3.0f32; 8];
        let g = vec![1.0f32; 8];
        rmsnorm_inplace(&mut x, &g);
        for v in &x {
            assert!((v - 1.0).abs() < 1e-3);
        }
    }

    #[test]
    fn rope_pos0_is_identity() {
        let mut x = vec![0.3f32, -0.5, 0.7, 0.2];
        let orig = x.clone();
        rope_inplace(&mut x, 0);
        for (a, b) in x.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn rope_preserves_pair_norms() {
        let mut x = vec![0.3f32, -0.5, 0.7, 0.2, 0.9, -0.1];
        let half = 3;
        let before: Vec<f32> = (0..half).map(|i| x[i].hypot(x[i + half])).collect();
        rope_inplace(&mut x, 17);
        for (i, b) in before.iter().enumerate() {
            assert!((x[i].hypot(x[i + half]) - b).abs() < 1e-5);
        }
    }
}
