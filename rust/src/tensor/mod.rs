//! Row-major f32 matrix substrate used across the native engine, the
//! quantizers, and the eval harness.
//!
//! Deliberately minimal: `Mat` is a shape-checked `Vec<f32>`; the hot
//! inference path in `engine/` works on raw slices for speed, this type is
//! for the orchestration/eval layers.

pub mod ops;

pub use ops::{gemv_f32, matmul, rmsnorm_inplace, rope_inplace, softmax_inplace};

/// Dense row-major f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    /// Zero-filled matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// From an existing row-major buffer (length must match).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(rows * cols, data.len(), "shape/data mismatch");
        Self { rows, cols, data }
    }

    /// i.i.d. N(0, scale²) entries.
    pub fn randn(rng: &mut crate::util::Pcg64, rows: usize, cols: usize, scale: f32) -> Self {
        let data = (0..rows * cols).map(|_| rng.normal() * scale).collect();
        Self { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }

    /// Borrow row `r` as a slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Copy of column `c`.
    pub fn col(&self, c: usize) -> Vec<f32> {
        (0..self.rows).map(|r| self.at(r, c)).collect()
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                *t.at_mut(c, r) = self.at(r, c);
            }
        }
        t
    }

    /// Frobenius norm.
    pub fn frob(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Σ (a - b)² over all entries.
    pub fn sq_err(&self, other: &Mat) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum()
    }

    /// Max |a - b|.
    pub fn max_abs_diff(&self, other: &Mat) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    #[test]
    fn indexing_row_major() {
        let m = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(m.at(0, 2), 3.0);
        assert_eq!(m.at(1, 0), 4.0);
        assert_eq!(m.row(1), &[4., 5., 6.]);
        assert_eq!(m.col(1), vec![2., 5.]);
    }

    #[test]
    fn transpose_involutive() {
        let mut rng = Pcg64::seeded(0);
        let m = Mat::randn(&mut rng, 5, 7, 1.0);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn from_vec_checks_shape() {
        Mat::from_vec(2, 2, vec![1.0; 3]);
    }

    #[test]
    fn error_metrics() {
        let a = Mat::from_vec(1, 3, vec![1., 2., 3.]);
        let b = Mat::from_vec(1, 3, vec![1., 2., 5.]);
        assert_eq!(a.sq_err(&b), 4.0);
        assert_eq!(a.max_abs_diff(&b), 2.0);
        assert!((a.frob() - 14f32.sqrt()).abs() < 1e-6);
    }
}
