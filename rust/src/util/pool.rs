//! Minimal scoped thread pool (tokio is not available offline; the serving
//! loop and the parallel GEMV engine both run on this).
//!
//! Design: long-lived workers pull boxed jobs from a shared injector queue
//! guarded by a `Mutex` + `Condvar`. `scope` provides structured
//! parallelism: it blocks until every job submitted within the scope has
//! finished, so borrowed (non-'static) data is safe via a small amount of
//! `unsafe` transmute confined to `scope`.


use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<(std::collections::VecDeque<Job>, bool)>, // (jobs, shutdown)
    cv: Condvar,
}

/// Fixed-size thread pool.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    /// Pool with `n` workers (min 1).
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new((std::collections::VecDeque::new(), false)),
            cv: Condvar::new(),
        });
        let workers = (0..n)
            .map(|_| {
                let sh = Arc::clone(&shared);
                std::thread::spawn(move || loop {
                    let job = {
                        let mut q = sh.queue.lock().unwrap();
                        loop {
                            if let Some(job) = q.0.pop_front() {
                                break job;
                            }
                            if q.1 {
                                return;
                            }
                            q = sh.cv.wait(q).unwrap();
                        }
                    };
                    job();
                })
            })
            .collect();
        Self { shared, workers, size: n }
    }

    /// Pool sized to the machine (cores, capped at 16).
    pub fn default_size() -> usize {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Fire-and-forget 'static job.
    pub fn spawn(&self, job: impl FnOnce() + Send + 'static) {
        let mut q = self.shared.queue.lock().unwrap();
        q.0.push_back(Box::new(job));
        self.shared.cv.notify_one();
    }

    /// Structured parallelism: run `f`, which may submit borrowed jobs via
    /// the [`Scope`]; returns only after all scoped jobs complete.
    pub fn scope<'env, F>(&self, f: F)
    where
        F: FnOnce(&Scope<'env, '_>),
    {
        let pending = Arc::new((Mutex::new(0usize), Condvar::new()));
        let scope = Scope { pool: self, pending: Arc::clone(&pending), _marker: std::marker::PhantomData };
        f(&scope);
        let (lock, cv) = &*pending;
        let mut n = lock.lock().unwrap();
        while *n > 0 {
            n = cv.wait(n).unwrap();
        }
    }

    /// Parallel-for over `0..n` in contiguous chunks; `body(i)` per index.
    /// Falls back to inline execution for tiny `n`.
    pub fn par_for<F>(&self, n: usize, body: F)
    where
        F: Fn(usize) + Sync,
    {
        if n == 0 {
            return;
        }
        let chunks = (self.size * 4).min(n);
        let step = n.div_ceil(chunks);
        self.scope(|s| {
            let body = &body;
            let mut start = 0;
            while start < n {
                let end = (start + step).min(n);
                s.spawn(move || {
                    for i in start..end {
                        body(i);
                    }
                });
                start = end;
            }
        });
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.1 = true;
        }
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Shared lending pool of scratch buffers (f32 by default): attention
/// workers lease a tile (score rows, dequantized KV page blocks,
/// quantized-query codes), use it, and return it, so steady-state decode
/// reuses the same allocations across rounds instead of re-allocating
/// one buffer per job. Capacity converges to the peak number of
/// concurrent leases; buffers keep their grown capacity.
pub struct BufferPool<T = f32> {
    bufs: Mutex<Vec<Vec<T>>>,
}

impl<T> Default for BufferPool<T> {
    fn default() -> Self {
        Self { bufs: Mutex::new(Vec::new()) }
    }
}

impl<T> BufferPool<T> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Take a buffer (empty, but with whatever capacity it grew to on a
    /// previous lease).
    pub fn lease(&self) -> Vec<T> {
        self.bufs.lock().unwrap().pop().unwrap_or_default()
    }

    /// Return a leased buffer for reuse.
    pub fn give(&self, mut buf: Vec<T>) {
        buf.clear();
        self.bufs.lock().unwrap().push(buf);
    }

    /// Buffers currently parked in the pool (tests / diagnostics).
    pub fn parked(&self) -> usize {
        self.bufs.lock().unwrap().len()
    }
}

/// Handle for submitting borrowed jobs inside [`ThreadPool::scope`].
pub struct Scope<'env, 'pool> {
    pool: &'pool ThreadPool,
    pending: Arc<(Mutex<usize>, Condvar)>,
    _marker: std::marker::PhantomData<&'env ()>,
}

impl<'env, 'pool> Scope<'env, 'pool> {
    /// Submit a job that may borrow from `'env`. The scope's barrier
    /// guarantees the borrow outlives the job, making the lifetime
    /// extension sound (same contract as `std::thread::scope`).
    pub fn spawn<F>(&self, job: F)
    where
        F: FnOnce() + Send + 'env,
    {
        {
            let mut n = self.pending.0.lock().unwrap();
            *n += 1;
        }
        let pending = Arc::clone(&self.pending);
        // SAFETY: `ThreadPool::scope` blocks until `pending` drains, so the
        // 'env borrow cannot dangle. This mirrors crossbeam/std scoped
        // threads.
        let job: Box<dyn FnOnce() + Send + 'env> = Box::new(job);
        let job: Box<dyn FnOnce() + Send + 'static> = unsafe { std::mem::transmute(job) };
        self.pool.spawn(move || {
            job();
            let (lock, cv) = &*pending;
            let mut n = lock.lock().unwrap();
            *n -= 1;
            if *n == 0 {
                cv.notify_all();
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.spawn(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        // scope flushes nothing here; wait via drop
        drop(pool);
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn scope_waits_for_borrowed_jobs() {
        let pool = ThreadPool::new(4);
        let mut results = vec![0u64; 64];
        {
            let slots: Vec<&mut u64> = results.iter_mut().collect();
            pool.scope(|s| {
                for (i, slot) in slots.into_iter().enumerate() {
                    s.spawn(move || {
                        *slot = (i * i) as u64;
                    });
                }
            });
        }
        for (i, &r) in results.iter().enumerate() {
            assert_eq!(r, (i * i) as u64);
        }
    }

    #[test]
    fn par_for_covers_range() {
        let pool = ThreadPool::new(3);
        let sum = AtomicU64::new(0);
        pool.par_for(1000, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 999 * 1000 / 2);
    }

    #[test]
    fn par_for_empty_ok() {
        let pool = ThreadPool::new(2);
        pool.par_for(0, |_| panic!("must not run"));
    }

    #[test]
    fn buffer_pool_reuses_capacity() {
        let bp = BufferPool::new();
        let mut a = bp.lease();
        a.resize(1024, 1.0);
        let cap = a.capacity();
        bp.give(a);
        assert_eq!(bp.parked(), 1);
        let b = bp.lease();
        assert!(b.is_empty(), "returned buffers come back cleared");
        assert!(b.capacity() >= cap, "capacity survives the round trip");
        assert_eq!(bp.parked(), 0);
    }

    #[test]
    fn buffer_pool_shared_across_threads() {
        let bp = BufferPool::new();
        let pool = ThreadPool::new(4);
        pool.scope(|s| {
            for _ in 0..16 {
                let bp = &bp;
                s.spawn(move || {
                    let mut t = bp.lease();
                    t.resize(64, 0.5);
                    bp.give(t);
                });
            }
        });
        assert!(bp.parked() >= 1 && bp.parked() <= 16);
    }
}
