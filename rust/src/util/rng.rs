//! Deterministic PRNG substrate (no external crates).
//!
//! `Pcg64` is a PCG-XSL-RR 128/64 generator: fast, statistically solid,
//! and reproducible across platforms — every experiment in `exp/` seeds
//! one of these so table rows are re-runnable bit-for-bit.

/// PCG-XSL-RR 128/64.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Seeded constructor; `stream` selects an independent sequence.
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Self { state: 0, inc };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Convenience: stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [0, 1) with 53-bit precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). Lemire's method without bias for our use
    /// (n is tiny relative to 2^64; modulo bias < 2^-40 — fine for
    /// experiments, documented).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        self.next_u64() % n
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-12 {
                let u2 = self.next_f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
            }
        }
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample from an unnormalized discrete distribution.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f32 = weights.iter().sum();
        let mut u = self.next_f32() * total;
        for (i, &w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::seeded(42);
        let mut b = Pcg64::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_are_independent() {
        let mut a = Pcg64::new(42, 0);
        let mut b = Pcg64::new(42, 1);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Pcg64::seeded(7);
        for _ in 0..10_000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::seeded(1);
        let xs = r.normal_vec(50_000);
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_covers_range() {
        let mut r = Pcg64::seeded(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::seeded(9);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Pcg64::seeded(5);
        let mut counts = [0u32; 3];
        for _ in 0..30_000 {
            counts[r.categorical(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0]);
        let p2 = counts[2] as f32 / 30_000.0;
        assert!((p2 - 0.7).abs() < 0.03, "p2 {p2}");
    }
}
