//! Shared substrates: RNG, stats, binary I/O, thread pool, timing, and the
//! in-repo property-testing framework. All dependency-free (the offline
//! build vendors only the `xla` closure — see DESIGN.md substitutions).

pub mod bench;
pub mod binio;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod timer;

pub use pool::{BufferPool, ThreadPool};
pub use rng::Pcg64;
