//! Summary statistics for benchmarks and experiment tables.

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Median (interpolated for even lengths).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Linear-interpolated percentile, p in [0, 100]. NaN inputs sort to the
/// top (`total_cmp`'s IEEE 754 total order) instead of panicking the
/// comparator, so a poisoned sample degrades a tail percentile rather
/// than taking down a whole bench/experiment run.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s: Vec<f64> = xs.to_vec();
    s.sort_by(f64::total_cmp);
    let rank = (p / 100.0) * (s.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (rank - lo as f64) * (s[hi] - s[lo])
    }
}

/// Median absolute deviation — the robust spread used by the bench harness.
pub fn mad(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = median(xs);
    let devs: Vec<f64> = xs.iter().map(|x| (x - m).abs()).collect();
    median(&devs)
}

/// Histogram with `bins` equal-width buckets over [lo, hi].
pub fn histogram(xs: &[f32], lo: f32, hi: f32, bins: usize) -> Vec<u64> {
    let mut h = vec![0u64; bins];
    let w = (hi - lo) / bins as f32;
    if w <= 0.0 {
        return h;
    }
    for &x in xs {
        if x >= lo && x < hi {
            let b = ((x - lo) / w) as usize;
            h[b.min(bins - 1)] += 1;
        } else if x == hi {
            h[bins - 1] += 1;
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert_eq!(mean(&xs), 5.0);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn percentile_bounds() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
    }

    #[test]
    fn mad_robust_to_outlier() {
        let xs = [1.0, 1.1, 0.9, 1.0, 100.0];
        assert!(mad(&xs) < 0.2);
    }

    #[test]
    fn histogram_counts_everything_in_range() {
        let xs = [0.0f32, 0.5, 0.99, 1.0, -0.1];
        let h = histogram(&xs, 0.0, 1.0, 2);
        assert_eq!(h.iter().sum::<u64>(), 4); // -0.1 excluded
        assert_eq!(h[0], 1); // 0.0
        assert_eq!(h[1], 3); // 0.5 (boundary), 0.99, 1.0 (hi → last bin)
    }

    #[test]
    fn histogram_includes_the_hi_edge_and_excludes_outside() {
        // Regression: x == hi must land in the top bucket (the seed once
        // dropped the closed upper edge), while values strictly outside
        // [lo, hi] stay excluded on both sides.
        let h = histogram(&[1.0f32], 0.0, 1.0, 4);
        assert_eq!(h, vec![0, 0, 0, 1], "x == hi belongs to the last bin");
        let h = histogram(&[-0.001f32, 1.001], 0.0, 1.0, 4);
        assert_eq!(h.iter().sum::<u64>(), 0, "outside values never count");
        // Degenerate range records nothing instead of dividing by zero.
        let h = histogram(&[0.5f32], 1.0, 1.0, 4);
        assert_eq!(h.iter().sum::<u64>(), 0);
    }

    #[test]
    fn percentile_and_median_survive_nan() {
        // Regression: `partial_cmp().unwrap()` panicked on any NaN in the
        // sample; total_cmp sorts NaN above every number instead.
        let xs = [3.0, f64::NAN, 1.0, 2.0];
        assert_eq!(median(&xs), 2.5, "NaN sorts last; the finite half still interpolates");
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert!(percentile(&xs, 100.0).is_nan(), "the NaN surfaces at the top, not as a panic");
        let all_nan = [f64::NAN, f64::NAN];
        assert!(median(&all_nan).is_nan());
    }

    #[test]
    fn empty_inputs_are_zero() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(median(&[]), 0.0);
        assert_eq!(mad(&[]), 0.0);
    }
}
