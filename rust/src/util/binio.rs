//! Binary matrix I/O shared with the Python side.
//!
//! Format (see `python/compile/golden.py`):
//! `[u32 rows LE][u32 cols LE][f32 data row-major LE]`.
//! Used for golden test vectors and model checkpoints.

use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

/// Read a `(rows, cols, data)` matrix from the golden/checkpoint format.
pub fn read_mat(path: &Path) -> Result<(usize, usize, Vec<f32>)> {
    let mut f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut hdr = [0u8; 8];
    f.read_exact(&mut hdr)?;
    let rows = u32::from_le_bytes(hdr[0..4].try_into().unwrap()) as usize;
    let cols = u32::from_le_bytes(hdr[4..8].try_into().unwrap()) as usize;
    let n = rows
        .checked_mul(cols)
        .context("matrix dims overflow")?;
    let mut buf = vec![0u8; n * 4];
    f.read_exact(&mut buf)
        .with_context(|| format!("short read in {}", path.display()))?;
    let mut rest = [0u8; 1];
    if f.read(&mut rest)? != 0 {
        bail!("trailing bytes in {}", path.display());
    }
    let data = buf
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    Ok((rows, cols, data))
}

/// Write a matrix in the shared format.
pub fn write_mat(path: &Path, rows: usize, cols: usize, data: &[f32]) -> Result<()> {
    assert_eq!(rows * cols, data.len());
    let mut f = std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?;
    f.write_all(&(rows as u32).to_le_bytes())?;
    f.write_all(&(cols as u32).to_le_bytes())?;
    let mut buf = Vec::with_capacity(data.len() * 4);
    for &x in data {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    f.write_all(&buf)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("sherry_binio_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("m.bin");
        let data: Vec<f32> = (0..12).map(|i| i as f32 * 0.5).collect();
        write_mat(&p, 3, 4, &data).unwrap();
        let (r, c, back) = read_mat(&p).unwrap();
        assert_eq!((r, c), (3, 4));
        assert_eq!(back, data);
    }

    #[test]
    fn rejects_truncated() {
        let dir = std::env::temp_dir().join("sherry_binio_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.bin");
        std::fs::write(&p, [2, 0, 0, 0, 2, 0, 0, 0, 1, 2, 3]).unwrap();
        assert!(read_mat(&p).is_err());
    }
}
