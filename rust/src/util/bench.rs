//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Protocol: warmup runs, then `samples` timed runs; report median, MAD
//! and derived throughput. Benches (`rust/benches/*.rs`, harness = false)
//! print one table row per case so `cargo bench` regenerates the paper's
//! tables directly.

use std::time::Instant;

use super::stats;

/// One measured case.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    /// Median seconds per iteration.
    pub median_s: f64,
    /// Median absolute deviation (s).
    pub mad_s: f64,
    pub samples: usize,
}

impl Measurement {
    /// items/second at `items` work items per iteration.
    pub fn throughput(&self, items: f64) -> f64 {
        if self.median_s <= 0.0 {
            return 0.0;
        }
        items / self.median_s
    }
}

/// Benchmark `f`, self-calibrating the batch size so one sample takes
/// ≥ `min_sample_s`.
pub fn bench(name: &str, warmup: usize, samples: usize, mut f: impl FnMut()) -> Measurement {
    bench_with(name, warmup, samples, 0.005, &mut f)
}

/// [`bench`] with explicit minimum sample time.
pub fn bench_with(
    name: &str,
    warmup: usize,
    samples: usize,
    min_sample_s: f64,
    f: &mut dyn FnMut(),
) -> Measurement {
    for _ in 0..warmup {
        f();
    }
    // calibrate batch
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let batch = ((min_sample_s / once).ceil() as usize).max(1);

    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        for _ in 0..batch {
            f();
        }
        times.push(t0.elapsed().as_secs_f64() / batch as f64);
    }
    Measurement {
        name: name.to_string(),
        median_s: stats::median(&times),
        mad_s: stats::mad(&times),
        samples,
    }
}

/// Render a bench table (markdown).
pub fn render(title: &str, rows: &[(String, String)]) -> String {
    let mut s = format!("\n## {title}\n\n");
    for (k, v) in rows {
        s.push_str(&format!("  {k:<38} {v}\n"));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let m = bench("spin", 1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(m.median_s > 0.0);
        assert_eq!(m.samples, 5);
    }

    #[test]
    fn throughput_inverse_of_time() {
        let m = Measurement { name: "x".into(), median_s: 0.5, mad_s: 0.0, samples: 1 };
        assert_eq!(m.throughput(10.0), 20.0);
    }

    #[test]
    fn ordering_detects_slower_code() {
        // black_box the bounds so release-mode LLVM can't closed-form the
        // sums away.
        let fast = bench("fast", 1, 5, || {
            let n = std::hint::black_box(100u64);
            std::hint::black_box((0..n).fold(0u64, |a, x| a ^ x.wrapping_mul(31)));
        });
        let slow = bench("slow", 1, 5, || {
            let n = std::hint::black_box(1_000_000u64);
            std::hint::black_box((0..n).fold(0u64, |a, x| a ^ x.wrapping_mul(31)));
        });
        assert!(slow.median_s > fast.median_s);
    }
}
