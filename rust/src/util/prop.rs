//! Mini property-testing framework (proptest is unavailable offline; this
//! provides the subset we need: seeded generators, N-case sweeps, and
//! greedy input shrinking on failure).
//!
//! ```ignore
//! prop_check("lut matches dense", 200, gen, |case| { ... Ok(()) });
//! ```
//! Generators are plain `Fn(&mut Pcg64) -> T`; shrinkers are optional
//! `Fn(&T) -> Vec<T>` producing smaller candidates.

use super::rng::Pcg64;

/// Result of a single property case.
pub type PropResult = Result<(), String>;

/// Run `cases` random cases of `prop` over inputs from `gen`.
/// Panics with the seed + failing input `Debug` on the first failure
/// (after greedy shrinking when `shrink` yields candidates).
pub fn check_with_shrink<T: std::fmt::Debug + Clone>(
    name: &str,
    cases: usize,
    gen: impl Fn(&mut Pcg64) -> T,
    shrink: impl Fn(&T) -> Vec<T>,
    prop: impl Fn(&T) -> PropResult,
) {
    let seed = std::env::var("SHERRY_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEEu64);
    let mut rng = Pcg64::new(seed, name.len() as u64);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            // Greedy shrink: keep taking the first failing smaller candidate.
            let mut cur = input.clone();
            let mut cur_msg = msg;
            'outer: loop {
                for cand in shrink(&cur) {
                    if let Err(m) = prop(&cand) {
                        cur = cand;
                        cur_msg = m;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property '{name}' failed (seed={seed}, case={case}):\n  input: {cur:?}\n  error: {cur_msg}"
            );
        }
    }
}

/// [`check_with_shrink`] without shrinking.
pub fn check<T: std::fmt::Debug + Clone>(
    name: &str,
    cases: usize,
    gen: impl Fn(&mut Pcg64) -> T,
    prop: impl Fn(&T) -> PropResult,
) {
    check_with_shrink(name, cases, gen, |_| Vec::new(), prop);
}

/// Generator helpers.
pub mod gens {
    use super::*;

    /// Uniform usize in [lo, hi].
    pub fn usize_in(rng: &mut Pcg64, lo: usize, hi: usize) -> usize {
        lo + rng.below((hi - lo + 1) as u64) as usize
    }

    /// Vec of standard normals with random length in [lo_len, hi_len].
    pub fn normal_vec(rng: &mut Pcg64, lo_len: usize, hi_len: usize) -> Vec<f32> {
        let n = usize_in(rng, lo_len, hi_len);
        rng.normal_vec(n)
    }

    /// Random ternary vector in {-1, 0, +1}.
    pub fn ternary_vec(rng: &mut Pcg64, n: usize) -> Vec<i8> {
        (0..n).map(|_| (rng.below(3) as i8) - 1).collect()
    }

    /// Random 3:4-sparse ternary vector (n % 4 == 0): one zero per block.
    pub fn sparse34_vec(rng: &mut Pcg64, n: usize) -> Vec<i8> {
        assert_eq!(n % 4, 0);
        let mut t = Vec::with_capacity(n);
        for _ in 0..n / 4 {
            let z = rng.below(4) as usize;
            for lane in 0..4 {
                if lane == z {
                    t.push(0i8);
                } else {
                    t.push(if rng.below(2) == 0 { -1 } else { 1 });
                }
            }
        }
        t
    }
}

/// Shrinker: halve the length of a Vec (front half), useful default.
pub fn shrink_vec_halves<T: Clone>(v: &Vec<T>) -> Vec<Vec<T>> {
    let mut out = Vec::new();
    if v.len() > 1 {
        out.push(v[..v.len() / 2].to_vec());
        out.push(v[v.len() / 2..].to_vec());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("sum is commutative", 100, |r| (r.next_f32(), r.next_f32()), |&(a, b)| {
            if (a + b - (b + a)).abs() < 1e-9 {
                Ok(())
            } else {
                Err("not commutative".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_context() {
        check("always fails", 10, |r| r.next_u64(), |_| Err("boom".into()));
    }

    #[test]
    fn shrinking_reduces_input() {
        let result = std::panic::catch_unwind(|| {
            check_with_shrink(
                "vec contains 7",
                100,
                |r| (0..32).map(|_| r.below(10)).collect::<Vec<u64>>(),
                shrink_vec_halves,
                |v| {
                    if v.contains(&7) {
                        Err("has a 7".into())
                    } else {
                        Ok(())
                    }
                },
            )
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // The shrunk failing input should be much smaller than 32 elems.
        let input_part = msg.split("input: ").nth(1).unwrap();
        let commas = input_part.split("error:").next().unwrap().matches(',').count();
        assert!(commas < 16, "shrinker did not reduce: {msg}");
    }

    #[test]
    fn sparse34_gen_invariant() {
        let mut r = Pcg64::seeded(1);
        for _ in 0..50 {
            let t = gens::sparse34_vec(&mut r, 64);
            for b in t.chunks(4) {
                assert_eq!(b.iter().filter(|&&x| x == 0).count(), 1);
            }
        }
    }
}
