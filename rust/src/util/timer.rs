//! Wall-clock timing helpers for the bench harness and metrics.

use std::time::{Duration, Instant};

/// Measure `f`, returning (result, elapsed).
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed())
}

/// Simple stopwatch accumulating named segments (used by the training
/// driver to attribute step time to data/compute/logging).
#[derive(Default)]
pub struct Stopwatch {
    segments: Vec<(String, Duration)>,
    current: Option<(String, Instant)>,
}

impl Stopwatch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Start (or switch to) segment `name`, closing any open segment.
    pub fn start(&mut self, name: &str) {
        self.stop();
        self.current = Some((name.to_string(), Instant::now()));
    }

    /// Close the open segment, if any.
    pub fn stop(&mut self) {
        if let Some((name, t0)) = self.current.take() {
            self.segments.push((name, t0.elapsed()));
        }
    }

    /// Total time attributed to `name` across all segments.
    pub fn total(&self, name: &str) -> Duration {
        self.segments
            .iter()
            .filter(|(n, _)| n == name)
            .map(|(_, d)| *d)
            .sum()
    }

    /// (name, total) for each distinct segment, in first-seen order.
    pub fn summary(&self) -> Vec<(String, Duration)> {
        let mut order: Vec<String> = Vec::new();
        for (n, _) in &self.segments {
            if !order.contains(n) {
                order.push(n.clone());
            }
        }
        order.into_iter().map(|n| (n.clone(), self.total(&n))).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_returns_result() {
        let (x, d) = time_it(|| 21 * 2);
        assert_eq!(x, 42);
        assert!(d.as_nanos() > 0);
    }

    #[test]
    fn stopwatch_accumulates() {
        let mut sw = Stopwatch::new();
        sw.start("a");
        std::thread::sleep(Duration::from_millis(2));
        sw.start("b");
        std::thread::sleep(Duration::from_millis(1));
        sw.start("a");
        sw.stop();
        assert!(sw.total("a") >= Duration::from_millis(2));
        assert!(sw.total("b") >= Duration::from_millis(1));
        assert_eq!(sw.summary().len(), 2);
        assert_eq!(sw.summary()[0].0, "a");
    }
}
