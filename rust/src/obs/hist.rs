//! Bounded log-linear (HDR-style) histograms for latency metrics.
//!
//! Values are recorded as u64 nanoseconds into buckets that are linear
//! within each power-of-two octave: [`SUB_BUCKET_BITS`] = 5 gives 32
//! sub-buckets per octave, so a bucket spanning `[v, v + v/32)` quotes
//! its midpoint with relative error ≤ 1/64 ≈ 1.56% — inside the ~2%
//! bound DESIGN.md §9 documents. Values below 32ns are exact. Memory is
//! **fixed**: at most [`N_BUCKETS`] u64 counts (~15 KiB), allocated
//! lazily on the first record, no matter how many samples arrive — the
//! property that replaces the serving metrics' unbounded `Vec<f64>`
//! reservoirs. Exact count/sum/min/max are tracked alongside, so `mean`,
//! `min` and `max` carry no quantization error and percentile estimates
//! are clamped into `[min, max]`.

/// Sub-bucket resolution bits: 32 linear sub-buckets per octave.
pub const SUB_BUCKET_BITS: u32 = 5;
const SUB: usize = 1 << SUB_BUCKET_BITS; // 32

/// Total buckets covering the full u64 range: one linear run for values
/// < 32, then 59 octaves × 32 sub-buckets up to 2^64.
pub const N_BUCKETS: usize = SUB * (64 - SUB_BUCKET_BITS as usize + 1); // 1920

/// Bucket index for a value (total order, adjacent buckets contiguous).
#[inline]
fn bucket_index(v: u64) -> usize {
    if v < SUB as u64 {
        return v as usize; // exact below one octave of sub-buckets
    }
    let h = 63 - v.leading_zeros(); // floor(log2 v), ≥ SUB_BUCKET_BITS
    let octave = (h - SUB_BUCKET_BITS + 1) as usize;
    let sub = ((v >> (h - SUB_BUCKET_BITS)) as usize) & (SUB - 1);
    octave * SUB + sub
}

/// Lowest value mapping to `index` and the bucket's width.
fn bucket_bounds(index: usize) -> (u64, u64) {
    if index < SUB {
        return (index as u64, 1);
    }
    let octave = (index / SUB) as u32;
    let sub = (index % SUB) as u64;
    let width = 1u64 << (octave - 1);
    ((SUB as u64 + sub) << (octave - 1), width)
}

/// The value a bucket reports for everything it absorbed (midpoint).
fn representative(index: usize) -> u64 {
    let (lo, width) = bucket_bounds(index);
    lo + width / 2
}

/// Fixed-memory log-linear histogram of nanosecond values.
#[derive(Clone, Default)]
pub struct LogHistogram {
    /// Lazily allocated (`N_BUCKETS` once the first value arrives) so an
    /// empty histogram in a Metrics struct costs three words.
    counts: Vec<u64>,
    count: u64,
    sum: u64,
    min: u64,
    max: u64,
}

impl LogHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one value in nanoseconds.
    pub fn record(&mut self, nanos: u64) {
        if self.counts.is_empty() {
            self.counts = vec![0u64; N_BUCKETS];
        }
        self.counts[bucket_index(nanos)] += 1;
        if self.count == 0 {
            self.min = nanos;
            self.max = nanos;
        } else {
            self.min = self.min.min(nanos);
            self.max = self.max.max(nanos);
        }
        self.count += 1;
        self.sum = self.sum.saturating_add(nanos);
    }

    /// Record a duration in seconds (negative / non-finite clamp to 0).
    pub fn record_secs(&mut self, secs: f64) {
        let nanos = if secs.is_finite() && secs > 0.0 { (secs * 1e9).round() as u64 } else { 0 };
        self.record(nanos);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Exact minimum in seconds (0 when empty).
    pub fn min_secs(&self) -> f64 {
        self.min as f64 * 1e-9
    }

    /// Exact maximum in seconds (0 when empty).
    pub fn max_secs(&self) -> f64 {
        self.max as f64 * 1e-9
    }

    /// Exact mean in seconds (0 when empty).
    pub fn mean_secs(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.sum as f64 / self.count as f64 * 1e-9
    }

    /// Nearest-rank percentile estimate in seconds, `p` in [0, 100]:
    /// the midpoint of the bucket holding the ⌈p·count/100⌉-th smallest
    /// sample, clamped into the exact `[min, max]` — so single-valued
    /// histograms and the extreme percentiles are exact, and everything
    /// else is within the bucket's ≤ 1.56% relative error.
    pub fn percentile_secs(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = ((p / 100.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                let est = representative(i).clamp(self.min, self.max);
                return est as f64 * 1e-9;
            }
        }
        self.max_secs()
    }

    pub fn p50(&self) -> f64 {
        self.percentile_secs(50.0)
    }

    pub fn p90(&self) -> f64 {
        self.percentile_secs(90.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile_secs(99.0)
    }

    pub fn p999(&self) -> f64 {
        self.percentile_secs(99.9)
    }
}

// Manual Debug: a 1920-bucket dump would swamp every `{:?}` of Metrics.
impl std::fmt::Debug for LogHistogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("LogHistogram")
            .field("count", &self.count)
            .field("min_s", &self.min_secs())
            .field("p50_s", &self.p50())
            .field("max_s", &self.max_secs())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_is_monotonic_and_contains_value() {
        // Probe around every power of two (the octave boundaries where
        // the index math could go wrong) plus a mid-bucket offset.
        let mut vals: Vec<u64> = vec![0, u64::MAX];
        for shift in 0..64u32 {
            let p = 1u128 << shift;
            for near in [-1i128, 0, 1, 17] {
                let v = p + near;
                if (0..=u64::MAX as u128).contains(&(v as u128)) && v >= 0 {
                    vals.push(v as u64);
                }
            }
        }
        vals.sort_unstable();
        vals.dedup();
        let mut prev = 0usize;
        for v in vals {
            let i = bucket_index(v);
            assert!(i < N_BUCKETS, "v={v} i={i}");
            assert!(i >= prev, "index must be monotone in the value (v={v})");
            let (lo, w) = bucket_bounds(i);
            assert!(v >= lo, "v={v} below bucket lo={lo}");
            assert!((v - lo) < w.max(1), "v={v} past bucket [{lo}, {lo}+{w})");
            prev = i;
        }
    }

    #[test]
    fn small_values_are_exact() {
        for v in 0..SUB as u64 {
            assert_eq!(bucket_index(v), v as usize);
            assert_eq!(representative(v as usize), v);
        }
    }

    #[test]
    fn relative_error_is_within_bound() {
        // Midpoint error ≤ width/2 / lo = 2^(o-1) / (2·(32+sub)·2^(o-1))
        // ≤ 1/64 for every bucket past the exact run.
        for v in [33u64, 100, 1_000, 123_456, 10_000_000_000, u64::MAX / 3] {
            let rep = representative(bucket_index(v));
            let err = (rep as f64 - v as f64).abs() / v as f64;
            assert!(err <= 1.0 / 64.0 + 1e-12, "v={v} rep={rep} err={err}");
        }
    }

    #[test]
    fn percentiles_track_exact_sorting_within_documented_error() {
        // The satellite acceptance test: recorded percentiles vs. exact
        // sorted percentiles on a skewed sample, within the ≤ 2%
        // documented relative error (actual bound 1/64).
        let mut h = LogHistogram::new();
        let mut xs: Vec<u64> = Vec::new();
        let mut state = 0x9e3779b97f4a7c15u64;
        for _ in 0..10_000 {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            // Log-uniform-ish spread over ~5 decades of nanoseconds.
            let v = 1_000 + (state >> 40) * ((state >> 20) & 0xfff) % 100_000_000;
            xs.push(v);
            h.record(v);
        }
        xs.sort_unstable();
        for p in [50.0, 90.0, 99.0, 99.9] {
            let rank = ((p / 100.0) * xs.len() as f64).ceil().max(1.0) as usize - 1;
            let exact = xs[rank] as f64 * 1e-9;
            let got = h.percentile_secs(p);
            let err = (got - exact).abs() / exact;
            assert!(err <= 0.02, "p{p}: got {got}, exact {exact}, err {err}");
        }
        assert_eq!(h.count(), 10_000);
        assert_eq!(h.min_secs(), xs[0] as f64 * 1e-9);
        assert_eq!(h.max_secs(), *xs.last().unwrap() as f64 * 1e-9);
    }

    #[test]
    fn memory_is_fixed_no_matter_the_sample_count() {
        let mut h = LogHistogram::new();
        assert_eq!(h.counts.capacity(), 0, "empty histogram holds no buckets");
        for i in 0..100_000u64 {
            h.record(i * 31);
        }
        assert_eq!(h.counts.len(), N_BUCKETS, "bucket storage never grows past the fixed cap");
        assert_eq!(h.count(), 100_000);
    }

    #[test]
    fn single_value_percentiles_are_exact_and_empty_is_zero() {
        let mut h = LogHistogram::new();
        h.record_secs(0.125);
        for p in [0.0, 50.0, 99.0, 100.0] {
            assert_eq!(h.percentile_secs(p), 0.125, "clamp to [min,max] makes this exact");
        }
        assert_eq!(h.mean_secs(), 0.125);
        let e = LogHistogram::new();
        assert_eq!(e.p50(), 0.0);
        assert_eq!(e.mean_secs(), 0.0);
        assert!(e.is_empty());
    }

    #[test]
    fn record_secs_clamps_junk() {
        let mut h = LogHistogram::new();
        h.record_secs(-1.0);
        h.record_secs(f64::NAN);
        h.record_secs(f64::INFINITY);
        assert_eq!(h.count(), 3);
        assert_eq!(h.max_secs(), 0.0, "junk inputs land at 0, never panic");
    }
}
