//! Dependency-free JSON tree for `Metrics::snapshot()` export.
//!
//! The build is hermetic (no serde), so structured export is a small
//! value enum with a renderer and a minimal parser. Objects keep
//! insertion order (a `Vec` of pairs, not a map) so snapshots render
//! deterministically and diffs stay readable. The parser exists for the
//! golden round-trip tests and the CI snapshot validator's Rust-side
//! counterpart — it accepts exactly the JSON the renderer emits plus
//! ordinary whitespace, which is all this crate ever needs to read back.

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    /// All numbers are f64 (integers render without a fraction).
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Insertion-ordered key/value pairs.
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object builder seed: `Json::obj().field("k", v)...`.
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Append a field (object values only; panics otherwise — builder
    /// misuse is a programming error, not a data error).
    pub fn field(mut self, key: &str, value: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(pairs) => pairs.push((key.to_string(), value.into())),
            _ => panic!("field() on non-object Json"),
        }
        self
    }

    /// Member lookup on objects.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(xs) => Some(xs),
            _ => None,
        }
    }

    /// Compact one-line rendering.
    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty rendering with 2-space indentation (the `--metrics-json`
    /// file format: humans read these).
    pub fn render_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => out.push_str(&render_num(*x)),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(xs) => {
                write_seq(out, indent, depth, '[', ']', xs.len(), |out, i, ind, d| {
                    xs[i].write(out, ind, d);
                });
            }
            Json::Obj(pairs) => {
                write_seq(out, indent, depth, '{', '}', pairs.len(), |out, i, ind, d| {
                    write_escaped(out, &pairs[i].0);
                    out.push_str(": ");
                    pairs[i].1.write(out, ind, d);
                });
            }
        }
    }

    /// Parse a JSON document (whole-input; trailing garbage is an error).
    pub fn parse(src: &str) -> Result<Json, String> {
        let bytes = src.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(format!("trailing input at byte {pos}"));
        }
        Ok(v)
    }
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}

impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}

impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}

impl From<u32> for Json {
    fn from(x: u32) -> Json {
        Json::Num(x as f64)
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}

fn render_num(x: f64) -> String {
    if !x.is_finite() {
        // JSON has no NaN/Inf; null is the conventional degradation.
        return "null".to_string();
    }
    if x.fract() == 0.0 && x.abs() < 9.007_199_254_740_992e15 {
        format!("{}", x as i64)
    } else {
        format!("{x}")
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_seq(
    out: &mut String,
    indent: Option<usize>,
    depth: usize,
    open: char,
    close: char,
    len: usize,
    mut item: impl FnMut(&mut String, usize, Option<usize>, usize),
) {
    out.push(open);
    if len == 0 {
        out.push(close);
        return;
    }
    for i in 0..len {
        if let Some(w) = indent {
            out.push('\n');
            out.push_str(&" ".repeat(w * (depth + 1)));
        }
        item(out, i, indent, depth + 1);
        if i + 1 < len {
            out.push(',');
            if indent.is_none() {
                out.push(' ');
            }
        }
    }
    if let Some(w) = indent {
        out.push('\n');
        out.push_str(&" ".repeat(w * depth));
    }
    out.push(close);
}

// --- minimal recursive-descent parser ---

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, c: u8) -> Result<(), String> {
    if *pos < b.len() && b[*pos] == c {
        *pos += 1;
        Ok(())
    } else {
        Err(format!("expected '{}' at byte {}", c as char, pos))
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(b, pos);
    match b.get(*pos) {
        None => Err("unexpected end of input".into()),
        Some(b'n') => parse_lit(b, pos, "null", Json::Null),
        Some(b't') => parse_lit(b, pos, "true", Json::Bool(true)),
        Some(b'f') => parse_lit(b, pos, "false", Json::Bool(false)),
        Some(b'"') => Ok(Json::Str(parse_string(b, pos)?)),
        Some(b'[') => {
            *pos += 1;
            let mut xs = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(Json::Arr(xs));
            }
            loop {
                xs.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(xs));
                    }
                    _ => return Err(format!("expected ',' or ']' at byte {pos}")),
                }
            }
        }
        Some(b'{') => {
            *pos += 1;
            let mut pairs = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(Json::Obj(pairs));
            }
            loop {
                skip_ws(b, pos);
                let key = parse_string(b, pos)?;
                skip_ws(b, pos);
                expect(b, pos, b':')?;
                let val = parse_value(b, pos)?;
                pairs.push((key, val));
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(pairs));
                    }
                    _ => return Err(format!("expected ',' or '}}' at byte {pos}")),
                }
            }
        }
        Some(_) => parse_number(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, v: Json) -> Result<Json, String> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(v)
    } else {
        Err(format!("bad literal at byte {pos}"))
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, String> {
    expect(b, pos, b'"')?;
    let mut s = String::new();
    loop {
        match b.get(*pos) {
            None => return Err("unterminated string".into()),
            Some(b'"') => {
                *pos += 1;
                return Ok(s);
            }
            Some(b'\\') => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let hex = b
                            .get(*pos + 1..*pos + 5)
                            .ok_or_else(|| "truncated \\u escape".to_string())?;
                        let code = u32::from_str_radix(
                            std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                            16,
                        )
                        .map_err(|e| e.to_string())?;
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    _ => return Err(format!("bad escape at byte {pos}")),
                }
                *pos += 1;
            }
            Some(_) => {
                // Consume one UTF-8 scalar (input is a &str, so byte
                // boundaries are valid).
                let start = *pos;
                *pos += 1;
                while *pos < b.len() && (b[*pos] & 0xc0) == 0x80 {
                    *pos += 1;
                }
                s.push_str(std::str::from_utf8(&b[start..*pos]).map_err(|e| e.to_string())?);
            }
        }
    }
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    let text = std::str::from_utf8(&b[start..*pos]).map_err(|e| e.to_string())?;
    text.parse::<f64>().map(Json::Num).map_err(|_| format!("bad number '{text}' at byte {start}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_compact_and_pretty() {
        let v = Json::obj()
            .field("name", "sherry")
            .field("n", 3u64)
            .field("pi", 3.5)
            .field("ok", true)
            .field("xs", Json::Arr(vec![Json::Num(1.0), Json::Num(2.0)]))
            .field("none", Json::Null);
        assert_eq!(
            v.render(),
            r#"{"name": "sherry", "n": 3, "pi": 3.5, "ok": true, "xs": [1, 2], "none": null}"#
        );
        let pretty = v.render_pretty();
        assert!(pretty.contains("\n  \"name\": \"sherry\","), "{pretty}");
        assert!(pretty.ends_with("}\n"));
    }

    #[test]
    fn roundtrips_through_parse() {
        let v = Json::obj()
            .field("s", "a \"quoted\"\nline\twith \\ stuff")
            .field("neg", -1.25)
            .field("big", 1.0e18)
            .field("deep", Json::Arr(vec![Json::obj().field("k", Json::Arr(vec![]))]))
            .field("empty", Json::obj());
        for text in [v.render(), v.render_pretty()] {
            assert_eq!(Json::parse(&text).unwrap(), v, "{text}");
        }
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1, 2,]").is_err());
        assert!(Json::parse("{\"a\": 1} trailing").is_err());
        assert!(Json::parse("nope").is_err());
    }

    #[test]
    fn accessors_navigate_the_tree() {
        let v = Json::parse(r#"{"a": {"b": [1, "x"]}, "c": 2}"#).unwrap();
        assert_eq!(v.get("c").and_then(Json::as_f64), Some(2.0));
        let b = v.get("a").and_then(|a| a.get("b")).and_then(Json::as_arr).unwrap();
        assert_eq!(b[1].as_str(), Some("x"));
        assert_eq!(v.get("missing"), None);
    }

    #[test]
    fn nonfinite_numbers_degrade_to_null() {
        assert_eq!(Json::Num(f64::NAN).render(), "null");
        assert_eq!(Json::Num(f64::INFINITY).render(), "null");
    }
}
