//! Low-overhead serving observability: phase/kernel tracing, bounded
//! log-linear latency histograms, and structured metrics export.
//!
//! Three pieces (DESIGN.md §9):
//!
//! 1. **Phase spans** — a [`PhaseClock`] owned by one serve run times the
//!    coordinator loop's disjoint phases (admission, prefix lookup,
//!    ragged prefill, decode) with RAII guards over monotonic
//!    [`Instant`]s. The coordinator is single-threaded, spans never
//!    nest, and idle sleeps are deliberately untimed, so the phase total
//!    is always ≤ the run's wall seconds (asserted in tests).
//! 2. **Kernel spans** — [`KernelSpan`] guards at the `simd::` dispatch
//!    call sites attribute CPU-seconds to the dispatched hot loops (the
//!    i8 q·k dot, the ternary q·k LUT walk, the fixed-point a·V
//!    accumulation, the three LUT-GEMM tile walks, and the f32 fallback
//!    arms). Kernel accounting is process-global ([`kernel_totals`])
//!    because the engine call sites have no server handle; a run
//!    captures a baseline at start and reports the delta, like
//!    `kv_dequant_seconds`. GEMM walks run on worker threads, so their
//!    CPU-seconds sum across workers and may exceed wall time.
//! 3. **Trace levels** — the process-global [`TraceLevel`] gates kernel
//!    spans: at `Off` and `Phases` a [`KernelSpan`] costs exactly one
//!    relaxed atomic load and performs **no clock reads**, so leaving
//!    the guards compiled into the hot loops is free in the sense the
//!    `--trace` contract documents. Phase spans are gated per run by
//!    `ServerConfig::trace` (an `Off` run's clock records nothing), so
//!    parallel tests never race on phase state.
//!
//! [`hist::LogHistogram`] (bounded HDR-style percentiles),
//! [`json::Json`] (dependency-free serialization for
//! `Metrics::snapshot()`), and [`ring::FlightRecorder`] (per-round
//! flight recorder) round out the subsystem.

pub mod hist;
pub mod json;
pub mod ring;

use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::time::Instant;

// ---------------------------------------------------------------------------
// Trace levels
// ---------------------------------------------------------------------------

/// How much the serving stack traces. Ordered: each level includes the
/// previous one's instrumentation.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Default)]
pub enum TraceLevel {
    /// No clock reads anywhere: phase spans are disabled per run and a
    /// kernel span is one relaxed atomic load.
    Off,
    /// Coordinator phase spans (admission / prefix lookup / prefill /
    /// decode) — a handful of `Instant` reads per round.
    #[default]
    Phases,
    /// Phases plus per-kernel CPU-second attribution at the `simd::`
    /// dispatch sites (one `Instant` pair per page block / GEMM tile
    /// range, never per row).
    Kernels,
}

impl TraceLevel {
    /// Every level, in CLI-listing order.
    pub const ALL: [TraceLevel; 3] = [TraceLevel::Off, TraceLevel::Phases, TraceLevel::Kernels];

    /// Stable lowercase name (CLI values, metrics report, snapshot).
    pub fn name(self) -> &'static str {
        match self {
            TraceLevel::Off => "off",
            TraceLevel::Phases => "phases",
            TraceLevel::Kernels => "kernels",
        }
    }

    /// Parse a CLI spelling.
    pub fn parse(s: &str) -> Option<TraceLevel> {
        match s {
            "off" => Some(TraceLevel::Off),
            "phases" => Some(TraceLevel::Phases),
            "kernels" => Some(TraceLevel::Kernels),
            _ => None,
        }
    }

    fn from_u8(v: u8) -> TraceLevel {
        match v {
            0 => TraceLevel::Off,
            2 => TraceLevel::Kernels,
            _ => TraceLevel::Phases,
        }
    }
}

/// Process-global trace level; default `Phases`. Kernel spans read this
/// (they have no per-run handle); the serve loop's phase clock is gated
/// by `ServerConfig::trace` instead, so runs don't race on it.
static LEVEL: AtomicU8 = AtomicU8::new(TraceLevel::Phases as u8);

/// Set the process trace level (the `--trace` flag).
pub fn set_trace_level(level: TraceLevel) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// The current process trace level.
pub fn trace_level() -> TraceLevel {
    TraceLevel::from_u8(LEVEL.load(Ordering::Relaxed))
}

/// Are kernel spans live? One relaxed load — the entire cost of a
/// [`KernelSpan`] below the `Kernels` level.
#[inline]
pub fn kernels_on() -> bool {
    LEVEL.load(Ordering::Relaxed) >= TraceLevel::Kernels as u8
}

// ---------------------------------------------------------------------------
// Kernel spans
// ---------------------------------------------------------------------------

/// The dispatched hot loops whose CPU-seconds the tracer attributes.
/// `Qk*`/`Av*` are the page-blocked attention arms (keyed by the KV
/// plane they walk); `Gemm*` are the LUT-GEMM tile walks over packed
/// weight planes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kernel {
    /// `simd::dot_i8` rows over raw int8 K page bytes.
    QkDotI8,
    /// `simd::qk_lut34_rows` LUT walks over packed 1.25-bit K pages.
    QkLut34,
    /// The f32 q·k fallback arm (borrowed f32 pages / dequantized tiles).
    QkF32,
    /// `simd::av_i8_rows` fixed-point accumulation over int8 V pages.
    AvI8,
    /// The f32 a·V fallback arm.
    AvF32,
    /// `simd::gemm_pack34_preluts` — the Sherry 3:4 tile walk.
    GemmPack34,
    /// `simd::gemm_tl2_preluts` — the TL2 tile walk.
    GemmTl2,
    /// `simd::gemm_i2s` — the I2_S decode-and-add walk.
    GemmI2S,
}

/// Number of kernel slots (array sizing).
pub const N_KERNELS: usize = 8;

impl Kernel {
    /// Every kernel, in slot order.
    pub const ALL: [Kernel; N_KERNELS] = [
        Kernel::QkDotI8,
        Kernel::QkLut34,
        Kernel::QkF32,
        Kernel::AvI8,
        Kernel::AvF32,
        Kernel::GemmPack34,
        Kernel::GemmTl2,
        Kernel::GemmI2S,
    ];

    /// Stable snake_case name (snapshot keys, Prometheus labels).
    pub fn name(self) -> &'static str {
        match self {
            Kernel::QkDotI8 => "qk_dot_i8",
            Kernel::QkLut34 => "qk_lut34",
            Kernel::QkF32 => "qk_f32",
            Kernel::AvI8 => "av_i8",
            Kernel::AvF32 => "av_f32",
            Kernel::GemmPack34 => "gemm_pack34",
            Kernel::GemmTl2 => "gemm_tl2",
            Kernel::GemmI2S => "gemm_i2s",
        }
    }

    /// The data plane the kernel walks — the kv-dtype key for attention
    /// kernels ("int8" / "ternary" / "f32"), "weights" for the GEMM
    /// walks over packed weight planes.
    pub fn plane(self) -> &'static str {
        match self {
            Kernel::QkDotI8 | Kernel::AvI8 => "int8",
            Kernel::QkLut34 => "ternary",
            Kernel::QkF32 | Kernel::AvF32 => "f32",
            Kernel::GemmPack34 | Kernel::GemmTl2 | Kernel::GemmI2S => "weights",
        }
    }

    fn slot(self) -> usize {
        self as usize
    }
}

// `static [AtomicU64; N]` needs a const initializer element; the interior
// mutability is the point here (the const is only an array seed).
#[allow(clippy::declare_interior_mutable_const)]
const ATOMIC_ZERO: AtomicU64 = AtomicU64::new(0);
static KERNEL_NANOS: [AtomicU64; N_KERNELS] = [ATOMIC_ZERO; N_KERNELS];
static KERNEL_CALLS: [AtomicU64; N_KERNELS] = [ATOMIC_ZERO; N_KERNELS];

/// RAII guard timing one kernel invocation (one page block or one GEMM
/// tile range — never one row). Below [`TraceLevel::Kernels`] the guard
/// holds no `Instant` and drop is a no-op: enter + drop cost one relaxed
/// atomic load total, which is the `--trace off`/`phases` overhead
/// contract. Tracing never touches kernel inputs or outputs, so numeric
/// parity (bit-for-bit f32, exact i32) is unaffected at every level.
pub struct KernelSpan {
    kernel: Kernel,
    start: Option<Instant>,
}

impl KernelSpan {
    #[inline]
    pub fn enter(kernel: Kernel) -> Self {
        let start = if kernels_on() { Some(Instant::now()) } else { None };
        Self { kernel, start }
    }
}

impl Drop for KernelSpan {
    #[inline]
    fn drop(&mut self) {
        if let Some(t0) = self.start {
            let slot = self.kernel.slot();
            KERNEL_NANOS[slot].fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            KERNEL_CALLS[slot].fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// A point-in-time copy of the process-global kernel counters. A serve
/// run snapshots one at start and reports [`KernelTotals::delta_since`]
/// at the end, so concurrent runs only ever over-attribute (never lose)
/// kernel time — the same cross-run-additive contract as
/// `kv_dequant_seconds`.
#[derive(Clone, Copy, Debug, Default)]
pub struct KernelTotals {
    nanos: [u64; N_KERNELS],
    calls: [u64; N_KERNELS],
}

/// One kernel's accumulated time since a baseline.
#[derive(Clone, Copy, Debug)]
pub struct KernelDelta {
    pub kernel: Kernel,
    pub nanos: u64,
    pub calls: u64,
}

impl KernelTotals {
    /// Per-kernel deltas vs. an earlier snapshot, skipping kernels that
    /// never ran in between.
    pub fn delta_since(&self, base: &KernelTotals) -> Vec<KernelDelta> {
        Kernel::ALL
            .into_iter()
            .map(|k| {
                let s = k.slot();
                KernelDelta {
                    kernel: k,
                    nanos: self.nanos[s].saturating_sub(base.nanos[s]),
                    calls: self.calls[s].saturating_sub(base.calls[s]),
                }
            })
            .filter(|d| d.calls > 0)
            .collect()
    }
}

/// Snapshot the process-global kernel counters.
pub fn kernel_totals() -> KernelTotals {
    let mut t = KernelTotals::default();
    for k in Kernel::ALL {
        let s = k.slot();
        t.nanos[s] = KERNEL_NANOS[s].load(Ordering::Relaxed);
        t.calls[s] = KERNEL_CALLS[s].load(Ordering::Relaxed);
    }
    t
}

// ---------------------------------------------------------------------------
// Phase spans
// ---------------------------------------------------------------------------

/// The coordinator loop's disjoint phases.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Arrival intake + page-counted admission (excluding prefix lookup).
    Admission,
    /// Radix prefix-index lookup and page leasing (`PagedKv::lease`).
    PrefixLookup,
    /// Ragged prefill micro-steps (any sequence fed a prompt token).
    Prefill,
    /// Pure decode micro-steps (every fed token is generated).
    Decode,
}

/// Number of phases (array sizing).
pub const N_PHASES: usize = 4;

impl Phase {
    /// Every phase, in report order.
    pub const ALL: [Phase; N_PHASES] =
        [Phase::Admission, Phase::PrefixLookup, Phase::Prefill, Phase::Decode];

    /// Stable snake_case name (snapshot keys, Prometheus labels).
    pub fn name(self) -> &'static str {
        match self {
            Phase::Admission => "admission",
            Phase::PrefixLookup => "prefix_lookup",
            Phase::Prefill => "prefill",
            Phase::Decode => "decode",
        }
    }

    fn slot(self) -> usize {
        self as usize
    }
}

/// Per-run phase accumulator. One instance per serve run (no global
/// state → parallel tests can't race); atomics make it `Sync` so spans
/// borrow `&self`. Spans on the run's single coordinator thread are
/// strictly disjoint by construction — the serve loop never nests them —
/// so `total_seconds()` ≤ wall seconds.
#[derive(Debug, Default)]
pub struct PhaseClock {
    enabled: bool,
    nanos: [AtomicU64; N_PHASES],
}

impl PhaseClock {
    /// A clock that records (`enabled`) or ignores every span
    /// (`!enabled`, the `--trace off` run mode: no clock reads at all).
    pub fn new(enabled: bool) -> Self {
        Self { enabled, ..Default::default() }
    }

    /// Open a span; time accrues to `phase` when the guard drops.
    #[inline]
    pub fn span(&self, phase: Phase) -> PhaseSpan<'_> {
        let start = if self.enabled { Some(Instant::now()) } else { None };
        PhaseSpan { clock: self, phase, start }
    }

    /// Seconds accumulated in one phase.
    pub fn seconds(&self, phase: Phase) -> f64 {
        self.nanos[phase.slot()].load(Ordering::Relaxed) as f64 * 1e-9
    }

    /// Seconds across all phases (≤ wall when spans are disjoint).
    pub fn total_seconds(&self) -> f64 {
        Phase::ALL.into_iter().map(|p| self.seconds(p)).sum()
    }
}

/// RAII guard for one phase span.
pub struct PhaseSpan<'a> {
    clock: &'a PhaseClock,
    phase: Phase,
    start: Option<Instant>,
}

impl Drop for PhaseSpan<'_> {
    #[inline]
    fn drop(&mut self) {
        if let Some(t0) = self.start {
            self.clock.nanos[self.phase.slot()]
                .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_level_parse_roundtrips() {
        for l in TraceLevel::ALL {
            assert_eq!(TraceLevel::parse(l.name()), Some(l));
        }
        assert_eq!(TraceLevel::parse("verbose"), None);
        assert!(TraceLevel::Off < TraceLevel::Phases);
        assert!(TraceLevel::Phases < TraceLevel::Kernels);
    }

    #[test]
    fn kernel_names_and_planes_are_stable() {
        for k in Kernel::ALL {
            assert!(!k.name().is_empty());
            assert!(["int8", "ternary", "f32", "weights"].contains(&k.plane()), "{}", k.name());
        }
        assert_eq!(Kernel::QkLut34.plane(), "ternary");
        assert_eq!(Kernel::GemmPack34.plane(), "weights");
    }

    #[test]
    fn phase_clock_accumulates_and_disabled_clock_stays_zero() {
        let c = PhaseClock::new(true);
        {
            let _s = c.span(Phase::Decode);
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        assert!(c.seconds(Phase::Decode) > 0.0);
        assert_eq!(c.seconds(Phase::Admission), 0.0);
        assert!(c.total_seconds() >= c.seconds(Phase::Decode));

        let off = PhaseClock::new(false);
        {
            let _s = off.span(Phase::Decode);
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(off.total_seconds(), 0.0, "disabled clocks record nothing");
    }

    #[test]
    fn kernel_spans_record_only_at_kernels_level() {
        // Global level: other tests in the process may have set it; only
        // delta-based invariants are asserted. Deltas snapshot around a
        // span opened at an explicitly raised level, then restore.
        let before_level = trace_level();
        set_trace_level(TraceLevel::Phases);
        let base = kernel_totals();
        {
            let _s = KernelSpan::enter(Kernel::GemmTl2);
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        // Concurrent tests can only have run *other* kernels at Kernels
        // level — nobody else times GemmTl2 in this suite without first
        // raising the level, so a Phases-level span must not move it.
        set_trace_level(TraceLevel::Kernels);
        {
            let _s = KernelSpan::enter(Kernel::GemmTl2);
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        let delta = kernel_totals().delta_since(&base);
        let tl2 = delta.iter().find(|d| d.kernel == Kernel::GemmTl2);
        let tl2 = tl2.expect("Kernels-level span must be recorded");
        assert!(tl2.nanos >= 1_000_000, "~2ms span, got {}ns", tl2.nanos);
        assert!(tl2.calls >= 1);
        set_trace_level(before_level);
    }

    #[test]
    fn delta_since_skips_idle_kernels() {
        let t = kernel_totals();
        assert!(t.delta_since(&t).is_empty(), "zero-delta snapshot reports nothing");
    }
}
