//! The serving loop: trace-driven request arrival → continuous batching →
//! parallel decode rounds on the worker pool → completions + metrics.
//!
//! Decode parallelism is *across sequences*: each active sequence owns a
//! KV cache from the pool and decodes one token per round; rounds fan out
//! over the thread pool with one LUT `Scratch` per worker. (Environment
//! is offline, so "arrival" is simulated from the trace clock; everything
//! downstream of arrival is the real engine.)

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use super::{Batcher, BatcherConfig, Completion, KvPool, Metrics, Request};
use crate::engine::{argmax, KvCache, Scratch, TernaryModel};
use crate::util::{Pcg64, ThreadPool};

/// Server configuration.
#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    pub batcher: BatcherConfig,
    pub kv_capacity: usize,
    pub workers: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self { batcher: BatcherConfig::default(), kv_capacity: 8, workers: ThreadPool::default_size() }
    }
}

/// Synthetic trace parameters (Poisson arrivals).
#[derive(Clone, Copy, Debug)]
pub struct TraceSpec {
    pub n_requests: usize,
    pub mean_interarrival_s: f64,
    pub prompt_len: usize,
    pub max_new_tokens: usize,
    pub seed: u64,
}

impl TraceSpec {
    /// Materialize the request trace.
    pub fn generate(&self, vocab: usize) -> Vec<Request> {
        let mut rng = Pcg64::new(self.seed, 31);
        let mut t = 0.0f64;
        (0..self.n_requests)
            .map(|i| {
                t += -self.mean_interarrival_s * (1.0 - rng.next_f64()).ln();
                Request {
                    id: i as u64,
                    prompt: (0..self.prompt_len).map(|_| rng.below(vocab as u64) as u32).collect(),
                    max_new_tokens: self.max_new_tokens,
                    arrival: t,
                }
            })
            .collect()
    }
}

/// The serving coordinator.
pub struct Server<'m> {
    model: &'m TernaryModel,
    cfg: ServerConfig,
    pool: ThreadPool,
}

struct SeqState {
    cache: KvCache,
    last_token: u32,
    prompt_done: bool,
    tokens: Vec<u32>,
    first_token_at: Option<f64>,
}

impl<'m> Server<'m> {
    pub fn new(model: &'m TernaryModel, cfg: ServerConfig) -> Self {
        let pool = ThreadPool::new(cfg.workers);
        Self { model, cfg, pool }
    }

    /// Run a full trace to completion; returns (completions, metrics).
    pub fn run(&self, mut trace: Vec<Request>) -> (Vec<Completion>, Metrics) {
        trace.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
        let t0 = Instant::now();
        let clock = |t0: Instant| t0.elapsed().as_secs_f64();

        let mut batcher = Batcher::new(self.cfg.batcher);
        let mut kv = KvPool::new(self.model.cfg, self.cfg.kv_capacity);
        let mut metrics = Metrics { requests_in: trace.len() as u64, ..Default::default() };
        let mut completions = Vec::new();
        let mut states: Vec<SeqState> = Vec::new();
        let mut next_arrival = 0usize;
        let tokens_done = AtomicU64::new(0);

        while next_arrival < trace.len() || !batcher.is_idle() {
            // Admit arrivals whose time has come on the wall clock.
            let now = clock(t0);
            while next_arrival < trace.len() && trace[next_arrival].arrival <= now {
                batcher.submit(trace[next_arrival].clone());
                next_arrival += 1;
            }
            // Idle with future arrivals: sleep toward the next one.
            if batcher.is_idle() {
                if next_arrival >= trace.len() {
                    break;
                }
                next_arrival_sleep(trace[next_arrival].arrival - clock(t0));
                continue;
            }

            // Admission bounded by both the batcher and the KV pool.
            let before = batcher.active_len();
            batcher.admit();
            for _ in before..batcher.active_len() {
                let cache = match kv.acquire() {
                    Some(c) => c,
                    None => {
                        // KV pool exhausted: put the last admitted back.
                        // (batcher max_active should be ≤ kv capacity; this
                        // is a safety valve.)
                        break;
                    }
                };
                let (req, _) = &batcher.active()[states.len()];
                states.push(SeqState {
                    cache,
                    last_token: *req.prompt.first().unwrap_or(&0),
                    prompt_done: false,
                    tokens: Vec::new(),
                    first_token_at: None,
                });
            }

            if batcher.active_len() == 0 {
                if next_arrival >= trace.len() && batcher.waiting_len() == 0 {
                    break;
                }
                continue;
            }

            // One decode round across active sequences, in parallel.
            {
                let model = self.model;
                let active: Vec<(usize, Request)> = batcher
                    .active()
                    .iter()
                    .enumerate()
                    .map(|(i, (r, _))| (i, r.clone()))
                    .collect();
                let states_mu: Vec<Mutex<&mut SeqState>> =
                    states.iter_mut().map(Mutex::new).collect();
                let td = &tokens_done;
                self.pool.scope(|s| {
                    for (i, req) in active {
                        let st_mu = &states_mu[i];
                        s.spawn(move || {
                            let mut st = st_mu.lock().unwrap();
                            let mut scratch = Scratch::default();
                            if !st.prompt_done {
                                // Prefill: feed the whole prompt.
                                let mut logits = Vec::new();
                                for &t in &req.prompt {
                                    logits = model.forward_one(t, &mut st.cache, &mut scratch);
                                }
                                st.last_token = argmax(&logits) as u32;
                                st.prompt_done = true;
                            } else {
                                let tok = st.last_token;
                                let logits = model.forward_one(tok, &mut st.cache, &mut scratch);
                                st.last_token = argmax(&logits) as u32;
                            }
                            let last = st.last_token;
                            st.tokens.push(last);
                            td.fetch_add(1, Ordering::Relaxed);
                        });
                    }
                });
            }
            metrics.decode_rounds += 1;

            // Bookkeeping: advance, record first-token times, retire.
            let now = clock(t0);
            let mut finished = Vec::new();
            for i in 0..batcher.active_len() {
                if states[i].first_token_at.is_none() {
                    states[i].first_token_at = Some(now);
                }
                let done = batcher.advance(i)
                    || states[i].cache.len + 1 >= self.model.cfg.seq_len;
                if done {
                    finished.push(i);
                }
            }
            // retire uses swap_remove; mirror it on `states`.
            for &i in finished.iter().rev() {
                let st = states.swap_remove(i);
                let (req, _gen) = (
                    batcher.active()[i].0.clone(),
                    batcher.active()[i].1,
                );
                kv.release(st.cache);
                completions.push(Completion {
                    id: req.id,
                    tokens: st.tokens,
                    ttft: st.first_token_at.unwrap_or(now) - req.arrival,
                    latency: now - req.arrival,
                });
                metrics.ttfts.push(st.first_token_at.unwrap_or(now) - req.arrival);
                metrics.latencies.push(now - req.arrival);
            }
            batcher.retire(&finished);
        }

        metrics.requests_done = completions.len() as u64;
        metrics.tokens_generated = tokens_done.load(Ordering::Relaxed);
        metrics.wall_seconds = clock(t0);
        (completions, metrics)
    }
}

fn next_arrival_sleep(dt: f64) {
    if dt > 0.0 {
        std::thread::sleep(std::time::Duration::from_secs_f64(dt.min(0.01)));
    }
}

/// Convenience: build a trace, serve it, return metrics.
pub fn serve_trace(model: &TernaryModel, server_cfg: ServerConfig, trace: TraceSpec) -> (Vec<Completion>, Metrics) {
    let reqs = trace.generate(model.cfg.vocab_size);
    Server::new(model, server_cfg).run(reqs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{random_weights, NativeConfig, TernaryModel};
    use crate::pack::Format;

    fn model() -> TernaryModel {
        let cfg = NativeConfig::named("nano").unwrap();
        TernaryModel::build(cfg, &random_weights(&cfg, 0), Format::Sherry)
    }

    #[test]
    fn serves_all_requests() {
        let m = model();
        let (completions, metrics) = serve_trace(
            &m,
            ServerConfig::default(),
            TraceSpec { n_requests: 6, mean_interarrival_s: 0.0, prompt_len: 4, max_new_tokens: 5, seed: 1 },
        );
        assert_eq!(completions.len(), 6);
        assert_eq!(metrics.requests_done, 6);
        for c in &completions {
            assert_eq!(c.tokens.len(), 5);
            assert!(c.latency >= 0.0 && c.ttft >= 0.0);
            assert!(c.ttft <= c.latency + 1e-9);
        }
    }

    #[test]
    fn deterministic_tokens_per_request() {
        let m = model();
        let spec = TraceSpec { n_requests: 3, mean_interarrival_s: 0.0, prompt_len: 3, max_new_tokens: 4, seed: 7 };
        let (c1, _) = serve_trace(&m, ServerConfig::default(), spec);
        let (c2, _) = serve_trace(&m, ServerConfig::default(), spec);
        let mut c1 = c1;
        let mut c2 = c2;
        c1.sort_by_key(|c| c.id);
        c2.sort_by_key(|c| c.id);
        for (a, b) in c1.iter().zip(&c2) {
            assert_eq!(a.tokens, b.tokens);
        }
    }

    #[test]
    fn respects_max_active() {
        let m = model();
        let cfg = ServerConfig {
            batcher: BatcherConfig { max_active: 2, token_budget: 100_000 },
            kv_capacity: 2,
            workers: 2,
        };
        let (completions, metrics) = serve_trace(
            &m,
            cfg,
            TraceSpec { n_requests: 5, mean_interarrival_s: 0.0, prompt_len: 2, max_new_tokens: 3, seed: 2 },
        );
        assert_eq!(completions.len(), 5);
        assert!(metrics.decode_rounds >= 3, "must take multiple rounds");
    }
}
