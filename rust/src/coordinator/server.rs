//! The serving loop: trace-driven request arrival → continuous batching →
//! fused batched decode rounds on the LUT engine → completions + metrics.
//!
//! Decode parallelism is *inside the kernel*: each round gathers every
//! active sequence's next token and issues one
//! [`TernaryModel::forward_kv`] call — one fused LUT-GEMM per layer with
//! all sequences' activation tables resident, fanned out over
//! output-channel tiles on the worker pool. Newly admitted sequences
//! prefill their whole prompt inside their first round via ragged
//! micro-steps that stay fused across sequences at the same prompt
//! offset.
//!
//! KV storage is the paged subsystem (`crate::cache`): sequences decode
//! through per-sequence block tables over one refcounted arena, admission
//! is counted in free pages (so short requests no longer reserve
//! worst-case contiguous caches), and a prompt whose prefix was already
//! served reuses the frozen KV pages of that prefix — prefill for the
//! shared span is skipped entirely. The arena's storage dtype is the
//! `kv_dtype` policy: f32 pages are the bit-for-bit parity baseline;
//! int8 pages (per-page-per-head scales, `PageStore`) hold the same
//! byte budget in ~4× the pages, run the attention score pass
//! int8-natively (i32 q·k dots over raw page bytes — the
//! `kv_int8_dot_fraction` gauge), and share prefixes at whole-page
//! granularity with registration-frozen scales, so quantization buys
//! admission concurrency as well as footprint. Ternary pages push the
//! K side to 1.25 bits/weight (pack34 3:4-sparse codes, V stays int8)
//! and run the score pass as per-query LUT walks over the packed codes
//! (the `kv_qk_rows_ternary` gauge) — K is never dequantized. The a·V
//! pass is integer too: after softmax, each (page, head) weight group is
//! quantized to u8 fixed point and accumulated in i32 over raw int8 V
//! page bytes (the `kv_av_rows_int8` gauge), so with the default
//! `integer_av` a quantized pool's decode round performs **zero** f32
//! dequantization of K or V page bytes — `kv_dequant_seconds` meters
//! only residual dequantization off the hot path. Because
//! batched and single-row kernels are bit-for-bit identical and shared
//! KV pages are a deterministic function of the token prefix
//! (byte-exact for frozen quantized pages), a request's tokens do not
//! depend on which sequences share its rounds, on paging, on prefix
//! hits, or on arrival order.
//! (Environment is offline, so "arrival" is simulated from the trace
//! clock; everything downstream of arrival is the real engine.)
//!
//! Scheduling is SLO-aware. **Chunked prefill** caps how many prompt
//! tokens one sequence may feed per round
//! ([`ServerConfig::prefill_chunk_tokens`], default one page; 0 =
//! legacy monolithic), so a long prompt interleaves with decode
//! micro-steps instead of stalling every resident decoder behind its
//! whole prompt. **Priority classes** ([`super::Priority`]) give the
//! batcher strict-priority, per-class FIFO queues with an aging bound
//! on Batch starvation. **Preemption** ([`Preemption`]) responds to
//! page pressure by parking the most recently admitted lower-priority
//! active sequence — its pages are released, its sampler and generated
//! tokens survive in a parked record, and a later re-admission rebuilds
//! its KV state by re-prefilling the prompt (through the prefix index,
//! where frozen full pages replay byte-exact) and replaying the
//! already-generated tokens without emitting. Because KV pages are a
//! deterministic function of the token prefix, the restored sequence
//! continues with exactly the tokens it would have produced unpreempted.

use std::collections::HashMap;
use std::time::Instant;

use super::{
    Batcher, BatcherConfig, Completion, FinishReason, KernelStat, Metrics, PagedKv, Priority,
    Request, Sampler, SamplerConfig,
};
use crate::cache::{BlockTable, KvBatch, KvDtype};
use crate::engine::TernaryModel;
use crate::obs::ring::RoundRecord;
use crate::obs::{self, Phase, PhaseClock, TraceLevel};
use crate::util::{Pcg64, ThreadPool};

/// When the scheduler may preempt an active sequence.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Preemption {
    /// Never preempt: a blocked queue head waits for natural retirements
    /// (the index-flush pressure valve still applies). The comparison
    /// baseline for the invariance tests.
    Never,
    /// Preempt only when an admission wave admits nothing while a
    /// strictly higher-priority request waits at a queue head (default).
    UnderPressure,
    /// Preempt whenever a strictly higher-priority request waits at a
    /// queue head, even with pages to spare — the forcing leg for tests
    /// and the pressure bench.
    Always,
}

impl Preemption {
    /// Stable lowercase name (CLI values, metric labels).
    pub fn name(self) -> &'static str {
        match self {
            Preemption::Never => "never",
            Preemption::UnderPressure => "pressure",
            Preemption::Always => "always",
        }
    }

    /// Parse a CLI name produced by [`Preemption::name`].
    pub fn parse(s: &str) -> Option<Preemption> {
        match s {
            "never" => Some(Preemption::Never),
            "pressure" => Some(Preemption::UnderPressure),
            "always" => Some(Preemption::Always),
            _ => None,
        }
    }
}

/// Server configuration.
#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    pub batcher: BatcherConfig,
    /// KV byte budget in f32 whole-cache equivalents (the seed's knob):
    /// the paged arena gets however many `page_size` pages *at
    /// `kv_dtype`* fit in the bytes `kv_capacity` contiguous f32 caches
    /// held — so int8 pools admit more sequences at the same budget.
    pub kv_capacity: usize,
    /// Positions per KV page.
    pub page_size: usize,
    /// KV page storage dtype (f32 parity baseline / int8 quantized /
    /// 1.25-bit ternary K with int8 V).
    pub kv_dtype: KvDtype,
    /// Reuse frozen KV pages across requests sharing a prompt prefix.
    /// Works for both dtypes: f32 pools share down to a page's live
    /// prefix; quantized pools share whole registration-frozen pages
    /// only, which keeps reuse byte-exact and completions independent of
    /// serving order (see `PagedKv::new`).
    pub prefix_sharing: bool,
    /// Frozen-tile LRU capacity (tiles) for quantized pools: a shared
    /// prefix page read by N sequences is dequantized once per cache
    /// residency instead of N times per round. With `integer_av` on the
    /// cache is off the decode hot path — it serves residual f32
    /// consumers only, and admission is lease-gated (≥ 2 leases).
    /// 0 disables; ignored by f32 pools (their block reads are borrows).
    pub tile_cache_tiles: usize,
    /// Integer a·V accumulation for quantized pools (default on): the V
    /// pass quantizes softmax weights to u8 fixed point per (page, head)
    /// and accumulates in i32 over raw int8 V page bytes — no f32
    /// dequantization on the decode hot path. Off forces the V pass back
    /// through f32 tiles (the bench comparison leg); f32 pools ignore it.
    pub integer_av: bool,
    /// Max prompt (or restore-replay) tokens one sequence feeds per
    /// decode round — chunked prefill. Default is one page
    /// (`page_size`); 0 means the legacy monolithic prefill (the whole
    /// prompt inside the sequence's first round). Chunking never changes
    /// tokens (micro-steps are fused and order-free); it bounds how long
    /// a long prompt can stall the resident decoders.
    pub prefill_chunk_tokens: usize,
    /// Preemption policy under page pressure (see [`Preemption`]).
    pub preemption: Preemption,
    /// Decode sampling policy (greedy by default).
    pub sampler: SamplerConfig,
    pub workers: usize,
    /// Tracing depth for this run (`--trace`): `Off` disables the phase
    /// clock entirely (spans cost one branch, no clock reads — the f32
    /// parity path is untouched bit-for-bit); `Phases` (default) times
    /// the coordinator phases; `Kernels` additionally meters the
    /// dispatched hot loops (which is gated on the *process* trace level,
    /// `obs::set_trace_level`, since kernels run below the coordinator).
    pub trace: TraceLevel,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            batcher: BatcherConfig::default(),
            kv_capacity: 8,
            page_size: 16,
            kv_dtype: KvDtype::F32,
            prefix_sharing: true,
            tile_cache_tiles: crate::cache::DEFAULT_TILE_CACHE_TILES,
            integer_av: true,
            // One page per round per sequence: matches the default
            // `page_size` above so a chunk fills exactly one fresh page.
            prefill_chunk_tokens: 16,
            preemption: Preemption::UnderPressure,
            sampler: SamplerConfig::default(),
            workers: ThreadPool::default_size(),
            // Inherit the process level so `sherry serve --trace ...`
            // (which pins it before building the config) propagates.
            trace: obs::trace_level(),
        }
    }
}

/// Synthetic trace parameters (Poisson arrivals).
#[derive(Clone, Copy, Debug)]
pub struct TraceSpec {
    pub n_requests: usize,
    pub mean_interarrival_s: f64,
    pub prompt_len: usize,
    /// Leading tokens common to every prompt (a shared system prompt);
    /// 0 = fully independent prompts.
    pub shared_prefix_len: usize,
    pub max_new_tokens: usize,
    pub seed: u64,
    /// Fraction of requests drawn as [`Priority::Batch`] (0.0 = all
    /// Interactive — the legacy trace, byte-identical RNG stream).
    pub batch_fraction: f64,
    /// Per-request latency deadline in seconds from arrival (0.0 =
    /// none). Observational only — see [`Request::deadline`].
    pub deadline_s: f64,
}

impl Default for TraceSpec {
    fn default() -> Self {
        Self {
            n_requests: 16,
            mean_interarrival_s: 0.0,
            prompt_len: 8,
            shared_prefix_len: 0,
            max_new_tokens: 16,
            seed: 0,
            batch_fraction: 0.0,
            deadline_s: 0.0,
        }
    }
}

impl TraceSpec {
    /// Materialize the request trace. With `batch_fraction == 0.0` the
    /// RNG stream is identical to the pre-priority trace generator, so
    /// every existing seeded trace replays byte-for-byte; a nonzero
    /// fraction draws one extra uniform per request for its class.
    pub fn generate(&self, vocab: usize) -> Vec<Request> {
        let mut rng = Pcg64::new(self.seed, 31);
        let shared: Vec<u32> = (0..self.shared_prefix_len.min(self.prompt_len))
            .map(|_| rng.below(vocab as u64) as u32)
            .collect();
        let mut t = 0.0f64;
        (0..self.n_requests)
            .map(|i| {
                t += -self.mean_interarrival_s * (1.0 - rng.next_f64()).ln();
                let mut prompt = shared.clone();
                let tail = (shared.len()..self.prompt_len).map(|_| rng.below(vocab as u64) as u32);
                prompt.extend(tail);
                let priority = if self.batch_fraction > 0.0 && rng.next_f64() < self.batch_fraction
                {
                    Priority::Batch
                } else {
                    Priority::Interactive
                };
                Request {
                    id: i as u64,
                    prompt,
                    max_new_tokens: self.max_new_tokens,
                    arrival: t,
                    priority,
                    deadline: (self.deadline_s > 0.0).then_some(self.deadline_s),
                }
            })
            .collect()
    }
}

/// The serving coordinator.
pub struct Server<'m> {
    model: &'m TernaryModel,
    cfg: ServerConfig,
    pool: ThreadPool,
}

struct SeqState {
    table: BlockTable,
    sampler: Sampler,
    /// Worst-case pages this request may still allocate (admission
    /// reservation; `page_need - table.owned_pages()` is outstanding).
    page_need: usize,
    last_token: u32,
    prompt_done: bool,
    /// Prompt pages frozen into the prefix index (once, after prefill).
    registered: bool,
    /// Prompt tokens consumed so far — starts at the shared-prefix span,
    /// whose KV pages came from the index, skipping their prefill.
    fed: usize,
    /// Restore replay (empty except after a preemption): the tokens this
    /// sequence had generated before being parked, minus the last one
    /// (which becomes `last_token`, the next decode feed). They are fed
    /// after the prompt without emitting — pure KV rebuild.
    pending: Vec<u32>,
    /// Replay tokens consumed so far (`pending[..replayed]` are fed).
    replayed: usize,
    /// Admission stamp (monotone): preemption picks the most recently
    /// admitted victim, so long-running work is disturbed last.
    admitted_seq: u64,
    tokens: Vec<u32>,
    first_token_at: Option<f64>,
    /// Trace-clock time of the last emitted token — seeds the
    /// inter-token-latency histogram from the second emission on.
    last_emit_at: Option<f64>,
    finish: Option<FinishReason>,
}

/// Decode state that survives a preemption (everything a restored
/// sequence needs beyond what re-prefilling the prompt rebuilds). Keyed
/// by request id while the request waits in the batcher's class queue.
struct ParkedSeq {
    sampler: Sampler,
    tokens: Vec<u32>,
    last_token: u32,
    first_token_at: Option<f64>,
    last_emit_at: Option<f64>,
}

impl<'m> Server<'m> {
    pub fn new(model: &'m TernaryModel, cfg: ServerConfig) -> Self {
        let pool = ThreadPool::new(cfg.workers);
        Self { model, cfg, pool }
    }

    /// Run a full trace to completion; returns (completions, metrics).
    pub fn run(&self, mut trace: Vec<Request>) -> (Vec<Completion>, Metrics) {
        // A non-finite arrival in a hand-built trace used to panic the
        // sort (`partial_cmp().unwrap()`); worse, a NaN that merely
        // sorted last would never satisfy `arrival <= now` and livelock
        // the intake loop. Clamp to "arrives immediately" and sort with
        // the total order (same fix PR 9 applied to `util::stats`).
        for r in &mut trace {
            if !r.arrival.is_finite() {
                r.arrival = 0.0;
            }
        }
        trace.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
        let t0 = Instant::now();
        let clock = |t0: Instant| t0.elapsed().as_secs_f64();
        let seq_cap = self.model.cfg.seq_len;

        let mut batcher = Batcher::new(self.cfg.batcher);
        let num_pages = PagedKv::pages_for_budget(
            &self.model.cfg,
            self.cfg.kv_capacity,
            self.cfg.page_size,
            self.cfg.kv_dtype,
        );
        let mut kv = PagedKv::new(
            &self.model.cfg,
            num_pages,
            self.cfg.page_size,
            self.cfg.prefix_sharing,
            self.cfg.kv_dtype,
        );
        kv.set_tile_cache_capacity(self.cfg.tile_cache_tiles);
        kv.set_integer_av(self.cfg.integer_av);
        let mut metrics = Metrics { requests_in: trace.len() as u64, ..Default::default() };
        // Per-run phase clock (no global state: concurrent runs in one
        // process, e.g. parallel tests, never cross-attribute). Kernel
        // counters ARE process-global, so snapshot a baseline and report
        // this run as the delta.
        let phases = PhaseClock::new(self.cfg.trace != TraceLevel::Off);
        let kernel_base = obs::kernel_totals();
        let mut completions = Vec::new();
        let mut states: Vec<SeqState> = Vec::new();
        // Decode state of preempted sequences, keyed by request id; the
        // request itself waits (front-parked) in the batcher.
        let mut parked: HashMap<u64, ParkedSeq> = HashMap::new();
        let mut scratch = crate::engine::Scratch::default();
        let mut next_arrival = 0usize;
        let mut tokens_done = 0u64;
        // 0 = legacy monolithic prefill: the whole prompt in one round.
        let chunk = match self.cfg.prefill_chunk_tokens {
            0 => usize::MAX,
            c => c,
        };

        while next_arrival < trace.len() || !batcher.is_idle() {
            // Admit arrivals whose time has come on the wall clock.
            {
                let _s = phases.span(Phase::Admission);
                let now = clock(t0);
                while next_arrival < trace.len() && trace[next_arrival].arrival <= now {
                    batcher.submit(trace[next_arrival].clone());
                    next_arrival += 1;
                }
            }
            // Idle with future arrivals: sleep toward the next one (the
            // sleep is deliberately unattributed — it is idle wall time,
            // not coordinator work, so phase seconds stay ≤ wall).
            if batcher.is_idle() {
                if next_arrival >= trace.len() {
                    break;
                }
                next_arrival_sleep(trace[next_arrival].arrival - clock(t0));
                continue;
            }

            // Page-counted admission: reserve each request's worst-case
            // allocation (minus fully shared prefix pages) against the
            // arena's free pages, net of what already-active sequences
            // may still claim — so a decode step can never hit arena
            // exhaustion mid-round. When a wave admits nothing while a
            // strictly higher-priority request heads a queue, preempt
            // the most recently admitted lower-priority sequence and
            // retry (policy-gated); the index flush stays as the last
            // resort when preemption has no victims to offer.
            {
                let _s = phases.span(Phase::Admission);
                let now = clock(t0);
                loop {
                    let outstanding: usize = states
                        .iter()
                        .map(|st| st.page_need.saturating_sub(st.table.owned_pages()))
                        .sum();
                    let free = kv.free_pages().saturating_sub(outstanding);
                    let admitted = batcher.admit_pages(free, |r| kv.page_need(r), now);
                    let victim = match (self.cfg.preemption, batcher.head_priority()) {
                        (Preemption::Never, _) | (_, None) => None,
                        (Preemption::UnderPressure, Some(head)) if admitted > 0 => {
                            let _ = head;
                            None
                        }
                        (Preemption::UnderPressure, Some(head))
                        | (Preemption::Always, Some(head)) => (0..states.len())
                            .filter(|&i| batcher.active()[i].0.priority > head)
                            .max_by_key(|&i| states[i].admitted_seq),
                    };
                    let Some(victim) = victim else {
                        if admitted == 0
                            && batcher.active_len() == 0
                            && batcher.waiting_len() > 0
                            && kv.index_pages() > 0
                        {
                            // Frozen prefix pages are starving admission:
                            // evict the index's zero-lease nodes (with the
                            // active set empty every frozen page qualifies;
                            // LRU ordering over the unreferenced set is a
                            // ROADMAP item) and retry so the queue head
                            // cannot deadlock.
                            metrics.prefix_flushes += 1;
                            kv.flush_index();
                            batcher.admit_pages(kv.free_pages(), |r| kv.page_need(r), now);
                        }
                        break;
                    };
                    // Park the victim: return its pages to the arena,
                    // stash the decode state that re-prefilling cannot
                    // rebuild, and front-queue the request in its class.
                    let req_id = batcher.active()[victim].0.id;
                    batcher.preempt(victim, now);
                    let mut st = states.swap_remove(victim);
                    kv.release(&mut st.table);
                    parked.insert(
                        req_id,
                        ParkedSeq {
                            sampler: st.sampler,
                            tokens: st.tokens,
                            last_token: st.last_token,
                            first_token_at: st.first_token_at,
                            last_emit_at: st.last_emit_at,
                        },
                    );
                    metrics.preemptions += 1;
                }
            }
            for idx in states.len()..batcher.active_len() {
                let req = &batcher.active()[idx].0;
                // Radix-index walk + page leasing is its own phase;
                // everything else in admitting a request is Admission.
                // (Sibling spans, never nested — the sum stays ≤ wall.)
                let (table, shared) = {
                    let _s = phases.span(Phase::PrefixLookup);
                    kv.lease(&req.prompt)
                };
                let _s = phases.span(Phase::Admission);
                // Only positions up to the context limit are ever
                // prefilled; count the denominator accordingly.
                metrics.prompt_tokens += req.prompt.len().min(seq_cap) as u64;
                metrics.prefix_hit_tokens += shared as u64;
                if shared > 0 {
                    metrics.prefix_hits += 1;
                }
                // A preempted request re-admits as a *restore*: the
                // parked record supplies the sampler (already past the
                // prompt and every generated token — re-observing would
                // skew repetition state) and the generated stream. All
                // but the last generated token are queued for no-emit
                // replay after the prompt; the last becomes the next
                // decode feed. KV pages are a deterministic function of
                // the token prefix, so the rebuilt state is exactly the
                // pre-preemption state and the continuation is
                // token-identical.
                let (sampler, tokens, last_token, first_token_at, last_emit_at, pending) =
                    match parked.remove(&req.id) {
                        Some(p) => {
                            let pending: Vec<u32> = p.tokens[..p.tokens.len().saturating_sub(1)]
                                .to_vec();
                            metrics.restored_tokens += (req.prompt.len().min(seq_cap) as u64)
                                .saturating_sub(shared as u64)
                                + pending.len() as u64;
                            (
                                p.sampler,
                                p.tokens,
                                p.last_token,
                                p.first_token_at,
                                p.last_emit_at,
                                pending,
                            )
                        }
                        None => {
                            let mut sampler = Sampler::for_request(&self.cfg.sampler, req.id);
                            for &t in &req.prompt {
                                // Repetition-penalty support set spans the
                                // prompt too (no-op when the penalty is
                                // off).
                                sampler.observe(t);
                            }
                            (sampler, Vec::new(), 0, None, None, Vec::new())
                        }
                    };
                states.push(SeqState {
                    sampler,
                    page_need: kv.pages_for(req, shared),
                    last_token,
                    prompt_done: req.prompt.is_empty() && pending.is_empty(),
                    registered: false,
                    fed: shared,
                    pending,
                    replayed: 0,
                    admitted_seq: batcher.admissions() - (batcher.active_len() - idx) as u64,
                    tokens,
                    first_token_at,
                    last_emit_at,
                    finish: None,
                    table,
                });
            }

            if batcher.active_len() == 0 {
                if next_arrival >= trace.len() && batcher.waiting_len() == 0 {
                    break;
                }
                continue;
            }

            // One decode round: every sequence that can still feed
            // contributes one generated token. The first micro-step fuses
            // all in-decode sequences with the next prompt token of
            // freshly admitted ones; later micro-steps continue the
            // (ragged) prefill until every prompt is consumed. Each
            // micro-step is ONE forward_kv — one fused LUT-GEMM per layer
            // across its sequences. A sequence at the context limit is
            // never fed (the engine's overflow contract): it finishes
            // gracefully with FinishReason::ContextLimit below.
            let round_start = Instant::now();
            let mut round_tokens = 0u32;
            let mut emitted = vec![false; states.len()];
            // Prefill/replay tokens fed per sequence this round — the
            // chunk budget. A sequence that exhausts its chunk stops
            // feeding until the next round, so resident decoders are
            // never stalled behind more than one chunk of any prompt.
            let mut fed_round = vec![0usize; states.len()];
            {
                let active = batcher.active();
                loop {
                    // (state index, token, emits-an-output)
                    let mut plan: Vec<(usize, u32, bool)> = Vec::new();
                    let mut feeds_prompt = false;
                    for (i, st) in states.iter_mut().enumerate() {
                        if st.finish.is_some() {
                            continue;
                        }
                        let (req, _) = &active[i];
                        if st.prompt_done {
                            if emitted[i] {
                                continue; // one decode feed per round
                            }
                            if st.table.len() >= seq_cap {
                                st.finish = Some(FinishReason::ContextLimit);
                                continue;
                            }
                            plan.push((i, st.last_token, true));
                        } else if fed_round[i] < chunk {
                            if st.table.len() >= seq_cap {
                                // Prompt longer than the context: finish
                                // with whatever was produced (possibly
                                // nothing) instead of overflowing.
                                st.finish = Some(FinishReason::ContextLimit);
                                continue;
                            }
                            if st.fed < req.prompt.len() {
                                // Emit only off the true last prompt token
                                // of a sequence that has never emitted — a
                                // restored sequence already produced its
                                // first token pre-preemption (tokens is
                                // non-empty even when the replay queue is
                                // not: one generated token restores with an
                                // empty `pending`), and its next emission
                                // comes from the decode feed of
                                // `last_token` after the rebuild.
                                let emits = st.fed + 1 == req.prompt.len()
                                    && st.pending.is_empty()
                                    && st.tokens.is_empty();
                                plan.push((i, req.prompt[st.fed], emits));
                            } else {
                                // Restore replay: re-feed an already
                                // generated token to rebuild its KV page
                                // without emitting it again.
                                plan.push((i, st.pending[st.replayed], false));
                            }
                            feeds_prompt = true;
                        }
                    }
                    if plan.is_empty() {
                        break;
                    }
                    // A micro-step feeding any prompt token is prefill
                    // work; a pure-generation step is decode. The span
                    // covers the fused forward and sampling.
                    let _step =
                        phases.span(if feeds_prompt { Phase::Prefill } else { Phase::Decode });
                    let toks: Vec<u32> = plan.iter().map(|&(_, t, _)| t).collect();
                    // Disjoint &mut block tables for the selected
                    // sequences (plan indices are strictly ascending).
                    let mut tables: Vec<&mut BlockTable> = {
                        let mut picked = Vec::with_capacity(plan.len());
                        let mut it = plan.iter().map(|&(i, _, _)| i).peekable();
                        for (i, st) in states.iter_mut().enumerate() {
                            if it.peek() == Some(&i) {
                                picked.push(&mut st.table);
                                it.next();
                            }
                        }
                        picked
                    };
                    let logits = {
                        let mut kvb =
                            KvBatch::Paged { alloc: kv.alloc_mut(), tables: &mut tables };
                        self.model.forward_kv(&toks, &mut kvb, &mut scratch, Some(&self.pool))
                    };
                    drop(tables);
                    for (row, &(i, _, emits)) in plan.iter().enumerate() {
                        let st = &mut states[i];
                        if !st.prompt_done {
                            if st.fed < active[i].0.prompt.len() {
                                st.fed += 1;
                            } else {
                                st.replayed += 1;
                            }
                            fed_round[i] += 1;
                            if st.fed == active[i].0.prompt.len()
                                && st.replayed == st.pending.len()
                            {
                                st.prompt_done = true;
                            }
                        }
                        if emits {
                            let next = st.sampler.sample(logits.row(row));
                            st.last_token = next;
                            st.tokens.push(next);
                            emitted[i] = true;
                            tokens_done += 1;
                            round_tokens += 1;
                        }
                    }
                }
            }
            metrics.decode_rounds += 1;
            metrics.peak_active = metrics.peak_active.max(states.len() as u64);
            // One chunk = one (sequence, round) pair that fed prefill or
            // replay tokens; a monolithic prefill counts as one chunk.
            metrics.prefill_chunks += fed_round.iter().filter(|&&f| f > 0).count() as u64;
            let round_s = round_start.elapsed().as_secs_f64();
            metrics.round_hist.record_secs(round_s);
            metrics.flight.push(RoundRecord {
                round: metrics.decode_rounds - 1,
                active: states.len() as u32,
                pages_in_use: kv.used_pages() as u32,
                tokens: round_tokens,
                prefill_tokens: fed_round.iter().sum::<usize>() as u32,
                duration_s: round_s,
            });

            // Bookkeeping: freeze prefilled prompts into the prefix
            // index, record first-token times, advance, retire.
            let now = clock(t0);
            let mut finished = Vec::new();
            for (i, st) in states.iter_mut().enumerate() {
                if st.first_token_at.is_none() && !st.tokens.is_empty() {
                    st.first_token_at = Some(now);
                }
                if emitted[i] {
                    // Inter-token latency: gap between consecutive
                    // emissions of one sequence (the first emission only
                    // seeds the clock). A preemption gap lands here too —
                    // that is the point: the victim's ITL tail is the
                    // price the Batch class pays, and the per-class
                    // histogram shows it.
                    if let Some(prev) = st.last_emit_at {
                        metrics.itl_hist.record_secs(now - prev);
                        metrics.itl_class[batcher.active()[i].0.priority.index()]
                            .record_secs(now - prev);
                    }
                    st.last_emit_at = Some(now);
                }
                if st.prompt_done && !st.registered {
                    kv.register(&batcher.active()[i].0.prompt, &st.table);
                    st.registered = true;
                }
                let done = match st.finish {
                    Some(_) => true,
                    None => {
                        if emitted[i] && batcher.advance(i) {
                            st.finish = Some(FinishReason::Length);
                            true
                        } else {
                            false
                        }
                    }
                };
                if done {
                    finished.push(i);
                }
            }
            // retire uses swap_remove; mirror it on `states`.
            for &i in finished.iter().rev() {
                let mut st = states.swap_remove(i);
                let (req_id, arrival, class, deadline) = {
                    let r = &batcher.active()[i].0;
                    (r.id, r.arrival, r.priority, r.deadline)
                };
                kv.release(&mut st.table);
                let finish = st.finish.unwrap_or(FinishReason::Length);
                if finish == FinishReason::ContextLimit {
                    metrics.context_limit_finishes += 1;
                }
                completions.push(Completion {
                    id: req_id,
                    tokens: st.tokens,
                    finish,
                    ttft: st.first_token_at.unwrap_or(now) - arrival,
                    latency: now - arrival,
                });
                // A request that never emitted has no first token: folding
                // its full latency into the TTFT histogram (the seed's
                // `unwrap_or(now)`) would fabricate a sample, so it is
                // counted separately instead.
                match st.first_token_at {
                    Some(t) => {
                        metrics.ttft_hist.record_secs(t - arrival);
                        metrics.ttft_class[class.index()].record_secs(t - arrival);
                    }
                    None => metrics.zero_token_finishes += 1,
                }
                metrics.latency_hist.record_secs(now - arrival);
                if deadline.is_some_and(|d| now - arrival > d) {
                    metrics.deadline_misses += 1;
                }
            }
            batcher.retire(&finished);
        }

        metrics.requests_done = completions.len() as u64;
        metrics.tokens_generated = tokens_done;
        metrics.wall_seconds = clock(t0);
        metrics.aged_promotions = batcher.aged_promotions();
        metrics.preemption_policy = self.cfg.preemption.name().to_string();
        metrics.prefill_chunk_tokens = self.cfg.prefill_chunk_tokens as u64;
        metrics.kv_pages_total = kv.num_pages() as u64;
        metrics.kv_pages_peak = kv.peak_used() as u64;
        metrics.kv_pages_index = kv.index_pages() as u64;
        metrics.kv_pages_end_in_use = kv.used_pages() as u64;
        metrics.kv_bytes = kv.bytes() as u64;
        metrics.kv_bytes_per_token = kv.bytes_per_token() as u64;
        metrics.kv_bytes_per_token_k = kv.k_bytes_per_token() as u64;
        metrics.kv_bytes_per_token_v = kv.v_bytes_per_token() as u64;
        metrics.kv_dequant_seconds = kv.dequant_nanos() as f64 * 1e-9;
        let (qk_i8, qk_f32, qk_ternary) = kv.qk_rows();
        metrics.kv_qk_rows_int8 = qk_i8;
        metrics.kv_qk_rows_f32 = qk_f32;
        metrics.kv_qk_rows_ternary = qk_ternary;
        metrics.kv_av_rows_int8 = kv.av_rows();
        let (tile_hits, tile_misses) = kv.tile_cache_stats();
        metrics.kv_tile_hits = tile_hits;
        metrics.kv_tile_misses = tile_misses;
        let isa = crate::simd::active().name();
        metrics.kernel_isa = isa.to_string();
        metrics.kv_dtype = self.cfg.kv_dtype.name().to_string();
        metrics.trace_level = self.cfg.trace.name().to_string();
        metrics.phases.admission = phases.seconds(Phase::Admission);
        metrics.phases.prefix_lookup = phases.seconds(Phase::PrefixLookup);
        metrics.phases.prefill = phases.seconds(Phase::Prefill);
        metrics.phases.decode = phases.seconds(Phase::Decode);
        // Kernel CPU-seconds this run contributed (empty unless the
        // process traced at `kernels`). GEMM walks run on the worker
        // pool, so their seconds sum across workers like
        // `kv_dequant_seconds` does and may exceed wall time.
        metrics.kernels = obs::kernel_totals()
            .delta_since(&kernel_base)
            .into_iter()
            .map(|d| KernelStat {
                kernel: d.kernel.name(),
                plane: d.kernel.plane(),
                isa: isa.to_string(),
                cpu_seconds: d.nanos as f64 * 1e-9,
                calls: d.calls,
            })
            .collect();
        (completions, metrics)
    }
}

fn next_arrival_sleep(dt: f64) {
    if dt > 0.0 {
        std::thread::sleep(std::time::Duration::from_secs_f64(dt.min(0.01)));
    }
}

/// Convenience: build a trace, serve it, return metrics.
pub fn serve_trace(model: &TernaryModel, server_cfg: ServerConfig, trace: TraceSpec) -> (Vec<Completion>, Metrics) {
    let reqs = trace.generate(model.cfg.vocab_size);
    Server::new(model, server_cfg).run(reqs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{random_weights, KvCache, NativeConfig, Scratch, TernaryModel};
    use crate::pack::Format;

    fn model() -> TernaryModel {
        let cfg = NativeConfig::named("nano").unwrap();
        TernaryModel::build(cfg, &random_weights(&cfg, 0), Format::Sherry)
    }

    fn spec(n: usize, prompt: usize, gen: usize, seed: u64) -> TraceSpec {
        TraceSpec {
            n_requests: n,
            mean_interarrival_s: 0.0,
            prompt_len: prompt,
            shared_prefix_len: 0,
            max_new_tokens: gen,
            seed,
            ..Default::default()
        }
    }

    #[test]
    fn serves_all_requests() {
        let m = model();
        let (completions, metrics) =
            serve_trace(&m, ServerConfig::default(), spec(6, 4, 5, 1));
        assert_eq!(completions.len(), 6);
        assert_eq!(metrics.requests_done, 6);
        for c in &completions {
            assert_eq!(c.tokens.len(), 5);
            assert_eq!(c.finish, super::FinishReason::Length);
            assert!(c.latency >= 0.0 && c.ttft >= 0.0);
            assert!(c.ttft <= c.latency + 1e-9);
        }
        // All sequence page references were returned; only the prefix
        // index still holds pages.
        assert_eq!(metrics.kv_pages_end_in_use, metrics.kv_pages_index);
    }

    #[test]
    fn deterministic_tokens_per_request() {
        let m = model();
        let s = spec(3, 3, 4, 7);
        let (c1, _) = serve_trace(&m, ServerConfig::default(), s);
        let (c2, _) = serve_trace(&m, ServerConfig::default(), s);
        let mut c1 = c1;
        let mut c2 = c2;
        c1.sort_by_key(|c| c.id);
        c2.sort_by_key(|c| c.id);
        for (a, b) in c1.iter().zip(&c2) {
            assert_eq!(a.tokens, b.tokens);
        }
    }

    #[test]
    fn batched_serving_matches_single_stream_decoding() {
        // The fused, paged decode rounds must produce exactly the tokens
        // a single-stream greedy decode (contiguous KV) of each request
        // produces — paging and batching are memory/throughput
        // optimizations, never a behavior change.
        let m = model();
        let s = spec(4, 5, 6, 11);
        let reqs = s.generate(m.cfg.vocab_size);
        let (mut served, _) = serve_trace(&m, ServerConfig::default(), s);
        served.sort_by_key(|c| c.id);
        let mut scratch = Scratch::default();
        for (req, comp) in reqs.iter().zip(&served) {
            assert_eq!(req.id, comp.id);
            let mut cache = KvCache::new(&m.cfg);
            let expect = m.generate(&req.prompt, req.max_new_tokens, &mut cache, &mut scratch);
            assert_eq!(expect, comp.tokens, "request {}", req.id);
        }
    }

    #[test]
    fn kv_budget_smaller_than_max_active_still_serves_everything() {
        // Misconfigured max_active beyond the page budget must degrade to
        // fewer-way batching, not starve or mispair sequences.
        let m = model();
        let cfg = ServerConfig {
            batcher: BatcherConfig { max_active: 4, token_budget: 100_000, ..Default::default() },
            kv_capacity: 1,
            page_size: 16,
            workers: 2,
            ..Default::default()
        };
        let s = spec(6, 3, 4, 5);
        let reqs = s.generate(m.cfg.vocab_size);
        let (mut completions, metrics) = serve_trace(&m, cfg, s);
        assert_eq!(completions.len(), 6);
        assert_eq!(metrics.tokens_generated, 6 * 4);
        completions.sort_by_key(|c| c.id);
        let mut scratch = Scratch::default();
        for (req, comp) in reqs.iter().zip(&completions) {
            let mut cache = KvCache::new(&m.cfg);
            let expect = m.generate(&req.prompt, req.max_new_tokens, &mut cache, &mut scratch);
            assert_eq!(expect, comp.tokens, "request {} got another request's stream", req.id);
        }
    }

    #[test]
    fn respects_max_active() {
        let m = model();
        let cfg = ServerConfig {
            batcher: BatcherConfig { max_active: 2, token_budget: 100_000, ..Default::default() },
            kv_capacity: 2,
            workers: 2,
            ..Default::default()
        };
        let (completions, metrics) = serve_trace(&m, cfg, spec(5, 2, 3, 2));
        assert_eq!(completions.len(), 5);
        assert!(metrics.decode_rounds >= 3, "must take multiple rounds");
        assert!(metrics.peak_active <= 2);
    }

    #[test]
    fn context_limit_finishes_gracefully() {
        // A request whose allowance exceeds the context must complete
        // with FinishReason::ContextLimit and exactly the tokens a
        // single-stream generate (which caps at seq_len) produces —
        // not panic the serving loop. (nano seq_len = 64.)
        let m = model();
        let cfg = ServerConfig {
            batcher: BatcherConfig { max_active: 2, token_budget: 100_000, ..Default::default() },
            ..Default::default()
        };
        let s = spec(2, 4, 500, 13);
        let reqs = s.generate(m.cfg.vocab_size);
        let (mut completions, metrics) = serve_trace(&m, cfg, s);
        assert_eq!(completions.len(), 2);
        completions.sort_by_key(|c| c.id);
        let mut scratch = Scratch::default();
        for (req, comp) in reqs.iter().zip(&completions) {
            assert_eq!(comp.finish, super::FinishReason::ContextLimit);
            // generate() stops at the same boundary.
            let mut cache = KvCache::new(&m.cfg);
            let expect = m.generate(&req.prompt, req.max_new_tokens, &mut cache, &mut scratch);
            assert_eq!(expect, comp.tokens, "request {}", req.id);
            assert_eq!(comp.tokens.len(), m.cfg.seq_len - req.prompt.len() + 1);
        }
        assert_eq!(metrics.context_limit_finishes, 2);
    }

    #[test]
    fn oversized_prompt_finishes_without_panicking() {
        // Prompt longer than seq_len: the seed's serving loop hit the
        // engine's overflow assert; now it must finish gracefully with
        // zero tokens and ContextLimit.
        let m = model();
        let (completions, metrics) =
            serve_trace(&m, ServerConfig::default(), spec(1, 80, 4, 3));
        assert_eq!(completions.len(), 1);
        assert_eq!(completions[0].finish, super::FinishReason::ContextLimit);
        assert!(completions[0].tokens.is_empty());
        assert_eq!(metrics.context_limit_finishes, 1);
    }

    #[test]
    fn shared_prefix_tokens_identical_with_sharing_on_and_off() {
        // The acceptance bar: on a trace with a common system-prompt
        // prefix, prefix sharing changes throughput characteristics but
        // never tokens.
        let m = model();
        let s = TraceSpec {
            n_requests: 8,
            mean_interarrival_s: 0.0,
            prompt_len: 24,
            shared_prefix_len: 18,
            max_new_tokens: 6,
            seed: 21,
            ..Default::default()
        };
        // max_active 2 serializes admission waves: the first wave's
        // prompts are frozen into the index before later waves are
        // admitted, so prefix hits are deterministic (no wall-clock
        // dependence).
        let base = ServerConfig {
            batcher: BatcherConfig { max_active: 2, token_budget: 100_000, ..Default::default() },
            page_size: 4,
            ..Default::default()
        };
        let on = ServerConfig { prefix_sharing: true, ..base };
        let off = ServerConfig { prefix_sharing: false, ..base };
        let (mut c_on, m_on) = serve_trace(&m, on, s);
        let (mut c_off, m_off) = serve_trace(&m, off, s);
        c_on.sort_by_key(|c| c.id);
        c_off.sort_by_key(|c| c.id);
        for (a, b) in c_on.iter().zip(&c_off) {
            assert_eq!(a.tokens, b.tokens, "request {}", a.id);
        }
        assert!(m_on.prefix_hit_tokens > 0, "staggered identical prefixes must hit");
        assert_eq!(m_off.prefix_hit_tokens, 0);
        // And both match the single-stream contiguous baseline.
        let reqs = s.generate(m.cfg.vocab_size);
        let mut scratch = Scratch::default();
        for (req, comp) in reqs.iter().zip(&c_on) {
            let mut cache = KvCache::new(&m.cfg);
            let expect = m.generate(&req.prompt, req.max_new_tokens, &mut cache, &mut scratch);
            assert_eq!(expect, comp.tokens, "request {}", req.id);
        }
    }

    #[test]
    fn int8_kv_serves_all_requests_and_halves_bytes_per_token() {
        let m = model();
        let base = ServerConfig {
            batcher: BatcherConfig { max_active: 4, token_budget: 100_000, ..Default::default() },
            kv_capacity: 2,
            page_size: 16,
            workers: 2,
            ..Default::default()
        };
        let s = spec(6, 4, 5, 3);
        let (c_f32, m_f32) = serve_trace(&m, ServerConfig { kv_dtype: KvDtype::F32, ..base }, s);
        let (c_i8, m_i8) = serve_trace(&m, ServerConfig { kv_dtype: KvDtype::Int8, ..base }, s);
        assert_eq!(c_f32.len(), 6);
        assert_eq!(c_i8.len(), 6);
        // Same byte budget, but int8 reports ≤ half the per-token bytes
        // and at least double the pages (the acceptance gauge).
        assert!(m_i8.kv_bytes <= m_f32.kv_bytes);
        assert!(
            m_i8.kv_bytes_per_token * 2 <= m_f32.kv_bytes_per_token,
            "{} vs {}",
            m_i8.kv_bytes_per_token,
            m_f32.kv_bytes_per_token
        );
        assert!(m_i8.kv_pages_total >= 2 * m_f32.kv_pages_total);
        // With the integer a·V pass on (the default), the int8 decode hot
        // path never dequantizes K or V page bytes — the residual dequant
        // gauge stays 0 for both pools.
        assert_eq!(m_f32.kv_dequant_seconds, 0.0);
        assert_eq!(m_i8.kv_dequant_seconds, 0.0, "integer a·V leaves no hot-path dequant");
        // The score pass runs at the storage dtype: every int8 q·k row is
        // an i32 dot over raw page bytes; f32 pools never take that path.
        assert_eq!(m_i8.int8_dot_fraction(), 1.0, "int8 pool must dot int8-natively");
        assert_eq!(m_f32.int8_dot_fraction(), 0.0);
        assert!(m_f32.kv_qk_rows_f32 > 0, "f32 rows are still metered");
        // And the V pass is metered as integer rows for int8 only.
        assert!(m_i8.kv_av_rows_int8 > 0, "int8 V rows accumulate in fixed point");
        assert_eq!(m_f32.kv_av_rows_int8, 0);
        // Every request still runs to its full allowance.
        for c in c_i8.iter().chain(&c_f32) {
            assert_eq!(c.tokens.len(), 5);
            assert_eq!(c.finish, super::FinishReason::Length);
        }
    }

    #[test]
    fn ternary_kv_serves_at_1_25_bit_k_rate_and_lut_walks_every_row() {
        let m = model();
        let base = ServerConfig {
            batcher: BatcherConfig { max_active: 4, token_budget: 100_000, ..Default::default() },
            kv_capacity: 2,
            page_size: 16,
            workers: 2,
            ..Default::default()
        };
        let s = spec(6, 4, 5, 3);
        let (c_i8, m_i8) = serve_trace(&m, ServerConfig { kv_dtype: KvDtype::Int8, ..base }, s);
        let (c_t, m_t) = serve_trace(&m, ServerConfig { kv_dtype: KvDtype::Ternary, ..base }, s);
        assert_eq!(c_i8.len(), 6);
        assert_eq!(c_t.len(), 6);
        // K pages drop from int8 to 1.25-bit pack34 codes while V stays
        // int8, so the same byte budget buys strictly more pages. At the
        // nano shape (4 heads × hd 32, page_size 16) that is K 42 vs 258
        // B/token — more than 4× smaller.
        assert!(
            m_t.kv_bytes_per_token < m_i8.kv_bytes_per_token,
            "{} vs {}",
            m_t.kv_bytes_per_token,
            m_i8.kv_bytes_per_token
        );
        assert_eq!(
            m_t.kv_bytes_per_token_k + m_t.kv_bytes_per_token_v,
            m_t.kv_bytes_per_token
        );
        assert!(
            m_t.kv_bytes_per_token_k * 4 < m_i8.kv_bytes_per_token_k,
            "ternary K must be >4x smaller than int8 K ({} vs {})",
            m_t.kv_bytes_per_token_k,
            m_i8.kv_bytes_per_token_k
        );
        assert_eq!(m_t.kv_bytes_per_token_v, m_i8.kv_bytes_per_token_v);
        assert!(m_t.kv_pages_total > m_i8.kv_pages_total);
        // Score-pass routing: every paged q·k row in the ternary pool is
        // a LUT walk over packed codes; none takes the int8 or borrowed
        // f32 path. The V pass accumulates integer fixed point over the
        // shared int8 V plane, so the residual dequant gauge stays 0 —
        // a ternary decode round touches no f32 K or V page bytes.
        assert_eq!(m_t.ternary_dot_fraction(), 1.0, "ternary pool must LUT-walk every row");
        assert_eq!(m_t.int8_dot_fraction(), 0.0);
        assert_eq!(m_i8.ternary_dot_fraction(), 0.0);
        assert_eq!(m_t.kv_dequant_seconds, 0.0, "integer a·V leaves no hot-path dequant");
        assert!(m_t.kv_av_rows_int8 > 0, "ternary V rows accumulate in fixed point");
        // Every request still runs to its full allowance.
        for c in &c_t {
            assert_eq!(c.tokens.len(), 5);
            assert_eq!(c.finish, super::FinishReason::Length);
        }
        // And the quantized decode replays identically per trace.
        let (mut r1, _) = serve_trace(&m, ServerConfig { kv_dtype: KvDtype::Ternary, ..base }, s);
        let (mut r2, _) = serve_trace(&m, ServerConfig { kv_dtype: KvDtype::Ternary, ..base }, s);
        r1.sort_by_key(|c| c.id);
        r2.sort_by_key(|c| c.id);
        for (a, b) in r1.iter().zip(&r2) {
            assert_eq!(a.tokens, b.tokens, "ternary decode must replay identically");
        }
    }

    #[test]
    fn int8_prefix_sharing_serves_hits_and_tile_cache_works() {
        // Int8 pools now share prefixes (whole frozen pages): a trace
        // with a common system prompt must record prefix hits and — the
        // exactness claim — produce the same tokens with sharing on,
        // sharing off, and the tile cache off. With the integer a·V pass
        // on (the default) the frozen-tile cache is bypassed entirely; it
        // only runs on the residual f32 path (integer-V disabled), where
        // shared pages must still hit it.
        let m = model();
        let s = TraceSpec {
            n_requests: 8,
            mean_interarrival_s: 0.0,
            prompt_len: 24,
            shared_prefix_len: 18,
            max_new_tokens: 6,
            seed: 21,
            ..Default::default()
        };
        // max_active 2 serializes admission waves (deterministic hits).
        let base = ServerConfig {
            batcher: BatcherConfig { max_active: 2, token_budget: 100_000, ..Default::default() },
            page_size: 4,
            kv_dtype: KvDtype::Int8,
            ..Default::default()
        };
        let on = ServerConfig { prefix_sharing: true, ..base };
        let off = ServerConfig { prefix_sharing: false, ..base };
        let no_cache = ServerConfig { prefix_sharing: true, tile_cache_tiles: 0, ..base };
        let residual = ServerConfig { prefix_sharing: true, integer_av: false, ..base };
        let (mut c_on, m_on) = serve_trace(&m, on, s);
        let (mut c_off, m_off) = serve_trace(&m, off, s);
        let (mut c_nc, m_nc) = serve_trace(&m, no_cache, s);
        let (c_res, m_res) = serve_trace(&m, residual, s);
        c_on.sort_by_key(|c| c.id);
        c_off.sort_by_key(|c| c.id);
        c_nc.sort_by_key(|c| c.id);
        for ((a, b), c) in c_on.iter().zip(&c_off).zip(&c_nc) {
            assert_eq!(a.tokens, b.tokens, "sharing changed int8 tokens for request {}", a.id);
            assert_eq!(a.tokens, c.tokens, "tile cache changed tokens for request {}", a.id);
        }
        // 18 shared tokens at page_size 4 → 4 whole pages reusable.
        assert!(m_on.prefix_hit_tokens > 0, "int8 pools must record prefix hits now");
        assert_eq!(m_on.prefix_hit_tokens % 4, 0, "int8 spans are whole-page multiples");
        assert_eq!(m_off.prefix_hit_tokens, 0);
        // Hot path: integer a·V bypasses the tile cache and dequantizes
        // nothing, even with sharing on.
        assert_eq!(m_on.kv_tile_hits + m_on.kv_tile_misses, 0, "integer a·V bypasses tiles");
        assert_eq!(m_on.kv_dequant_seconds, 0.0);
        assert!(m_on.kv_av_rows_int8 > 0);
        assert_eq!(m_nc.kv_tile_hits + m_nc.kv_tile_misses, 0);
        let _ = m_nc.tile_cache_hit_rate();
        // Residual path (integer-V off): the V pass reads f32 tiles
        // again, shared lease-admitted pages hit the LRU, and the
        // residual dequant gauge moves.
        assert_eq!(c_res.len(), 8);
        assert_eq!(m_res.kv_av_rows_int8, 0, "integer-V off meters no fixed-point rows");
        assert!(m_res.kv_tile_hits > 0, "shared prefix pages must hit the tile cache");
        assert!(m_res.kv_dequant_seconds > 0.0, "residual f32 V pass dequantizes");
    }

    #[test]
    fn int8_kv_is_deterministic_per_trace() {
        let m = model();
        let cfg = ServerConfig { kv_dtype: KvDtype::Int8, ..Default::default() };
        let s = spec(4, 3, 6, 19);
        let (mut c1, _) = serve_trace(&m, cfg, s);
        let (mut c2, _) = serve_trace(&m, cfg, s);
        c1.sort_by_key(|c| c.id);
        c2.sort_by_key(|c| c.id);
        for (a, b) in c1.iter().zip(&c2) {
            assert_eq!(a.tokens, b.tokens, "int8 decode must replay identically");
        }
    }

    #[test]
    fn sampling_knobs_serve_end_to_end() {
        // top-p + repetition penalty through the whole serving stack:
        // everything completes, and the per-request streams stay
        // reproducible across runs.
        let m = model();
        let cfg = ServerConfig {
            sampler: SamplerConfig {
                temperature: 0.8,
                top_k: 0,
                top_p: 0.9,
                repetition_penalty: 1.3,
                seed: 5,
            },
            ..Default::default()
        };
        let s = spec(5, 4, 8, 23);
        let (mut c1, _) = serve_trace(&m, cfg, s);
        let (mut c2, _) = serve_trace(&m, cfg, s);
        assert_eq!(c1.len(), 5);
        c1.sort_by_key(|c| c.id);
        c2.sort_by_key(|c| c.id);
        for (a, b) in c1.iter().zip(&c2) {
            assert_eq!(a.tokens.len(), 8);
            assert_eq!(a.tokens, b.tokens, "sampled streams replay per request id");
        }
    }

    #[test]
    fn paged_admission_beats_whole_cache_leasing_at_same_byte_budget() {
        // kv_capacity = 2 whole-cache equivalents. The seed's pool could
        // never have more than 2 sequences in flight; page-granular
        // admission fits more because these requests need far fewer
        // pages than a worst-case sequence.
        let m = model();
        let cfg = ServerConfig {
            batcher: BatcherConfig { max_active: 8, token_budget: 100_000, ..Default::default() },
            kv_capacity: 2,
            page_size: 4,
            ..Default::default()
        };
        let (completions, metrics) = serve_trace(&m, cfg, spec(8, 3, 4, 9));
        assert_eq!(completions.len(), 8);
        assert!(
            metrics.peak_active > 2,
            "paged admission must exceed whole-cache concurrency ({} ≤ 2)",
            metrics.peak_active
        );
        assert_eq!(metrics.kv_pages_total, 2 * 16); // same byte budget
    }

    #[test]
    fn phase_seconds_are_nonnegative_and_sum_to_at_most_wall() {
        // The tentpole acceptance test: the trace report must break wall
        // time into admission / prefix lookup / prefill / decode, with
        // disjoint spans (sum ≤ wall) and real work attributed.
        let m = model();
        let cfg = ServerConfig { trace: TraceLevel::Phases, ..Default::default() };
        let (completions, metrics) = serve_trace(&m, cfg, spec(6, 4, 5, 1));
        assert_eq!(completions.len(), 6);
        let p = metrics.phases;
        for (name, v) in [
            ("admission", p.admission),
            ("prefix_lookup", p.prefix_lookup),
            ("prefill", p.prefill),
            ("decode", p.decode),
        ] {
            assert!(v >= 0.0, "{name} must be non-negative, got {v}");
        }
        // Instant-nanos rounding can only lose time, never add it, but
        // leave a whisker of epsilon for the f64 conversions.
        assert!(
            p.total() <= metrics.wall_seconds + 1e-6,
            "phase sum {} must be ≤ wall {}",
            p.total(),
            metrics.wall_seconds
        );
        // Real work ran, so the forward-pass phases must have moved, and
        // prompts exist, so prefill specifically is nonzero.
        assert!(p.prefill > 0.0, "prompt feeding must attribute prefill time");
        assert!(p.decode > 0.0, "generation must attribute decode time");
        assert_eq!(metrics.trace_level, "phases");
        assert_eq!(metrics.kv_dtype, "f32");
        // Round-duration histogram: one sample per decode round.
        assert_eq!(metrics.round_hist.count(), metrics.decode_rounds);
        assert!(metrics.round_hist.p50() > 0.0);
        // 5 tokens per request → 4 inter-token gaps each.
        assert_eq!(metrics.itl_hist.count(), 6 * 4);
        assert!(metrics.itl_p50() >= 0.0 && metrics.itl_p99() >= metrics.itl_p50());
        // Latency/TTFT histograms replaced the reservoirs one-for-one.
        assert_eq!(metrics.latency_hist.count(), 6);
        assert_eq!(metrics.ttft_hist.count(), 6);
        assert_eq!(metrics.zero_token_finishes, 0);
        // And the snapshot carries the same invariant.
        let snap = metrics.snapshot();
        let phases = snap.get("phases").unwrap();
        assert!(phases.get("total_s").unwrap().as_f64().unwrap() <= metrics.wall_seconds + 1e-6);
    }

    #[test]
    fn trace_off_records_no_phases_and_identical_tokens() {
        // `--trace off` is the zero-overhead contract: no phase clock
        // reads, and — since tracing never touches kernel inputs — the
        // f32 parity path produces bit-for-bit the same tokens.
        let m = model();
        let s = spec(4, 3, 4, 7);
        let off = ServerConfig { trace: TraceLevel::Off, ..Default::default() };
        let on = ServerConfig { trace: TraceLevel::Phases, ..Default::default() };
        let (mut c_off, m_off) = serve_trace(&m, off, s);
        let (mut c_on, _) = serve_trace(&m, on, s);
        assert_eq!(m_off.phases.total(), 0.0, "off-level runs must not attribute time");
        assert_eq!(m_off.trace_level, "off");
        c_off.sort_by_key(|c| c.id);
        c_on.sort_by_key(|c| c.id);
        for (a, b) in c_off.iter().zip(&c_on) {
            assert_eq!(a.tokens, b.tokens, "tracing changed tokens for request {}", a.id);
        }
        // Latency accounting is unconditional — only attribution is off.
        assert_eq!(m_off.latency_hist.count(), 4);
        assert_eq!(m_off.round_hist.count(), m_off.decode_rounds);
    }

    #[test]
    fn zero_token_finish_is_excluded_from_ttft() {
        // The seed folded `first_token_at.unwrap_or(now)` into the TTFT
        // reservoir, so a request that never emitted recorded its FULL
        // latency as a time-to-first-token. It must be excluded and
        // counted separately instead.
        let m = model();
        let (completions, metrics) =
            serve_trace(&m, ServerConfig::default(), spec(1, 80, 4, 3));
        assert_eq!(completions.len(), 1);
        assert!(completions[0].tokens.is_empty());
        assert_eq!(metrics.zero_token_finishes, 1);
        assert!(metrics.ttft_hist.is_empty(), "no first token → no TTFT sample");
        assert_eq!(metrics.latency_hist.count(), 1, "latency is still a real sample");
        assert!(metrics.report().contains("zero-token finishes: 1"), "{}", metrics.report());
    }

    #[test]
    fn flight_recorder_captures_every_round_up_to_capacity() {
        let m = model();
        let (_, metrics) = serve_trace(&m, ServerConfig::default(), spec(5, 3, 6, 17));
        assert_eq!(metrics.flight.total(), metrics.decode_rounds);
        let recs = metrics.flight.records();
        assert_eq!(
            recs.len(),
            (metrics.decode_rounds as usize).min(crate::obs::ring::FLIGHT_RING_CAP)
        );
        let mut tokens_in_flight = 0u64;
        for r in &recs {
            assert!(r.duration_s >= 0.0);
            assert!(u64::from(r.pages_in_use) <= metrics.kv_pages_peak);
            tokens_in_flight += u64::from(r.tokens);
        }
        // Short run: the ring did not wrap, so its tokens are ALL tokens.
        assert_eq!(tokens_in_flight, metrics.tokens_generated);
        for w in recs.windows(2) {
            assert_eq!(w[1].round, w[0].round + 1, "rounds are recorded in order");
        }
    }

    #[test]
    fn kernel_tracing_attributes_cpu_seconds_by_kernel_and_plane() {
        // `--trace kernels`: the dispatched hot loops must show up keyed
        // kernel × ISA × data plane. (The level is process-global; other
        // suites only ever *raise* it transiently, which can add entries
        // but never remove the ones this run produces.)
        let prior = obs::trace_level();
        obs::set_trace_level(TraceLevel::Kernels);
        let m = model();
        let cfg = ServerConfig { trace: TraceLevel::Kernels, ..Default::default() };
        let (completions, metrics) = serve_trace(&m, cfg, spec(4, 4, 5, 1));
        obs::set_trace_level(prior);
        assert_eq!(completions.len(), 4);
        assert!(!metrics.kernels.is_empty(), "kernels level must attribute kernel time");
        let isa = crate::simd::active().name();
        for k in &metrics.kernels {
            assert!(k.cpu_seconds >= 0.0);
            assert!(k.calls > 0, "delta reporting skips idle kernels");
            assert_eq!(k.isa, isa);
            assert!(["int8", "ternary", "f32", "weights"].contains(&k.plane), "{}", k.kernel);
        }
        // A Sherry-format model forwards through the pack34 tile walk,
        // and an f32 pool's attention runs the f32 arms.
        let names: Vec<&str> = metrics.kernels.iter().map(|k| k.kernel).collect();
        assert!(names.contains(&"gemm_pack34"), "{names:?}");
        assert!(names.contains(&"qk_f32"), "{names:?}");
        assert!(names.contains(&"av_f32"), "{names:?}");
        // The report and snapshot carry the breakdown.
        assert!(metrics.report().contains("kernel gemm_pack34["), "{}", metrics.report());
        let snap = metrics.snapshot();
        assert!(!snap.get("kernels").unwrap().as_arr().unwrap().is_empty());
    }
}
