//! The serving loop: trace-driven request arrival → continuous batching →
//! fused batched decode rounds on the LUT engine → completions + metrics.
//!
//! Decode parallelism is *inside the kernel*: each round gathers every
//! active sequence's next token and issues one
//! [`TernaryModel::forward_batch`] call — one fused LUT-GEMM per layer
//! with all sequences' activation tables resident, fanned out over
//! output-channel tiles on the worker pool. (The previous design decoded
//! each sequence independently on its own worker, which re-walked every
//! packed weight plane once per sequence per layer.) Newly admitted
//! sequences prefill their whole prompt inside their first round via
//! ragged micro-steps that stay fused across sequences at the same prompt
//! offset. Because batched and single-row kernels are bit-for-bit
//! identical, a request's tokens do not depend on which sequences share
//! its rounds. (Environment is offline, so "arrival" is simulated from
//! the trace clock; everything downstream of arrival is the real engine.)

use std::time::Instant;

use super::{Batcher, BatcherConfig, Completion, KvPool, Metrics, Request};
use crate::engine::{argmax, KvCache, Scratch, TernaryModel};
use crate::util::{Pcg64, ThreadPool};

/// Server configuration.
#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    pub batcher: BatcherConfig,
    pub kv_capacity: usize,
    pub workers: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self { batcher: BatcherConfig::default(), kv_capacity: 8, workers: ThreadPool::default_size() }
    }
}

/// Synthetic trace parameters (Poisson arrivals).
#[derive(Clone, Copy, Debug)]
pub struct TraceSpec {
    pub n_requests: usize,
    pub mean_interarrival_s: f64,
    pub prompt_len: usize,
    pub max_new_tokens: usize,
    pub seed: u64,
}

impl TraceSpec {
    /// Materialize the request trace.
    pub fn generate(&self, vocab: usize) -> Vec<Request> {
        let mut rng = Pcg64::new(self.seed, 31);
        let mut t = 0.0f64;
        (0..self.n_requests)
            .map(|i| {
                t += -self.mean_interarrival_s * (1.0 - rng.next_f64()).ln();
                Request {
                    id: i as u64,
                    prompt: (0..self.prompt_len).map(|_| rng.below(vocab as u64) as u32).collect(),
                    max_new_tokens: self.max_new_tokens,
                    arrival: t,
                }
            })
            .collect()
    }
}

/// The serving coordinator.
pub struct Server<'m> {
    model: &'m TernaryModel,
    cfg: ServerConfig,
    pool: ThreadPool,
}

struct SeqState {
    cache: KvCache,
    last_token: u32,
    prompt_done: bool,
    tokens: Vec<u32>,
    first_token_at: Option<f64>,
}

impl<'m> Server<'m> {
    pub fn new(model: &'m TernaryModel, cfg: ServerConfig) -> Self {
        let pool = ThreadPool::new(cfg.workers);
        Self { model, cfg, pool }
    }

    /// Run a full trace to completion; returns (completions, metrics).
    pub fn run(&self, mut trace: Vec<Request>) -> (Vec<Completion>, Metrics) {
        trace.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
        let t0 = Instant::now();
        let clock = |t0: Instant| t0.elapsed().as_secs_f64();

        let mut batcher = Batcher::new(self.cfg.batcher);
        let mut kv = KvPool::new(self.model.cfg, self.cfg.kv_capacity);
        let mut metrics = Metrics { requests_in: trace.len() as u64, ..Default::default() };
        let mut completions = Vec::new();
        let mut states: Vec<SeqState> = Vec::new();
        let mut scratch = Scratch::default();
        let mut next_arrival = 0usize;
        let mut tokens_done = 0u64;

        while next_arrival < trace.len() || !batcher.is_idle() {
            // Admit arrivals whose time has come on the wall clock.
            let now = clock(t0);
            while next_arrival < trace.len() && trace[next_arrival].arrival <= now {
                batcher.submit(trace[next_arrival].clone());
                next_arrival += 1;
            }
            // Idle with future arrivals: sleep toward the next one.
            if batcher.is_idle() {
                if next_arrival >= trace.len() {
                    break;
                }
                next_arrival_sleep(trace[next_arrival].arrival - clock(t0));
                continue;
            }

            // Admission bounded by both the batcher and the KV pool:
            // capping admissions at the pool's free capacity guarantees
            // every active entry owns a cache, keeping `states[i]` and
            // `batcher.active()[i]` aligned through retire's swap_remove
            // mirroring (a cache-less entry would starve and desync them).
            let before = batcher.active_len();
            batcher.admit_up_to(kv.available());
            for _ in before..batcher.active_len() {
                let cache = kv
                    .acquire()
                    .expect("admission is capped at kv.available(), a cache must be free");
                let (req, _) = &batcher.active()[states.len()];
                states.push(SeqState {
                    cache,
                    last_token: *req.prompt.first().unwrap_or(&0),
                    prompt_done: false,
                    tokens: Vec::new(),
                    first_token_at: None,
                });
            }

            if batcher.active_len() == 0 {
                if next_arrival >= trace.len() && batcher.waiting_len() == 0 {
                    break;
                }
                continue;
            }

            // One decode round: every sequence with a cache contributes one
            // generated token. Micro-step 0 fuses all in-decode sequences
            // with the first prompt token of freshly admitted ones; later
            // micro-steps continue the (ragged) prefill until every prompt
            // is consumed. Each micro-step is ONE forward_batch — one fused
            // LUT-GEMM per layer across its sequences.
            {
                let active = batcher.active();
                let n_act = states.len();
                let mut step = 0usize;
                loop {
                    // (index, token, emits-an-output-this-round)
                    let mut plan: Vec<(usize, u32, bool)> = Vec::new();
                    for (i, st) in states.iter().enumerate().take(n_act) {
                        let (req, _) = &active[i];
                        let entry = if st.prompt_done || req.prompt.is_empty() {
                            // decode step (degenerate empty prompt decodes
                            // straight from its placeholder token)
                            if step == 0 {
                                Some((st.last_token, true))
                            } else {
                                None
                            }
                        } else if step < req.prompt.len() {
                            Some((req.prompt[step], step + 1 == req.prompt.len()))
                        } else {
                            None
                        };
                        if let Some((tok, emits)) = entry {
                            plan.push((i, tok, emits));
                        }
                    }
                    if plan.is_empty() {
                        break;
                    }
                    let toks: Vec<u32> = plan.iter().map(|&(_, t, _)| t).collect();
                    // Disjoint &mut caches for the selected sequences
                    // (plan indices are strictly ascending).
                    let mut sel: Vec<&mut SeqState> = {
                        let mut picked = Vec::with_capacity(plan.len());
                        let mut it = plan.iter().map(|&(i, _, _)| i).peekable();
                        for (i, st) in states.iter_mut().enumerate() {
                            if it.peek() == Some(&i) {
                                picked.push(st);
                                it.next();
                            }
                        }
                        picked
                    };
                    let mut caches: Vec<&mut KvCache> =
                        sel.iter_mut().map(|st| &mut st.cache).collect();
                    let logits =
                        self.model.forward_batch(&toks, &mut caches, &mut scratch, Some(&self.pool));
                    drop(caches);
                    for (row, (st, &(_, _, emits))) in sel.iter_mut().zip(plan.iter()).enumerate() {
                        if emits {
                            let next = argmax(logits.row(row)) as u32;
                            st.last_token = next;
                            st.tokens.push(next);
                            st.prompt_done = true;
                            tokens_done += 1;
                        }
                    }
                    step += 1;
                }
            }
            metrics.decode_rounds += 1;

            // Bookkeeping: advance, record first-token times, retire.
            let now = clock(t0);
            let mut finished = Vec::new();
            for (i, st) in states.iter_mut().enumerate() {
                if st.first_token_at.is_none() {
                    st.first_token_at = Some(now);
                }
                let done = batcher.advance(i) || st.cache.len + 1 >= self.model.cfg.seq_len;
                if done {
                    finished.push(i);
                }
            }
            // retire uses swap_remove; mirror it on `states`.
            for &i in finished.iter().rev() {
                let st = states.swap_remove(i);
                let req = batcher.active()[i].0.clone();
                kv.release(st.cache);
                completions.push(Completion {
                    id: req.id,
                    tokens: st.tokens,
                    ttft: st.first_token_at.unwrap_or(now) - req.arrival,
                    latency: now - req.arrival,
                });
                metrics.ttfts.push(st.first_token_at.unwrap_or(now) - req.arrival);
                metrics.latencies.push(now - req.arrival);
            }
            batcher.retire(&finished);
        }

        metrics.requests_done = completions.len() as u64;
        metrics.tokens_generated = tokens_done;
        metrics.wall_seconds = clock(t0);
        (completions, metrics)
    }
}

fn next_arrival_sleep(dt: f64) {
    if dt > 0.0 {
        std::thread::sleep(std::time::Duration::from_secs_f64(dt.min(0.01)));
    }
}

/// Convenience: build a trace, serve it, return metrics.
pub fn serve_trace(model: &TernaryModel, server_cfg: ServerConfig, trace: TraceSpec) -> (Vec<Completion>, Metrics) {
    let reqs = trace.generate(model.cfg.vocab_size);
    Server::new(model, server_cfg).run(reqs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{random_weights, NativeConfig, TernaryModel};
    use crate::pack::Format;

    fn model() -> TernaryModel {
        let cfg = NativeConfig::named("nano").unwrap();
        TernaryModel::build(cfg, &random_weights(&cfg, 0), Format::Sherry)
    }

    #[test]
    fn serves_all_requests() {
        let m = model();
        let (completions, metrics) = serve_trace(
            &m,
            ServerConfig::default(),
            TraceSpec { n_requests: 6, mean_interarrival_s: 0.0, prompt_len: 4, max_new_tokens: 5, seed: 1 },
        );
        assert_eq!(completions.len(), 6);
        assert_eq!(metrics.requests_done, 6);
        for c in &completions {
            assert_eq!(c.tokens.len(), 5);
            assert!(c.latency >= 0.0 && c.ttft >= 0.0);
            assert!(c.ttft <= c.latency + 1e-9);
        }
    }

    #[test]
    fn deterministic_tokens_per_request() {
        let m = model();
        let spec = TraceSpec { n_requests: 3, mean_interarrival_s: 0.0, prompt_len: 3, max_new_tokens: 4, seed: 7 };
        let (c1, _) = serve_trace(&m, ServerConfig::default(), spec);
        let (c2, _) = serve_trace(&m, ServerConfig::default(), spec);
        let mut c1 = c1;
        let mut c2 = c2;
        c1.sort_by_key(|c| c.id);
        c2.sort_by_key(|c| c.id);
        for (a, b) in c1.iter().zip(&c2) {
            assert_eq!(a.tokens, b.tokens);
        }
    }

    #[test]
    fn batched_serving_matches_single_stream_decoding() {
        // The fused decode rounds must produce exactly the tokens a
        // single-stream greedy decode of each request produces — batching
        // is a throughput optimization, never a behavior change.
        let m = model();
        let spec = TraceSpec { n_requests: 4, mean_interarrival_s: 0.0, prompt_len: 5, max_new_tokens: 6, seed: 11 };
        let reqs = spec.generate(m.cfg.vocab_size);
        let (mut served, _) = serve_trace(&m, ServerConfig::default(), spec);
        served.sort_by_key(|c| c.id);
        let mut scratch = Scratch::default();
        for (req, comp) in reqs.iter().zip(&served) {
            assert_eq!(req.id, comp.id);
            let mut cache = KvCache::new(&m.cfg);
            let expect = m.generate(&req.prompt, req.max_new_tokens, &mut cache, &mut scratch);
            assert_eq!(expect, comp.tokens, "request {}", req.id);
        }
    }

    #[test]
    fn kv_pool_smaller_than_max_active_still_serves_everything() {
        // Misconfigured max_active > kv_capacity must degrade to
        // kv_capacity-way batching, not starve or mispair sequences.
        let m = model();
        let cfg = ServerConfig {
            batcher: BatcherConfig { max_active: 4, token_budget: 100_000 },
            kv_capacity: 2,
            workers: 2,
        };
        let spec =
            TraceSpec { n_requests: 6, mean_interarrival_s: 0.0, prompt_len: 3, max_new_tokens: 4, seed: 5 };
        let reqs = spec.generate(m.cfg.vocab_size);
        let (mut completions, metrics) = serve_trace(&m, cfg, spec);
        assert_eq!(completions.len(), 6);
        assert_eq!(metrics.tokens_generated, 6 * 4);
        completions.sort_by_key(|c| c.id);
        let mut scratch = Scratch::default();
        for (req, comp) in reqs.iter().zip(&completions) {
            let mut cache = KvCache::new(&m.cfg);
            let expect = m.generate(&req.prompt, req.max_new_tokens, &mut cache, &mut scratch);
            assert_eq!(expect, comp.tokens, "request {} got another request's stream", req.id);
        }
    }

    #[test]
    fn respects_max_active() {
        let m = model();
        let cfg = ServerConfig {
            batcher: BatcherConfig { max_active: 2, token_budget: 100_000 },
            kv_capacity: 2,
            workers: 2,
        };
        let (completions, metrics) = serve_trace(
            &m,
            cfg,
            TraceSpec { n_requests: 5, mean_interarrival_s: 0.0, prompt_len: 2, max_new_tokens: 3, seed: 2 },
        );
        assert_eq!(completions.len(), 5);
        assert!(metrics.decode_rounds >= 3, "must take multiple rounds");
    }
}
