//! Continuous batcher: admits waiting requests into the active decode set
//! under a token budget — strict priority across the two [`Priority`]
//! classes, FIFO within one, Batch-class starvation bounded by aging.
//!
//! The active set is the decode round's batch: the server feeds every
//! active sequence's next token through one fused
//! `TernaryModel::forward_batch` call per (micro-)step, so admission here
//! directly sets the LUT-GEMM batch width the kernels amortize over.
//!
//! Scheduling rules, in the order they are applied each admission wave:
//!
//! 1. **Aging**: any Batch-class entry that has waited at least
//!    [`BatcherConfig::aging_threshold_s`] is promoted to the tail of the
//!    Interactive queue (relative order among promotees preserved). This
//!    bounds starvation under sustained Interactive load.
//! 2. **Strict priority, FIFO within a class**: the Interactive queue is
//!    drained head-first, then the Batch queue. A head that does not fit
//!    (max_active / token budget / page cost) blocks the whole wave — a
//!    lower class never backfills past a blocked higher-class head, so
//!    admission order stays a deterministic function of the queue state.
//! 3. **Preemption parking** ([`Batcher::preempt`]): a preempted active
//!    sequence returns to the *front* of its class queue (it was admitted
//!    before everything waiting there) with its generated-token count
//!    carried along, so a later re-admission resumes its allowance
//!    instead of restarting it.

use std::collections::VecDeque;

use super::{Priority, Request};

/// Batching policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Max concurrently active sequences (bounded by the KV pool).
    pub max_active: usize,
    /// Max total resident tokens (prompt + generated) across active seqs.
    pub token_budget: usize,
    /// Seconds a Batch-class request may wait before it is promoted to
    /// the Interactive queue's tail (the starvation bound).
    /// `f64::INFINITY` disables aging.
    pub aging_threshold_s: f64,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self { max_active: 8, token_budget: 4096, aging_threshold_s: 5.0 }
    }
}

/// A queued request plus the scheduling state that must survive parking.
struct Waiting {
    req: Request,
    /// Tokens already generated — nonzero only for preempted sequences
    /// parked for restore (their allowance resumes, not restarts).
    generated: usize,
    /// Trace-clock time this entry (re-)entered a queue; aging input.
    enqueued_at: f64,
}

/// Two-class priority batcher (strict priority, FIFO within a class).
pub struct Batcher {
    cfg: BatcherConfig,
    /// Per-class wait queues, indexed by `Priority::index()`.
    queues: [VecDeque<Waiting>; Priority::COUNT],
    active: Vec<(Request, usize)>, // (request, generated so far)
    /// Tokens reserved by the active set (kept incrementally so admission
    /// is O(1) per candidate instead of re-summing the active set).
    reserved: usize,
    /// Monotone admission stamp; the server uses it to pick the
    /// most-recently-admitted victim under preemption.
    admissions: u64,
    aged_promotions: u64,
}

impl Batcher {
    pub fn new(cfg: BatcherConfig) -> Self {
        Self {
            cfg,
            queues: [VecDeque::new(), VecDeque::new()],
            active: Vec::new(),
            reserved: 0,
            admissions: 0,
            aged_promotions: 0,
        }
    }

    /// Enqueue an arriving request into its class queue. Non-finite
    /// arrival stamps (a NaN in a hand-built trace) are clamped to 0.0 so
    /// aging arithmetic stays well-defined.
    pub fn submit(&mut self, r: Request) {
        let at = if r.arrival.is_finite() { r.arrival } else { 0.0 };
        self.queues[r.priority.index()].push_back(Waiting { generated: 0, enqueued_at: at, req: r });
    }

    /// Total waiting entries across both class queues.
    pub fn waiting_len(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }

    /// Waiting entries in one class queue (post-aging residence, not the
    /// requests' intrinsic class: promoted Batch entries count as
    /// Interactive here).
    pub fn waiting_len_class(&self, p: Priority) -> usize {
        self.queues[p.index()].len()
    }

    /// Intrinsic priority of the next admission candidate (the head of
    /// the highest non-empty queue), or `None` when nothing waits. The
    /// server compares this against active sequences to pick preemption
    /// victims — intrinsic, not queue residence, so an aged-up Batch
    /// request never preempts a Batch peer.
    pub fn head_priority(&self) -> Option<Priority> {
        for p in Priority::ALL {
            if let Some(w) = self.queues[p.index()].front() {
                return Some(w.req.priority);
            }
        }
        None
    }

    pub fn active_len(&self) -> usize {
        self.active.len()
    }

    /// Tokens *reserved* by active sequences (prompt + full generation
    /// allowance): admission is pessimistic so a round never overflows.
    pub fn reserved_tokens(&self) -> usize {
        self.reserved
    }

    /// Batch→Interactive promotions performed by aging so far.
    pub fn aged_promotions(&self) -> u64 {
        self.aged_promotions
    }

    /// Admit as many waiting requests as fit (strict priority, FIFO
    /// within a class; head-of-line blocking by design so no request
    /// starves). `now = 0.0` — aging never fires for a fresh queue.
    pub fn admit(&mut self) -> usize {
        self.admit_pages(usize::MAX, |_| 0, 0.0)
    }

    /// Promote Batch entries that have waited past the aging threshold to
    /// the Interactive queue's tail, preserving their relative order.
    fn age(&mut self, now: f64) {
        if !self.cfg.aging_threshold_s.is_finite() {
            return;
        }
        let mut i = 0;
        while i < self.queues[Priority::Batch.index()].len() {
            let waited = now - self.queues[Priority::Batch.index()][i].enqueued_at;
            if waited >= self.cfg.aging_threshold_s {
                let w = self.queues[Priority::Batch.index()].remove(i).unwrap();
                self.queues[Priority::Interactive.index()].push_back(w);
                self.aged_promotions += 1;
            } else {
                i += 1;
            }
        }
    }

    /// Page-counted priority admission for the paged KV arena: admit
    /// waiting requests while their worst-case page need (per
    /// `page_cost`, which the server backs with the prefix index so
    /// shared prefixes cost nothing) fits in `free_pages`, alongside the
    /// usual `max_active` and token-budget caps. `now` is the trace
    /// clock, consumed by aging. Unlike the token budget there is no
    /// lone-oversized exception — pages are physical memory; the server
    /// sizes the arena to at least one worst-case sequence so the queue
    /// head always becomes admissible once the arena drains.
    pub fn admit_pages<F>(&mut self, mut free_pages: usize, page_cost: F, now: f64) -> usize
    where
        F: Fn(&Request) -> usize,
    {
        self.age(now);
        let mut admitted = 0;
        'wave: for q in 0..self.queues.len() {
            loop {
                if self.active.len() >= self.cfg.max_active {
                    break 'wave;
                }
                let Some(front) = self.queues[q].front() else { break };
                let need = front.req.prompt.len() + front.req.max_new_tokens;
                // A blocked head blocks the whole wave — never skipped
                // within its class and never backfilled past by a lower
                // class (that would be priority inversion in reverse:
                // Batch work grabbing pages an Interactive head is
                // waiting on).
                if self.reserved + need > self.cfg.token_budget && !self.active.is_empty() {
                    break 'wave;
                }
                if page_cost(&front.req) > free_pages {
                    break 'wave;
                }
                let w = self.queues[q].pop_front().unwrap();
                self.reserved += need;
                free_pages -= page_cost(&w.req);
                self.active.push((w.req, w.generated));
                self.admissions += 1;
                admitted += 1;
            }
        }
        admitted
    }

    /// Monotone count of admissions so far (the server stamps each
    /// `SeqState` with this to identify the most recent victim).
    pub fn admissions(&self) -> u64 {
        self.admissions
    }

    /// Preempt active sequence `i`: remove it from the active set
    /// (`swap_remove`, which the server mirrors on its state vector),
    /// release its token reservation, and park it at the *front* of its
    /// class queue — it was admitted before anything now waiting there,
    /// so the front slot preserves FIFO order. Its generated count rides
    /// along so the eventual re-admission resumes the allowance.
    pub fn preempt(&mut self, i: usize, now: f64) {
        let (req, generated) = self.active.swap_remove(i);
        self.reserved -= req.prompt.len() + req.max_new_tokens;
        let q = req.priority.index();
        self.queues[q].push_front(Waiting { generated, enqueued_at: now, req });
    }

    /// Record one generated token for active seq `i`; returns true if the
    /// sequence is finished.
    pub fn advance(&mut self, i: usize) -> bool {
        let (r, g) = &mut self.active[i];
        *g += 1;
        *g >= r.max_new_tokens
    }

    /// Remove finished sequences (indices into the active set) and return
    /// their requests + generated counts. Indices must be sorted ascending.
    pub fn retire(&mut self, finished: &[usize]) -> Vec<(Request, usize)> {
        let mut out = Vec::with_capacity(finished.len());
        for &i in finished.iter().rev() {
            let entry = self.active.swap_remove(i);
            self.reserved -= entry.0.prompt.len() + entry.0.max_new_tokens;
            out.push(entry);
        }
        out.reverse();
        out
    }

    /// Access active entries (request, generated).
    pub fn active(&self) -> &[(Request, usize)] {
        &self.active
    }

    pub fn is_idle(&self) -> bool {
        self.waiting_len() == 0 && self.active.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn req(id: u64, prompt_len: usize, gen: usize) -> Request {
        Request { id, prompt: vec![1; prompt_len], max_new_tokens: gen, ..Default::default() }
    }

    fn breq(id: u64, prompt_len: usize, gen: usize) -> Request {
        Request { priority: Priority::Batch, ..req(id, prompt_len, gen) }
    }

    #[test]
    fn fifo_admission() {
        let mut b = Batcher::new(BatcherConfig { max_active: 2, token_budget: 1000, ..Default::default() });
        b.submit(req(1, 4, 4));
        b.submit(req(2, 4, 4));
        b.submit(req(3, 4, 4));
        assert_eq!(b.admit(), 2);
        assert_eq!(b.active()[0].0.id, 1);
        assert_eq!(b.active()[1].0.id, 2);
        assert_eq!(b.waiting_len(), 1);
    }

    #[test]
    fn token_budget_respected() {
        let mut b = Batcher::new(BatcherConfig { max_active: 10, token_budget: 20, ..Default::default() });
        b.submit(req(1, 8, 4)); // needs 12
        b.submit(req(2, 8, 4)); // would exceed 20
        assert_eq!(b.admit(), 1);
        // first request alone may exceed? no: admitted even if alone
        assert_eq!(b.active_len(), 1);
    }

    #[test]
    fn oversized_request_admitted_when_alone() {
        // A request larger than the budget must still run (alone) rather
        // than deadlock the queue.
        let mut b = Batcher::new(BatcherConfig { max_active: 4, token_budget: 10, ..Default::default() });
        b.submit(req(1, 50, 10));
        assert_eq!(b.admit(), 1);
    }

    #[test]
    fn admit_pages_counts_free_pages() {
        let mut b = Batcher::new(BatcherConfig { max_active: 8, token_budget: 10_000, ..Default::default() });
        for i in 0..4 {
            b.submit(req(i, 4, 4)); // 8 positions → 2 pages at page_size 4
        }
        let cost = |r: &Request| (r.prompt.len() + r.max_new_tokens).div_ceil(4);
        assert_eq!(b.admit_pages(5, cost, 0.0), 2, "2 pages each, 5 free → 2 admitted");
        assert_eq!(b.waiting_len(), 2);
        // Freeing pages admits the FIFO head next.
        assert_eq!(b.admit_pages(2, cost, 0.0), 1);
        assert_eq!(b.active()[2].0.id, 2);
    }

    #[test]
    fn admit_pages_still_respects_max_active_and_token_budget() {
        let mut b = Batcher::new(BatcherConfig { max_active: 1, token_budget: 1000, ..Default::default() });
        b.submit(req(1, 2, 2));
        b.submit(req(2, 2, 2));
        assert_eq!(b.admit_pages(100, |_| 1, 0.0), 1, "max_active caps page admission");
        let mut b = Batcher::new(BatcherConfig { max_active: 8, token_budget: 10, ..Default::default() });
        b.submit(req(1, 4, 4));
        b.submit(req(2, 4, 4));
        assert_eq!(b.admit_pages(100, |_| 1, 0.0), 1, "token budget caps page admission");
    }

    #[test]
    fn advance_and_retire() {
        let mut b = Batcher::new(BatcherConfig::default());
        b.submit(req(1, 2, 2));
        b.submit(req(2, 2, 3));
        b.admit();
        assert!(!b.advance(0));
        assert!(b.advance(0)); // finished after 2 tokens
        let done = b.retire(&[0]);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].0.id, 1);
        assert_eq!(b.active_len(), 1);
        assert_eq!(b.active()[0].0.id, 2);
    }

    #[test]
    fn reserved_tokens_track_admit_and_retire() {
        let mut b = Batcher::new(BatcherConfig { max_active: 4, token_budget: 100, ..Default::default() });
        b.submit(req(1, 4, 6)); // 10
        b.submit(req(2, 3, 7)); // 10
        assert_eq!(b.reserved_tokens(), 0);
        b.admit();
        assert_eq!(b.reserved_tokens(), 20);
        b.retire(&[0]);
        assert_eq!(b.reserved_tokens(), 10);
        b.retire(&[0]);
        assert_eq!(b.reserved_tokens(), 0);
    }

    #[test]
    fn interactive_admits_before_earlier_batch() {
        // Strict priority: a Batch request submitted first still yields
        // to a later Interactive arrival at admission time.
        let mut b = Batcher::new(BatcherConfig { max_active: 1, ..Default::default() });
        b.submit(breq(1, 4, 4));
        b.submit(req(2, 4, 4));
        assert_eq!(b.admit(), 1);
        assert_eq!(b.active()[0].0.id, 2, "interactive preferred over older batch");
        assert_eq!(b.waiting_len_class(Priority::Batch), 1);
    }

    #[test]
    fn blocked_interactive_head_is_never_backfilled_by_batch() {
        // An Interactive head too big for the remaining budget blocks the
        // wave: the small Batch request behind it must NOT sneak in and
        // grab the capacity the head is waiting for.
        let mut b = Batcher::new(BatcherConfig { max_active: 4, token_budget: 20, ..Default::default() });
        b.submit(req(1, 8, 4)); // 12 — admitted
        b.submit(req(2, 8, 4)); // 12 — blocks (would exceed 20)
        b.submit(breq(3, 1, 1)); // 2 — would fit, must wait anyway
        assert_eq!(b.admit(), 1);
        assert_eq!(b.active()[0].0.id, 1);
        assert_eq!(b.waiting_len(), 2);
    }

    #[test]
    fn aging_promotes_old_batch_requests() {
        let mut b = Batcher::new(BatcherConfig {
            max_active: 1,
            token_budget: 1000,
            aging_threshold_s: 2.0,
        });
        b.submit(breq(1, 4, 4)); // arrival 0.0
        b.submit(req(2, 4, 4));
        // Below the threshold: strict priority holds.
        assert_eq!(b.admit_pages(usize::MAX, |_| 0, 1.0), 1);
        assert_eq!(b.active()[0].0.id, 2);
        assert_eq!(b.aged_promotions(), 0);
        b.retire(&[0]);
        // Past the threshold the Batch entry is promoted to the
        // Interactive queue's tail (a page-blocked wave still ages).
        assert_eq!(b.admit_pages(0, |_| 1, 3.0), 0);
        assert_eq!(b.aged_promotions(), 1);
        assert_eq!(b.waiting_len_class(Priority::Interactive), 1);
        // A newer Interactive arrival now ranks BEHIND the promotee —
        // aging bounds how long Batch work can be overtaken.
        b.submit(req(3, 4, 4));
        assert_eq!(b.admit_pages(usize::MAX, |_| 0, 3.0), 1);
        assert_eq!(b.active()[0].0.id, 1, "aged batch request admitted first");
        assert_eq!(b.aged_promotions(), 1);
        // Its intrinsic class is unchanged (per-class metrics, preemption
        // comparisons), only its queue residence moved.
        assert_eq!(b.active()[0].0.priority, Priority::Batch);
    }

    #[test]
    fn infinite_aging_threshold_disables_promotion() {
        let mut b = Batcher::new(BatcherConfig {
            max_active: 1,
            token_budget: 1000,
            aging_threshold_s: f64::INFINITY,
        });
        b.submit(breq(1, 4, 4));
        b.submit(req(2, 4, 4));
        assert_eq!(b.admit_pages(usize::MAX, |_| 0, 1e12), 1);
        assert_eq!(b.active()[0].0.id, 2);
        assert_eq!(b.aged_promotions(), 0);
    }

    #[test]
    fn preempt_parks_at_front_with_generated_count() {
        let mut b = Batcher::new(BatcherConfig { max_active: 2, ..Default::default() });
        b.submit(breq(1, 4, 6));
        b.submit(breq(2, 4, 6));
        b.submit(breq(3, 4, 6));
        assert_eq!(b.admit(), 2);
        assert!(!b.advance(0)); // id 1 has generated 1 of 6
        let reserved = b.reserved_tokens();
        b.preempt(0, 1.0);
        assert_eq!(b.active_len(), 1);
        assert_eq!(b.reserved_tokens(), reserved - 10);
        // Parked at the front: re-admission picks id 1 before id 3.
        assert_eq!(b.admit(), 1);
        assert_eq!(b.active()[1].0.id, 1);
        assert_eq!(b.active()[1].1, 1, "generated count survives parking");
        // Its remaining allowance resumes: 5 more tokens finish it.
        for k in 0..5 {
            let done = b.advance(1);
            assert_eq!(done, k == 4, "token {k}");
        }
    }

    #[test]
    fn head_priority_reports_intrinsic_class() {
        let mut b = Batcher::new(BatcherConfig { aging_threshold_s: 1.0, ..Default::default() });
        assert_eq!(b.head_priority(), None);
        b.submit(breq(1, 4, 4));
        assert_eq!(b.head_priority(), Some(Priority::Batch));
        b.submit(req(2, 4, 4));
        assert_eq!(b.head_priority(), Some(Priority::Interactive));
        // Age the Batch entry into the Interactive queue: residence moves
        // but the reported class stays Batch once it reaches the head.
        let mut b = Batcher::new(BatcherConfig { max_active: 0, aging_threshold_s: 1.0, ..Default::default() });
        b.submit(breq(3, 4, 4));
        b.admit_pages(usize::MAX, |_| 0, 2.0);
        assert_eq!(b.waiting_len_class(Priority::Interactive), 1);
        assert_eq!(b.head_priority(), Some(Priority::Batch));
    }

    /// Satellite regression: the accounting invariants under random
    /// interleavings of submit / admit_pages / advance / retire /
    /// preempt — `reserved` always equals the active set's worst-case
    /// token sum, the caps always hold (modulo the documented
    /// lone-oversized exception), and admission never skips a class
    /// queue's head (FIFO within class, strict priority across, aging
    /// disabled here so the expected order is exact).
    #[test]
    fn prop_accounting_and_fifo_order_under_random_interleavings() {
        prop::check(
            "batcher accounting invariants",
            60,
            |rng| {
                let n = prop::gens::usize_in(rng, 1, 24);
                let reqs: Vec<(usize, usize, bool)> = (0..n)
                    .map(|_| {
                        (
                            prop::gens::usize_in(rng, 1, 20),
                            prop::gens::usize_in(rng, 1, 10),
                            prop::gens::usize_in(rng, 0, 1) == 1, // batch class?
                        )
                    })
                    .collect();
                let max_active = prop::gens::usize_in(rng, 1, 6);
                let budget = prop::gens::usize_in(rng, 10, 120);
                // Per-step op seeds: page supply, preempt choice.
                let ops: Vec<(usize, usize)> = (0..400)
                    .map(|_| (prop::gens::usize_in(rng, 0, 40), prop::gens::usize_in(rng, 0, 9)))
                    .collect();
                (reqs, max_active, budget, ops)
            },
            |(reqs, max_active, budget, ops)| {
                let mut b = Batcher::new(BatcherConfig {
                    max_active: *max_active,
                    token_budget: *budget,
                    aging_threshold_s: f64::INFINITY,
                });
                // Model: per-class expected FIFO order of waiting ids.
                let mut expect: [std::collections::VecDeque<u64>; 2] =
                    [Default::default(), Default::default()];
                let mut next_submit = 0usize;
                let mut completed = 0usize;
                let mut step = 0usize;
                let page_cost = |r: &Request| (r.prompt.len() + r.max_new_tokens).div_ceil(4);
                while completed < reqs.len() {
                    let (pages, knob) = ops[step % ops.len()];
                    step += 1;
                    if step > 20_000 {
                        return Err("livelock".into());
                    }
                    // Interleave submissions with scheduling steps.
                    if next_submit < reqs.len() && knob % 3 != 0 {
                        let (p, g, batch) = reqs[next_submit];
                        let pr = if batch { Priority::Batch } else { Priority::Interactive };
                        b.submit(Request {
                            id: next_submit as u64,
                            prompt: vec![1; p],
                            max_new_tokens: g,
                            priority: pr,
                            ..Default::default()
                        });
                        expect[pr.index()].push_back(next_submit as u64);
                        next_submit += 1;
                    }
                    let before = b.active_len();
                    b.admit_pages(pages, page_cost, 0.0);
                    // FIFO-head law: the admitted ids must be exactly the
                    // heads of the model queues, interactive first.
                    for (r, _) in &b.active()[before..] {
                        let q = r.priority.index();
                        let head = expect[q].pop_front();
                        if head != Some(r.id) {
                            return Err(format!(
                                "class {q} admitted {} but head was {head:?}",
                                r.id
                            ));
                        }
                        if q == 1 && !expect[0].is_empty() {
                            return Err(format!(
                                "batch {} admitted past waiting interactive head",
                                r.id
                            ));
                        }
                    }
                    // Accounting law: reserved == Σ active worst case.
                    let sum: usize = b
                        .active()
                        .iter()
                        .map(|(r, _)| r.prompt.len() + r.max_new_tokens)
                        .sum();
                    if b.reserved_tokens() != sum {
                        return Err(format!(
                            "reserved {} != active sum {sum}",
                            b.reserved_tokens()
                        ));
                    }
                    if b.active_len() > *max_active {
                        return Err("max_active exceeded".into());
                    }
                    if b.active_len() > 1 && sum > *budget {
                        return Err(format!("budget exceeded: {sum} > {budget}"));
                    }
                    // Occasionally preempt a random active sequence; it
                    // must reappear at its class head.
                    if b.active_len() > 1 && knob == 9 {
                        let i = knob % b.active_len();
                        let (victim, _) = &b.active()[i];
                        let (vid, vq) = (victim.id, victim.priority.index());
                        b.preempt(i, 0.0);
                        expect[vq].push_front(vid);
                    }
                    // Advance everyone one token; retire the finished.
                    let mut finished = Vec::new();
                    for i in 0..b.active_len() {
                        if b.advance(i) {
                            finished.push(i);
                        }
                    }
                    completed += b.retire(&finished).len();
                }
                if !b.is_idle() {
                    return Err("requests left behind".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_no_starvation_and_budget_invariant() {
        prop::check(
            "batcher invariants",
            50,
            |rng| {
                let n = prop::gens::usize_in(rng, 1, 30);
                let reqs: Vec<(usize, usize)> = (0..n)
                    .map(|_| (prop::gens::usize_in(rng, 1, 20), prop::gens::usize_in(rng, 1, 10)))
                    .collect();
                let max_active = prop::gens::usize_in(rng, 1, 6);
                let budget = prop::gens::usize_in(rng, 10, 120);
                (reqs, max_active, budget)
            },
            |(reqs, max_active, budget)| {
                let mut b = Batcher::new(BatcherConfig {
                    max_active: *max_active,
                    token_budget: *budget,
                    ..Default::default()
                });
                for (i, &(p, g)) in reqs.iter().enumerate() {
                    b.submit(req(i as u64, p, g));
                }
                let mut completed: Vec<u64> = Vec::new();
                let mut rounds = 0usize;
                while !b.is_idle() {
                    rounds += 1;
                    if rounds > 10_000 {
                        return Err("livelock".into());
                    }
                    b.admit();
                    // budget invariant (allow the lone-oversized exception)
                    if b.active_len() > 1 {
                        let reserved: usize = b
                            .active()
                            .iter()
                            .map(|(r, _)| r.prompt.len() + r.max_new_tokens)
                            .sum();
                        if reserved > *budget {
                            return Err(format!("budget exceeded: reserved {reserved} > {budget}"));
                        }
                    }
                    if b.active_len() > *max_active {
                        return Err("max_active exceeded".into());
                    }
                    let mut finished = Vec::new();
                    for i in 0..b.active_len() {
                        if b.advance(i) {
                            finished.push(i);
                        }
                    }
                    for (r, _) in b.retire(&finished) {
                        completed.push(r.id);
                    }
                }
                if completed.len() != reqs.len() {
                    return Err(format!("starved: {} of {} completed", completed.len(), reqs.len()));
                }
                Ok(())
            },
        );
    }
}
