//! Continuous batcher: admits waiting requests into the active decode set
//! under a token budget, FIFO within arrival order (no starvation).
//!
//! The active set is the decode round's batch: the server feeds every
//! active sequence's next token through one fused
//! `TernaryModel::forward_batch` call per (micro-)step, so admission here
//! directly sets the LUT-GEMM batch width the kernels amortize over.

use std::collections::VecDeque;

use super::Request;

/// Batching policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Max concurrently active sequences (bounded by the KV pool).
    pub max_active: usize,
    /// Max total resident tokens (prompt + generated) across active seqs.
    pub token_budget: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self { max_active: 8, token_budget: 4096 }
    }
}

/// FIFO continuous batcher.
pub struct Batcher {
    cfg: BatcherConfig,
    waiting: VecDeque<Request>,
    active: Vec<(Request, usize)>, // (request, generated so far)
    /// Tokens reserved by the active set (kept incrementally so admission
    /// is O(1) per candidate instead of re-summing the active set).
    reserved: usize,
}

impl Batcher {
    pub fn new(cfg: BatcherConfig) -> Self {
        Self { cfg, waiting: VecDeque::new(), active: Vec::new(), reserved: 0 }
    }

    /// Enqueue an arriving request.
    pub fn submit(&mut self, r: Request) {
        self.waiting.push_back(r);
    }

    pub fn waiting_len(&self) -> usize {
        self.waiting.len()
    }

    pub fn active_len(&self) -> usize {
        self.active.len()
    }

    /// Tokens *reserved* by active sequences (prompt + full generation
    /// allowance): admission is pessimistic so a round never overflows.
    pub fn reserved_tokens(&self) -> usize {
        self.reserved
    }

    /// Admit as many waiting requests as fit (FIFO; head-of-line blocking
    /// by design so no request starves).
    pub fn admit(&mut self) -> usize {
        self.admit_pages(usize::MAX, |_| 0)
    }

    /// Page-counted FIFO admission for the paged KV arena: admit waiting
    /// requests while their worst-case page need (per `page_cost`, which
    /// the server backs with the prefix index so shared prefixes cost
    /// nothing) fits in `free_pages`, alongside the usual `max_active`
    /// and token-budget caps. Unlike the token budget there is no
    /// lone-oversized exception — pages are physical memory; the server
    /// sizes the arena to at least one worst-case sequence so the queue
    /// head always becomes admissible once the arena drains.
    pub fn admit_pages<F>(&mut self, mut free_pages: usize, page_cost: F) -> usize
    where
        F: Fn(&Request) -> usize,
    {
        let mut admitted = 0;
        while self.active.len() < self.cfg.max_active {
            let Some(front) = self.waiting.front() else { break };
            let need = front.prompt.len() + front.max_new_tokens;
            if self.reserved + need > self.cfg.token_budget && !self.active.is_empty() {
                break; // wait for space; never skip the head
            }
            let pages = page_cost(front);
            if pages > free_pages {
                break;
            }
            let r = self.waiting.pop_front().unwrap();
            self.reserved += need;
            free_pages -= pages;
            self.active.push((r, 0));
            admitted += 1;
        }
        admitted
    }

    /// Record one generated token for active seq `i`; returns true if the
    /// sequence is finished.
    pub fn advance(&mut self, i: usize) -> bool {
        let (r, g) = &mut self.active[i];
        *g += 1;
        *g >= r.max_new_tokens
    }

    /// Remove finished sequences (indices into the active set) and return
    /// their requests + generated counts. Indices must be sorted ascending.
    pub fn retire(&mut self, finished: &[usize]) -> Vec<(Request, usize)> {
        let mut out = Vec::with_capacity(finished.len());
        for &i in finished.iter().rev() {
            let entry = self.active.swap_remove(i);
            self.reserved -= entry.0.prompt.len() + entry.0.max_new_tokens;
            out.push(entry);
        }
        out.reverse();
        out
    }

    /// Access active entries (request, generated).
    pub fn active(&self) -> &[(Request, usize)] {
        &self.active
    }

    pub fn is_idle(&self) -> bool {
        self.waiting.is_empty() && self.active.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn req(id: u64, prompt_len: usize, gen: usize) -> Request {
        Request { id, prompt: vec![1; prompt_len], max_new_tokens: gen, arrival: 0.0 }
    }

    #[test]
    fn fifo_admission() {
        let mut b = Batcher::new(BatcherConfig { max_active: 2, token_budget: 1000 });
        b.submit(req(1, 4, 4));
        b.submit(req(2, 4, 4));
        b.submit(req(3, 4, 4));
        assert_eq!(b.admit(), 2);
        assert_eq!(b.active()[0].0.id, 1);
        assert_eq!(b.active()[1].0.id, 2);
        assert_eq!(b.waiting_len(), 1);
    }

    #[test]
    fn token_budget_respected() {
        let mut b = Batcher::new(BatcherConfig { max_active: 10, token_budget: 20 });
        b.submit(req(1, 8, 4)); // needs 12
        b.submit(req(2, 8, 4)); // would exceed 20
        assert_eq!(b.admit(), 1);
        // first request alone may exceed? no: admitted even if alone
        assert_eq!(b.active_len(), 1);
    }

    #[test]
    fn oversized_request_admitted_when_alone() {
        // A request larger than the budget must still run (alone) rather
        // than deadlock the queue.
        let mut b = Batcher::new(BatcherConfig { max_active: 4, token_budget: 10 });
        b.submit(req(1, 50, 10));
        assert_eq!(b.admit(), 1);
    }

    #[test]
    fn admit_pages_counts_free_pages() {
        let mut b = Batcher::new(BatcherConfig { max_active: 8, token_budget: 10_000 });
        for i in 0..4 {
            b.submit(req(i, 4, 4)); // 8 positions → 2 pages at page_size 4
        }
        let cost = |r: &Request| (r.prompt.len() + r.max_new_tokens).div_ceil(4);
        assert_eq!(b.admit_pages(5, cost), 2, "2 pages each, 5 free → 2 admitted");
        assert_eq!(b.waiting_len(), 2);
        // Freeing pages admits the FIFO head next.
        assert_eq!(b.admit_pages(2, cost), 1);
        assert_eq!(b.active()[2].0.id, 2);
    }

    #[test]
    fn admit_pages_still_respects_max_active_and_token_budget() {
        let mut b = Batcher::new(BatcherConfig { max_active: 1, token_budget: 1000 });
        b.submit(req(1, 2, 2));
        b.submit(req(2, 2, 2));
        assert_eq!(b.admit_pages(100, |_| 1), 1, "max_active caps page admission");
        let mut b = Batcher::new(BatcherConfig { max_active: 8, token_budget: 10 });
        b.submit(req(1, 4, 4));
        b.submit(req(2, 4, 4));
        assert_eq!(b.admit_pages(100, |_| 1), 1, "token budget caps page admission");
    }

    #[test]
    fn advance_and_retire() {
        let mut b = Batcher::new(BatcherConfig::default());
        b.submit(req(1, 2, 2));
        b.submit(req(2, 2, 3));
        b.admit();
        assert!(!b.advance(0));
        assert!(b.advance(0)); // finished after 2 tokens
        let done = b.retire(&[0]);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].0.id, 1);
        assert_eq!(b.active_len(), 1);
        assert_eq!(b.active()[0].0.id, 2);
    }

    #[test]
    fn reserved_tokens_track_admit_and_retire() {
        let mut b = Batcher::new(BatcherConfig { max_active: 4, token_budget: 100 });
        b.submit(req(1, 4, 6)); // 10
        b.submit(req(2, 3, 7)); // 10
        assert_eq!(b.reserved_tokens(), 0);
        b.admit();
        assert_eq!(b.reserved_tokens(), 20);
        b.retire(&[0]);
        assert_eq!(b.reserved_tokens(), 10);
        b.retire(&[0]);
        assert_eq!(b.reserved_tokens(), 0);
    }

    #[test]
    fn prop_no_starvation_and_budget_invariant() {
        prop::check(
            "batcher invariants",
            50,
            |rng| {
                let n = prop::gens::usize_in(rng, 1, 30);
                let reqs: Vec<(usize, usize)> = (0..n)
                    .map(|_| (prop::gens::usize_in(rng, 1, 20), prop::gens::usize_in(rng, 1, 10)))
                    .collect();
                let max_active = prop::gens::usize_in(rng, 1, 6);
                let budget = prop::gens::usize_in(rng, 10, 120);
                (reqs, max_active, budget)
            },
            |(reqs, max_active, budget)| {
                let mut b = Batcher::new(BatcherConfig { max_active: *max_active, token_budget: *budget });
                for (i, &(p, g)) in reqs.iter().enumerate() {
                    b.submit(req(i as u64, p, g));
                }
                let mut completed: Vec<u64> = Vec::new();
                let mut rounds = 0usize;
                while !b.is_idle() {
                    rounds += 1;
                    if rounds > 10_000 {
                        return Err("livelock".into());
                    }
                    b.admit();
                    // budget invariant (allow the lone-oversized exception)
                    if b.active_len() > 1 {
                        let reserved: usize = b
                            .active()
                            .iter()
                            .map(|(r, _)| r.prompt.len() + r.max_new_tokens)
                            .sum();
                        if reserved > *budget {
                            return Err(format!("budget exceeded: reserved {reserved} > {budget}"));
                        }
                    }
                    if b.active_len() > *max_active {
                        return Err("max_active exceeded".into());
                    }
                    let mut finished = Vec::new();
                    for i in 0..b.active_len() {
                        if b.advance(i) {
                            finished.push(i);
                        }
                    }
                    for (r, _) in b.retire(&finished) {
                        completed.push(r.id);
                    }
                }
                if completed.len() != reqs.len() {
                    return Err(format!("starved: {} of {} completed", completed.len(), reqs.len()));
                }
                Ok(())
            },
        );
    }
}
