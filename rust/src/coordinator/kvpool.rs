//! Allocator-backed KV leasing: the coordinator's window onto the paged
//! cache subsystem (`crate::cache`).
//!
//! Replaces the seed's whole-cache pool (a bounded set of
//! `seq_len × d_model` contiguous caches) with page-granular leasing:
//! admission is counted in free *pages*, a newly admitted request leases
//! a [`BlockTable`] seeded from the radix [`PrefixIndex`] (reusing the
//! frozen KV pages of any previously seen prompt prefix), and retirement
//! returns pages to the arena. On edge devices the KV cache dominates
//! transient memory (the paper's Limitations note BF16 KV); paging turns
//! the same byte budget into strictly more admissible concurrency
//! whenever requests are shorter than the worst case.
//!
//! Byte accounting is **dtype-aware**: the pool turns one fixed byte
//! budget into a page count at the arena's [`KvDtype`]
//! ([`PagedKv::pages_for_budget`]), so an int8 arena holds ~4× the pages
//! of an f32 one — and a ternary arena (1.25-bit 3:4 K pages + int8 V)
//! more still — and page-counted admission scales with it: KV
//! quantization is a concurrency knob, not just a footprint one.
//!
//! Prefix sharing works for **every** dtype. f32 pools share down to a
//! page's live prefix; quantized pools (int8 and ternary) share at
//! whole-page granularity only (`page_exact`), because a frozen page's
//! bytes are a deterministic function of its full chunk while a
//! *partial* read of them is quantized at a scale the donor's later rows
//! grew — see [`PagedKv::new`] and DESIGN.md §4 for the
//! serving-order-invariance argument.

use crate::cache::{page_bytes, BlockAllocator, BlockTable, KvDtype, PrefixIndex};
use crate::engine::NativeConfig;

use super::Request;

/// Paged KV lease manager: one arena + one prefix index per server run.
pub struct PagedKv {
    alloc: BlockAllocator,
    index: PrefixIndex,
    sharing: bool,
    /// Quantized pools share whole frozen pages only (see
    /// [`PagedKv::new`]); f32 pools may also share a page's live prefix.
    page_exact: bool,
    seq_len: usize,
}

impl PagedKv {
    /// Arena with `num_pages` pages of `page_size` positions at `dtype`,
    /// sized for `cfg`. `sharing` enables the radix prefix index.
    /// `num_pages` is raised to at least one worst-case sequence so a
    /// lone request can always run (head-of-line liveness).
    ///
    /// Sharing's contract is that a reused page holds exactly the rows
    /// the recipient's own prefill would have produced. For **f32**
    /// pages that holds row-by-row, so a partially matched tail page is
    /// shared up to its live prefix (the recipient copy-on-writes at
    /// first divergence). For **quantized** pages it holds only at
    /// whole-page granularity: a page's bytes are a deterministic
    /// function of its full chunk's tokens (same rows ⇒ same
    /// quantization trajectory ⇒ same bytes and frozen registration
    /// scales), but a *prefix* of those bytes is quantized at a scale
    /// the donor's later rows in that page grew — not the scale the
    /// recipient's own prefill would have used — which would make
    /// completions depend on serving order. Quantized pools therefore
    /// truncate every shared span to a whole-page multiple
    /// (`page_exact`): reuse stays byte-exact and serving-order
    /// invariant, at the cost of re-prefilling at most
    /// `page_size − 1` matched tail tokens.
    pub fn new(
        cfg: &NativeConfig,
        num_pages: usize,
        page_size: usize,
        sharing: bool,
        dtype: KvDtype,
    ) -> Self {
        let page_size = page_size.max(1);
        let per_seq = cfg.seq_len.div_ceil(page_size);
        let num_pages = num_pages.max(per_seq);
        Self {
            alloc: BlockAllocator::new_with(cfg, num_pages, page_size, dtype),
            index: PrefixIndex::new(page_size),
            sharing,
            page_exact: dtype != KvDtype::F32,
            seq_len: cfg.seq_len,
        }
    }

    /// Pages a byte budget of `kv_capacity` f32 whole-cache equivalents
    /// (the seed's knob: `kv_capacity` contiguous `seq_len × d_model`
    /// caches) buys at `dtype` — the coordinator holds bytes fixed and
    /// lets the dtype set the page count.
    pub fn pages_for_budget(
        cfg: &NativeConfig,
        kv_capacity: usize,
        page_size: usize,
        dtype: KvDtype,
    ) -> usize {
        let page_size = page_size.max(1);
        let budget = kv_capacity.max(1) * page_bytes(cfg, cfg.seq_len, KvDtype::F32);
        (budget / page_bytes(cfg, page_size, dtype)).max(1)
    }

    pub fn page_size(&self) -> usize {
        self.alloc.page_size()
    }

    pub fn num_pages(&self) -> usize {
        self.alloc.num_pages()
    }

    pub fn free_pages(&self) -> usize {
        self.alloc.free_pages()
    }

    pub fn used_pages(&self) -> usize {
        self.alloc.used_pages()
    }

    pub fn peak_used(&self) -> usize {
        self.alloc.peak_used()
    }

    /// Pages frozen in the prefix index.
    pub fn index_pages(&self) -> usize {
        self.index.pages_held()
    }

    /// Total arena bytes at the storage dtype (the KV byte budget).
    pub fn bytes(&self) -> usize {
        self.alloc.bytes()
    }

    /// Storage dtype of the arena.
    pub fn dtype(&self) -> KvDtype {
        self.alloc.dtype()
    }

    /// Bytes one stored position costs (kv-bytes-per-token gauge).
    pub fn bytes_per_token(&self) -> usize {
        self.alloc.bytes_per_token()
    }

    /// K-plane share of [`PagedKv::bytes_per_token`] — dtype-asymmetric
    /// stores (ternary: 1.25-bit K, int8 V) split unevenly.
    pub fn k_bytes_per_token(&self) -> usize {
        self.alloc.store().k_bytes_per_token()
    }

    /// V-plane share of [`PagedKv::bytes_per_token`].
    pub fn v_bytes_per_token(&self) -> usize {
        self.alloc.store().v_bytes_per_token()
    }

    /// Cumulative nanoseconds the store spent dequantizing page blocks
    /// (0 for f32 — the dequant-overhead gauge).
    pub fn dequant_nanos(&self) -> u64 {
        self.alloc.store().dequant_nanos()
    }

    /// `(int8-native, dequant/borrow, ternary-LUT)` attention q·k row
    /// counts — inputs of the storage-dtype dot-fraction gauges.
    pub fn qk_rows(&self) -> (u64, u64, u64) {
        self.alloc.store().qk_rows()
    }

    /// Attention a·V rows accumulated in integer fixed point over raw
    /// int8 V page bytes (the `kv_av_rows_int8` gauge; 0 for f32 pools
    /// or with integer-V disabled).
    pub fn av_rows(&self) -> u64 {
        self.alloc.store().av_rows()
    }

    /// Toggle the integer a·V pass (quantized pools only; f32 pools
    /// ignore it). On by default — off forces the V pass back through
    /// f32 tiles, the bench sweep's comparison leg.
    pub fn set_integer_av(&mut self, on: bool) {
        self.alloc.set_integer_av(on);
    }

    /// `(hits, misses)` of the store's frozen-tile cache.
    pub fn tile_cache_stats(&self) -> (u64, u64) {
        self.alloc.store().tile_cache_stats()
    }

    /// Resize the store's frozen-tile LRU (0 disables caching; no-op for
    /// f32 pools, whose block reads are free borrows).
    pub fn set_tile_cache_capacity(&mut self, tiles: usize) {
        self.alloc.set_tile_cache_capacity(tiles);
    }

    /// The arena, for the decode round's [`KvBatch`](crate::cache::KvBatch).
    pub fn alloc_mut(&mut self) -> &mut BlockAllocator {
        &mut self.alloc
    }

    /// Largest prefix span a lease may reuse: at least one prompt token
    /// must always be fed (to produce logits) and the context limit is
    /// respected. One definition shared by probe and lease so the two
    /// walks can never disagree.
    fn probe_cap(&self, prompt: &[u32]) -> usize {
        prompt.len().saturating_sub(1).min(self.seq_len.saturating_sub(1))
    }

    /// Shared spans a quantized pool may reuse are whole-page multiples
    /// (see [`PagedKv::new`]); f32 pools reuse the full matched span.
    /// One definition shared by probe and lease so the two can never
    /// disagree.
    fn effective_span(&self, matched: usize) -> usize {
        if self.page_exact {
            matched - matched % self.page_size()
        } else {
            matched
        }
    }

    /// Longest index-reusable prefix of `prompt`.
    fn shared_span(&self, prompt: &[u32]) -> usize {
        if !self.sharing {
            return 0;
        }
        self.effective_span(self.index.probe_len(prompt, self.probe_cap(prompt)))
    }

    /// Worst-case pages `req` will allocate over its lifetime: every
    /// position up to the context limit, minus fully shared prefix pages.
    /// (A partially shared page is counted — its copy-on-write target is
    /// a fresh allocation.) Admission reserves against this, so decode
    /// can never hit arena exhaustion.
    pub fn page_need(&self, req: &Request) -> usize {
        self.pages_for(req, self.shared_span(&req.prompt))
    }

    /// [`PagedKv::page_need`] with an already-known shared span — lets the
    /// server reuse the span [`PagedKv::lease`] returned instead of
    /// walking the prefix trie again.
    pub fn pages_for(&self, req: &Request, shared: usize) -> usize {
        let total = (req.prompt.len() + req.max_new_tokens).min(self.seq_len);
        let ps = self.page_size();
        total.div_ceil(ps) - shared / ps
    }

    /// Lease a block table for `prompt`: seeded from the prefix index
    /// (taking one reference per shared page) when sharing is on.
    /// Returns the table and the shared span length — prefill starts at
    /// that offset. Quantized pools drop a partially matched tail page
    /// here (`effective_span`), so their leases hold whole frozen pages
    /// only and never copy-on-write out of one.
    pub fn lease(&mut self, prompt: &[u32]) -> (BlockTable, usize) {
        let ps = self.page_size();
        if !self.sharing {
            return (BlockTable::new(ps), 0);
        }
        let (mut pages, probed) = self.index.probe_pages(prompt, self.probe_cap(prompt));
        let matched = self.effective_span(probed);
        pages.truncate(matched.div_ceil(ps));
        for &p in &pages {
            self.alloc.retain(p);
        }
        (BlockTable::from_shared(ps, pages, matched), matched)
    }

    /// Return a retired sequence's pages to the arena.
    pub fn release(&mut self, table: &mut BlockTable) {
        table.release_all(&mut self.alloc);
    }

    /// Freeze a prefilled sequence's full prompt pages into the index
    /// (no-op with sharing off).
    pub fn register(&mut self, prompt: &[u32], table: &BlockTable) {
        if self.sharing {
            self.index.register(prompt, table, &mut self.alloc);
        }
    }

    /// Evict index-frozen pages with zero live leases — the coordinator's
    /// pressure valve when frozen pages would starve admission. Prefixes
    /// that live sequences still decode through survive (flushing them
    /// frees no memory — their lease refcounts keep the pages resident).
    /// Returns pages actually freed back to the arena.
    pub fn flush_index(&mut self) -> usize {
        self.index.evict_unreferenced(&mut self.alloc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kv(pages: usize, ps: usize, sharing: bool) -> PagedKv {
        PagedKv::new(&NativeConfig::named("nano").unwrap(), pages, ps, sharing, KvDtype::F32)
    }

    fn req(prompt: Vec<u32>, gen: usize) -> Request {
        Request { id: 0, prompt, max_new_tokens: gen, ..Default::default() }
    }

    #[test]
    fn page_need_is_worst_case_rounded_up() {
        let kv = kv(64, 4, true);
        assert_eq!(kv.page_need(&req(vec![1; 3], 1)), 1); // 4 positions → 1 page
        assert_eq!(kv.page_need(&req(vec![1; 3], 2)), 2); // 5 positions → 2 pages
        // Capped at the context limit (nano seq_len = 64 → 16 pages).
        assert_eq!(kv.page_need(&req(vec![1; 10], 1000)), 16);
    }

    #[test]
    fn lease_prefill_register_release_cycle() {
        let mut kv = kv(64, 4, true);
        let prompt: Vec<u32> = (0..8).collect();
        // First request: no sharing available yet.
        let (mut t, shared) = kv.lease(&prompt);
        assert_eq!(shared, 0);
        for _ in 0..prompt.len() {
            t.prepare_append(kv.alloc_mut());
            t.advance();
        }
        kv.register(&prompt, &t);
        assert_eq!(kv.index_pages(), 2);
        kv.release(&mut t);
        assert_eq!(kv.used_pages(), 2, "index keeps the frozen prompt pages");

        // Second request with the same prompt shares all but the last token.
        let (mut t2, shared) = kv.lease(&prompt);
        assert_eq!(shared, 7);
        assert_eq!(kv.page_need(&req(prompt.clone(), 4)), 3 - 1, "one full page shared");
        kv.release(&mut t2);

        assert_eq!(kv.flush_index(), 2);
        assert_eq!(kv.used_pages(), 0);
    }

    #[test]
    fn sharing_off_is_inert() {
        let mut kv = kv(16, 4, false);
        let prompt: Vec<u32> = (0..8).collect();
        let (mut t, shared) = kv.lease(&prompt);
        assert_eq!(shared, 0);
        for _ in 0..prompt.len() {
            t.prepare_append(kv.alloc_mut());
            t.advance();
        }
        kv.register(&prompt, &t);
        assert_eq!(kv.index_pages(), 0);
        kv.release(&mut t);
        assert_eq!(kv.used_pages(), 0);
    }

    #[test]
    fn num_pages_raised_to_one_worst_case_sequence() {
        let kv = kv(1, 16, true); // nano seq_len 64 → 4 pages minimum
        assert_eq!(kv.num_pages(), 4);
    }

    #[test]
    fn budget_buys_more_int8_pages_than_f32_at_same_bytes() {
        let cfg = NativeConfig::named("nano").unwrap();
        let f32_pages = PagedKv::pages_for_budget(&cfg, 2, 16, KvDtype::F32);
        let int8_pages = PagedKv::pages_for_budget(&cfg, 2, 16, KvDtype::Int8);
        // 2 whole caches at page_size 16 → 8 f32 pages; int8 pages cost
        // just over a quarter of the bytes.
        assert_eq!(f32_pages, 8);
        assert!(int8_pages >= 2 * f32_pages, "{int8_pages} vs {f32_pages}");
        // And the arena built at that count stays within the f32 budget.
        let budget = PagedKv::new(&cfg, f32_pages, 16, false, KvDtype::F32).bytes();
        let quant = PagedKv::new(&cfg, int8_pages, 16, false, KvDtype::Int8);
        assert!(quant.bytes() <= budget);
        assert!(quant.bytes_per_token() * 2 <= 2 * cfg.n_layers * cfg.d_model * 4);
    }

    #[test]
    fn budget_buys_most_pages_at_ternary() {
        // Same byte budget, three dtypes: page counts must be strictly
        // ordered f32 < int8 < ternary, and the K/V breakdown must show
        // the ternary pool's K plane at the 1.25-bit rate.
        let cfg = NativeConfig::named("nano").unwrap();
        let f32_pages = PagedKv::pages_for_budget(&cfg, 2, 16, KvDtype::F32);
        let int8_pages = PagedKv::pages_for_budget(&cfg, 2, 16, KvDtype::Int8);
        let tern_pages = PagedKv::pages_for_budget(&cfg, 2, 16, KvDtype::Ternary);
        assert!(f32_pages < int8_pages && int8_pages < tern_pages, "{f32_pages}/{int8_pages}/{tern_pages}");
        let budget = PagedKv::new(&cfg, f32_pages, 16, false, KvDtype::F32).bytes();
        let tern = PagedKv::new(&cfg, tern_pages, 16, false, KvDtype::Ternary);
        assert!(tern.bytes() <= budget);
        assert_eq!(
            tern.k_bytes_per_token() + tern.v_bytes_per_token(),
            tern.bytes_per_token()
        );
        // nano: ternary K = 42 B/token vs int8 K = 258 B/token.
        let int8 = PagedKv::new(&cfg, 4, 16, false, KvDtype::Int8);
        assert!(tern.k_bytes_per_token() * 4 < int8.k_bytes_per_token(), "1.25-bit K plane");
        assert_eq!(tern.v_bytes_per_token(), int8.v_bytes_per_token(), "V stays int8");
    }

    #[test]
    fn ternary_pool_shares_whole_frozen_pages_only() {
        // Same page_exact protocol as int8: the absmean trajectory of a
        // page is a function of its full chunk, so partial tail pages are
        // re-prefilled rather than shared.
        let cfg = NativeConfig::named("nano").unwrap();
        let mut kv = PagedKv::new(&cfg, 64, 4, true, KvDtype::Ternary);
        let prompt: Vec<u32> = (0..8).collect();
        let (mut t, shared) = kv.lease(&prompt);
        assert_eq!(shared, 0);
        for _ in 0..prompt.len() {
            t.prepare_append(kv.alloc_mut());
            t.advance();
        }
        kv.register(&prompt, &t);
        assert_eq!(kv.index_pages(), 2);
        let (mut t2, shared) = kv.lease(&prompt);
        assert_eq!(shared, 4, "shared span truncates to a whole-page multiple");
        assert_eq!(t2.shared_prefix_pages(), 1);
        kv.release(&mut t);
        kv.release(&mut t2);
        assert_eq!(kv.flush_index(), 2);
        assert_eq!(kv.used_pages(), 0);
    }

    #[test]
    fn int8_pool_shares_whole_frozen_pages_only() {
        // Quantized pools share at page granularity: a probe that
        // matches 7 of 8 tokens (cap always drops the last) reuses only
        // the first full page — the partially matched tail page is
        // re-prefilled by the recipient so its quantization trajectory
        // is its own, keeping completions serving-order invariant.
        let cfg = NativeConfig::named("nano").unwrap();
        let mut kv = PagedKv::new(&cfg, 64, 4, true, KvDtype::Int8);
        let prompt: Vec<u32> = (0..8).collect();
        let (mut t, shared) = kv.lease(&prompt);
        assert_eq!(shared, 0);
        for _ in 0..prompt.len() {
            t.prepare_append(kv.alloc_mut());
            t.advance();
        }
        kv.register(&prompt, &t);
        assert_eq!(kv.index_pages(), 2, "full prompt chunks freeze for int8 pools too");

        // f32 pools would share 7 tokens here; int8 rounds down to 4.
        let (mut t2, shared) = kv.lease(&prompt);
        assert_eq!(shared, 4, "shared span truncates to a whole-page multiple");
        assert_eq!(t2.pages().len(), 1);
        assert_eq!(t2.shared_prefix_pages(), 1);
        // Admission accounting sees the same span (probe == lease).
        assert_eq!(kv.page_need(&req(prompt.clone(), 4)), 3 - 1);

        // A prompt diverging mid-chunk-2 also shares exactly one page.
        let other: Vec<u32> = vec![0, 1, 2, 3, 4, 5, 99, 99];
        let (mut t3, shared) = kv.lease(&other);
        assert_eq!(shared, 4);

        kv.release(&mut t);
        kv.release(&mut t2);
        kv.release(&mut t3);
        assert_eq!(kv.flush_index(), 2);
        assert_eq!(kv.used_pages(), 0);
    }

    #[test]
    fn registration_freezes_int8_pages() {
        // Registered pages are frozen artifacts: the store reports them
        // frozen (enabling tile caching + byte-exact sharing), and a
        // fresh reallocation after eviction thaws them.
        let cfg = NativeConfig::named("nano").unwrap();
        let mut kv = PagedKv::new(&cfg, 64, 4, true, KvDtype::Int8);
        let prompt: Vec<u32> = (10..18).collect();
        let (mut t, _) = kv.lease(&prompt);
        for _ in 0..prompt.len() {
            t.prepare_append(kv.alloc_mut());
            t.advance();
        }
        let frozen_pages: Vec<_> = t.pages()[..2].to_vec();
        for &p in &frozen_pages {
            assert!(!kv.alloc_mut().store().is_frozen(p), "not frozen before registration");
        }
        kv.register(&prompt, &t);
        for &p in &frozen_pages {
            assert!(kv.alloc_mut().store().is_frozen(p), "registration freezes the page");
        }
        kv.release(&mut t);
        assert_eq!(kv.flush_index(), 2);
        // Reallocate: the page comes back thawed.
        let p = kv.alloc_mut().alloc().unwrap();
        assert!(!kv.alloc_mut().store().is_frozen(p));
        kv.alloc_mut().release(p);
    }

    #[test]
    fn flush_spares_leased_prefix_pages() {
        // A prompt frozen into the index and actively leased by a live
        // table must survive the pressure flush; once released it goes.
        let mut kv = kv(64, 4, true);
        let prompt: Vec<u32> = (0..8).collect();
        let (mut t, _) = kv.lease(&prompt);
        for _ in 0..prompt.len() {
            t.prepare_append(kv.alloc_mut());
            t.advance();
        }
        kv.register(&prompt, &t);
        // Lease a second table over the shared prefix, retire the donor.
        let (mut t2, shared) = kv.lease(&prompt);
        assert_eq!(shared, 7);
        kv.release(&mut t);
        assert_eq!(kv.flush_index(), 0, "leased prefix pages are not freed");
        assert_eq!(kv.index_pages(), 2, "nodes survive for future hits");
        kv.release(&mut t2);
        assert_eq!(kv.flush_index(), 2);
        assert_eq!(kv.used_pages(), 0);
    }
}
