//! KV-cache pool: bounded set of reusable per-sequence caches.
//!
//! On edge devices the KV cache dominates transient memory (the paper's
//! Limitations note BF16 KV). The pool caps concurrency, reuses
//! allocations across requests, and reports resident bytes to the metrics
//! registry.

use crate::engine::{KvCache, NativeConfig};

/// Fixed-capacity cache pool.
pub struct KvPool {
    cfg: NativeConfig,
    free: Vec<KvCache>,
    capacity: usize,
    leased: usize,
}

impl KvPool {
    pub fn new(cfg: NativeConfig, capacity: usize) -> Self {
        Self { cfg, free: Vec::new(), capacity, leased: 0 }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn leased(&self) -> usize {
        self.leased
    }

    pub fn available(&self) -> usize {
        self.capacity - self.leased
    }

    /// Take a cleared cache, or None at capacity.
    pub fn acquire(&mut self) -> Option<KvCache> {
        if self.leased >= self.capacity {
            return None;
        }
        self.leased += 1;
        Some(match self.free.pop() {
            Some(mut c) => {
                c.clear();
                c
            }
            None => KvCache::new(&self.cfg),
        })
    }

    /// Return a cache to the pool.
    pub fn release(&mut self, cache: KvCache) {
        assert!(self.leased > 0, "release without acquire");
        self.leased -= 1;
        self.free.push(cache);
    }

    /// Bytes resident in pooled (idle) caches.
    pub fn idle_bytes(&self) -> usize {
        self.free.iter().map(|c| c.bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool(cap: usize) -> KvPool {
        KvPool::new(NativeConfig::named("nano").unwrap(), cap)
    }

    #[test]
    fn capacity_enforced() {
        let mut p = pool(2);
        let a = p.acquire().unwrap();
        let _b = p.acquire().unwrap();
        assert!(p.acquire().is_none());
        p.release(a);
        assert!(p.acquire().is_some());
    }

    #[test]
    fn reuses_allocations() {
        let mut p = pool(1);
        let c = p.acquire().unwrap();
        p.release(c);
        let c2 = p.acquire().unwrap();
        assert_eq!(c2.len, 0); // cleared on reuse
        p.release(c2);
        assert_eq!(p.leased(), 0);
    }

    #[test]
    #[should_panic(expected = "release without acquire")]
    fn double_release_panics() {
        let mut p = pool(1);
        p.release(KvCache::new(&NativeConfig::named("nano").unwrap()));
    }
}
