//! Serving metrics registry: counters + latency reservoirs, rendered as a
//! human-readable report (and consumed by the Table 4 bench harness).

use crate::util::stats;

/// Aggregated serving metrics.
#[derive(Default, Clone, Debug)]
pub struct Metrics {
    pub requests_in: u64,
    pub requests_done: u64,
    pub tokens_generated: u64,
    pub decode_rounds: u64,
    /// Per-request end-to-end latencies (s).
    pub latencies: Vec<f64>,
    /// Per-request time-to-first-token (s).
    pub ttfts: Vec<f64>,
    /// Wall-clock of the serve loop (s).
    pub wall_seconds: f64,
}

impl Metrics {
    pub fn throughput_tps(&self) -> f64 {
        if self.wall_seconds <= 0.0 {
            return 0.0;
        }
        self.tokens_generated as f64 / self.wall_seconds
    }

    pub fn latency_p50(&self) -> f64 {
        stats::percentile(&self.latencies, 50.0)
    }

    pub fn latency_p99(&self) -> f64 {
        stats::percentile(&self.latencies, 99.0)
    }

    pub fn ttft_p50(&self) -> f64 {
        stats::percentile(&self.ttfts, 50.0)
    }

    pub fn report(&self) -> String {
        format!(
            "requests: {}/{} done | tokens: {} | rounds: {} | wall: {:.2}s\n\
             throughput: {:.1} tok/s | latency p50/p99: {:.3}/{:.3}s | ttft p50: {:.3}s",
            self.requests_done,
            self.requests_in,
            self.tokens_generated,
            self.decode_rounds,
            self.wall_seconds,
            self.throughput_tps(),
            self.latency_p50(),
            self.latency_p99(),
            self.ttft_p50(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_math() {
        let m = Metrics { tokens_generated: 100, wall_seconds: 4.0, ..Default::default() };
        assert_eq!(m.throughput_tps(), 25.0);
    }

    #[test]
    fn zero_wall_is_zero_throughput() {
        let m = Metrics::default();
        assert_eq!(m.throughput_tps(), 0.0);
    }

    #[test]
    fn report_contains_counters() {
        let m = Metrics { requests_in: 5, requests_done: 5, tokens_generated: 42, ..Default::default() };
        let r = m.report();
        assert!(r.contains("5/5"));
        assert!(r.contains("42"));
    }
}
