//! Serving metrics registry: counters, bounded log-linear latency
//! histograms, per-phase/per-kernel time attribution, and export — the
//! human report, a machine-readable [`Metrics::snapshot`] JSON tree, and
//! a Prometheus text exposition ([`Metrics::render_prometheus`]).

use super::Priority;
use crate::obs::hist::LogHistogram;
use crate::obs::json::Json;
use crate::obs::ring::FlightRecorder;

/// Seconds the serve loop spent in each coordinator phase (disjoint
/// spans on the coordinator thread → the sum is ≤ `wall_seconds`;
/// idle sleeps between arrivals are deliberately unattributed).
#[derive(Clone, Copy, Debug, Default)]
pub struct PhaseSeconds {
    /// Arrival intake + page-counted admission.
    pub admission: f64,
    /// Radix prefix-index lookups and page leasing.
    pub prefix_lookup: f64,
    /// Ragged prefill micro-steps (≥ 1 prompt token fed).
    pub prefill: f64,
    /// Pure decode micro-steps.
    pub decode: f64,
}

impl PhaseSeconds {
    pub fn total(&self) -> f64 {
        self.admission + self.prefix_lookup + self.prefill + self.decode
    }
}

/// One dispatched kernel's CPU-seconds over a serve run, keyed kernel ×
/// ISA × data plane (the kv-dtype for attention kernels, "weights" for
/// the LUT-GEMM walks). GEMM walks run on the worker pool, so their
/// CPU-seconds sum across workers and may exceed wall time — same
/// contract as `kv_dequant_seconds`. Empty unless the process traced at
/// `--trace kernels`.
#[derive(Clone, Debug)]
pub struct KernelStat {
    /// `obs::Kernel::name()` (e.g. "qk_dot_i8", "gemm_pack34").
    pub kernel: &'static str,
    /// `obs::Kernel::plane()` ("int8" | "ternary" | "f32" | "weights").
    pub plane: &'static str,
    /// ISA the process dispatched through.
    pub isa: String,
    /// CPU-seconds inside the kernel across all threads.
    pub cpu_seconds: f64,
    /// Invocations (page blocks / GEMM tile ranges, not rows).
    pub calls: u64,
}

/// Aggregated serving metrics.
#[derive(Default, Clone, Debug)]
pub struct Metrics {
    pub requests_in: u64,
    pub requests_done: u64,
    pub tokens_generated: u64,
    pub decode_rounds: u64,
    /// Per-request end-to-end latency (bounded log-linear histogram —
    /// fixed memory however many requests the run serves).
    pub latency_hist: LogHistogram,
    /// Per-request time-to-first-token. Requests that finished without
    /// emitting any token (e.g. oversized prompts) are **excluded** and
    /// counted in [`Metrics::zero_token_finishes`] instead — recording
    /// their full latency here would fabricate a first token.
    pub ttft_hist: LogHistogram,
    /// Inter-token latency: gap between consecutive token emissions of
    /// one sequence (first tokens seed the clock, second+ record).
    pub itl_hist: LogHistogram,
    /// Decode-round wall duration.
    pub round_hist: LogHistogram,
    /// Requests retired with zero generated tokens (no TTFT exists).
    pub zero_token_finishes: u64,
    /// Wall-clock of the serve loop (s).
    pub wall_seconds: f64,
    /// Per-phase breakdown of the coordinator loop (all zero when the
    /// run traced at `--trace off`).
    pub phases: PhaseSeconds,
    /// Per-kernel CPU-seconds (empty below `--trace kernels`).
    pub kernels: Vec<KernelStat>,
    /// Trace level the run was configured with ("off"|"phases"|"kernels").
    pub trace_level: String,
    /// Last [`crate::obs::ring::FLIGHT_RING_CAP`] decode rounds' vitals.
    pub flight: FlightRecorder,

    // --- paged KV cache gauges ---
    /// Pages in the arena.
    pub kv_pages_total: u64,
    /// High-water mark of pages in use.
    pub kv_pages_peak: u64,
    /// Pages frozen in the prefix index at end of run.
    pub kv_pages_index: u64,
    /// Pages still in use at end of run (must equal `kv_pages_index`:
    /// every sequence reference was returned).
    pub kv_pages_end_in_use: u64,
    /// KV arena bytes (the byte budget the sweep holds fixed).
    pub kv_bytes: u64,
    /// Bytes one stored KV position costs at the pool's storage dtype
    /// (scales amortized) — the kv-bytes-per-token gauge; int8 pools
    /// must report at most half the f32 figure.
    pub kv_bytes_per_token: u64,
    /// K-plane share of `kv_bytes_per_token`. Symmetric dtypes (f32,
    /// int8) split evenly; ternary pools store K at 1.25 bits/element
    /// and V at int8, so the breakdown is how the report shows where the
    /// bytes went.
    pub kv_bytes_per_token_k: u64,
    /// V-plane share of `kv_bytes_per_token`.
    pub kv_bytes_per_token_v: u64,
    /// KV storage dtype of the run's arena ("f32"|"int8"|"ternary";
    /// empty when never recorded) — keys the kernel breakdown.
    pub kv_dtype: String,
    /// CPU-seconds the page store spent dequantizing blocks into f32,
    /// summed across all worker threads — **residual** dequantization
    /// outside the decode hot path. With the integer a·V pass on (the
    /// default), a quantized pool's decode round reads K and V pages as
    /// raw bytes and this stays 0; it only grows for f32 consumers
    /// (integer-V disabled, diagnostics, tile-cache fills). Because
    /// workers dequantize concurrently, this can exceed `wall_seconds`.
    pub kv_dequant_seconds: f64,
    /// Attention q·k rows computed int8-natively (i32 dot over raw page
    /// bytes, one scale multiply per page-head) — numerator of
    /// [`Metrics::int8_dot_fraction`].
    pub kv_qk_rows_int8: u64,
    /// Attention q·k rows computed from f32 tiles (borrowed f32 pages or
    /// dequantized quantized pages) — the fractions' shared denominator
    /// leg.
    pub kv_qk_rows_f32: u64,
    /// Attention q·k rows computed by the 1.25-bit LUT walk over packed
    /// ternary K pages (no dequantization) — numerator of
    /// [`Metrics::ternary_dot_fraction`].
    pub kv_qk_rows_ternary: u64,
    /// Attention a·V rows accumulated in integer fixed point (u8 softmax
    /// weight codes × raw int8 V page bytes, i32 accumulate, one
    /// `s_a·s_v` fold per page-head) — ~all V rows for quantized pools
    /// with the integer a·V pass on, 0 for f32 pools or with it off.
    pub kv_av_rows_int8: u64,
    /// Frozen-tile cache hits: V-pass reads of a shared prefix page
    /// served from the store's LRU instead of re-dequantizing.
    pub kv_tile_hits: u64,
    /// Frozen-tile cache misses (tile built and inserted).
    pub kv_tile_misses: u64,
    /// Prefix-index flushes forced by admission pressure.
    pub prefix_flushes: u64,
    /// Kernel ISA the run dispatched through (`simd::active().name()`:
    /// "scalar" | "avx2" | "neon"; empty when never recorded) — lets
    /// benches and reports attribute numbers to the vector path that ran.
    pub kernel_isa: String,

    // --- scheduling (SLO) gauges ---
    /// Per-class time-to-first-token, indexed by [`Priority::index`].
    /// Same exclusion rule as [`Metrics::ttft_hist`].
    pub ttft_class: [LogHistogram; Priority::COUNT],
    /// Per-class inter-token latency, indexed by [`Priority::index`].
    /// Preemption gaps land in the victim's class — the per-class view
    /// is how the report shows who paid for an SLO.
    pub itl_class: [LogHistogram; Priority::COUNT],
    /// Active sequences preempted: pages released, decode state parked,
    /// request re-queued at its class front for a later restore.
    pub preemptions: u64,
    /// Tokens re-fed during restores (prompt re-prefill beyond the
    /// shared-prefix span + no-emit replay of generated tokens) — the
    /// compute cost preemption traded for pages.
    pub restored_tokens: u64,
    /// Prefill chunks fed: one per (sequence, round) that consumed
    /// prompt or replay tokens. A monolithic prefill is one chunk.
    pub prefill_chunks: u64,
    /// Batch→Interactive promotions by the batcher's aging bound.
    pub aged_promotions: u64,
    /// Completions that finished after their request's deadline.
    pub deadline_misses: u64,
    /// Preemption policy the run was configured with
    /// ("never"|"pressure"|"always"; empty when unrecorded).
    pub preemption_policy: String,
    /// Configured prefill chunk size in tokens (0 = monolithic).
    pub prefill_chunk_tokens: u64,

    // --- prefix sharing / concurrency gauges ---
    /// Prompt tokens across admitted requests.
    pub prompt_tokens: u64,
    /// Prompt tokens whose prefill was skipped via a shared prefix.
    pub prefix_hit_tokens: u64,
    /// Requests that reused a nonzero shared prefix.
    pub prefix_hits: u64,
    /// Most sequences concurrently active in any decode round.
    pub peak_active: u64,
    /// Requests finished by hitting the context limit (vs. max tokens).
    pub context_limit_finishes: u64,
}

impl Metrics {
    pub fn throughput_tps(&self) -> f64 {
        if self.wall_seconds <= 0.0 {
            return 0.0;
        }
        self.tokens_generated as f64 / self.wall_seconds
    }

    pub fn latency_p50(&self) -> f64 {
        self.latency_hist.p50()
    }

    pub fn latency_p99(&self) -> f64 {
        self.latency_hist.p99()
    }

    pub fn ttft_p50(&self) -> f64 {
        self.ttft_hist.p50()
    }

    pub fn ttft_p99(&self) -> f64 {
        self.ttft_hist.p99()
    }

    /// Inter-token latency p50 (0 until any sequence emits twice).
    pub fn itl_p50(&self) -> f64 {
        self.itl_hist.p50()
    }

    /// Inter-token latency p99.
    pub fn itl_p99(&self) -> f64 {
        self.itl_hist.p99()
    }

    /// Peak fraction of the KV arena in use (0 when unpaged/untracked).
    pub fn block_utilization(&self) -> f64 {
        if self.kv_pages_total == 0 {
            return 0.0;
        }
        self.kv_pages_peak as f64 / self.kv_pages_total as f64
    }

    /// Fraction of prompt tokens served from shared prefix pages.
    pub fn prefix_hit_rate(&self) -> f64 {
        if self.prompt_tokens == 0 {
            return 0.0;
        }
        self.prefix_hit_tokens as f64 / self.prompt_tokens as f64
    }

    /// Dequantization CPU-seconds per wall second (0 for f32). Summed
    /// across concurrent workers, so values above 1 mean more than one
    /// core's worth of dequantization on average.
    pub fn dequant_overhead(&self) -> f64 {
        if self.wall_seconds <= 0.0 {
            return 0.0;
        }
        self.kv_dequant_seconds / self.wall_seconds
    }

    /// Fraction of attention q·k rows computed as int8-native i32 dots:
    /// ~1 for int8 pools, 0 for f32/ternary pools, 0 when nothing was
    /// recorded.
    pub fn int8_dot_fraction(&self) -> f64 {
        let total = self.kv_qk_rows_int8 + self.kv_qk_rows_f32 + self.kv_qk_rows_ternary;
        if total == 0 {
            return 0.0;
        }
        self.kv_qk_rows_int8 as f64 / total as f64
    }

    /// Fraction of attention q·k rows computed by the 1.25-bit ternary
    /// LUT walk: ~1 for ternary pools, 0 elsewhere / when unrecorded.
    pub fn ternary_dot_fraction(&self) -> f64 {
        let total = self.kv_qk_rows_int8 + self.kv_qk_rows_f32 + self.kv_qk_rows_ternary;
        if total == 0 {
            return 0.0;
        }
        self.kv_qk_rows_ternary as f64 / total as f64
    }

    /// Hit rate of the frozen-tile LRU (0 when the cache never ran —
    /// f32 pools, sharing off, or capacity 0).
    pub fn tile_cache_hit_rate(&self) -> f64 {
        let total = self.kv_tile_hits + self.kv_tile_misses;
        if total == 0 {
            return 0.0;
        }
        self.kv_tile_hits as f64 / total as f64
    }

    pub fn report(&self) -> String {
        let mut s = format!(
            "requests: {}/{} done | tokens: {} | rounds: {} | wall: {:.2}s\n\
             throughput: {:.1} tok/s | latency p50/p99: {:.3}/{:.3}s | ttft p50: {:.3}s\n\
             itl p50/p99: {:.4}/{:.4}s | round p50/p99: {:.4}/{:.4}s | zero-token finishes: {}\n\
             phases: admission {:.3}s | prefix {:.3}s | prefill {:.3}s | decode {:.3}s \
             (sum {:.3}s, trace: {})\n\
             kv: {}/{} pages peak ({:.0}% util) | {} B/token (K {} + V {}) | dequant: {:.3} cpu-s\n\
             int8 q·k: {:.0}% | ternary q·k: {:.0}% of dot rows | int8 a·V rows: {} | tile cache: {:.0}% hits ({}/{}) | kernel isa: {}\n\
             prefix hit-rate: {:.0}% ({} hits) | \
             peak active: {} | context-limit finishes: {}",
            self.requests_done,
            self.requests_in,
            self.tokens_generated,
            self.decode_rounds,
            self.wall_seconds,
            self.throughput_tps(),
            self.latency_p50(),
            self.latency_p99(),
            self.ttft_p50(),
            self.itl_p50(),
            self.itl_p99(),
            self.round_hist.p50(),
            self.round_hist.p99(),
            self.zero_token_finishes,
            self.phases.admission,
            self.phases.prefix_lookup,
            self.phases.prefill,
            self.phases.decode,
            self.phases.total(),
            if self.trace_level.is_empty() { "unrecorded" } else { &self.trace_level },
            self.kv_pages_peak,
            self.kv_pages_total,
            100.0 * self.block_utilization(),
            self.kv_bytes_per_token,
            self.kv_bytes_per_token_k,
            self.kv_bytes_per_token_v,
            self.kv_dequant_seconds,
            100.0 * self.int8_dot_fraction(),
            100.0 * self.ternary_dot_fraction(),
            self.kv_av_rows_int8,
            100.0 * self.tile_cache_hit_rate(),
            self.kv_tile_hits,
            self.kv_tile_hits + self.kv_tile_misses,
            if self.kernel_isa.is_empty() { "unrecorded" } else { &self.kernel_isa },
            100.0 * self.prefix_hit_rate(),
            self.prefix_hits,
            self.peak_active,
            self.context_limit_finishes,
        );
        s.push_str(&format!(
            "\nsched: chunk {} tok ({} chunks) | preemptions {} (restored {} tok) | \
             aged promotions {} | deadline misses {} | policy {}",
            self.prefill_chunk_tokens,
            self.prefill_chunks,
            self.preemptions,
            self.restored_tokens,
            self.aged_promotions,
            self.deadline_misses,
            if self.preemption_policy.is_empty() { "unrecorded" } else { &self.preemption_policy },
        ));
        for p in Priority::ALL {
            let (t, i) = (&self.ttft_class[p.index()], &self.itl_class[p.index()]);
            s.push_str(&format!(
                "\nclass {}: ttft p50/p99 {:.3}/{:.3}s over {} | itl p50/p99 {:.4}/{:.4}s over {}",
                p.name(),
                t.p50(),
                t.p99(),
                t.count(),
                i.p50(),
                i.p99(),
                i.count(),
            ));
        }
        for k in &self.kernels {
            s.push_str(&format!(
                "\nkernel {}[{}/{}]: {:.4} cpu-s over {} calls",
                k.kernel, k.isa, k.plane, k.cpu_seconds, k.calls
            ));
        }
        s
    }

    fn hist_json(h: &LogHistogram) -> Json {
        Json::obj()
            .field("count", h.count())
            .field("mean_s", h.mean_secs())
            .field("min_s", h.min_secs())
            .field("p50_s", h.p50())
            .field("p90_s", h.p90())
            .field("p99_s", h.p99())
            .field("p999_s", h.p999())
            .field("max_s", h.max_secs())
    }

    /// The full metrics tree as a serializable [`Json`] value — what
    /// `--metrics-json` writes and the bench JSON records embed. Keys
    /// are stable; the golden round-trip test pins the required set.
    pub fn snapshot(&self) -> Json {
        let phases = Json::obj()
            .field("admission_s", self.phases.admission)
            .field("prefix_lookup_s", self.phases.prefix_lookup)
            .field("prefill_s", self.phases.prefill)
            .field("decode_s", self.phases.decode)
            .field("total_s", self.phases.total());
        let kernels = Json::Arr(
            self.kernels
                .iter()
                .map(|k| {
                    Json::obj()
                        .field("kernel", k.kernel)
                        .field("plane", k.plane)
                        .field("isa", k.isa.clone())
                        .field("cpu_seconds", k.cpu_seconds)
                        .field("calls", k.calls)
                })
                .collect(),
        );
        let kv = Json::obj()
            .field("dtype", self.kv_dtype.clone())
            .field("pages_total", self.kv_pages_total)
            .field("pages_peak", self.kv_pages_peak)
            .field("pages_index", self.kv_pages_index)
            .field("pages_end_in_use", self.kv_pages_end_in_use)
            .field("bytes", self.kv_bytes)
            .field("bytes_per_token", self.kv_bytes_per_token)
            .field("bytes_per_token_k", self.kv_bytes_per_token_k)
            .field("bytes_per_token_v", self.kv_bytes_per_token_v)
            .field("dequant_seconds", self.kv_dequant_seconds)
            .field("dequant_overhead", self.dequant_overhead())
            .field("qk_rows_int8", self.kv_qk_rows_int8)
            .field("qk_rows_f32", self.kv_qk_rows_f32)
            .field("qk_rows_ternary", self.kv_qk_rows_ternary)
            .field("int8_dot_fraction", self.int8_dot_fraction())
            .field("ternary_dot_fraction", self.ternary_dot_fraction())
            .field("av_rows_int8", self.kv_av_rows_int8)
            .field("tile_hits", self.kv_tile_hits)
            .field("tile_misses", self.kv_tile_misses)
            .field("tile_cache_hit_rate", self.tile_cache_hit_rate())
            .field("block_utilization", self.block_utilization());
        let prefix = Json::obj()
            .field("prompt_tokens", self.prompt_tokens)
            .field("hit_tokens", self.prefix_hit_tokens)
            .field("hits", self.prefix_hits)
            .field("hit_rate", self.prefix_hit_rate())
            .field("flushes", self.prefix_flushes);
        let flight = Json::Arr(
            self.flight
                .records()
                .into_iter()
                .map(|r| {
                    Json::obj()
                        .field("round", r.round)
                        .field("active", r.active)
                        .field("pages_in_use", r.pages_in_use)
                        .field("tokens", r.tokens)
                        .field("prefill_tokens", r.prefill_tokens)
                        .field("duration_s", r.duration_s)
                })
                .collect(),
        );
        let classes = Json::Arr(
            Priority::ALL
                .iter()
                .map(|&p| {
                    Json::obj()
                        .field("class", p.name())
                        .field("ttft", Self::hist_json(&self.ttft_class[p.index()]))
                        .field("inter_token", Self::hist_json(&self.itl_class[p.index()]))
                })
                .collect(),
        );
        let sched = Json::obj()
            .field("prefill_chunk_tokens", self.prefill_chunk_tokens)
            .field("prefill_chunks", self.prefill_chunks)
            .field("preemption_policy", self.preemption_policy.clone())
            .field("preemptions", self.preemptions)
            .field("restored_tokens", self.restored_tokens)
            .field("aged_promotions", self.aged_promotions)
            .field("deadline_misses", self.deadline_misses)
            .field("classes", classes);
        Json::obj()
            .field("schema_version", 1u64)
            .field("requests_in", self.requests_in)
            .field("requests_done", self.requests_done)
            .field("tokens_generated", self.tokens_generated)
            .field("decode_rounds", self.decode_rounds)
            .field("wall_seconds", self.wall_seconds)
            .field("throughput_tps", self.throughput_tps())
            .field("kernel_isa", self.kernel_isa.clone())
            .field("trace_level", self.trace_level.clone())
            .field("zero_token_finishes", self.zero_token_finishes)
            .field("peak_active", self.peak_active)
            .field("context_limit_finishes", self.context_limit_finishes)
            .field("latency", Self::hist_json(&self.latency_hist))
            .field("ttft", Self::hist_json(&self.ttft_hist))
            .field("inter_token", Self::hist_json(&self.itl_hist))
            .field("decode_round", Self::hist_json(&self.round_hist))
            .field("phases", phases)
            .field("kernels", kernels)
            .field("kv", kv)
            .field("prefix", prefix)
            .field("sched", sched)
            .field("flight", flight)
    }

    /// Prometheus text exposition (0.0.4) of the snapshot's scalar
    /// surface: counters, gauges, histogram quantiles as labeled gauges,
    /// per-phase seconds, and per-kernel CPU-seconds. Quantiles are
    /// pre-computed (this is an end-of-run exposition, not a live
    /// scrape target), which keeps the writer dependency-free.
    pub fn render_prometheus(&self) -> String {
        let mut s = String::new();
        let mut counter = |out: &mut String, name: &str, help: &str, v: f64| {
            out.push_str(&format!(
                "# HELP sherry_{name} {help}\n# TYPE sherry_{name} counter\nsherry_{name} {v}\n"
            ));
        };
        let mut gauge = |out: &mut String, name: &str, help: &str, v: f64| {
            out.push_str(&format!(
                "# HELP sherry_{name} {help}\n# TYPE sherry_{name} gauge\nsherry_{name} {v}\n"
            ));
        };
        counter(&mut s, "requests_total", "Requests submitted", self.requests_in as f64);
        counter(&mut s, "requests_done_total", "Requests completed", self.requests_done as f64);
        counter(&mut s, "tokens_generated_total", "Generated tokens", self.tokens_generated as f64);
        counter(&mut s, "decode_rounds_total", "Fused decode rounds", self.decode_rounds as f64);
        counter(
            &mut s,
            "zero_token_finishes_total",
            "Requests retired without emitting a token",
            self.zero_token_finishes as f64,
        );
        gauge(&mut s, "wall_seconds", "Serve-loop wall clock", self.wall_seconds);
        gauge(&mut s, "throughput_tps", "Generated tokens per second", self.throughput_tps());
        gauge(&mut s, "kv_pages_peak", "High-water KV pages in use", self.kv_pages_peak as f64);
        gauge(&mut s, "kv_pages_total", "KV pages in the arena", self.kv_pages_total as f64);
        gauge(
            &mut s,
            "kv_dequant_cpu_seconds",
            "Residual dequantization CPU-seconds",
            self.kv_dequant_seconds,
        );
        gauge(&mut s, "peak_active", "Peak concurrent sequences", self.peak_active as f64);
        counter(
            &mut s,
            "preemptions_total",
            "Sequences preempted to free KV pages",
            self.preemptions as f64,
        );
        counter(
            &mut s,
            "restored_tokens_total",
            "Tokens re-fed while restoring preempted sequences",
            self.restored_tokens as f64,
        );
        counter(&mut s, "prefill_chunks_total", "Prefill chunks fed", self.prefill_chunks as f64);
        counter(
            &mut s,
            "aged_promotions_total",
            "Batch requests promoted to the interactive queue by aging",
            self.aged_promotions as f64,
        );
        counter(
            &mut s,
            "deadline_misses_total",
            "Completions that finished past their deadline",
            self.deadline_misses as f64,
        );
        for (name, help, h) in [
            ("latency_seconds", "End-to-end request latency", &self.latency_hist),
            ("ttft_seconds", "Time to first token", &self.ttft_hist),
            ("inter_token_seconds", "Inter-token latency", &self.itl_hist),
            ("decode_round_seconds", "Decode round duration", &self.round_hist),
        ] {
            s.push_str(&format!(
                "# HELP sherry_{name} {help} (log-linear histogram summary)\n\
                 # TYPE sherry_{name} summary\n"
            ));
            for (q, v) in [
                ("0.5", h.p50()),
                ("0.9", h.p90()),
                ("0.99", h.p99()),
                ("0.999", h.p999()),
            ] {
                s.push_str(&format!("sherry_{name}{{quantile=\"{q}\"}} {v}\n"));
            }
            s.push_str(&format!("sherry_{name}_count {}\n", h.count()));
            s.push_str(&format!("sherry_{name}_sum {}\n", h.mean_secs() * h.count() as f64));
        }
        for (name, help, hists) in [
            ("class_ttft_seconds", "Time to first token per priority class", &self.ttft_class),
            ("class_inter_token_seconds", "Inter-token latency per priority class", &self.itl_class),
        ] {
            s.push_str(&format!(
                "# HELP sherry_{name} {help} (log-linear histogram summary)\n\
                 # TYPE sherry_{name} summary\n"
            ));
            for p in Priority::ALL {
                let h = &hists[p.index()];
                for (q, v) in [("0.5", h.p50()), ("0.99", h.p99())] {
                    s.push_str(&format!(
                        "sherry_{name}{{class=\"{}\",quantile=\"{q}\"}} {v}\n",
                        p.name()
                    ));
                }
                s.push_str(&format!(
                    "sherry_{name}_count{{class=\"{}\"}} {}\n",
                    p.name(),
                    h.count()
                ));
            }
        }
        s.push_str(
            "# HELP sherry_phase_seconds Coordinator time per phase\n\
             # TYPE sherry_phase_seconds gauge\n",
        );
        for (phase, v) in [
            ("admission", self.phases.admission),
            ("prefix_lookup", self.phases.prefix_lookup),
            ("prefill", self.phases.prefill),
            ("decode", self.phases.decode),
        ] {
            s.push_str(&format!("sherry_phase_seconds{{phase=\"{phase}\"}} {v}\n"));
        }
        if !self.kernels.is_empty() {
            s.push_str(
                "# HELP sherry_kernel_cpu_seconds CPU-seconds per dispatched kernel\n\
                 # TYPE sherry_kernel_cpu_seconds gauge\n",
            );
            for k in &self.kernels {
                s.push_str(&format!(
                    "sherry_kernel_cpu_seconds{{kernel=\"{}\",isa=\"{}\",plane=\"{}\"}} {}\n",
                    k.kernel, k.isa, k.plane, k.cpu_seconds
                ));
                s.push_str(&format!(
                    "sherry_kernel_calls{{kernel=\"{}\",isa=\"{}\",plane=\"{}\"}} {}\n",
                    k.kernel, k.isa, k.plane, k.calls
                ));
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_math() {
        let m = Metrics { tokens_generated: 100, wall_seconds: 4.0, ..Default::default() };
        assert_eq!(m.throughput_tps(), 25.0);
    }

    #[test]
    fn zero_wall_is_zero_throughput() {
        let m = Metrics::default();
        assert_eq!(m.throughput_tps(), 0.0);
    }

    #[test]
    fn report_contains_counters() {
        let m = Metrics { requests_in: 5, requests_done: 5, tokens_generated: 42, ..Default::default() };
        let r = m.report();
        assert!(r.contains("5/5"));
        assert!(r.contains("42"));
    }

    #[test]
    fn kv_gauge_math() {
        let m = Metrics {
            kv_pages_total: 32,
            kv_pages_peak: 8,
            prompt_tokens: 100,
            prefix_hit_tokens: 25,
            ..Default::default()
        };
        assert_eq!(m.block_utilization(), 0.25);
        assert_eq!(m.prefix_hit_rate(), 0.25);
        // Zero denominators stay finite.
        let z = Metrics::default();
        assert_eq!(z.block_utilization(), 0.0);
        assert_eq!(z.prefix_hit_rate(), 0.0);
        assert_eq!(z.dequant_overhead(), 0.0);
        assert_eq!(z.int8_dot_fraction(), 0.0);
        assert_eq!(z.ternary_dot_fraction(), 0.0);
        assert_eq!(z.tile_cache_hit_rate(), 0.0);
    }

    #[test]
    fn int8_attention_gauge_math_and_report() {
        let m = Metrics {
            kv_qk_rows_int8: 300,
            kv_qk_rows_f32: 100,
            kv_av_rows_int8: 400,
            kv_tile_hits: 30,
            kv_tile_misses: 10,
            ..Default::default()
        };
        assert_eq!(m.int8_dot_fraction(), 0.75);
        assert_eq!(m.ternary_dot_fraction(), 0.0);
        assert_eq!(m.tile_cache_hit_rate(), 0.75);
        let r = m.report();
        assert!(r.contains("int8 q·k: 75% | ternary q·k: 0% of dot rows"), "{r}");
        assert!(r.contains("int8 a·V rows: 400"), "{r}");
        assert!(r.contains("tile cache: 75% hits (30/40)"), "{r}");
    }

    #[test]
    fn ternary_attention_gauge_math_and_report() {
        // A ternary pool's score pass is all LUT rows except the f32
        // leg contributed by contiguous prefill caches.
        let m = Metrics {
            kv_qk_rows_int8: 0,
            kv_qk_rows_f32: 100,
            kv_qk_rows_ternary: 300,
            ..Default::default()
        };
        assert_eq!(m.ternary_dot_fraction(), 0.75);
        assert_eq!(m.int8_dot_fraction(), 0.0);
        let r = m.report();
        assert!(r.contains("int8 q·k: 0% | ternary q·k: 75% of dot rows"), "{r}");
        // All three classes share one denominator.
        let mixed = Metrics {
            kv_qk_rows_int8: 100,
            kv_qk_rows_f32: 100,
            kv_qk_rows_ternary: 200,
            ..Default::default()
        };
        assert_eq!(mixed.int8_dot_fraction(), 0.25);
        assert_eq!(mixed.ternary_dot_fraction(), 0.5);
    }

    #[test]
    fn kernel_isa_surfaces_in_report() {
        let m = Metrics { kernel_isa: "avx2".to_string(), ..Default::default() };
        assert!(m.report().contains("kernel isa: avx2"), "{}", m.report());
        let unset = Metrics::default();
        assert!(unset.report().contains("kernel isa: unrecorded"), "{}", unset.report());
        // The serving loop records whatever the process pinned.
        let live = Metrics {
            kernel_isa: crate::simd::active().name().to_string(),
            ..Default::default()
        };
        assert!(live.report().contains("kernel isa: "), "{}", live.report());
    }

    #[test]
    fn dequant_overhead_math_and_report_gauges() {
        let m = Metrics {
            wall_seconds: 2.0,
            kv_dequant_seconds: 0.5,
            kv_bytes_per_token: 516,
            kv_bytes_per_token_k: 258,
            kv_bytes_per_token_v: 258,
            ..Default::default()
        };
        assert_eq!(m.dequant_overhead(), 0.25);
        let r = m.report();
        assert!(r.contains("516 B/token (K 258 + V 258)"), "{r}");
        assert!(r.contains("dequant: 0.500 cpu-s"), "{r}");
        // Summed across workers: more dequant CPU than wall is legal.
        let busy = Metrics { wall_seconds: 1.0, kv_dequant_seconds: 3.0, ..Default::default() };
        assert_eq!(busy.dequant_overhead(), 3.0);
    }

    fn sample_metrics() -> Metrics {
        let mut m = Metrics {
            requests_in: 4,
            requests_done: 4,
            tokens_generated: 40,
            decode_rounds: 10,
            wall_seconds: 0.5,
            trace_level: "phases".to_string(),
            kernel_isa: "scalar".to_string(),
            kv_dtype: "int8".to_string(),
            zero_token_finishes: 1,
            phases: PhaseSeconds {
                admission: 0.01,
                prefix_lookup: 0.002,
                prefill: 0.08,
                decode: 0.3,
            },
            kernels: vec![KernelStat {
                kernel: "qk_dot_i8",
                plane: "int8",
                isa: "scalar".to_string(),
                cpu_seconds: 0.123,
                calls: 77,
            }],
            preemptions: 2,
            restored_tokens: 12,
            prefill_chunks: 6,
            aged_promotions: 1,
            deadline_misses: 1,
            preemption_policy: "pressure".to_string(),
            prefill_chunk_tokens: 16,
            ..Default::default()
        };
        for x in [0.01, 0.02, 0.03, 0.5] {
            m.latency_hist.record_secs(x);
            m.ttft_hist.record_secs(x / 2.0);
            m.ttft_class[0].record_secs(x / 2.0);
            m.itl_class[0].record_secs(x / 4.0);
            m.ttft_class[1].record_secs(x * 2.0);
            m.itl_class[1].record_secs(x);
        }
        for _ in 0..36 {
            m.itl_hist.record_secs(0.01);
        }
        for _ in 0..10 {
            m.round_hist.record_secs(0.04);
        }
        m.flight.push(crate::obs::ring::RoundRecord {
            round: 9,
            active: 4,
            pages_in_use: 7,
            tokens: 4,
            prefill_tokens: 2,
            duration_s: 0.04,
        });
        m
    }

    #[test]
    fn report_surfaces_phase_itl_and_kernel_lines() {
        let r = sample_metrics().report();
        assert!(r.contains("itl p50/p99: 0.0100/0.0100s"), "{r}");
        assert!(r.contains("round p50/p99: 0.0400/0.0400s"), "{r}");
        assert!(r.contains("zero-token finishes: 1"), "{r}");
        assert!(
            r.contains("phases: admission 0.010s | prefix 0.002s | prefill 0.080s | decode 0.300s"),
            "{r}"
        );
        assert!(r.contains("(sum 0.392s, trace: phases)"), "{r}");
        assert!(r.contains("kernel qk_dot_i8[scalar/int8]: 0.1230 cpu-s over 77 calls"), "{r}");
        assert!(
            r.contains("sched: chunk 16 tok (6 chunks) | preemptions 2 (restored 12 tok)"),
            "{r}"
        );
        assert!(r.contains("policy pressure"), "{r}");
        assert!(r.contains("class interactive: ttft p50/p99"), "{r}");
        assert!(r.contains("class batch: ttft p50/p99"), "{r}");
        // Default metrics keep the report well-formed with no kernels.
        let bare = Metrics::default().report();
        assert!(bare.contains("trace: unrecorded"), "{bare}");
        assert!(bare.contains("policy unrecorded"), "{bare}");
        assert!(!bare.contains("kernel qk"), "{bare}");
    }

    #[test]
    fn snapshot_round_trips_with_all_required_keys() {
        // The golden test: snapshot → render → parse must preserve every
        // required key, and the values must match the source metrics.
        let m = sample_metrics();
        let snap = m.snapshot();
        for text in [snap.render(), snap.render_pretty()] {
            let back = Json::parse(&text).expect("snapshot must parse back");
            assert_eq!(back, snap, "round-trip must be lossless");
        }
        for key in [
            "schema_version",
            "requests_in",
            "requests_done",
            "tokens_generated",
            "decode_rounds",
            "wall_seconds",
            "throughput_tps",
            "kernel_isa",
            "trace_level",
            "zero_token_finishes",
            "peak_active",
            "context_limit_finishes",
            "latency",
            "ttft",
            "inter_token",
            "decode_round",
            "phases",
            "kernels",
            "kv",
            "prefix",
            "sched",
            "flight",
        ] {
            assert!(snap.get(key).is_some(), "snapshot missing key {key}");
        }
        assert_eq!(snap.get("wall_seconds").unwrap().as_f64(), Some(0.5));
        assert_eq!(snap.get("trace_level").unwrap().as_str(), Some("phases"));
        let hist = snap.get("latency").unwrap();
        for key in ["count", "mean_s", "min_s", "p50_s", "p90_s", "p99_s", "p999_s", "max_s"] {
            assert!(hist.get(key).is_some(), "histogram summary missing {key}");
        }
        assert_eq!(hist.get("count").unwrap().as_f64(), Some(4.0));
        let phases = snap.get("phases").unwrap();
        let sum: f64 = ["admission_s", "prefix_lookup_s", "prefill_s", "decode_s"]
            .iter()
            .map(|k| phases.get(k).unwrap().as_f64().unwrap())
            .sum();
        assert!(sum >= 0.0);
        assert!(sum <= m.wall_seconds, "phase seconds must sum to <= wall");
        let kernels = snap.get("kernels").unwrap().as_arr().unwrap();
        assert_eq!(kernels[0].get("kernel").unwrap().as_str(), Some("qk_dot_i8"));
        assert_eq!(kernels[0].get("plane").unwrap().as_str(), Some("int8"));
        let kv = snap.get("kv").unwrap();
        assert_eq!(kv.get("dtype").unwrap().as_str(), Some("int8"));
        let flight = snap.get("flight").unwrap().as_arr().unwrap();
        assert_eq!(flight[0].get("round").unwrap().as_f64(), Some(9.0));
        assert_eq!(flight[0].get("prefill_tokens").unwrap().as_f64(), Some(2.0));
        let sched = snap.get("sched").unwrap();
        assert_eq!(sched.get("preemptions").unwrap().as_f64(), Some(2.0));
        assert_eq!(sched.get("restored_tokens").unwrap().as_f64(), Some(12.0));
        assert_eq!(sched.get("prefill_chunk_tokens").unwrap().as_f64(), Some(16.0));
        assert_eq!(sched.get("preemption_policy").unwrap().as_str(), Some("pressure"));
        let classes = sched.get("classes").unwrap().as_arr().unwrap();
        assert_eq!(classes.len(), 2);
        assert_eq!(classes[0].get("class").unwrap().as_str(), Some("interactive"));
        assert_eq!(classes[1].get("class").unwrap().as_str(), Some("batch"));
        for c in classes {
            for key in ["ttft", "inter_token"] {
                let h = c.get(key).unwrap();
                assert_eq!(h.get("count").unwrap().as_f64(), Some(4.0));
            }
        }
    }

    #[test]
    fn prometheus_exposition_has_the_core_families() {
        let text = sample_metrics().render_prometheus();
        for needle in [
            "# TYPE sherry_requests_total counter",
            "sherry_tokens_generated_total 40",
            "# TYPE sherry_latency_seconds summary",
            "sherry_inter_token_seconds{quantile=\"0.99\"}",
            "sherry_phase_seconds{phase=\"decode\"} 0.3",
            "sherry_kernel_cpu_seconds{kernel=\"qk_dot_i8\",isa=\"scalar\",plane=\"int8\"} 0.123",
            "sherry_zero_token_finishes_total 1",
            "sherry_preemptions_total 2",
            "sherry_restored_tokens_total 12",
            "sherry_prefill_chunks_total 6",
            "sherry_deadline_misses_total 1",
            "sherry_class_ttft_seconds{class=\"interactive\",quantile=\"0.5\"}",
            "sherry_class_inter_token_seconds_count{class=\"batch\"} 4",
        ] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
    }

    #[test]
    fn histograms_replace_reservoirs_with_fixed_memory() {
        // The tentpole bound: a million recorded latencies must not grow
        // per-request storage (the old Vec<f64> reservoirs did).
        let mut m = Metrics::default();
        for i in 0..100_000u64 {
            m.latency_hist.record(1_000_000 + i * 17);
        }
        assert_eq!(m.latency_hist.count(), 100_000);
        assert!(m.latency_p50() > 0.0);
        assert!(m.latency_p99() >= m.latency_p50());
    }
}
