//! Serving metrics registry: counters + latency reservoirs, rendered as a
//! human-readable report (and consumed by the Table 4 bench harness).

use crate::util::stats;

/// Aggregated serving metrics.
#[derive(Default, Clone, Debug)]
pub struct Metrics {
    pub requests_in: u64,
    pub requests_done: u64,
    pub tokens_generated: u64,
    pub decode_rounds: u64,
    /// Per-request end-to-end latencies (s).
    pub latencies: Vec<f64>,
    /// Per-request time-to-first-token (s).
    pub ttfts: Vec<f64>,
    /// Wall-clock of the serve loop (s).
    pub wall_seconds: f64,

    // --- paged KV cache gauges ---
    /// Pages in the arena.
    pub kv_pages_total: u64,
    /// High-water mark of pages in use.
    pub kv_pages_peak: u64,
    /// Pages frozen in the prefix index at end of run.
    pub kv_pages_index: u64,
    /// Pages still in use at end of run (must equal `kv_pages_index`:
    /// every sequence reference was returned).
    pub kv_pages_end_in_use: u64,
    /// KV arena bytes (the byte budget the sweep holds fixed).
    pub kv_bytes: u64,
    /// Bytes one stored KV position costs at the pool's storage dtype
    /// (scales amortized) — the kv-bytes-per-token gauge; int8 pools
    /// must report at most half the f32 figure.
    pub kv_bytes_per_token: u64,
    /// K-plane share of `kv_bytes_per_token`. Symmetric dtypes (f32,
    /// int8) split evenly; ternary pools store K at 1.25 bits/element
    /// and V at int8, so the breakdown is how the report shows where the
    /// bytes went.
    pub kv_bytes_per_token_k: u64,
    /// V-plane share of `kv_bytes_per_token`.
    pub kv_bytes_per_token_v: u64,
    /// CPU-seconds the page store spent dequantizing blocks into f32,
    /// summed across all worker threads — **residual** dequantization
    /// outside the decode hot path. With the integer a·V pass on (the
    /// default), a quantized pool's decode round reads K and V pages as
    /// raw bytes and this stays 0; it only grows for f32 consumers
    /// (integer-V disabled, diagnostics, tile-cache fills). Because
    /// workers dequantize concurrently, this can exceed `wall_seconds`.
    pub kv_dequant_seconds: f64,
    /// Attention q·k rows computed int8-natively (i32 dot over raw page
    /// bytes, one scale multiply per page-head) — numerator of
    /// [`Metrics::int8_dot_fraction`].
    pub kv_qk_rows_int8: u64,
    /// Attention q·k rows computed from f32 tiles (borrowed f32 pages or
    /// dequantized quantized pages) — the fractions' shared denominator
    /// leg.
    pub kv_qk_rows_f32: u64,
    /// Attention q·k rows computed by the 1.25-bit LUT walk over packed
    /// ternary K pages (no dequantization) — numerator of
    /// [`Metrics::ternary_dot_fraction`].
    pub kv_qk_rows_ternary: u64,
    /// Attention a·V rows accumulated in integer fixed point (u8 softmax
    /// weight codes × raw int8 V page bytes, i32 accumulate, one
    /// `s_a·s_v` fold per page-head) — ~all V rows for quantized pools
    /// with the integer a·V pass on, 0 for f32 pools or with it off.
    pub kv_av_rows_int8: u64,
    /// Frozen-tile cache hits: V-pass reads of a shared prefix page
    /// served from the store's LRU instead of re-dequantizing.
    pub kv_tile_hits: u64,
    /// Frozen-tile cache misses (tile built and inserted).
    pub kv_tile_misses: u64,
    /// Prefix-index flushes forced by admission pressure.
    pub prefix_flushes: u64,
    /// Kernel ISA the run dispatched through (`simd::active().name()`:
    /// "scalar" | "avx2" | "neon"; empty when never recorded) — lets
    /// benches and reports attribute numbers to the vector path that ran.
    pub kernel_isa: String,

    // --- prefix sharing / concurrency gauges ---
    /// Prompt tokens across admitted requests.
    pub prompt_tokens: u64,
    /// Prompt tokens whose prefill was skipped via a shared prefix.
    pub prefix_hit_tokens: u64,
    /// Requests that reused a nonzero shared prefix.
    pub prefix_hits: u64,
    /// Most sequences concurrently active in any decode round.
    pub peak_active: u64,
    /// Requests finished by hitting the context limit (vs. max tokens).
    pub context_limit_finishes: u64,
}

impl Metrics {
    pub fn throughput_tps(&self) -> f64 {
        if self.wall_seconds <= 0.0 {
            return 0.0;
        }
        self.tokens_generated as f64 / self.wall_seconds
    }

    pub fn latency_p50(&self) -> f64 {
        stats::percentile(&self.latencies, 50.0)
    }

    pub fn latency_p99(&self) -> f64 {
        stats::percentile(&self.latencies, 99.0)
    }

    pub fn ttft_p50(&self) -> f64 {
        stats::percentile(&self.ttfts, 50.0)
    }

    /// Peak fraction of the KV arena in use (0 when unpaged/untracked).
    pub fn block_utilization(&self) -> f64 {
        if self.kv_pages_total == 0 {
            return 0.0;
        }
        self.kv_pages_peak as f64 / self.kv_pages_total as f64
    }

    /// Fraction of prompt tokens served from shared prefix pages.
    pub fn prefix_hit_rate(&self) -> f64 {
        if self.prompt_tokens == 0 {
            return 0.0;
        }
        self.prefix_hit_tokens as f64 / self.prompt_tokens as f64
    }

    /// Dequantization CPU-seconds per wall second (0 for f32). Summed
    /// across concurrent workers, so values above 1 mean more than one
    /// core's worth of dequantization on average.
    pub fn dequant_overhead(&self) -> f64 {
        if self.wall_seconds <= 0.0 {
            return 0.0;
        }
        self.kv_dequant_seconds / self.wall_seconds
    }

    /// Fraction of attention q·k rows computed as int8-native i32 dots:
    /// ~1 for int8 pools, 0 for f32/ternary pools, 0 when nothing was
    /// recorded.
    pub fn int8_dot_fraction(&self) -> f64 {
        let total = self.kv_qk_rows_int8 + self.kv_qk_rows_f32 + self.kv_qk_rows_ternary;
        if total == 0 {
            return 0.0;
        }
        self.kv_qk_rows_int8 as f64 / total as f64
    }

    /// Fraction of attention q·k rows computed by the 1.25-bit ternary
    /// LUT walk: ~1 for ternary pools, 0 elsewhere / when unrecorded.
    pub fn ternary_dot_fraction(&self) -> f64 {
        let total = self.kv_qk_rows_int8 + self.kv_qk_rows_f32 + self.kv_qk_rows_ternary;
        if total == 0 {
            return 0.0;
        }
        self.kv_qk_rows_ternary as f64 / total as f64
    }

    /// Hit rate of the frozen-tile LRU (0 when the cache never ran —
    /// f32 pools, sharing off, or capacity 0).
    pub fn tile_cache_hit_rate(&self) -> f64 {
        let total = self.kv_tile_hits + self.kv_tile_misses;
        if total == 0 {
            return 0.0;
        }
        self.kv_tile_hits as f64 / total as f64
    }

    pub fn report(&self) -> String {
        format!(
            "requests: {}/{} done | tokens: {} | rounds: {} | wall: {:.2}s\n\
             throughput: {:.1} tok/s | latency p50/p99: {:.3}/{:.3}s | ttft p50: {:.3}s\n\
             kv: {}/{} pages peak ({:.0}% util) | {} B/token (K {} + V {}) | dequant: {:.3} cpu-s\n\
             int8 q·k: {:.0}% | ternary q·k: {:.0}% of dot rows | int8 a·V rows: {} | tile cache: {:.0}% hits ({}/{}) | kernel isa: {}\n\
             prefix hit-rate: {:.0}% ({} hits) | \
             peak active: {} | context-limit finishes: {}",
            self.requests_done,
            self.requests_in,
            self.tokens_generated,
            self.decode_rounds,
            self.wall_seconds,
            self.throughput_tps(),
            self.latency_p50(),
            self.latency_p99(),
            self.ttft_p50(),
            self.kv_pages_peak,
            self.kv_pages_total,
            100.0 * self.block_utilization(),
            self.kv_bytes_per_token,
            self.kv_bytes_per_token_k,
            self.kv_bytes_per_token_v,
            self.kv_dequant_seconds,
            100.0 * self.int8_dot_fraction(),
            100.0 * self.ternary_dot_fraction(),
            self.kv_av_rows_int8,
            100.0 * self.tile_cache_hit_rate(),
            self.kv_tile_hits,
            self.kv_tile_hits + self.kv_tile_misses,
            if self.kernel_isa.is_empty() { "unrecorded" } else { &self.kernel_isa },
            100.0 * self.prefix_hit_rate(),
            self.prefix_hits,
            self.peak_active,
            self.context_limit_finishes,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_math() {
        let m = Metrics { tokens_generated: 100, wall_seconds: 4.0, ..Default::default() };
        assert_eq!(m.throughput_tps(), 25.0);
    }

    #[test]
    fn zero_wall_is_zero_throughput() {
        let m = Metrics::default();
        assert_eq!(m.throughput_tps(), 0.0);
    }

    #[test]
    fn report_contains_counters() {
        let m = Metrics { requests_in: 5, requests_done: 5, tokens_generated: 42, ..Default::default() };
        let r = m.report();
        assert!(r.contains("5/5"));
        assert!(r.contains("42"));
    }

    #[test]
    fn kv_gauge_math() {
        let m = Metrics {
            kv_pages_total: 32,
            kv_pages_peak: 8,
            prompt_tokens: 100,
            prefix_hit_tokens: 25,
            ..Default::default()
        };
        assert_eq!(m.block_utilization(), 0.25);
        assert_eq!(m.prefix_hit_rate(), 0.25);
        // Zero denominators stay finite.
        let z = Metrics::default();
        assert_eq!(z.block_utilization(), 0.0);
        assert_eq!(z.prefix_hit_rate(), 0.0);
        assert_eq!(z.dequant_overhead(), 0.0);
        assert_eq!(z.int8_dot_fraction(), 0.0);
        assert_eq!(z.ternary_dot_fraction(), 0.0);
        assert_eq!(z.tile_cache_hit_rate(), 0.0);
    }

    #[test]
    fn int8_attention_gauge_math_and_report() {
        let m = Metrics {
            kv_qk_rows_int8: 300,
            kv_qk_rows_f32: 100,
            kv_av_rows_int8: 400,
            kv_tile_hits: 30,
            kv_tile_misses: 10,
            ..Default::default()
        };
        assert_eq!(m.int8_dot_fraction(), 0.75);
        assert_eq!(m.ternary_dot_fraction(), 0.0);
        assert_eq!(m.tile_cache_hit_rate(), 0.75);
        let r = m.report();
        assert!(r.contains("int8 q·k: 75% | ternary q·k: 0% of dot rows"), "{r}");
        assert!(r.contains("int8 a·V rows: 400"), "{r}");
        assert!(r.contains("tile cache: 75% hits (30/40)"), "{r}");
    }

    #[test]
    fn ternary_attention_gauge_math_and_report() {
        // A ternary pool's score pass is all LUT rows except the f32
        // leg contributed by contiguous prefill caches.
        let m = Metrics {
            kv_qk_rows_int8: 0,
            kv_qk_rows_f32: 100,
            kv_qk_rows_ternary: 300,
            ..Default::default()
        };
        assert_eq!(m.ternary_dot_fraction(), 0.75);
        assert_eq!(m.int8_dot_fraction(), 0.0);
        let r = m.report();
        assert!(r.contains("int8 q·k: 0% | ternary q·k: 75% of dot rows"), "{r}");
        // All three classes share one denominator.
        let mixed = Metrics {
            kv_qk_rows_int8: 100,
            kv_qk_rows_f32: 100,
            kv_qk_rows_ternary: 200,
            ..Default::default()
        };
        assert_eq!(mixed.int8_dot_fraction(), 0.25);
        assert_eq!(mixed.ternary_dot_fraction(), 0.5);
    }

    #[test]
    fn kernel_isa_surfaces_in_report() {
        let m = Metrics { kernel_isa: "avx2".to_string(), ..Default::default() };
        assert!(m.report().contains("kernel isa: avx2"), "{}", m.report());
        let unset = Metrics::default();
        assert!(unset.report().contains("kernel isa: unrecorded"), "{}", unset.report());
        // The serving loop records whatever the process pinned.
        let live = Metrics {
            kernel_isa: crate::simd::active().name().to_string(),
            ..Default::default()
        };
        assert!(live.report().contains("kernel isa: "), "{}", live.report());
    }

    #[test]
    fn dequant_overhead_math_and_report_gauges() {
        let m = Metrics {
            wall_seconds: 2.0,
            kv_dequant_seconds: 0.5,
            kv_bytes_per_token: 516,
            kv_bytes_per_token_k: 258,
            kv_bytes_per_token_v: 258,
            ..Default::default()
        };
        assert_eq!(m.dequant_overhead(), 0.25);
        let r = m.report();
        assert!(r.contains("516 B/token (K 258 + V 258)"), "{r}");
        assert!(r.contains("dequant: 0.500 cpu-s"), "{r}");
        // Summed across workers: more dequant CPU than wall is legal.
        let busy = Metrics { wall_seconds: 1.0, kv_dequant_seconds: 3.0, ..Default::default() };
        assert_eq!(busy.dequant_overhead(), 3.0);
    }
}
