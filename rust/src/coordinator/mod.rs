//! Layer-3 serving coordinator: request routing, continuous batching,
//! KV-cache pooling and the decode scheduler over the native LUT engine.
//!
//! The paper's system is an edge inference engine (BitNet.cpp-style); the
//! coordinator wraps it the way a local serving daemon would: requests
//! arrive (here from a synthetic trace — the environment is offline),
//! are admitted against a KV-pool budget, batched into decode rounds, and
//! executed on a worker pool where each worker owns its LUT scratch.

mod batcher;
mod kvpool;
mod metrics;
mod server;

pub use batcher::{Batcher, BatcherConfig};
pub use kvpool::KvPool;
pub use metrics::Metrics;
pub use server::{serve_trace, Server, ServerConfig, TraceSpec};

/// An inference request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
    /// Arrival time offset (seconds from trace start).
    pub arrival: f64,
}

/// A finished request.
#[derive(Clone, Debug)]
pub struct Completion {
    pub id: u64,
    pub tokens: Vec<u32>,
    /// Seconds from arrival to first generated token.
    pub ttft: f64,
    /// Seconds from arrival to completion.
    pub latency: f64,
}
