//! Layer-3 serving coordinator: request routing, continuous batching,
//! paged KV-cache leasing with radix prefix sharing, and the decode
//! scheduler over the native LUT engine.
//!
//! The paper's system is an edge inference engine (BitNet.cpp-style); the
//! coordinator wraps it the way a local serving daemon would: requests
//! arrive (here from a synthetic trace — the environment is offline),
//! are admitted against a page budget on the KV arena (`crate::cache`),
//! batched into decode rounds, and executed on a worker pool where each
//! worker owns its LUT scratch. Prompts whose prefix matches a
//! previously served request skip prefill for the shared span.
//!
//! Invariants the whole layer is tested against:
//!
//! * a request's tokens are a function of the request alone — never of
//!   batching, paging, KV dtype knobs (tile cache, sharing), arrival
//!   order, prefill chunking, or preemption (greedy sampling; non-greedy
//!   draws are reproducible per request id);
//! * admission reserves worst-case pages, so decode can never exhaust
//!   the arena mid-round; scheduling is strict priority across classes
//!   and FIFO within one, with aging bounding Batch-class starvation;
//! * every page reference a sequence takes is returned at retirement —
//!   at trace end only the prefix index holds pages;
//! * a sequence at the context limit finishes with
//!   [`FinishReason::ContextLimit`] instead of feeding the engine past
//!   `seq_len`.

mod batcher;
mod kvpool;
mod metrics;
mod sampler;
mod server;

pub use batcher::{Batcher, BatcherConfig};
pub use kvpool::PagedKv;
pub use metrics::{KernelStat, Metrics, PhaseSeconds};
pub use sampler::{Sampler, SamplerConfig};
pub use server::{serve_trace, Preemption, Server, ServerConfig, TraceSpec};

/// Scheduling class of a request. Admission is strict priority across
/// classes and FIFO within one; starvation of [`Priority::Batch`] work is
/// bounded by the batcher's aging threshold (old Batch requests are
/// promoted to the Interactive queue's tail). Preemption only ever runs
/// *down* the order: an Interactive arrival may preempt a Batch sequence,
/// never a peer.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    /// Latency-sensitive foreground traffic (the default).
    Interactive,
    /// Throughput-oriented background traffic; yields to Interactive.
    Batch,
}

impl Priority {
    /// All classes, in admission order (highest priority first).
    pub const ALL: [Priority; 2] = [Priority::Interactive, Priority::Batch];
    /// Number of classes (per-class queue/histogram array length).
    pub const COUNT: usize = 2;

    /// Dense index for per-class arrays (admission order).
    pub fn index(self) -> usize {
        match self {
            Priority::Interactive => 0,
            Priority::Batch => 1,
        }
    }

    /// Stable lowercase name (CLI values, metric labels, JSON keys).
    pub fn name(self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Batch => "batch",
        }
    }

    /// Parse a CLI/class name produced by [`Priority::name`].
    pub fn parse(s: &str) -> Option<Priority> {
        match s {
            "interactive" => Some(Priority::Interactive),
            "batch" => Some(Priority::Batch),
            _ => None,
        }
    }
}

/// An inference request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
    /// Arrival time offset (seconds from trace start).
    pub arrival: f64,
    /// Scheduling class (see [`Priority`]).
    pub priority: Priority,
    /// Optional latency SLO in seconds from arrival. Purely
    /// observational: a completion later than this increments the
    /// `deadline_misses` counter; it never changes scheduling.
    pub deadline: Option<f64>,
}

impl Default for Request {
    fn default() -> Self {
        Self {
            id: 0,
            prompt: Vec::new(),
            max_new_tokens: 0,
            arrival: 0.0,
            priority: Priority::Interactive,
            deadline: None,
        }
    }
}

/// Why a request stopped generating.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// Reached its `max_new_tokens` allowance.
    Length,
    /// Hit the model's context limit (`seq_len`) — finished gracefully
    /// with the tokens produced so far instead of overflowing the cache.
    ContextLimit,
}

/// A finished request.
#[derive(Clone, Debug)]
pub struct Completion {
    pub id: u64,
    pub tokens: Vec<u32>,
    pub finish: FinishReason,
    /// Seconds from arrival to first generated token.
    pub ttft: f64,
    /// Seconds from arrival to completion.
    pub latency: f64,
}
