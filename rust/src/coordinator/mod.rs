//! Layer-3 serving coordinator: request routing, continuous batching,
//! paged KV-cache leasing with radix prefix sharing, and the decode
//! scheduler over the native LUT engine.
//!
//! The paper's system is an edge inference engine (BitNet.cpp-style); the
//! coordinator wraps it the way a local serving daemon would: requests
//! arrive (here from a synthetic trace — the environment is offline),
//! are admitted against a page budget on the KV arena (`crate::cache`),
//! batched into decode rounds, and executed on a worker pool where each
//! worker owns its LUT scratch. Prompts whose prefix matches a
//! previously served request skip prefill for the shared span.
//!
//! Invariants the whole layer is tested against:
//!
//! * a request's tokens are a function of the request alone — never of
//!   batching, paging, KV dtype knobs (tile cache, sharing), or arrival
//!   order (greedy sampling; non-greedy draws are reproducible per
//!   request id);
//! * admission reserves worst-case pages, so decode can never exhaust
//!   the arena mid-round, and FIFO order is preserved (no starvation);
//! * every page reference a sequence takes is returned at retirement —
//!   at trace end only the prefix index holds pages;
//! * a sequence at the context limit finishes with
//!   [`FinishReason::ContextLimit`] instead of feeding the engine past
//!   `seq_len`.

mod batcher;
mod kvpool;
mod metrics;
mod sampler;
mod server;

pub use batcher::{Batcher, BatcherConfig};
pub use kvpool::PagedKv;
pub use metrics::{KernelStat, Metrics, PhaseSeconds};
pub use sampler::{Sampler, SamplerConfig};
pub use server::{serve_trace, Server, ServerConfig, TraceSpec};

/// An inference request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
    /// Arrival time offset (seconds from trace start).
    pub arrival: f64,
}

/// Why a request stopped generating.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// Reached its `max_new_tokens` allowance.
    Length,
    /// Hit the model's context limit (`seq_len`) — finished gracefully
    /// with the tokens produced so far instead of overflowing the cache.
    ContextLimit,
}

/// A finished request.
#[derive(Clone, Debug)]
pub struct Completion {
    pub id: u64,
    pub tokens: Vec<u32>,
    pub finish: FinishReason,
    /// Seconds from arrival to first generated token.
    pub ttft: f64,
    /// Seconds from arrival to completion.
    pub latency: f64,
}
