//! Token sampling policy for the serving loop.
//!
//! The seed hardcoded `argmax` into the server's decode rounds; this
//! small sampler keeps greedy as the default (temperature 0 — every
//! determinism and parity test rides on it) while letting traces
//! exercise non-greedy workloads: temperature softmax over an optional
//! top-k cut, drawn from a per-request PCG stream so completions are
//! reproducible per request id regardless of batching order.

use crate::engine::argmax;
use crate::util::Pcg64;

/// Server-level sampling knobs (per-request RNG streams are derived from
/// `seed` and the request id).
#[derive(Clone, Copy, Debug)]
pub struct SamplerConfig {
    /// Softmax temperature; `0` (or any non-positive value) = greedy.
    pub temperature: f32,
    /// Keep only the `top_k` highest logits before sampling; `0` = full
    /// vocabulary.
    pub top_k: usize,
    /// Base seed; request `r` samples from `Pcg64::new(seed, r)`.
    pub seed: u64,
}

impl Default for SamplerConfig {
    fn default() -> Self {
        Self { temperature: 0.0, top_k: 0, seed: 0 }
    }
}

impl SamplerConfig {
    pub fn is_greedy(&self) -> bool {
        self.temperature <= 0.0 || self.top_k == 1
    }
}

/// Per-sequence sampler state (one per active request).
pub struct Sampler {
    temperature: f32,
    top_k: usize,
    rng: Pcg64,
}

impl Sampler {
    /// Sampler for one request: an independent, reproducible PCG stream.
    pub fn for_request(cfg: &SamplerConfig, request_id: u64) -> Self {
        let rng = Pcg64::new(cfg.seed, request_id);
        Self { temperature: cfg.temperature, top_k: cfg.top_k, rng }
    }

    /// Draw the next token id from `logits`.
    pub fn sample(&mut self, logits: &[f32]) -> u32 {
        if self.temperature <= 0.0 || self.top_k == 1 {
            return argmax(logits) as u32;
        }
        // Candidate set: top-k logits (full vocab when top_k = 0). A
        // total order (logit desc, index asc) makes both the partition
        // and the final candidate sequence uniquely defined, so draws
        // stay reproducible across std versions.
        let mut idx: Vec<usize> = (0..logits.len()).collect();
        if self.top_k > 0 && self.top_k < logits.len() {
            let by_logit_desc = |&a: &usize, &b: &usize| {
                logits[b]
                    .partial_cmp(&logits[a])
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.cmp(&b))
            };
            idx.select_nth_unstable_by(self.top_k - 1, by_logit_desc);
            idx.truncate(self.top_k);
            idx.sort_unstable_by(by_logit_desc);
        }
        // Temperature softmax over candidates (max-subtracted for
        // stability), then one categorical draw.
        let max = idx.iter().map(|&i| logits[i]).fold(f32::NEG_INFINITY, f32::max);
        let weights: Vec<f32> =
            idx.iter().map(|&i| ((logits[i] - max) / self.temperature).exp()).collect();
        idx[self.rng.categorical(&weights)] as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_matches_argmax() {
        let logits = vec![0.1, 2.0, -1.0, 1.9];
        let mut s = Sampler::for_request(&SamplerConfig::default(), 3);
        for _ in 0..4 {
            assert_eq!(s.sample(&logits), 1);
        }
    }

    #[test]
    fn top_k_one_is_greedy_at_any_temperature() {
        let logits = vec![0.1, 2.0, -1.0, 1.9];
        let cfg = SamplerConfig { temperature: 5.0, top_k: 1, seed: 9 };
        let mut s = Sampler::for_request(&cfg, 0);
        assert!(cfg.is_greedy());
        for _ in 0..8 {
            assert_eq!(s.sample(&logits), 1);
        }
    }

    #[test]
    fn top_k_restricts_support() {
        let logits = vec![0.0, 5.0, 4.0, -3.0];
        let cfg = SamplerConfig { temperature: 2.0, top_k: 2, seed: 1 };
        let mut s = Sampler::for_request(&cfg, 0);
        for _ in 0..200 {
            let t = s.sample(&logits);
            assert!(t == 1 || t == 2, "sampled outside top-2: {t}");
        }
    }

    #[test]
    fn per_request_streams_are_reproducible_and_distinct() {
        let logits: Vec<f32> = (0..16).map(|i| (i % 5) as f32 * 0.3).collect();
        let cfg = SamplerConfig { temperature: 1.0, top_k: 0, seed: 7 };
        let draw = |rid: u64| {
            let mut s = Sampler::for_request(&cfg, rid);
            (0..32).map(|_| s.sample(&logits)).collect::<Vec<u32>>()
        };
        assert_eq!(draw(1), draw(1), "same request id replays identically");
        assert_ne!(draw(1), draw(2), "request ids get independent streams");
    }

    #[test]
    fn high_temperature_spreads_mass() {
        let logits = vec![1.0, 1.1, 0.9, 1.05];
        let cfg = SamplerConfig { temperature: 10.0, top_k: 0, seed: 3 };
        let mut s = Sampler::for_request(&cfg, 0);
        let mut seen = [false; 4];
        for _ in 0..500 {
            seen[s.sample(&logits) as usize] = true;
        }
        assert!(seen.iter().all(|&x| x), "all tokens reachable at high temperature");
    }
}
