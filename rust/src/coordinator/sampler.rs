//! Token sampling policy for the serving loop.
//!
//! The seed hardcoded `argmax` into the server's decode rounds; this
//! small sampler keeps greedy as the default (temperature 0 — every
//! determinism and parity test rides on it) while letting traces
//! exercise non-greedy workloads: temperature softmax over an optional
//! top-k cut and/or top-p (nucleus) cut, with a CTRL-style repetition
//! penalty over the tokens a request has already seen (prompt +
//! generated), all drawn from a per-request PCG stream so completions
//! are reproducible per request id regardless of batching order.

use std::collections::HashSet;

use crate::engine::argmax;
use crate::util::Pcg64;

/// Server-level sampling knobs (per-request RNG streams are derived from
/// `seed` and the request id).
#[derive(Clone, Copy, Debug)]
pub struct SamplerConfig {
    /// Softmax temperature; `0` (or any non-positive value) = greedy.
    pub temperature: f32,
    /// Keep only the `top_k` highest logits before sampling; `0` = full
    /// vocabulary.
    pub top_k: usize,
    /// Nucleus sampling: keep the smallest logit-descending prefix whose
    /// probability mass reaches `top_p`; `≥ 1` (or `≤ 0`) = off.
    pub top_p: f32,
    /// CTRL-style repetition penalty over already-seen tokens (prompt +
    /// generated): positive logits divided by, negative multiplied by the
    /// penalty. `1` = off. Applies before the greedy/top-k/top-p cut, so
    /// it also steers temperature-0 decoding.
    pub repetition_penalty: f32,
    /// Base seed; request `r` samples from `Pcg64::new(seed, r)`.
    pub seed: u64,
}

impl Default for SamplerConfig {
    fn default() -> Self {
        Self { temperature: 0.0, top_k: 0, top_p: 1.0, repetition_penalty: 1.0, seed: 0 }
    }
}

impl SamplerConfig {
    /// No randomness involved (the repetition penalty is deterministic,
    /// so a penalized temperature-0 stream is still greedy).
    pub fn is_greedy(&self) -> bool {
        self.temperature <= 0.0 || self.top_k == 1
    }
}

/// Per-sequence sampler state (one per active request).
///
/// ```
/// use sherry::coordinator::{Sampler, SamplerConfig};
///
/// // Greedy default: temperature 0 picks the argmax deterministically.
/// let mut sampler = Sampler::for_request(&SamplerConfig::default(), /*request_id=*/ 7);
/// assert_eq!(sampler.sample(&[0.1, 2.0, -0.3]), 1);
///
/// // Non-greedy draws come from a per-request PCG stream: the same
/// // request id replays the same tokens regardless of batching order.
/// let cfg = SamplerConfig { temperature: 0.8, top_p: 0.9, ..SamplerConfig::default() };
/// let logits = [0.5, 1.5, 0.2, 3.0];
/// let mut a = Sampler::for_request(&cfg, 7);
/// let mut b = Sampler::for_request(&cfg, 7);
/// assert_eq!(a.sample(&logits), b.sample(&logits));
/// ```
pub struct Sampler {
    temperature: f32,
    top_k: usize,
    top_p: f32,
    repetition_penalty: f32,
    /// Tokens this request has seen (prompt + generated); the penalty's
    /// support set. Unused (empty) when the penalty is off.
    seen: HashSet<u32>,
    rng: Pcg64,
}

impl Sampler {
    /// Sampler for one request: an independent, reproducible PCG stream.
    pub fn for_request(cfg: &SamplerConfig, request_id: u64) -> Self {
        let rng = Pcg64::new(cfg.seed, request_id);
        // Penalty must be a positive finite factor: 0 would turn a
        // penalized positive logit into +inf (the repeat wins forever)
        // and NaN poisons the softmax. Anything unusable degrades to off.
        let rp = cfg.repetition_penalty;
        let repetition_penalty = if rp.is_finite() && rp > 0.0 { rp } else { 1.0 };
        Self {
            temperature: cfg.temperature,
            top_k: cfg.top_k,
            top_p: cfg.top_p,
            repetition_penalty,
            seen: HashSet::new(),
            rng,
        }
    }

    /// Record a token as part of this request's context (the server feeds
    /// prompt tokens at admission; sampled tokens are recorded
    /// automatically by [`Sampler::sample`]). No-op when the penalty is
    /// off, so greedy parity paths never touch the set.
    pub fn observe(&mut self, token: u32) {
        if self.repetition_penalty != 1.0 {
            self.seen.insert(token);
        }
    }

    /// Draw the next token id from `logits`.
    pub fn sample(&mut self, logits: &[f32]) -> u32 {
        let tok = self.pick(logits);
        self.observe(tok);
        tok
    }

    fn pick(&mut self, logits: &[f32]) -> u32 {
        // Repetition penalty first: it reshapes the distribution every
        // later stage (greedy cut included) sees.
        let penalized: Option<Vec<f32>> = if self.repetition_penalty != 1.0 && !self.seen.is_empty()
        {
            let mut l = logits.to_vec();
            for &t in &self.seen {
                let x = &mut l[t as usize];
                if *x > 0.0 {
                    *x /= self.repetition_penalty;
                } else {
                    *x *= self.repetition_penalty;
                }
            }
            Some(l)
        } else {
            None
        };
        let logits = penalized.as_deref().unwrap_or(logits);

        if self.temperature <= 0.0 || self.top_k == 1 {
            return argmax(logits) as u32;
        }
        // Candidate set: top-k logits (full vocab when top_k = 0). A
        // total order (logit desc, index asc) makes both the partition
        // and the final candidate sequence uniquely defined, so draws
        // stay reproducible across std versions.
        let by_logit_desc = |&a: &usize, &b: &usize| {
            logits[b]
                .partial_cmp(&logits[a])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        };
        let mut idx: Vec<usize> = (0..logits.len()).collect();
        if self.top_k > 0 && self.top_k < logits.len() {
            idx.select_nth_unstable_by(self.top_k - 1, by_logit_desc);
            idx.truncate(self.top_k);
        }
        // Temperature softmax over candidates (max-subtracted for
        // stability).
        let nucleus = self.top_p > 0.0 && self.top_p < 1.0;
        if nucleus || self.top_k > 0 {
            // Nucleus truncation needs descending order; the top-k path
            // sorts anyway to keep the candidate sequence well-defined.
            idx.sort_unstable_by(by_logit_desc);
        }
        let max = idx.iter().map(|&i| logits[i]).fold(f32::NEG_INFINITY, f32::max);
        let mut weights: Vec<f32> =
            idx.iter().map(|&i| ((logits[i] - max) / self.temperature).exp()).collect();
        if nucleus {
            // Keep the smallest descending prefix reaching `top_p` mass
            // (always ≥ 1 candidate).
            let total: f32 = weights.iter().sum();
            let mut cum = 0.0f32;
            let mut keep = weights.len();
            for (j, w) in weights.iter().enumerate() {
                cum += w / total;
                if cum >= self.top_p {
                    keep = j + 1;
                    break;
                }
            }
            idx.truncate(keep);
            weights.truncate(keep);
        }
        idx[self.rng.categorical(&weights)] as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_matches_argmax() {
        let logits = vec![0.1, 2.0, -1.0, 1.9];
        let mut s = Sampler::for_request(&SamplerConfig::default(), 3);
        for _ in 0..4 {
            assert_eq!(s.sample(&logits), 1);
        }
    }

    #[test]
    fn top_k_one_is_greedy_at_any_temperature() {
        let logits = vec![0.1, 2.0, -1.0, 1.9];
        let cfg = SamplerConfig { temperature: 5.0, top_k: 1, seed: 9, ..Default::default() };
        let mut s = Sampler::for_request(&cfg, 0);
        assert!(cfg.is_greedy());
        for _ in 0..8 {
            assert_eq!(s.sample(&logits), 1);
        }
    }

    #[test]
    fn top_k_restricts_support() {
        let logits = vec![0.0, 5.0, 4.0, -3.0];
        let cfg = SamplerConfig { temperature: 2.0, top_k: 2, seed: 1, ..Default::default() };
        let mut s = Sampler::for_request(&cfg, 0);
        for _ in 0..200 {
            let t = s.sample(&logits);
            assert!(t == 1 || t == 2, "sampled outside top-2: {t}");
        }
    }

    #[test]
    fn top_p_restricts_support_to_the_nucleus() {
        // Probabilities at temperature 1 ≈ [0.64, 0.24, 0.09, 0.03]:
        // top_p = 0.6 keeps {0}, 0.95 keeps {0, 1, 2}.
        let logits = vec![3.0, 2.0, 1.0, 0.0];
        let cfg = SamplerConfig { temperature: 1.0, top_p: 0.6, seed: 2, ..Default::default() };
        let mut s = Sampler::for_request(&cfg, 0);
        for _ in 0..100 {
            assert_eq!(s.sample(&logits), 0, "0.6 nucleus is the single top token");
        }
        let cfg = SamplerConfig { temperature: 1.0, top_p: 0.95, seed: 2, ..Default::default() };
        let mut s = Sampler::for_request(&cfg, 0);
        let mut seen = [false; 4];
        for _ in 0..500 {
            seen[s.sample(&logits) as usize] = true;
        }
        assert!(!seen[3], "tail token outside the 0.95 nucleus");
        assert!(seen[0] && seen[1], "nucleus tokens reachable");
    }

    #[test]
    fn top_p_composes_with_top_k() {
        let logits = vec![3.0, 2.9, 2.8, 2.7, -10.0];
        let cfg = SamplerConfig {
            temperature: 1.0,
            top_k: 3,
            top_p: 0.99,
            seed: 4,
            ..Default::default()
        };
        let mut s = Sampler::for_request(&cfg, 0);
        for _ in 0..300 {
            let t = s.sample(&logits);
            assert!(t <= 2, "outside top-k∩nucleus: {t}");
        }
    }

    #[test]
    fn repetition_penalty_steers_greedy_off_repeats() {
        // Deterministic (temperature 0) walk: each drawn token is
        // penalized, handing the argmax to the next-best fresh token.
        let logits = vec![1.0, 2.0, 1.5, 0.5];
        let cfg = SamplerConfig { repetition_penalty: 3.0, ..Default::default() };
        let mut s = Sampler::for_request(&cfg, 0);
        assert_eq!(s.sample(&logits), 1);
        assert_eq!(s.sample(&logits), 2, "penalized repeat loses the argmax");
        assert_eq!(s.sample(&logits), 0, "next repeat penalized too");
        assert_eq!(s.sample(&logits), 1, "all penalized: best of the penalized set");
    }

    #[test]
    fn degenerate_repetition_penalty_degrades_to_off() {
        let logits = vec![0.1, 2.0, -1.0, 1.9];
        for bad in [0.0f32, -3.0, f32::NAN, f32::INFINITY] {
            let cfg = SamplerConfig { repetition_penalty: bad, ..Default::default() };
            let mut s = Sampler::for_request(&cfg, 0);
            for _ in 0..3 {
                assert_eq!(s.sample(&logits), 1, "penalty {bad} must not corrupt sampling");
            }
        }
    }

    #[test]
    fn repetition_penalty_multiplies_negative_logits() {
        // All-negative logits: a penalized negative must be *multiplied*
        // (pushed further down). Wrongly dividing would leave token 1 on
        // top forever.
        let logits = vec![-0.1, -0.05, -0.2];
        let cfg = SamplerConfig { repetition_penalty: 4.0, ..Default::default() };
        let mut s = Sampler::for_request(&cfg, 0);
        assert_eq!(s.sample(&logits), 1);
        assert_eq!(s.sample(&logits), 0, "-0.05·4 = -0.2 drops below -0.1");
    }

    #[test]
    fn repetition_penalty_counts_prompt_tokens() {
        let logits = vec![0.1, 2.0, -1.0, 1.9];
        let cfg = SamplerConfig { repetition_penalty: 2.0, ..Default::default() };
        let mut s = Sampler::for_request(&cfg, 0);
        s.observe(1); // prompt contained the dominant token
        assert_eq!(s.sample(&logits), 3, "prompt repeat already penalized");
    }

    #[test]
    fn penalty_off_is_exactly_argmax_even_after_observe() {
        let logits = vec![0.1, 2.0, -1.0, 1.9];
        let mut s = Sampler::for_request(&SamplerConfig::default(), 0);
        s.observe(1);
        for _ in 0..4 {
            assert_eq!(s.sample(&logits), 1, "penalty 1.0 must not alter greedy");
        }
    }

    #[test]
    fn per_request_streams_are_reproducible_and_distinct() {
        let logits: Vec<f32> = (0..16).map(|i| (i % 5) as f32 * 0.3).collect();
        let cfg = SamplerConfig { temperature: 1.0, top_k: 0, seed: 7, ..Default::default() };
        let draw = |rid: u64| {
            let mut s = Sampler::for_request(&cfg, rid);
            (0..32).map(|_| s.sample(&logits)).collect::<Vec<u32>>()
        };
        assert_eq!(draw(1), draw(1), "same request id replays identically");
        assert_ne!(draw(1), draw(2), "request ids get independent streams");
    }

    #[test]
    fn high_temperature_spreads_mass() {
        let logits = vec![1.0, 1.1, 0.9, 1.05];
        let cfg = SamplerConfig { temperature: 10.0, top_k: 0, seed: 3, ..Default::default() };
        let mut s = Sampler::for_request(&cfg, 0);
        let mut seen = [false; 4];
        for _ in 0..500 {
            seen[s.sample(&logits) as usize] = true;
        }
        assert!(seen.iter().all(|&x| x), "all tokens reachable at high temperature");
    }
}
