//! Synthetic zero-shot tasks (DESIGN.md substitution for the paper's five
//! lm-eval-harness benchmarks).
//!
//! Each task emits multiple-choice questions over the synthetic corpus
//! process: a context, four candidate continuations, one correct. Tasks
//! differ in the *kind* of structure required, giving the same difficulty
//! spread the paper's suite has:
//!
//! | here        | proxies | requires                                  |
//! |-------------|---------|-------------------------------------------|
//! | `succ`      | ARC-e   | 1-step bigram structure (easy)            |
//! | `chain`     | PIQA    | 2-step transition composition             |
//! | `induction` | HelS    | in-context copy of a repeated motif       |
//! | `recall`    | WinG    | long-range token membership               |
//! | `fine`      | ARC-c   | discriminating near-miss successors (hard)|

use crate::train::corpus::Corpus;
use crate::util::Pcg64;

/// One multiple-choice question.
#[derive(Clone, Debug)]
pub struct Question {
    pub context: Vec<u32>,
    /// Four candidates, each a short token continuation.
    pub candidates: Vec<Vec<u32>>,
    pub correct: usize,
}

/// Task identifiers (display order matches the paper's tables).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    Succ,
    Fine,
    Induction,
    Chain,
    Recall,
}

impl Task {
    pub const ALL: [Task; 5] = [Task::Succ, Task::Fine, Task::Induction, Task::Chain, Task::Recall];

    /// (our name, paper benchmark it proxies)
    pub fn name(&self) -> (&'static str, &'static str) {
        match self {
            Task::Succ => ("succ", "ARC-e"),
            Task::Fine => ("fine", "ARC-c"),
            Task::Induction => ("induction", "HelS"),
            Task::Chain => ("chain", "PIQA"),
            Task::Recall => ("recall", "WinG"),
        }
    }
}

/// Deterministic question set for a task.
pub fn questions(task: Task, corpus: &Corpus, n: usize, seed: u64) -> Vec<Question> {
    let vocab = corpus.vocab() as u64;
    let mut rng = Pcg64::new(seed ^ 0x7A5C, task.name().0.len() as u64);
    let mut out = Vec::with_capacity(n);
    // Fresh corpus stream for contexts (separate from train/heldout seeds).
    let mut ctx_gen = Corpus::new(corpus.vocab(), seed ^ 0xC0DE);
    while out.len() < n {
        let ctx_len = 12 + rng.below(12) as usize;
        let context = ctx_gen.sequence(ctx_len);
        let last = *context.last().unwrap();
        let (s1, s2) = corpus.successors(last);
        let mut distractor = |exclude: &[u32]| -> u32 {
            loop {
                let c = rng.below(vocab) as u32;
                if !exclude.contains(&c) {
                    return c;
                }
            }
        };
        let q = match task {
            Task::Succ => {
                let correct = s1;
                let ex = [s1, s2, last];
                mk_q(context, vec![vec![correct], vec![distractor(&ex)], vec![distractor(&ex)], vec![distractor(&ex)]], &mut rng)
            }
            Task::Fine => {
                // Discriminate the secondary successor from near misses.
                let correct = s2;
                let near1 = (s2 + 1) % vocab as u32;
                let near2 = (s2 + vocab as u32 - 1) % vocab as u32;
                let near3 = (s2 + 2) % vocab as u32;
                if [near1, near2, near3].contains(&s1) {
                    continue; // ambiguous; resample
                }
                mk_q(context, vec![vec![correct], vec![near1], vec![near2], vec![near3]], &mut rng)
            }
            Task::Induction => {
                // context: ... A B C ... A B → C
                let a = context[2];
                let b = context[3];
                let c = context[4];
                let mut ctx = context;
                ctx.push(a);
                ctx.push(b);
                let ex = [c, a, b];
                mk_q(ctx, vec![vec![c], vec![distractor(&ex)], vec![distractor(&ex)], vec![distractor(&ex)]], &mut rng)
            }
            Task::Chain => {
                // two-step composition: succ(succ(last)).
                let step2 = corpus.successors(s1).0;
                let ex = [s1, s2, step2];
                mk_q(
                    context,
                    vec![
                        vec![s1, step2],
                        vec![s1, distractor(&ex)],
                        vec![distractor(&ex), step2],
                        vec![distractor(&ex), distractor(&ex)],
                    ],
                    &mut rng,
                )
            }
            Task::Recall => {
                // which token appeared early in the context?
                let seen = context[1];
                let ex: Vec<u32> = context.clone();
                mk_q(context.clone(), vec![vec![seen], vec![distractor(&ex)], vec![distractor(&ex)], vec![distractor(&ex)]], &mut rng)
            }
        };
        out.push(q);
    }
    out
}

fn mk_q(context: Vec<u32>, mut cands: Vec<Vec<u32>>, rng: &mut Pcg64) -> Question {
    // Shuffle candidate order so position carries no signal.
    let mut order: Vec<usize> = (0..cands.len()).collect();
    rng.shuffle(&mut order);
    let correct = order.iter().position(|&o| o == 0).unwrap();
    let mut shuffled = Vec::with_capacity(cands.len());
    for &o in &order {
        shuffled.push(std::mem::take(&mut cands[o]));
    }
    Question { context, candidates: shuffled, correct }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn corpus() -> Corpus {
        Corpus::new(256, 0)
    }

    #[test]
    fn questions_well_formed() {
        let c = corpus();
        for task in Task::ALL {
            let qs = questions(task, &c, 20, 1);
            assert_eq!(qs.len(), 20, "{task:?}");
            for q in &qs {
                assert_eq!(q.candidates.len(), 4);
                assert!(q.correct < 4);
                assert!(!q.context.is_empty());
                assert!(q.candidates.iter().all(|cd| !cd.is_empty()));
                // distractors must differ from the correct answer
                let correct = &q.candidates[q.correct];
                for (i, cd) in q.candidates.iter().enumerate() {
                    if i != q.correct {
                        assert_ne!(cd, correct, "{task:?}");
                    }
                }
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let c = corpus();
        let a = questions(Task::Succ, &c, 10, 42);
        let b = questions(Task::Succ, &c, 10, 42);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.context, y.context);
            assert_eq!(x.correct, y.correct);
        }
    }

    #[test]
    fn correct_position_is_uniformish() {
        let c = corpus();
        let qs = questions(Task::Succ, &c, 200, 3);
        let mut counts = [0usize; 4];
        for q in &qs {
            counts[q.correct] += 1;
        }
        for &ct in &counts {
            assert!(ct > 20, "position bias: {counts:?}");
        }
    }

    #[test]
    fn succ_correct_is_true_successor() {
        let c = corpus();
        for q in questions(Task::Succ, &c, 20, 5) {
            let last = *q.context.last().unwrap();
            assert_eq!(q.candidates[q.correct][0], c.successors(last).0);
        }
    }
}
