//! Evaluation harness: scores quantized models on the synthetic benchmark
//! suite and renders the paper's table rows (Tables 1-3).
//!
//! Scoring runs on the *native* engine (the deployed artifact): after QAT,
//! weights are fixed ternary, so PTQ-projecting the trained latents and
//! serving them natively is exactly the paper's deployment path. A
//! PJRT-vs-native parity test lives in `rust/tests/`.

pub mod tasks;

use std::collections::BTreeMap;

use crate::engine::{KvCache, NativeConfig, Scratch, TernaryModel};
use crate::quant::{Granularity, Method};
use crate::tensor::Mat;
use crate::train::corpus::Corpus;
use tasks::{questions, Question, Task};

/// Log-probability of `continuation` given `context` under `model`.
/// Uses one KV-cache pass; length-normalized for candidate comparison.
pub fn continuation_logprob(
    model: &TernaryModel,
    context: &[u32],
    continuation: &[u32],
    cache: &mut KvCache,
    scratch: &mut Scratch,
) -> f32 {
    cache.clear();
    let mut logits = vec![0.0f32; model.cfg.vocab_size];
    for &t in context {
        logits = model.forward_one(t, cache, scratch);
    }
    let mut total = 0.0f32;
    for &t in continuation {
        let lse = log_sum_exp(&logits);
        total += logits[t as usize] - lse;
        if cache.len < model.cfg.seq_len {
            logits = model.forward_one(t, cache, scratch);
        }
    }
    total / continuation.len() as f32
}

fn log_sum_exp(xs: &[f32]) -> f32 {
    let m = xs.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    m + xs.iter().map(|x| (x - m).exp()).sum::<f32>().ln()
}

/// Answer a multiple-choice question: highest normalized logprob wins.
pub fn answer(model: &TernaryModel, q: &Question, cache: &mut KvCache, scratch: &mut Scratch) -> usize {
    let mut best = 0usize;
    let mut best_lp = f32::NEG_INFINITY;
    for (i, cand) in q.candidates.iter().enumerate() {
        let lp = continuation_logprob(model, &q.context, cand, cache, scratch);
        if lp > best_lp {
            best_lp = lp;
            best = i;
        }
    }
    best
}

/// Accuracy of `model` on `n_q` questions of `task`.
pub fn task_accuracy(model: &TernaryModel, corpus: &Corpus, task: Task, n_q: usize, seed: u64) -> f32 {
    let mut cache = KvCache::new(&model.cfg);
    let mut scratch = Scratch::default();
    let qs = questions(task, corpus, n_q, seed);
    let correct = qs
        .iter()
        .filter(|q| answer(model, q, &mut cache, &mut scratch) == q.correct)
        .count();
    correct as f32 / n_q as f32
}

/// Perplexity on `n_seq` held-out sequences.
pub fn perplexity(model: &TernaryModel, vocab: usize, n_seq: usize, seed: u64) -> f32 {
    let mut corpus = Corpus::new(vocab, seed ^ 0xEEE);
    let mut cache = KvCache::new(&model.cfg);
    let mut scratch = Scratch::default();
    let mut nll = 0.0f64;
    let mut count = 0usize;
    for _ in 0..n_seq {
        let seq = corpus.sequence(model.cfg.seq_len);
        cache.clear();
        let mut logits = model.forward_one(seq[0], &mut cache, &mut scratch);
        for &t in &seq[1..] {
            let lse = log_sum_exp(&logits);
            nll += (lse - logits[t as usize]) as f64;
            count += 1;
            if cache.len < model.cfg.seq_len {
                logits = model.forward_one(t, &mut cache, &mut scratch);
            }
        }
    }
    ((nll / count as f64).exp()) as f32
}

/// One evaluated row: per-task accuracy + average (a Table 1/2 row).
#[derive(Clone, Debug)]
pub struct EvalRow {
    pub label: String,
    pub bits: f32,
    pub accs: Vec<(String, f32)>,
    pub average: f32,
    pub perplexity: f32,
}

/// Evaluate a model across the five tasks (+ perplexity).
pub fn evaluate(
    label: &str,
    bits: f32,
    model: &TernaryModel,
    vocab: usize,
    n_q: usize,
    seed: u64,
) -> EvalRow {
    let corpus = Corpus::new(vocab, 0);
    let mut accs = Vec::new();
    let mut sum = 0.0;
    for task in Task::ALL {
        let acc = task_accuracy(model, &corpus, task, n_q, seed);
        sum += acc;
        accs.push((task.name().1.to_string(), acc));
    }
    let ppl = perplexity(model, vocab, 8, seed);
    EvalRow {
        label: label.to_string(),
        bits,
        accs,
        average: sum / Task::ALL.len() as f32,
        perplexity: ppl,
    }
}

/// PTQ-project trained latents with `method` and evaluate (the deployed
/// model of Tables 1-3).
pub fn evaluate_ptq(
    label: &str,
    cfg: NativeConfig,
    params: &BTreeMap<String, Mat>,
    method: Method,
    granularity: Granularity,
    n_q: usize,
    seed: u64,
) -> EvalRow {
    let model = TernaryModel::build_ptq(cfg, params, method, granularity);
    let bits = method.bits_per_weight();
    evaluate(label, bits, &model, cfg.vocab_size, n_q, seed)
}

/// Render rows as the paper-style table.
pub fn render_table(title: &str, rows: &[EvalRow]) -> String {
    let mut s = format!("### {title}\n\n");
    if rows.is_empty() {
        return s;
    }
    s.push_str("| Method | Bits | ");
    for (name, _) in &rows[0].accs {
        s.push_str(&format!("{name} | "));
    }
    s.push_str("Average | PPL |\n|---|---|");
    for _ in 0..rows[0].accs.len() + 2 {
        s.push_str("---|");
    }
    s.push('\n');
    for r in rows {
        s.push_str(&format!("| {} | {:.2} | ", r.label, r.bits));
        for (_, a) in &r.accs {
            s.push_str(&format!("{a:.3} | "));
        }
        s.push_str(&format!("{:.3} | {:.2} |\n", r.average, r.perplexity));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::random_weights;
    use crate::pack::Format;

    fn nano() -> NativeConfig {
        NativeConfig::named("nano").unwrap()
    }

    #[test]
    fn logprob_is_negative_and_finite() {
        let cfg = nano();
        let w = random_weights(&cfg, 0);
        let m = TernaryModel::build(cfg, &w, Format::Dense);
        let mut cache = KvCache::new(&cfg);
        let mut scratch = Scratch::default();
        let lp = continuation_logprob(&m, &[1, 2, 3], &[4, 5], &mut cache, &mut scratch);
        assert!(lp.is_finite() && lp < 0.0);
    }

    #[test]
    fn untrained_model_near_chance() {
        let cfg = nano();
        let w = random_weights(&cfg, 1);
        let m = TernaryModel::build(cfg, &w, Format::Dense);
        let corpus = Corpus::new(cfg.vocab_size, 0);
        let acc = task_accuracy(&m, &corpus, Task::Succ, 40, 0);
        assert!(acc < 0.6, "untrained acc {acc} suspiciously high");
    }

    #[test]
    fn perplexity_of_untrained_near_vocab() {
        let cfg = nano();
        let w = random_weights(&cfg, 2);
        let m = TernaryModel::build(cfg, &w, Format::Dense);
        let ppl = perplexity(&m, cfg.vocab_size, 2, 0);
        // untrained ≈ uniform ⇒ ppl ≈ vocab (loose band)
        assert!(ppl > 64.0 && ppl < 1024.0, "ppl {ppl}");
    }

    #[test]
    fn render_includes_all_rows() {
        let rows = vec![EvalRow {
            label: "sherry".into(),
            bits: 1.25,
            accs: vec![("ARC-e".into(), 0.5)],
            average: 0.5,
            perplexity: 10.0,
        }];
        let t = render_table("Table 1", &rows);
        assert!(t.contains("sherry"));
        assert!(t.contains("1.25"));
        assert!(t.contains("ARC-e"));
    }

    #[test]
    fn evaluate_ptq_all_methods_smoke() {
        let cfg = nano();
        let w = random_weights(&cfg, 3);
        for m in [Method::Sherry34, Method::AbsMean, Method::Binary] {
            let row = evaluate_ptq(m.name(), cfg, &w, m, Granularity::PerChannel, 4, 0);
            assert_eq!(row.accs.len(), 5);
            assert!(row.average >= 0.0 && row.average <= 1.0);
        }
    }
}
