//! AVX2 leaf kernels (x86-64). Eight f32 lanes; LUT/activation rows are
//! gathered with `vgatherdps` (i32 indices scaled ×4), the mirror sign is
//! a `vpxor` on the f32 bit patterns, and the i8 dot widens 16 bytes at a
//! time through `vpmovsxbw` + `vpmaddwd`.
//!
//! Safety contract for every `unsafe fn` here: the host supports AVX2
//! (runtime-checked by the dispatch layer), the matching scalar kernel's
//! slice bounds hold (asserted by the dispatch layer), and
//! `7 * stride <= i32::MAX` for the strided gathers (the
//! `gather_stride_ok` guard). No alignment requirements — all loads are
//! unaligned forms.

use std::arch::x86_64::*;

use super::walk::{self, Lanes};
use crate::pack::{Packed34, PackedI2S, PackedTl2};

#[derive(Clone, Copy)]
pub(crate) struct Avx2;

impl Lanes for Avx2 {
    const W: usize = 8;
    type V = __m256;

    #[inline(always)]
    unsafe fn zero() -> __m256 {
        _mm256_setzero_ps()
    }

    #[inline(always)]
    unsafe fn splat(x: f32) -> __m256 {
        _mm256_set1_ps(x)
    }

    #[inline(always)]
    unsafe fn gather(base: *const f32, stride: usize, off: usize) -> __m256 {
        // Lane i reads base[i*stride + off]. The caller guarantees
        // 7*stride fits i32; the index vector is loop-invariant, so LLVM
        // hoists it out of the walk.
        let s = stride as i32;
        let idx = _mm256_setr_epi32(0, s, 2 * s, 3 * s, 4 * s, 5 * s, 6 * s, 7 * s);
        _mm256_i32gather_ps::<4>(base.add(off), idx)
    }

    #[inline(always)]
    unsafe fn gather_at(base: *const f32, off: &[i32; super::MAX_LANES]) -> __m256 {
        // Lane i reads base[off[i]] — one vgatherdps with per-lane
        // indices loaded straight from the walk's offset array.
        let idx = _mm256_loadu_si256(off.as_ptr() as *const __m256i);
        _mm256_i32gather_ps::<4>(base, idx)
    }

    #[inline(always)]
    unsafe fn xor_sign(v: __m256, sign_bit: u32) -> __m256 {
        let m = _mm256_set1_epi32(sign_bit as i32);
        _mm256_castsi256_ps(_mm256_xor_si256(_mm256_castps_si256(v), m))
    }

    #[inline(always)]
    unsafe fn add(a: __m256, b: __m256) -> __m256 {
        _mm256_add_ps(a, b)
    }

    #[inline(always)]
    unsafe fn mul(a: __m256, b: __m256) -> __m256 {
        _mm256_mul_ps(a, b)
    }

    #[inline(always)]
    unsafe fn store(v: __m256, dst: &mut [f32]) {
        debug_assert!(dst.len() >= 8);
        _mm256_storeu_ps(dst.as_mut_ptr(), v);
    }

    type I = __m256i;

    #[inline(always)]
    unsafe fn izero() -> __m256i {
        _mm256_setzero_si256()
    }

    #[inline(always)]
    unsafe fn imac(acc: __m256i, w: i32, v: *const i8) -> __m256i {
        // Exactly 8 V bytes sign-extended straight to i32 lanes and
        // multiplied by the broadcast weight. (The `vpmaddubsw` pairing
        // trick would mix adjacent channels across lanes; per-channel
        // widening keeps lane c == channel c, and i32 math is exact
        // either way.)
        let bytes = _mm_loadl_epi64(v as *const __m128i);
        let wide = _mm256_cvtepi8_epi32(bytes);
        _mm256_add_epi32(acc, _mm256_mullo_epi32(wide, _mm256_set1_epi32(w)))
    }

    #[inline(always)]
    unsafe fn istore(acc: __m256i, dst: &mut [i32]) {
        debug_assert!(dst.len() >= 8);
        _mm256_storeu_si256(dst.as_mut_ptr() as *mut __m256i, acc);
    }
}

/// i8×i8 dot, i32-accumulated: 16 bytes/iter sign-extended to i16 lanes,
/// `vpmaddwd` pairs into i32, tail scalar. Integer addition is
/// associative, so the lane arrangement is exactly equal to the scalar
/// iterator sum (including two's-complement wrap-around).
///
/// # Safety
///
/// AVX2 available; `a.len() == b.len()`.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let mut acc = _mm256_setzero_si256();
    let mut i = 0usize;
    while i + 16 <= n {
        let va = _mm_loadu_si128(a.as_ptr().add(i) as *const __m128i);
        let vb = _mm_loadu_si128(b.as_ptr().add(i) as *const __m128i);
        let wa = _mm256_cvtepi8_epi16(va);
        let wb = _mm256_cvtepi8_epi16(vb);
        acc = _mm256_add_epi32(acc, _mm256_madd_epi16(wa, wb));
        i += 16;
    }
    // Horizontal i32 sum of the 8 lanes.
    let lo = _mm256_castsi256_si128(acc);
    let hi = _mm256_extracti128_si256::<1>(acc);
    let s = _mm_add_epi32(lo, hi);
    let s = _mm_add_epi32(s, _mm_unpackhi_epi64(s, s));
    let s = _mm_add_epi32(s, _mm_shuffle_epi32::<0x55>(s));
    let mut total = _mm_cvtsi128_si32(s);
    while i < n {
        total = total.wrapping_add(a[i] as i32 * b[i] as i32);
        i += 1;
    }
    total
}

/// # Safety
///
/// AVX2 available; `lut::gemm_pack34_preluts` bounds; `7*lut_stride <=
/// i32::MAX` (all asserted/guarded by the dispatch layer).
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn gemm_pack34(
    p: &Packed34,
    luts: &[f32],
    lut_stride: usize,
    batch: usize,
    j0: usize,
    j1: usize,
    out: &mut [f32],
) {
    walk::gemm_pack34::<Avx2>(p, luts, lut_stride, batch, j0, j1, out)
}

/// # Safety
///
/// AVX2 available; `lut::gemm_tl2_preluts` bounds; `7*lut_stride <=
/// i32::MAX`.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn gemm_tl2(
    p: &PackedTl2,
    luts: &[f32],
    lut_stride: usize,
    batch: usize,
    j0: usize,
    j1: usize,
    out: &mut [f32],
) {
    walk::gemm_tl2::<Avx2>(p, luts, lut_stride, batch, j0, j1, out)
}

/// # Safety
///
/// AVX2 available; `lut::qk_lut34_rows` bounds (asserted by the dispatch
/// layer). Offsets are < nb·32 per head table, so no stride guard is
/// needed.
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn qk_lut34_rows(
    idx: &[u8],
    sign: &[u8],
    idx_bh: usize,
    sign_bh: usize,
    nb: usize,
    head: usize,
    n_heads: usize,
    luts: &[f32],
    rows: usize,
    out: &mut [f32],
) {
    walk::qk_lut34_rows::<Avx2>(idx, sign, idx_bh, sign_bh, nb, head, n_heads, luts, rows, out)
}

/// # Safety
///
/// AVX2 available; `av_i8_rows` bounds (asserted by the dispatch layer).
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn av_i8_rows(
    weights: &[u8],
    v: &[i8],
    d: usize,
    col0: usize,
    hd: usize,
    rows: usize,
    out: &mut [i32],
) {
    walk::av_i8_rows::<Avx2>(weights, v, d, col0, hd, rows, out)
}

/// # Safety
///
/// AVX2 available; `lut::gemm_i2s` bounds; `7*d_in <= i32::MAX`.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn gemm_i2s(
    p: &PackedI2S,
    xs: &[f32],
    batch: usize,
    j0: usize,
    j1: usize,
    out: &mut [f32],
) {
    walk::gemm_i2s::<Avx2>(p, xs, batch, j0, j1, out)
}
