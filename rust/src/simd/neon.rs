//! NEON leaf kernels (AArch64). Four f32 lanes; NEON has no hardware
//! gather, so the strided row gather is four scalar loads assembled into
//! a register (still profitable: the walk's decode work and sign flips
//! amortize ×4, and the accumulate chain runs in vector registers). The
//! i8 dot widens 16 bytes per iteration via `smull`/`smull2` + `sadalp`.
//!
//! Safety contract for every `unsafe fn` here: the host supports NEON
//! (runtime-checked by the dispatch layer) and the matching scalar
//! kernel's slice bounds hold (asserted by the dispatch layer). No
//! alignment requirements; no i32 index limits (gathers use usize
//! pointer arithmetic).
//!
//! Note `vmulq_f32` + `vaddq_f32` are used separately — never `vfmaq` —
//! because the scalar ground truth rounds after the multiply and after
//! the add; a fused multiply-add would break bit parity.

use std::arch::aarch64::*;

use super::walk::{self, Lanes};
use crate::pack::{Packed34, PackedI2S, PackedTl2};

#[derive(Clone, Copy)]
pub(crate) struct Neon;

impl Lanes for Neon {
    const W: usize = 4;
    type V = float32x4_t;

    #[inline(always)]
    unsafe fn zero() -> float32x4_t {
        vdupq_n_f32(0.0)
    }

    #[inline(always)]
    unsafe fn splat(x: f32) -> float32x4_t {
        vdupq_n_f32(x)
    }

    #[inline(always)]
    unsafe fn gather(base: *const f32, stride: usize, off: usize) -> float32x4_t {
        let p = base.add(off);
        let t = [*p, *p.add(stride), *p.add(2 * stride), *p.add(3 * stride)];
        vld1q_f32(t.as_ptr())
    }

    #[inline(always)]
    unsafe fn gather_at(base: *const f32, off: &[i32; super::MAX_LANES]) -> float32x4_t {
        // No hardware gather: four scalar loads assembled into a register
        // (same shape as `gather`, but per-lane offsets).
        let t = [
            *base.add(off[0] as usize),
            *base.add(off[1] as usize),
            *base.add(off[2] as usize),
            *base.add(off[3] as usize),
        ];
        vld1q_f32(t.as_ptr())
    }

    #[inline(always)]
    unsafe fn xor_sign(v: float32x4_t, sign_bit: u32) -> float32x4_t {
        vreinterpretq_f32_u32(veorq_u32(vreinterpretq_u32_f32(v), vdupq_n_u32(sign_bit)))
    }

    #[inline(always)]
    unsafe fn add(a: float32x4_t, b: float32x4_t) -> float32x4_t {
        vaddq_f32(a, b)
    }

    #[inline(always)]
    unsafe fn mul(a: float32x4_t, b: float32x4_t) -> float32x4_t {
        vmulq_f32(a, b)
    }

    #[inline(always)]
    unsafe fn store(v: float32x4_t, dst: &mut [f32]) {
        debug_assert!(dst.len() >= 4);
        vst1q_f32(dst.as_mut_ptr(), v);
    }

    type I = int32x4_t;

    #[inline(always)]
    unsafe fn izero() -> int32x4_t {
        vdupq_n_s32(0)
    }

    #[inline(always)]
    unsafe fn imac(acc: int32x4_t, w: i32, v: *const i8) -> int32x4_t {
        // Exactly 4 V bytes via an unaligned 4-byte read — `vld1_s8`
        // would read 8 and could overrun the plane at the last chunk —
        // widened s8 → s16 → s32, then MAC by the broadcast weight.
        let bytes = (v as *const u32).read_unaligned();
        let v8 = vcreate_s8(bytes as u64);
        let v32 = vmovl_s16(vget_low_s16(vmovl_s8(v8)));
        vmlaq_s32(acc, v32, vdupq_n_s32(w))
    }

    #[inline(always)]
    unsafe fn istore(acc: int32x4_t, dst: &mut [i32]) {
        debug_assert!(dst.len() >= 4);
        vst1q_s32(dst.as_mut_ptr(), acc);
    }
}

/// i8×i8 dot, i32-accumulated: 16 bytes/iter widened through i16 products
/// (`smull`/`smull2`) then pairwise-accumulated into i32 (`sadalp`), tail
/// scalar. Exactly equal to the scalar iterator sum — integer addition is
/// associative.
///
/// # Safety
///
/// NEON available; `a.len() == b.len()`.
#[target_feature(enable = "neon")]
pub(crate) unsafe fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let mut acc = vdupq_n_s32(0);
    let mut i = 0usize;
    while i + 16 <= n {
        let va = vld1q_s8(a.as_ptr().add(i));
        let vb = vld1q_s8(b.as_ptr().add(i));
        let lo = vmull_s8(vget_low_s8(va), vget_low_s8(vb));
        let hi = vmull_high_s8(va, vb);
        acc = vpadalq_s16(acc, lo);
        acc = vpadalq_s16(acc, hi);
        i += 16;
    }
    let mut total = vaddvq_s32(acc);
    while i < n {
        total = total.wrapping_add(a[i] as i32 * b[i] as i32);
        i += 1;
    }
    total
}

/// # Safety
///
/// NEON available; `lut::gemm_pack34_preluts` bounds (asserted by the
/// dispatch layer).
#[target_feature(enable = "neon")]
pub(crate) unsafe fn gemm_pack34(
    p: &Packed34,
    luts: &[f32],
    lut_stride: usize,
    batch: usize,
    j0: usize,
    j1: usize,
    out: &mut [f32],
) {
    walk::gemm_pack34::<Neon>(p, luts, lut_stride, batch, j0, j1, out)
}

/// # Safety
///
/// NEON available; `lut::gemm_tl2_preluts` bounds.
#[target_feature(enable = "neon")]
pub(crate) unsafe fn gemm_tl2(
    p: &PackedTl2,
    luts: &[f32],
    lut_stride: usize,
    batch: usize,
    j0: usize,
    j1: usize,
    out: &mut [f32],
) {
    walk::gemm_tl2::<Neon>(p, luts, lut_stride, batch, j0, j1, out)
}

/// # Safety
///
/// NEON available; `lut::qk_lut34_rows` bounds (asserted by the dispatch
/// layer).
#[target_feature(enable = "neon")]
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn qk_lut34_rows(
    idx: &[u8],
    sign: &[u8],
    idx_bh: usize,
    sign_bh: usize,
    nb: usize,
    head: usize,
    n_heads: usize,
    luts: &[f32],
    rows: usize,
    out: &mut [f32],
) {
    walk::qk_lut34_rows::<Neon>(idx, sign, idx_bh, sign_bh, nb, head, n_heads, luts, rows, out)
}

/// # Safety
///
/// NEON available; `av_i8_rows` bounds (asserted by the dispatch layer).
#[target_feature(enable = "neon")]
pub(crate) unsafe fn av_i8_rows(
    weights: &[u8],
    v: &[i8],
    d: usize,
    col0: usize,
    hd: usize,
    rows: usize,
    out: &mut [i32],
) {
    walk::av_i8_rows::<Neon>(weights, v, d, col0, hd, rows, out)
}

/// # Safety
///
/// NEON available; `lut::gemm_i2s` bounds.
#[target_feature(enable = "neon")]
pub(crate) unsafe fn gemm_i2s(
    p: &PackedI2S,
    xs: &[f32],
    batch: usize,
    j0: usize,
    j1: usize,
    out: &mut [f32],
) {
    walk::gemm_i2s::<Neon>(p, xs, batch, j0, j1, out)
}
