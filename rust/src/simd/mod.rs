//! Runtime-dispatched SIMD kernels for the four hottest inner loops:
//!
//! 1. the fused **i8×i8 q·k dot** in the page-blocked attention walk
//!    (`engine::model::attention_blocked`) — an i32-accumulated dot over
//!    raw int8 page bytes, one scale multiply per page-head;
//! 2. the **LUT-GEMM tile walk** (`engine::lut`) — LUT gather + f32
//!    accumulate over packed weight planes, for all three pack formats
//!    (Sherry 3:4, TL2, I2_S);
//! 3. the **ternary-KV q·k LUT walk** ([`qk_lut34_rows`]) — per-query
//!    32-entry tables indexed by packed 1.25-bit K page codes, one
//!    gather + add per (block, W rows), never dequantizing K;
//! 4. the **fixed-point a·V accumulation** ([`av_i8_rows`]) — u8-quantized
//!    softmax weights times raw int8 V page bytes, i32-accumulated across
//!    head channels, one `s_a·s_v` scale multiply per page-head, never
//!    dequantizing V.
//!
//! ## Dispatch model
//!
//! An [`Isa`] is picked **once** per process: the `SHERRY_KERNEL_ISA`
//! environment variable (used by the CI matrix, where tests cannot take
//! CLI flags) or the `--kernel-isa` binary flag pins it; otherwise
//! [`Isa::detect`] probes the host via
//! `std::arch::is_x86_feature_detected!` / `is_aarch64_feature_detected!`.
//! The chosen ISA is cached in a `OnceLock` ([`active`]) and surfaced in
//! the serving metrics report and bench JSON so every measurement records
//! which path ran.
//!
//! Scalar code (the `engine::lut` kernels and a plain iterator dot) is the
//! always-available fallback and the **ground truth**: every vector path
//! is bit-for-bit identical to it (hard equality, not a tolerance — see
//! DESIGN.md §5 for why). The `*_with` entry points take an explicit
//! [`Isa`] so parity tests can compare paths without touching the
//! process-global selection.
//!
//! ## Safety architecture
//!
//! `unsafe` is confined to the leaf kernels in [`avx2`] / [`neon`]: a safe
//! generic walk ([`walk`]) is written once against the [`walk::Lanes`]
//! trait, and each arch module provides `#[target_feature]` wrappers that
//! monomorphize it. Dispatch arms are guarded by *both* a
//! `#[cfg(target_arch)]` gate and a runtime [`Isa::available`] check, so
//! calling any public function here with any `Isa` value on any host is
//! sound — an unavailable ISA silently degrades to scalar (which is
//! bit-identical anyway).

use crate::engine::lut;
use crate::pack::{Packed34, PackedI2S, PackedTl2};
use std::sync::OnceLock;

#[cfg(target_arch = "x86_64")]
mod avx2;
#[cfg(target_arch = "aarch64")]
mod neon;
pub(crate) mod walk;

/// Widest lane count of any vector path (AVX2: 8 × f32). Row chunking in
/// [`walk`] and scratch sizing use this as the compile-time upper bound.
pub const MAX_LANES: usize = 8;

/// A kernel instruction-set path. `Scalar` is always available; the
/// vector variants exist on every build (so `--kernel-isa avx2` parses
/// everywhere and fails with a clear message) but are only *selectable*
/// where [`Isa::available`] says so.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Isa {
    /// Portable scalar kernels (`engine::lut` + iterator dot) — the
    /// bit-exact ground truth.
    Scalar,
    /// x86-64 AVX2: 8×f32 LUT gathers (`vgatherdps`), `vpmaddwd` i8 dot.
    Avx2,
    /// AArch64 NEON: 4×f32 lanes, `smull`/`sadalp` widening i8 dot.
    Neon,
}

impl Isa {
    /// Every variant, in detection-preference order (widest first).
    pub const ALL: [Isa; 3] = [Isa::Avx2, Isa::Neon, Isa::Scalar];

    /// Stable lowercase name (CLI values, metrics report, bench JSON).
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2 => "avx2",
            Isa::Neon => "neon",
        }
    }

    /// Parse a fixed ISA name (`auto` is handled by [`select`]).
    pub fn parse(s: &str) -> Option<Isa> {
        match s {
            "scalar" => Some(Isa::Scalar),
            "avx2" => Some(Isa::Avx2),
            "neon" => Some(Isa::Neon),
            _ => None,
        }
    }

    /// Can this path actually execute on the running host? Compile-time
    /// arch gate + runtime feature probe (the probe result is cached by
    /// std, so this is cheap enough for per-call dispatch guards).
    pub fn available(self) -> bool {
        match self {
            Isa::Scalar => true,
            Isa::Avx2 => avx2_available(),
            Isa::Neon => neon_available(),
        }
    }

    /// Best available path on this host: AVX2 > NEON > scalar.
    pub fn detect() -> Isa {
        *Isa::ALL.iter().find(|isa| isa.available()).expect("Scalar is always available")
    }
}

fn avx2_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_feature_detected!("avx2")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

fn neon_available() -> bool {
    #[cfg(target_arch = "aarch64")]
    {
        std::arch::is_aarch64_feature_detected!("neon")
    }
    #[cfg(not(target_arch = "aarch64"))]
    {
        false
    }
}

/// The process-wide ISA, pinned on first use.
static ACTIVE: OnceLock<Isa> = OnceLock::new();

/// Resolve an ISA request string: `auto` detects; a fixed name must name
/// a path the host can run.
fn resolve_request(s: &str) -> Result<Isa, String> {
    if s == "auto" {
        return Ok(Isa::detect());
    }
    let isa = Isa::parse(s)
        .ok_or_else(|| format!("unknown kernel ISA {s:?} (expected auto|scalar|avx2|neon)"))?;
    if !isa.available() {
        return Err(format!("kernel ISA {s:?} is not available on this host"));
    }
    Ok(isa)
}

fn resolve_default() -> Isa {
    match std::env::var("SHERRY_KERNEL_ISA") {
        Ok(s) => match resolve_request(&s) {
            Ok(isa) => isa,
            Err(e) => {
                eprintln!("[simd] SHERRY_KERNEL_ISA ignored: {e}; detecting");
                Isa::detect()
            }
        },
        Err(_) => Isa::detect(),
    }
}

/// The process-wide kernel ISA. First call pins it: `SHERRY_KERNEL_ISA`
/// if set (invalid values warn and fall back to detection), else
/// [`Isa::detect`]. Hot paths hoist this out of their inner loops.
pub fn active() -> Isa {
    *ACTIVE.get_or_init(resolve_default)
}

/// Pin the process ISA from a CLI request (`--kernel-isa`). Errors if the
/// name is unknown, the path is unavailable on this host, or a
/// *different* ISA was already pinned (selection happens once at
/// startup; re-selecting the same one is fine).
pub fn select(name: &str) -> Result<Isa, String> {
    let want = resolve_request(name)?;
    let got = *ACTIVE.get_or_init(|| want);
    if got != want {
        return Err(format!(
            "kernel ISA already pinned to {} (selection happens once at startup)",
            got.name()
        ));
    }
    Ok(got)
}

// ---------------------------------------------------------------------------
// i8×i8 dot
// ---------------------------------------------------------------------------

/// Scalar i8×i8 dot with i32 accumulation — the ground-truth loop the
/// attention score pass ran before dispatch existed.
#[inline]
pub fn dot_i8_scalar(a: &[i8], b: &[i8]) -> i32 {
    a.iter().zip(b.iter()).map(|(&x, &y)| x as i32 * y as i32).sum()
}

/// i8×i8 dot through the pinned process ISA.
#[inline]
pub fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    dot_i8_with(active(), a, b)
}

/// i8×i8 dot through an explicit ISA (parity tests; hot loops that hoist
/// [`active`]). i32 addition is associative, so any lane arrangement is
/// *exactly* equal to scalar. Only `min(a.len(), b.len())` elements
/// contribute (the scalar zip contract).
#[inline]
pub fn dot_i8_with(isa: Isa, a: &[i8], b: &[i8]) -> i32 {
    let n = a.len().min(b.len());
    let (a, b) = (&a[..n], &b[..n]);
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: the arm only runs when the host reports AVX2.
        Isa::Avx2 if avx2_available() => unsafe { avx2::dot_i8(a, b) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: the arm only runs when the host reports NEON.
        Isa::Neon if neon_available() => unsafe { neon::dot_i8(a, b) },
        _ => dot_i8_scalar(a, b),
    }
}

// ---------------------------------------------------------------------------
// LUT-GEMM tile walks
// ---------------------------------------------------------------------------

/// AVX2 gathers index with i32 lanes (`lane·stride` must fit); absurdly
/// wide strides fall back to scalar rather than overflow. (Referenced
/// only by x86 dispatch arms outside of tests, hence the allow.)
#[cfg_attr(not(target_arch = "x86_64"), allow(dead_code))]
fn gather_stride_ok(stride: usize) -> bool {
    stride.checked_mul(MAX_LANES - 1).is_some_and(|v| v <= i32::MAX as usize)
}

/// Batched Sherry 3:4 accumulate phase through the pinned process ISA.
/// Drop-in for [`lut::gemm_pack34_preluts`] (same layout contract).
#[inline]
pub fn gemm_pack34_preluts(
    p: &Packed34,
    luts: &[f32],
    lut_stride: usize,
    batch: usize,
    j0: usize,
    j1: usize,
    out: &mut [f32],
) {
    gemm_pack34_preluts_with(active(), p, luts, lut_stride, batch, j0, j1, out);
}

/// [`gemm_pack34_preluts`] through an explicit ISA (parity tests).
pub fn gemm_pack34_preluts_with(
    isa: Isa,
    p: &Packed34,
    luts: &[f32],
    lut_stride: usize,
    batch: usize,
    j0: usize,
    j1: usize,
    out: &mut [f32],
) {
    // Mirror the scalar kernel's contract up front: the unsafe gathers
    // below rely on exactly these bounds.
    let nb = p.n_blocks();
    assert!(j0 <= j1 && j1 <= p.d_out);
    assert_eq!(out.len(), batch * (j1 - j0));
    assert!(lut_stride >= nb * 16, "LUT stride too small for d_in");
    assert!(luts.len() >= batch * lut_stride);
    // One span per tile range (workers call this per output-channel
    // tile); below `--trace kernels` it costs one relaxed atomic load.
    let _k = crate::obs::KernelSpan::enter(crate::obs::Kernel::GemmPack34);
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: host reports AVX2; bounds asserted above; stride fits
        // the gather's i32 index lanes.
        Isa::Avx2 if avx2_available() && gather_stride_ok(lut_stride) => unsafe {
            avx2::gemm_pack34(p, luts, lut_stride, batch, j0, j1, out)
        },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: host reports NEON; bounds asserted above.
        Isa::Neon if neon_available() => unsafe {
            neon::gemm_pack34(p, luts, lut_stride, batch, j0, j1, out)
        },
        _ => lut::gemm_pack34_preluts(p, luts, lut_stride, batch, j0, j1, out),
    }
}

/// Batched TL2 accumulate phase through the pinned process ISA.
/// Drop-in for [`lut::gemm_tl2_preluts`].
#[inline]
pub fn gemm_tl2_preluts(
    p: &PackedTl2,
    luts: &[f32],
    lut_stride: usize,
    batch: usize,
    j0: usize,
    j1: usize,
    out: &mut [f32],
) {
    gemm_tl2_preluts_with(active(), p, luts, lut_stride, batch, j0, j1, out);
}

/// [`gemm_tl2_preluts`] through an explicit ISA (parity tests).
pub fn gemm_tl2_preluts_with(
    isa: Isa,
    p: &PackedTl2,
    luts: &[f32],
    lut_stride: usize,
    batch: usize,
    j0: usize,
    j1: usize,
    out: &mut [f32],
) {
    let ng = p.n_groups();
    assert!(j0 <= j1 && j1 <= p.d_out);
    assert_eq!(out.len(), batch * (j1 - j0));
    assert!(lut_stride >= ng * lut::TL2_LUT_STRIDE, "LUT stride too small for d_in");
    assert!(luts.len() >= batch * lut_stride);
    let _k = crate::obs::KernelSpan::enter(crate::obs::Kernel::GemmTl2);
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: host reports AVX2; bounds asserted above; stride fits
        // the gather's i32 index lanes.
        Isa::Avx2 if avx2_available() && gather_stride_ok(lut_stride) => unsafe {
            avx2::gemm_tl2(p, luts, lut_stride, batch, j0, j1, out)
        },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: host reports NEON; bounds asserted above.
        Isa::Neon if neon_available() => unsafe {
            neon::gemm_tl2(p, luts, lut_stride, batch, j0, j1, out)
        },
        _ => lut::gemm_tl2_preluts(p, luts, lut_stride, batch, j0, j1, out),
    }
}

/// Batched I2_S decode-and-add through the pinned process ISA. Drop-in
/// for [`lut::gemm_i2s`].
#[inline]
pub fn gemm_i2s(p: &PackedI2S, xs: &[f32], batch: usize, j0: usize, j1: usize, out: &mut [f32]) {
    gemm_i2s_with(active(), p, xs, batch, j0, j1, out);
}

/// [`gemm_i2s`] through an explicit ISA (parity tests).
pub fn gemm_i2s_with(
    isa: Isa,
    p: &PackedI2S,
    xs: &[f32],
    batch: usize,
    j0: usize,
    j1: usize,
    out: &mut [f32],
) {
    let d_in = p.d_in;
    assert!(j0 <= j1 && j1 <= p.d_out);
    assert_eq!(xs.len(), batch * d_in);
    assert_eq!(out.len(), batch * (j1 - j0));
    let _k = crate::obs::KernelSpan::enter(crate::obs::Kernel::GemmI2S);
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: host reports AVX2; bounds asserted above; activation
        // rows are gathered at stride d_in, which must fit i32 lanes.
        Isa::Avx2 if avx2_available() && gather_stride_ok(d_in) => unsafe {
            avx2::gemm_i2s(p, xs, batch, j0, j1, out)
        },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: host reports NEON; bounds asserted above.
        Isa::Neon if neon_available() => unsafe { neon::gemm_i2s(p, xs, batch, j0, j1, out) },
        _ => lut::gemm_i2s(p, xs, batch, j0, j1, out),
    }
}

// ---------------------------------------------------------------------------
// Ternary-KV q·k LUT walk
// ---------------------------------------------------------------------------

/// Per-query LUT walk over one head of a packed 3:4-ternary K plane
/// through the pinned process ISA. Drop-in for [`lut::qk_lut34_rows`]
/// (same layout contract: `TernaryBlock` planes, [`lut::build_qk_luts34`]
/// tables, raw integer sums into `out[..rows]`).
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn qk_lut34_rows(
    idx: &[u8],
    sign: &[u8],
    idx_bh: usize,
    sign_bh: usize,
    nb: usize,
    head: usize,
    n_heads: usize,
    luts: &[f32],
    rows: usize,
    out: &mut [f32],
) {
    qk_lut34_rows_with(active(), idx, sign, idx_bh, sign_bh, nb, head, n_heads, luts, rows, out);
}

/// [`qk_lut34_rows`] through an explicit ISA (parity tests; hot loops
/// that hoist [`active`]).
#[allow(clippy::too_many_arguments)]
pub fn qk_lut34_rows_with(
    isa: Isa,
    idx: &[u8],
    sign: &[u8],
    idx_bh: usize,
    sign_bh: usize,
    nb: usize,
    head: usize,
    n_heads: usize,
    luts: &[f32],
    rows: usize,
    out: &mut [f32],
) {
    // Mirror the scalar kernel's contract up front: the unsafe gathers
    // below rely on exactly these bounds. Per-lane gather offsets are
    // < nb·32 within the head's table, which the LUT-length assert keeps
    // in bounds for every head < n_heads.
    assert!(head < n_heads, "head {head} out of range for {n_heads} heads");
    assert!(nb <= idx_bh * 2 && nb <= sign_bh * 8, "head lane bytes too small for {nb} blocks");
    assert!(idx.len() >= rows * n_heads * idx_bh, "idx plane too short");
    assert!(sign.len() >= rows * n_heads * sign_bh, "sign plane too short");
    assert!(luts.len() >= n_heads * nb * 32, "q·k LUTs too short");
    assert!(out.len() >= rows, "output row buffer too short");
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: host reports AVX2; bounds asserted above.
        Isa::Avx2 if avx2_available() => unsafe {
            avx2::qk_lut34_rows(idx, sign, idx_bh, sign_bh, nb, head, n_heads, luts, rows, out)
        },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: host reports NEON; bounds asserted above.
        Isa::Neon if neon_available() => unsafe {
            neon::qk_lut34_rows(idx, sign, idx_bh, sign_bh, nb, head, n_heads, luts, rows, out)
        },
        _ => lut::qk_lut34_rows(idx, sign, idx_bh, sign_bh, nb, head, n_heads, luts, rows, out),
    }
}

// ---------------------------------------------------------------------------
// Fixed-point a·V accumulation
// ---------------------------------------------------------------------------

/// Scalar fixed-point a·V accumulation — the ground truth: `out[c] =
/// Σ_r weights[r] · v[r·d + col0 + c]` for `c < hd`, exactly in i32,
/// over the first `rows` rows of an int8 V page block of row stride
/// `d`. `weights[r]` is one softmax weight quantized to `[0, 127]`
/// (see `engine::model::attention_blocked`); `col0 = head · head_dim`
/// selects the head's channel window. Products are ≤ 127·128 and page
/// row counts are small, so i32 never wraps; zero weights are skipped,
/// which no arrangement of exact integer adds can observe.
pub fn av_i8_rows_scalar(
    weights: &[u8],
    v: &[i8],
    d: usize,
    col0: usize,
    hd: usize,
    rows: usize,
    out: &mut [i32],
) {
    out[..hd].fill(0);
    for r in 0..rows {
        let w = weights[r] as i32;
        if w == 0 {
            continue;
        }
        let vrow = &v[r * d + col0..r * d + col0 + hd];
        for (o, &x) in out[..hd].iter_mut().zip(vrow) {
            *o += w * x as i32;
        }
    }
}

/// Fixed-point a·V accumulation over one head of an int8 V page block
/// through the pinned process ISA. See [`av_i8_rows_scalar`] for the
/// layout contract.
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn av_i8_rows(
    weights: &[u8],
    v: &[i8],
    d: usize,
    col0: usize,
    hd: usize,
    rows: usize,
    out: &mut [i32],
) {
    av_i8_rows_with(active(), weights, v, d, col0, hd, rows, out);
}

/// [`av_i8_rows`] through an explicit ISA (parity tests; hot loops that
/// hoist [`active`]). All paths accumulate in i32 — exact — so every
/// ISA is bit-for-bit the scalar reference.
#[allow(clippy::too_many_arguments)]
pub fn av_i8_rows_with(
    isa: Isa,
    weights: &[u8],
    v: &[i8],
    d: usize,
    col0: usize,
    hd: usize,
    rows: usize,
    out: &mut [i32],
) {
    // Mirror the scalar kernel's contract up front: the unsafe loads
    // below rely on exactly these bounds.
    assert!(col0 + hd <= d, "head window [{col0}, {}) exceeds row stride {d}", col0 + hd);
    assert!(weights.len() >= rows, "weight row buffer too short");
    assert!(rows == 0 || v.len() >= (rows - 1) * d + col0 + hd, "V plane too short");
    assert!(out.len() >= hd, "output channel buffer too short");
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: host reports AVX2; bounds asserted above.
        Isa::Avx2 if avx2_available() => unsafe {
            avx2::av_i8_rows(weights, v, d, col0, hd, rows, out)
        },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: host reports NEON; bounds asserted above.
        Isa::Neon if neon_available() => unsafe {
            neon::av_i8_rows(weights, v, d, col0, hd, rows, out)
        },
        _ => av_i8_rows_scalar(weights, v, d, col0, hd, rows, out),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_always_available_and_detect_returns_available() {
        assert!(Isa::Scalar.available());
        assert!(Isa::detect().available());
    }

    #[test]
    fn parse_roundtrips_names() {
        for isa in Isa::ALL {
            assert_eq!(Isa::parse(isa.name()), Some(isa));
        }
        assert_eq!(Isa::parse("auto"), None, "auto is a select() concept, not an Isa");
        assert_eq!(Isa::parse("sse9"), None);
    }

    #[test]
    fn resolve_rejects_unknown_and_auto_detects() {
        assert!(resolve_request("wombat").is_err());
        assert_eq!(resolve_request("auto").unwrap(), Isa::detect());
        // Scalar is resolvable on every host.
        assert_eq!(resolve_request("scalar").unwrap(), Isa::Scalar);
    }

    #[test]
    fn active_is_stable_and_select_agrees_with_it() {
        // Other tests in the process may already have pinned the ISA;
        // only invariants that hold regardless are asserted here.
        let a = active();
        assert!(a.available());
        assert_eq!(active(), a, "OnceLock pins the first selection");
        assert_eq!(select(a.name()).unwrap(), a, "re-selecting the pinned ISA is fine");
        assert!(select("not-an-isa").is_err());
    }

    #[test]
    fn dot_dispatch_matches_scalar_on_every_available_isa() {
        let a: Vec<i8> = (0..133).map(|i| ((i * 37 + 11) % 255 - 127) as i8).collect();
        let b: Vec<i8> = (0..133).map(|i| ((i * 91 + 3) % 255 - 127) as i8).collect();
        for isa in Isa::ALL.into_iter().filter(|i| i.available()) {
            assert_eq!(dot_i8_with(isa, &a, &b), dot_i8_scalar(&a, &b), "{}", isa.name());
        }
        // Unavailable ISAs degrade to scalar rather than faulting.
        for isa in Isa::ALL.into_iter().filter(|i| !i.available()) {
            assert_eq!(dot_i8_with(isa, &a, &b), dot_i8_scalar(&a, &b), "{}", isa.name());
        }
    }

    #[test]
    fn qk_lut34_dispatch_is_bit_identical_to_scalar_on_every_isa() {
        // Synthetic packed K plane in the TernaryBlock layout; row count
        // is deliberately not a multiple of any lane width so both the
        // chunked path and the scalar tail run.
        let (rows, nh, hd) = (13usize, 2usize, 16usize);
        let nb = hd / 4;
        let (idx_bh, sign_bh) = (nb.div_ceil(2), nb.div_ceil(8));
        let mut idx = vec![0u8; rows * nh * idx_bh];
        let mut sign = vec![0u8; rows * nh * sign_bh];
        for r in 0..rows {
            for h in 0..nh {
                let lane = r * nh + h;
                for b in 0..nb {
                    let code = ((r * 11 + h * 5 + b * 3) % 16) as u8;
                    idx[lane * idx_bh + b / 2] |= code << ((b % 2) * 4);
                    sign[lane * sign_bh + b / 8] |= (((r + h + b) % 2) as u8) << (b % 8);
                }
            }
        }
        let q: Vec<i8> = (0..nh * hd).map(|i| ((i * 53 + 29) % 255 - 127) as i8).collect();
        let mut luts = vec![0.0f32; nh * nb * 32];
        lut::build_qk_luts34(&q, hd, nh, &mut luts);
        for head in 0..nh {
            let mut want = vec![0.0f32; rows];
            lut::qk_lut34_rows(&idx, &sign, idx_bh, sign_bh, nb, head, nh, &luts, rows, &mut want);
            for isa in Isa::ALL {
                let mut got = vec![f32::NAN; rows];
                qk_lut34_rows_with(
                    isa, &idx, &sign, idx_bh, sign_bh, nb, head, nh, &luts, rows, &mut got,
                );
                for (r, (g, w)) in got.iter().zip(&want).enumerate() {
                    assert_eq!(
                        g.to_bits(),
                        w.to_bits(),
                        "{} head {head} row {r}: {g} vs {w}",
                        isa.name()
                    );
                }
            }
        }
    }

    #[test]
    fn av_i8_dispatch_is_bit_identical_to_scalar_on_every_isa() {
        // Synthetic V page block: head_dim 19 exercises both the chunked
        // path and the channel tail on every lane width (19 = 2·8+3 =
        // 4·4+3); rows 13 is a partial page; weights include zeros (the
        // skip path) and the extremes 1 and 127.
        let (rows, nh, hd) = (13usize, 2usize, 19usize);
        let d = nh * hd;
        let v: Vec<i8> = (0..rows * d).map(|i| ((i * 37 + 11) % 255 - 127) as i8).collect();
        let weights: Vec<u8> =
            (0..rows).map(|r| [0u8, 1, 64, 127, 3, 0, 99][r % 7]).collect();
        for col0 in [0, hd] {
            let mut want = vec![0i32; hd];
            av_i8_rows_scalar(&weights, &v, d, col0, hd, rows, &mut want);
            for isa in Isa::ALL {
                for r in [rows, 1, 0] {
                    let mut got = vec![i32::MIN; hd];
                    av_i8_rows_with(isa, &weights, &v, d, col0, hd, r, &mut got);
                    let mut w = vec![0i32; hd];
                    av_i8_rows_scalar(&weights, &v, d, col0, hd, r, &mut w);
                    assert_eq!(got, w, "{} col0 {col0} rows {r}", isa.name());
                }
            }
            assert_ne!(want, vec![0i32; hd], "nonzero fixture sanity");
        }
    }

    #[test]
    fn gather_stride_guard() {
        assert!(gather_stride_ok(0));
        assert!(gather_stride_ok(51_200)); // d=3200 pack34 LUT stride
        assert!(!gather_stride_ok(usize::MAX / 2));
    }
}
