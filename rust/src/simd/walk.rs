//! The vectorized LUT-GEMM tile walks, written **once** as generic code
//! over the [`Lanes`] trait and monomorphized per arch by the leaf
//! wrappers in `simd::avx2` / `simd::neon`.
//!
//! ## Why parity with scalar is exact
//!
//! The scalar kernels (`engine::lut`) accumulate f32, and f32 addition is
//! **not** associative — so these walks never reassociate. They vectorize
//! across the **batch dimension** instead: a chunk of exactly `Lanes::W`
//! activation rows advances through the packed weight plane in the same
//! order as scalar, with lane `i` receiving exactly the operands scalar
//! row `i` receives, in the same sequence. IEEE arithmetic is performed
//! per lane, so every lane's result is bit-identical to its scalar row.
//! Rows past the last full chunk (`batch % W`) are handled by *calling
//! the scalar kernel* on the remaining region — parity there is
//! tautological. The scalar kernels stay untouched as ground truth.
//!
//! The ternary-KV q·k walk ([`qk_lut34_rows`]) vectorizes across **K
//! rows** instead of batch rows and leans on a stronger invariant: its
//! LUT entries are integer-valued f32s whose sums stay ≪ 2²⁴, so f32
//! accumulation is exact in any order and parity is structural.
//!
//! The fixed-point a·V walk ([`av_i8_rows`]) vectorizes across **head
//! channels** and accumulates in i32, which is exact — parity with
//! scalar is structural for any lane arrangement, like the i8 dot.
//!
//! ## Safety contract (shared by every `unsafe fn` here)
//!
//! Callers (the dispatch layer in `simd::mod`) must ensure:
//! * the target feature backing `L` is available on the host (the walks
//!   are only reachable through `#[target_feature]` wrappers guarded by
//!   runtime detection);
//! * the slice-length preconditions of the matching scalar kernel hold
//!   (asserted by the dispatch layer before entry);
//! * for gather-by-i32-index implementations ([`Lanes::gather`]),
//!   `(W-1) * stride` fits in `i32` (the `gather_stride_ok` guard).
//!
//! No alignment is required: all vector loads/stores are unaligned, and
//! gathers address individual f32s.

use crate::engine::lut;
use crate::pack::{Packed34, PackedI2S, PackedTl2};

use super::MAX_LANES;

/// One SIMD register of `W` f32 lanes plus the operations the tile walks
/// need. Implementations are thin intrinsic wrappers, `#[inline(always)]`
/// so they fuse into the `#[target_feature]` leaf that monomorphizes the
/// walk.
///
/// # Safety
///
/// Every method may only be called when the backing target feature is
/// available (see module docs); `gather` additionally requires
/// `base[i * stride + off]` in bounds for all `i < W`, and `store`
/// requires `dst.len() >= W`.
pub(crate) trait Lanes: Copy {
    /// Lane count (8 for AVX2, 4 for NEON). Must be ≤ [`MAX_LANES`].
    const W: usize;
    /// The register type.
    type V: Copy;

    unsafe fn zero() -> Self::V;
    unsafe fn splat(x: f32) -> Self::V;
    /// Strided gather: lane `i` loads `base[i * stride + off]` — one f32
    /// from each of `W` consecutive LUT/activation rows.
    unsafe fn gather(base: *const f32, stride: usize, off: usize) -> Self::V;
    /// Per-lane indexed gather: lane `i` loads `base[off[i]]`. Unlike
    /// [`Lanes::gather`] each lane carries its own offset — the ternary
    /// q·k walk decodes `W` different K rows to `W` different LUT
    /// entries of one shared table. Requires `off[i] >= 0` and
    /// `base[off[i]]` in bounds for all `i < W` (lanes `W..MAX_LANES`
    /// are ignored).
    unsafe fn gather_at(base: *const f32, off: &[i32; MAX_LANES]) -> Self::V;
    /// XOR `sign_bit` (0 or `1 << 31`) into every lane's bit pattern —
    /// the branchless mirror-sign flip, applied to all rows at once.
    unsafe fn xor_sign(v: Self::V, sign_bit: u32) -> Self::V;
    unsafe fn add(a: Self::V, b: Self::V) -> Self::V;
    unsafe fn mul(a: Self::V, b: Self::V) -> Self::V;
    /// Write the `W` lanes to `dst[..W]` (unaligned).
    unsafe fn store(v: Self::V, dst: &mut [f32]);

    /// Integer accumulator register of `W` i32 lanes (the fixed-point
    /// a·V walk accumulates exactly in i32).
    type I: Copy;
    unsafe fn izero() -> Self::I;
    /// Widening MAC: lane `i` becomes `acc[i] + w · (v[i] as i32)` over
    /// `W` consecutive int8 V bytes at `v` (exactly `W` bytes are read).
    /// `w` is a softmax weight quantized to `[0, 127]`, so products are
    /// ≤ 127·128 and i32 sums stay exact at any page size.
    unsafe fn imac(acc: Self::I, w: i32, v: *const i8) -> Self::I;
    /// Write the `W` i32 lanes to `dst[..W]` (unaligned).
    unsafe fn istore(acc: Self::I, dst: &mut [i32]);
}

/// Sherry 3:4 walk for one chunk of exactly `L::W` rows. `luts` starts at
/// the chunk's first row; `out` is the chunk's `W × w` output region.
/// Mirrors `lut::gemm_pack34_preluts` statement for statement — lane `bi`
/// computes scalar's `acc[2*bi]` / `acc[2*bi+1]` pair.
///
/// # Safety
///
/// Module safety contract; additionally `luts.len() >= W * lut_stride`
/// and `out.len() == W * (j1 - j0)`.
#[inline(always)]
pub(crate) unsafe fn pack34_chunk<L: Lanes>(
    p: &Packed34,
    luts: &[f32],
    lut_stride: usize,
    j0: usize,
    j1: usize,
    out: &mut [f32],
) {
    let nb = p.n_blocks();
    let w = j1 - j0;
    debug_assert!(luts.len() >= L::W * lut_stride);
    debug_assert_eq!(out.len(), L::W * w);
    let full = nb / 8; // complete sign bytes
    const TILE_SB: usize = 16; // sign bytes per tile = 128 blocks
    out.fill(0.0);
    let base = luts.as_ptr();
    let mut sb0 = 0usize;
    while sb0 < full {
        let sb1 = (sb0 + TILE_SB).min(full);
        for (jj, j) in (j0..j1).enumerate() {
            let idx_plane = p.idx_plane(j);
            let sign_plane = p.sign_plane(j);
            let mut acc0 = L::zero();
            let mut acc1 = L::zero();
            for sb in sb0..sb1 {
                let signs = sign_plane[sb] as u32;
                let ibase = sb * 4;
                let lbase = sb * 8 * 16;
                for k in 0..4 {
                    let byte = idx_plane[ibase + k];
                    let lo = (byte & 0x0F) as usize;
                    let hi = (byte >> 4) as usize;
                    let b0 = 2 * k;
                    let o0 = lbase + b0 * 16 + lo;
                    let o1 = lbase + (b0 + 1) * 16 + hi;
                    let s0 = ((signs >> b0) & 1) << 31;
                    let s1 = ((signs >> (b0 + 1)) & 1) << 31;
                    acc0 = L::add(acc0, L::xor_sign(L::gather(base, lut_stride, o0), s0));
                    acc1 = L::add(acc1, L::xor_sign(L::gather(base, lut_stride, o1), s1));
                }
            }
            let (mut t0, mut t1) = ([0.0f32; MAX_LANES], [0.0f32; MAX_LANES]);
            L::store(acc0, &mut t0);
            L::store(acc1, &mut t1);
            for bi in 0..L::W {
                // Same two adds as scalar: (acc0 + acc1), then += out.
                out[bi * w + jj] += t0[bi] + t1[bi];
            }
        }
        sb0 = sb1;
    }
    // Tail blocks + final per-channel scale: exact scalar replica.
    for (jj, j) in (j0..j1).enumerate() {
        for bi in 0..L::W {
            let mut a = out[bi * w + jj];
            let row = &luts[bi * lut_stride..];
            for b in full * 8..nb {
                let v = row[b * 16 + p.idx_at(j, b) as usize];
                let s = (p.sign_at(j, b) as u32) << 31;
                a += f32::from_bits(v.to_bits() ^ s);
            }
            out[bi * w + jj] = a * p.alpha[j];
        }
    }
}

/// Full batched Sherry 3:4 walk: full `W`-row chunks through
/// [`pack34_chunk`], remaining rows through the scalar kernel.
///
/// # Safety
///
/// Module safety contract; scalar-kernel preconditions asserted by the
/// dispatch layer.
#[inline(always)]
pub(crate) unsafe fn gemm_pack34<L: Lanes>(
    p: &Packed34,
    luts: &[f32],
    lut_stride: usize,
    batch: usize,
    j0: usize,
    j1: usize,
    out: &mut [f32],
) {
    let w = j1 - j0;
    let mut r0 = 0usize;
    while r0 + L::W <= batch {
        pack34_chunk::<L>(
            p,
            &luts[r0 * lut_stride..],
            lut_stride,
            j0,
            j1,
            &mut out[r0 * w..(r0 + L::W) * w],
        );
        r0 += L::W;
    }
    if r0 < batch {
        lut::gemm_pack34_preluts(p, &luts[r0 * lut_stride..], lut_stride, batch - r0, j0, j1, &mut out[r0 * w..]);
    }
}

/// Ternary-KV q·k LUT walk over one head of a packed 3:4 K plane:
/// chunks of exactly `L::W` K rows advance block-by-block, each lane
/// decoding its own row's nibble index + mirror bit into an offset of
/// the head's 32-entry-per-block table ([`lut::build_qk_luts34`]) and
/// gathering its entry via [`Lanes::gather_at`]; the `W` per-row integer
/// sums accumulate in vector lanes. Table entries are integer-valued
/// f32s with exact sums, so the lanes are bit-identical to the scalar
/// walk ([`lut::qk_lut34_rows`]) regardless of accumulation order.
/// Rows past the last full chunk go through the scalar kernel.
///
/// # Safety
///
/// Module safety contract; `lut::qk_lut34_rows` bounds (asserted by the
/// dispatch layer): `idx.len() >= rows * n_heads * idx_bh`,
/// `sign.len() >= rows * n_heads * sign_bh`,
/// `luts.len() >= n_heads * nb * 32`, `out.len() >= rows`,
/// `head < n_heads`, and `nb` blocks fit the per-lane byte widths
/// (`nb <= 2*idx_bh`, `nb <= 8*sign_bh`).
#[inline(always)]
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn qk_lut34_rows<L: Lanes>(
    idx: &[u8],
    sign: &[u8],
    idx_bh: usize,
    sign_bh: usize,
    nb: usize,
    head: usize,
    n_heads: usize,
    luts: &[f32],
    rows: usize,
    out: &mut [f32],
) {
    let base = luts.as_ptr().add(head * nb * 32);
    let mut r0 = 0usize;
    while r0 + L::W <= rows {
        let mut acc = L::zero();
        for b in 0..nb {
            let mut off = [0i32; MAX_LANES];
            for (i, o) in off.iter_mut().enumerate().take(L::W) {
                let lane = (r0 + i) * n_heads + head;
                let nib = (idx[lane * idx_bh + b / 2] >> ((b % 2) * 4)) & 0x0F;
                let m = (sign[lane * sign_bh + b / 8] >> (b % 8)) & 1;
                *o = (b * 32 + (m as usize) * 16 + nib as usize) as i32;
            }
            acc = L::add(acc, L::gather_at(base, &off));
        }
        L::store(acc, &mut out[r0..]);
        r0 += L::W;
    }
    if r0 < rows {
        lut::qk_lut34_rows(
            &idx[r0 * n_heads * idx_bh..],
            &sign[r0 * n_heads * sign_bh..],
            idx_bh,
            sign_bh,
            nb,
            head,
            n_heads,
            luts,
            rows - r0,
            &mut out[r0..],
        );
    }
}

/// Fixed-point a·V accumulation over one head of an int8 V page block:
/// `out[c] = Σ_r weights[r] · v[r·d + col0 + c]` in exact i32
/// arithmetic. Vectorizes across **head channels** (`W` i32 lanes per
/// register), accumulating over rows; channels past the last full
/// vector go through the scalar kernel
/// ([`crate::simd::av_i8_rows_scalar`]). Integer addition is
/// associative, so every lane arrangement is bit-identical to scalar —
/// and zero weights may be skipped without changing any sum.
///
/// # Safety
///
/// Module safety contract; `av_i8_rows` bounds (asserted by the
/// dispatch layer): `col0 + hd <= d`, `weights.len() >= rows`,
/// `v.len() >= (rows-1)·d + col0 + hd` when `rows > 0`, and
/// `out.len() >= hd`.
#[inline(always)]
pub(crate) unsafe fn av_i8_rows<L: Lanes>(
    weights: &[u8],
    v: &[i8],
    d: usize,
    col0: usize,
    hd: usize,
    rows: usize,
    out: &mut [i32],
) {
    let base = v.as_ptr();
    let mut c0 = 0usize;
    while c0 + L::W <= hd {
        let mut acc = L::izero();
        for r in 0..rows {
            let w = weights[r] as i32;
            if w == 0 {
                continue;
            }
            acc = L::imac(acc, w, base.add(r * d + col0 + c0));
        }
        L::istore(acc, &mut out[c0..]);
        c0 += L::W;
    }
    if c0 < hd {
        super::av_i8_rows_scalar(weights, v, d, col0 + c0, hd - c0, rows, &mut out[c0..]);
    }
}

/// TL2 walk for one chunk of exactly `L::W` rows: the misaligned 5-bit
/// code extraction is done once (shared across lanes, exactly as scalar
/// shares it across the batch), then one gather + add per group.
///
/// # Safety
///
/// Module safety contract; `luts.len() >= W * lut_stride`,
/// `out.len() == W * (j1 - j0)`.
#[inline(always)]
pub(crate) unsafe fn tl2_chunk<L: Lanes>(
    p: &PackedTl2,
    luts: &[f32],
    lut_stride: usize,
    j0: usize,
    j1: usize,
    out: &mut [f32],
) {
    let ng = p.n_groups();
    let w = j1 - j0;
    debug_assert!(luts.len() >= L::W * lut_stride);
    debug_assert_eq!(out.len(), L::W * w);
    let base = luts.as_ptr();
    for (jj, j) in (j0..j1).enumerate() {
        let stream = p.stream(j);
        let mut acc = L::zero();
        let mut bit_off = 0usize;
        for g in 0..ng {
            let byte = bit_off / 8;
            let shift = bit_off % 8;
            let lo = stream[byte] as u16;
            let hi = if byte + 1 < stream.len() { stream[byte + 1] as u16 } else { 0 };
            let code = (((hi << 8) | lo) >> shift) as usize & 0x1F;
            let o = g * lut::TL2_LUT_STRIDE + code;
            acc = L::add(acc, L::gather(base, lut_stride, o));
            bit_off += 5;
        }
        let mut t = [0.0f32; MAX_LANES];
        L::store(acc, &mut t);
        for bi in 0..L::W {
            out[bi * w + jj] = t[bi] * p.alpha[j];
        }
    }
}

/// Full batched TL2 walk (chunks + scalar row tail).
///
/// # Safety
///
/// Module safety contract; scalar-kernel preconditions asserted by the
/// dispatch layer.
#[inline(always)]
pub(crate) unsafe fn gemm_tl2<L: Lanes>(
    p: &PackedTl2,
    luts: &[f32],
    lut_stride: usize,
    batch: usize,
    j0: usize,
    j1: usize,
    out: &mut [f32],
) {
    let w = j1 - j0;
    let mut r0 = 0usize;
    while r0 + L::W <= batch {
        tl2_chunk::<L>(
            p,
            &luts[r0 * lut_stride..],
            lut_stride,
            j0,
            j1,
            &mut out[r0 * w..(r0 + L::W) * w],
        );
        r0 += L::W;
    }
    if r0 < batch {
        lut::gemm_tl2_preluts(p, &luts[r0 * lut_stride..], lut_stride, batch - r0, j0, j1, &mut out[r0 * w..]);
    }
}

/// I2_S decode-and-add for one chunk of exactly `L::W` rows. The packed
/// byte is decoded to 4 ternary multipliers once (scalar table lookup,
/// shared across lanes); activations are gathered at stride `d_in`.
/// Product/sum order replicates scalar's
/// `m[0]*x[0] + m[1]*x[1] + m[2]*x[2] + m[3]*x[3]` left-to-right chain.
///
/// # Safety
///
/// Module safety contract; `xs.len() >= W * d_in`,
/// `out.len() == W * (j1 - j0)`.
#[inline(always)]
pub(crate) unsafe fn i2s_chunk<L: Lanes>(
    p: &PackedI2S,
    xs: &[f32],
    j0: usize,
    j1: usize,
    out: &mut [f32],
) {
    let d_in = p.d_in;
    let w = j1 - j0;
    debug_assert!(xs.len() >= L::W * d_in);
    debug_assert_eq!(out.len(), L::W * w);
    let full_bytes = d_in / 4;
    let pairs = full_bytes / 2;
    let base = xs.as_ptr();
    for (jj, j) in (j0..j1).enumerate() {
        let ch = p.channel(j);
        let mut acc0 = L::zero();
        let mut acc1 = L::zero();
        for bp in 0..pairs {
            let m0 = lut::i2s_multipliers(ch[2 * bp]);
            let m1 = lut::i2s_multipliers(ch[2 * bp + 1]);
            let xo = bp * 8;
            let t0 = L::add(
                L::add(
                    L::add(
                        L::mul(L::splat(m0[0]), L::gather(base, d_in, xo)),
                        L::mul(L::splat(m0[1]), L::gather(base, d_in, xo + 1)),
                    ),
                    L::mul(L::splat(m0[2]), L::gather(base, d_in, xo + 2)),
                ),
                L::mul(L::splat(m0[3]), L::gather(base, d_in, xo + 3)),
            );
            let t1 = L::add(
                L::add(
                    L::add(
                        L::mul(L::splat(m1[0]), L::gather(base, d_in, xo + 4)),
                        L::mul(L::splat(m1[1]), L::gather(base, d_in, xo + 5)),
                    ),
                    L::mul(L::splat(m1[2]), L::gather(base, d_in, xo + 6)),
                ),
                L::mul(L::splat(m1[3]), L::gather(base, d_in, xo + 7)),
            );
            acc0 = L::add(acc0, t0);
            acc1 = L::add(acc1, t1);
        }
        for i in pairs * 8..d_in {
            let m = lut::i2s_multipliers(ch[i / 4])[i % 4];
            acc0 = L::add(acc0, L::mul(L::splat(m), L::gather(base, d_in, i)));
        }
        let (mut t0, mut t1) = ([0.0f32; MAX_LANES], [0.0f32; MAX_LANES]);
        L::store(acc0, &mut t0);
        L::store(acc1, &mut t1);
        for bi in 0..L::W {
            out[bi * w + jj] = (t0[bi] + t1[bi]) * p.alpha[j];
        }
    }
}

/// Full batched I2_S walk (chunks + scalar row tail).
///
/// # Safety
///
/// Module safety contract; scalar-kernel preconditions asserted by the
/// dispatch layer.
#[inline(always)]
pub(crate) unsafe fn gemm_i2s<L: Lanes>(
    p: &PackedI2S,
    xs: &[f32],
    batch: usize,
    j0: usize,
    j1: usize,
    out: &mut [f32],
) {
    let d_in = p.d_in;
    let w = j1 - j0;
    let mut r0 = 0usize;
    while r0 + L::W <= batch {
        i2s_chunk::<L>(p, &xs[r0 * d_in..], j0, j1, &mut out[r0 * w..(r0 + L::W) * w]);
        r0 += L::W;
    }
    if r0 < batch {
        lut::gemm_i2s(p, &xs[r0 * d_in..], batch - r0, j0, j1, &mut out[r0 * w..]);
    }
}
