//! Block-table view: the engine's one window onto KV storage.
//!
//! [`TernaryModel::forward_kv`](crate::engine::TernaryModel::forward_kv)
//! appends and reads K/V exclusively through [`KvBatch`], so paged and
//! contiguous storage run the *same* model code. [`Rows`] exposes a
//! sequence's K (or V) history as **page blocks**, one per resident page
//! (the whole history is a single block for a contiguous cache), walked
//! in ascending position order, which is what keeps paged decode
//! bit-for-bit equal to the contiguous baseline (the contiguous path is
//! literally the degenerate single-block case). Three walks exist:
//!
//! * [`Rows::for_each_block`] — f32 tiles (`rows × d_model`): borrowed
//!   from the arena for f32 storage, served from the store's frozen-tile
//!   LRU for registration-frozen quantized pages, dequantized into
//!   caller scratch otherwise. The f32 score and V passes run on this.
//! * [`Rows::for_each_kblock`] — the score-pass walk: yields each page
//!   at the cheapest representation its store supports —
//!   [`KBlock::Ternary`] (raw pack34 planes + per-head absmean scales,
//!   LUT-walked without touching f32 K at all), [`KBlock::I8`] (raw
//!   int8 page bytes + per-head scales, dotted in i32), falling back to
//!   [`KBlock::F32`] tiles for f32 storage and contiguous caches.
//! * [`Rows::for_each_vblock`] — the V-pass walk: [`VBlock::I8`] raw
//!   int8 V bytes + per-head scales for quantized stores with the
//!   integer-a·V path enabled (attention accumulates a·V in i32 via
//!   `simd::av_i8_rows` — V is never dequantized), [`VBlock::F32`]
//!   tiles otherwise (f32 storage, contiguous caches, integer-V off —
//!   this fallback is the only remaining frozen-tile consumer).

use super::allocator::{BlockAllocator, PageId};
use super::store::{PageStore, Plane, TernaryBlock};
use super::table::BlockTable;
use crate::engine::KvCache;

/// One page block of a sequence's K history, at the cheapest
/// representation its store supports (see [`Rows::for_each_kblock`]).
pub enum KBlock<'a> {
    /// Dequantized (or natively-f32) `rows × d_model` tile.
    F32(&'a [f32]),
    /// Int8-native page block: `rows × d_model` raw bytes plus the
    /// page's `n_heads` per-head scales. Element `(r, h·head_dim + c)`
    /// dequantizes as `data[r·d + h·head_dim + c] as f32 * scales[h]`.
    I8 { data: &'a [i8], scales: &'a [f32] },
    /// Packed-ternary page block: raw pack34 index/sign lanes plus the
    /// page's per-head absmean scales ([`TernaryBlock`]). The score pass
    /// walks it through per-query 32-entry LUTs
    /// (`simd::qk_lut34_rows`) — K is never dequantized.
    Ternary(TernaryBlock<'a>),
}

/// One page block of a sequence's V history, at the cheapest
/// representation its store supports (see [`Rows::for_each_vblock`]).
pub enum VBlock<'a> {
    /// Dequantized (or natively-f32) `rows × d_model` tile.
    F32(&'a [f32]),
    /// Int8-native V page block: `rows × d_model` raw bytes plus the
    /// page's `n_heads` per-head scales. Element `(r, h·head_dim + c)`
    /// dequantizes as `data[r·d + h·head_dim + c] as f32 * scales[h]`;
    /// attention instead accumulates `a·V` in i32 over the raw bytes
    /// and applies `s_a · scales[h]` once per (page, head).
    I8 { data: &'a [i8], scales: &'a [f32] },
}

/// Position-indexed block access into one sequence's K (or V) history at
/// one layer. Copyable, shareable across the attention worker pool.
#[derive(Clone, Copy)]
pub enum Rows<'a> {
    /// Contiguous per-sequence buffer: position `s` at `buf[s*d..]`.
    Contig { buf: &'a [f32], d: usize },
    /// Paged arena: position `s` lives in `pages[s / page_size]` at slot
    /// `s % page_size`, stored at the store's dtype.
    Paged {
        store: &'a dyn PageStore,
        plane: Plane,
        layer: usize,
        pages: &'a [PageId],
        page_size: usize,
        d: usize,
    },
}

impl<'a> Rows<'a> {
    /// Walk the first `t` positions as page blocks, in ascending position
    /// order: `f(start, block, rows)` receives a `rows × d` f32 tile
    /// covering positions `start .. start + rows`. For f32 storage the
    /// tile borrows the arena (or the contiguous buffer — one block).
    /// Quantized storage serves registration-frozen pages from the
    /// store's shared tile cache (one dequant per cache residency, no
    /// matter how many sequences share the page) and dequantizes private
    /// pages into `scratch` once per page. Cached and scratch dequants
    /// run the same arithmetic, so the cache never changes values.
    #[inline]
    pub fn for_each_block(
        &self,
        t: usize,
        scratch: &mut Vec<f32>,
        mut f: impl FnMut(usize, &[f32], usize),
    ) {
        match *self {
            Rows::Contig { buf, d } => {
                if t > 0 {
                    f(0, &buf[..t * d], t);
                }
            }
            Rows::Paged { store, plane, layer, pages, page_size, d } => {
                let mut start = 0usize;
                while start < t {
                    let rows = page_size.min(t - start);
                    let page = pages[start / page_size];
                    if let Some(tile) = store.frozen_tile(plane, layer, page) {
                        // Frozen pages are always fully written; a
                        // partial read is a prefix of the full tile.
                        f(start, &tile[..rows * d], rows);
                    } else {
                        let block = store.block(plane, layer, page, rows, scratch);
                        f(start, block, rows);
                    }
                    start += rows;
                }
            }
        }
    }

    /// Score-pass walk: like [`Rows::for_each_block`], but yields each
    /// page at the cheapest representation its store supports —
    /// [`KBlock::Ternary`] packed lanes for ternary-K stores (LUT walk,
    /// no dequantization), [`KBlock::I8`] raw bytes for int8-native
    /// stores (i32 dot, no dequantization), [`KBlock::F32`] tiles
    /// otherwise.
    #[inline]
    pub fn for_each_kblock(
        &self,
        t: usize,
        scratch: &mut Vec<f32>,
        mut f: impl FnMut(usize, KBlock<'_>, usize),
    ) {
        match *self {
            Rows::Contig { buf, d } => {
                if t > 0 {
                    f(0, KBlock::F32(&buf[..t * d]), t);
                }
            }
            Rows::Paged { store, plane, layer, pages, page_size, d } => {
                let mut start = 0usize;
                while start < t {
                    let rows = page_size.min(t - start);
                    let page = pages[start / page_size];
                    // Cheapest representation first; the tile cache only
                    // ever serves the V-pass walk. `block_ternary` is
                    // K-plane-only by contract.
                    if matches!(plane, Plane::K) {
                        if let Some(tb) = store.block_ternary(layer, page, rows) {
                            f(start, KBlock::Ternary(tb), rows);
                            start += rows;
                            continue;
                        }
                    }
                    if let Some((data, scales)) = store.block_i8(plane, layer, page, rows) {
                        f(start, KBlock::I8 { data, scales }, rows);
                    } else {
                        let block = store.block(plane, layer, page, rows, scratch);
                        f(start, KBlock::F32(block), rows);
                    }
                    start += rows;
                }
            }
        }
    }

    /// V-pass walk: like [`Rows::for_each_block`], but yields each page
    /// at the cheapest representation its store supports —
    /// [`VBlock::I8`] raw int8 V bytes for quantized stores with the
    /// integer-a·V path enabled (no dequantization at all),
    /// [`VBlock::F32`] tiles otherwise (f32 storage, contiguous caches,
    /// or integer-V toggled off — that fallback is the residual
    /// frozen-tile / scratch-dequant consumer).
    #[inline]
    pub fn for_each_vblock(
        &self,
        t: usize,
        scratch: &mut Vec<f32>,
        mut f: impl FnMut(usize, VBlock<'_>, usize),
    ) {
        match *self {
            Rows::Contig { buf, d } => {
                if t > 0 {
                    f(0, VBlock::F32(&buf[..t * d]), t);
                }
            }
            Rows::Paged { store, plane, layer, pages, page_size, d } => {
                let integer_av = store.integer_av_enabled();
                let mut start = 0usize;
                while start < t {
                    let rows = page_size.min(t - start);
                    let page = pages[start / page_size];
                    if integer_av {
                        if let Some((data, scales)) = store.block_i8(plane, layer, page, rows) {
                            f(start, VBlock::I8 { data, scales }, rows);
                            start += rows;
                            continue;
                        }
                    }
                    if let Some(tile) = store.frozen_tile(plane, layer, page) {
                        f(start, VBlock::F32(&tile[..rows * d]), rows);
                    } else {
                        let block = store.block(plane, layer, page, rows, scratch);
                        f(start, VBlock::F32(block), rows);
                    }
                    start += rows;
                }
            }
        }
    }

    /// Record attention q·k row counts against the backing store (the
    /// per-dtype dot-fraction gauges). No-op for contiguous caches — the
    /// single-stream paths are not metered.
    #[inline]
    pub fn record_qk(&self, native_rows: u64, dequant_rows: u64, ternary_rows: u64) {
        if let Rows::Paged { store, .. } = *self {
            store.record_qk_rows(native_rows, dequant_rows, ternary_rows);
        }
    }

    /// Record int8-native a·V row counts against the backing store (the
    /// `kv_av_rows_int8` gauge). No-op for contiguous caches.
    #[inline]
    pub fn record_av(&self, int8_rows: u64) {
        if let Rows::Paged { store, .. } = *self {
            store.record_av_rows(int8_rows);
        }
    }

    /// Model width of the rows this view yields.
    pub fn width(&self) -> usize {
        match *self {
            Rows::Contig { d, .. } => d,
            Rows::Paged { d, .. } => d,
        }
    }
}

/// Mutable KV backing for one decode micro-step over a batch of
/// sequences: either each sequence's own contiguous [`KvCache`], or
/// per-sequence [`BlockTable`]s over one shared [`BlockAllocator`].
pub enum KvBatch<'s, 'c> {
    Contig(&'s mut [&'c mut KvCache]),
    Paged { alloc: &'s mut BlockAllocator, tables: &'s mut [&'c mut BlockTable] },
}

impl<'s, 'c> KvBatch<'s, 'c> {
    /// Number of sequences in the batch.
    pub fn batch(&self) -> usize {
        match self {
            KvBatch::Contig(caches) => caches.len(),
            KvBatch::Paged { tables, .. } => tables.len(),
        }
    }

    /// Current decode position (= stored KV length) of sequence `i`.
    pub fn pos(&self, i: usize) -> usize {
        match self {
            KvBatch::Contig(caches) => caches[i].len,
            KvBatch::Paged { tables, .. } => tables[i].len(),
        }
    }

    /// Make every sequence's next slot writable (page allocation and
    /// copy-on-write happen here, once per step, before any layer reads).
    pub fn begin_step(&mut self) {
        if let KvBatch::Paged { alloc, tables } = self {
            for t in tables.iter_mut() {
                t.prepare_append(alloc);
            }
        }
    }

    /// Append sequence `i`'s K/V rows for `layer` at its current position.
    #[inline]
    pub fn append(&mut self, layer: usize, i: usize, k_row: &[f32], v_row: &[f32]) {
        match self {
            KvBatch::Contig(caches) => {
                caches[i].k[layer].extend_from_slice(k_row);
                caches[i].v[layer].extend_from_slice(v_row);
            }
            KvBatch::Paged { alloc, tables } => {
                let (page, slot) = tables[i].slot_for(tables[i].len());
                alloc.write_row(layer, page, slot, k_row, v_row);
            }
        }
    }

    /// K rows of sequence `i` at `layer` (history including this step's
    /// appended row).
    #[inline]
    pub fn k_rows(&self, layer: usize, i: usize) -> Rows<'_> {
        self.rows(Plane::K, layer, i)
    }

    /// V rows of sequence `i` at `layer`.
    #[inline]
    pub fn v_rows(&self, layer: usize, i: usize) -> Rows<'_> {
        self.rows(Plane::V, layer, i)
    }

    fn rows(&self, plane: Plane, layer: usize, i: usize) -> Rows<'_> {
        match self {
            KvBatch::Contig(caches) => {
                let buf = match plane {
                    Plane::K => &caches[i].k[layer],
                    Plane::V => &caches[i].v[layer],
                };
                Rows::Contig { buf, d: caches[i].d_model }
            }
            KvBatch::Paged { alloc, tables } => Rows::Paged {
                store: alloc.store(),
                plane,
                layer,
                pages: tables[i].pages(),
                page_size: alloc.page_size(),
                d: alloc.d_model(),
            },
        }
    }

    /// Commit the step: every sequence's length advances by one.
    pub fn advance(&mut self) {
        match self {
            KvBatch::Contig(caches) => {
                for c in caches.iter_mut() {
                    c.len += 1;
                }
            }
            KvBatch::Paged { tables, .. } => {
                for t in tables.iter_mut() {
                    t.advance();
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::KvDtype;
    use crate::engine::NativeConfig;

    /// Flatten the first `t` positions of a view into one `t × d` buffer.
    fn collect(rows: &Rows<'_>, t: usize) -> Vec<f32> {
        let d = rows.width();
        let mut out = vec![0.0; t * d];
        let mut scratch = Vec::new();
        rows.for_each_block(t, &mut scratch, |start, block, n| {
            out[start * d..(start + n) * d].copy_from_slice(&block[..n * d]);
        });
        out
    }

    #[test]
    fn contig_and_paged_blocks_agree() {
        let cfg = NativeConfig::named("nano").unwrap();
        let d = cfg.d_model;
        let mut cache = KvCache::new(&cfg);
        let mut alloc = BlockAllocator::new(&cfg, 4, 4);
        let mut table = BlockTable::new(4);
        // Append 6 positions of distinct rows through both backings.
        for pos in 0..6usize {
            let krow: Vec<f32> = (0..d).map(|c| (pos * d + c) as f32).collect();
            let vrow: Vec<f32> = krow.iter().map(|x| -x).collect();
            {
                let mut caches = [&mut cache];
                let mut kv = KvBatch::Contig(&mut caches);
                kv.begin_step();
                for li in 0..cfg.n_layers {
                    kv.append(li, 0, &krow, &vrow);
                }
                kv.advance();
            }
            {
                let mut tables = [&mut table];
                let mut kv = KvBatch::Paged { alloc: &mut alloc, tables: &mut tables };
                kv.begin_step();
                for li in 0..cfg.n_layers {
                    kv.append(li, 0, &krow, &vrow);
                }
                kv.advance();
            }
        }
        let mut caches = [&mut cache];
        let kv_c = KvBatch::Contig(&mut caches);
        let mut tables = [&mut table];
        let kv_p = KvBatch::Paged { alloc: &mut alloc, tables: &mut tables };
        assert_eq!(kv_c.pos(0), 6);
        assert_eq!(kv_p.pos(0), 6);
        for li in 0..cfg.n_layers {
            for t in [1usize, 4, 5, 6] {
                assert_eq!(collect(&kv_c.k_rows(li, 0), t), collect(&kv_p.k_rows(li, 0), t));
                assert_eq!(collect(&kv_c.v_rows(li, 0), t), collect(&kv_p.v_rows(li, 0), t));
            }
        }
    }

    #[test]
    fn block_walk_covers_positions_in_order_with_partial_tail() {
        let cfg = NativeConfig::named("nano").unwrap();
        let mut alloc = BlockAllocator::new(&cfg, 4, 4);
        let mut table = BlockTable::new(4);
        let d = cfg.d_model;
        for pos in 0..7usize {
            table.prepare_append(&mut alloc);
            let (page, slot) = table.slot_for(pos);
            alloc.write_row(0, page, slot, &vec![pos as f32; d], &vec![pos as f32; d]);
            table.advance();
        }
        let mut tables = [&mut table];
        let kv = KvBatch::Paged { alloc: &mut alloc, tables: &mut tables };
        let rows = kv.k_rows(0, 0);
        let mut seen = Vec::new();
        let mut scratch = Vec::new();
        rows.for_each_block(7, &mut scratch, |start, block, n| {
            for r in 0..n {
                seen.push((start + r, block[r * d]));
            }
        });
        assert_eq!(seen.len(), 7);
        for (i, &(pos, val)) in seen.iter().enumerate() {
            assert_eq!(pos, i, "ascending positions");
            assert_eq!(val, i as f32);
        }
    }

    #[test]
    fn kblock_walk_yields_int8_native_blocks_that_dequantize_identically() {
        let cfg = NativeConfig::named("nano").unwrap();
        let d = cfg.d_model;
        let hd = cfg.head_dim();
        let mut alloc = BlockAllocator::new_with(&cfg, 4, 4, KvDtype::Int8);
        let mut table = BlockTable::new(4);
        let mut rng = crate::util::Pcg64::seeded(9);
        for pos in 0..6usize {
            table.prepare_append(&mut alloc);
            let (page, slot) = table.slot_for(pos);
            let row = rng.normal_vec(d);
            alloc.write_row(0, page, slot, &row, &row);
            table.advance();
        }
        let mut tables = [&mut table];
        let kv = KvBatch::Paged { alloc: &mut alloc, tables: &mut tables };
        let rows = kv.k_rows(0, 0);
        // Reference: the f32 walk.
        let reference = collect(&rows, 6);
        // The kblock walk must yield I8 blocks (int8 store) that
        // dequantize to exactly the f32 walk's tiles.
        let mut scratch = Vec::new();
        let mut covered = 0usize;
        rows.for_each_kblock(6, &mut scratch, |start, block, n| {
            match block {
                super::KBlock::I8 { data, scales } => {
                    for r in 0..n {
                        for h in 0..cfg.n_heads {
                            for c in h * hd..(h + 1) * hd {
                                assert_eq!(
                                    data[r * d + c] as f32 * scales[h],
                                    reference[(start + r) * d + c],
                                    "pos {} ch {c}",
                                    start + r
                                );
                            }
                        }
                    }
                }
                super::KBlock::F32(_) => panic!("int8 store must yield int8-native blocks"),
            }
            covered += n;
        });
        assert_eq!(covered, 6);

        // Contiguous caches (and f32 arenas) yield F32 blocks.
        let mut cache = KvCache::new(&cfg);
        cache.k[0].extend_from_slice(&vec![1.0; d]);
        cache.v[0].extend_from_slice(&vec![1.0; d]);
        cache.len = 1;
        let mut caches = [&mut cache];
        let kv = KvBatch::Contig(&mut caches);
        kv.k_rows(0, 0).for_each_kblock(1, &mut scratch, |_, block, _| {
            assert!(matches!(block, super::KBlock::F32(_)));
        });
    }

    #[test]
    fn kblock_walk_yields_ternary_blocks_that_decode_identically() {
        let cfg = NativeConfig::named("nano").unwrap();
        let d = cfg.d_model;
        let hd = cfg.head_dim();
        let mut alloc = BlockAllocator::new_with(&cfg, 4, 4, KvDtype::Ternary);
        let mut table = BlockTable::new(4);
        let mut rng = crate::util::Pcg64::seeded(21);
        for pos in 0..6usize {
            table.prepare_append(&mut alloc);
            let (page, slot) = table.slot_for(pos);
            let row = rng.normal_vec(d);
            alloc.write_row(0, page, slot, &row, &row);
            table.advance();
        }
        let mut tables = [&mut table];
        let kv = KvBatch::Paged { alloc: &mut alloc, tables: &mut tables };

        // K must walk as packed-ternary blocks that decode to exactly
        // the f32 walk's tiles; scratch must stay untouched (the score
        // pass never materializes a dequantized K tile).
        let rows = kv.k_rows(0, 0);
        let reference = collect(&rows, 6);
        let mut scratch = Vec::new();
        let mut covered = 0usize;
        rows.for_each_kblock(6, &mut scratch, |start, block, n| {
            let super::KBlock::Ternary(tb) = block else {
                panic!("ternary store must yield packed-ternary K blocks")
            };
            for r in 0..n {
                for h in 0..cfg.n_heads {
                    let ib = (r * cfg.n_heads + h) * tb.idx_bh;
                    let mb = (r * cfg.n_heads + h) * tb.sign_bh;
                    for b in 0..hd / 4 {
                        let nib = (tb.idx[ib + b / 2] >> ((b % 2) * 4)) & 0x0F;
                        let mirror = (tb.sign[mb + b / 8] >> (b % 8)) & 1 == 1;
                        let pat = crate::pack::pack34::decode_block(nib, mirror);
                        for (lane, &t) in pat.iter().enumerate() {
                            assert_eq!(
                                t as f32 * tb.scales[h],
                                reference[(start + r) * d + h * hd + b * 4 + lane],
                                "pos {} head {h} block {b}",
                                start + r
                            );
                        }
                    }
                }
            }
            covered += n;
        });
        assert_eq!(covered, 6);
        assert!(scratch.is_empty(), "K walk never dequantized into scratch");

        // V stays int8-native.
        kv.v_rows(0, 0).for_each_kblock(6, &mut scratch, |_, block, _| {
            assert!(matches!(block, super::KBlock::I8 { .. }));
        });
    }

    #[test]
    fn vblock_walk_yields_int8_blocks_without_touching_scratch() {
        // Both quantized stores must serve the V pass as raw int8
        // blocks that dequantize to exactly the f32 walk's tiles, with
        // no scratch dequantization at all; toggling integer-V off
        // restores the f32 tile walk with identical values.
        let cfg = NativeConfig::named("nano").unwrap();
        let d = cfg.d_model;
        let hd = cfg.head_dim();
        for dtype in [KvDtype::Int8, KvDtype::Ternary] {
            let mut alloc = BlockAllocator::new_with(&cfg, 4, 4, dtype);
            let mut table = BlockTable::new(4);
            let mut rng = crate::util::Pcg64::seeded(27);
            for pos in 0..6usize {
                table.prepare_append(&mut alloc);
                let (page, slot) = table.slot_for(pos);
                let row = rng.normal_vec(d);
                alloc.write_row(0, page, slot, &row, &row);
                table.advance();
            }
            let mut tables = [&mut table];
            let kv = KvBatch::Paged { alloc: &mut alloc, tables: &mut tables };
            let rows = kv.v_rows(0, 0);
            let reference = collect(&rows, 6);
            let mut scratch = Vec::new();
            let mut covered = 0usize;
            rows.for_each_vblock(6, &mut scratch, |start, block, n| {
                let super::VBlock::I8 { data, scales } = block else {
                    panic!("{dtype:?} store must yield int8-native V blocks")
                };
                for r in 0..n {
                    for h in 0..cfg.n_heads {
                        for c in h * hd..(h + 1) * hd {
                            assert_eq!(
                                data[r * d + c] as f32 * scales[h],
                                reference[(start + r) * d + c],
                                "pos {} ch {c}",
                                start + r
                            );
                        }
                    }
                }
                covered += n;
            });
            assert_eq!(covered, 6);
            assert!(scratch.is_empty(), "V walk never dequantized into scratch");

            // Toggle off: the walk falls back to f32 tiles, same values.
            let KvBatch::Paged { alloc, tables } = kv else { unreachable!() };
            alloc.set_integer_av(false);
            let kv = KvBatch::Paged { alloc, tables };
            let rows = kv.v_rows(0, 0);
            let mut flat = vec![0.0; 6 * d];
            rows.for_each_vblock(6, &mut scratch, |start, block, n| {
                let super::VBlock::F32(tile) = block else {
                    panic!("integer-V off must fall back to f32 tiles")
                };
                flat[start * d..(start + n) * d].copy_from_slice(&tile[..n * d]);
            });
            assert_eq!(flat, reference, "both V walks dequantize identically");
        }

        // Contiguous caches yield one F32 block, borrowed bit-for-bit.
        let mut cache = KvCache::new(&cfg);
        cache.k[0].extend_from_slice(&vec![2.0; d]);
        cache.v[0].extend_from_slice(&vec![3.0; d]);
        cache.len = 1;
        let mut caches = [&mut cache];
        let kv = KvBatch::Contig(&mut caches);
        let mut scratch = Vec::new();
        kv.v_rows(0, 0).for_each_vblock(1, &mut scratch, |_, block, n| {
            let super::VBlock::F32(tile) = block else { panic!("contig must yield F32") };
            assert_eq!(n, 1);
            assert_eq!(tile, &vec![3.0; d][..]);
        });
    }

    #[test]
    fn int8_paged_blocks_approximate_f32() {
        let cfg = NativeConfig::named("nano").unwrap();
        let d = cfg.d_model;
        let mut f32_alloc = BlockAllocator::new(&cfg, 4, 4);
        let mut i8_alloc = BlockAllocator::new_with(&cfg, 4, 4, KvDtype::Int8);
        let mut tf = BlockTable::new(4);
        let mut tq = BlockTable::new(4);
        let mut rng = crate::util::Pcg64::seeded(3);
        for pos in 0..6usize {
            let row = rng.normal_vec(d);
            for (alloc, t) in [(&mut f32_alloc, &mut tf), (&mut i8_alloc, &mut tq)] {
                t.prepare_append(alloc);
                let (page, slot) = t.slot_for(pos);
                alloc.write_row(0, page, slot, &row, &row);
                t.advance();
            }
        }
        let mut tables_f = [&mut tf];
        let kv_f = KvBatch::Paged { alloc: &mut f32_alloc, tables: &mut tables_f };
        let mut tables_q = [&mut tq];
        let kv_q = KvBatch::Paged { alloc: &mut i8_alloc, tables: &mut tables_q };
        let a = collect(&kv_f.k_rows(0, 0), 6);
        let b = collect(&kv_q.k_rows(0, 0), 6);
        let max_abs = a.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        // ≤ (page_size + 1)/2 quanta of the global absmax (page/head
        // scales are all ≤ max_abs/127 here).
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() <= 2.5 * max_abs / 127.0 + 1e-6, "{x} vs {y}");
        }
    }
}
