//! Block-table view: the engine's one window onto KV storage.
//!
//! [`TernaryModel::forward_kv`](crate::engine::TernaryModel::forward_kv)
//! appends and reads K/V exclusively through [`KvBatch`], so paged and
//! contiguous storage run the *same* model code. [`Rows`] exposes a
//! sequence's K (or V) history as **page blocks**: contiguous
//! `rows × d_model` f32 tiles, one per resident page (the whole history
//! is a single block for a contiguous cache). The attention kernel walks
//! blocks in ascending position order and consumes rows in identical
//! order either way, which is what keeps paged decode bit-for-bit equal
//! to the contiguous baseline (the contiguous path is literally the
//! degenerate single-block case). Quantized stores dequantize each block
//! once into a caller scratch tile, amortizing the conversion over every
//! query·key dot product and value accumulation that touches the page.

use super::allocator::{BlockAllocator, PageId};
use super::store::{PageStore, Plane};
use super::table::BlockTable;
use crate::engine::KvCache;

/// Position-indexed block access into one sequence's K (or V) history at
/// one layer. Copyable, shareable across the attention worker pool.
#[derive(Clone, Copy)]
pub enum Rows<'a> {
    /// Contiguous per-sequence buffer: position `s` at `buf[s*d..]`.
    Contig { buf: &'a [f32], d: usize },
    /// Paged arena: position `s` lives in `pages[s / page_size]` at slot
    /// `s % page_size`, stored at the store's dtype.
    Paged {
        store: &'a dyn PageStore,
        plane: Plane,
        layer: usize,
        pages: &'a [PageId],
        page_size: usize,
        d: usize,
    },
}

impl<'a> Rows<'a> {
    /// Walk the first `t` positions as page blocks, in ascending position
    /// order: `f(start, block, rows)` receives a `rows × d` f32 tile
    /// covering positions `start .. start + rows`. For f32 storage the
    /// tile borrows the arena (or the contiguous buffer — one block);
    /// quantized storage dequantizes into `scratch` once per page.
    #[inline]
    pub fn for_each_block(
        &self,
        t: usize,
        scratch: &mut Vec<f32>,
        mut f: impl FnMut(usize, &[f32], usize),
    ) {
        match *self {
            Rows::Contig { buf, d } => {
                if t > 0 {
                    f(0, &buf[..t * d], t);
                }
            }
            Rows::Paged { store, plane, layer, pages, page_size, .. } => {
                let mut start = 0usize;
                while start < t {
                    let rows = page_size.min(t - start);
                    let page = pages[start / page_size];
                    let block = store.block(plane, layer, page, rows, scratch);
                    f(start, block, rows);
                    start += rows;
                }
            }
        }
    }

    /// Model width of the rows this view yields.
    pub fn width(&self) -> usize {
        match *self {
            Rows::Contig { d, .. } => d,
            Rows::Paged { d, .. } => d,
        }
    }
}

/// Mutable KV backing for one decode micro-step over a batch of
/// sequences: either each sequence's own contiguous [`KvCache`], or
/// per-sequence [`BlockTable`]s over one shared [`BlockAllocator`].
pub enum KvBatch<'s, 'c> {
    Contig(&'s mut [&'c mut KvCache]),
    Paged { alloc: &'s mut BlockAllocator, tables: &'s mut [&'c mut BlockTable] },
}

impl<'s, 'c> KvBatch<'s, 'c> {
    /// Number of sequences in the batch.
    pub fn batch(&self) -> usize {
        match self {
            KvBatch::Contig(caches) => caches.len(),
            KvBatch::Paged { tables, .. } => tables.len(),
        }
    }

    /// Current decode position (= stored KV length) of sequence `i`.
    pub fn pos(&self, i: usize) -> usize {
        match self {
            KvBatch::Contig(caches) => caches[i].len,
            KvBatch::Paged { tables, .. } => tables[i].len(),
        }
    }

    /// Make every sequence's next slot writable (page allocation and
    /// copy-on-write happen here, once per step, before any layer reads).
    pub fn begin_step(&mut self) {
        if let KvBatch::Paged { alloc, tables } = self {
            for t in tables.iter_mut() {
                t.prepare_append(alloc);
            }
        }
    }

    /// Append sequence `i`'s K/V rows for `layer` at its current position.
    #[inline]
    pub fn append(&mut self, layer: usize, i: usize, k_row: &[f32], v_row: &[f32]) {
        match self {
            KvBatch::Contig(caches) => {
                caches[i].k[layer].extend_from_slice(k_row);
                caches[i].v[layer].extend_from_slice(v_row);
            }
            KvBatch::Paged { alloc, tables } => {
                let (page, slot) = tables[i].slot_for(tables[i].len());
                alloc.write_row(layer, page, slot, k_row, v_row);
            }
        }
    }

    /// K rows of sequence `i` at `layer` (history including this step's
    /// appended row).
    #[inline]
    pub fn k_rows(&self, layer: usize, i: usize) -> Rows<'_> {
        self.rows(Plane::K, layer, i)
    }

    /// V rows of sequence `i` at `layer`.
    #[inline]
    pub fn v_rows(&self, layer: usize, i: usize) -> Rows<'_> {
        self.rows(Plane::V, layer, i)
    }

    fn rows(&self, plane: Plane, layer: usize, i: usize) -> Rows<'_> {
        match self {
            KvBatch::Contig(caches) => {
                let buf = match plane {
                    Plane::K => &caches[i].k[layer],
                    Plane::V => &caches[i].v[layer],
                };
                Rows::Contig { buf, d: caches[i].d_model }
            }
            KvBatch::Paged { alloc, tables } => Rows::Paged {
                store: alloc.store(),
                plane,
                layer,
                pages: tables[i].pages(),
                page_size: alloc.page_size(),
                d: alloc.d_model(),
            },
        }
    }

    /// Commit the step: every sequence's length advances by one.
    pub fn advance(&mut self) {
        match self {
            KvBatch::Contig(caches) => {
                for c in caches.iter_mut() {
                    c.len += 1;
                }
            }
            KvBatch::Paged { tables, .. } => {
                for t in tables.iter_mut() {
                    t.advance();
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::KvDtype;
    use crate::engine::NativeConfig;

    /// Flatten the first `t` positions of a view into one `t × d` buffer.
    fn collect(rows: &Rows<'_>, t: usize) -> Vec<f32> {
        let d = rows.width();
        let mut out = vec![0.0; t * d];
        let mut scratch = Vec::new();
        rows.for_each_block(t, &mut scratch, |start, block, n| {
            out[start * d..(start + n) * d].copy_from_slice(&block[..n * d]);
        });
        out
    }

    #[test]
    fn contig_and_paged_blocks_agree() {
        let cfg = NativeConfig::named("nano").unwrap();
        let d = cfg.d_model;
        let mut cache = KvCache::new(&cfg);
        let mut alloc = BlockAllocator::new(&cfg, 4, 4);
        let mut table = BlockTable::new(4);
        // Append 6 positions of distinct rows through both backings.
        for pos in 0..6usize {
            let krow: Vec<f32> = (0..d).map(|c| (pos * d + c) as f32).collect();
            let vrow: Vec<f32> = krow.iter().map(|x| -x).collect();
            {
                let mut caches = [&mut cache];
                let mut kv = KvBatch::Contig(&mut caches);
                kv.begin_step();
                for li in 0..cfg.n_layers {
                    kv.append(li, 0, &krow, &vrow);
                }
                kv.advance();
            }
            {
                let mut tables = [&mut table];
                let mut kv = KvBatch::Paged { alloc: &mut alloc, tables: &mut tables };
                kv.begin_step();
                for li in 0..cfg.n_layers {
                    kv.append(li, 0, &krow, &vrow);
                }
                kv.advance();
            }
        }
        let mut caches = [&mut cache];
        let kv_c = KvBatch::Contig(&mut caches);
        let mut tables = [&mut table];
        let kv_p = KvBatch::Paged { alloc: &mut alloc, tables: &mut tables };
        assert_eq!(kv_c.pos(0), 6);
        assert_eq!(kv_p.pos(0), 6);
        for li in 0..cfg.n_layers {
            for t in [1usize, 4, 5, 6] {
                assert_eq!(collect(&kv_c.k_rows(li, 0), t), collect(&kv_p.k_rows(li, 0), t));
                assert_eq!(collect(&kv_c.v_rows(li, 0), t), collect(&kv_p.v_rows(li, 0), t));
            }
        }
    }

    #[test]
    fn block_walk_covers_positions_in_order_with_partial_tail() {
        let cfg = NativeConfig::named("nano").unwrap();
        let mut alloc = BlockAllocator::new(&cfg, 4, 4);
        let mut table = BlockTable::new(4);
        let d = cfg.d_model;
        for pos in 0..7usize {
            table.prepare_append(&mut alloc);
            let (page, slot) = table.slot_for(pos);
            alloc.write_row(0, page, slot, &vec![pos as f32; d], &vec![pos as f32; d]);
            table.advance();
        }
        let mut tables = [&mut table];
        let kv = KvBatch::Paged { alloc: &mut alloc, tables: &mut tables };
        let rows = kv.k_rows(0, 0);
        let mut seen = Vec::new();
        let mut scratch = Vec::new();
        rows.for_each_block(7, &mut scratch, |start, block, n| {
            for r in 0..n {
                seen.push((start + r, block[r * d]));
            }
        });
        assert_eq!(seen.len(), 7);
        for (i, &(pos, val)) in seen.iter().enumerate() {
            assert_eq!(pos, i, "ascending positions");
            assert_eq!(val, i as f32);
        }
    }

    #[test]
    fn int8_paged_blocks_approximate_f32() {
        let cfg = NativeConfig::named("nano").unwrap();
        let d = cfg.d_model;
        let mut f32_alloc = BlockAllocator::new(&cfg, 4, 4);
        let mut i8_alloc = BlockAllocator::new_with(&cfg, 4, 4, KvDtype::Int8);
        let mut tf = BlockTable::new(4);
        let mut tq = BlockTable::new(4);
        let mut rng = crate::util::Pcg64::seeded(3);
        for pos in 0..6usize {
            let row = rng.normal_vec(d);
            for (alloc, t) in [(&mut f32_alloc, &mut tf), (&mut i8_alloc, &mut tq)] {
                t.prepare_append(alloc);
                let (page, slot) = t.slot_for(pos);
                alloc.write_row(0, page, slot, &row, &row);
                t.advance();
            }
        }
        let mut tables_f = [&mut tf];
        let kv_f = KvBatch::Paged { alloc: &mut f32_alloc, tables: &mut tables_f };
        let mut tables_q = [&mut tq];
        let kv_q = KvBatch::Paged { alloc: &mut i8_alloc, tables: &mut tables_q };
        let a = collect(&kv_f.k_rows(0, 0), 6);
        let b = collect(&kv_q.k_rows(0, 0), 6);
        let max_abs = a.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        // ≤ (page_size + 1)/2 quanta of the global absmax (page/head
        // scales are all ≤ max_abs/127 here).
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() <= 2.5 * max_abs / 127.0 + 1e-6, "{x} vs {y}");
        }
    }
}
