//! Block-table view: the engine's one window onto KV storage.
//!
//! [`TernaryModel::forward_kv`](crate::engine::TernaryModel::forward_kv)
//! appends and reads K/V exclusively through [`KvBatch`], so paged and
//! contiguous storage run the *same* model code. [`Rows`] resolves a
//! logical position to its `d_model`-wide row — a slice offset for a
//! contiguous cache, a page-table lookup for the paged arena — and the
//! attention math consumes rows in identical order either way, which is
//! what keeps paged decode bit-for-bit equal to the contiguous baseline
//! (the contiguous path is literally the degenerate single-table case).

use super::allocator::{BlockAllocator, PageId};
use super::table::BlockTable;
use crate::engine::KvCache;

/// Position-indexed row access into one sequence's K (or V) history at
/// one layer. Copyable, shareable across the attention worker pool.
#[derive(Clone, Copy)]
pub enum Rows<'a> {
    /// Contiguous per-sequence buffer: position `s` at `buf[s*d..]`.
    Contig { buf: &'a [f32], d: usize },
    /// Paged arena: position `s` lives in `pages[s / page_size]` at slot
    /// `s % page_size`.
    Paged { plane: &'a [f32], pages: &'a [PageId], page_size: usize, d: usize },
}

impl<'a> Rows<'a> {
    /// The row for logical position `s`.
    #[inline]
    pub fn row(&self, s: usize) -> &'a [f32] {
        match *self {
            Rows::Contig { buf, d } => &buf[s * d..(s + 1) * d],
            Rows::Paged { plane, pages, page_size, d } => {
                let base = (pages[s / page_size] as usize * page_size + s % page_size) * d;
                &plane[base..base + d]
            }
        }
    }
}

/// Mutable KV backing for one decode micro-step over a batch of
/// sequences: either each sequence's own contiguous [`KvCache`], or
/// per-sequence [`BlockTable`]s over one shared [`BlockAllocator`].
pub enum KvBatch<'s, 'c> {
    Contig(&'s mut [&'c mut KvCache]),
    Paged { alloc: &'s mut BlockAllocator, tables: &'s mut [&'c mut BlockTable] },
}

impl<'s, 'c> KvBatch<'s, 'c> {
    /// Number of sequences in the batch.
    pub fn batch(&self) -> usize {
        match self {
            KvBatch::Contig(caches) => caches.len(),
            KvBatch::Paged { tables, .. } => tables.len(),
        }
    }

    /// Current decode position (= stored KV length) of sequence `i`.
    pub fn pos(&self, i: usize) -> usize {
        match self {
            KvBatch::Contig(caches) => caches[i].len,
            KvBatch::Paged { tables, .. } => tables[i].len(),
        }
    }

    /// Make every sequence's next slot writable (page allocation and
    /// copy-on-write happen here, once per step, before any layer reads).
    pub fn begin_step(&mut self) {
        if let KvBatch::Paged { alloc, tables } = self {
            for t in tables.iter_mut() {
                t.prepare_append(alloc);
            }
        }
    }

    /// Append sequence `i`'s K/V rows for `layer` at its current position.
    #[inline]
    pub fn append(&mut self, layer: usize, i: usize, k_row: &[f32], v_row: &[f32]) {
        match self {
            KvBatch::Contig(caches) => {
                caches[i].k[layer].extend_from_slice(k_row);
                caches[i].v[layer].extend_from_slice(v_row);
            }
            KvBatch::Paged { alloc, tables } => {
                let (page, slot) = tables[i].slot_for(tables[i].len());
                alloc.write_row(layer, page, slot, k_row, v_row);
            }
        }
    }

    /// K rows of sequence `i` at `layer` (history including this step's
    /// appended row).
    #[inline]
    pub fn k_rows(&self, layer: usize, i: usize) -> Rows<'_> {
        match self {
            KvBatch::Contig(caches) => {
                Rows::Contig { buf: &caches[i].k[layer], d: caches[i].d_model }
            }
            KvBatch::Paged { alloc, tables } => Rows::Paged {
                plane: alloc.k_plane(layer),
                pages: tables[i].pages(),
                page_size: alloc.page_size(),
                d: alloc.d_model(),
            },
        }
    }

    /// V rows of sequence `i` at `layer`.
    #[inline]
    pub fn v_rows(&self, layer: usize, i: usize) -> Rows<'_> {
        match self {
            KvBatch::Contig(caches) => {
                Rows::Contig { buf: &caches[i].v[layer], d: caches[i].d_model }
            }
            KvBatch::Paged { alloc, tables } => Rows::Paged {
                plane: alloc.v_plane(layer),
                pages: tables[i].pages(),
                page_size: alloc.page_size(),
                d: alloc.d_model(),
            },
        }
    }

    /// Commit the step: every sequence's length advances by one.
    pub fn advance(&mut self) {
        match self {
            KvBatch::Contig(caches) => {
                for c in caches.iter_mut() {
                    c.len += 1;
                }
            }
            KvBatch::Paged { tables, .. } => {
                for t in tables.iter_mut() {
                    t.advance();
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::NativeConfig;

    #[test]
    fn contig_and_paged_rows_agree() {
        let cfg = NativeConfig::named("nano").unwrap();
        let d = cfg.d_model;
        let mut cache = KvCache::new(&cfg);
        let mut alloc = BlockAllocator::new(&cfg, 4, 4);
        let mut table = BlockTable::new(4);
        // Append 6 positions of distinct rows through both backings.
        for pos in 0..6usize {
            let krow: Vec<f32> = (0..d).map(|c| (pos * d + c) as f32).collect();
            let vrow: Vec<f32> = krow.iter().map(|x| -x).collect();
            {
                let mut caches = [&mut cache];
                let mut kv = KvBatch::Contig(&mut caches);
                kv.begin_step();
                for li in 0..cfg.n_layers {
                    kv.append(li, 0, &krow, &vrow);
                }
                kv.advance();
            }
            {
                let mut tables = [&mut table];
                let mut kv = KvBatch::Paged { alloc: &mut alloc, tables: &mut tables };
                kv.begin_step();
                for li in 0..cfg.n_layers {
                    kv.append(li, 0, &krow, &vrow);
                }
                kv.advance();
            }
        }
        let mut caches = [&mut cache];
        let kv_c = KvBatch::Contig(&mut caches);
        let mut tables = [&mut table];
        let kv_p = KvBatch::Paged { alloc: &mut alloc, tables: &mut tables };
        assert_eq!(kv_c.pos(0), 6);
        assert_eq!(kv_p.pos(0), 6);
        for li in 0..cfg.n_layers {
            for s in 0..6 {
                assert_eq!(kv_c.k_rows(li, 0).row(s), kv_p.k_rows(li, 0).row(s));
                assert_eq!(kv_c.v_rows(li, 0).row(s), kv_p.v_rows(li, 0).row(s));
            }
        }
    }
}
