//! Paged KV-cache subsystem: block allocator, per-sequence block tables,
//! radix prefix sharing, and the storage view the engine decodes through.
//!
//! The seed served each sequence from a whole `seq_len × d_model`
//! contiguous cache leased from a fixed pool, so concurrency was capped
//! by worst-case allocation and identical prompt prefixes were recomputed
//! per request. This subsystem replaces that with vLLM-style paging:
//!
//! * [`BlockAllocator`] — one preallocated per-layer K/V arena carved
//!   into fixed pages (default 16 positions), refcounted.
//! * [`BlockTable`] — per-sequence logical-position → page map; frozen
//!   shared pages copy-on-write at first divergence.
//! * [`PrefixIndex`] — radix trie over registered prompt prefixes; a new
//!   request reuses the frozen KV pages of any previously seen prefix,
//!   skipping prefill for the shared span with token-identical results.
//! * [`PageStore`] — the storage-dtype policy behind the arena:
//!   [`F32Store`] (parity baseline, block reads borrow the plane),
//!   [`Int8Store`] (int8 pages + per-page-per-head f32 scales, quantized
//!   at page-write time), and [`TernaryStore`] (1.25-bit 3:4-sparse
//!   pack34 K pages + per-page-per-head absmean scales, int8 V pages).
//!   Quantized pages expose four read paths, cheapest first:
//!   packed-ternary raw blocks ([`PageStore::block_ternary`] — the score
//!   pass walks them through per-query LUTs without dequantizing K),
//!   int8-native raw blocks ([`PageStore::block_i8`] — the score pass
//!   dots them in i32 without dequantizing), LRU-cached f32 tiles of
//!   registration-frozen pages ([`PageStore::frozen_tile`]), and scratch
//!   dequantization ([`PageStore::block`]) for private, still-growing
//!   pages.
//! * [`KvBatch`] / [`Rows`] — the engine-facing view; attention walks
//!   histories as page blocks ([`Rows::for_each_block`] for f32 tiles,
//!   [`Rows::for_each_kblock`] for dtype-native [`KBlock`]s,
//!   [`Rows::for_each_vblock`] for dtype-native [`VBlock`]s on the
//!   integer a·V pass), and contiguous
//!   [`KvCache`](crate::engine::KvCache)s are the degenerate
//!   single-block case of the same code path, preserving bit-for-bit
//!   parity between paged and contiguous decode.
//!
//! Invariants (property-tested in `tests/paged_kv.rs`):
//!
//! * f32 pages through any walk are bit-for-bit the contiguous engine;
//! * a page registered in the [`PrefixIndex`] is **frozen** — bytes and
//!   quantizer scales immutable until freed — making shared-prefix reads
//!   byte-exact and completions serving-order invariant (quantized
//!   pools share whole frozen pages only; see `coordinator::PagedKv`);
//! * refcounts return to zero after every trace, CoW never mutates a
//!   shared page, and no slot is read before it is written.
//!
//! DESIGN.md §4 documents the page layout, the block-table indirection,
//! the radix prefix lifecycle, the CoW rules, the frozen-scale
//! registration protocol, the int8 q·k error bound, and the tile-cache
//! lifecycle.

mod allocator;
mod prefix;
mod store;
mod table;
mod ternary;
mod view;

pub use allocator::{BlockAllocator, PageId};
pub use prefix::PrefixIndex;
pub use store::{
    new_store, page_bytes, F32Store, Int8Store, KvDtype, PageStore, Plane, TernaryBlock,
    DEFAULT_TILE_CACHE_TILES,
};
pub use ternary::TernaryStore;
pub use table::BlockTable;
pub use view::{KBlock, KvBatch, Rows, VBlock};
