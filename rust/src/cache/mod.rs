//! Paged KV-cache subsystem: block allocator, per-sequence block tables,
//! radix prefix sharing, and the storage view the engine decodes through.
//!
//! The seed served each sequence from a whole `seq_len × d_model`
//! contiguous cache leased from a fixed pool, so concurrency was capped
//! by worst-case allocation and identical prompt prefixes were recomputed
//! per request. This subsystem replaces that with vLLM-style paging:
//!
//! * [`BlockAllocator`] — one preallocated per-layer K/V arena carved
//!   into fixed pages (default 16 positions), refcounted.
//! * [`BlockTable`] — per-sequence logical-position → page map; frozen
//!   shared pages copy-on-write at first divergence.
//! * [`PrefixIndex`] — radix trie over registered prompt prefixes; a new
//!   request reuses the frozen KV pages of any previously seen prefix,
//!   skipping prefill for the shared span with token-identical results.
//! * [`PageStore`] — the storage-dtype policy behind the arena:
//!   [`F32Store`] (parity baseline, block reads borrow the plane) and
//!   [`Int8Store`] (int8 pages + per-page-per-head f32 scales, quantized
//!   at page-write time, dequantized per block into scratch tiles).
//! * [`KvBatch`] / [`Rows`] — the engine-facing view; attention walks
//!   histories as page blocks ([`Rows::for_each_block`]), and contiguous
//!   [`KvCache`](crate::engine::KvCache)s are the degenerate
//!   single-block case of the same code path, preserving bit-for-bit
//!   parity between paged and contiguous decode.
//!
//! DESIGN.md §4 documents the page layout, the block-table indirection,
//! the radix prefix lifecycle, the CoW rules, and the `PageStore` byte
//! formats / accuracy bound.

mod allocator;
mod prefix;
mod store;
mod table;
mod view;

pub use allocator::{BlockAllocator, PageId};
pub use prefix::PrefixIndex;
pub use store::{new_store, page_bytes, F32Store, Int8Store, KvDtype, PageStore, Plane};
pub use table::BlockTable;
pub use view::{KvBatch, Rows};
