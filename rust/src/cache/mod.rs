//! Paged KV-cache subsystem: block allocator, per-sequence block tables,
//! radix prefix sharing, and the storage view the engine decodes through.
//!
//! The seed served each sequence from a whole `seq_len × d_model`
//! contiguous cache leased from a fixed pool, so concurrency was capped
//! by worst-case allocation and identical prompt prefixes were recomputed
//! per request. This subsystem replaces that with vLLM-style paging:
//!
//! * [`BlockAllocator`] — one preallocated per-layer K/V arena carved
//!   into fixed pages (default 16 positions), refcounted.
//! * [`BlockTable`] — per-sequence logical-position → page map; frozen
//!   shared pages copy-on-write at first divergence.
//! * [`PrefixIndex`] — radix trie over registered prompt prefixes; a new
//!   request reuses the frozen KV pages of any previously seen prefix,
//!   skipping prefill for the shared span with token-identical results.
//! * [`KvBatch`] / [`Rows`] — the engine-facing view; contiguous
//!   [`KvCache`](crate::engine::KvCache)s are the degenerate
//!   single-table case of the same code path, preserving bit-for-bit
//!   parity between paged and contiguous decode.
//!
//! DESIGN.md §4 documents the page layout, the block-table indirection,
//! the radix prefix lifecycle, and the CoW rules.

mod allocator;
mod prefix;
mod table;
mod view;

pub use allocator::{BlockAllocator, PageId};
pub use prefix::PrefixIndex;
pub use table::BlockTable;
pub use view::{KvBatch, Rows};
