//! Radix prefix index: maps prompt-token prefixes to frozen KV pages.
//!
//! A trie whose edges are full-page token chunks (`page_size` tokens).
//! Each non-root node owns one reference to the arena page holding the
//! KV rows of its chunk. A newly admitted request walks the trie with
//! its prompt: every fully matched chunk contributes a whole shared
//! page; a partial match on the last chunk shares the page's live
//! prefix (the recipient copy-on-writes at first divergence — see
//! `super::table`; quantized pools restrict sharing to whole pages — see
//! `PagedKv`). Because K/V rows are a deterministic function of the
//! token prefix (causal attention, absolute-position RoPE, bit-for-bit
//! batched kernels), reusing a registered page is exact, not
//! approximate: prefill for the shared span is skipped with
//! token-identical results.
//!
//! **Frozen-scale registration.** [`PrefixIndex::register`] does two
//! things per newly inserted chunk: it takes one arena reference on the
//! page, and it *freezes* the page through
//! [`BlockAllocator::freeze_page`] — from that point the page's bytes
//! and (for quantized stores) its per-head quantizer scales are
//! immutable until the page is freed and reallocated. Registered chunks
//! are always full pages, every slot written during the donor's
//! prefill, and no later append can land inside them, so freezing
//! asserts an invariant the write path already guarantees — and it is
//! what makes a frozen page a byte-exact artifact: the store may cache
//! its dequantized tile, and a recipient that shares it reads exactly
//! the bytes its own prefill would have produced, independent of
//! serving order (DESIGN.md §4).
//!
//! Generated tokens are never registered — only prompt pages freeze
//! (the standard system-prompt sharing workload). Under admission
//! pressure the coordinator evicts via
//! [`PrefixIndex::evict_unreferenced`], which frees only nodes with zero
//! live leases: flushing a node whose page a live block table still
//! references frees no memory (the refcount keeps the page resident) and
//! would only destroy reuse for the sequences mid-flight on that prefix.
//! [`PrefixIndex::clear`] remains as the wholesale reset. Finer-grained
//! LRU over unreferenced nodes is a ROADMAP follow-on.

use super::allocator::{BlockAllocator, PageId};
use super::table::BlockTable;

struct Node {
    /// Edges: full-page token chunk → child node index.
    children: Vec<(Box<[u32]>, usize)>,
    /// The frozen page holding this chunk's KV rows (one index-owned
    /// reference). `PageId::MAX` sentinel on the root, which has no page.
    page: PageId,
}

/// Refcounted radix index over registered prompt prefixes.
///
/// ```
/// use sherry::cache::{BlockAllocator, BlockTable, PrefixIndex};
/// use sherry::engine::NativeConfig;
///
/// let cfg = NativeConfig::named("nano").unwrap();
/// let mut alloc = BlockAllocator::new(&cfg, /*num_pages=*/ 8, /*page_size=*/ 4);
/// let mut index = PrefixIndex::new(4);
///
/// // A donor prefills a 6-token prompt, then registers it: only the
/// // full 4-token chunk freezes (partial tail pages never register).
/// let prompt: Vec<u32> = vec![10, 11, 12, 13, 20, 21];
/// let mut donor = BlockTable::new(4);
/// for _ in 0..prompt.len() {
///     donor.prepare_append(&mut alloc);
///     donor.advance();
/// }
/// index.register(&prompt, &donor, &mut alloc);
/// assert_eq!(index.pages_held(), 1);
///
/// // A second request with the same prompt can reuse that chunk's page
/// // (capped so at least one token is always fed to produce logits).
/// let (pages, matched) = index.probe_pages(&prompt, prompt.len() - 1);
/// assert_eq!(matched, 4);
/// assert_eq!(pages, &donor.pages()[..1]);
///
/// // Retirement: the donor returns its references; the index's own
/// // reference keeps the frozen page resident until eviction.
/// donor.release_all(&mut alloc);
/// assert_eq!(alloc.used_pages(), 1);
/// assert_eq!(index.evict_unreferenced(&mut alloc), 1);
/// assert_eq!(alloc.used_pages(), 0);
/// ```
pub struct PrefixIndex {
    page_size: usize,
    nodes: Vec<Node>,
}

fn common_prefix(a: &[u32], b: &[u32]) -> usize {
    a.iter().zip(b).take_while(|(x, y)| x == y).count()
}

impl PrefixIndex {
    pub fn new(page_size: usize) -> Self {
        assert!(page_size > 0);
        Self { page_size, nodes: vec![Node { children: Vec::new(), page: PageId::MAX }] }
    }

    pub fn page_size(&self) -> usize {
        self.page_size
    }

    /// Pages the index holds references to (one per non-root node).
    pub fn pages_held(&self) -> usize {
        self.nodes.len() - 1
    }

    /// Longest reusable prefix of `prompt`, capped at `cap` tokens, plus
    /// the pages covering it (`ceil(matched / page_size)` pages; the last
    /// may be partially used). Read-only: takes no page references.
    ///
    /// `cap` exists because a request must always feed at least its final
    /// prompt token to produce logits, and may never feed past the
    /// context limit — callers pass `min(prompt_len - 1, seq_len - 1)`.
    pub fn probe_pages(&self, prompt: &[u32], cap: usize) -> (Vec<PageId>, usize) {
        let ps = self.page_size;
        let mut pages = Vec::new();
        let mut matched = 0usize;
        let mut node = 0usize;
        while matched < cap {
            let remaining = &prompt[matched..];
            let mut best: Option<(usize, usize)> = None; // (common_len, child)
            for (edge, child) in &self.nodes[node].children {
                let m = common_prefix(edge, remaining);
                if m > best.map_or(0, |(b, _)| b) {
                    best = Some((m, *child));
                }
            }
            let Some((m, child)) = best else { break };
            let use_len = m.min(cap - matched);
            if use_len == 0 {
                break;
            }
            pages.push(self.nodes[child].page);
            matched += use_len;
            if use_len < ps {
                break; // partial page: divergence, prompt end, or cap
            }
            node = child;
        }
        (pages, matched)
    }

    /// Reusable-prefix length only (admission cost estimation).
    pub fn probe_len(&self, prompt: &[u32], cap: usize) -> usize {
        self.probe_pages(prompt, cap).1
    }

    /// Freeze the full-page chunks of `prompt` into the index: take one
    /// arena reference per newly inserted page and freeze its bytes and
    /// quantizer scales ([`BlockAllocator::freeze_page`]) so the page
    /// becomes an immutable, byte-exact artifact for every future
    /// recipient. Chunks already present are left untouched (identical
    /// tokens ⇒ identical KV rows and — for quantized stores — an
    /// identical quantization trajectory, so the existing page is
    /// byte-equal to `table`'s). Call after prefill — every prompt
    /// position must be resident in `table`.
    pub fn register(&mut self, prompt: &[u32], table: &BlockTable, alloc: &mut BlockAllocator) {
        let ps = self.page_size;
        debug_assert_eq!(ps, alloc.page_size());
        debug_assert!(table.len() >= prompt.len(), "register before prefill completed");
        let mut node = 0usize;
        for (i, chunk) in prompt.chunks_exact(ps).enumerate() {
            if let Some(&(_, child)) =
                self.nodes[node].children.iter().find(|(edge, _)| edge.as_ref() == chunk)
            {
                node = child;
                continue;
            }
            let page = table.pages()[i];
            alloc.retain(page);
            alloc.freeze_page(page);
            let id = self.nodes.len();
            self.nodes.push(Node { children: Vec::new(), page });
            self.nodes[node].children.push((chunk.to_vec().into_boxed_slice(), id));
            node = id;
        }
    }

    /// Release every index-held page and reset to empty (wholesale reset;
    /// pressure eviction uses [`PrefixIndex::evict_unreferenced`]).
    pub fn clear(&mut self, alloc: &mut BlockAllocator) {
        for node in self.nodes.drain(1..) {
            alloc.release(node.page);
        }
        self.nodes[0].children.clear();
    }

    /// Evict only nodes with **zero live leases**: a node is dropped iff
    /// its page's only remaining reference is the index's own (refcount
    /// 1) *and* its whole subtree is likewise unreferenced — dropping an
    /// interior node whose descendant is still leased would sever the
    /// probe path to pages that remain resident anyway. Returns the
    /// number of pages actually freed back to the arena.
    ///
    /// This is the admission-pressure valve: unlike a wholesale
    /// [`PrefixIndex::clear`], prefixes that live block tables are
    /// actively decoding through stay probe-able (flushing them frees no
    /// memory — the lease refcount keeps the page resident — so clearing
    /// them only destroyed reuse).
    pub fn evict_unreferenced(&mut self, alloc: &mut BlockAllocator) -> usize {
        // Post-order: keep[id] = any child kept, or the page is leased.
        fn walk(nodes: &[Node], alloc: &BlockAllocator, id: usize, keep: &mut [bool]) -> bool {
            let mut kept = id == 0; // the pageless root always stays
            for &(_, child) in &nodes[id].children {
                kept |= walk(nodes, alloc, child, keep);
            }
            if !kept && alloc.ref_count(nodes[id].page) > 1 {
                kept = true;
            }
            keep[id] = kept;
            kept
        }
        let mut keep = vec![false; self.nodes.len()];
        walk(&self.nodes, alloc, 0, &mut keep);

        // Compact: remap kept nodes, release dropped pages, drop edges to
        // evicted children.
        let mut remap = vec![usize::MAX; self.nodes.len()];
        let mut next = 0usize;
        for (id, &k) in keep.iter().enumerate() {
            if k {
                remap[id] = next;
                next += 1;
            }
        }
        let mut freed = 0usize;
        let old = std::mem::take(&mut self.nodes);
        for (id, mut node) in old.into_iter().enumerate() {
            if keep[id] {
                node.children.retain_mut(|(_, child)| {
                    if keep[*child] {
                        *child = remap[*child];
                        true
                    } else {
                        false
                    }
                });
                self.nodes.push(node);
            } else {
                // Dropped ⇒ refcount was exactly 1 (ours): rc > 1 keeps a
                // node, and no two nodes share a page. Releasing frees it.
                debug_assert_eq!(alloc.ref_count(node.page), 1);
                alloc.release(node.page);
                freed += 1;
            }
        }
        freed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::NativeConfig;

    fn arena(pages: usize, ps: usize) -> BlockAllocator {
        BlockAllocator::new(&NativeConfig::named("nano").unwrap(), pages, ps)
    }

    /// Build a table holding `positions` freshly allocated positions.
    fn filled_table(a: &mut BlockAllocator, positions: usize) -> BlockTable {
        let mut t = BlockTable::new(a.page_size());
        for _ in 0..positions {
            t.prepare_append(a);
            t.advance();
        }
        t
    }

    #[test]
    fn empty_index_matches_nothing() {
        let idx = PrefixIndex::new(4);
        let (pages, matched) = idx.probe_pages(&[1, 2, 3, 4, 5], 4);
        assert!(pages.is_empty());
        assert_eq!(matched, 0);
    }

    #[test]
    fn register_then_probe_full_and_partial() {
        let mut a = arena(8, 4);
        let prompt: Vec<u32> = vec![10, 11, 12, 13, 20, 21, 22, 23, 30]; // 2 full chunks + tail
        let t = filled_table(&mut a, prompt.len());
        let mut idx = PrefixIndex::new(4);
        idx.register(&prompt, &t, &mut a);
        assert_eq!(idx.pages_held(), 2, "only full-page chunks freeze");

        // Identical prompt: both full chunks reusable (cap leaves ≥1 token).
        let (pages, matched) = idx.probe_pages(&prompt, prompt.len() - 1);
        assert_eq!(matched, 8);
        assert_eq!(pages, &t.pages()[..2]);

        // Prompt diverging inside chunk 2: partial share of page 1.
        let other: Vec<u32> = vec![10, 11, 12, 13, 20, 21, 99, 99, 7];
        let (pages, matched) = idx.probe_pages(&other, other.len() - 1);
        assert_eq!(matched, 6);
        assert_eq!(pages.len(), 2);

        // Prompt diverging at token 0: no share.
        assert_eq!(idx.probe_len(&[5, 5, 5, 5], 3), 0);
    }

    #[test]
    fn cap_truncates_match() {
        let mut a = arena(8, 4);
        let prompt: Vec<u32> = (0..8).collect();
        let t = filled_table(&mut a, prompt.len());
        let mut idx = PrefixIndex::new(4);
        idx.register(&prompt, &t, &mut a);
        // cap 7 < full match 8 → last page shared partially.
        let (pages, matched) = idx.probe_pages(&prompt, 7);
        assert_eq!(matched, 7);
        assert_eq!(pages.len(), 2);
        // cap 3 → only a prefix of the first page.
        let (pages, matched) = idx.probe_pages(&prompt, 3);
        assert_eq!(matched, 3);
        assert_eq!(pages.len(), 1);
    }

    #[test]
    fn evict_spares_nodes_with_live_leases() {
        // Two registered prompts; a live block table leases the pages of
        // the first. Pressure eviction must free only the second prompt's
        // nodes — the leased prefix stays probe-able (regression: the old
        // wholesale flush dropped it while freeing zero bytes for it).
        let mut a = arena(16, 4);
        let p1: Vec<u32> = (0..8).collect();
        let p2: Vec<u32> = (100..108).collect();
        let mut idx = PrefixIndex::new(4);
        let mut t1 = filled_table(&mut a, p1.len());
        idx.register(&p1, &t1, &mut a);
        let mut t2 = filled_table(&mut a, p2.len());
        idx.register(&p2, &t2, &mut a);
        assert_eq!(idx.pages_held(), 4);

        // A recipient leases p1's two frozen pages; donors retire.
        let (shared_pages, matched) = idx.probe_pages(&p1, 7);
        assert_eq!(matched, 7);
        for &p in &shared_pages {
            a.retain(p);
        }
        let mut lease = BlockTable::from_shared(4, shared_pages, matched);
        t1.release_all(&mut a);
        t2.release_all(&mut a);

        let freed = idx.evict_unreferenced(&mut a);
        assert_eq!(freed, 2, "only the unleased prompt's pages free");
        assert_eq!(idx.pages_held(), 2, "leased nodes survive");
        assert_eq!(idx.probe_len(&p1, 7), 7, "leased prefix still probe-able");
        assert_eq!(idx.probe_len(&p2, 7), 0, "unleased prefix evicted");

        // Once the lease retires, a second eviction frees the rest.
        lease.release_all(&mut a);
        assert_eq!(idx.evict_unreferenced(&mut a), 2);
        assert_eq!(idx.pages_held(), 0);
        assert_eq!(a.used_pages(), 0);
    }

    #[test]
    fn evict_keeps_unreferenced_ancestor_of_leased_child() {
        // Prompt spanning 3 pages; a lease holds only the *last* page's
        // node alive. Its ancestors must survive too (the probe path), and
        // nothing may be freed while the leaf is leased.
        let mut a = arena(16, 4);
        let prompt: Vec<u32> = (0..12).collect();
        let mut idx = PrefixIndex::new(4);
        let mut t = filled_table(&mut a, prompt.len());
        idx.register(&prompt, &t, &mut a);
        let leaf_page = t.pages()[2];
        a.retain(leaf_page); // simulate a live lease of the deepest chunk
        t.release_all(&mut a);

        assert_eq!(idx.evict_unreferenced(&mut a), 0, "leased subtree pins its path");
        assert_eq!(idx.pages_held(), 3);
        assert_eq!(idx.probe_len(&prompt, 11), 11);

        a.release(leaf_page);
        assert_eq!(idx.evict_unreferenced(&mut a), 3);
        assert_eq!(a.used_pages(), 0);
    }

    #[test]
    fn register_is_idempotent_and_refcounts_balance() {
        let mut a = arena(8, 4);
        let prompt: Vec<u32> = (100..108).collect();
        let mut t = filled_table(&mut a, prompt.len());
        let mut idx = PrefixIndex::new(4);
        idx.register(&prompt, &t, &mut a);
        idx.register(&prompt, &t, &mut a);
        assert_eq!(idx.pages_held(), 2);
        let frozen = [t.pages()[0], t.pages()[1]];
        assert_eq!(a.ref_count(frozen[0]), 2); // table + index
        t.release_all(&mut a);
        assert_eq!(a.ref_count(frozen[0]), 1); // index keeps it alive
        idx.clear(&mut a);
        assert_eq!(a.used_pages(), 0);
        assert_eq!(idx.pages_held(), 0);
    }
}
