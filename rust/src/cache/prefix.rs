//! Radix prefix index: maps prompt-token prefixes to frozen KV pages.
//!
//! A trie whose edges are full-page token chunks (`page_size` tokens).
//! Each non-root node owns one reference to the arena page holding the
//! KV rows of its chunk. A newly admitted request walks the trie with
//! its prompt: every fully matched chunk contributes a whole shared
//! page; a partial match on the last chunk shares the page's live
//! prefix (the recipient copy-on-writes at first divergence — see
//! `super::table`). Because K/V rows are a deterministic function of the
//! token prefix (causal attention, absolute-position RoPE, bit-for-bit
//! batched kernels), reusing a registered page is exact, not
//! approximate: prefill for the shared span is skipped with
//! token-identical results.
//!
//! Generated tokens are never registered — only prompt pages freeze
//! (the standard system-prompt sharing workload). Index-held pages are
//! released wholesale via [`PrefixIndex::clear`]; finer-grained
//! eviction (LRU over nodes) is a ROADMAP follow-on.

use super::allocator::{BlockAllocator, PageId};
use super::table::BlockTable;

struct Node {
    /// Edges: full-page token chunk → child node index.
    children: Vec<(Box<[u32]>, usize)>,
    /// The frozen page holding this chunk's KV rows (one index-owned
    /// reference). `PageId::MAX` sentinel on the root, which has no page.
    page: PageId,
}

/// Refcounted radix index over registered prompt prefixes.
pub struct PrefixIndex {
    page_size: usize,
    nodes: Vec<Node>,
}

fn common_prefix(a: &[u32], b: &[u32]) -> usize {
    a.iter().zip(b).take_while(|(x, y)| x == y).count()
}

impl PrefixIndex {
    pub fn new(page_size: usize) -> Self {
        assert!(page_size > 0);
        Self { page_size, nodes: vec![Node { children: Vec::new(), page: PageId::MAX }] }
    }

    pub fn page_size(&self) -> usize {
        self.page_size
    }

    /// Pages the index holds references to (one per non-root node).
    pub fn pages_held(&self) -> usize {
        self.nodes.len() - 1
    }

    /// Longest reusable prefix of `prompt`, capped at `cap` tokens, plus
    /// the pages covering it (`ceil(matched / page_size)` pages; the last
    /// may be partially used). Read-only: takes no page references.
    ///
    /// `cap` exists because a request must always feed at least its final
    /// prompt token to produce logits, and may never feed past the
    /// context limit — callers pass `min(prompt_len - 1, seq_len - 1)`.
    pub fn probe_pages(&self, prompt: &[u32], cap: usize) -> (Vec<PageId>, usize) {
        let ps = self.page_size;
        let mut pages = Vec::new();
        let mut matched = 0usize;
        let mut node = 0usize;
        while matched < cap {
            let remaining = &prompt[matched..];
            let mut best: Option<(usize, usize)> = None; // (common_len, child)
            for (edge, child) in &self.nodes[node].children {
                let m = common_prefix(edge, remaining);
                if m > best.map_or(0, |(b, _)| b) {
                    best = Some((m, *child));
                }
            }
            let Some((m, child)) = best else { break };
            let use_len = m.min(cap - matched);
            if use_len == 0 {
                break;
            }
            pages.push(self.nodes[child].page);
            matched += use_len;
            if use_len < ps {
                break; // partial page: divergence, prompt end, or cap
            }
            node = child;
        }
        (pages, matched)
    }

    /// Reusable-prefix length only (admission cost estimation).
    pub fn probe_len(&self, prompt: &[u32], cap: usize) -> usize {
        self.probe_pages(prompt, cap).1
    }

    /// Freeze the full-page chunks of `prompt` into the index, taking one
    /// arena reference per newly inserted page. Chunks already present
    /// are left untouched (identical tokens ⇒ identical KV rows, so the
    /// existing page is as good as `table`'s). Call after prefill — every
    /// prompt position must be resident in `table`.
    pub fn register(&mut self, prompt: &[u32], table: &BlockTable, alloc: &mut BlockAllocator) {
        let ps = self.page_size;
        debug_assert_eq!(ps, alloc.page_size());
        debug_assert!(table.len() >= prompt.len(), "register before prefill completed");
        let mut node = 0usize;
        for (i, chunk) in prompt.chunks_exact(ps).enumerate() {
            if let Some(&(_, child)) =
                self.nodes[node].children.iter().find(|(edge, _)| edge.as_ref() == chunk)
            {
                node = child;
                continue;
            }
            let page = table.pages()[i];
            alloc.retain(page);
            let id = self.nodes.len();
            self.nodes.push(Node { children: Vec::new(), page });
            self.nodes[node].children.push((chunk.to_vec().into_boxed_slice(), id));
            node = id;
        }
    }

    /// Release every index-held page and reset to empty — the flush
    /// "eviction policy" the coordinator falls back on when frozen pages
    /// would otherwise starve admission.
    pub fn clear(&mut self, alloc: &mut BlockAllocator) {
        for node in self.nodes.drain(1..) {
            alloc.release(node.page);
        }
        self.nodes[0].children.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::NativeConfig;

    fn arena(pages: usize, ps: usize) -> BlockAllocator {
        BlockAllocator::new(&NativeConfig::named("nano").unwrap(), pages, ps)
    }

    /// Build a table holding `positions` freshly allocated positions.
    fn filled_table(a: &mut BlockAllocator, positions: usize) -> BlockTable {
        let mut t = BlockTable::new(a.page_size());
        for _ in 0..positions {
            t.prepare_append(a);
            t.advance();
        }
        t
    }

    #[test]
    fn empty_index_matches_nothing() {
        let idx = PrefixIndex::new(4);
        let (pages, matched) = idx.probe_pages(&[1, 2, 3, 4, 5], 4);
        assert!(pages.is_empty());
        assert_eq!(matched, 0);
    }

    #[test]
    fn register_then_probe_full_and_partial() {
        let mut a = arena(8, 4);
        let prompt: Vec<u32> = vec![10, 11, 12, 13, 20, 21, 22, 23, 30]; // 2 full chunks + tail
        let t = filled_table(&mut a, prompt.len());
        let mut idx = PrefixIndex::new(4);
        idx.register(&prompt, &t, &mut a);
        assert_eq!(idx.pages_held(), 2, "only full-page chunks freeze");

        // Identical prompt: both full chunks reusable (cap leaves ≥1 token).
        let (pages, matched) = idx.probe_pages(&prompt, prompt.len() - 1);
        assert_eq!(matched, 8);
        assert_eq!(pages, &t.pages()[..2]);

        // Prompt diverging inside chunk 2: partial share of page 1.
        let other: Vec<u32> = vec![10, 11, 12, 13, 20, 21, 99, 99, 7];
        let (pages, matched) = idx.probe_pages(&other, other.len() - 1);
        assert_eq!(matched, 6);
        assert_eq!(pages.len(), 2);

        // Prompt diverging at token 0: no share.
        assert_eq!(idx.probe_len(&[5, 5, 5, 5], 3), 0);
    }

    #[test]
    fn cap_truncates_match() {
        let mut a = arena(8, 4);
        let prompt: Vec<u32> = (0..8).collect();
        let t = filled_table(&mut a, prompt.len());
        let mut idx = PrefixIndex::new(4);
        idx.register(&prompt, &t, &mut a);
        // cap 7 < full match 8 → last page shared partially.
        let (pages, matched) = idx.probe_pages(&prompt, 7);
        assert_eq!(matched, 7);
        assert_eq!(pages.len(), 2);
        // cap 3 → only a prefix of the first page.
        let (pages, matched) = idx.probe_pages(&prompt, 3);
        assert_eq!(matched, 3);
        assert_eq!(pages.len(), 1);
    }

    #[test]
    fn register_is_idempotent_and_refcounts_balance() {
        let mut a = arena(8, 4);
        let prompt: Vec<u32> = (100..108).collect();
        let mut t = filled_table(&mut a, prompt.len());
        let mut idx = PrefixIndex::new(4);
        idx.register(&prompt, &t, &mut a);
        idx.register(&prompt, &t, &mut a);
        assert_eq!(idx.pages_held(), 2);
        let frozen = [t.pages()[0], t.pages()[1]];
        assert_eq!(a.ref_count(frozen[0]), 2); // table + index
        t.release_all(&mut a);
        assert_eq!(a.ref_count(frozen[0]), 1); // index keeps it alive
        idx.clear(&mut a);
        assert_eq!(a.used_pages(), 0);
        assert_eq!(idx.pages_held(), 0);
    }
}
