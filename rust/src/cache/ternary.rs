//! [`TernaryStore`] — 1.25-bit 3:4-sparse ternary K pages with int8 V
//! pages: the paper's weight format (§3.1, App. A) applied to the live
//! KV cache, which the Limitations section singles out as the dominant
//! transient memory once weights are 1.25-bit.
//!
//! **K plane.** Each written K row is ternarized per head with the
//! streaming b1.58 absmean rule ([`crate::quant::absmean`]): per
//! 4-channel block the smallest-|x| lane is zeroed (stable argmin) and
//! the kept lanes store `sign(x)` with `sign(0) = +1` — so every block
//! holds exactly one zero and packs through the weight path's `pack34`
//! codec: a 4-bit pattern index + 1 mirror bit = 5 bits per 4 channels
//! = **1.25 bits/channel**. Codes are scale-independent; the one f32
//! scale per (layer, page, head) is the running absmean of the kept
//! lanes of the rows written so far, updated as a pure fold in write
//! order (no requantization cascade can ever touch written bytes —
//! unlike int8 absmax growth).
//!
//! **Per-(slot, head) lane layout** (byte-aligned, row-major over
//! `(slot, head)`): `idx_bh = (hd/4).div_ceil(2)` nibble bytes (low
//! nibble = even block) then `sign_bh = (hd/4).div_ceil(8)` mirror-bit
//! bytes (bit `b % 8` of byte `b / 8`). At nano (hd = 32): 4 + 1 = 5
//! bytes per head, 20 B per slot of K vs 128 B int8 / 512 B f32.
//!
//! **V plane** stays int8 — V rows feed the attention-weighted *sum*
//! where ternary's 1-bit mantissa is too coarse — reusing
//! [`Int8Store`]'s exact write path so identical writes produce
//! identical V bytes in both stores.
//!
//! **Frozen-byte invariants** (the PR 5 registration protocol, verbatim):
//! after [`PageStore::freeze_page`] the page's packed K nibbles, mirror
//! bits, absmean scales *and accumulator state*, int8 V bytes, and V
//! scales are all immutable until `reset_page` thaws it. A frozen page
//! is therefore a byte-exact artifact: shared-prefix reads are
//! serving-order invariant, [`PageStore::frozen_tile`] may cache its
//! dequantized form, and [`PageStore::block_ternary`] views can be
//! LUT-walked concurrently with no synchronization. `copy_rows` (CoW)
//! carries packed bytes, scales, and the `(sum_abs, count)` accumulator,
//! so a divergent copy dequantizes identically at copy time and keeps
//! appending on the donor's absmean trajectory.
//!
//! The attention score pass never dequantizes K: it consumes
//! [`PageStore::block_ternary`] through per-query 32-entry LUTs
//! (`simd::qk_lut34_rows`; bound derived in DESIGN.md §4).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::engine::NativeConfig;
use crate::pack::pack34::{decode_block, encode_block};
use crate::quant::absmean::{absmean_scale, kept_abs_sum, sparsify34_codes};

use super::store::{
    dequant_i8_rows, Int8Store, KvDtype, PageId, PageStore, Plane, TernaryBlock, TileCache,
    DEFAULT_TILE_CACHE_TILES,
};

/// 1.25-bit ternary-K / int8-V page store. See the module docs for the
/// layout and invariants; `tests/paged_kv.rs` property-tests the
/// lifecycle end-to-end.
pub struct TernaryStore {
    page_size: usize,
    d_model: usize,
    n_layers: usize,
    n_heads: usize,
    head_dim: usize,
    num_pages: usize,
    /// pack34 blocks per head lane: `head_dim / 4`.
    nb: usize,
    /// Index bytes per (slot, head) lane: `nb.div_ceil(2)`.
    idx_bh: usize,
    /// Sign bytes per (slot, head) lane: `nb.div_ceil(8)`.
    sign_bh: usize,
    /// Per-layer K index planes: `num_pages·page_size·n_heads·idx_bh` bytes.
    k_idx: Vec<Vec<u8>>,
    /// Per-layer K mirror planes: `num_pages·page_size·n_heads·sign_bh` bytes.
    k_sign: Vec<Vec<u8>>,
    /// `[layer][p·n_heads + h]` K absmean scales (materialized from the
    /// accumulator after every write so block reads are pure loads).
    k_scales: Vec<Vec<f32>>,
    /// `[layer][p·n_heads + h]` running Σ|x| over kept lanes.
    k_sum_abs: Vec<Vec<f32>>,
    /// `[layer][p·n_heads + h]` kept-lane count behind `k_sum_abs`.
    k_count: Vec<Vec<u32>>,
    /// Int8 V planes + scales, laid out exactly like [`Int8Store`]'s.
    v: Vec<Vec<i8>>,
    v_scales: Vec<Vec<f32>>,
    /// Registration-frozen pages (one flag per page, all layers/planes).
    frozen: Vec<bool>,
    /// LRU of dequantized full-page tiles for frozen pages (residual
    /// f32 consumers; the integer a·V pass bypasses it).
    tiles: TileCache,
    /// Allocator-reported refcount per page; `u32::MAX` = never
    /// notified (no allocator → admit every tile).
    lease_refs: Vec<u32>,
    /// Integer a·V path toggle (default on): serve the V plane through
    /// `block_i8` so attention accumulates in i32 over raw page bytes.
    integer_av: bool,
    /// Reusable per-write codes scratch (`d_model` lanes).
    codes: Vec<i8>,
    dequant_ns: AtomicU64,
    qk_native: AtomicU64,
    qk_dequant: AtomicU64,
    qk_ternary: AtomicU64,
    /// Attention a·V rows accumulated int8-natively.
    av_int8: AtomicU64,
}

impl TernaryStore {
    pub fn new(cfg: &NativeConfig, num_pages: usize, page_size: usize) -> Self {
        assert_eq!(cfg.d_model % cfg.n_heads, 0, "d_model must split into heads");
        let hd = cfg.head_dim();
        assert_eq!(hd % 4, 0, "ternary KV needs head_dim % 4 == 0 (3:4 blocks)");
        let nb = hd / 4;
        let idx_bh = nb.div_ceil(2);
        let sign_bh = nb.div_ceil(8);
        let slots = num_pages * page_size * cfg.n_heads;
        let scales = num_pages * cfg.n_heads;
        let v_plane = num_pages * page_size * cfg.d_model;
        Self {
            page_size,
            d_model: cfg.d_model,
            n_layers: cfg.n_layers,
            n_heads: cfg.n_heads,
            head_dim: hd,
            num_pages,
            nb,
            idx_bh,
            sign_bh,
            k_idx: (0..cfg.n_layers).map(|_| vec![0; slots * idx_bh]).collect(),
            k_sign: (0..cfg.n_layers).map(|_| vec![0; slots * sign_bh]).collect(),
            k_scales: (0..cfg.n_layers).map(|_| vec![0.0; scales]).collect(),
            k_sum_abs: (0..cfg.n_layers).map(|_| vec![0.0; scales]).collect(),
            k_count: (0..cfg.n_layers).map(|_| vec![0; scales]).collect(),
            v: (0..cfg.n_layers).map(|_| vec![0; v_plane]).collect(),
            v_scales: (0..cfg.n_layers).map(|_| vec![0.0; scales]).collect(),
            frozen: vec![false; num_pages],
            tiles: TileCache::new(DEFAULT_TILE_CACHE_TILES),
            lease_refs: vec![u32::MAX; num_pages],
            integer_av: true,
            codes: vec![0; cfg.d_model],
            dequant_ns: AtomicU64::new(0),
            qk_native: AtomicU64::new(0),
            qk_dequant: AtomicU64::new(0),
            qk_ternary: AtomicU64::new(0),
            av_int8: AtomicU64::new(0),
        }
    }

    /// Tile-cache admission (same policy as `Int8Store`): a frozen
    /// page's refcount is `leases + 1` (the prefix index holds one),
    /// so require `refs ≥ 3`; never-notified pages always admit.
    fn admit_tile(&self, p: PageId) -> bool {
        let refs = self.lease_refs[p as usize];
        refs == u32::MAX || refs >= 3
    }

    /// K absmean scale of (layer, page, head) (tests / diagnostics).
    pub fn k_scale(&self, layer: usize, p: PageId, head: usize) -> f32 {
        self.k_scales[layer][p as usize * self.n_heads + head]
    }

    /// Absmean accumulator of (layer, page, head): `(Σ|x| kept, count)`.
    pub fn k_state(&self, layer: usize, p: PageId, head: usize) -> (f32, u32) {
        let si = p as usize * self.n_heads + head;
        (self.k_sum_abs[layer][si], self.k_count[layer][si])
    }

    /// Byte offset of (page, slot, head)'s lane in a per-`bh`-byte plane.
    #[inline]
    fn lane_base(&self, p: usize, slot: usize, head: usize, bh: usize) -> usize {
        ((p * self.page_size + slot) * self.n_heads + head) * bh
    }

    /// Decode the first `rows` K rows of page `p` into `out`
    /// (`rows × d_model` floats): codes × per-head absmean scale. Only
    /// the fallback/tile path uses this — attention walks the packed
    /// bytes via [`PageStore::block_ternary`] instead.
    fn dequant_k_into(&self, layer: usize, p: PageId, rows: usize, out: &mut Vec<f32>) {
        let (d, nh) = (self.d_model, self.n_heads);
        out.resize(rows * d, 0.0);
        let sbase = p as usize * nh;
        for r in 0..rows {
            for h in 0..nh {
                let s = self.k_scales[layer][sbase + h];
                let ib = self.lane_base(p as usize, r, h, self.idx_bh);
                let mb = self.lane_base(p as usize, r, h, self.sign_bh);
                let col0 = h * self.head_dim;
                for b in 0..self.nb {
                    let nib = (self.k_idx[layer][ib + b / 2] >> ((b % 2) * 4)) & 0x0F;
                    let mirror = (self.k_sign[layer][mb + b / 8] >> (b % 8)) & 1 == 1;
                    let pat = decode_block(nib, mirror);
                    for (lane, &t) in pat.iter().enumerate() {
                        out[r * d + col0 + b * 4 + lane] = t as f32 * s;
                    }
                }
            }
        }
    }

    fn dequant_into(&self, plane: Plane, layer: usize, p: PageId, rows: usize, out: &mut Vec<f32>) {
        match plane {
            Plane::K => self.dequant_k_into(layer, p, rows, out),
            Plane::V => dequant_i8_rows(
                &self.v[layer],
                &self.v_scales[layer],
                p as usize,
                self.page_size,
                rows,
                self.d_model,
                self.head_dim,
                self.n_heads,
                out,
            ),
        }
    }
}

impl PageStore for TernaryStore {
    fn dtype(&self) -> KvDtype {
        KvDtype::Ternary
    }

    fn reset_page(&mut self, p: PageId) {
        self.frozen[p as usize] = false;
        self.tiles.invalidate_page(p);
        let s0 = p as usize * self.n_heads;
        for li in 0..self.n_layers {
            self.k_scales[li][s0..s0 + self.n_heads].fill(0.0);
            self.k_sum_abs[li][s0..s0 + self.n_heads].fill(0.0);
            self.k_count[li][s0..s0 + self.n_heads].fill(0);
            self.v_scales[li][s0..s0 + self.n_heads].fill(0.0);
        }
    }

    fn write_row(&mut self, layer: usize, p: PageId, slot: usize, k_row: &[f32], v_row: &[f32]) {
        debug_assert!(slot < self.page_size);
        debug_assert_eq!(k_row.len(), self.d_model);
        debug_assert!(!self.frozen[p as usize], "write to a registration-frozen page");
        let (ps, d, hd, nh) = (self.page_size, self.d_model, self.head_dim, self.n_heads);
        let mut codes = std::mem::take(&mut self.codes);
        sparsify34_codes(k_row, &mut codes);
        for h in 0..nh {
            let col0 = h * hd;
            // Running absmean over kept lanes; materialize the scale so
            // reads are pure loads. Codes never depend on it.
            let si = p as usize * nh + h;
            self.k_sum_abs[layer][si] += kept_abs_sum(&k_row[col0..col0 + hd], &codes[col0..col0 + hd]);
            self.k_count[layer][si] += (3 * hd / 4) as u32;
            self.k_scales[layer][si] = absmean_scale(self.k_sum_abs[layer][si], self.k_count[layer][si]);
            // Pack the lane: clear-then-set — neighbouring blocks share
            // nibble/sign bytes and slots are rewritable after reset.
            let ib = self.lane_base(p as usize, slot, h, self.idx_bh);
            let mb = self.lane_base(p as usize, slot, h, self.sign_bh);
            self.k_idx[layer][ib..ib + self.idx_bh].fill(0);
            self.k_sign[layer][mb..mb + self.sign_bh].fill(0);
            for b in 0..self.nb {
                let (code, mirror) = encode_block(&codes[col0 + b * 4..col0 + b * 4 + 4]);
                self.k_idx[layer][ib + b / 2] |= code << ((b % 2) * 4);
                if mirror {
                    self.k_sign[layer][mb + b / 8] |= 1 << (b % 8);
                }
            }
            // V stays int8: the exact Int8Store write path.
            Int8Store::write_head(
                &mut self.v[layer],
                &mut self.v_scales[layer],
                v_row,
                p as usize,
                slot,
                h,
                ps,
                d,
                hd,
                nh,
            );
        }
        self.codes = codes;
    }

    fn copy_rows(&mut self, src: PageId, dst: PageId, rows: usize) {
        debug_assert!(rows <= self.page_size);
        debug_assert_ne!(src, dst, "CoW onto the same page");
        debug_assert!(!self.frozen[dst as usize], "CoW target must be a fresh page");
        let (ps, d, nh) = (self.page_size, self.d_model, self.n_heads);
        let (src, dst) = (src as usize, dst as usize);
        let (ss, ds) = (src * nh, dst * nh);
        for li in 0..self.n_layers {
            let n = rows * nh * self.idx_bh;
            let (s0, d0) = (src * ps * nh * self.idx_bh, dst * ps * nh * self.idx_bh);
            self.k_idx[li].copy_within(s0..s0 + n, d0);
            let n = rows * nh * self.sign_bh;
            let (s0, d0) = (src * ps * nh * self.sign_bh, dst * ps * nh * self.sign_bh);
            self.k_sign[li].copy_within(s0..s0 + n, d0);
            let n = rows * d;
            let (s0, d0) = (src * ps * d, dst * ps * d);
            self.v[li].copy_within(s0..s0 + n, d0);
            // Carry the quantizer state: the copy dequantizes identically
            // at copy time and later appends continue the donor's absmean
            // trajectory deterministically.
            self.k_scales[li].copy_within(ss..ss + nh, ds);
            self.k_sum_abs[li].copy_within(ss..ss + nh, ds);
            self.k_count[li].copy_within(ss..ss + nh, ds);
            self.v_scales[li].copy_within(ss..ss + nh, ds);
        }
    }

    fn block<'a>(
        &'a self,
        plane: Plane,
        layer: usize,
        p: PageId,
        rows: usize,
        scratch: &'a mut Vec<f32>,
    ) -> &'a [f32] {
        debug_assert!(rows <= self.page_size);
        let t0 = Instant::now();
        self.dequant_into(plane, layer, p, rows, scratch);
        self.dequant_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        &scratch[..rows * self.d_model]
    }

    fn block_i8(&self, plane: Plane, layer: usize, p: PageId, rows: usize) -> Option<(&[i8], &[f32])> {
        // Only V has an int8-native form; K is packed tighter still.
        if !matches!(plane, Plane::V) {
            return None;
        }
        debug_assert!(rows <= self.page_size);
        let pbase = p as usize * self.page_size * self.d_model;
        let sbase = p as usize * self.n_heads;
        Some((
            &self.v[layer][pbase..pbase + rows * self.d_model],
            &self.v_scales[layer][sbase..sbase + self.n_heads],
        ))
    }

    fn block_ternary(&self, layer: usize, p: PageId, rows: usize) -> Option<TernaryBlock<'_>> {
        debug_assert!(rows <= self.page_size);
        let p = p as usize;
        let ib = self.lane_base(p, 0, 0, self.idx_bh);
        let mb = self.lane_base(p, 0, 0, self.sign_bh);
        let sbase = p * self.n_heads;
        Some(TernaryBlock {
            idx: &self.k_idx[layer][ib..ib + rows * self.n_heads * self.idx_bh],
            sign: &self.k_sign[layer][mb..mb + rows * self.n_heads * self.sign_bh],
            scales: &self.k_scales[layer][sbase..sbase + self.n_heads],
            idx_bh: self.idx_bh,
            sign_bh: self.sign_bh,
        })
    }

    fn freeze_page(&mut self, p: PageId) {
        self.frozen[p as usize] = true;
    }

    fn is_frozen(&self, p: PageId) -> bool {
        self.frozen[p as usize]
    }

    fn frozen_tile(&self, plane: Plane, layer: usize, p: PageId) -> Option<Arc<[f32]>> {
        if self.tiles.cap == 0 || !self.frozen[p as usize] {
            return None;
        }
        let key = (plane, layer as u32, p);
        if let Some(tile) = self.tiles.get(key) {
            return Some(tile);
        }
        // Miss: build outside the lock — frozen pages are immutable, so
        // a racing duplicate build produces identical bytes.
        let t0 = Instant::now();
        let mut buf = Vec::new();
        self.dequant_into(plane, layer, p, self.page_size, &mut buf);
        self.dequant_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        let tile: Arc<[f32]> = Arc::from(buf);
        if self.admit_tile(p) {
            self.tiles.insert(key, Arc::clone(&tile));
        } else {
            // Single-reader page: serve but never cache.
            self.tiles.note_miss();
        }
        Some(tile)
    }

    fn set_tile_cache_capacity(&mut self, tiles: usize) {
        self.tiles = TileCache::new(tiles);
    }

    fn tile_cache_stats(&self) -> (u64, u64) {
        self.tiles.stats()
    }

    fn record_qk_rows(&self, native: u64, dequant: u64, ternary: u64) {
        self.qk_native.fetch_add(native, Ordering::Relaxed);
        self.qk_dequant.fetch_add(dequant, Ordering::Relaxed);
        self.qk_ternary.fetch_add(ternary, Ordering::Relaxed);
    }

    fn qk_rows(&self) -> (u64, u64, u64) {
        (
            self.qk_native.load(Ordering::Relaxed),
            self.qk_dequant.load(Ordering::Relaxed),
            self.qk_ternary.load(Ordering::Relaxed),
        )
    }

    fn record_av_rows(&self, int8: u64) {
        self.av_int8.fetch_add(int8, Ordering::Relaxed);
    }

    fn av_rows(&self) -> u64 {
        self.av_int8.load(Ordering::Relaxed)
    }

    fn set_page_leases(&mut self, p: PageId, refs: u32) {
        self.lease_refs[p as usize] = refs;
    }

    fn set_integer_av(&mut self, on: bool) {
        self.integer_av = on;
    }

    fn integer_av_enabled(&self) -> bool {
        self.integer_av
    }

    fn bytes(&self) -> usize {
        let lane = self.idx_bh + self.sign_bh;
        let k_plane = self.page_size * self.n_heads * lane + self.n_heads * 4;
        let v_plane = self.page_size * self.d_model + self.n_heads * 4;
        self.n_layers * self.num_pages * (k_plane + v_plane)
    }

    fn bytes_per_token(&self) -> usize {
        self.k_bytes_per_token() + self.v_bytes_per_token()
    }

    fn k_bytes_per_token(&self) -> usize {
        // 5 bits per 4 channels, byte-aligned per head, + the page's
        // per-head scales amortized over its slots.
        let lane = self.idx_bh + self.sign_bh;
        self.n_layers * (self.n_heads * lane + (self.n_heads * 4).div_ceil(self.page_size))
    }

    fn v_bytes_per_token(&self) -> usize {
        self.n_layers * (self.d_model + (self.n_heads * 4).div_ceil(self.page_size))
    }

    fn dequant_nanos(&self) -> u64 {
        self.dequant_ns.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::page_bytes;
    use crate::util::Pcg64;

    fn cfg() -> NativeConfig {
        NativeConfig::named("nano").unwrap()
    }

    /// Reference dequant of one K row: codes from the pure-fn quantizer
    /// times the *current* per-head scale.
    fn reference_k(row: &[f32], scales: &[f32], hd: usize) -> Vec<f32> {
        let mut codes = vec![0i8; row.len()];
        sparsify34_codes(row, &mut codes);
        codes.iter().enumerate().map(|(c, &t)| t as f32 * scales[c / hd]).collect()
    }

    #[test]
    fn k_roundtrip_is_codes_times_running_absmean() {
        let cfg = cfg();
        let (d, hd) = (cfg.d_model, cfg.head_dim());
        let mut st = TernaryStore::new(&cfg, 2, 4);
        st.reset_page(0);
        let mut rng = Pcg64::seeded(17);
        let rows: Vec<Vec<f32>> = (0..4).map(|_| rng.normal_vec(d)).collect();
        for (s, row) in rows.iter().enumerate() {
            st.write_row(0, 0, s, row, row);
        }
        // Scales must equal the batch absmean over all kept lanes.
        for h in 0..cfg.n_heads {
            let mut sum = 0.0f32;
            let mut n = 0u32;
            for row in &rows {
                let mut codes = vec![0i8; d];
                sparsify34_codes(row, &mut codes);
                let c0 = h * hd;
                sum += kept_abs_sum(&row[c0..c0 + hd], &codes[c0..c0 + hd]);
                n += (3 * hd / 4) as u32;
            }
            assert!((st.k_scale(0, 0, h) - sum / n as f32).abs() < 1e-6);
            assert_eq!(st.k_state(0, 0, h), (sum, n));
        }
        // Every row dequantizes to its (scale-independent) codes times
        // the final scale — earlier rows are never requantized.
        let scales: Vec<f32> = (0..cfg.n_heads).map(|h| st.k_scale(0, 0, h)).collect();
        let mut scratch = Vec::new();
        let blk = st.block(Plane::K, 0, 0, 4, &mut scratch).to_vec();
        for (s, row) in rows.iter().enumerate() {
            assert_eq!(&blk[s * d..(s + 1) * d], &reference_k(row, &scales, hd)[..], "slot {s}");
        }
        assert!(st.dequant_nanos() > 0);
    }

    #[test]
    fn block_ternary_exposes_the_packed_lanes_attention_walks() {
        let cfg = cfg();
        let d = cfg.d_model;
        let mut st = TernaryStore::new(&cfg, 2, 4);
        st.reset_page(1);
        let mut rng = Pcg64::seeded(23);
        for s in 0..3 {
            let row = rng.normal_vec(d);
            st.write_row(1, 1, s, &row, &row);
        }
        let tb = st.block_ternary(1, 1, 3).expect("ternary store is ternary-native");
        assert_eq!(tb.idx.len(), 3 * cfg.n_heads * tb.idx_bh);
        assert_eq!(tb.sign.len(), 3 * cfg.n_heads * tb.sign_bh);
        assert_eq!(tb.scales.len(), cfg.n_heads);
        // Decode the packed lanes by hand; must equal the block() floats.
        let mut scratch = Vec::new();
        let blk = st.block(Plane::K, 1, 1, 3, &mut scratch).to_vec();
        let (hd, nb) = (cfg.head_dim(), cfg.head_dim() / 4);
        for r in 0..3 {
            for h in 0..cfg.n_heads {
                let ib = (r * cfg.n_heads + h) * tb.idx_bh;
                let mb = (r * cfg.n_heads + h) * tb.sign_bh;
                for b in 0..nb {
                    let nib = (tb.idx[ib + b / 2] >> ((b % 2) * 4)) & 0x0F;
                    let mirror = (tb.sign[mb + b / 8] >> (b % 8)) & 1 == 1;
                    let pat = decode_block(nib, mirror);
                    for (lane, &t) in pat.iter().enumerate() {
                        assert_eq!(t as f32 * tb.scales[h], blk[r * d + h * hd + b * 4 + lane]);
                    }
                }
            }
        }
        // V is int8-native; K deliberately is not.
        assert!(st.block_i8(Plane::V, 1, 1, 3).is_some());
        assert!(st.block_i8(Plane::K, 1, 1, 3).is_none());
    }

    #[test]
    fn v_plane_bytes_match_int8_store_exactly() {
        let cfg = cfg();
        let d = cfg.d_model;
        let mut t = TernaryStore::new(&cfg, 1, 4);
        let mut q = Int8Store::new(&cfg, 1, 4);
        t.reset_page(0);
        q.reset_page(0);
        let mut rng = Pcg64::seeded(31);
        for s in 0..4 {
            let row = rng.normal_vec(d);
            t.write_row(0, 0, s, &row, &row);
            q.write_row(0, 0, s, &row, &row);
        }
        let (tv, ts) = t.block_i8(Plane::V, 0, 0, 4).unwrap();
        let (qv, qs) = q.block_i8(Plane::V, 0, 0, 4).unwrap();
        assert_eq!(tv, qv, "identical writes produce identical V bytes");
        assert_eq!(ts, qs);
    }

    #[test]
    fn byte_accounting_matches_page_bytes_and_the_125_bit_ceiling() {
        let cfg = cfg();
        for ps in [4usize, 16] {
            let st = TernaryStore::new(&cfg, 3, ps);
            assert_eq!(st.bytes(), 3 * page_bytes(&cfg, ps, KvDtype::Ternary));
            assert_eq!(st.bytes_per_token(), st.k_bytes_per_token() + st.v_bytes_per_token());
            // Acceptance ceiling: K bytes per token-slot (per layer) stay
            // under ⌈0.3125·page_size·head_dim⌉ + 4·heads.
            let lane = st.idx_bh + st.sign_bh;
            let k_slot = st.n_heads * lane + (st.n_heads * 4).div_ceil(ps);
            let ceiling = (0.3125 * ps as f32 * cfg.head_dim() as f32).ceil() as usize + 4 * cfg.n_heads;
            assert!(k_slot <= ceiling, "K {k_slot} B/slot > ceiling {ceiling}");
        }
        // nano @ page 16: K 42 + V 258 = 300 B/token vs 516 int8, 2048 f32.
        let st = TernaryStore::new(&cfg, 1, 16);
        assert_eq!((st.k_bytes_per_token(), st.v_bytes_per_token()), (42, 258));
        let q = Int8Store::new(&cfg, 1, 16);
        assert!(st.bytes_per_token() < q.bytes_per_token());
        assert!(st.k_bytes_per_token() * 3 <= q.k_bytes_per_token(), "K shrinks ≥3× vs int8");
    }

    #[test]
    fn reset_page_clears_the_absmean_accumulator() {
        let cfg = cfg();
        let d = cfg.d_model;
        let mut st = TernaryStore::new(&cfg, 1, 2);
        st.reset_page(0);
        st.write_row(0, 0, 0, &vec![100.0; d], &vec![100.0; d]);
        assert!(st.k_scale(0, 0, 0) > 50.0);
        st.reset_page(0);
        assert_eq!(st.k_scale(0, 0, 0), 0.0);
        assert_eq!(st.k_state(0, 0, 0), (0.0, 0));
        // A tiny row after reset gets a tiny scale, not the stale one.
        st.write_row(0, 0, 0, &vec![0.01; d], &vec![0.01; d]);
        assert!((st.k_scale(0, 0, 0) - 0.01).abs() < 1e-6);
    }

    #[test]
    fn copy_rows_carries_bytes_scales_and_accumulator() {
        let cfg = cfg();
        let d = cfg.d_model;
        let mut st = TernaryStore::new(&cfg, 2, 4);
        st.reset_page(0);
        st.reset_page(1);
        let mut rng = Pcg64::seeded(41);
        for s in 0..3 {
            let row = rng.normal_vec(d);
            st.write_row(0, 0, s, &row, &row);
        }
        st.copy_rows(0, 1, 3);
        let (mut a, mut b) = (Vec::new(), Vec::new());
        for plane in [Plane::K, Plane::V] {
            assert_eq!(
                st.block(plane, 0, 0, 3, &mut a).to_vec(),
                st.block(plane, 0, 1, 3, &mut b).to_vec(),
                "copy dequantizes identically ({plane:?})"
            );
        }
        for h in 0..cfg.n_heads {
            assert_eq!(st.k_state(0, 0, h), st.k_state(0, 1, h), "accumulator carried");
        }
        // Appending the same row to donor and copy keeps them identical:
        // the copy continues the donor's absmean trajectory.
        let row = rng.normal_vec(d);
        st.write_row(0, 0, 3, &row, &row);
        st.write_row(0, 1, 3, &row, &row);
        assert_eq!(
            st.block(Plane::K, 0, 0, 4, &mut a).to_vec(),
            st.block(Plane::K, 0, 1, 4, &mut b).to_vec()
        );
    }

    #[test]
    fn frozen_tile_serves_both_planes_and_reset_thaws() {
        let cfg = cfg();
        let d = cfg.d_model;
        let mut st = TernaryStore::new(&cfg, 2, 4);
        st.reset_page(0);
        let mut rng = Pcg64::seeded(43);
        for s in 0..4 {
            let row = rng.normal_vec(d);
            st.write_row(0, 0, s, &row, &row);
        }
        assert!(st.frozen_tile(Plane::K, 0, 0).is_none(), "unfrozen pages never serve tiles");
        st.freeze_page(0);
        assert!(st.is_frozen(0));
        let mut scratch = Vec::new();
        for plane in [Plane::K, Plane::V] {
            let tile = st.frozen_tile(plane, 0, 0).expect("frozen page serves a tile");
            assert_eq!(tile.len(), 4 * d);
            assert_eq!(&tile[..], st.block(plane, 0, 0, 4, &mut scratch), "{plane:?}");
        }
        let (hits, misses) = st.tile_cache_stats();
        assert_eq!((hits, misses), (0, 2));
        st.reset_page(0);
        assert!(!st.is_frozen(0));
        assert!(st.frozen_tile(Plane::K, 0, 0).is_none());
    }
}
