//! KV page storage behind the [`PageStore`] trait: the storage *dtype*
//! is a per-pool policy, not a global assumption.
//!
//! The paper's Limitations single out the BF16 KV cache as the dominant
//! transient memory once weights are 1.25-bit; on edge CPUs the decode
//! hot path is memory-bandwidth-bound (BitNet.cpp, TENET), so shrinking
//! KV pages is a latency win as well as a capacity win — *and* keeping
//! the low-bit representation through the compute kernel (not just in
//! storage) is where the bandwidth saving actually lands. Three
//! implementations share one contract:
//!
//! * [`F32Store`] — the parity layout (`num_pages × page_size × d_model`
//!   floats per layer per plane). Block reads *borrow* the plane, so the
//!   f32 path stays bit-for-bit identical to the pre-trait engine.
//! * [`Int8Store`] — int8 pages with **per-page-per-head** f32 scales,
//!   quantized at page-write time. A page's (page, head) scale is the
//!   running absmax of the rows written so far; a row that exceeds the
//!   current range *requantizes* the page's head lane to the grown scale
//!   (one extra quantum of error, bounded — see DESIGN.md §4).
//! * [`TernaryStore`] (`super::ternary`) — 1.25-bit 3:4-sparse ternary
//!   K pages (`pack34` 5-bit blocks + per-(page, head) absmean scales),
//!   int8 V pages. The score pass consumes the packed K bytes through
//!   per-query LUTs ([`PageStore::block_ternary`]) — K is never
//!   dequantized on the attention path.
//!
//! Four read paths exist, cheapest first:
//!
//! 1. [`PageStore::block_ternary`] — the **packed-ternary** view: raw
//!    pack34 index/sign planes plus per-head absmean scales; attention
//!    walks them through 32-entry per-query LUTs (`simd::qk_lut34_rows`).
//! 2. [`PageStore::block_i8`] — the **int8-native** view: raw page bytes
//!    plus the page's per-head scales, so attention computes q·k as an
//!    i32 integer dot with a single `q_scale · page_head_scale` multiply
//!    per (page, head). No dequantization at all on the score path.
//! 3. [`PageStore::frozen_tile`] — a dequantized f32 tile of a *frozen*
//!    (immutable, registration-frozen-scale) page served from a small
//!    shared LRU cache, so a prefix page read by N sequences in a round
//!    is expanded once, not N times. Since the integer a·V pass
//!    (`simd::av_i8_rows`) consumes raw int8 V bytes directly, this is
//!    no longer on the quantized decode hot path — it serves the
//!    residual f32 consumers (integer-V disabled, diagnostics), and
//!    admission is lease-gated ([`PageStore::set_page_leases`]).
//! 4. [`PageStore::block`] — dequantize into caller scratch: the
//!    fallback for private (still-growing) pages.
//!
//! Pages become **frozen** when the prefix index registers them
//! ([`PageStore::freeze_page`]): from that point their bytes *and*
//! scales are immutable until the page is freed (`reset_page` thaws it
//! on the last reference drop), which is what makes shared-prefix reads
//! byte-exact and serving-order independent — see DESIGN.md §4.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, RwLock};
use std::time::Instant;

use crate::engine::NativeConfig;

/// Index of a page in the arena.
pub type PageId = u32;

/// KV storage dtype policy for a paged arena.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum KvDtype {
    /// 4 B/channel float pages (parity baseline; bit-for-bit with the
    /// contiguous engine path).
    #[default]
    F32,
    /// 1 B/channel int8 pages + per-page-per-head f32 scales.
    Int8,
    /// 1.25-bit 3:4-sparse ternary K pages (pack34 5-bit blocks +
    /// per-page-per-head absmean scales); V pages stay int8.
    Ternary,
}

impl KvDtype {
    /// Every dtype, in CLI-listing order — the single source of truth
    /// the parser, its error message, and the sweeps iterate.
    pub const ALL: [KvDtype; 3] = [KvDtype::F32, KvDtype::Int8, KvDtype::Ternary];

    pub fn name(&self) -> &'static str {
        match self {
            KvDtype::F32 => "f32",
            KvDtype::Int8 => "int8",
            KvDtype::Ternary => "ternary",
        }
    }

    /// Parse a CLI/config spelling.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "f32" | "fp32" | "float" => Some(KvDtype::F32),
            "int8" | "i8" => Some(KvDtype::Int8),
            "ternary" | "t34" => Some(KvDtype::Ternary),
            _ => None,
        }
    }

    /// The canonical names, `|`-joined, for help text and errors.
    pub fn valid_names() -> String {
        Self::ALL.iter().map(|d| d.name()).collect::<Vec<_>>().join("|")
    }

    /// Parse, rejecting unknown spellings with an error that lists the
    /// valid set (a typo must never fall through to a default).
    pub fn from_name(s: &str) -> Result<Self, String> {
        Self::parse(s)
            .ok_or_else(|| format!("unknown kv dtype {s:?} (expected one of: {})", Self::valid_names()))
    }
}

/// Which of the two KV planes a read addresses.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Plane {
    K,
    V,
}

/// Storage backend for the paged KV arena: owns the per-layer K/V pages
/// in whatever byte format, and converts to/from f32 rows at the edges.
///
/// Contract (shared by all implementations, property-tested in
/// `tests/paged_kv.rs`):
/// * a slot is written at most once between `reset_page` calls, and only
///   read after it was written (`rows` in `block` never exceeds the
///   written prefix);
/// * `copy_rows` makes `dst`'s first `rows` slots dequantize to the same
///   values `src`'s did at copy time (CoW-through-store), and carries the
///   quantizer state so `dst` can keep appending;
/// * `block` must not change the values a slot dequantizes to (reads are
///   pure) — only `write_row` may (and for quantized stores only within
///   the documented requantization bound);
/// * after `freeze_page`, neither bytes nor quantizer state of the page
///   may change until `reset_page` thaws it — a frozen page is an
///   immutable artifact, which is what lets `frozen_tile` cache its
///   dequantized form and the prefix index share it byte-exactly.
///
/// ```
/// use sherry::cache::{F32Store, PageStore, Plane};
/// use sherry::engine::NativeConfig;
///
/// let cfg = NativeConfig::named("nano").unwrap();
/// let mut store = F32Store::new(&cfg, /*num_pages=*/ 2, /*page_size=*/ 4);
/// let row = vec![0.5f32; cfg.d_model];
/// store.reset_page(0);
/// store.write_row(/*layer=*/ 0, /*page=*/ 0, /*slot=*/ 0, &row, &row);
///
/// // Reads come back as `rows × d_model` f32 blocks; for the f32 store
/// // the block borrows the arena (scratch stays untouched).
/// let mut scratch = Vec::new();
/// let block = store.block(Plane::K, 0, 0, /*rows=*/ 1, &mut scratch);
/// assert_eq!(block, &row[..]);
/// assert_eq!(store.bytes_per_token(), 2 * cfg.n_layers * cfg.d_model * 4);
/// ```
pub trait PageStore: Send + Sync {
    fn dtype(&self) -> KvDtype;

    /// Reset per-page quantizer state and thaw a frozen page. Called by
    /// the allocator the moment a page's last reference drops (so dead
    /// pages hold no cache entries while on the free stack); page *data*
    /// is never zeroed (a slot is written before any read). Also
    /// invalidates any cached [`PageStore::frozen_tile`] for the page.
    fn reset_page(&mut self, p: PageId);

    /// Write one position's K and V rows into `(page, slot)` of `layer`.
    fn write_row(&mut self, layer: usize, p: PageId, slot: usize, k_row: &[f32], v_row: &[f32]);

    /// Copy the first `rows` slots of `src` into `dst` across every layer
    /// and both planes, including quantizer state (copy-on-write).
    fn copy_rows(&mut self, src: PageId, dst: PageId, rows: usize);

    /// The first `rows` rows of page `p`'s block on `plane` at `layer`,
    /// as a `rows × d_model` f32 slice: borrowed straight from the arena
    /// for f32 storage, dequantized into `scratch` for quantized storage.
    fn block<'a>(
        &'a self,
        plane: Plane,
        layer: usize,
        p: PageId,
        rows: usize,
        scratch: &'a mut Vec<f32>,
    ) -> &'a [f32];

    /// Raw low-bit view of the first `rows` rows of page `p`: the int8
    /// page bytes (`rows × d_model`) and the page's `n_heads` per-head
    /// scales, or `None` for stores with no int8-native representation.
    /// The attention score pass uses this to run q·k as an i32 integer
    /// dot with one `q_scale · page_head_scale` multiply per (page,
    /// head) instead of dequantizing the page.
    fn block_i8(
        &self,
        _plane: Plane,
        _layer: usize,
        _p: PageId,
        _rows: usize,
    ) -> Option<(&[i8], &[f32])> {
        None
    }

    /// Packed-ternary view of the first `rows` K rows of page `p`: raw
    /// pack34 index/sign planes plus per-head absmean scales, or `None`
    /// for stores whose K plane is not ternary. K-plane only (V never
    /// ternarizes); the score pass walks this through per-query LUTs
    /// without ever materializing a dequantized K tile.
    fn block_ternary(&self, _layer: usize, _p: PageId, _rows: usize) -> Option<TernaryBlock<'_>> {
        None
    }

    /// Mark page `p` immutable (prefix-index registration): its bytes and
    /// quantizer scales are now frozen until `reset_page`. Only ever
    /// called on *full* pages (every slot written), so a frozen page can
    /// always be materialized whole. No-op for stores whose pages carry
    /// no mutable quantizer state (f32).
    fn freeze_page(&mut self, _p: PageId) {}

    /// Whether `p` is currently frozen (registration-scale-frozen).
    fn is_frozen(&self, _p: PageId) -> bool {
        false
    }

    /// Dequantized full-page f32 tile of *frozen* page `p`, served from
    /// the store's shared LRU tile cache (a page shared by N sequences is
    /// expanded once per cache residency, not N times per round). `None`
    /// for non-frozen pages, for stores where block reads are free
    /// borrows (f32), or when the cache is disabled. The tile always
    /// holds all `page_size` rows; callers slice the prefix they need.
    fn frozen_tile(&self, _plane: Plane, _layer: usize, _p: PageId) -> Option<Arc<[f32]>> {
        None
    }

    /// Resize the frozen-tile LRU cache to at most `tiles` tiles
    /// (0 disables caching). No-op for stores that never cache.
    fn set_tile_cache_capacity(&mut self, _tiles: usize) {}

    /// `(hits, misses)` of the frozen-tile cache (both 0 when absent).
    fn tile_cache_stats(&self) -> (u64, u64) {
        (0, 0)
    }

    /// Record attention q·k rows served from this store: `native` rows
    /// dotted int8-natively, `dequant` rows via a dequantized f32 tile,
    /// `ternary` rows walked through pack34 LUTs — the per-dtype dot
    /// gauges' numerators/denominator.
    fn record_qk_rows(&self, _native: u64, _dequant: u64, _ternary: u64) {}

    /// Cumulative `(native, dequant, ternary)` q·k row counts recorded
    /// so far.
    fn qk_rows(&self) -> (u64, u64, u64) {
        (0, 0, 0)
    }

    /// Record attention a·V rows accumulated int8-natively (fixed-point
    /// weights × raw int8 V bytes, `simd::av_i8_rows`). No-op for
    /// stores without an int8 V plane.
    fn record_av_rows(&self, _int8: u64) {}

    /// Cumulative int8-native a·V row count recorded so far.
    fn av_rows(&self) -> u64 {
        0
    }

    /// Lease notification from the allocator: page `p` now holds `refs`
    /// live references. Stores with a frozen-tile cache use this to
    /// gate admission — a frozen (prefix-registered) page's refcount is
    /// `leases + 1` (the index itself holds one reference), and a tile
    /// is only worth caching when ≥ 2 sequences actually read it, so
    /// single-reader pages stop evicting genuinely shared ones. A store
    /// never notified (direct use, no allocator) admits everything.
    fn set_page_leases(&mut self, _p: PageId, _refs: u32) {}

    /// Enable/disable the integer a·V accumulation path (the V-plane
    /// [`PageStore::block_i8`] walk). On by default for stores with an
    /// int8 V plane; the off position restores the dequantize-tile V
    /// pass for A/B sweeps. No-op for f32 stores.
    fn set_integer_av(&mut self, _on: bool) {}

    /// Whether the integer a·V path is enabled (always `false` for
    /// stores without an int8 V plane).
    fn integer_av_enabled(&self) -> bool {
        false
    }

    /// Total arena bytes at this dtype (the KV byte budget).
    fn bytes(&self) -> usize;

    /// Bytes one stored position costs across both planes and all layers
    /// (scale bytes amortized over the page) — the kv-bytes-per-token
    /// gauge.
    fn bytes_per_token(&self) -> usize;

    /// K-plane share of [`PageStore::bytes_per_token`]. Symmetric stores
    /// (f32, int8) split evenly; K/V-asymmetric stores override.
    fn k_bytes_per_token(&self) -> usize {
        self.bytes_per_token() / 2
    }

    /// V-plane share of [`PageStore::bytes_per_token`].
    fn v_bytes_per_token(&self) -> usize {
        self.bytes_per_token() - self.k_bytes_per_token()
    }

    /// Cumulative nanoseconds spent dequantizing blocks (0 for f32).
    fn dequant_nanos(&self) -> u64;
}

/// Packed-ternary view of one K page: `rows × n_heads` per-(slot, head)
/// lanes of pack34 bytes plus the page's per-head absmean scales.
///
/// Per (slot, head) lane the layout is byte-aligned: `idx_bh` bytes of
/// 4-bit pattern indices (one nibble per 4-channel block, low nibble
/// first) and `sign_bh` bytes of mirror bits (bit `b % 8` of byte
/// `b / 8` for block `b`). `idx`/`sign` are row-major over
/// `(slot, head)`, so row `r`, head `h` starts at
/// `(r·n_heads + h)·idx_bh` (resp. `·sign_bh`).
pub struct TernaryBlock<'a> {
    /// Pattern-index nibbles, `rows · n_heads · idx_bh` bytes.
    pub idx: &'a [u8],
    /// Mirror bits, `rows · n_heads · sign_bh` bytes.
    pub sign: &'a [u8],
    /// Per-head absmean scales, `n_heads` entries.
    pub scales: &'a [f32],
    /// Index bytes per (slot, head) lane: `(head_dim/4).div_ceil(2)`.
    pub idx_bh: usize,
    /// Sign bytes per (slot, head) lane: `(head_dim/4).div_ceil(8)`.
    pub sign_bh: usize,
}

/// Per-page bytes a store of `dtype` costs for `cfg` — used by the
/// coordinator to turn one fixed byte budget into a dtype-aware page
/// count (int8 pages buy ~4× the positions of f32 pages, ternary ~7×).
/// K and V planes price separately: ternary K packs 4 channels into
/// 5 bits while its V stays int8.
pub fn page_bytes(cfg: &NativeConfig, page_size: usize, dtype: KvDtype) -> usize {
    let (k_plane, v_plane) = match dtype {
        KvDtype::F32 => (page_size * cfg.d_model * 4, page_size * cfg.d_model * 4),
        KvDtype::Int8 => {
            let plane = page_size * cfg.d_model + cfg.n_heads * 4;
            (plane, plane)
        }
        KvDtype::Ternary => {
            let nb = cfg.head_dim() / 4;
            let lane = nb.div_ceil(2) + nb.div_ceil(8);
            (
                page_size * cfg.n_heads * lane + cfg.n_heads * 4,
                page_size * cfg.d_model + cfg.n_heads * 4,
            )
        }
    };
    cfg.n_layers * (k_plane + v_plane)
}

/// Construct the store for `dtype`.
pub fn new_store(cfg: &NativeConfig, num_pages: usize, page_size: usize, dtype: KvDtype) -> Box<dyn PageStore> {
    match dtype {
        KvDtype::F32 => Box::new(F32Store::new(cfg, num_pages, page_size)),
        KvDtype::Int8 => Box::new(Int8Store::new(cfg, num_pages, page_size)),
        KvDtype::Ternary => Box::new(super::ternary::TernaryStore::new(cfg, num_pages, page_size)),
    }
}

// ---------------------------------------------------------------------------
// F32Store — the parity baseline
// ---------------------------------------------------------------------------

/// Full-precision page store: the exact pre-trait layout. Page `p`, slot
/// `s`, channel `c` live at `plane[(p·page_size + s)·d_model + c]`.
pub struct F32Store {
    page_size: usize,
    d_model: usize,
    n_layers: usize,
    num_pages: usize,
    /// Per-layer K planes: `num_pages * page_size * d_model` floats.
    k: Vec<Vec<f32>>,
    /// Per-layer V planes, same shape.
    v: Vec<Vec<f32>>,
    /// q·k rows recorded against this store (always the dequant/borrow
    /// side — there is no int8-native path for f32 pages).
    qk_f32: AtomicU64,
}

impl F32Store {
    pub fn new(cfg: &NativeConfig, num_pages: usize, page_size: usize) -> Self {
        let plane = num_pages * page_size * cfg.d_model;
        Self {
            page_size,
            d_model: cfg.d_model,
            n_layers: cfg.n_layers,
            num_pages,
            k: (0..cfg.n_layers).map(|_| vec![0.0; plane]).collect(),
            v: (0..cfg.n_layers).map(|_| vec![0.0; plane]).collect(),
            qk_f32: AtomicU64::new(0),
        }
    }
}

impl PageStore for F32Store {
    fn dtype(&self) -> KvDtype {
        KvDtype::F32
    }

    fn reset_page(&mut self, _p: PageId) {}

    #[inline]
    fn write_row(&mut self, layer: usize, p: PageId, slot: usize, k_row: &[f32], v_row: &[f32]) {
        debug_assert!(slot < self.page_size);
        let d = self.d_model;
        let base = (p as usize * self.page_size + slot) * d;
        self.k[layer][base..base + d].copy_from_slice(k_row);
        self.v[layer][base..base + d].copy_from_slice(v_row);
    }

    fn copy_rows(&mut self, src: PageId, dst: PageId, rows: usize) {
        debug_assert!(rows <= self.page_size);
        debug_assert_ne!(src, dst, "CoW onto the same page");
        let d = self.d_model;
        let n = rows * d;
        let (s0, d0) = (src as usize * self.page_size * d, dst as usize * self.page_size * d);
        for li in 0..self.n_layers {
            self.k[li].copy_within(s0..s0 + n, d0);
            self.v[li].copy_within(s0..s0 + n, d0);
        }
    }

    #[inline]
    fn block<'a>(
        &'a self,
        plane: Plane,
        layer: usize,
        p: PageId,
        rows: usize,
        _scratch: &'a mut Vec<f32>,
    ) -> &'a [f32] {
        debug_assert!(rows <= self.page_size);
        let d = self.d_model;
        let base = p as usize * self.page_size * d;
        let buf = match plane {
            Plane::K => &self.k[layer],
            Plane::V => &self.v[layer],
        };
        &buf[base..base + rows * d]
    }

    fn record_qk_rows(&self, _native: u64, dequant: u64, _ternary: u64) {
        self.qk_f32.fetch_add(dequant, Ordering::Relaxed);
    }

    fn qk_rows(&self) -> (u64, u64, u64) {
        (0, self.qk_f32.load(Ordering::Relaxed), 0)
    }

    fn bytes(&self) -> usize {
        2 * self.n_layers * self.num_pages * self.page_size * self.d_model * 4
    }

    fn bytes_per_token(&self) -> usize {
        2 * self.n_layers * self.d_model * 4
    }

    fn dequant_nanos(&self) -> u64 {
        0
    }
}

// ---------------------------------------------------------------------------
// Int8Store — quantized pages, per-page-per-head scales
// ---------------------------------------------------------------------------

/// Default frozen-tile cache capacity (tiles). One tile is
/// `page_size × d_model` floats. Since the integer a·V pass took the
/// quantized V walk off the tile cache, only residual f32 consumers
/// (integer-V disabled, diagnostics) read tiles, so the default is
/// small; raise it via `--tile-cache` when running with integer-V off.
/// 0 disables the cache.
pub const DEFAULT_TILE_CACHE_TILES: usize = 16;

/// Lock shards in the frozen-tile cache. Shared prefix pages are the hot
/// case — every sequence in a round hits the same few tiles — so the
/// point is less spreading *keys* than making hits lock-free-ish: a hit
/// takes a shard **read** lock plus one atomic tick store, so concurrent
/// attention workers hammering one hot page no longer serialize the way
/// they did on the old global `Mutex<HashMap>`.
const TILE_SHARDS: usize = 8;

/// One resident tile: the dequantized page plus its last-use tick. The
/// tick is atomic so `get` can refresh it under a shard *read* lock.
pub(crate) struct TileEntry {
    last: AtomicU64,
    tile: Arc<[f32]>,
}

/// Shared LRU cache of dequantized full-page f32 tiles for *frozen*
/// pages. Frozen pages are immutable (bytes and scales), so a cached
/// tile stays valid until the page is freed — `reset_page` invalidates.
/// Concurrent misses on the same page may dequantize twice; both produce
/// identical tiles (frozen bytes, deterministic dequant), so the race is
/// benign and the build runs outside any lock.
///
/// The map is sharded by key ([`TILE_SHARDS`]); hits only ever take one
/// shard's read lock. Eviction preserves **exact global LRU** (the same
/// victim the single-map scan picked): residency is tracked in a global
/// `len` counter and the evictor min-scans every shard for the oldest
/// tick — cap is tens of tiles, so the scan stays cheap, and it only
/// runs on inserts (misses), never on the hit path.
pub(crate) struct TileCache {
    /// Max resident tiles; 0 = disabled.
    pub(crate) cap: usize,
    /// Monotone use-clock for LRU ordering (global across shards).
    tick: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    /// Resident tiles across all shards.
    len: AtomicUsize,
    /// (plane, layer, page) → entry, sharded by [`shard_of`].
    shards: [RwLock<HashMap<(Plane, u32, PageId), TileEntry>>; TILE_SHARDS],
}

/// Deterministic key → shard mix (page dominates: distinct hot pages land
/// on distinct locks; plane/layer separate a page's K/V and layer tiles).
fn shard_of(key: &(Plane, u32, PageId)) -> usize {
    let plane = matches!(key.0, Plane::V) as usize;
    (key.2 as usize)
        .wrapping_add((key.1 as usize).wrapping_mul(31))
        .wrapping_add(plane.wrapping_mul(17))
        % TILE_SHARDS
}

impl TileCache {
    pub(crate) fn new(cap: usize) -> Self {
        Self {
            cap,
            tick: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            len: AtomicUsize::new(0),
            shards: std::array::from_fn(|_| RwLock::new(HashMap::new())),
        }
    }

    pub(crate) fn get(&self, key: (Plane, u32, PageId)) -> Option<Arc<[f32]>> {
        let shard = self.shards[shard_of(&key)].read().unwrap();
        if let Some(e) = shard.get(&key) {
            e.last.store(self.tick.fetch_add(1, Ordering::Relaxed) + 1, Ordering::Relaxed);
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Some(Arc::clone(&e.tile));
        }
        None
    }

    pub(crate) fn insert(&self, key: (Plane, u32, PageId), tile: Arc<[f32]>) {
        self.misses.fetch_add(1, Ordering::Relaxed);
        let now = self.tick.fetch_add(1, Ordering::Relaxed) + 1;
        {
            let mut shard = self.shards[shard_of(&key)].write().unwrap();
            if shard.insert(key, TileEntry { last: AtomicU64::new(now), tile }).is_none() {
                self.len.fetch_add(1, Ordering::Relaxed);
            }
        }
        // Evict past capacity: global min-tick scan across shards (exact
        // LRU, same victim as the pre-sharding single-map scan).
        while self.len.load(Ordering::Relaxed) > self.cap {
            let mut victim: Option<((Plane, u32, PageId), u64)> = None;
            for s in &self.shards {
                let shard = s.read().unwrap();
                for (k, e) in shard.iter() {
                    let last = e.last.load(Ordering::Relaxed);
                    let older = match victim {
                        None => true,
                        Some((_, vt)) => last < vt,
                    };
                    if older {
                        victim = Some((*k, last));
                    }
                }
            }
            let Some((vk, vt)) = victim else { break };
            let mut shard = self.shards[shard_of(&vk)].write().unwrap();
            // Re-check under the write lock: a concurrent hit may have
            // refreshed the victim since the scan — skip it and rescan.
            if let Some(e) = shard.get(&vk) {
                if e.last.load(Ordering::Relaxed) == vt {
                    shard.remove(&vk);
                    self.len.fetch_sub(1, Ordering::Relaxed);
                }
            }
        }
    }

    /// Count a miss whose tile was built but *not* admitted (the
    /// lease-count admission gate declined it), so hit/miss accounting
    /// still balances the access count exactly.
    pub(crate) fn note_miss(&self) {
        self.misses.fetch_add(1, Ordering::Relaxed);
    }

    /// Drop every cached tile of page `p` (page freed / reallocated).
    pub(crate) fn invalidate_page(&self, p: PageId) {
        if self.cap == 0 {
            return;
        }
        for s in &self.shards {
            let mut shard = s.write().unwrap();
            let before = shard.len();
            shard.retain(|&(_, _, page), _| page != p);
            self.len.fetch_sub(before - shard.len(), Ordering::Relaxed);
        }
    }

    pub(crate) fn stats(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }
}

/// Dequantize the first `rows` rows of an int8 plane laid out like
/// [`Int8Store`]'s (page-major data, `p·n_heads + h` scales) into `out`
/// (resized to `rows × d`). Shared by [`Int8Store`] for both planes and
/// by `TernaryStore` for its int8 V plane, so every int8 read path
/// produces identical floats.
#[allow(clippy::too_many_arguments)]
pub(crate) fn dequant_i8_rows(
    data: &[i8],
    scales: &[f32],
    p: usize,
    page_size: usize,
    rows: usize,
    d: usize,
    hd: usize,
    n_heads: usize,
    out: &mut Vec<f32>,
) {
    out.resize(rows * d, 0.0);
    let pbase = p * page_size * d;
    let sbase = p * n_heads;
    for r in 0..rows {
        let rbase = pbase + r * d;
        for h in 0..n_heads {
            let s = scales[sbase + h];
            let col0 = h * hd;
            for c in 0..hd {
                out[r * d + col0 + c] = data[rbase + col0 + c] as f32 * s;
            }
        }
    }
}

/// Int8 page store. Data layout matches [`F32Store`] with 1-byte
/// channels; each (layer, plane, page, head) has one f32 scale at
/// `scales[layer][p·n_heads + h]`, the running `absmax/127` of the rows
/// written to that page so far.
///
/// Quantization happens at page-write time: `q = round(x/s)` clamped to
/// ±127. When a new row's head absmax exceeds the current range, the
/// page's already-written lane for that head is requantized to the grown
/// scale (`q' = round(q·s_old/s_new)`), adding ≤ `0.5·s_new` per event.
/// Each of a page's ≤ `page_size` row writes triggers at most one
/// rescale per head, so the per-element bound is
/// `≤ (page_size + 1)/2 · s_final` (vs one-shot quantization's `0.5·s`);
/// in practice scales grow geometrically when they grow at all and the
/// observed error sits near one quantum (property-tested, both bounds).
pub struct Int8Store {
    page_size: usize,
    d_model: usize,
    n_layers: usize,
    n_heads: usize,
    head_dim: usize,
    num_pages: usize,
    k: Vec<Vec<i8>>,
    v: Vec<Vec<i8>>,
    /// `[layer][p * n_heads + h]` K scales.
    k_scales: Vec<Vec<f32>>,
    /// `[layer][p * n_heads + h]` V scales.
    v_scales: Vec<Vec<f32>>,
    /// Registration-frozen pages: bytes and scales immutable until the
    /// page is freed (`reset_page` thaws). One flag per page, covering
    /// every layer and both planes.
    frozen: Vec<bool>,
    /// LRU of dequantized full-page tiles for frozen pages.
    tiles: TileCache,
    /// Allocator-reported refcount per page; `u32::MAX` = never
    /// notified (no allocator drives this store → admit every tile).
    lease_refs: Vec<u32>,
    /// Integer a·V path toggle (default on): serve the V plane through
    /// `block_i8` so attention accumulates in i32 over raw page bytes.
    integer_av: bool,
    /// Cumulative block-dequantization time (metrics gauge).
    dequant_ns: AtomicU64,
    /// Attention q·k rows served int8-natively / via dequantized tiles.
    qk_native: AtomicU64,
    qk_dequant: AtomicU64,
    /// Attention a·V rows accumulated int8-natively.
    av_int8: AtomicU64,
}

impl Int8Store {
    pub fn new(cfg: &NativeConfig, num_pages: usize, page_size: usize) -> Self {
        assert_eq!(cfg.d_model % cfg.n_heads, 0, "d_model must split into heads");
        let plane = num_pages * page_size * cfg.d_model;
        let scales = num_pages * cfg.n_heads;
        Self {
            page_size,
            d_model: cfg.d_model,
            n_layers: cfg.n_layers,
            n_heads: cfg.n_heads,
            head_dim: cfg.d_model / cfg.n_heads,
            num_pages,
            k: (0..cfg.n_layers).map(|_| vec![0; plane]).collect(),
            v: (0..cfg.n_layers).map(|_| vec![0; plane]).collect(),
            k_scales: (0..cfg.n_layers).map(|_| vec![0.0; scales]).collect(),
            v_scales: (0..cfg.n_layers).map(|_| vec![0.0; scales]).collect(),
            frozen: vec![false; num_pages],
            tiles: TileCache::new(DEFAULT_TILE_CACHE_TILES),
            lease_refs: vec![u32::MAX; num_pages],
            integer_av: true,
            dequant_ns: AtomicU64::new(0),
            qk_native: AtomicU64::new(0),
            qk_dequant: AtomicU64::new(0),
            av_int8: AtomicU64::new(0),
        }
    }

    /// Tile-cache admission: a frozen page's refcount is `leases + 1`
    /// (the prefix index holds one reference), and caching only pays
    /// when ≥ 2 sequences read the tile, so require `refs ≥ 3`. Pages
    /// of a store never lease-notified (`u32::MAX`) always admit.
    fn admit_tile(&self, p: PageId) -> bool {
        let refs = self.lease_refs[p as usize];
        refs == u32::MAX || refs >= 3
    }

    /// Dequantize the first `rows` rows of `(plane, layer, p)` into `out`
    /// (resized to `rows × d_model`). One shared body for scratch-block
    /// reads and frozen-tile builds so both produce identical floats.
    fn dequant_into(&self, plane: Plane, layer: usize, p: PageId, rows: usize, out: &mut Vec<f32>) {
        let (data, scales) = match plane {
            Plane::K => (&self.k[layer], &self.k_scales[layer]),
            Plane::V => (&self.v[layer], &self.v_scales[layer]),
        };
        dequant_i8_rows(
            data,
            scales,
            p as usize,
            self.page_size,
            rows,
            self.d_model,
            self.head_dim,
            self.n_heads,
            out,
        );
    }

    /// Scale of (layer, page, head) on `plane` (tests / diagnostics).
    pub fn scale(&self, plane: Plane, layer: usize, p: PageId, head: usize) -> f32 {
        let s = match plane {
            Plane::K => &self.k_scales[layer],
            Plane::V => &self.v_scales[layer],
        };
        s[p as usize * self.n_heads + head]
    }

    /// Quantize one head-lane of `row` into `(page, slot)`, growing (and
    /// requantizing) the page's head scale when the row exceeds its range.
    /// `pub(crate)`: `TernaryStore` reuses it verbatim for its int8 V
    /// plane so both stores' V bytes are identical for identical writes.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn write_head(
        data: &mut [i8],
        scales: &mut [f32],
        row: &[f32],
        p: usize,
        slot: usize,
        head: usize,
        ps: usize,
        d: usize,
        hd: usize,
        n_heads: usize,
    ) {
        let col0 = head * hd;
        let absmax = row[col0..col0 + hd].iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        let si = p * n_heads + head;
        let mut s = scales[si];
        if absmax > s * 127.0 {
            let s_new = absmax / 127.0;
            if s > 0.0 {
                // Requantize the already-written lane to the grown scale.
                // Unwritten slots hold stale bytes that only shrink in
                // magnitude here and are overwritten before any read.
                let ratio = s / s_new;
                for s2 in 0..ps {
                    let base = (p * ps + s2) * d + col0;
                    for q in &mut data[base..base + hd] {
                        *q = (*q as f32 * ratio).round() as i8;
                    }
                }
            }
            s = s_new;
            scales[si] = s;
        }
        let base = (p * ps + slot) * d + col0;
        if s == 0.0 {
            data[base..base + hd].fill(0);
        } else {
            for (q, &x) in data[base..base + hd].iter_mut().zip(&row[col0..col0 + hd]) {
                *q = (x / s).round().clamp(-127.0, 127.0) as i8;
            }
        }
    }
}

impl PageStore for Int8Store {
    fn dtype(&self) -> KvDtype {
        KvDtype::Int8
    }

    fn reset_page(&mut self, p: PageId) {
        self.frozen[p as usize] = false;
        self.tiles.invalidate_page(p);
        let s0 = p as usize * self.n_heads;
        for li in 0..self.n_layers {
            self.k_scales[li][s0..s0 + self.n_heads].fill(0.0);
            self.v_scales[li][s0..s0 + self.n_heads].fill(0.0);
        }
    }

    fn write_row(&mut self, layer: usize, p: PageId, slot: usize, k_row: &[f32], v_row: &[f32]) {
        debug_assert!(slot < self.page_size);
        debug_assert_eq!(k_row.len(), self.d_model);
        debug_assert!(!self.frozen[p as usize], "write to a registration-frozen page");
        let (ps, d, hd, nh) = (self.page_size, self.d_model, self.head_dim, self.n_heads);
        for h in 0..nh {
            Self::write_head(&mut self.k[layer], &mut self.k_scales[layer], k_row, p as usize, slot, h, ps, d, hd, nh);
            Self::write_head(&mut self.v[layer], &mut self.v_scales[layer], v_row, p as usize, slot, h, ps, d, hd, nh);
        }
    }

    fn copy_rows(&mut self, src: PageId, dst: PageId, rows: usize) {
        debug_assert!(rows <= self.page_size);
        debug_assert_ne!(src, dst, "CoW onto the same page");
        debug_assert!(!self.frozen[dst as usize], "CoW target must be a fresh page");
        let d = self.d_model;
        let n = rows * d;
        let (s0, d0) = (src as usize * self.page_size * d, dst as usize * self.page_size * d);
        let (ss, ds) = (src as usize * self.n_heads, dst as usize * self.n_heads);
        for li in 0..self.n_layers {
            self.k[li].copy_within(s0..s0 + n, d0);
            self.v[li].copy_within(s0..s0 + n, d0);
            // Carry the quantizer state so the copy dequantizes
            // identically and later appends keep growing from it.
            self.k_scales[li].copy_within(ss..ss + self.n_heads, ds);
            self.v_scales[li].copy_within(ss..ss + self.n_heads, ds);
        }
    }

    fn block<'a>(
        &'a self,
        plane: Plane,
        layer: usize,
        p: PageId,
        rows: usize,
        scratch: &'a mut Vec<f32>,
    ) -> &'a [f32] {
        debug_assert!(rows <= self.page_size);
        let t0 = Instant::now();
        self.dequant_into(plane, layer, p, rows, scratch);
        self.dequant_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        &scratch[..rows * self.d_model]
    }

    fn block_i8(
        &self,
        plane: Plane,
        layer: usize,
        p: PageId,
        rows: usize,
    ) -> Option<(&[i8], &[f32])> {
        debug_assert!(rows <= self.page_size);
        let (data, scales) = match plane {
            Plane::K => (&self.k[layer], &self.k_scales[layer]),
            Plane::V => (&self.v[layer], &self.v_scales[layer]),
        };
        let pbase = p as usize * self.page_size * self.d_model;
        let sbase = p as usize * self.n_heads;
        Some((&data[pbase..pbase + rows * self.d_model], &scales[sbase..sbase + self.n_heads]))
    }

    fn freeze_page(&mut self, p: PageId) {
        self.frozen[p as usize] = true;
    }

    fn is_frozen(&self, p: PageId) -> bool {
        self.frozen[p as usize]
    }

    fn frozen_tile(&self, plane: Plane, layer: usize, p: PageId) -> Option<Arc<[f32]>> {
        if self.tiles.cap == 0 || !self.frozen[p as usize] {
            return None;
        }
        let key = (plane, layer as u32, p);
        if let Some(tile) = self.tiles.get(key) {
            return Some(tile);
        }
        // Miss: build the full-page tile outside the lock (frozen pages
        // are fully written and immutable, so a racing duplicate build
        // produces identical bytes).
        let t0 = Instant::now();
        let mut buf = Vec::new();
        self.dequant_into(plane, layer, p, self.page_size, &mut buf);
        self.dequant_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        let tile: Arc<[f32]> = Arc::from(buf);
        if self.admit_tile(p) {
            self.tiles.insert(key, Arc::clone(&tile));
        } else {
            // Single-reader page: serve the tile but keep it out of the
            // cache so it can't evict a genuinely shared one.
            self.tiles.note_miss();
        }
        Some(tile)
    }

    fn set_tile_cache_capacity(&mut self, tiles: usize) {
        self.tiles = TileCache::new(tiles);
    }

    fn tile_cache_stats(&self) -> (u64, u64) {
        self.tiles.stats()
    }

    fn record_qk_rows(&self, native: u64, dequant: u64, _ternary: u64) {
        self.qk_native.fetch_add(native, Ordering::Relaxed);
        self.qk_dequant.fetch_add(dequant, Ordering::Relaxed);
    }

    fn qk_rows(&self) -> (u64, u64, u64) {
        (self.qk_native.load(Ordering::Relaxed), self.qk_dequant.load(Ordering::Relaxed), 0)
    }

    fn record_av_rows(&self, int8: u64) {
        self.av_int8.fetch_add(int8, Ordering::Relaxed);
    }

    fn av_rows(&self) -> u64 {
        self.av_int8.load(Ordering::Relaxed)
    }

    fn set_page_leases(&mut self, p: PageId, refs: u32) {
        self.lease_refs[p as usize] = refs;
    }

    fn set_integer_av(&mut self, on: bool) {
        self.integer_av = on;
    }

    fn integer_av_enabled(&self) -> bool {
        self.integer_av
    }

    fn bytes(&self) -> usize {
        2 * self.n_layers * self.num_pages * (self.page_size * self.d_model + self.n_heads * 4)
    }

    fn bytes_per_token(&self) -> usize {
        // 1 B/channel + the page's per-head scales amortized over its slots.
        2 * self.n_layers * (self.d_model + (self.n_heads * 4).div_ceil(self.page_size))
    }

    fn dequant_nanos(&self) -> u64 {
        self.dequant_ns.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn cfg() -> NativeConfig {
        NativeConfig::named("nano").unwrap()
    }

    #[test]
    fn f32_store_roundtrips_exactly() {
        let cfg = cfg();
        let d = cfg.d_model;
        let mut st = F32Store::new(&cfg, 2, 4);
        let krow: Vec<f32> = (0..d).map(|i| i as f32 * 0.25 - 3.0).collect();
        let vrow: Vec<f32> = krow.iter().map(|x| -x).collect();
        st.write_row(1, 0, 2, &krow, &vrow);
        let mut scratch = Vec::new();
        let blk = st.block(Plane::K, 1, 0, 3, &mut scratch);
        assert_eq!(&blk[2 * d..3 * d], &krow[..]);
        let blk = st.block(Plane::V, 1, 0, 3, &mut scratch);
        assert_eq!(&blk[2 * d..3 * d], &vrow[..]);
        assert_eq!(st.bytes_per_token(), 2 * cfg.n_layers * d * 4);
        assert_eq!(st.dequant_nanos(), 0);
    }

    #[test]
    fn int8_roundtrip_within_rescale_bound() {
        let cfg = cfg();
        let d = cfg.d_model;
        let hd = cfg.head_dim();
        let mut st = Int8Store::new(&cfg, 2, 4);
        st.reset_page(0);
        let mut rng = Pcg64::seeded(11);
        let rows: Vec<Vec<f32>> = (0..4).map(|_| rng.normal_vec(d)).collect();
        for (s, row) in rows.iter().enumerate() {
            st.write_row(0, 0, s, row, row);
        }
        // ≤ one rescale per row write → (rows + 1)/2 quanta worst case.
        let bound_quanta = (rows.len() + 1) as f32 / 2.0;
        let mut scratch = Vec::new();
        let blk = st.block(Plane::K, 0, 0, 4, &mut scratch).to_vec();
        for (s, row) in rows.iter().enumerate() {
            for h in 0..cfg.n_heads {
                let scale = st.scale(Plane::K, 0, 0, h);
                for c in h * hd..(h + 1) * hd {
                    let err = (blk[s * d + c] - row[c]).abs();
                    assert!(
                        err <= bound_quanta * scale + 1e-6,
                        "slot {s} ch {c}: err {err} > {bound_quanta}·scale {scale}"
                    );
                }
            }
        }
    }

    #[test]
    fn int8_scale_grows_and_early_rows_stay_bounded() {
        // Rows of sharply increasing magnitude force requantization; the
        // earliest row must still dequantize within the documented bound
        // of the *final* scale.
        let cfg = cfg();
        let d = cfg.d_model;
        let mut st = Int8Store::new(&cfg, 1, 4);
        st.reset_page(0);
        let rows: Vec<Vec<f32>> = (0..4).map(|s| vec![10f32.powi(s as i32 - 1); d]).collect();
        for (s, row) in rows.iter().enumerate() {
            st.write_row(0, 0, s, row, row);
        }
        let final_scale = st.scale(Plane::K, 0, 0, 0);
        assert!((final_scale - 100.0 / 127.0).abs() < 1e-4, "scale follows the page absmax");
        let mut scratch = Vec::new();
        let blk = st.block(Plane::K, 0, 0, 4, &mut scratch);
        for (s, row) in rows.iter().enumerate() {
            let err = (blk[s * d] - row[0]).abs();
            // Geometric (×10) growth keeps the rescale series convergent:
            // well under the generic (rows+1)/2-quanta bound.
            assert!(err <= 2.5 * final_scale + 1e-6, "slot {s}: err {err}");
        }
        assert!(st.dequant_nanos() > 0, "dequant gauge advanced");
    }

    #[test]
    fn int8_reset_page_clears_quantizer_state() {
        let cfg = cfg();
        let d = cfg.d_model;
        let mut st = Int8Store::new(&cfg, 1, 2);
        st.reset_page(0);
        st.write_row(0, 0, 0, &vec![100.0; d], &vec![100.0; d]);
        assert!(st.scale(Plane::K, 0, 0, 0) > 0.5);
        st.reset_page(0);
        assert_eq!(st.scale(Plane::K, 0, 0, 0), 0.0);
        // A tiny row after reset gets a tiny scale, not the stale one.
        st.write_row(0, 0, 0, &vec![0.01; d], &vec![0.01; d]);
        let s = st.scale(Plane::K, 0, 0, 0);
        assert!((s - 0.01 / 127.0).abs() < 1e-9);
    }

    #[test]
    fn int8_copy_rows_preserves_values_and_state() {
        let cfg = cfg();
        let d = cfg.d_model;
        let mut st = Int8Store::new(&cfg, 2, 4);
        st.reset_page(0);
        st.reset_page(1);
        let mut rng = Pcg64::seeded(5);
        for s in 0..3 {
            let row = rng.normal_vec(d);
            st.write_row(0, 0, s, &row, &row);
        }
        st.copy_rows(0, 1, 3);
        let mut a = Vec::new();
        let mut b = Vec::new();
        assert_eq!(
            st.block(Plane::K, 0, 0, 3, &mut a).to_vec(),
            st.block(Plane::K, 0, 1, 3, &mut b).to_vec(),
            "copy dequantizes identically"
        );
        for h in 0..cfg.n_heads {
            assert_eq!(st.scale(Plane::K, 0, 0, h), st.scale(Plane::K, 0, 1, h));
        }
    }

    #[test]
    fn int8_block_i8_matches_dequantized_block() {
        // The int8-native view must be exactly the bytes/scales the f32
        // dequant path uses: data[i]·scale == block()[i] for every
        // element, so the fused q·k dot differs from the dequant path
        // only by query-quantization error.
        let cfg = cfg();
        let d = cfg.d_model;
        let hd = cfg.head_dim();
        let mut st = Int8Store::new(&cfg, 2, 4);
        st.reset_page(1);
        let mut rng = Pcg64::seeded(7);
        for s in 0..3 {
            let row = rng.normal_vec(d);
            st.write_row(1, 1, s, &row, &row);
        }
        let (data, scales) = st.block_i8(Plane::K, 1, 1, 3).expect("int8 store is int8-native");
        assert_eq!(data.len(), 3 * d);
        assert_eq!(scales.len(), cfg.n_heads);
        let mut scratch = Vec::new();
        let blk = st.block(Plane::K, 1, 1, 3, &mut scratch);
        for r in 0..3 {
            for h in 0..cfg.n_heads {
                for c in h * hd..(h + 1) * hd {
                    assert_eq!(data[r * d + c] as f32 * scales[h], blk[r * d + c]);
                }
            }
        }
        // The f32 store has no int8-native view.
        let f = F32Store::new(&cfg, 1, 4);
        assert!(f.block_i8(Plane::K, 0, 0, 1).is_none());
    }

    #[test]
    fn frozen_tile_serves_cache_and_reset_thaws() {
        let cfg = cfg();
        let d = cfg.d_model;
        let mut st = Int8Store::new(&cfg, 2, 4);
        st.reset_page(0);
        let mut rng = Pcg64::seeded(13);
        for s in 0..4 {
            let row = rng.normal_vec(d);
            st.write_row(0, 0, s, &row, &row);
        }
        // Unfrozen pages never serve tiles (they may still requantize).
        assert!(st.frozen_tile(Plane::V, 0, 0).is_none());
        st.freeze_page(0);
        assert!(st.is_frozen(0));

        let tile = st.frozen_tile(Plane::V, 0, 0).expect("frozen page serves a tile");
        assert_eq!(tile.len(), 4 * d, "tile holds the full page");
        let mut scratch = Vec::new();
        assert_eq!(
            &tile[..],
            st.block(Plane::V, 0, 0, 4, &mut scratch),
            "cached tile is bitwise the scratch dequant"
        );
        // Second read hits the cache.
        let again = st.frozen_tile(Plane::V, 0, 0).unwrap();
        assert_eq!(&tile[..], &again[..]);
        let (hits, misses) = st.tile_cache_stats();
        assert_eq!((hits, misses), (1, 1));

        // Reallocation thaws the page and drops its tiles.
        st.reset_page(0);
        assert!(!st.is_frozen(0));
        assert!(st.frozen_tile(Plane::V, 0, 0).is_none());
    }

    #[test]
    fn tile_cache_capacity_bounds_residency() {
        let cfg = cfg();
        let d = cfg.d_model;
        let mut st = Int8Store::new(&cfg, 3, 2);
        st.set_tile_cache_capacity(1);
        for p in 0..3u32 {
            st.reset_page(p);
            for s in 0..2 {
                st.write_row(0, p, s, &vec![p as f32 + 1.0; d], &vec![p as f32 + 1.0; d]);
            }
            st.freeze_page(p);
        }
        // Touch three pages through a 1-tile cache: every access misses.
        for p in 0..3u32 {
            assert!(st.frozen_tile(Plane::K, 0, p).is_some());
        }
        let (hits, misses) = st.tile_cache_stats();
        assert_eq!((hits, misses), (0, 3));
        // Re-touching the most recent page hits; the evicted one misses.
        assert!(st.frozen_tile(Plane::K, 0, 2).is_some());
        assert!(st.frozen_tile(Plane::K, 0, 0).is_some());
        let (hits, misses) = st.tile_cache_stats();
        assert_eq!((hits, misses), (1, 4));
        // Capacity 0 disables caching entirely.
        st.set_tile_cache_capacity(0);
        assert!(st.frozen_tile(Plane::K, 0, 2).is_none());
    }

    #[test]
    fn tile_cache_concurrent_hits_on_hot_page_stay_coherent() {
        // The sharded-lock regression test: many workers hammering the
        // same hot frozen page (the shared-prefix serving pattern) must
        // all see the identical tile, and the hit/miss accounting must
        // balance the access count exactly.
        let cfg = cfg();
        let d = cfg.d_model;
        let mut st = Int8Store::new(&cfg, 4, 2);
        let mut rng = Pcg64::seeded(29);
        for p in 0..4u32 {
            st.reset_page(p);
            for s in 0..2 {
                let row = rng.normal_vec(d);
                st.write_row(0, p, s, &row, &row);
            }
            st.freeze_page(p);
        }
        let reference: Vec<Arc<[f32]>> =
            (0..4u32).map(|p| st.frozen_tile(Plane::K, 0, p).unwrap()).collect();
        let (hits0, misses0) = st.tile_cache_stats();
        assert_eq!(misses0, 4);

        let pool = crate::util::ThreadPool::new(8);
        const ACCESSES: usize = 64;
        pool.scope(|s| {
            for i in 0..ACCESSES {
                let st = &st;
                let reference = &reference;
                s.spawn(move || {
                    // Page 0 is the hot one; a few accesses spread out.
                    let p = if i % 8 == 0 { (i / 8) as u32 % 4 } else { 0 };
                    let tile = st.frozen_tile(Plane::K, 0, p).unwrap();
                    assert_eq!(&tile[..], &reference[p as usize][..]);
                });
            }
        });
        let (hits, misses) = st.tile_cache_stats();
        assert_eq!(
            hits + misses,
            hits0 + misses0 + ACCESSES as u64,
            "every access is counted exactly once"
        );
        assert_eq!(misses, 4, "all four tiles fit the default capacity: hammering never misses");
    }

    #[test]
    fn tile_admission_requires_two_leases() {
        // The lease-gated admission policy: a frozen page whose lease
        // count (allocator refcount minus the index's own reference) is
        // < 2 still *serves* correct tiles, but never occupies the
        // cache — so single-reader pages can't evict shared ones.
        let cfg = cfg();
        let d = cfg.d_model;
        let mut st = Int8Store::new(&cfg, 2, 2);
        let mut rng = Pcg64::seeded(31);
        for p in 0..2u32 {
            st.reset_page(p);
            for s in 0..2 {
                let row = rng.normal_vec(d);
                st.write_row(0, p, s, &row, &row);
            }
            st.freeze_page(p);
        }
        // Page 0: index + one sequence → lease count 1 → not admitted.
        st.set_page_leases(0, 2);
        let t1 = st.frozen_tile(Plane::V, 0, 0).expect("un-admitted pages still serve tiles");
        let t2 = st.frozen_tile(Plane::V, 0, 0).unwrap();
        assert_eq!(&t1[..], &t2[..], "repeated builds dequantize identically");
        let mut scratch = Vec::new();
        assert_eq!(&t1[..], st.block(Plane::V, 0, 0, 2, &mut scratch));
        let (hits, misses) = st.tile_cache_stats();
        assert_eq!((hits, misses), (0, 2), "both accesses missed: tile never cached");

        // Page 0 gains a second reader → lease count 2 → admitted.
        st.set_page_leases(0, 3);
        assert!(st.frozen_tile(Plane::V, 0, 0).is_some());
        assert!(st.frozen_tile(Plane::V, 0, 0).is_some());
        let (hits, misses) = st.tile_cache_stats();
        assert_eq!((hits, misses), (1, 3), "admitted on miss 3, hit on access 4");

        // Page 1 was never lease-notified → default-admit (direct-store
        // use keeps the pre-admission-policy behavior).
        assert!(st.frozen_tile(Plane::V, 0, 1).is_some());
        assert!(st.frozen_tile(Plane::V, 0, 1).is_some());
        let (hits, misses) = st.tile_cache_stats();
        assert_eq!((hits, misses), (2, 4));
    }

    #[test]
    fn qk_row_counters_accumulate_per_store() {
        let cfg = cfg();
        let q = Int8Store::new(&cfg, 1, 4);
        q.record_qk_rows(10, 2, 0);
        q.record_qk_rows(5, 0, 0);
        assert_eq!(q.qk_rows(), (15, 2, 0));
        let f = F32Store::new(&cfg, 1, 4);
        f.record_qk_rows(0, 7, 0);
        assert_eq!(f.qk_rows(), (0, 7, 0), "f32 stores only ever count dequant rows");
    }

    #[test]
    fn av_row_counter_and_integer_av_toggle() {
        let cfg = cfg();
        let mut q = Int8Store::new(&cfg, 1, 4);
        assert!(q.integer_av_enabled(), "integer a·V defaults on for int8 stores");
        q.record_av_rows(6);
        q.record_av_rows(3);
        assert_eq!(q.av_rows(), 9);
        q.set_integer_av(false);
        assert!(!q.integer_av_enabled());
        let mut f = F32Store::new(&cfg, 1, 4);
        f.record_av_rows(5);
        assert_eq!(f.av_rows(), 0, "f32 stores have no int8 a·V plane");
        f.set_integer_av(true);
        assert!(!f.integer_av_enabled());
    }

    #[test]
    fn kv_dtype_from_name_rejects_unknowns_with_the_valid_set() {
        for d in KvDtype::ALL {
            assert_eq!(KvDtype::from_name(d.name()), Ok(d), "canonical name roundtrips");
        }
        assert_eq!(KvDtype::from_name("i8"), Ok(KvDtype::Int8), "aliases still parse");
        let err = KvDtype::from_name("bf16").unwrap_err();
        assert!(err.contains("\"bf16\""), "error names the offending input: {err}");
        for d in KvDtype::ALL {
            assert!(err.contains(d.name()), "error lists {}: {err}", d.name());
        }
        assert_eq!(KvDtype::valid_names(), "f32|int8|ternary");
    }

    #[test]
    fn symmetric_stores_split_bytes_per_token_evenly() {
        let cfg = cfg();
        for st in [
            Box::new(F32Store::new(&cfg, 1, 16)) as Box<dyn PageStore>,
            Box::new(Int8Store::new(&cfg, 1, 16)),
        ] {
            assert_eq!(st.k_bytes_per_token() + st.v_bytes_per_token(), st.bytes_per_token());
            assert_eq!(st.k_bytes_per_token(), st.v_bytes_per_token(), "{:?}", st.dtype());
        }
    }

    #[test]
    fn int8_halves_bytes_per_token() {
        let cfg = cfg();
        let f = F32Store::new(&cfg, 1, 16);
        let q = Int8Store::new(&cfg, 1, 16);
        assert!(
            q.bytes_per_token() * 2 <= f.bytes_per_token(),
            "int8 {} vs f32 {}",
            q.bytes_per_token(),
            f.bytes_per_token()
        );
        assert!(q.bytes() * 2 <= f.bytes());
        assert_eq!(page_bytes(&cfg, 16, KvDtype::F32), f.bytes());
        assert_eq!(page_bytes(&cfg, 16, KvDtype::Int8), q.bytes());
    }
}
