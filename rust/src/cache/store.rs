//! KV page storage behind the [`PageStore`] trait: the storage *dtype*
//! is a per-pool policy, not a global assumption.
//!
//! The paper's Limitations single out the BF16 KV cache as the dominant
//! transient memory once weights are 1.25-bit; on edge CPUs the decode
//! hot path is memory-bandwidth-bound (BitNet.cpp, TENET), so shrinking
//! KV pages is a latency win as well as a capacity win. Two
//! implementations share one contract:
//!
//! * [`F32Store`] — today's layout (`num_pages × page_size × d_model`
//!   floats per layer per plane). Block reads *borrow* the plane, so the
//!   f32 path stays bit-for-bit identical to the pre-trait engine.
//! * [`Int8Store`] — int8 pages with **per-page-per-head** f32 scales,
//!   quantized at page-write time. A page's (page, head) scale is the
//!   running absmax of the rows written so far; a row that exceeds the
//!   current range *requantizes* the page's head lane to the grown scale
//!   (one extra quantum of error, bounded — see DESIGN.md §4). Block
//!   reads dequantize the page once into a caller scratch tile.
//!
//! The attention kernel consumes pages as whole blocks
//! ([`super::view::Rows::for_each_block`]), so a quantized page is
//! dequantized once per (layer, sequence, step) and then reused for all
//! query·key dot products and value accumulations over that page —
//! the same amortization `gemm_nt` applies to weight planes.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use crate::engine::NativeConfig;

/// Index of a page in the arena.
pub type PageId = u32;

/// KV storage dtype policy for a paged arena.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum KvDtype {
    /// 4 B/channel float pages (parity baseline; bit-for-bit with the
    /// contiguous engine path).
    #[default]
    F32,
    /// 1 B/channel int8 pages + per-page-per-head f32 scales.
    Int8,
}

impl KvDtype {
    pub fn name(&self) -> &'static str {
        match self {
            KvDtype::F32 => "f32",
            KvDtype::Int8 => "int8",
        }
    }

    /// Parse a CLI/config spelling.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "f32" | "fp32" | "float" => Some(KvDtype::F32),
            "int8" | "i8" => Some(KvDtype::Int8),
            _ => None,
        }
    }
}

/// Which of the two KV planes a read addresses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Plane {
    K,
    V,
}

/// Storage backend for the paged KV arena: owns the per-layer K/V pages
/// in whatever byte format, and converts to/from f32 rows at the edges.
///
/// Contract (shared by all implementations, property-tested in
/// `tests/paged_kv.rs`):
/// * a slot is written at most once between `reset_page` calls, and only
///   read after it was written (`rows` in `block` never exceeds the
///   written prefix);
/// * `copy_rows` makes `dst`'s first `rows` slots dequantize to the same
///   values `src`'s did at copy time (CoW-through-store), and carries the
///   quantizer state so `dst` can keep appending;
/// * `block` must not change the values a slot dequantizes to (reads are
///   pure) — only `write_row` may (and for quantized stores only within
///   the documented requantization bound).
pub trait PageStore: Send + Sync {
    fn dtype(&self) -> KvDtype;

    /// Reset per-page quantizer state. Called when a page is (re)allocated;
    /// page *data* is never zeroed (a slot is written before any read).
    fn reset_page(&mut self, p: PageId);

    /// Write one position's K and V rows into `(page, slot)` of `layer`.
    fn write_row(&mut self, layer: usize, p: PageId, slot: usize, k_row: &[f32], v_row: &[f32]);

    /// Copy the first `rows` slots of `src` into `dst` across every layer
    /// and both planes, including quantizer state (copy-on-write).
    fn copy_rows(&mut self, src: PageId, dst: PageId, rows: usize);

    /// The first `rows` rows of page `p`'s block on `plane` at `layer`,
    /// as a `rows × d_model` f32 slice: borrowed straight from the arena
    /// for f32 storage, dequantized into `scratch` for quantized storage.
    fn block<'a>(
        &'a self,
        plane: Plane,
        layer: usize,
        p: PageId,
        rows: usize,
        scratch: &'a mut Vec<f32>,
    ) -> &'a [f32];

    /// Total arena bytes at this dtype (the KV byte budget).
    fn bytes(&self) -> usize;

    /// Bytes one stored position costs across both planes and all layers
    /// (scale bytes amortized over the page) — the kv-bytes-per-token
    /// gauge.
    fn bytes_per_token(&self) -> usize;

    /// Cumulative nanoseconds spent dequantizing blocks (0 for f32).
    fn dequant_nanos(&self) -> u64;
}

/// Per-page bytes a store of `dtype` costs for `cfg` — used by the
/// coordinator to turn one fixed byte budget into a dtype-aware page
/// count (int8 pages buy ~4× the positions of f32 pages).
pub fn page_bytes(cfg: &NativeConfig, page_size: usize, dtype: KvDtype) -> usize {
    let per_plane = match dtype {
        KvDtype::F32 => page_size * cfg.d_model * 4,
        KvDtype::Int8 => page_size * cfg.d_model + cfg.n_heads * 4,
    };
    2 * cfg.n_layers * per_plane
}

/// Construct the store for `dtype`.
pub fn new_store(cfg: &NativeConfig, num_pages: usize, page_size: usize, dtype: KvDtype) -> Box<dyn PageStore> {
    match dtype {
        KvDtype::F32 => Box::new(F32Store::new(cfg, num_pages, page_size)),
        KvDtype::Int8 => Box::new(Int8Store::new(cfg, num_pages, page_size)),
    }
}

// ---------------------------------------------------------------------------
// F32Store — the parity baseline
// ---------------------------------------------------------------------------

/// Full-precision page store: the exact pre-trait layout. Page `p`, slot
/// `s`, channel `c` live at `plane[(p·page_size + s)·d_model + c]`.
pub struct F32Store {
    page_size: usize,
    d_model: usize,
    n_layers: usize,
    num_pages: usize,
    /// Per-layer K planes: `num_pages * page_size * d_model` floats.
    k: Vec<Vec<f32>>,
    /// Per-layer V planes, same shape.
    v: Vec<Vec<f32>>,
}

impl F32Store {
    pub fn new(cfg: &NativeConfig, num_pages: usize, page_size: usize) -> Self {
        let plane = num_pages * page_size * cfg.d_model;
        Self {
            page_size,
            d_model: cfg.d_model,
            n_layers: cfg.n_layers,
            num_pages,
            k: (0..cfg.n_layers).map(|_| vec![0.0; plane]).collect(),
            v: (0..cfg.n_layers).map(|_| vec![0.0; plane]).collect(),
        }
    }
}

impl PageStore for F32Store {
    fn dtype(&self) -> KvDtype {
        KvDtype::F32
    }

    fn reset_page(&mut self, _p: PageId) {}

    #[inline]
    fn write_row(&mut self, layer: usize, p: PageId, slot: usize, k_row: &[f32], v_row: &[f32]) {
        debug_assert!(slot < self.page_size);
        let d = self.d_model;
        let base = (p as usize * self.page_size + slot) * d;
        self.k[layer][base..base + d].copy_from_slice(k_row);
        self.v[layer][base..base + d].copy_from_slice(v_row);
    }

    fn copy_rows(&mut self, src: PageId, dst: PageId, rows: usize) {
        debug_assert!(rows <= self.page_size);
        debug_assert_ne!(src, dst, "CoW onto the same page");
        let d = self.d_model;
        let n = rows * d;
        let (s0, d0) = (src as usize * self.page_size * d, dst as usize * self.page_size * d);
        for li in 0..self.n_layers {
            self.k[li].copy_within(s0..s0 + n, d0);
            self.v[li].copy_within(s0..s0 + n, d0);
        }
    }

    #[inline]
    fn block<'a>(
        &'a self,
        plane: Plane,
        layer: usize,
        p: PageId,
        rows: usize,
        _scratch: &'a mut Vec<f32>,
    ) -> &'a [f32] {
        debug_assert!(rows <= self.page_size);
        let d = self.d_model;
        let base = p as usize * self.page_size * d;
        let buf = match plane {
            Plane::K => &self.k[layer],
            Plane::V => &self.v[layer],
        };
        &buf[base..base + rows * d]
    }

    fn bytes(&self) -> usize {
        2 * self.n_layers * self.num_pages * self.page_size * self.d_model * 4
    }

    fn bytes_per_token(&self) -> usize {
        2 * self.n_layers * self.d_model * 4
    }

    fn dequant_nanos(&self) -> u64 {
        0
    }
}

// ---------------------------------------------------------------------------
// Int8Store — quantized pages, per-page-per-head scales
// ---------------------------------------------------------------------------

/// Int8 page store. Data layout matches [`F32Store`] with 1-byte
/// channels; each (layer, plane, page, head) has one f32 scale at
/// `scales[layer][p·n_heads + h]`, the running `absmax/127` of the rows
/// written to that page so far.
///
/// Quantization happens at page-write time: `q = round(x/s)` clamped to
/// ±127. When a new row's head absmax exceeds the current range, the
/// page's already-written lane for that head is requantized to the grown
/// scale (`q' = round(q·s_old/s_new)`), adding ≤ `0.5·s_new` per event.
/// Each of a page's ≤ `page_size` row writes triggers at most one
/// rescale per head, so the per-element bound is
/// `≤ (page_size + 1)/2 · s_final` (vs one-shot quantization's `0.5·s`);
/// in practice scales grow geometrically when they grow at all and the
/// observed error sits near one quantum (property-tested, both bounds).
pub struct Int8Store {
    page_size: usize,
    d_model: usize,
    n_layers: usize,
    n_heads: usize,
    head_dim: usize,
    num_pages: usize,
    k: Vec<Vec<i8>>,
    v: Vec<Vec<i8>>,
    /// `[layer][p * n_heads + h]` K scales.
    k_scales: Vec<Vec<f32>>,
    /// `[layer][p * n_heads + h]` V scales.
    v_scales: Vec<Vec<f32>>,
    /// Cumulative block-dequantization time (metrics gauge).
    dequant_ns: AtomicU64,
}

impl Int8Store {
    pub fn new(cfg: &NativeConfig, num_pages: usize, page_size: usize) -> Self {
        assert_eq!(cfg.d_model % cfg.n_heads, 0, "d_model must split into heads");
        let plane = num_pages * page_size * cfg.d_model;
        let scales = num_pages * cfg.n_heads;
        Self {
            page_size,
            d_model: cfg.d_model,
            n_layers: cfg.n_layers,
            n_heads: cfg.n_heads,
            head_dim: cfg.d_model / cfg.n_heads,
            num_pages,
            k: (0..cfg.n_layers).map(|_| vec![0; plane]).collect(),
            v: (0..cfg.n_layers).map(|_| vec![0; plane]).collect(),
            k_scales: (0..cfg.n_layers).map(|_| vec![0.0; scales]).collect(),
            v_scales: (0..cfg.n_layers).map(|_| vec![0.0; scales]).collect(),
            dequant_ns: AtomicU64::new(0),
        }
    }

    /// Scale of (layer, page, head) on `plane` (tests / diagnostics).
    pub fn scale(&self, plane: Plane, layer: usize, p: PageId, head: usize) -> f32 {
        let s = match plane {
            Plane::K => &self.k_scales[layer],
            Plane::V => &self.v_scales[layer],
        };
        s[p as usize * self.n_heads + head]
    }

    /// Quantize one head-lane of `row` into `(page, slot)`, growing (and
    /// requantizing) the page's head scale when the row exceeds its range.
    fn write_head(
        data: &mut [i8],
        scales: &mut [f32],
        row: &[f32],
        p: usize,
        slot: usize,
        head: usize,
        ps: usize,
        d: usize,
        hd: usize,
        n_heads: usize,
    ) {
        let col0 = head * hd;
        let absmax = row[col0..col0 + hd].iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        let si = p * n_heads + head;
        let mut s = scales[si];
        if absmax > s * 127.0 {
            let s_new = absmax / 127.0;
            if s > 0.0 {
                // Requantize the already-written lane to the grown scale.
                // Unwritten slots hold stale bytes that only shrink in
                // magnitude here and are overwritten before any read.
                let ratio = s / s_new;
                for s2 in 0..ps {
                    let base = (p * ps + s2) * d + col0;
                    for q in &mut data[base..base + hd] {
                        *q = (*q as f32 * ratio).round() as i8;
                    }
                }
            }
            s = s_new;
            scales[si] = s;
        }
        let base = (p * ps + slot) * d + col0;
        if s == 0.0 {
            data[base..base + hd].fill(0);
        } else {
            for (q, &x) in data[base..base + hd].iter_mut().zip(&row[col0..col0 + hd]) {
                *q = (x / s).round().clamp(-127.0, 127.0) as i8;
            }
        }
    }
}

impl PageStore for Int8Store {
    fn dtype(&self) -> KvDtype {
        KvDtype::Int8
    }

    fn reset_page(&mut self, p: PageId) {
        let s0 = p as usize * self.n_heads;
        for li in 0..self.n_layers {
            self.k_scales[li][s0..s0 + self.n_heads].fill(0.0);
            self.v_scales[li][s0..s0 + self.n_heads].fill(0.0);
        }
    }

    fn write_row(&mut self, layer: usize, p: PageId, slot: usize, k_row: &[f32], v_row: &[f32]) {
        debug_assert!(slot < self.page_size);
        debug_assert_eq!(k_row.len(), self.d_model);
        let (ps, d, hd, nh) = (self.page_size, self.d_model, self.head_dim, self.n_heads);
        for h in 0..nh {
            Self::write_head(&mut self.k[layer], &mut self.k_scales[layer], k_row, p as usize, slot, h, ps, d, hd, nh);
            Self::write_head(&mut self.v[layer], &mut self.v_scales[layer], v_row, p as usize, slot, h, ps, d, hd, nh);
        }
    }

    fn copy_rows(&mut self, src: PageId, dst: PageId, rows: usize) {
        debug_assert!(rows <= self.page_size);
        debug_assert_ne!(src, dst, "CoW onto the same page");
        let d = self.d_model;
        let n = rows * d;
        let (s0, d0) = (src as usize * self.page_size * d, dst as usize * self.page_size * d);
        let (ss, ds) = (src as usize * self.n_heads, dst as usize * self.n_heads);
        for li in 0..self.n_layers {
            self.k[li].copy_within(s0..s0 + n, d0);
            self.v[li].copy_within(s0..s0 + n, d0);
            // Carry the quantizer state so the copy dequantizes
            // identically and later appends keep growing from it.
            self.k_scales[li].copy_within(ss..ss + self.n_heads, ds);
            self.v_scales[li].copy_within(ss..ss + self.n_heads, ds);
        }
    }

    fn block<'a>(
        &'a self,
        plane: Plane,
        layer: usize,
        p: PageId,
        rows: usize,
        scratch: &'a mut Vec<f32>,
    ) -> &'a [f32] {
        debug_assert!(rows <= self.page_size);
        let t0 = Instant::now();
        let (d, hd, nh) = (self.d_model, self.head_dim, self.n_heads);
        let (data, scales) = match plane {
            Plane::K => (&self.k[layer], &self.k_scales[layer]),
            Plane::V => (&self.v[layer], &self.v_scales[layer]),
        };
        scratch.resize(rows * d, 0.0);
        let pbase = p as usize * self.page_size * d;
        let sbase = p as usize * nh;
        for r in 0..rows {
            let rbase = pbase + r * d;
            for h in 0..nh {
                let s = scales[sbase + h];
                let col0 = h * hd;
                for c in 0..hd {
                    scratch[r * d + col0 + c] = data[rbase + col0 + c] as f32 * s;
                }
            }
        }
        self.dequant_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        &scratch[..rows * d]
    }

    fn bytes(&self) -> usize {
        2 * self.n_layers * self.num_pages * (self.page_size * self.d_model + self.n_heads * 4)
    }

    fn bytes_per_token(&self) -> usize {
        // 1 B/channel + the page's per-head scales amortized over its slots.
        2 * self.n_layers * (self.d_model + (self.n_heads * 4).div_ceil(self.page_size))
    }

    fn dequant_nanos(&self) -> u64 {
        self.dequant_ns.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn cfg() -> NativeConfig {
        NativeConfig::named("nano").unwrap()
    }

    #[test]
    fn f32_store_roundtrips_exactly() {
        let cfg = cfg();
        let d = cfg.d_model;
        let mut st = F32Store::new(&cfg, 2, 4);
        let krow: Vec<f32> = (0..d).map(|i| i as f32 * 0.25 - 3.0).collect();
        let vrow: Vec<f32> = krow.iter().map(|x| -x).collect();
        st.write_row(1, 0, 2, &krow, &vrow);
        let mut scratch = Vec::new();
        let blk = st.block(Plane::K, 1, 0, 3, &mut scratch);
        assert_eq!(&blk[2 * d..3 * d], &krow[..]);
        let blk = st.block(Plane::V, 1, 0, 3, &mut scratch);
        assert_eq!(&blk[2 * d..3 * d], &vrow[..]);
        assert_eq!(st.bytes_per_token(), 2 * cfg.n_layers * d * 4);
        assert_eq!(st.dequant_nanos(), 0);
    }

    #[test]
    fn int8_roundtrip_within_rescale_bound() {
        let cfg = cfg();
        let d = cfg.d_model;
        let hd = cfg.head_dim();
        let mut st = Int8Store::new(&cfg, 2, 4);
        st.reset_page(0);
        let mut rng = Pcg64::seeded(11);
        let rows: Vec<Vec<f32>> = (0..4).map(|_| rng.normal_vec(d)).collect();
        for (s, row) in rows.iter().enumerate() {
            st.write_row(0, 0, s, row, row);
        }
        // ≤ one rescale per row write → (rows + 1)/2 quanta worst case.
        let bound_quanta = (rows.len() + 1) as f32 / 2.0;
        let mut scratch = Vec::new();
        let blk = st.block(Plane::K, 0, 0, 4, &mut scratch).to_vec();
        for (s, row) in rows.iter().enumerate() {
            for h in 0..cfg.n_heads {
                let scale = st.scale(Plane::K, 0, 0, h);
                for c in h * hd..(h + 1) * hd {
                    let err = (blk[s * d + c] - row[c]).abs();
                    assert!(
                        err <= bound_quanta * scale + 1e-6,
                        "slot {s} ch {c}: err {err} > {bound_quanta}·scale {scale}"
                    );
                }
            }
        }
    }

    #[test]
    fn int8_scale_grows_and_early_rows_stay_bounded() {
        // Rows of sharply increasing magnitude force requantization; the
        // earliest row must still dequantize within the documented bound
        // of the *final* scale.
        let cfg = cfg();
        let d = cfg.d_model;
        let mut st = Int8Store::new(&cfg, 1, 4);
        st.reset_page(0);
        let rows: Vec<Vec<f32>> = (0..4).map(|s| vec![10f32.powi(s as i32 - 1); d]).collect();
        for (s, row) in rows.iter().enumerate() {
            st.write_row(0, 0, s, row, row);
        }
        let final_scale = st.scale(Plane::K, 0, 0, 0);
        assert!((final_scale - 100.0 / 127.0).abs() < 1e-4, "scale follows the page absmax");
        let mut scratch = Vec::new();
        let blk = st.block(Plane::K, 0, 0, 4, &mut scratch);
        for (s, row) in rows.iter().enumerate() {
            let err = (blk[s * d] - row[0]).abs();
            // Geometric (×10) growth keeps the rescale series convergent:
            // well under the generic (rows+1)/2-quanta bound.
            assert!(err <= 2.5 * final_scale + 1e-6, "slot {s}: err {err}");
        }
        assert!(st.dequant_nanos() > 0, "dequant gauge advanced");
    }

    #[test]
    fn int8_reset_page_clears_quantizer_state() {
        let cfg = cfg();
        let d = cfg.d_model;
        let mut st = Int8Store::new(&cfg, 1, 2);
        st.reset_page(0);
        st.write_row(0, 0, 0, &vec![100.0; d], &vec![100.0; d]);
        assert!(st.scale(Plane::K, 0, 0, 0) > 0.5);
        st.reset_page(0);
        assert_eq!(st.scale(Plane::K, 0, 0, 0), 0.0);
        // A tiny row after reset gets a tiny scale, not the stale one.
        st.write_row(0, 0, 0, &vec![0.01; d], &vec![0.01; d]);
        let s = st.scale(Plane::K, 0, 0, 0);
        assert!((s - 0.01 / 127.0).abs() < 1e-9);
    }

    #[test]
    fn int8_copy_rows_preserves_values_and_state() {
        let cfg = cfg();
        let d = cfg.d_model;
        let mut st = Int8Store::new(&cfg, 2, 4);
        st.reset_page(0);
        st.reset_page(1);
        let mut rng = Pcg64::seeded(5);
        for s in 0..3 {
            let row = rng.normal_vec(d);
            st.write_row(0, 0, s, &row, &row);
        }
        st.copy_rows(0, 1, 3);
        let mut a = Vec::new();
        let mut b = Vec::new();
        assert_eq!(
            st.block(Plane::K, 0, 0, 3, &mut a).to_vec(),
            st.block(Plane::K, 0, 1, 3, &mut b).to_vec(),
            "copy dequantizes identically"
        );
        for h in 0..cfg.n_heads {
            assert_eq!(st.scale(Plane::K, 0, 0, h), st.scale(Plane::K, 0, 1, h));
        }
    }

    #[test]
    fn int8_halves_bytes_per_token() {
        let cfg = cfg();
        let f = F32Store::new(&cfg, 1, 16);
        let q = Int8Store::new(&cfg, 1, 16);
        assert!(
            q.bytes_per_token() * 2 <= f.bytes_per_token(),
            "int8 {} vs f32 {}",
            q.bytes_per_token(),
            f.bytes_per_token()
        );
        assert!(q.bytes() * 2 <= f.bytes());
        assert_eq!(page_bytes(&cfg, 16, KvDtype::F32), f.bytes());
        assert_eq!(page_bytes(&cfg, 16, KvDtype::Int8), q.bytes());
    }
}
